"""Optimizer, collectives/compression, elastic remap, HLO cost analysis."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import (accumulate_microbatches,
                                           compress_int8, decompress_int8,
                                           error_feedback_apply)
from repro.distributed.elastic import best_mesh_shape
from repro.optim import AdamWCfg, apply_updates, init_opt_state, lr_at


def test_adamw_converges_on_quadratic():
    cfg = AdamWCfg(lr_peak=0.1, warmup_steps=5, decay_steps=200,
                   weight_decay=0.0, clip_norm=10.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_clip_and_schedule():
    cfg = AdamWCfg(clip_norm=1.0, warmup_steps=10, decay_steps=100)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(cfg.lr_peak)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(cfg.lr_min)
    params = {"w": jnp.ones((4,))}
    state = init_opt_state(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, m = apply_updates(params, huge, state, cfg)
    assert float(m["clip_scale"]) < 1e-5


def test_bf16_state_dtype_halves_memory():
    cfg = AdamWCfg(state_dtype="bfloat16")
    params = {"w": jnp.zeros((128, 128), jnp.bfloat16)}
    st = init_opt_state(params, cfg)
    assert st.mu["w"].dtype == jnp.bfloat16


def test_int8_compression_roundtrip_error_bounded():
    g = {"a": jnp.asarray([[0.5, -1.0], [2.0, 0.01]])}
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    err = float(jnp.max(jnp.abs(back["a"] - g["a"])))
    assert err <= 2.0 / 127.0


def test_error_feedback_is_lossless_over_time():
    """Sum of compressed grads + final residual == sum of true grads."""
    rng = np.random.default_rng(0)
    total_true = np.zeros((32,), np.float32)
    total_sent = np.zeros((32,), np.float32)
    residual = None
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=32).astype(np.float32) * 1e-3)}
        total_true += np.asarray(g["w"])
        sent, residual = error_feedback_apply(g, residual)
        total_sent += np.asarray(sent["w"], np.float32)
    drift = np.abs(total_sent + np.asarray(residual["w"]) - total_true).max()
    assert drift < 1e-5


def test_accumulate_microbatches_equals_full_grad():
    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    rng = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(rng, (8, 4))}
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 4))
    full_l, full_g = jax.value_and_grad(loss)(p, {"x": x, "y": y})
    mbs = {"x": x.reshape(4, 4, 8), "y": y.reshape(4, 4, 4)}
    acc_l, acc_g = accumulate_microbatches(loss, p, mbs)
    np.testing.assert_allclose(float(acc_l), float(full_l), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(acc_g["w"]),
                               np.asarray(full_g["w"]), rtol=1e-5)


def test_best_mesh_shape_handles_failures():
    assert best_mesh_shape(512, want_pods=2) == ((2, 16, 16),
                                                 ("pod", "data", "model"))
    assert best_mesh_shape(256) == ((16, 16), ("data", "model"))
    # lose 3 nodes -> fall back to largest power-of-two fleet
    shape, axes = best_mesh_shape(253)
    assert int(np.prod(shape)) == 128
    shape, axes = best_mesh_shape(7)
    assert int(np.prod(shape)) == 4


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.distributed.elastic import make_mesh_for, remap_state

mesh8 = make_mesh_for(8, model_cap=4)
assert mesh8.shape == {"data": 2, "model": 4}, mesh8.shape
specs = {"w": P("data", "model"), "b": P()}
state = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((3,))}
st8 = remap_state(state, specs, mesh8)
# simulate losing half the fleet
mesh4 = make_mesh_for(4, model_cap=4)
st4 = remap_state(st8, specs, mesh4)
assert np.array_equal(np.asarray(st4["w"]), np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""


def test_elastic_remap_subprocess():
    """Remap state across shrinking meshes (8 -> 4 devices) in a separate
    process (device count is fixed per process)."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
