"""Small-mesh dry-run: lower+compile representative cells on 8 host devices
in a subprocess (fast version of the full 256/512-chip dry-run)."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # heavyweight model/accelerator tests

_TMPL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import Mesh
from repro.launch import dryrun as D

mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
r = D.analyze_cell("%ARCH%", "%SHAPE%", multi_pod=True, mesh=mesh)
assert r["hlo_flops"] > 0, r
assert r["per_device_bytes"] > 0, r
assert r["bottleneck"] in ("compute", "memory", "collective")
print("CELL_OK", r["bottleneck"], r["hlo_flops"])
"""

CELLS = [
    ("internlm2-1.8b", "train_4k"),      # dense train
    ("mixtral-8x7b", "decode_32k"),      # MoE + SWA decode
    ("rwkv6-7b", "long_500k"),           # SSM long-context decode
    ("whisper-medium", "prefill_32k"),   # enc-dec prefill
]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_lowers_and_compiles(arch, shape):
    src = _TMPL.replace("%ARCH%", arch).replace("%SHAPE%", shape)
    r = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "CELL_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
