"""Property-based tests (hypothesis): random edit scripts on random tables
must satisfy the system's invariants.

Oracle: a plain Python multiset model of the table contents.
"""
from collections import Counter

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Column, CType, ConflictMode, Engine,
                        MergeConflictError, Schema, snapshot_diff, sql_diff,
                        three_way_merge)
from repro.core.compaction import compact_objects

SCH = Schema((Column("k", CType.I64), Column("v", CType.I64)),
             primary_key=("k",))
SCH_NOPK = Schema(SCH.columns, primary_key=None)


def rows_multiset(e, table, directory=None) -> Counter:
    batch, _ = e.table(table).scan(directory)
    return Counter(zip(batch["k"].tolist(), batch["v"].tolist()))


# edit script: list of (op, key, val)
edit = st.tuples(st.sampled_from(["ins", "del", "upd"]),
                 st.integers(0, 39), st.integers(0, 5))
scripts = st.lists(edit, max_size=12)


def apply_script(e: Engine, table: str, script, model: Counter, pk=True):
    """Apply an edit script to both the engine and the python model."""
    for op, key, val in script:
        present = [kv for kv in model if kv[0] == key]
        if op == "ins" and not present:
            e.insert(table, {"k": [key], "v": [val]})
            model[(key, val)] += 1
        elif op == "del" and present:
            e.delete_by_keys(table, {"k": np.asarray([key])})
            model[present[0]] -= 1
            model += Counter()
        elif op == "upd" and present:
            e.update_by_keys(table, {"k": [key], "v": [val]})
            model[present[0]] -= 1
            model[(key, val)] += 1
            model += Counter()


def fresh_engine(n0: int = 10):
    e = Engine()
    e.create_table("T", SCH)
    e.insert("T", {"k": np.arange(n0), "v": np.full(n0, 100)})
    model = Counter({(int(k), 100): 1 for k in range(n0)})
    return e, model


@settings(max_examples=40, deadline=None)
@given(scripts)
def test_engine_matches_multiset_model(script):
    e, model = fresh_engine()
    apply_script(e, "T", script, model)
    assert rows_multiset(e, "T") == +model


@settings(max_examples=40, deadline=None)
@given(scripts, scripts)
def test_diff_equals_sql_and_multiset_difference(s_a, s_b):
    e, model_a = fresh_engine()
    sn = e.create_snapshot("base", "T")
    e.clone_table("U", "base")
    model_b = model_a.copy()
    apply_script(e, "T", s_a, model_a)
    apply_script(e, "U", s_b, model_b)
    a = e.current_snapshot("T")
    b = e.current_snapshot("U")
    d = snapshot_diff(e.store, a, b)
    ds = sql_diff(e.store, a, b)
    # diff == multiset(b) − multiset(a)
    want = +Counter({kv: model_b[kv] - model_a[kv]
                     for kv in set(model_a) | set(model_b)
                     if model_b[kv] != model_a[kv]})
    neg = Counter({kv: model_a[kv] - model_b[kv]
                   for kv in set(model_a) | set(model_b)
                   if model_a[kv] > model_b[kv]})
    assert int(d.diff_cnt[d.diff_cnt > 0].sum()) == sum(want.values())
    assert int(-d.diff_cnt[d.diff_cnt < 0].sum()) == sum(neg.values())
    # Δ-path equals the full-scan baseline
    assert sorted(d.diff_cnt.tolist()) == sorted(ds.diff_cnt.tolist())
    assert sorted(zip(d.row_lo.tolist(), d.diff_cnt.tolist())) == \
        sorted(zip(ds.row_lo.tolist(), ds.diff_cnt.tolist()))


@settings(max_examples=40, deadline=None)
@given(scripts, scripts)
def test_merge_disjoint_edits_is_union(s_t, s_s):
    """If the two branches touch DISJOINT keys, merge == both edit sets."""
    s_t = [(op, k * 2, v) for op, k, v in s_t]        # evens
    s_s = [(op, k * 2 + 1, v) for op, k, v in s_s]    # odds
    e, model = fresh_engine(20)
    sn = e.create_snapshot("base", "T")
    e.clone_table("U", "base")
    model_t, model_s = model.copy(), model.copy()
    apply_script(e, "T", s_t, model_t)
    apply_script(e, "U", s_s, model_s)
    rep = three_way_merge(e, "T", e.current_snapshot("U"), base=sn,
                          mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    # expected: start + t-changes + s-changes
    want = +Counter({kv: model_t[kv] + model_s[kv] - model[kv]
                     for kv in set(model) | set(model_t) | set(model_s)})
    assert rows_multiset(e, "T") == want


@settings(max_examples=30, deadline=None)
@given(scripts, scripts)
def test_merge_accept_respects_source_on_conflicts(s_t, s_s):
    """ACCEPT: every key the source changed ends at the source's version."""
    e, model = fresh_engine()
    sn = e.create_snapshot("base", "T")
    e.clone_table("U", "base")
    model_t, model_s = model.copy(), model.copy()
    apply_script(e, "T", s_t, model_t)
    apply_script(e, "U", s_s, model_s)
    three_way_merge(e, "T", e.current_snapshot("U"), base=sn,
                    mode=ConflictMode.ACCEPT)
    merged = rows_multiset(e, "T")
    src_changed = {k for k in range(40)
                   if {kv for kv in model if kv[0] == k}
                   != {kv for kv in model_s if kv[0] == k}}
    for k in src_changed:
        assert {kv for kv in merged if kv[0] == k} == \
            {kv for kv in model_s if kv[0] == k}, k


@settings(max_examples=25, deadline=None)
@given(scripts)
def test_restore_round_trip(script):
    e, model = fresh_engine()
    before = rows_multiset(e, "T")
    sn = e.create_snapshot("s", "T")
    apply_script(e, "T", script, model.copy())
    e.restore_table("T", "s")
    assert rows_multiset(e, "T") == before


@settings(max_examples=25, deadline=None)
@given(scripts)
def test_compaction_preserves_content_and_diffs(script):
    e, model = fresh_engine()
    sn = e.create_snapshot("s", "T")
    e.clone_table("U", "s")
    apply_script(e, "T", script, model)
    before = rows_multiset(e, "T")
    d_before = snapshot_diff(e.store, e.snapshots["s"],
                             e.current_snapshot("T"))
    compact_objects(e, "T", list(e.table("T").directory.data_oids))
    assert rows_multiset(e, "T") == before
    d_after = snapshot_diff(e.store, e.snapshots["s"],
                            e.current_snapshot("T"))
    assert sorted(d_before.diff_cnt.tolist()) == \
        sorted(d_after.diff_cnt.tolist())
    # snapshot still readable (pinned objects)
    assert rows_multiset(e, "T", e.snapshots["s"].directory) == \
        Counter({(k, 100): 1 for k in range(10)})


@settings(max_examples=25, deadline=None)
@given(scripts)
def test_wal_replay_property(script):
    e, model = fresh_engine()
    apply_script(e, "T", script, model)
    e2 = Engine.replay(e.wal)
    assert rows_multiset(e2, "T") == rows_multiset(e, "T")
