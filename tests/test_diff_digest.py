"""Byte-identity regression guard for the Δ pipeline.

Runs a fixed-seed PK + NoPK update workload and hashes every array of the
resulting ``DiffResult``s (built-in and SQL paths), the merge application
(report counters + post-merge table scan), and a PITR diff. The golden
digests below were recorded on the PR 1 engine; any refactor of the signed-Δ
pipeline (sorted emission, k-way merge, aggregation) must keep them stable —
"sort-free" is an execution strategy, not a semantics change.

All inputs are deterministic: gen_lineitem uses seeded PCG64 (stable streams
across numpy versions), signatures are exact integer math, and sort orders
are fully determined by the 128-bit signatures.
"""
import hashlib

import numpy as np
import pytest

from repro.configs.paper_vcs import gen_lineitem  # noqa: F401 (det. check)
from repro.core import (ConflictMode, Engine, snapshot_diff, sql_diff,
                        three_way_merge)


def _h(update, arr):
    a = np.ascontiguousarray(arr)
    update(a.tobytes())


def diff_digest(d) -> str:
    h = hashlib.sha256()
    for f in ("diff_cnt", "key_lo", "key_hi", "row_lo", "row_hi", "rowid"):
        _h(h.update, getattr(d, f))
    return h.hexdigest()[:16]


def scan_digest(engine, table) -> str:
    batch, rowids, lo, hi = engine.table(table).scan(with_sigs=True)
    h = hashlib.sha256()
    _h(h.update, rowids)
    _h(h.update, lo)
    _h(h.update, hi)
    for name in sorted(batch):
        col = batch[name]
        if col.dtype == object:
            h.update(b"\x00".join(bytes(x) for x in col))
        else:
            _h(h.update, col)
    return h.hexdigest()[:16]


def run_workload(pk: bool, n_rows: int = 50_000, csize: int = 2_000):
    from benchmarks.vcs_tables import _mk_engine, _random_update
    rng = np.random.default_rng([csize] + list(b"DIG"))
    engine, base = _mk_engine(n_rows, pk)
    sn1 = engine.create_snapshot("sn1", "lineitem")
    engine.clone_table("t", sn1)
    _random_update(engine, "t", base, csize, rng, pk)
    sn3 = engine.create_snapshot("sn3", "t")
    cur = engine.current_snapshot("lineitem")

    d_b = snapshot_diff(engine.store, cur, sn3)
    d_s = sql_diff(engine.store, cur, sn3)
    rep = three_way_merge(engine, "lineitem", sn3, base=sn1,
                          mode=ConflictMode.ACCEPT)
    d_pitr = snapshot_diff(engine.store, engine.snapshot_at("lineitem", 1),
                           engine.current_snapshot("lineitem"))
    return {
        "diff": diff_digest(d_b),
        "sql_diff": diff_digest(d_s),
        "merge": f"{rep.inserted}/{rep.deleted}/{rep.true_conflicts}",
        "scan": scan_digest(engine, "lineitem"),
        "pitr": diff_digest(d_pitr),
    }


def _edit(engine, table, base, idx, pk, col="l_quantity", tag=1):
    """Deterministic update of ``base[idx]`` rows on ``table``."""
    newvals = {k: v[idx].copy() for k, v in base.items()}
    if col == "l_quantity":
        newvals["l_quantity"] = newvals["l_quantity"] + 1.0 + tag
    else:
        newvals["l_comment"] = np.array(
            [b"edit-%d-%d" % (tag, i) for i in range(idx.shape[0])],
            dtype=object)
    tx = engine.begin()
    if pk:
        tx.update_by_keys(table, newvals)
    else:
        t = engine.table(table)
        _, rowids = t.scan()
        tx.delete_rowids(table, rowids[idx])
        tx.insert(table, newvals)
    tx.commit()


def _apply_setup(pk, overlap, n_rows=30_000, csize=1_500,
                 cell_cols=False):
    """Engine with target ('lineitem') and source ('t') edits vs sn1.

    ``overlap`` rows are edited by BOTH branches (PK: different values —
    or different columns when ``cell_cols`` — NoPK: source deletes them
    while the target gains duplicate copies, the §3 cardinality conflict).
    """
    from benchmarks.vcs_tables import _mk_engine
    engine, base = _mk_engine(n_rows, pk)
    sn1 = engine.create_snapshot("sn1", "lineitem")
    engine.clone_table("t", sn1)
    rng = np.random.default_rng([n_rows, csize, int(overlap * 1000), int(pk)])
    idx = rng.choice(n_rows, size=2 * csize, replace=False)
    t_idx, s_rest = np.sort(idx[:csize]), np.sort(idx[csize:])
    k = int(overlap * csize)
    ov = t_idx[:k]                       # rows both branches touch
    if pk:
        _edit(engine, "lineitem", base, t_idx, pk, tag=1,
              col="l_comment" if cell_cols else "l_quantity")
        _edit(engine, "t", base, np.sort(np.concatenate([ov, s_rest])),
              pk, tag=2, col="l_quantity")
    else:
        # NoPK §3 cardinality conflict: the target gains a duplicate copy
        # of each overlap row's VALUE while the source deletes that row —
        # residual deltas per value group disagree (+1 vs -1)
        scan_batch, rowids = engine.table("t").scan()  # pristine == sn1
        _edit(engine, "lineitem", base, t_idx[k:], pk, tag=1)
        if k:
            engine.insert("lineitem", {c: v[ov].copy()
                                       for c, v in scan_batch.items()})
        tx = engine.begin()
        if k:
            tx.delete_rowids("t", rowids[ov])
        newvals = {c: v[s_rest].copy() for c, v in scan_batch.items()}
        newvals["l_quantity"] = newvals["l_quantity"] + 5.0
        tx.delete_rowids("t", rowids[s_rest])
        tx.insert("t", newvals)
        tx.commit()
    sn3 = engine.create_snapshot("sn3", "t")
    return engine, sn1, sn3


def run_apply_workload(pk: bool, pack_root=None):
    """Apply-path digests: merge in every conflict mode, revert, publish.

    The scan digest pins the POST-APPLY table bytes (object contents,
    rowids, signatures) — the seal path itself, not just the DiffResult.

    With ``pack_root`` set (ISSUE 10), every engine gets a pack tier and
    is fully evicted before AND after each apply: the same goldens then
    also pin that spill/evict/fault-in round trips are byte-invisible."""
    from benchmarks.vcs_tables import _mk_engine
    seq = [0]

    def _tier(engine):
        if pack_root is None:
            return
        import os
        from repro.store import attach_packs
        if engine.store.packs is None:
            seq[0] += 1
            attach_packs(engine.store,
                         os.path.join(str(pack_root), f"p{seq[0]}"))
        engine.store.evict_all()
    out = {}
    # merges: disjoint edits under FAIL; overlapping under SKIP/ACCEPT/CELL
    modes = [("fail", ConflictMode.FAIL, 0.0, False),
             ("skip", ConflictMode.SKIP, 0.5, False),
             ("accept", ConflictMode.ACCEPT, 0.5, False)]
    if pk:
        modes.append(("cell", ConflictMode.CELL, 0.5, True))
    for name, mode, overlap, cell_cols in modes:
        engine, sn1, sn3 = _apply_setup(pk, overlap, cell_cols=cell_cols)
        _tier(engine)
        rep = three_way_merge(engine, "lineitem", sn3, base=sn1, mode=mode)
        _tier(engine)
        out[f"merge_{name}"] = (
            f"{rep.inserted}/{rep.deleted}/{rep.true_conflicts}/"
            f"{rep.false_conflicts}/{rep.cell_merged}/"
            + scan_digest(engine, "lineitem"))
    # no-base merges (cross-delta §5.3 path)
    engine, sn1, sn3 = _apply_setup(pk, 0.5)
    engine._base.clear()
    _tier(engine)
    rep = three_way_merge(engine, "lineitem", sn3, base=None,
                          mode=ConflictMode.ACCEPT)
    _tier(engine)
    out["merge_nobase"] = (f"{rep.inserted}/{rep.deleted}/"
                           f"{rep.true_conflicts}/"
                           + scan_digest(engine, "lineitem"))
    # revert: undo the ACCEPT merge via the inverse delta
    engine, sn1, sn3 = _apply_setup(pk, 0.0)
    pre = engine.create_snapshot("pre", "lineitem")
    _tier(engine)
    three_way_merge(engine, "lineitem", sn3, base=sn1,
                    mode=ConflictMode.ACCEPT)
    post = engine.create_snapshot("post", "lineitem")
    engine.revert("lineitem", pre, post)
    _tier(engine)
    out["revert"] = scan_digest(engine, "lineitem")
    # publish + revert_publish through the workflow porcelain
    engine, base = _mk_engine(30_000, pk)
    engine.create_branch("dev", ["lineitem"])
    rng = np.random.default_rng([77, pk])
    idx = np.sort(rng.choice(30_000, size=1_500, replace=False))
    _edit(engine, "dev/lineitem", base, idx, pk, tag=3)
    pr = engine.open_pr("main", "dev")
    _tier(engine)
    pr.publish()
    out["publish"] = scan_digest(engine, "lineitem")
    pr.revert_publish()
    _tier(engine)
    out["publish_revert"] = scan_digest(engine, "lineitem")
    return out


# Golden digests recorded on the PR 1 engine (fixed-seed workload above).
GOLDEN = {
    True: {
        "diff": "4953744753d67b10",
        "sql_diff": "4953744753d67b10",
        "merge": "2000/2000/0",
        "scan": "8ef72a49adf021ca",
        "pitr": "593ece73c0d631df",
    },
    False: {
        "diff": "b265412cf4eb3342",
        "sql_diff": "b265412cf4eb3342",
        "merge": "2000/2000/0",
        "scan": "a7500c287b142086",
        "pitr": "7de964732d98a93e",
    },
}


# Apply-path goldens recorded on the PR 3 engine (pre ISSUE 4): the
# sig-carrying seal path must land byte-identical objects.
GOLDEN_APPLY = {
    True: {
        "merge_fail": "1500/1500/0/1500/0/9175a02fb5212c8b",
        "merge_skip": "1500/1500/750/1500/0/4bed1479eb2d935c",
        "merge_accept": "2250/2250/750/1500/0/3dcc9d6952350aea",
        "merge_cell": "2250/2250/750/1500/750/1a9fce248a60f246",
        "merge_nobase": "3000/3000/3000/0a867bd86d60e5c0",
        "revert": "d7d4eebfa086d68b",
        "publish": "1f0ff3dab3c88b9c",
        "publish_revert": "255d731b902dc7bf",
    },
    False: {
        "merge_fail": "1500/1500/0/3000/0/c3ad540e2e7ab79f",
        "merge_skip": "1500/1500/750/3000/0/d8d647613324fefa",
        "merge_accept": "1500/3000/750/3000/0/04a454d7d8aa2a54",
        "merge_nobase": "2250/0/0/f79b73c6652df224",
        "revert": "267ea3643bb54dd8",
        "publish": "6cdb0f2c0762963f",
        "publish_revert": "d6722819d4896927",
    },
}


@pytest.mark.parametrize("pk", [True, False])
def test_diff_pipeline_byte_identical(pk):
    got = run_workload(pk)
    assert got == GOLDEN[pk], got


@pytest.mark.parametrize("pk", [True, False])
def test_apply_path_byte_identical(pk):
    got = run_apply_workload(pk)
    assert got == GOLDEN_APPLY[pk], got


@pytest.mark.parametrize("pk", [True, False])
def test_apply_path_byte_identical_from_evicted_store(pk, tmp_path):
    """ISSUE 10: the SAME goldens with every engine spilled to a pack
    tier and fully evicted around each apply — merge/revert/publish over
    faulted-in objects must land byte-identical tables."""
    got = run_apply_workload(pk, pack_root=tmp_path)
    assert got == GOLDEN_APPLY[pk], got


if __name__ == "__main__":
    import json
    print(json.dumps({("PK" if pk else "NoPK"): run_workload(pk)
                      for pk in (True, False)}, indent=1))
    print(json.dumps({("PK" if pk else "NoPK"): run_apply_workload(pk)
                      for pk in (True, False)}, indent=1))
