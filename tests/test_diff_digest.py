"""Byte-identity regression guard for the Δ pipeline.

Runs a fixed-seed PK + NoPK update workload and hashes every array of the
resulting ``DiffResult``s (built-in and SQL paths), the merge application
(report counters + post-merge table scan), and a PITR diff. The golden
digests below were recorded on the PR 1 engine; any refactor of the signed-Δ
pipeline (sorted emission, k-way merge, aggregation) must keep them stable —
"sort-free" is an execution strategy, not a semantics change.

All inputs are deterministic: gen_lineitem uses seeded PCG64 (stable streams
across numpy versions), signatures are exact integer math, and sort orders
are fully determined by the 128-bit signatures.
"""
import hashlib

import numpy as np
import pytest

from repro.configs.paper_vcs import gen_lineitem  # noqa: F401 (det. check)
from repro.core import (ConflictMode, Engine, snapshot_diff, sql_diff,
                        three_way_merge)


def _h(update, arr):
    a = np.ascontiguousarray(arr)
    update(a.tobytes())


def diff_digest(d) -> str:
    h = hashlib.sha256()
    for f in ("diff_cnt", "key_lo", "key_hi", "row_lo", "row_hi", "rowid"):
        _h(h.update, getattr(d, f))
    return h.hexdigest()[:16]


def scan_digest(engine, table) -> str:
    batch, rowids, lo, hi = engine.table(table).scan(with_sigs=True)
    h = hashlib.sha256()
    _h(h.update, rowids)
    _h(h.update, lo)
    _h(h.update, hi)
    for name in sorted(batch):
        col = batch[name]
        if col.dtype == object:
            h.update(b"\x00".join(bytes(x) for x in col))
        else:
            _h(h.update, col)
    return h.hexdigest()[:16]


def run_workload(pk: bool, n_rows: int = 50_000, csize: int = 2_000):
    from benchmarks.vcs_tables import _mk_engine, _random_update
    rng = np.random.default_rng([csize] + list(b"DIG"))
    engine, base = _mk_engine(n_rows, pk)
    sn1 = engine.create_snapshot("sn1", "lineitem")
    engine.clone_table("t", sn1)
    _random_update(engine, "t", base, csize, rng, pk)
    sn3 = engine.create_snapshot("sn3", "t")
    cur = engine.current_snapshot("lineitem")

    d_b = snapshot_diff(engine.store, cur, sn3)
    d_s = sql_diff(engine.store, cur, sn3)
    rep = three_way_merge(engine, "lineitem", sn3, base=sn1,
                          mode=ConflictMode.ACCEPT)
    d_pitr = snapshot_diff(engine.store, engine.snapshot_at("lineitem", 1),
                           engine.current_snapshot("lineitem"))
    return {
        "diff": diff_digest(d_b),
        "sql_diff": diff_digest(d_s),
        "merge": f"{rep.inserted}/{rep.deleted}/{rep.true_conflicts}",
        "scan": scan_digest(engine, "lineitem"),
        "pitr": diff_digest(d_pitr),
    }


# Golden digests recorded on the PR 1 engine (fixed-seed workload above).
GOLDEN = {
    True: {
        "diff": "4953744753d67b10",
        "sql_diff": "4953744753d67b10",
        "merge": "2000/2000/0",
        "scan": "8ef72a49adf021ca",
        "pitr": "593ece73c0d631df",
    },
    False: {
        "diff": "b265412cf4eb3342",
        "sql_diff": "b265412cf4eb3342",
        "merge": "2000/2000/0",
        "scan": "a7500c287b142086",
        "pitr": "7de964732d98a93e",
    },
}


@pytest.mark.parametrize("pk", [True, False])
def test_diff_pipeline_byte_identical(pk):
    got = run_workload(pk)
    assert got == GOLDEN[pk], got


if __name__ == "__main__":
    import json
    print(json.dumps({("PK" if pk else "NoPK"): run_workload(pk)
                      for pk in (True, False)}, indent=1))
