"""Workflow porcelain e2e (ISSUE 3): branch refs, PRs, CI gating, atomic
publish, Δ-based revert, and GC pin semantics."""
import numpy as np
import pytest

from conftest import VCS_SCHEMA as SCH
from conftest import VCS_SCHEMA_NOPK as SCH_NOPK
from conftest import content_digest as digest
from conftest import kv_batch as _batch
from repro.core import (ConflictMode, Engine, GCStats, MergeConflictError,
                        PKViolation, PublishBlocked, RevertConflict, WAL,
                        snapshot_diff)


def mk_engine(nopk=False):
    e = Engine()
    e.create_table("t", SCH_NOPK if nopk else SCH)
    e.create_table("u", SCH)
    e.insert("t", _batch([1, 2, 3]))
    e.insert("u", _batch([10, 20]))
    return e


# ------------------------------------------------------------- branch refs

def test_branch_is_metadata_only_and_namespaced():
    e = mk_engine()
    bytes_before = e.store.bytes_written
    br = e.create_branch("dev", ["t", "u"])
    assert e.store.bytes_written == bytes_before      # zero data copied
    assert br.tables == {"t": "dev/t", "u": "dev/u"}
    assert set(br.base) == {"t", "u"}
    assert [b.name for b in e.list_branches()] == ["dev"]
    # branch isolation both ways
    e.insert("dev/t", _batch([4]))
    e.delete_by_keys("t", {"k": np.asarray([1])})
    assert e.table("dev/t").count() == 4
    assert e.table("t").count() == 2
    e.drop_branch("dev")
    assert "dev/t" not in e.tables and "dev/u" not in e.tables
    assert e.list_branches() == []


def test_branch_from_branch_and_name_validation():
    e = mk_engine()
    e.create_branch("dev", ["t"])
    e.insert("dev/t", _batch([4]))
    br2 = e.create_branch("dev2", ["t"], from_ref="dev")
    assert br2.parent == "dev"
    assert e.table("dev2/t").count() == 4
    with pytest.raises(ValueError):
        e.create_branch("dev", ["t"])         # exists
    with pytest.raises(ValueError):
        e.create_branch("main", ["t"])        # reserved
    with pytest.raises(ValueError):
        e.create_branch("a/b", ["t"])         # namespace separator
    with pytest.raises(KeyError):
        e.create_branch("x", ["missing"])


def test_list_snapshots():
    e = mk_engine()
    e.create_snapshot("s1", "t")
    e.create_snapshot("s2", "u")
    rows = e.list_snapshots()
    assert [r[0] for r in rows] == ["s1", "s2"]
    assert rows[0][1] == "t"


# ------------------------------------------------------ PR review surfaces

def test_pr_diff_pins_base_horizon():
    e = mk_engine()
    e.create_branch("dev", ["t"])
    e.update_by_keys("dev/t", _batch([2], vals=[99.0]))
    pr = e.open_pr("main", "dev")
    d1 = pr.diff()["t"].n_groups
    # base moves AFTER open: the review diff must not shift
    e.insert("t", _batch([7]))
    assert pr.diff()["t"].n_groups == d1 == 2
    # second review round hits the delta cache
    d = pr.diff()["t"]
    assert d.stats.delta_cache_hits >= 1


def test_dry_run_merge_reports_conflicts_without_mutation():
    e = mk_engine()
    e.create_branch("dev", ["t"])
    e.update_by_keys("dev/t", _batch([2], vals=[99.0]))
    e.update_by_keys("t", _batch([2], vals=[55.0]))    # conflicting base edit
    pr = e.open_pr("main", "dev")
    before = digest(e, "t"), digest(e, "dev/t")
    wal_len = len(e.wal)
    oids = set(e.store.oids())
    rep = pr.dry_run_merge()["t"]
    assert rep.true_conflicts == 1
    assert rep.commit_ts is None
    assert (digest(e, "t"), digest(e, "dev/t")) == before
    assert len(e.wal) == wal_len and set(e.store.oids()) == oids


# ------------------------------------------------- CI checks gate publish

def test_failing_check_blocks_publish_then_fix_publishes():
    e = mk_engine()
    e.create_branch("dev", ["t", "u"])
    e.update_by_keys("dev/t", _batch([2], vals=[999.0]))
    e.insert("dev/u", _batch([30]))
    pr = e.open_pr("main", "dev")
    pr.add_check(lambda ctx: bool((ctx.scan("t")[0]["v"] < 100).all()),
                 "v-limit")
    before = digest(e, "t"), digest(e, "u")
    ts0, oids0 = e.ts, set(e.store.oids())
    with pytest.raises(PublishBlocked) as exc:
        pr.publish()
    assert [c.name for c in exc.value.checks if not c.ok] == ["v-limit"]
    # blocked publish left EVERYTHING untouched: state, ts, store, WAL
    assert (digest(e, "t"), digest(e, "u")) == before
    assert e.ts == ts0 and set(e.store.oids()) == oids0
    assert pr.status == "open"
    # fix on the branch -> checks pass -> atomic publish
    e.update_by_keys("dev/t", _batch([2], vals=[42.0]))
    reports = pr.publish()
    assert pr.status == "published"
    assert pr.publish_ts is not None
    # every table landed at ONE commit timestamp
    assert e.table("t").directory.ts == pr.publish_ts
    assert e.table("u").directory.ts == pr.publish_ts
    assert reports["t"].commit_ts == reports["u"].commit_ts == pr.publish_ts
    assert sorted(e.table("u").scan()[0]["k"].tolist()) == [10, 20, 30]
    assert 42.0 in e.table("t").scan()[0]["v"].tolist()


def test_check_exception_is_a_failure_and_preview_is_ephemeral():
    e = mk_engine()
    e.create_branch("dev", ["t"])
    e.insert("dev/t", _batch([4]))
    pr = e.open_pr("main", "dev")

    def boom(ctx):
        raise RuntimeError("bad data")

    pr.add_check(boom)
    seen = {}

    def peek(ctx):
        seen["count"] = ctx.count("t")
        return True

    pr.add_check(peek, "peek")
    results = pr.run_checks()
    assert [r.ok for r in results] == [False, True]
    assert "RuntimeError" in results[0].error
    # the check saw the MERGED preview (3 base rows + 1 branch row) ...
    assert seen["count"] == 4
    # ... but the preview never escaped: ts, oid counter, WAL all clean
    assert e.table("t").count() == 3
    assert e.ts == Engine.replay(
        WAL.deserialize(e.wal.serialize())).ts


# -------------------------------------------------- publish atomicity

def test_conflict_in_second_table_unwinds_whole_publish():
    e = mk_engine()
    e.create_branch("dev", ["t", "u"])
    e.insert("dev/t", _batch([4]))                        # clean change
    e.update_by_keys("dev/u", _batch([10], vals=[1.0]))   # will conflict
    e.update_by_keys("u", _batch([10], vals=[2.0]))       # divergent base
    pr = e.open_pr("main", "dev")
    before = digest(e, "t"), digest(e, "u")
    ts0 = e.ts
    with pytest.raises(MergeConflictError):
        pr.publish(mode=ConflictMode.FAIL)
    # the clean table did NOT land: all-or-nothing
    assert (digest(e, "t"), digest(e, "u")) == before
    assert e.ts == ts0
    assert pr.status == "open"
    # force-resolve and the same PR publishes atomically
    reports = pr.publish(mode=ConflictMode.ACCEPT)
    assert reports["u"].true_conflicts == 1
    assert e.table("t").directory.ts == e.table("u").directory.ts


def test_publish_conflict_raises_merge_error_even_with_checks():
    """The exception type for a conflict must not depend on whether CI
    checks happen to be registered."""
    e = mk_engine()
    e.create_branch("dev", ["t"])
    e.update_by_keys("dev/t", _batch([2], vals=[9.0]))
    e.update_by_keys("t", _batch([2], vals=[5.0]))     # divergent base
    pr = e.open_pr("main", "dev")
    pr.add_check(lambda ctx: True, "always-green")
    with pytest.raises(MergeConflictError) as exc:
        pr.publish(mode=ConflictMode.FAIL)
    assert exc.value.report.true_conflicts == 1
    assert pr.status == "open"


def test_user_check_named_merge_still_gates_publish():
    """A user check whose name collides with the synthetic preview-conflict
    sentinel must still block publish (structural flag, not name match)."""
    e = mk_engine()
    e.create_branch("dev", ["t"])
    e.insert("dev/t", _batch([4]))
    pr = e.open_pr("main", "dev")

    def merge(ctx):          # fn.__name__ == "merge"
        return False

    pr.add_check(merge)
    with pytest.raises(PublishBlocked):
        pr.publish()
    assert pr.status == "open"
    assert e.table("t").count() == 3


def test_multi_table_commit_unwinds_on_seal_failure():
    """Engine-level atomicity: a PK violation in the second table of one
    transaction leaves the first table untouched and no sealed garbage."""
    e = mk_engine()
    oids0 = set(e.store.oids())
    d_t = e.table("t").directory
    tx = e.begin()
    tx.insert("t", _batch([100]))
    tx.insert("u", _batch([10]))       # duplicate PK in "u"
    with pytest.raises(PKViolation):
        tx.commit()
    assert e.table("t").directory is d_t
    assert e.table("t").count() == 3
    assert set(e.store.oids()) == oids0


# ------------------------------------------------------- Δ-based revert

@pytest.mark.parametrize("nopk", [False, True])
def test_revert_publish_restores_base_and_preserves_history(nopk):
    e = mk_engine(nopk=nopk)
    e.create_branch("dev", ["t", "u"])
    if nopk:
        t = e.table("dev/t")
        _, rowids = t.scan()
        tx = e.begin()
        tx.delete_rowids("dev/t", rowids[:1])
        tx.insert("dev/t", _batch([8, 8], vals=[7.0, 7.0],
                                  docs=[b"x", b"x"]))
        tx.commit()
    else:
        e.update_by_keys("dev/t", _batch([2], vals=[99.0]))
        e.delete_by_keys("dev/t", {"k": np.asarray([3])})
        e.insert("dev/t", _batch([8]))
    e.insert("dev/u", _batch([30]))
    pr = e.open_pr("main", "dev")
    pre = digest(e, "t"), digest(e, "u")
    history_len = len(e.table("t").history)
    pr.publish()
    post = digest(e, "t"), digest(e, "u")
    assert post != pre
    ts_rev = pr.revert_publish()
    assert pr.status == "reverted"
    # base is byte-identical to the pre-publish state ...
    assert (digest(e, "t"), digest(e, "u")) == pre
    # ... via NEW commits, not a head rewrite: history grew monotonically
    # and the published state is still reachable through PITR
    assert ts_rev > pr.publish_ts
    assert len(e.table("t").history) > history_len
    # published state differs from reverted head at the PITR horizon
    snap = e.snapshot_at("t", pr.publish_ts)
    assert snapshot_diff(e.store, snap, e.current_snapshot("t")).n_groups > 0


def test_engine_revert_is_delta_sized_and_strict():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch(np.arange(1000)))
    s1 = e.current_snapshot("t")
    e.update_by_keys("t", _batch([5], vals=[99.0]))
    s2 = e.current_snapshot("t")
    # Δ-sized: the revert scans the changed rows, not the 1000-row table
    ts = e.revert("t", s1, s2)
    assert ts == e.ts
    assert digest_equal(e, s1)
    # inverse of an empty delta is a no-op (no commit)
    s3 = e.current_snapshot("t")
    assert e.revert("t", s3, s3) is None
    # strictness: if the key moved on since, the revert conflicts
    e.update_by_keys("t", _batch([5], vals=[99.0]))
    s4 = e.current_snapshot("t")
    e.update_by_keys("t", _batch([5], vals=[123.0]))   # concurrent edit
    with pytest.raises(RevertConflict):
        e.revert("t", s3, s4)


def digest_equal(e, snap):
    _, _, lo, hi = e.table(snap.table).scan(with_sigs=True)
    _, _, lo2, hi2 = e.table(snap.table).scan(snap.directory,
                                              with_sigs=True)
    o, o2 = np.lexsort((hi, lo)), np.lexsort((hi2, lo2))
    return (np.array_equal(lo[o], lo2[o2])
            and np.array_equal(hi[o], hi2[o2]))


def test_revert_conflict_on_retaken_key():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2]))
    s1 = e.current_snapshot("t")
    e.delete_by_keys("t", {"k": np.asarray([2])})
    s2 = e.current_snapshot("t")
    e.insert("t", _batch([2], vals=[77.0]))     # key re-taken since
    with pytest.raises(RevertConflict):
        e.revert("t", s1, s2)


# ----------------------------------------------------------- GC pinning

def test_gc_honors_pr_pinned_horizons():
    e = Engine(retention_versions=1)
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2, 3]))
    e.create_branch("dev", ["t"])
    e.update_by_keys("dev/t", _batch([2], vals=[9.0]))
    pr = e.open_pr("main", "dev")
    pin_ts = pr.base_pins["t"].created_ts
    # base churns past the retention window
    for i in range(5):
        e.update_by_keys("t", _batch([1], vals=[float(i)]))
    stats = e.gc()
    assert isinstance(stats, GCStats)
    assert stats.pinned_horizons >= 1
    assert stats.versions_pruned > 0
    # the pinned horizon is still resolvable AND scannable after GC
    d = e.table("t").directory_at(pin_ts)
    batch, _ = e.table("t").scan(d)
    assert sorted(batch["k"].tolist()) == [1, 2, 3]
    # review + publish still work after GC
    assert pr.diff()["t"].n_groups == 2
    pr.publish(mode=ConflictMode.ACCEPT)
    # once the PR is done and the branch dropped, the pin is released
    pr.close()
    e.drop_branch("dev")
    e.gc()
    assert e.table("t").count() == 3


def test_gc_keeps_published_pr_revertible():
    e = Engine(retention_versions=1)
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2, 3]))
    e.create_branch("dev", ["t"])
    e.update_by_keys("dev/t", _batch([2], vals=[9.0]))
    pr = e.open_pr("main", "dev")
    pre = digest(e, "t")
    pr.publish()
    e.gc()                       # published PR pins pre/post states
    pr.revert_publish()
    assert digest(e, "t") == pre


def test_gc_retention_zero_keeps_all_history():
    """Engine(retention_versions=0) has always meant 'retain everything'
    (history[-0:] == the whole list) — trim_history must preserve that."""
    e = Engine(retention_versions=0)
    e.create_table("t", SCH)
    e.insert("t", _batch([1]))
    ts1 = e.ts
    for i in range(5):
        e.insert("t", _batch([10 + i]))
    e.gc()
    d = e.table("t").directory_at(ts1)
    batch, _ = e.table("t").scan(d)
    assert batch["k"].tolist() == [1]


def test_drop_branch_refused_while_pr_live():
    e = mk_engine()
    e.create_branch("dev", ["t"])
    pr = e.open_pr("main", "dev")
    with pytest.raises(ValueError):
        e.drop_branch("dev")                 # open PR holds the branch
    e.insert("dev/t", _batch([4]))
    pr.publish()
    with pytest.raises(ValueError):
        e.drop_branch("dev")                 # published PR must stay
    #                                          revertible until closed
    pr.close()
    e.drop_branch("dev")
    assert pr.status == "closed"


def test_noop_publish_and_revert_replay():
    """A PR with no changes publishes (ts=None), reverts as a no-op, and
    the WAL still replays cleanly."""
    e = mk_engine()
    e.create_branch("dev", ["t"])
    pr = e.open_pr("main", "dev")
    reports = pr.publish()
    assert pr.publish_ts is None
    assert reports["t"].inserted == reports["t"].deleted == 0
    assert pr.revert_publish() is None
    e2 = Engine.replay(WAL.deserialize(e.wal.serialize()))
    assert e2.ts == e.ts
    assert digest(e, "t") == digest(e2, "t")


# ------------------------------------------------------------- e2e + WAL

def test_full_workflow_e2e_wal_replay():
    """branch -> mutate -> PR -> blocked -> fix -> atomic publish -> revert,
    then the WAL replays to an identical engine."""
    e = mk_engine()
    e.create_branch("dev", ["t", "u"])
    e.update_by_keys("dev/t", _batch([2], vals=[999.0]))
    e.insert("dev/u", _batch([30]))
    pr = e.open_pr("main", "dev")
    pr.add_check(lambda ctx: bool((ctx.scan("t")[0]["v"] < 100).all()))
    with pytest.raises(PublishBlocked):
        pr.publish()
    e.update_by_keys("dev/t", _batch([2], vals=[42.0]))
    pr.publish()
    pr.revert_publish()
    e.drop_branch("dev")

    e2 = Engine.replay(WAL.deserialize(e.wal.serialize()))
    assert e2.ts == e.ts
    assert set(e2.tables) == set(e.tables)
    for tbl in e.tables:
        assert digest(e, tbl) == digest(e2, tbl), tbl
    assert set(e2.branches) == set(e.branches) == set()
    assert {i: p.status for i, p in e2.prs.items()} == \
        {i: p.status for i, p in e.prs.items()}
