"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle vs the
numpy fast path, swept over shapes/dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.rowhash import rowhash_pallas
from repro.kernels.searchsorted import searchsorted_pallas
from repro.kernels.segsum_diff import segsum_pallas


@pytest.mark.parametrize("rows,lanes", [(1024, 2), (2048, 6), (3072, 24),
                                        (1024, 1)])
def test_rowhash_pallas_vs_ref(rows, lanes):
    rng = np.random.default_rng(rows + lanes)
    x = rng.integers(0, 2**32, size=(rows, lanes), dtype=np.uint32)
    out_k = np.asarray(rowhash_pallas(jnp.asarray(x), interpret=True))
    out_r = np.asarray(ref.rowhash(jnp.asarray(x)))
    out_n = ops._rowhash_np(x)
    assert np.array_equal(out_k, out_r)
    assert np.array_equal(out_r, out_n)


def test_rowhash_avalanche():
    """Flipping any single input bit must flip ~half the signature bits."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2**32, size=(1, 4), dtype=np.uint32)
    base = ops._rowhash_np(x)
    flips = []
    for lane in range(4):
        for bit in (0, 7, 31):
            y = x.copy()
            y[0, lane] ^= np.uint32(1 << bit)
            h = ops._rowhash_np(y)
            flips.append(bin(int(base[0, 0]) ^ int(h[0, 0])).count("1"))
    assert 8 <= np.mean(flips) <= 24  # ~16 of 32 bits


@pytest.mark.parametrize("n,q", [(1, 1024), (1000, 1024), (4096, 2048),
                                 (65536, 1024)])
def test_searchsorted_pallas_vs_numpy(n, q):
    rng = np.random.default_rng(n)
    tab = np.sort(rng.integers(0, 2**63, size=n).astype(np.uint64))
    # include exact hits, misses, extremes
    queries = np.concatenate([
        rng.choice(tab, size=q // 2),
        rng.integers(0, 2**63, size=q // 2 - 2).astype(np.uint64),
        np.asarray([0, 2**63 - 1], np.uint64)])
    t_hi, t_lo = ops.unpack64(tab)
    q_hi, q_lo = ops.unpack64(queries)
    got = np.asarray(searchsorted_pallas(
        jnp.asarray(t_hi), jnp.asarray(t_lo), jnp.asarray(q_hi),
        jnp.asarray(q_lo), interpret=True))
    want = np.searchsorted(tab, queries, side="left")
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,card", [(2048, 3), (4096, 100), (2048, 2048)])
def test_segsum_pallas_vs_oracle(n, card):
    rng = np.random.default_rng(n + card)
    keys64 = np.sort(rng.integers(0, card, size=n).astype(np.uint64))
    hi = (keys64 * np.uint64(7)) % np.uint64(5)  # correlated hi lanes
    signs = rng.choice([-1, 1], size=n).astype(np.int32)
    order, agg = ops.diff_aggregate(keys64, hi, signs)
    # oracle: per unique (lo, hi) pair, net sum
    pairs = np.stack([keys64[order], hi[order]], 1)
    uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
    sums = np.zeros(len(uniq), np.int64)
    np.add.at(sums, inv, signs[order])
    assert len(agg.run_sums) == len(uniq)
    assert np.array_equal(np.sort(agg.run_sums), np.sort(sums.astype(np.int32)))
    # pallas path agrees with numpy fast path
    ops.FORCE_PALLAS_INTERPRET = True
    try:
        order2, agg2 = ops.diff_aggregate(keys64, hi, signs)
    finally:
        ops.FORCE_PALLAS_INTERPRET = False
    assert np.array_equal(order, order2)
    assert np.array_equal(agg.boundary, agg2.boundary)
    assert np.array_equal(agg.run_sums, agg2.run_sums)


def test_lower_bound_dispatch_agreement():
    rng = np.random.default_rng(3)
    tab = np.sort(rng.integers(0, 2**60, size=777).astype(np.uint64))
    q = rng.integers(0, 2**60, size=333).astype(np.uint64)
    ops.FORCE_PALLAS_INTERPRET = True
    try:
        a = ops.lower_bound(tab, q)
    finally:
        ops.FORCE_PALLAS_INTERPRET = False
    b = ops.lower_bound(tab, q)
    assert np.array_equal(a, b)


def test_signatures_padding_path():
    """Non-block-multiple row counts go through the padding path."""
    rng = np.random.default_rng(5)
    lanes = rng.integers(0, 2**32, size=(1025, 4), dtype=np.uint32)
    ops.FORCE_PALLAS_INTERPRET = True
    try:
        lo1, hi1 = ops.signatures_from_lanes(lanes)
    finally:
        ops.FORCE_PALLAS_INTERPRET = False
    lo2, hi2 = ops.signatures_from_lanes(lanes)
    assert np.array_equal(lo1, lo2) and np.array_equal(hi1, hi2)


def test_empty_inputs():
    z64 = np.zeros((0,), np.uint64)
    assert ops.lower_bound(z64, z64).shape == (0,)
    order, agg = ops.diff_aggregate(z64, z64, np.zeros((0,), np.int32))
    assert agg.run_sums.shape == (0,)
    assert ops.rowhash(np.zeros((0, 4), np.uint32)).shape == (0, 4)


# ---------------------------------------------------------- flash attention

@pytest.mark.slow
@pytest.mark.parametrize("sq,sk,hd,causal", [(128, 128, 64, True),
                                             (128, 192, 64, False),
                                             (256, 256, 128, True)])
def test_flash_attention_vs_naive(sq, sk, hd, causal):
    from repro.kernels.flash_attention import flash_attention_pallas
    q = jax.random.normal(jax.random.PRNGKey(0), (2, sq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, sk, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, sk, hd), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    s = jnp.einsum("bqh,bkh->bqk", q, k) / np.sqrt(hd)
    if causal:
        m = jnp.tril(jnp.ones((sq, sk), bool))
        s = jnp.where(m[None], s, -1e30)
    ref_out = jnp.einsum("bqk,bkh->bqh", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_attention_dispatcher_gqa_matches_xla_path():
    from repro.kernels.ops import attention
    B, S, H, KV, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), jnp.float32)
    a = attention(q, k, v, causal=True, impl="pallas", block_q=32,
                  block_k=32, interpret=True)
    b = attention(q, k, v, causal=True, impl="xla", block_q=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
