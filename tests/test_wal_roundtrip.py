"""WAL round-trip property test (ISSUE 3 satellite): serialize ->
deserialize -> ``Engine.replay`` equivalence over EVERY record kind —
the existing storage/transaction kinds plus the new workflow porcelain
records (create_branch/open_pr/publish/publish_revert/revert/...).

Equivalence is asserted on content digests (sorted full-row signatures) of
every table, the engine timestamp, and the porcelain registries."""
import numpy as np
import pytest

from conftest import VCS_SCHEMA as SCH
from conftest import VCS_SCHEMA_NOPK as SCH_NOPK
from conftest import content_digest, kv_batch as _batch
from repro.core import (Column, ConflictMode, CType, Engine, WAL,
                        compact_objects, three_way_merge)
from repro.core.indices import create_index, drop_index
from repro.core.wal import KINDS


def digests(e):
    out = {"__ts__": e.ts,
           "__tables__": tuple(sorted(e.tables)),
           "__snapshots__": tuple(sorted(e.snapshots)),
           "__branches__": tuple(sorted(e.branches)),
           "__prs__": tuple(sorted((i, p.status) for i, p in e.prs.items()))}
    for name in e.tables:
        out[name] = content_digest(e, name)
    return out


def assert_replay_equivalent(e):
    e2 = Engine.replay(WAL.deserialize(e.wal.serialize()))
    assert digests(e2) == digests(e)
    return e2


def test_every_record_kind_round_trips():
    """One deterministic history covering EVERY WAL record kind."""
    e = Engine()
    e.create_table("t", SCH)                                  # create_table
    e.create_table("n", SCH_NOPK)
    e.insert("t", _batch([1, 2, 3, 4]))                       # commit
    e.insert("n", _batch([1, 1, 2], docs=[b"x", b"x", b"y"]))
    e.delete_by_keys("t", {"k": np.asarray([4])})
    e.create_snapshot("s1", "t")                              # snapshot
    e.clone_table("c", "s1")                                  # clone
    e.update_by_keys("c", _batch([2], vals=[77.0]))
    three_way_merge(e, "t", e.current_snapshot("c"),          # set_base
                    mode=ConflictMode.ACCEPT)
    e.restore_table("c", "s1")                                # restore
    create_index(e, "t", "by_v", ["v"])                       # create_index
    e.insert("t", _batch([10]))
    drop_index(e, "t", "by_v")                                # drop_index
    e.alter_table_add_column("n", Column("tag", CType.I64),   # alter_add_
                             0)                               # column
    compact_objects(e, "t", list(e.table("t").directory.data_oids))  # compact
    e.create_snapshot("tmp", "t")
    e.drop_snapshot("tmp")                                    # drop_snapshot
    e.drop_table("c")                                         # drop_table
    # workflow porcelain
    e.create_branch("dev", ["t"])                             # create_branch
    e.update_by_keys("dev/t", _batch([2], vals=[5.0]))
    pr = e.open_pr("main", "dev")                             # open_pr
    pr.publish()                                              # publish
    pr.revert_publish()                                       # publish_revert
    pr2 = e.open_pr(None, "dev")
    pr2.close()                                               # close_pr
    s_a = e.current_snapshot("t")
    e.update_by_keys("t", _batch([1], vals=[44.0]))
    s_b = e.current_snapshot("t")
    e.revert("t", s_a, s_b)                                   # revert
    e.drop_branch("dev")                                      # drop_branch

    assert {r.kind for r in e.wal} == KINDS, (
        "history must exercise every WAL record kind")
    assert_replay_equivalent(e)


def test_aborted_transactions_leave_no_replay_trace():
    """A failed commit consumes NO oid and NO timestamp: it is not WAL
    logged, so any leaked allocation would desynchronize every later
    rowid-bearing record at replay (regression: _commit now rolls back
    store._next_oid and engine.ts on abort)."""
    from repro.core import PKViolation, TxnConflict
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2, 3]))
    ts0, oid0 = e.ts, e.store._next_oid
    with pytest.raises(PKViolation):
        e.insert("t", _batch([1]))              # duplicate key -> abort
    assert (e.ts, e.store._next_oid) == (ts0, oid0)
    _, rowids = e.table("t").scan()
    e.delete_by_keys("t", {"k": np.asarray([3])})
    tx = e.begin()
    tx.delete_rowids("t", rowids[-1:])          # row already dead -> abort
    with pytest.raises(TxnConflict):
        tx.commit()
    assert (e.ts, e.store._next_oid) == (ts0 + 1, oid0 + 1)
    # post-abort history (rowid deletes included) still replays exactly
    e.update_by_keys("t", _batch([2], vals=[9.0]))
    e.delete_by_keys("t", {"k": np.asarray([1])})
    assert_replay_equivalent(e)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_history_round_trips(seed):
    """Seeded random op sequences over the full kind menu replay exactly."""
    rng = np.random.default_rng(seed)
    e = Engine()
    e.create_table("t", SCH)
    e.create_table("n", SCH_NOPK)
    next_key = [0]
    live_keys = []
    snap_i = [0]
    open_prs = []
    published = []

    def fresh(nrows):
        ks = list(range(next_key[0], next_key[0] + nrows))
        next_key[0] += nrows
        live_keys.extend(ks)
        return ks

    def op_insert():
        e.insert("t", _batch(fresh(int(rng.integers(1, 20)))))

    def op_insert_nopk():
        k = int(rng.integers(0, 5))
        e.insert("n", _batch([k, k], docs=[b"z", b"z"]))

    def op_update():
        if not live_keys:
            return
        ks = rng.choice(live_keys, size=min(3, len(live_keys)),
                        replace=False)
        e.update_by_keys("t", _batch(ks, vals=rng.random(ks.shape[0])))

    def op_delete():
        if len(live_keys) < 2:
            return
        k = live_keys.pop(int(rng.integers(0, len(live_keys))))
        e.delete_by_keys("t", {"k": np.asarray([k])})

    def op_snapshot():
        e.create_snapshot(f"s{snap_i[0]}", "t")
        snap_i[0] += 1

    def op_drop_snapshot():
        if e.snapshots:
            name = sorted(e.snapshots)[int(rng.integers(0, len(e.snapshots)))]
            e.drop_snapshot(name)

    def op_compact():
        compact_objects(e, "t", list(e.table("t").directory.data_oids))

    def op_gc():
        # NOT WAL-logged by design: replay keeps more garbage but the same
        # logical state — exactly what the digest compare verifies
        e.gc()

    def op_branch_cycle():
        if "dev" in e.branches or not live_keys:
            return
        e.create_branch("dev", ["t"])
        ks = rng.choice(live_keys, size=min(2, len(live_keys)),
                        replace=False)
        e.update_by_keys("dev/t", _batch(ks, vals=rng.random(ks.shape[0])))
        pr = e.open_pr("main", "dev")
        open_prs.append(pr)

    def op_publish():
        if not open_prs:
            return
        pr = open_prs.pop()
        pr.publish(mode=ConflictMode.ACCEPT)
        if rng.random() < 0.5:
            pr.revert_publish()
        else:
            published.append(pr)

    def op_drop_branch():
        if "dev" not in e.branches:
            return
        for pr in list(open_prs):
            pr.close()
            open_prs.remove(pr)
        for pr in list(published):           # published PRs hold the branch
            pr.close()
            published.remove(pr)
        e.drop_branch("dev")

    menu = [op_insert, op_insert, op_insert_nopk, op_update, op_update,
            op_delete, op_snapshot, op_drop_snapshot, op_compact, op_gc,
            op_branch_cycle, op_publish, op_drop_branch]
    op_insert()
    for _ in range(40):
        menu[int(rng.integers(0, len(menu)))]()
    assert_replay_equivalent(e)


# --------------------------------------------------------------------------
# crash at EVERY record boundary (ISSUE 6): a log cut anywhere must replay
# deterministically to exactly one of the states a clean run passes through
# --------------------------------------------------------------------------

def _assert_every_boundary_is_all_or_nothing(e, states):
    """Cut e's log after every record; each prefix must replay (twice,
    byte-identically) to an op-boundary state — a cut inside a multi-table
    commit group collapses to the pre-transaction state, never a partial
    one."""
    records = e.wal.records
    for k in range(len(records) + 1):
        w1, w2 = WAL(), WAL()
        w1.records = list(records[:k])
        w2.records = list(records[:k])
        r1, r2 = Engine.replay(w1), Engine.replay(w2)
        d1 = digests(r1)
        assert d1 == digests(r2), f"replay at boundary {k} nondeterministic"
        assert r1.commit_log == r2.commit_log
        assert d1 in states, (
            f"cut after record {k}: recovered state is not an op boundary "
            "(a partial operation survived the crash)")


def _stepper(e):
    states = [digests(e)]

    def step(fn):
        fn()
        states.append(digests(e))
    return step, states


def test_crash_at_every_record_boundary_is_all_or_nothing():
    """Deterministic mixed history: storage ops, a multi-table transaction
    (2 records, 1 boundary inside the group), and the porcelain cycle."""
    e = Engine()
    step, states = _stepper(e)
    step(lambda: e.create_table("t", SCH))
    step(lambda: e.create_table("u", SCH))
    step(lambda: e.insert("t", _batch([1, 2, 3])))
    step(lambda: e.insert("u", _batch([10, 11])))

    def multi():
        tx = e.begin()
        tx.insert("t", _batch([4]))
        tx.insert("u", _batch([12]))
        tx.commit()
    step(multi)
    step(lambda: e.delete_by_keys("t", {"k": np.asarray([3])}))
    step(lambda: e.create_snapshot("s1", "t"))
    step(lambda: e.create_branch("dev", ["t", "u"]))
    step(lambda: e.update_by_keys("dev/t", _batch([2], vals=[9.0])))
    pr_box = []
    step(lambda: pr_box.append(e.open_pr("main", "dev")))
    step(lambda: pr_box[0].publish())
    step(lambda: pr_box[0].revert_publish())
    step(lambda: compact_objects(
        e, "t", list(e.table("t").directory.data_oids)))
    step(lambda: e.update_by_keys("t", _batch([1], vals=[5.0])))
    _assert_every_boundary_is_all_or_nothing(e, states)


@pytest.mark.parametrize("seed", [5, 6, 7])
def test_random_history_every_boundary(seed):
    """Seeded-random op sequences x a crash at every WAL record boundary:
    the torn prefix always lands on (exactly) an op-boundary state."""
    rng = np.random.default_rng(seed)
    e = Engine()
    step, states = _stepper(e)
    step(lambda: e.create_table("t", SCH))
    step(lambda: e.create_table("u", SCH))
    next_key = [0]
    live = []

    def fresh(n):
        ks = list(range(next_key[0], next_key[0] + n))
        next_key[0] += n
        live.extend(ks)
        return ks

    for _ in range(25):
        r = rng.random()
        if r < 0.35:
            b = _batch(fresh(int(rng.integers(1, 6))))
            step(lambda: e.insert("t", b))
        elif r < 0.50:
            bt, bu = _batch(fresh(2)), _batch([int(rng.integers(50, 99))])

            def multi():
                tx = e.begin()
                tx.insert("t", bt)
                tx.insert("u", bu)
                tx.commit()
            step(multi)
        elif r < 0.65 and live:
            ks = rng.choice(live, size=min(2, len(live)), replace=False)
            b = _batch(ks, vals=rng.random(ks.shape[0]))
            step(lambda: e.update_by_keys("t", b))
        elif r < 0.75 and len(live) > 1:
            k = live.pop(int(rng.integers(0, len(live))))
            step(lambda: e.delete_by_keys("t", {"k": np.asarray([k])}))
        elif r < 0.85:
            name = f"s{len(e.snapshots)}"
            step(lambda: e.create_snapshot(name, "t"))
        elif "dev" not in e.branches and live:
            step(lambda: e.create_branch("dev", ["t"]))
            ks = rng.choice(live, size=1)
            b = _batch(ks, vals=rng.random(1))
            step(lambda: e.update_by_keys("dev/t", b))
            box = []
            step(lambda: box.append(e.open_pr("main", "dev")))
            step(lambda: box[0].publish(mode=ConflictMode.ACCEPT))
            step(lambda: box[0].revert_publish())
        else:
            step(lambda: compact_objects(
                e, "t", list(e.table("t").directory.data_oids)))
    _assert_every_boundary_is_all_or_nothing(e, states)
