"""Versioned checkpointing, fault tolerance, and the data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, VcsCheckpointer
from repro.core import Engine, snapshot_diff
from repro.data import (BatchPipeline, PinnedDataset, PipelineCfg,
                        add_samples, create_token_table, synth_corpus)


def _state(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {"w": (jax.random.normal(k, (64, 64)) * scale),
            "b": jnp.arange(8, dtype=jnp.float32),
            "step": jnp.zeros((), jnp.int32)}


def _eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_ckpt_save_restore_roundtrip():
    e = Engine()
    ck = VcsCheckpointer(e)
    s0 = _state()
    ck.save(s0, 0)
    s1 = _state(seed=1)
    ck.save(s1, 1)
    got0 = ck.restore("step-0", jax.tree.map(jnp.zeros_like, s0))
    got1 = ck.restore("step-1", jax.tree.map(jnp.zeros_like, s1))
    assert _eq(got0, s0) and _eq(got1, s1)


def test_ckpt_rollback_and_fork():
    e = Engine()
    ck = VcsCheckpointer(e)
    s0, s1 = _state(0), _state(1)
    ck.save(s0, 0)
    ck.save(s1, 1)
    ck.rollback("step-0")                       # instant revert
    cur = ck.restore(e.current_snapshot("ckpt"), jax.tree.map(
        jnp.zeros_like, s0))
    assert _eq(cur, s0)
    fork = ck.fork("ckpt_ft", "step-1")         # instant fine-tune fork
    got = fork.restore(e.current_snapshot("ckpt_ft"),
                       jax.tree.map(jnp.zeros_like, s1))
    assert _eq(got, s1)


def test_ckpt_incremental_diff_counts_changed_shards():
    e = Engine()
    ck = VcsCheckpointer(e)
    s0 = _state()
    ck.save(s0, 0)
    s1 = dict(s0)
    s1["b"] = s0["b"] + 1                       # change ONE tensor
    ck.save(s1, 1)
    changed = ck.changed_shards("step-0", "step-1")
    total = len(e.table("ckpt").scan()[0]["shard_id"])
    assert 0 < changed <= 2 * 2                 # tiny tensor: few shards
    assert changed < total                      # unchanged shards cancel


def test_manager_nan_rollback():
    e = Engine()
    cm = CheckpointManager(e, every=1, keep=2)
    s = _state()
    cm.maybe_save(s, 0)
    assert not cm.healthy(float("nan"))
    bad = jax.tree.map(lambda a: a * jnp.nan, s)
    recovered = cm.recover(bad)
    assert _eq(recovered, s)


def test_trainer_end_to_end_with_fault():
    from repro.launch.train import train_loop
    state, losses, engine = train_loop(
        "qwen1.5-0.5b", steps=30, seq_len=32, global_batch=4,
        ckpt_every=5, inject_fault_at=12, log_every=100)
    assert len(losses) >= 30              # all owed steps eventually done
    assert all(np.isfinite(l) for l in losses)
    # actually learns (synthetic corpus has repeating structure)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


# ------------------------------------------------------------- pipeline

def test_pipeline_deterministic_and_resumable():
    e = Engine()
    create_token_table(e, "c")
    synth_corpus(e, "c", n_samples=32, sample_len=33, vocab=100)
    snap = e.create_snapshot("pin", "c")
    ds = PinnedDataset(e, snap)
    p1 = BatchPipeline(ds, PipelineCfg(seq_len=32, global_batch=4, seed=7))
    p2 = BatchPipeline(ds, PipelineCfg(seq_len=32, global_batch=4, seed=7))
    b_a = p1.batch_at(5)
    b_b = p2.batch_at(5)                  # fresh pipeline, same step
    assert np.array_equal(b_a["tokens"], b_b["tokens"])
    assert np.array_equal(b_a["targets"], b_b["targets"])


def test_pipeline_host_sharding_partitions_global_batch():
    e = Engine()
    create_token_table(e, "c")
    synth_corpus(e, "c", n_samples=32, sample_len=33, vocab=100)
    snap = e.create_snapshot("pin", "c")
    ds = PinnedDataset(e, snap)
    full = BatchPipeline(ds, PipelineCfg(seq_len=32, global_batch=8)).batch_at(3)
    parts = [BatchPipeline(ds, PipelineCfg(seq_len=32, global_batch=8,
                                           host_index=i, host_count=4)
                           ).batch_at(3) for i in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts])
    assert np.array_equal(stacked, full["tokens"])


def test_pinned_snapshot_isolates_training_from_edits():
    e = Engine()
    create_token_table(e, "c")
    synth_corpus(e, "c", n_samples=16, sample_len=33, vocab=100)
    snap = e.create_snapshot("pin", "c")
    ds = PinnedDataset(e, snap)
    before = ds.n
    add_samples(e, "c", np.arange(1000, 1010),
                [np.arange(33, dtype=np.uint32)] * 10)
    ds2 = PinnedDataset(e, snap)          # re-read the SAME pin
    assert ds2.n == before                # edits invisible to the pin
    assert PinnedDataset(e, e.current_snapshot("c")).n == before + 10
