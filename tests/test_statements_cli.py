"""Golden parity (ISSUE 5): one workflow, three surfaces.

The SAME branch -> PR -> publish -> revert -> merge -> clone -> gc workflow
is driven through (a) the ``Repo`` Python API, (b) the statement layer, and
(c) the ``datagit`` CLI (each invocation replaying its WAL store file) —
and must produce byte-identical table scans (GOLDEN_APPLY-style content
digests), identical engine timestamps, and identical commit logs. A WAL
replay of the statement-driven session must reproduce the same state.
"""
import numpy as np
import pytest

from conftest import content_digest as digest
from repro import vcs_cli
from repro.core import Engine, Repo, WAL
from repro.core.statements import (StatementError, execute, execute_script)


# --------------------------------------------------------------------------
# one workflow, three drivers
# --------------------------------------------------------------------------
# Each step is (python_fn, statement, cli_argv). DML steps (seed/mutate)
# share the CLI's deterministic helpers on every surface, so any divergence
# is the porcelain's fault, not the data's.

def _init_store(tmp_path) -> str:
    store = str(tmp_path / "s.wal")
    assert vcs_cli.main(["--store", store, "init"]) == 0
    return store


def _steps():
    return [
        (lambda r: vcs_cli.seed_table(r, "orders", 500, 0),
         None, ["seed", "orders", "--rows", "500", "--seed", "0"]),
        (lambda r: r.tag("night", "orders"),
         "CREATE SNAPSHOT night FOR TABLE orders",
         ["snapshot", "night", "orders"]),
        (lambda r: r.branch("dev", ["orders"]),
         "CREATE BRANCH dev FOR (orders)",
         ["branch", "dev", "-t", "orders"]),
        (lambda r: vcs_cli.mutate_table(r, "dev/orders", 40, 7),
         None, ["mutate", "dev/orders", "--rows", "40", "--seed", "7"]),
        (lambda r: r.diff("branch:dev", "HEAD", table="orders"),
         "DIFF 'branch:dev' AGAINST 'HEAD' FOR TABLE orders",
         ["diff", "branch:dev", "HEAD", "--table", "orders"]),
        (lambda r: r.open_pr("dev"),
         "OPEN PR FROM dev INTO main",
         ["pr", "open", "dev", "--into", "main"]),
        (lambda r: r.check(1),
         "CHECK PR 1", ["pr", "check", "1"]),
        (lambda r: r.publish(1),
         "PUBLISH PR 1", ["publish", "1"]),
        (lambda r: r.log("orders"),
         "LOG TABLE orders", ["log", "orders"]),
        (lambda r: r.revert_pr(1),
         "REVERT PR 1", ["revert-pr", "1"]),
        (lambda r: vcs_cli.mutate_table(r, "dev/orders", 10, 11),
         None, ["mutate", "dev/orders", "--rows", "10", "--seed", "11"]),
        (lambda r: r.merge("branch:dev", "branch:main", mode="theirs"),
         "MERGE BRANCH dev INTO main MODE theirs",
         ["merge", "dev", "main", "--mode", "theirs"]),
        (lambda r: r.clone("orders_night", "snap:night"),
         "CLONE TABLE orders_night FROM 'snap:night'",
         ["clone", "orders_night", "snap:night"]),
        (lambda r: r.revert("orders", "orders~1", "HEAD"),
         "REVERT TABLE orders FROM 'orders~1' TO 'HEAD'",
         ["revert", "orders", "orders~1", "HEAD"]),
        (lambda r: r.gc(),
         "GC", ["gc"]),
        (lambda r: r.status(),
         "STATUS", ["status"]),
    ]


def _drive_python() -> Repo:
    r = Repo()
    for py, _, _ in _steps():
        py(r)
    return r


def _drive_statements() -> Repo:
    r = Repo()
    for py, stmt, _ in _steps():
        if stmt is None:
            py(r)                 # DML rides the same deterministic helper
        else:
            execute(r, stmt)
    return r


def _drive_cli(tmp_path) -> Repo:
    store = str(tmp_path / "store.wal")
    assert vcs_cli.main(["--store", store, "init"]) == 0
    for _, _, argv in _steps():
        assert vcs_cli.main(["--store", store] + argv) == 0, argv
    return vcs_cli.load_repo(store)


def _fingerprint(repo: Repo):
    e = repo.engine
    return {
        "ts": e.ts,
        "tables": {n: digest(e, n) for n in sorted(e.tables)},
        "log": e.commit_log,
        "branches": repo.branches(),
        "snapshots": repo.snapshots(),
        "prs": [(i, p.base_name, p.head_name, p.status)
                for i, p in sorted(e.prs.items())],
    }


def test_golden_three_surface_parity(tmp_path):
    fp_py = _fingerprint(_drive_python())
    fp_stmt = _fingerprint(_drive_statements())
    fp_cli = _fingerprint(_drive_cli(tmp_path))
    assert fp_py == fp_stmt, "python vs statement surface diverged"
    assert fp_py == fp_cli, "python vs CLI surface diverged"


def test_statement_session_wal_replays_identically():
    r = _drive_statements()
    e2 = Engine.replay(WAL.deserialize(r.engine.wal.serialize()))
    assert _fingerprint(Repo(e2)) == _fingerprint(r)


# --------------------------------------------------------------------------
# statement layer details
# --------------------------------------------------------------------------

def test_execute_script_and_messages():
    r = Repo()
    vcs_cli.seed_table(r, "t", 50, 0)
    out = execute_script(
        r, "CREATE SNAPSHOT s FOR TABLE t; CREATE BRANCH d FOR (t); "
           "SHOW BRANCHES; STATUS")
    assert [o.kind for o in out] == ["create_snapshot", "create_branch",
                                    "show", "status"]
    assert "branch d created" in out[1].message
    assert all(o.message for o in out)


def test_statement_errors_are_typed_with_suggestions():
    r = Repo()
    vcs_cli.seed_table(r, "t", 10, 0)
    with pytest.raises(StatementError) as exc:
        execute(r, "MERG BRANCH a INTO b")
    assert "MERGE" in exc.value.suggestions
    with pytest.raises(StatementError):
        execute(r, "DIFF TABLE t")             # missing AGAINST
    with pytest.raises(StatementError):
        execute(r, "PUBLISH PR notanumber")
    with pytest.raises(StatementError):
        execute(r, "CREATE BRANCH b FOR (t) trailing")
    from repro.core import UnknownRefError
    with pytest.raises(UnknownRefError):       # ref errors pass through
        execute(r, "DIFF TABLE t AGAINST 'snap:missing'")


def test_diff_table_statement_direction():
    """DIFF TABLE t AGAINST 'ref' reads like git diff ref..HEAD: positive
    groups are rows added since the ref."""
    r = Repo()
    vcs_cli.seed_table(r, "t", 20, 0)
    execute(r, "CREATE SNAPSHOT s FOR TABLE t")
    r.insert("t", vcs_cli._demo_batch(np.arange(20, 25), 1))
    d = execute(r, "DIFF TABLE t AGAINST 'snap:s'").data
    assert int((d.diff_cnt > 0).sum()) == 5
    assert int((d.diff_cnt < 0).sum()) == 0


def test_statement_conflict_modes_alias():
    """MODE ours keeps the target's rows, MODE theirs takes the source's —
    aliases over ConflictMode.SKIP/ACCEPT."""
    for mode, want in (("ours", 1.0), ("theirs", 2.0)):
        r = Repo()
        r.create_table("t", vcs_cli.DEMO_SCHEMA)
        r.insert("t", {"k": np.asarray([1]), "v": np.asarray([0.0]),
                       "doc": [b"x"]})
        execute(r, "CREATE BRANCH d FOR (t)")
        r.update_by_keys("t", {"k": np.asarray([1]),
                               "v": np.asarray([1.0]), "doc": [b"x"]})
        r.update_by_keys("d/t", {"k": np.asarray([1]),
                                 "v": np.asarray([2.0]), "doc": [b"x"]})
        execute(r, f"MERGE BRANCH d INTO main MODE {mode}")
        batch, _ = r.table("t").scan()
        assert batch["v"].tolist() == [want], mode


def test_branch_merge_is_atomic_multi_table():
    """MERGE BRANCH with several tables lands at ONE commit timestamp."""
    r = Repo()
    vcs_cli.seed_table(r, "a", 30, 0)
    vcs_cli.seed_table(r, "b", 30, 1)
    execute(r, "CREATE BRANCH d FOR (a, b)")
    vcs_cli.mutate_table(r, "d/a", 5, 2)
    vcs_cli.mutate_table(r, "d/b", 5, 3)
    reports = execute(r, "MERGE BRANCH d INTO main").data
    assert set(reports) == {"a", "b"}
    assert reports["a"].commit_ts == reports["b"].commit_ts is not None
    assert r.engine.table("a").directory.ts == \
        r.engine.table("b").directory.ts == reports["a"].commit_ts


# --------------------------------------------------------------------------
# CLI details
# --------------------------------------------------------------------------

def test_cli_error_exit_code_and_hint(tmp_path, capsys):
    store = _init_store(tmp_path)
    assert vcs_cli.main(["--store", store, "seed", "orders",
                         "--rows", "20"]) == 0
    assert vcs_cli.main(["--store", store, "snapshot", "night",
                         "orders"]) == 0
    rc = vcs_cli.main(["--store", store, "diff", "snap:nigt", "HEAD",
                       "--table", "orders"])
    err = capsys.readouterr().err
    assert rc == 2
    assert "no snapshot" in err and "night" in err


def test_cli_sql_subcommand_persists_mutations(tmp_path):
    """Mutating statements through the raw `sql` door must hit the store
    exactly like their dedicated subcommands (regression: sql was treated
    as read-only and its WAL silently dropped)."""
    store = _init_store(tmp_path)
    assert vcs_cli.main(["--store", store, "seed", "orders",
                         "--rows", "20"]) == 0
    assert vcs_cli.main(["--store", store, "sql",
                         "CREATE BRANCH dev FOR (orders); "
                         "CREATE SNAPSHOT night FOR TABLE orders"]) == 0
    r = vcs_cli.load_repo(store)
    assert [b[0] for b in r.branches()] == ["dev"]
    assert [s[0] for s in r.snapshots()] == ["night"]


def test_tag_refuses_non_head_with_clean_error():
    """Tagging a historical ref raises the intended ValueError (regression:
    the error path str.format()'ed the ref text and blew up in IndexError
    on @{ts} refs)."""
    r = Repo()
    vcs_cli.seed_table(r, "t", 10, 0)
    r.insert("t", vcs_cli._demo_batch(np.arange(10, 12), 1))
    with pytest.raises(ValueError, match="not the current head"):
        r.tag("old", "t~1")
    with pytest.raises(ValueError, match="not the current head"):
        r.tag("old", "t@{1}")
    # plain table name and statement form still tag the head
    assert r.tag("head1", "t").table == "t"
    execute(r, "CREATE SNAPSHOT head2 FOR TABLE t")
    # head-ness is by content: after restore, the restored-to snapshot's
    # object set IS the head again even though the Directory was rebuilt
    r.restore("t", "t~1")
    r.tag("head3", "t~0")


def test_cli_pr_check_exit_code_gates(tmp_path, capsys):
    """`dg pr check N` must be shell-gateable: exit 1 when the check run
    reports a failure (here the synthetic merge-conflict check)."""
    store = _init_store(tmp_path)
    vcs_cli.main(["--store", store, "seed", "t", "--rows", "30"])
    vcs_cli.main(["--store", store, "branch", "dev", "-t", "t"])
    vcs_cli.main(["--store", store, "mutate", "dev/t", "--rows", "5",
                  "--seed", "1"])
    vcs_cli.main(["--store", store, "pr", "open", "dev"])
    assert vcs_cli.main(["--store", store, "pr", "check", "1"]) == 0
    # conflicting base edit -> the merge preview fails the check run
    vcs_cli.main(["--store", store, "mutate", "t", "--rows", "5",
                  "--seed", "2"])
    rc = vcs_cli.main(["--store", store, "pr", "check", "1"])
    out = capsys.readouterr().out
    assert rc == 1 and "FAILED" in out


def test_cli_sql_check_exit_code_gates(tmp_path, capsys):
    """CHECK PR through the raw sql door obeys the same shell-gateable
    contract as `dg pr check` (regression: sql branch ignored check
    outcomes)."""
    store = _init_store(tmp_path)
    vcs_cli.main(["--store", store, "seed", "t", "--rows", "30"])
    vcs_cli.main(["--store", store, "branch", "dev", "-t", "t"])
    vcs_cli.main(["--store", store, "mutate", "dev/t", "--rows", "5",
                  "--seed", "1"])
    vcs_cli.main(["--store", store, "mutate", "t", "--rows", "5",
                  "--seed", "2"])
    vcs_cli.main(["--store", store, "pr", "open", "dev"])
    rc = vcs_cli.main(["--store", store, "sql",
                       "CREATE SNAPSHOT pre FOR TABLE t; CHECK PR 1"])
    assert rc == 1
    # mutations before the failing check still persisted
    assert [s[0] for s in vcs_cli.load_repo(store).snapshots()] == ["pre"]


def test_cli_merge_accepts_qualified_branch_refs(tmp_path):
    """`dg merge branch:dev branch:main` (the qualified spelling the diff
    subcommand documents) must not double-prefix into branch:branch:dev."""
    store = _init_store(tmp_path)
    vcs_cli.main(["--store", store, "seed", "t", "--rows", "20"])
    vcs_cli.main(["--store", store, "branch", "dev", "-t", "t"])
    vcs_cli.main(["--store", store, "mutate", "dev/t", "--rows", "3",
                  "--seed", "1"])
    assert vcs_cli.main(["--store", store, "merge", "branch:dev",
                         "branch:main", "--mode", "theirs"]) == 0
    # -t on a non-branch merge is an error, not silently dropped
    vcs_cli.main(["--store", store, "snapshot", "s", "t"])
    assert vcs_cli.main(["--store", store, "merge", "snap:s", "t",
                         "-t", "t"]) == 2


def test_cli_rejects_keyword_injection_in_name_positions(tmp_path, capsys):
    """Unquoted name args must not be reinterpretable as statement syntax
    (regression: `dg branch "dev FOR (prod)"` silently branched prod)."""
    store = _init_store(tmp_path)
    vcs_cli.main(["--store", store, "seed", "prod", "--rows", "10"])
    assert vcs_cli.main(["--store", store, "branch",
                         "dev FOR (prod)"]) == 2
    assert "invalid branch name" in capsys.readouterr().err
    assert vcs_cli.load_repo(store).branches() == []
    assert vcs_cli.main(["--store", store, "log", "prod LIMIT 1"]) == 2


def test_legacy_shim_prefers_snapshots_and_survives_pregrammar_names():
    """resolve_snapshot keeps the snapshots-only contract for bare names:
    a bare table name raises (existence probes must not match tables),
    and a pre-grammar name from an old WAL still resolves."""
    r = Repo()
    vcs_cli.seed_table(r, "t", 10, 0)
    with pytest.raises(KeyError):
        r.engine.resolve_snapshot("t")        # table, not a snapshot
    # pre-grammar snapshot names smuggled in via replay-style creation:
    # unparseable AND qualified-looking ones must hit the dict, never a
    # grammar reinterpretation (a tag literally named "t~1" is the tag,
    # not PITR one-version-back)
    r.engine.create_snapshot("night ly", "t", _log=False)
    assert r.engine.resolve_snapshot("night ly").table == "t"
    r.engine.create_snapshot("t~1", "t", _log=False)
    assert r.engine.resolve_snapshot("t~1") is r.engine.snapshots["t~1"]
    # checkpoint restore: the exact tag wins over a branch sharing the
    # name (dict-first rule in vcs_ckpt.restore, driven for real)
    jax = pytest.importorskip("jax")
    from repro.checkpoint.vcs_ckpt import VcsCheckpointer
    ck = VcsCheckpointer(r.engine, table="ckpt")
    state = {"w": np.arange(8, dtype=np.float32)}
    ck.save(state, step=1)                    # tags snapshot "step-1"
    r.engine.create_branch("step-1", ["t"])   # colliding branch name
    out = ck.restore("step-1", state)
    assert np.array_equal(out["w"], state["w"])


def test_merge_into_table_wins_over_branch_name_collision():
    """MERGE ... INTO TABLE x stays resolvable when a branch named x
    exists — the explicit table position prefers the table reading."""
    r = Repo()
    vcs_cli.seed_table(r, "x", 10, 0)
    r.tag("s", "x")
    r.engine.create_branch("x", ["x"])     # branch sharing the name
    rep = execute(r, "MERGE 'snap:s' INTO TABLE x MODE theirs").data
    assert rep.inserted == 0 and rep.deleted == 0


def test_branch_merge_disjoint_tables_is_an_error():
    r = Repo()
    vcs_cli.seed_table(r, "a", 10, 0)
    vcs_cli.seed_table(r, "b", 10, 1)
    execute(r, "CREATE BRANCH x FOR (a)")
    execute(r, "CREATE BRANCH y FOR (b)")
    with pytest.raises(ValueError, match="share no tables"):
        execute(r, "MERGE BRANCH x INTO y")


def test_cli_pkviolation_is_a_clean_error(tmp_path, capsys):
    """Engine data errors (PKViolation/TxnConflict) follow the error:/exit-2
    contract instead of crashing with a traceback."""
    store = _init_store(tmp_path)
    assert vcs_cli.main(["--store", store, "seed", "t", "--rows", "5"]) == 0
    rc = vcs_cli.main(["--store", store, "seed", "t", "--rows", "5"])
    assert rc == 2
    assert "error:" in capsys.readouterr().err


def test_table_position_wins_over_name_collision():
    """LOG TABLE t / REVERT TABLE t / MERGE ... INTO TABLE t stay
    unambiguous when a snapshot shares the table's name (regression:
    table positions resolved as bare refs -> AmbiguousRefError)."""
    r = Repo()
    vcs_cli.seed_table(r, "orders", 30, 0)
    r.tag("orders", "orders")          # snapshot named like the table
    assert [e.kind for e in r.log("orders")][-1] == "create"
    assert execute(r, "LOG TABLE orders").data
    vcs_cli.mutate_table(r, "orders", 5, 1)
    execute(r, "REVERT TABLE orders FROM 'orders~1' TO 'HEAD'")
    execute(r, "MERGE 'snap:orders' INTO TABLE orders MODE theirs")


def test_cli_torn_store_tail_is_dropped_not_appended_after(tmp_path,
                                                           capsys):
    """A crash-torn trailing frame (even a 1-2 byte tear, which pickle
    reports as EOFError like clean EOF) must be truncated before the next
    append — appending after garbage bricks the store permanently."""
    store = _init_store(tmp_path)
    assert vcs_cli.main(["--store", store, "seed", "t", "--rows", "10"]) == 0
    with open(store, "ab") as f:
        f.write(b"\x80")                      # torn frame: 1 stray byte
    assert vcs_cli.main(["--store", store, "branch", "dev",
                         "-t", "t"]) == 0
    assert "torn trailing frame" in capsys.readouterr().err
    # the store stays loadable and carries the new op
    r = vcs_cli.load_repo(store)
    assert [b[0] for b in r.branches()] == ["dev"]


def test_cli_missing_store_is_an_error(tmp_path, capsys):
    """Non-init commands refuse a nonexistent store (a typo'd --store must
    not silently create a fresh store at the wrong path)."""
    store = str(tmp_path / "strore.wal")      # deliberate typo
    rc = vcs_cli.main(["--store", store, "seed", "orders", "--rows", "5"])
    assert rc == 2
    assert "no store at" in capsys.readouterr().err
    import os
    assert not os.path.exists(store)


def test_cli_store_persists_and_replays(tmp_path):
    store = _init_store(tmp_path)
    vcs_cli.main(["--store", store, "seed", "t", "--rows", "30"])
    vcs_cli.main(["--store", store, "branch", "dev", "-t", "t"])
    vcs_cli.main(["--store", store, "mutate", "dev/t", "--rows", "5",
                  "--seed", "3"])
    r1 = vcs_cli.load_repo(store)
    # read-only commands do not rewrite the store
    import os
    mtime = os.path.getmtime(store)
    assert vcs_cli.main(["--store", store, "status"]) == 0
    assert vcs_cli.main(["--store", store, "log", "t"]) == 0
    assert os.path.getmtime(store) == mtime
    r2 = vcs_cli.load_repo(store)
    assert digest(r1.engine, "dev/t") == digest(r2.engine, "dev/t")
    assert r1.engine.ts == r2.engine.ts
