"""Tiered store + remote suite (ISSUE 10).

Pins the four contracts of ``repro.store``:

* tier transparency — spill/evict/fault-in never changes what a reader
  sees: content digests are byte-identical across any cache state, LRU
  eviction honours access recency, and GC releases pack files;
* crash safety — every ``store.*`` seam (pack write, spill, push
  manifest, pull apply) is swept with fault injection: recovery from the
  durable WAL lands on an all-or-nothing clean-run state, a crashed push
  leaves the remote readable at its OLD state, a crashed pull leaves the
  local engine untouched;
* remote exchange — push/pull/fetch move ONLY the missing objects
  (counter-pinned), pulls rehash zero rows, and shallow clones fault
  objects from origin on first read;
* the surfaces — fsck catches pack bit rot, ``status`` reports the
  crc32c impl + tier occupancy, read-only CLI commands never rewrite the
  store file, and the two-repo CLI round trip ends byte-identical with
  clean fsck on both sides.
"""
import json
import os

import numpy as np
import pytest

from conftest import VCS_SCHEMA as SCH
from conftest import content_digest, kv_batch as _batch
from test_wal_roundtrip import digests

from repro.core import Engine, FaultPlan, InjectedCrash, WAL, fsck, inject
from repro.core import telemetry
from repro.core import wal as walmod
from repro.core.faults import flip_bit
from repro.store import PackDir, attach_packs, blob_digest, pull, push
from repro.store.remote import RemoteError, fetch, read_remote
from repro import vcs_cli

from test_crash_recovery import STORE_POINTS


def _counters(e):
    return telemetry.stats_json(e)["metrics"]


def _seed(rows=60, packs_root=None):
    e = Engine()
    if packs_root is not None:
        attach_packs(e.store, packs_root)
    e.create_table("t", SCH)
    e.insert("t", _batch(range(rows)))
    return e


# --------------------------------------------------------------------------
# tier transparency
# --------------------------------------------------------------------------

def test_spill_evict_fault_digest_identity(tmp_path):
    """The capstone tier property: spill + evict + reopen-by-scan gives
    byte-identical content, and every tier transition is counted."""
    e = _seed(packs_root=str(tmp_path / "packs"))
    e.create_snapshot("s1", "t")
    e.update_by_keys("t", _batch(range(10), vals=np.arange(10) * 3.0))
    before = content_digest(e, "t")
    e.store.spill_all()
    e.store.evict_all()
    assert not e.store._objects and e.store._packed   # heap empty, tier 2 full
    assert content_digest(e, "t") == before           # faulted back in
    c = _counters(e)
    assert c["store.spills"] > 0 and c["store.evictions"] > 0
    assert c["store.faults"] > 0 and c["store.bytes_packed"] > 0
    # a second scan is all heap hits, no new faults
    n_faults = c["store.faults"]
    assert content_digest(e, "t") == before
    c2 = _counters(e)
    assert c2["store.faults"] == n_faults and c2["store.hits"] > c["store.hits"]


def test_oids_live_bytes_and_delete_span_both_tiers(tmp_path):
    e = _seed(rows=20, packs_root=str(tmp_path / "packs"))
    all_oids = sorted(e.store.oids())
    e.store.evict_all()
    assert sorted(e.store.oids()) == all_oids
    assert e.store.live_bytes() > 0
    # delete of a packed-only object works and releases its pack file
    victim = all_oids[0]
    digest = e.store.digest_of(victim)
    assert e.store.packs.has(digest)
    e.store.delete(victim)
    assert not e.store.has(victim)
    assert not e.store.packs.has(digest)              # refcount hit 0


def test_shrink_heap_evicts_lru_first(tmp_path):
    e = Engine()
    attach_packs(e.store, str(tmp_path / "packs"))
    e.create_table("t", SCH)
    for i in range(4):
        e.insert("t", _batch(range(i * 10, i * 10 + 10)))
    oids = sorted(e.store._objects)
    e.store.shrink_heap(0)
    assert not e.store._objects                       # target 0 evicts all
    for o in oids[1:]:
        e.store.get(o)                                # fault all back in...
    keep = e.store.get(oids[0]).nbytes                # ...oldest oid LAST:
    e.store.shrink_heap(keep)                         # it is now the MRU
    assert oids[0] in e.store._objects
    for o in oids[1:]:
        assert o not in e.store._objects              # LRU tail evicted
    assert sorted(e.store.oids()) == oids             # all still readable


def test_gc_prunes_pack_files(tmp_path):
    e = _seed(rows=30, packs_root=str(tmp_path / "packs"))
    e.store.spill_all()
    assert len(e.store.packs.digests()) > 0
    e.update_by_keys("t", _batch(range(30), vals=np.arange(30) * 2.0))
    e.store.spill_all()
    e.gc()
    # exactly the live packed set remains on disk — a GC'd oid's pack file
    # is released with it (refcounted by digest); survivors still verify
    assert e.store.packs.digests() == \
        {ent[0] for ent in e.store._packed.values()}
    for _, ent in sorted(e.store._packed.items()):
        assert e.store.packs.verify(ent[0]) == []


# --------------------------------------------------------------------------
# crash sweep: every store.* seam, all-or-nothing
# --------------------------------------------------------------------------

def store_script(box, root):
    """Spill/evict, push to a fresh remote, advance the remote through a
    second engine, pull back. Each yield is a legal recovery target for
    the engine in ``box`` (pull swaps the engine, hence the box)."""
    e = box["e"]
    attach_packs(e.store, os.path.join(root, "packs"))
    e.create_table("t", SCH);                          yield "create"
    e.insert("t", _batch(range(40)));                  yield "seed"
    e.store.spill_all();                               yield "spill"
    e.store.evict_all();                               yield "evict"
    e.insert("t", _batch(range(40, 50)));              yield "grow"
    remote = os.path.join(root, "remote")
    os.makedirs(remote, exist_ok=True)
    push(e, remote);                                   yield "push"
    b, _ = pull(Engine(), remote,
                pack_dir=os.path.join(root, "bpacks"))
    b.insert("t", _batch(range(50, 55)));              yield "b_grow"
    push(b, remote);                                   yield "b_push"
    box["e"], _ = pull(e, remote);                     yield "pull"


@pytest.fixture(scope="module")
def store_oracle(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("oracle"))
    box = {"e": Engine()}
    plan = FaultPlan({})
    states = [digests(box["e"])]
    with inject(plan):
        for _ in store_script(box, root):
            states.append(digests(box["e"]))
    return states, dict(plan.hits)


def test_store_points_all_registered():
    assert STORE_POINTS == ["store.pack.write", "store.pull.apply",
                            "store.push.manifest", "store.spill"]


@pytest.mark.parametrize("point", STORE_POINTS)
def test_store_crash_sweep_all_or_nothing(point, store_oracle, tmp_path):
    states, hits = store_oracle
    assert hits.get(point, 0) > 0, \
        f"store script never reaches crash point {point!r} — extend it"
    for n in range(1, hits[point] + 1):
        root = str(tmp_path / f"run{n}")
        os.makedirs(root)
        box = {"e": Engine()}
        tripped = False
        with inject(FaultPlan.at(point, n)) as plan:
            try:
                for _ in store_script(box, root):
                    pass
            except InjectedCrash as crash:
                tripped = True
                assert crash.point == point and crash.hit == n
        assert tripped and plan.tripped == point
        recovered = Engine.replay(
            WAL.deserialize(box["e"].wal.serialize()))
        assert digests(recovered) in states, (
            f"crash at {point} hit {n}: recovered state matches no "
            "clean-run state (partial operation survived)")
        report = fsck(recovered)
        assert report.ok, (point, n, [str(i) for i in report.issues])


def test_push_manifest_crash_leaves_remote_at_old_state(tmp_path):
    """The refs swing is the commit point: a push that dies after shipping
    objects + WAL but before the refs write is INVISIBLE to readers."""
    e = _seed(packs_root=str(tmp_path / "packs"))
    remote = str(tmp_path / "remote")
    os.makedirs(remote)
    push(e, remote)
    old_payload, old_records = read_remote(remote)
    e.insert("t", _batch(range(60, 80)))
    with inject(FaultPlan.at("store.push.manifest")):
        with pytest.raises(InjectedCrash):
            push(e, remote)
    payload, records = read_remote(remote)            # still readable...
    assert payload["n_records"] == old_payload["n_records"]
    assert len(records) == len(old_records)           # ...at the OLD state
    stats = push(e, remote)                           # retry completes
    assert stats["records_pushed"] > 0
    assert read_remote(remote)[0]["n_records"] > old_payload["n_records"]


def test_pull_apply_crash_leaves_local_untouched(tmp_path):
    e = _seed(packs_root=str(tmp_path / "packs"))
    remote = str(tmp_path / "remote")
    os.makedirs(remote)
    push(e, remote)
    b, _ = pull(Engine(), remote, pack_dir=str(tmp_path / "bpacks"))
    b.insert("t", _batch(range(60, 70)))
    push(b, remote)
    before = digests(e)
    with inject(FaultPlan.at("store.pull.apply")):
        with pytest.raises(InjectedCrash):
            pull(e, remote)
    assert digests(e) == before                       # engine never swung
    e2, stats = pull(e, remote)                       # retry completes
    assert stats["records_pulled"] > 0
    assert digests(e2) == digests(b)


def test_spill_crash_keeps_heap_authoritative(tmp_path):
    e = _seed(rows=10, packs_root=str(tmp_path / "packs"))
    before = content_digest(e, "t")
    with inject(FaultPlan.at("store.spill")):
        with pytest.raises(InjectedCrash):
            e.store.spill_all()
    # nothing moved to the packed map; readers are unaffected
    assert not e.store._packed
    assert content_digest(e, "t") == before
    e.store.spill_all()                               # retry is clean
    assert len(e.store._packed) == len(e.store._objects) > 0
    assert content_digest(e, "t") == before


# --------------------------------------------------------------------------
# remote exchange: only-missing-objects, zero rehash
# --------------------------------------------------------------------------

def test_push_pull_move_only_missing_objects(tmp_path):
    remote = str(tmp_path / "remote")
    os.makedirs(remote)
    a = _seed(packs_root=str(tmp_path / "apacks"))
    s1 = push(a, remote)
    assert s1["objects_pushed"] == len(set(a.store.oids()))
    assert push(a, remote)["objects_pushed"] == 0     # idempotent
    b, sp = pull(Engine(), remote, pack_dir=str(tmp_path / "bpacks"))
    assert sp["objects_pulled"] == s1["objects_pushed"]
    assert _counters(b)["commit.rows_rehashed"] == 0  # pull rehashes nothing
    b.insert("t", _batch(range(60, 70)))
    new = set(b.store.oids()) - set(a.store.oids())
    s2 = push(b, remote)
    assert s2["objects_pushed"] == len(new)           # dedup: missing set only
    a2, s3 = pull(a, remote)
    assert s3["objects_pulled"] == len(new)
    assert _counters(a2)["store.objects_pulled"] == len(new)
    assert _counters(a2)["commit.rows_rehashed"] == 0
    assert content_digest(a2, "t") == content_digest(b, "t")
    assert pull(a2, remote)[1]["up_to_date"]


def test_push_refuses_diverged_pull_refuses_behind(tmp_path):
    remote = str(tmp_path / "remote")
    os.makedirs(remote)
    a = _seed(rows=10, packs_root=str(tmp_path / "apacks"))
    push(a, remote)
    b, _ = pull(Engine(), remote, pack_dir=str(tmp_path / "bpacks"))
    b.insert("t", _batch(range(10, 15)))
    push(b, remote)
    a.insert("t", _batch(range(20, 25)))              # diverge locally
    with pytest.raises(RemoteError, match="pull first"):
        push(a, remote)
    with pytest.raises(RemoteError):
        pull(a, remote)                               # diverged pull refused


def test_fetch_prefetches_without_state_swing(tmp_path):
    remote = str(tmp_path / "remote")
    os.makedirs(remote)
    a = _seed(rows=20, packs_root=str(tmp_path / "apacks"))
    push(a, remote)
    b = Engine()
    st = fetch(b, remote, pack_dir=str(tmp_path / "bpacks"))
    assert st["objects_pulled"] > 0
    assert not b.tables                               # refs untouched
    assert b.store.packs.digests() == PackDir(remote).digests()
    assert fetch(b, remote)["objects_pulled"] == 0    # second fetch: no-op


def test_shallow_clone_faults_objects_on_first_read(tmp_path):
    from repro.store import clone
    remote = str(tmp_path / "remote")
    os.makedirs(remote)
    a = _seed(rows=50, packs_root=str(tmp_path / "apacks"))
    before = content_digest(a, "t")
    push(a, remote)
    dest = str(tmp_path / "b.wal")
    st = clone(remote, dest, shallow=True)
    assert st["shallow"] and st["objects_fetched"] == 0
    rb = vcs_cli.load_repo(dest)
    assert not rb.engine.store._objects               # nothing resident
    assert content_digest(rb.engine, "t") == before   # faults from origin
    assert _counters(rb.engine)["store.objects_pulled"] > 0
    st2 = clone(remote, str(tmp_path / "c.wal"))      # deep clone: eager
    assert st2["objects_fetched"] > 0


# --------------------------------------------------------------------------
# fsck + status surfaces
# --------------------------------------------------------------------------

def test_fsck_catches_pack_bit_rot(tmp_path):
    e = _seed(rows=20, packs_root=str(tmp_path / "packs"))
    e.store.spill_all()
    assert fsck(e).ok
    digest = sorted(ent[0] for ent in e.store._packed.values())[0]
    flip_bit(e.store.packs.path(digest), 200)
    report = fsck(e)
    assert not report.ok
    assert any(i.kind == "pack_corrupt" for i in report.issues)
    assert report.packs_checked == len(e.store._packed)


def test_status_reports_crc32c_and_tiers(tmp_path):
    from repro.core import Repo
    repo = Repo()
    repo.engine.create_table("t", SCH)
    repo.engine.insert("t", _batch(range(10)))
    st = repo.status()
    assert st["crc32c"] == walmod.CRC32C_IMPL
    assert st["store"]["resident"] > 0 and st["store"]["packed"] == 0
    assert st["store"]["packs"] is None
    attach_packs(repo.engine.store, str(tmp_path / "packs"))
    repo.engine.store.evict_all()
    st = repo.status()
    assert st["store"]["resident"] == 0 and st["store"]["packed"] > 0
    assert st["store"]["packs"] == str(tmp_path / "packs")


def test_pure_python_crc32c_warns_once(monkeypatch, capsys):
    """Past the byte threshold the fallback accounting warns exactly once
    (satellite 2). ``_note_py_crc32c`` is the unconditional seam: on this
    host the C impl may be loaded, so drive the helper directly — it is
    exactly what the fallback ``crc32c`` calls per hash."""
    monkeypatch.setattr(walmod, "_py_crc32c_bytes", 0)
    monkeypatch.setattr(walmod, "_py_crc32c_warned", False)
    monkeypatch.setattr(walmod, "_PY_CRC32C_WARN_BYTES", 1024)
    walmod._note_py_crc32c(512)
    assert capsys.readouterr().err == ""              # under threshold
    walmod._note_py_crc32c(1024)
    walmod._note_py_crc32c(4096)
    err = capsys.readouterr().err
    assert err.count("pure-python crc32c fallback") == 1


# --------------------------------------------------------------------------
# CLI: read-only commands never rewrite; two-repo round trip
# --------------------------------------------------------------------------

def _sig(path):
    st = os.stat(path)
    with open(path, "rb") as f:
        return st.st_mtime_ns, st.st_size, f.read()


def test_read_only_cli_commands_never_rewrite_store(tmp_path):
    store = str(tmp_path / "a.wal")
    assert vcs_cli.main(["--store", store, "init"]) == 0
    assert vcs_cli.main(["--store", store, "seed", "t", "--rows", "50"]) == 0
    before = _sig(store)
    for argv in (["status"], ["log", "t"], ["stats"], ["tables"],
                 ["branches"], ["sql", "STATUS"]):
        assert vcs_cli.main(["--store", store] + argv) == 0, argv
        assert _sig(store) == before, f"{argv} rewrote the store file"
    # a mutating command DOES write
    assert vcs_cli.main(["--store", store, "seed", "u", "--rows", "5"]) == 0
    assert _sig(store) != before


def test_read_only_cli_leaves_legacy_pickle_store_alone(tmp_path):
    """A legacy store pends a format upgrade — but only a MUTATING command
    may perform it (satellite 1)."""
    import pickle
    store = str(tmp_path / "legacy.wal")
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch(range(5)))
    with open(store, "wb") as f:
        pickle.dump(e.wal.records, f)
    before = _sig(store)
    assert vcs_cli.main(["--store", store, "status"]) == 0
    assert _sig(store) == before                      # untouched
    assert vcs_cli.main(["--store", store, "seed", "u", "--rows", "3"]) == 0
    assert _sig(store) != before                      # upgrade happened
    with open(store, "rb") as f:
        assert f.read(4) == walmod.MAGIC              # ...to DGWS framing


def test_cli_two_repo_round_trip(tmp_path, capsys):
    """seed A -> push -> shallow-clone B -> mutate/PR/publish in B -> push
    back -> pull into A; content digests identical, fsck clean both sides,
    and B's clone faulted zero objects up front (satellite 5 inner loop)."""
    a_store = str(tmp_path / "a.wal")
    b_store = str(tmp_path / "b.wal")
    remote = str(tmp_path / "origin")
    run = lambda s, *argv: vcs_cli.main(["--store", s] + list(argv))
    assert run(a_store, "init") == 0
    assert run(a_store, "seed", "orders", "--rows", "500") == 0
    assert run(a_store, "push", remote) == 0
    assert run(b_store, "clone", remote, "--shallow") == 0
    capsys.readouterr()
    assert run(b_store, "status") == 0
    out = capsys.readouterr().out
    assert "crc32c=" in out and "resident=0" in out   # shallow: nothing local
    # work happens in B: branch, mutate, PR, publish
    assert run(b_store, "branch", "dev", "-t", "orders") == 0
    assert run(b_store, "mutate", "dev/orders", "--rows", "40") == 0
    assert run(b_store, "pr", "open", "dev") == 0
    assert run(b_store, "publish", "1") == 0
    assert run(b_store, "push", remote) == 0
    assert run(a_store, "pull", remote) == 0
    ra = vcs_cli.load_repo(a_store)
    rb = vcs_cli.load_repo(b_store)
    assert content_digest(ra.engine, "orders") == \
        content_digest(rb.engine, "orders")
    for r in (ra, rb):
        report = fsck(r.engine)
        assert report.ok, [str(i) for i in report.issues]
    assert run(a_store, "fsck") == 0
    assert run(b_store, "fsck") == 0
    assert run(a_store, "pull", remote) == 0          # idempotent
    out = capsys.readouterr().out
    assert "up to date" in out


def test_cli_sql_push_pull_fetch(tmp_path, capsys):
    a = str(tmp_path / "a.wal")
    b = str(tmp_path / "b.wal")
    remote = str(tmp_path / "origin")
    assert vcs_cli.main(["--store", a, "init"]) == 0
    assert vcs_cli.main(["--store", a, "seed", "t", "--rows", "20"]) == 0
    assert vcs_cli.main(["--store", a, "sql", f"PUSH TO '{remote}'"]) == 0
    assert vcs_cli.main(["--store", b, "clone", remote]) == 0
    assert vcs_cli.main(["--store", b, "sql", f"FETCH FROM '{remote}'"]) == 0
    assert vcs_cli.main(["--store", b, "sql", f"PULL FROM '{remote}'"]) == 0
    out = capsys.readouterr().out
    assert "up to date" in out
