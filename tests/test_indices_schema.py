"""Secondary indices (paper §5.5.4) and schema change (§5.5.6)."""
import numpy as np
import pytest

from repro.core import (Column, CType, Engine, Schema, snapshot_diff)
from repro.core.indices import create_index, drop_index, lookup_eq

SCH = Schema((Column("id", CType.I64), Column("cat", CType.I32),
              Column("val", CType.F64)), primary_key=("id",))


def _setup(n=100):
    e = Engine()
    e.create_table("T", SCH)
    e.insert("T", {"id": np.arange(n), "cat": np.arange(n) % 5,
                   "val": np.arange(n) * 1.0})
    return e


def test_index_backfill_and_lookup():
    e = _setup()
    create_index(e, "T", "by_cat", ["cat"])
    hits = lookup_eq(e, "T", "by_cat", {"cat": np.int32(3)})
    assert sorted(hits["id"].tolist()) == [i for i in range(100) if i % 5 == 3]


def test_index_maintained_on_insert_update_delete():
    e = _setup()
    create_index(e, "T", "by_cat", ["cat"])
    e.insert("T", {"id": [1000], "cat": [3], "val": [1.0]})
    e.update_by_keys("T", {"id": [3], "cat": [4], "val": [3.0]})  # 3: cat 3->4
    e.delete_by_keys("T", {"id": np.asarray([8])})                # 8: cat 3
    hits = sorted(lookup_eq(e, "T", "by_cat", {"cat": np.int32(3)})["id"]
                  .tolist())
    want = sorted([i for i in range(100) if i % 5 == 3
                   and i not in (3, 8)] + [1000])
    assert hits == want
    hits4 = lookup_eq(e, "T", "by_cat", {"cat": np.int32(4)})["id"].tolist()
    assert 3 in hits4


def test_index_maintenance_is_atomic_with_base_commit():
    e = _setup()
    create_index(e, "T", "by_cat", ["cat"])
    tx = e.begin()
    tx.update_by_keys("T", {"id": [0], "cat": [9], "val": [0.0]})
    tx.insert("T", {"id": [2000], "cat": [9], "val": [2.0]})
    tx.commit()   # ONE commit covers base + aux
    hits = sorted(lookup_eq(e, "T", "by_cat", {"cat": np.int32(9)})["id"]
                  .tolist())
    assert hits == [0, 2000]


def test_clone_with_indices_is_independent():
    e = _setup()
    create_index(e, "T", "by_cat", ["cat"])
    snap = e.create_snapshot("s", "T")
    e.clone_table("C", "s", with_indices=True)
    e.update_by_keys("C", {"id": [0], "cat": [7], "val": [0.0]})
    assert lookup_eq(e, "C", "by_cat", {"cat": np.int32(7)})["id"].tolist() \
        == [0]
    assert lookup_eq(e, "T", "by_cat",
                     {"cat": np.int32(7)})["id"].shape[0] == 0


def test_index_survives_wal_replay():
    e = _setup(20)
    create_index(e, "T", "by_cat", ["cat"])
    e.insert("T", {"id": [500], "cat": [2], "val": [5.0]})
    e2 = Engine.replay(e.wal)
    hits = sorted(lookup_eq(e2, "T", "by_cat", {"cat": np.int32(2)})["id"]
                  .tolist())
    assert hits == sorted(lookup_eq(e, "T", "by_cat",
                                    {"cat": np.int32(2)})["id"].tolist())
    assert 500 in hits


def test_drop_index():
    e = _setup(10)
    spec = create_index(e, "T", "by_cat", ["cat"])
    assert spec.aux_table in e.tables
    drop_index(e, "T", "by_cat")
    assert spec.aux_table not in e.tables


# ------------------------------------------------------------ ALTER TABLE

def test_alter_add_column_and_pitr_restore():
    e = _setup(10)
    pre = e.create_snapshot("pre-alter", "T")
    e.alter_table_add_column("T", Column("note", CType.LOB), b"-")
    batch, _ = e.table("T").scan()
    assert "note" in batch and all(v == b"-" for v in batch["note"])
    # new writes carry the column
    e.insert("T", {"id": [99], "cat": [1], "val": [9.0], "note": [b"hi"]})
    assert e.table("T").count() == 11
    # diff across schema versions refused (paper §5.5.6)
    with pytest.raises(ValueError):
        snapshot_diff(e.store, pre, e.current_snapshot("T"))
    # RESTORE to the pre-alter snapshot works and restores the old schema
    e.restore_table("T", "pre-alter")
    batch, _ = e.table("T").scan()
    assert "note" not in batch
    assert e.table("T").count() == 10


def test_alter_preserves_row_identity_within_new_schema():
    e = _setup(10)
    e.alter_table_add_column("T", Column("flag", CType.BOOL), False)
    s1 = e.create_snapshot("s1", "T")
    e.clone_table("C", "s1")
    e.update_by_keys("C", {"id": [2], "cat": [2], "val": [22.0],
                           "flag": [True]})
    d = snapshot_diff(e.store, s1, e.current_snapshot("C"))
    assert d.n_groups == 2   # old row + new row only
