"""Crash-consistency sweep + durable-format corruption tests (ISSUE 6).

The capstone property: for EVERY registered crash point, killing the
process there and recovering from the durable WAL leaves each logical
operation either fully applied or fully absent — the recovered state is
byte-identical (content digests, registries, timestamp) to one of the
states a clean run passes through — and ``fsck`` reports clean.

Corruption is the second axis: a flipped bit or torn tail in the durable
bytes must surface as a typed error naming the frame/object (CorruptFrame,
TornFrame, StoreVersionError, fsck signature_mismatch), never as pickle
garbage or a silent wrong answer.
"""
import os
import pickle

import numpy as np
import pytest

from conftest import VCS_SCHEMA as SCH
from conftest import kv_batch as _batch
from test_wal_roundtrip import digests

from repro.core import (CorruptFrame, Engine, FaultPlan, InjectedCrash,
                        StoreVersionError, TornFrame, TornTransaction, WAL,
                        compact_objects, fsck, inject, registered)
from repro.core.faults import corrupt_object_bit, flip_bit
from repro.core.wal import MAGIC, STORE_HEADER
from repro.vcs_cli import load_repo, save_repo

import repro.store  # noqa: F401  — registers the store.* crash points

# the engine-level op script exercises these; cli.* seams need a store
# file and store.* seams need a pack directory — both swept separately
# (store.* in tests/test_store_tiers.py)
ENGINE_POINTS = sorted(p for p in registered()
                       if not p.startswith(("cli.", "store.")))
CLI_POINTS = sorted(p for p in registered() if p.startswith("cli."))
STORE_POINTS = sorted(p for p in registered() if p.startswith("store."))


def script(e):
    """The representative op script (seed -> branch -> PR -> publish ->
    revert -> gc). Each yield marks ONE completed logical operation, so
    the state after each yield is a legal all-or-nothing recovery target."""
    e.create_table("t", SCH);                                 yield "create_t"
    e.create_table("u", SCH);                                 yield "create_u"
    e.insert("t", _batch([1, 2, 3, 4, 5]));                   yield "seed_t"
    e.insert("u", _batch([10, 11, 12]));                      yield "seed_u"
    tx = e.begin()
    tx.insert("t", _batch([6]))
    tx.insert("u", _batch([13]))
    tx.commit();                                              yield "multi"
    e.delete_by_keys("t", {"k": np.asarray([5])});            yield "delete"
    e.create_snapshot("s1", "t");                             yield "snap"
    e.create_branch("dev", ["t", "u"]);                       yield "branch"
    e.update_by_keys("dev/t", _batch([2], vals=[7.0]));       yield "mut_dt"
    e.update_by_keys("dev/u", _batch([11], vals=[8.0]));      yield "mut_du"
    pr = e.open_pr("main", "dev");                            yield "open_pr"
    pr.publish();                                             yield "publish"
    pr.revert_publish();                                      yield "rev_pub"
    compact_objects(e, "t", list(e.table("t").directory.data_oids))
    yield "compact"
    s_a = e.current_snapshot("t")
    e.update_by_keys("t", _batch([1], vals=[44.0]));          yield "mut_t"
    e.revert("t", s_a, e.current_snapshot("t"));              yield "revert"
    e.create_table("tmp", SCH);                               yield "mk_tmp"
    e.insert("tmp", _batch([100]));                           yield "seed_tmp"
    e.drop_table("tmp");                                      yield "drop_tmp"
    e.gc();                                                   yield "gc"


@pytest.fixture(scope="module")
def oracle():
    """One clean run: the set of legal recovery states + how many times
    each crash point is hit (armed with a never-tripping plan)."""
    e = Engine()
    plan = FaultPlan({})
    states = [digests(e)]
    with inject(plan):
        for _ in script(e):
            states.append(digests(e))
    return states, dict(plan.hits)


@pytest.mark.parametrize("point", ENGINE_POINTS)
def test_crash_sweep_all_or_nothing(point, oracle):
    """Kill at hit n of `point` for EVERY n the script reaches; recovery
    via WAL replay must land exactly on a clean-run state and fsck clean."""
    states, hits = oracle
    assert hits.get(point, 0) > 0, \
        f"op script never reaches crash point {point!r} — extend it"
    for n in range(1, hits[point] + 1):
        e = Engine()
        tripped = False
        with inject(FaultPlan.at(point, n)) as plan:
            try:
                for _ in script(e):
                    pass
            except InjectedCrash as crash:
                tripped = True
                assert crash.point == point and crash.hit == n
        assert tripped and plan.tripped == point
        recovered = Engine.replay(WAL.deserialize(e.wal.serialize()))
        assert digests(recovered) in states, (
            f"crash at {point} hit {n}: recovered state matches no "
            "clean-run state (partial operation survived)")
        report = fsck(recovered)
        assert report.ok, (point, n, [str(i) for i in report.issues])


def test_mid_swing_crash_recovers_whole_transaction():
    """Log-before-swing: by the time the first directory swings, the FULL
    commit group is in the WAL — a mid-swing kill recovers to ALL tables
    committed, never a partial multi-table transaction."""
    e = Engine()
    e.create_table("a", SCH)
    e.create_table("b", SCH)
    tx = e.begin()
    tx.insert("a", _batch([1]))
    tx.insert("b", _batch([2]))
    with inject(FaultPlan.at("engine.commit.mid_swing")):
        with pytest.raises(InjectedCrash):
            tx.commit()
    recovered = Engine.replay(WAL.deserialize(e.wal.serialize()))
    assert recovered.table("a").scan()[0]["k"].tolist() == [1]
    assert recovered.table("b").scan()[0]["k"].tolist() == [2]
    assert fsck(recovered).ok


def test_torn_trailing_commit_group_drops_whole_transaction():
    """A commit group missing records at the END of the log is the torn
    tail of a crash during logging: replay drops the transaction whole
    (from the log too, so re-serialization cannot resurrect half of it)."""
    e = Engine()
    e.create_table("a", SCH)
    e.create_table("b", SCH)
    tx = e.begin()
    tx.insert("a", _batch([1]))
    tx.insert("b", _batch([2]))
    tx.commit()
    w = WAL.deserialize(e.wal.serialize())
    assert w.records[-1].kind == "commit" and w.records[-1].payload["ntab"] == 2
    w.records.pop()                       # tear the group's second record
    recovered = Engine.replay(w)
    assert recovered.table("a").scan()[0]["k"].shape[0] == 0
    assert recovered.table("b").scan()[0]["k"].shape[0] == 0
    assert recovered.ts == 0              # the torn txn's ts is not leaked
    assert w.records[-1].kind == "create_table"  # group gone from the log
    assert fsck(recovered).ok


def test_mid_log_incomplete_group_raises_typed_error():
    """An incomplete group with records AFTER it cannot be crash fallout
    (groups are logged contiguously before any swing): replay refuses with
    TornTransaction instead of guessing."""
    e = Engine()
    e.create_table("a", SCH)
    e.create_table("b", SCH)
    tx = e.begin()
    tx.insert("a", _batch([1]))
    tx.insert("b", _batch([2]))
    tx.commit()
    e.insert("a", _batch([3]))
    w = WAL.deserialize(e.wal.serialize())
    assert w.records[-2].payload["ntab"] == 2
    del w.records[-2]                     # tear a MID-log group
    with pytest.raises(TornTransaction):
        Engine.replay(w)


# --------------------------------------------------------------------------
# durable-format corruption: typed errors, never pickle garbage
# --------------------------------------------------------------------------

def _small_wal():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2, 3]))
    return e


def test_serialized_wal_bitflip_is_corrupt_frame():
    blob = bytearray(_small_wal().wal.serialize())
    blob[len(STORE_HEADER) + 8 + 40] ^= 0x10    # inside the frame payload
    with pytest.raises(CorruptFrame) as err:
        WAL.deserialize(bytes(blob))
    assert err.value.frame_index == 0           # typed, names the frame


def test_truncated_wal_is_torn_frame():
    blob = _small_wal().wal.serialize()
    with pytest.raises(TornFrame) as err:
        WAL.deserialize(blob[:-3])
    assert len(err.value.tail) > 0
    # ...and cutting into the length/crc prefix itself is still torn
    with pytest.raises(TornFrame):
        WAL.deserialize(blob[:len(STORE_HEADER) + 4])


def test_wrong_store_version_is_typed_with_upgrade_hint():
    blob = bytearray(_small_wal().wal.serialize())
    blob[4] = 99
    with pytest.raises(StoreVersionError, match="version 99"):
        WAL.deserialize(bytes(blob))
    bad_magic = b"NOPE" + bytes(blob[4:])
    with pytest.raises(StoreVersionError, match="bad magic"):
        WAL.deserialize(bad_magic)


def test_legacy_headerless_wal_still_loads():
    e = _small_wal()
    legacy = pickle.dumps(e.wal.records, protocol=pickle.HIGHEST_PROTOCOL)
    assert not legacy.startswith(MAGIC)
    w = WAL.deserialize(legacy)
    assert digests(Engine.replay(w)) == digests(e)


def test_object_bit_rot_is_reported_by_name_and_repairable():
    e = Engine()
    for name in script(e):
        pass
    oid = e.table("t").directory.data_oids[0]
    corrupt_object_bit(e.store.get(oid), row=0, bit=5)
    report = fsck(e)
    kinds = {(i.kind, i.oid) for i in report.issues}
    assert ("signature_mismatch", oid) in kinds   # typed, names the object
    repaired = fsck(e, repair=True, check_replay=False)
    assert oid in repaired.quarantined
    assert repaired.refs_unreachable
    # post-repair the engine is internally consistent again; only the
    # replay check still (correctly) reports divergence from the WAL
    clean = fsck(e, check_replay=False)
    assert clean.ok, [str(i) for i in clean.issues]
    assert {i.kind for i in fsck(e).issues} == {"replay_divergence"}


def test_fsck_flags_missing_object():
    e = _small_wal()
    e.store.delete(e.table("t").directory.data_oids[0])
    report = fsck(e)
    assert any(i.kind == "missing_object" for i in report.issues)


# --------------------------------------------------------------------------
# CLI store: crash points around the frame write/fsync
# --------------------------------------------------------------------------

def _cli_script(repo):
    repo.create_table("t", SCH)
    repo.insert("t", _batch([1, 2, 3]))


def test_cli_mid_frame_crash_recovers_and_preserves_tail(tmp_path, capsys):
    store = str(tmp_path / "s.wal")
    repo = load_repo(store)
    _cli_script(repo)
    save_repo(store, repo)
    pre = digests(repo.engine)
    repo2 = load_repo(store)
    repo2.insert("t", _batch([4, 5]))
    with inject(FaultPlan.at("cli.save.mid_frame")):
        with pytest.raises(InjectedCrash):
            save_repo(store, repo2)
    # the on-disk frame is genuinely torn: recovery = last acked state,
    # torn bytes preserved (never silently discarded), hint printed ONCE
    repo3 = load_repo(store)
    assert digests(repo3.engine) == pre
    assert os.path.getsize(store + ".corrupt") > 0
    assert "torn" in capsys.readouterr().err
    repo3b = load_repo(store)
    assert "torn" not in capsys.readouterr().err   # second load: silent
    assert digests(repo3b.engine) == pre
    # the next WRITE truncates the tail; the store is clean again
    repo3.insert("t", _batch([9]))
    save_repo(store, repo3)
    repo4 = load_repo(store)
    assert sorted(repo4.table("t").scan()[0]["k"].tolist()) == [1, 2, 3, 9]
    assert fsck(repo4.engine).ok


def test_cli_pre_fsync_crash_leaves_complete_frame(tmp_path):
    store = str(tmp_path / "s.wal")
    repo = load_repo(store)
    _cli_script(repo)
    save_repo(store, repo)
    repo2 = load_repo(store)
    repo2.insert("t", _batch([4]))
    post = digests(repo2.engine)
    with inject(FaultPlan.at("cli.save.pre_fsync")):
        with pytest.raises(InjectedCrash):
            save_repo(store, repo2)
    # all bytes written (fsync pending): both outcomes are all-or-nothing;
    # in-process the page cache survives, so the frame is present
    assert digests(load_repo(store).engine) == post


def test_cli_store_bitflip_is_corrupt_frame(tmp_path):
    store = str(tmp_path / "s.wal")
    repo = load_repo(store)
    _cli_script(repo)
    save_repo(store, repo)
    flip_bit(store, os.path.getsize(store) - 10, 2)
    with pytest.raises(CorruptFrame):
        load_repo(store)


def test_cli_legacy_store_upgrades_on_save(tmp_path):
    store = str(tmp_path / "s.wal")
    e = _small_wal()
    with open(store, "wb") as f:          # pre-ISSUE-6 headerless format
        pickle.dump(e.wal.records, f, protocol=pickle.HIGHEST_PROTOCOL)
    repo = load_repo(store)               # one-shot legacy path
    assert digests(repo.engine) == digests(e)
    repo.insert("t", _batch([7]))
    save_repo(store, repo)                # rewrites in the framed format
    with open(store, "rb") as f:
        assert f.read(4) == MAGIC
    repo2 = load_repo(store)
    assert sorted(repo2.table("t").scan()[0]["k"].tolist()) == [1, 2, 3, 7]
    assert fsck(repo2.engine).ok


# --------------------------------------------------------------------------
# fault-plan mechanics
# --------------------------------------------------------------------------

def test_fault_plan_validates_and_counts():
    with pytest.raises(KeyError):
        FaultPlan.at("no.such.point")
    with pytest.raises(ValueError):
        FaultPlan.at("wal.append", 0)
    e = Engine()
    with inject(FaultPlan.at("wal.append", 2)) as plan:
        e.create_table("t", SCH)          # hit 1 — survives
        with pytest.raises(InjectedCrash):
            e.create_table("u", SCH)      # hit 2 — trips
        with pytest.raises(RuntimeError):
            with inject(FaultPlan({})):   # no nesting
                pass
    assert plan.hits["wal.append"] == 2 and plan.tripped == "wal.append"
    e2 = Engine()
    e2.create_table("t", SCH)             # disarmed again: no-op
