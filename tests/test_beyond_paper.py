"""Beyond-paper extensions: three-way diff (paper §5.5.1, not exposed by
MatrixOne) and CELL-level conflict resolution (paper §5.5.3, future work)."""
import numpy as np
import pytest

from repro.core import (Column, CType, ConflictMode, Engine,
                        MergeConflictError, Schema, three_way_merge)
from repro.core.merge import (TW_BOTH_DIFFER, TW_BOTH_SAME, TW_SOURCE_ONLY,
                              TW_TARGET_ONLY, three_way_diff)

SCH = Schema((Column("k", CType.I64), Column("a", CType.F64),
              Column("b", CType.LOB)), primary_key=("k",))


def _setup():
    e = Engine()
    e.create_table("T", SCH)
    e.insert("T", {"k": np.arange(10), "a": np.ones(10),
                   "b": [b"x%d" % i for i in range(10)]})
    sn1 = e.create_snapshot("sn1", "T")
    e.clone_table("C", "sn1")
    return e, sn1


def test_three_way_diff_classification():
    e, sn1 = _setup()
    e.update_by_keys("T", {"k": [1], "a": [5.0], "b": [b"x1"]})  # target only
    e.update_by_keys("C", {"k": [2], "a": [6.0], "b": [b"x2"]})  # source only
    e.update_by_keys("T", {"k": [3], "a": [7.0], "b": [b"x3"]})  # both same
    e.update_by_keys("C", {"k": [3], "a": [7.0], "b": [b"x3"]})
    e.update_by_keys("T", {"k": [4], "a": [8.0], "b": [b"x4"]})  # both differ
    e.update_by_keys("C", {"k": [4], "a": [9.0], "b": [b"x4"]})
    twd = three_way_diff(e, sn1, e.current_snapshot("T"),
                         e.current_snapshot("C"))
    assert twd.k == 4
    assert sorted(twd.status.tolist()) == [TW_TARGET_ONLY, TW_SOURCE_ONLY,
                                           TW_BOTH_SAME, TW_BOTH_DIFFER]


def test_cell_merge_combines_disjoint_column_edits():
    e, sn1 = _setup()
    e.update_by_keys("T", {"k": [3], "a": [9.0], "b": [b"x3"]})   # col a
    e.update_by_keys("C", {"k": [3], "a": [1.0], "b": [b"NEW"]})  # col b
    rep = three_way_merge(e, "T", e.current_snapshot("C"), base=sn1,
                          mode=ConflictMode.CELL)
    assert rep.cell_merged == 1
    batch, _ = e.table("T").scan()
    i = int(np.flatnonzero(batch["k"] == 3)[0])
    assert batch["a"][i] == 9.0 and batch["b"][i] == b"NEW"
    assert e.table("T").count() == 10


def test_cell_merge_fails_on_same_cell_divergence():
    e, sn1 = _setup()
    e.update_by_keys("T", {"k": [4], "a": [100.0], "b": [b"x4"]})
    e.update_by_keys("C", {"k": [4], "a": [200.0], "b": [b"x4"]})
    with pytest.raises(MergeConflictError):
        three_way_merge(e, "T", e.current_snapshot("C"), base=sn1,
                        mode=ConflictMode.CELL)


def test_cell_merge_fails_on_del_vs_upd():
    e, sn1 = _setup()
    e.delete_by_keys("T", {"k": np.asarray([5])})
    e.update_by_keys("C", {"k": [5], "a": [3.0], "b": [b"z"]})
    with pytest.raises(MergeConflictError):
        three_way_merge(e, "T", e.current_snapshot("C"), base=sn1,
                        mode=ConflictMode.CELL)


def test_cell_merge_requires_pk_and_base():
    e = Engine()
    e.create_table("N", Schema(SCH.columns, primary_key=None))
    e.insert("N", {"k": [1], "a": [1.0], "b": [b"q"]})
    s = e.create_snapshot("s", "N")
    e.clone_table("M", "s")
    with pytest.raises(ValueError):
        three_way_merge(e, "N", e.current_snapshot("M"),
                        mode=ConflictMode.CELL)
