"""Operation telemetry suite (ISSUE 8).

Pins the four contracts of ``core.telemetry``:

* the registry — span/metric names register once, idempotently, and the
  ``stats_json`` key set is a golden schema (bump ``STATS_SCHEMA`` on any
  change);
* the tracer — spans nest with the call stack, record counter deltas,
  and the ARMED tree for the branch -> PR -> publish -> revert workflow
  is pinned by name and nesting;
* derived-state only — a replayed engine reports a clean registry and no
  armed tracer (traces never survive recovery);
* the exports — EXPLAIN renders zero-valued invariants
  (``commit.rows_rehashed=0``), the Chrome-tracing file is schema-stable
  JSON, and the CLI surfaces (``stats --format json``, ``--trace``) work
  end to end.  Plus a coarse smoke bound on armed overhead.
"""
import json
from time import perf_counter

import numpy as np
import pytest

from repro.core import Engine, Repo, snapshot_diff
from repro.core import telemetry
from repro.core.statements import execute

from conftest import VCS_SCHEMA, kv_batch

#: the golden ``datagit stats`` key set — a rename or addition is a schema
#: change: update this list AND bump telemetry.STATS_SCHEMA together
PINNED_METRICS = [
    "cache.delta_hits",
    "commit.apply_sort_merged",
    "commit.apply_sort_skipped",
    "commit.apply_sorts",
    "commit.lob_rows_hashed",
    "commit.rows_carried",
    "commit.rows_rehashed",
    "delta.bytes_scanned",
    "delta.objects_scanned",
    "delta.objects_skipped_shared",
    "delta.rows_scanned",
    "gc.objects_freed",
    "gc.pinned_horizons",
    "gc.versions_pruned",
    "probe.expansions",
    "probe.hits",
    "probe.objects_probed",
    "probe.objects_pruned",
    "probe.queries",
    "probe.shard_parts",
    "store.bytes_packed",
    "store.evictions",
    "store.faults",
    "store.hits",
    "store.objects_pulled",
    "store.objects_pushed",
    "store.spills",
    "vis.builds",
    "vis.derives",
    "vis.extends",
    "vis.hits",
    "wal.bytes",
    "wal.frames",
    "wal.fsyncs",
]


def _mk_repo(rows=1000):
    repo = Repo()
    repo.engine.create_table("t", VCS_SCHEMA)
    tx = repo.engine.begin()
    tx.insert("t", kv_batch(range(rows)))
    tx.commit()
    return repo


def _names(spans):
    return [s.name for s in spans]


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_idempotent_and_conflicting():
    # same doc re-registers as a no-op (module reimport)...
    assert telemetry.register_span("diff", telemetry.registered_spans()
                                   ["diff"]) == "diff"
    n = len(telemetry.registered_spans())
    telemetry.register_span("diff", telemetry.registered_spans()["diff"])
    assert len(telemetry.registered_spans()) == n
    # ...a different doc is a bug
    with pytest.raises(ValueError):
        telemetry.register_span("diff", "something else entirely")
    with pytest.raises(ValueError):
        telemetry.register_metric("vis.builds", "something else entirely")


def test_disarmed_span_is_the_null_singleton():
    assert telemetry.current() is None
    s = telemetry.span("diff")
    assert s is telemetry._NULL
    assert telemetry.span("publish") is s          # one object, ever
    with s:
        pass                                       # and it is a no-op CM


def test_armed_span_must_be_registered():
    with telemetry.trace(None):
        with pytest.raises(KeyError):
            telemetry.span("never.registered")


def test_trace_does_not_nest():
    with telemetry.trace(None):
        with pytest.raises(RuntimeError):
            with telemetry.trace(None):
                pass
    assert telemetry.current() is None             # cleared on exit


def test_stats_json_golden_schema():
    repo = _mk_repo()
    doc = telemetry.stats_json(repo.engine)
    assert set(doc) == {"schema", "metrics"}
    assert doc["schema"] == telemetry.STATS_SCHEMA == 3
    assert list(doc["metrics"]) == PINNED_METRICS  # sorted AND complete
    # engine=None (CLI arms before the store loads): same keys, all zero
    empty = telemetry.stats_json(None)
    assert list(empty["metrics"]) == PINNED_METRICS
    assert not any(empty["metrics"].values())
    json.dumps(doc)                                # round-trippable


# --------------------------------------------------------------------------
# span trees
# --------------------------------------------------------------------------

def test_cold_diff_span_tree():
    repo = _mk_repo()
    e = repo.engine
    sn1 = e.create_snapshot("s1", "t")
    tx = e.begin()
    tx.update_by_keys("t", kv_batch(range(100), vals=np.arange(100) * 2.0))
    tx.commit()
    sn2 = e.create_snapshot("s2", "t")
    # cold everything: a fresh process would have empty caches
    e.store.vis_cache.clear()
    if e.store.delta_cache is not None:
        e.store.delta_cache.clear()
    with repo.trace() as t:
        repo.diff("snap:s1", "snap:s2", table="t")
    assert _names(t.roots) == ["diff"]
    (diff,) = t.roots
    assert _names(diff.children) == ["signed_delta"]
    (sd,) = diff.children
    assert set(_names(sd.children)) == {"visibility.build"}
    assert sd.counters["vis.builds"] >= 1
    assert diff.counters["delta.rows_scanned"] > 0
    assert diff.dur_s > 0 and sd.t0_rel >= diff.t0_rel


def test_workflow_e2e_span_tree():
    repo = _mk_repo()
    repo.branch("dev", ["t"])
    with repo.trace() as t:
        tx = repo.engine.begin()
        tx.insert("dev/t", kv_batch(range(1000, 1100)))
        tx.commit()
        pr = repo.open_pr("dev")
        repo.publish(pr.id)
        repo.revert_pr(pr.id)
    # pinned by name AND nesting: the mutation commit, then publish with
    # its per-table plan -> commit(seal, swing), then the inverse-Δ revert
    assert _names(t.roots) == ["commit", "publish", "revert_publish"]
    commit, publish, revert = t.roots
    assert _names(commit.children) == ["commit.seal", "commit.swing"]
    assert _names(publish.children) == ["plan_merge", "commit"]
    plan, pcommit = publish.children
    assert set(_names(plan.children)) == {"signed_delta"}
    assert _names(pcommit.children) == ["commit.seal", "commit.swing"]
    assert pcommit.counters["commit.rows_carried"] > 0
    assert pcommit.counters.get("commit.rows_rehashed", 0) == 0
    assert "commit" in _names(revert.children)
    assert "signed_delta" in _names(revert.children)


def test_gc_span_and_gauge():
    repo = _mk_repo()
    e = repo.engine
    tx = e.begin()
    tx.update_by_keys("t", kv_batch(range(10), vals=np.arange(10) * 3.0))
    tx.commit()
    with repo.trace() as t:
        e.gc()
    (g,) = t.roots
    assert g.name == "gc"
    stats = repo.stats()
    assert stats["gc.pinned_horizons"] == e.gc().pinned_horizons  # gauge


# --------------------------------------------------------------------------
# derived state only: replay comes back clean
# --------------------------------------------------------------------------

def test_replayed_engine_reports_clean_metrics():
    repo = _mk_repo()
    e = repo.engine
    tx = e.begin()
    tx.update_by_keys("t", kv_batch(range(50), vals=np.arange(50) * 2.0))
    tx.commit()
    e.create_snapshot("s", "t")
    repo.diff("snap:s", "HEAD", table="t")        # accumulate counters
    assert any(telemetry.metrics_snapshot(e).values())
    e2 = Engine.replay(e.wal)
    snap = telemetry.metrics_snapshot(e2)
    assert sorted(snap) == PINNED_METRICS
    assert not any(snap.values()), {k: v for k, v in snap.items() if v}
    assert telemetry.current() is None            # no tracer leaked


# --------------------------------------------------------------------------
# surfaces: status / statements / EXPLAIN
# --------------------------------------------------------------------------

def test_repo_status_and_statement_carry_metrics():
    repo = _mk_repo()
    st = repo.status()
    assert list(st["metrics"]) == PINNED_METRICS
    assert st["metrics"]["wal.frames"] == repo.stats()["wal.frames"]
    msg = execute(repo, "STATUS").message
    assert "metric wal.frames=" in msg
    res = execute(repo, "STATS")
    assert res.kind == "stats"
    assert res.data == telemetry.stats_json(repo.engine)
    assert any(line.startswith("wal.frames=") for line
               in res.message.splitlines())


def test_explain_merge_shows_zero_rehash():
    repo = _mk_repo()
    repo.branch("dev", ["t"])
    tx = repo.engine.begin()
    tx.insert("dev/t", kv_batch(range(1000, 1200)))
    tx.commit()
    res = execute(repo, "EXPLAIN MERGE BRANCH dev INTO main")
    # the span tree renders merge -> plan_merge and the seal counters make
    # the zero-rehash invariant VISIBLE (group expansion prints the zero)
    assert res.kind == "explain"
    assert "merge" in res.message and "plan_merge" in res.message
    assert "commit.rows_rehashed=0" in res.message
    assert "commit.rows_carried=200" in res.message


def test_explain_warm_diff_shows_zero_builds():
    repo = _mk_repo()
    e = repo.engine
    e.create_snapshot("s1", "t")
    tx = e.begin()
    tx.update_by_keys("t", kv_batch(range(20), vals=np.arange(20) * 2.0))
    tx.commit()
    e.create_snapshot("s2", "t")
    repo.diff("snap:s1", "snap:s2", table="t")    # warm the vis cache
    tx = e.begin()
    tx.update_by_keys("t", kv_batch(range(5), vals=np.arange(5) * 7.0))
    tx.commit()
    e.create_snapshot("s3", "t")
    # delta cache misses (new pair) but visibility stays warm: the vis
    # group is touched, so its zero build count is printed, not omitted
    res = execute(repo, "EXPLAIN DIFF 'snap:s1' AGAINST 'snap:s3' "
                        "FOR TABLE t")
    assert "vis.builds=0" in res.message
    assert "signed_delta" in res.message


def test_explain_unknown_verb_suggests():
    repo = _mk_repo()
    from repro.core.statements import StatementError
    with pytest.raises(StatementError):
        execute(repo, "EXPLAIN EXPLAIN STATUS")
    with pytest.raises(StatementError):
        execute(repo, "EXPLAIN FROBNICATE")


def test_explain_nests_under_an_armed_tracer():
    repo = _mk_repo()
    with repo.trace() as t:
        res = execute(repo, "EXPLAIN STATS")
    assert res.kind == "explain"
    assert "explain" in _names(t.roots)           # no second tracer armed


# --------------------------------------------------------------------------
# chrome-tracing export + CLI surfaces
# --------------------------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    repo = _mk_repo()
    e = repo.engine
    e.create_snapshot("s", "t")
    with repo.trace() as t:
        repo.diff("snap:s", "HEAD", table="t")
    out = tmp_path / "trace.json"
    telemetry.write_chrome_trace(str(out), t)
    events = json.loads(out.read_text())
    assert events, "no events exported"
    for ev in events:
        assert set(ev) == {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                           "args"}
        assert ev["ph"] == "X" and ev["cat"] == "datagit"
        assert ev["dur"] >= 0 and ev["ts"] >= 0
    # one event per line: line-splittable for streaming consumers
    lines = out.read_text().splitlines()
    assert lines[0] == "[" and lines[-1] == "]"
    assert len(lines) == len(events) + 2


def test_cli_stats_and_trace(tmp_path, capsys):
    from repro.vcs_cli import main
    store = str(tmp_path / "s.wal")

    def dg(*a):
        rc = main(["--store", store, *a])
        out = capsys.readouterr().out
        assert rc == 0, out
        return out

    dg("init")
    dg("seed", "t", "--rows", "200")
    doc = json.loads(dg("stats", "--format", "json"))
    assert doc["schema"] == telemetry.STATS_SCHEMA
    assert list(doc["metrics"]) == PINNED_METRICS
    text = dg("stats")
    assert any(ln.startswith("wal.frames=") for ln in text.splitlines())

    trace = tmp_path / "out.jsonl"
    dg("--trace", str(trace), "seed", "u", "--rows", "100")
    events = json.loads(trace.read_text())
    names = [ev["name"] for ev in events]
    assert names[0] == "cli.seed"                 # the invocation root
    assert "replay" in names                      # armed before load
    assert "commit" in names
    for ev in events:
        assert set(ev) == {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                           "args"}


# --------------------------------------------------------------------------
# armed overhead smoke (the REAL parity gate is the interleaved A/B bench
# against the previous HEAD — this catches only gross regressions)
# --------------------------------------------------------------------------

def test_tracer_armed_overhead_smoke():
    repo = _mk_repo(rows=60_000)
    e = repo.engine
    a = e.create_snapshot("s1", "t")
    tx = e.begin()
    tx.update_by_keys("t", kv_batch(range(5000),
                                    vals=np.arange(5000) * 2.0))
    tx.commit()
    b = e.create_snapshot("s2", "t")

    def once():
        # cold every rep so both sides do identical full work
        e.store.vis_cache.clear()
        if e.store.delta_cache is not None:
            e.store.delta_cache.clear()
        t0 = perf_counter()
        snapshot_diff(e.store, a, b)
        return perf_counter() - t0

    once()                                        # warm numpy/allocator
    disarmed, armed = [], []
    for _ in range(5):                            # interleaved, min-fold
        disarmed.append(once())
        with telemetry.trace(e):
            armed.append(once())
    assert min(armed) <= min(disarmed) * 1.3, (min(armed), min(disarmed))
