"""Engine/table behaviour: transactions, MVCC, PK enforcement, PITR, WAL."""
import numpy as np
import pytest

from repro.core import (Column, CType, Engine, PKViolation, Schema,
                        TxnConflict, WAL)

SCH = Schema((Column("k", CType.I64), Column("v", CType.F64),
              Column("doc", CType.LOB)), primary_key=("k",))
SCH_NOPK = Schema(SCH.columns, primary_key=None)


def _batch(keys, vals=None, docs=None):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": np.asarray(vals if vals is not None else keys * 0.5),
            "doc": [b"d%d" % k for k in keys] if docs is None else docs}


def test_insert_scan_roundtrip():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([3, 1, 2]))
    batch, rowids = e.table("t").scan()
    assert sorted(batch["k"].tolist()) == [1, 2, 3]
    assert e.table("t").count() == 3
    assert all(isinstance(d, bytes) for d in batch["doc"])


def test_pk_enforced_within_batch_and_across_commits():
    e = Engine()
    e.create_table("t", SCH)
    with pytest.raises(PKViolation):
        e.insert("t", _batch([1, 1]))
    e.insert("t", _batch([1, 2]))
    with pytest.raises(PKViolation):
        e.insert("t", _batch([2]))
    # update (delete+insert same txn) is allowed
    e.update_by_keys("t", _batch([2], vals=[9.0]))
    batch, _ = e.table("t").scan()
    assert batch["v"][batch["k"] == 2][0] == 9.0


def test_delete_and_double_delete_conflict():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2, 3]))
    assert e.delete_by_keys("t", {"k": np.asarray([2])}) == 1
    assert e.table("t").count() == 2
    _, rowids = e.table("t").scan()
    tx1 = e.begin()
    tx1.delete_rowids("t", rowids[:1])
    tx1.commit()
    tx2 = e.begin()
    tx2.delete_rowids("t", rowids[:1])  # same row again
    with pytest.raises(TxnConflict):
        tx2.commit()


def test_mvcc_timestamp_snapshot_pitr():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1]))
    ts1 = e.ts
    e.insert("t", _batch([2]))
    e.delete_by_keys("t", {"k": np.asarray([1])})
    old = e.snapshot_at("t", ts1)          # T{mo_ts = ts1}
    batch, _ = e.table("t").scan(old.directory)
    assert batch["k"].tolist() == [1]
    cur, _ = e.table("t").scan()
    assert cur["k"].tolist() == [2]


def test_clone_is_metadata_only_and_independent():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch(np.arange(1000)))
    bytes_before = e.store.bytes_written
    snap = e.create_snapshot("s1", "t")
    e.clone_table("c", "s1")
    assert e.store.bytes_written == bytes_before  # zero data copied
    e.insert("c", _batch([5000]))
    e.delete_by_keys("t", {"k": np.asarray([0])})
    assert e.table("c").count() == 1001
    assert e.table("t").count() == 999


def test_restore_is_git_reset_hard():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2]))
    snap = e.create_snapshot("s1", "t")
    e.insert("t", _batch([3]))
    e.restore_table("t", "s1")
    batch, _ = e.table("t").scan()
    assert sorted(batch["k"].tolist()) == [1, 2]
    # restore from ANOTHER table's snapshot = pull (paper §3)
    e.create_table("u", SCH)
    e.insert("u", _batch([7]))
    e.restore_table("u", "s1")
    assert sorted(e.table("u").scan()[0]["k"].tolist()) == [1, 2]


def test_wal_replay_reproduces_logical_state():
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2, 3]))
    e.create_snapshot("s1", "t")
    e.clone_table("c", "s1")
    e.update_by_keys("c", _batch([2], vals=[77.0]))
    e.delete_by_keys("t", {"k": np.asarray([3])})
    e.restore_table("t", "s1")

    # serialize + deserialize the log (LogService durability), then replay
    wal2 = WAL.deserialize(e.wal.serialize())
    e2 = Engine.replay(wal2)
    for tbl in ("t", "c"):
        b1, _ = e.table(tbl).scan()
        b2, _ = e2.table(tbl).scan()
        o1 = np.argsort(b1["k"])
        o2 = np.argsort(b2["k"])
        assert np.array_equal(b1["k"][o1], b2["k"][o2])
        assert np.array_equal(b1["v"][o1], b2["v"][o2])
        assert [b1["doc"][i] for i in o1] == [b2["doc"][i] for i in o2]
    assert e2.ts == e.ts


def test_gc_respects_named_snapshots():
    e = Engine(retention_versions=1)
    e.create_table("t", SCH)
    e.insert("t", _batch([1, 2]))
    snap = e.create_snapshot("keep", "t")
    e.delete_by_keys("t", {"k": np.asarray([1])})
    e.insert("t", _batch([3]))
    collected = e.gc()
    # snapshot still fully readable after GC
    batch, _ = e.table("t").scan(snap.directory)
    assert sorted(batch["k"].tolist()) == [1, 2]
    e.drop_snapshot("keep")
    e.gc()
    batch, _ = e.table("t").scan()
    assert sorted(batch["k"].tolist()) == [2, 3]


def test_nopk_duplicates_supported():
    e = Engine()
    e.create_table("t", SCH_NOPK)
    e.insert("t", _batch([1, 1, 1], vals=[2.0, 2.0, 2.0],
                         docs=[b"x", b"x", b"x"]))
    assert e.table("t").count() == 3
    t = e.table("t")
    _, rowids = t.scan()
    tx = e.begin()
    tx.delete_rowids("t", rowids[:1])
    tx.commit()
    assert e.table("t").count() == 2


def test_lob_signature_identity():
    """LOB columns diff by content signature — identical bytes, same row."""
    from repro.core import snapshot_diff
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch([1], docs=[b"payload"]))
    s1 = e.create_snapshot("s1", "t")
    e.clone_table("c", "s1")
    # rewrite the same logical row with IDENTICAL content
    e.update_by_keys("c", _batch([1], docs=[b"payload"]))
    d = snapshot_diff(e.store, s1, e.current_snapshot("c"))
    assert d.is_empty()
    e.update_by_keys("c", _batch([1], docs=[b"payload2"]))
    d2 = snapshot_diff(e.store, s1, e.current_snapshot("c"))
    assert d2.n_groups == 2
