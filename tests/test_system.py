"""End-to-end behaviour tests for the paper's system: the full Listing-1
workflow, the collaborative publish pipeline, and training on versioned
data — the paper's §1 story as executable assertions."""
import numpy as np
import pytest

from repro.configs.paper_vcs import LINEITEM_SCHEMA, gen_lineitem
from repro.core import (ConflictMode, Engine, MergeConflictError,
                        snapshot_diff, sql_diff, three_way_merge)


def _bump(base, tag):
    out = {k: v.copy() for k, v in base.items()}
    out["l_quantity"] = out["l_quantity"] + tag
    out["l_comment"] = np.array(
        [b"t%d-%d" % (tag, i) for i in range(len(out["l_comment"]))],
        dtype=object)
    return out


def test_listing1_workflow_end_to_end():
    """Paper Listing 1: snapshot -> clone -> edits both sides -> diff ->
    merge -> verify content."""
    e = Engine()
    e.create_table("T", LINEITEM_SCHEMA)
    base = gen_lineitem(20_000)
    e.insert("T", base)
    sn1 = e.create_snapshot("sn1", "T")
    e.clone_table("TClone", "sn1")

    # modify T and TClone independently
    e.update_by_keys("T", {k: v[:50] for k, v in _bump(base, 1).items()})
    sn2 = e.create_snapshot("sn2", "T")
    tx = e.begin()
    tx.update_by_keys("TClone", {k: v[100:180]
                                 for k, v in _bump(base, 2).items()})
    tx.commit()
    sn3 = e.create_snapshot("sn3", "TClone")

    d = snapshot_diff(e.store, sn2, sn3)
    assert d.n_groups == 2 * (50 + 80)
    # Δ-scan read ~260 rows, not 40k
    assert d.stats.rows_scanned < 1000

    rep = three_way_merge(e, "T", sn3, base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0 and rep.inserted == 80
    assert e.table("T").count() == 20_000
    # T now contains BOTH change sets
    d_final = snapshot_diff(e.store, e.current_snapshot("T"), sn1)
    assert d_final.n_groups == 2 * (50 + 80)


def test_push_pull_via_restore():
    """Paper §3: RESTORE TABLE TClone FROM SNAPSHOT T{sn2} == git reset."""
    e = Engine()
    e.create_table("T", LINEITEM_SCHEMA)
    base = gen_lineitem(5_000)
    e.insert("T", base)
    sn1 = e.create_snapshot("sn1", "T")
    e.clone_table("TClone", "sn1")
    e.update_by_keys("T", {k: v[:10] for k, v in _bump(base, 1).items()})
    sn2 = e.create_snapshot("sn2", "T")
    tx = e.begin()
    tx.update_by_keys("TClone", {k: v[20:25]
                                 for k, v in _bump(base, 3).items()})
    tx.commit()
    e.restore_table("TClone", "sn2")  # pull: overwrite local changes
    d = snapshot_diff(e.store, e.current_snapshot("TClone"), sn2)
    assert d.is_empty()


def test_ci_cd_publish_pipeline():
    """Branch -> validate (CI) -> atomic publish; failed CI never touches
    prod."""
    e = Engine()
    e.create_table("prod", LINEITEM_SCHEMA)
    base = gen_lineitem(10_000)
    e.insert("prod", base)
    rel = e.create_snapshot("rel", "prod")
    e.clone_table("dev", "rel")
    bad = {k: v[:5].copy() for k, v in base.items()}
    bad["l_quantity"] = np.full(5, -1.0)  # violates business rule
    e.update_by_keys("dev", bad)
    d = snapshot_diff(e.store, rel, e.current_snapshot("dev"))
    payload = d.payload(e.store)
    ci_pass = bool((payload["l_quantity"] >= 0).all())
    assert not ci_pass
    # CI failed -> no merge; prod untouched
    assert snapshot_diff(e.store, e.current_snapshot("prod"), rel).is_empty()
    # fix the data, CI passes, publish atomically
    good = {k: v[:5].copy() for k, v in base.items()}
    good["l_quantity"] = np.full(5, 7.0)
    e.update_by_keys("dev", good)
    d2 = snapshot_diff(e.store, rel, e.current_snapshot("dev"))
    assert bool((d2.payload(e.store)["l_quantity"] >= 0).all())
    rep = three_way_merge(e, "prod", e.current_snapshot("dev"),
                          base=rel, mode=ConflictMode.FAIL)
    assert rep.commit_ts is not None  # one atomic transaction


def test_examples_run():
    """The quickstart example executes cleanly."""
    import subprocess
    import sys
    r = subprocess.run([sys.executable, "examples/quickstart.py"],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "merge:" in r.stdout
