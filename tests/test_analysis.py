"""Self-consistency suite for the invariant lint (ISSUE 7).

Every pass gets a POSITIVE fixture (a known-bad snippet is flagged) and a
NEGATIVE fixture (a justified pragma suppresses it); the repo itself must
lint clean; the JSON schema and the baseline-diff contract are pinned; and
the CI failure mode is demonstrated by running the real entry point on an
injected bad snippet (exit 1) rather than by breaking CI.
"""
import json
import subprocess
import sys
from pathlib import Path

from repro.analysis import (ALL_RULES, SCHEMA_VERSION, default_paths,
                            repo_root, run_analysis, to_json)
from repro.analysis.runner import main as lint_main

REPO = repo_root()


def lint_tree(tmp_path, files, _seq=[0]):
    """Write {relpath: source} under a fresh subtree and lint it."""
    _seq[0] += 1
    base = tmp_path / f"tree{_seq[0]}"
    for rel, src in files.items():
        f = base / rel
        f.parent.mkdir(parents=True, exist_ok=True)
        f.write_text(src)
    return run_analysis([base], root=base)


def flagged(findings, rule):
    return [f for f in findings if f.rule == rule and not f.suppressed]


def suppressed(findings, rule):
    return [f for f in findings if f.rule == rule and f.suppressed]


# --------------------------------------------------------------------------
# per-rule positive + negative fixtures
# --------------------------------------------------------------------------

def test_sorted_claims_positive_and_negative(tmp_path):
    fs = lint_tree(tmp_path, {"app.py": (
        "s = SignedStream(d, runs=r)\n"
        "b = SigBatch(a, b, c, d, e, runs=r)\n"
        "o = seal_data_object(1, sch, batch, ts, rl, rh, kl, kh, {},\n"
        "                     presorted=True)\n"
        "tx.insert('t', batch, sigs=s)\n"
        "r1 = SigBatch.sorted_run()\n"
        "ok = SignedStream(d, runs=None)\n"          # no claim: clean
        "tx2.insert('t', batch)\n"                   # no sigs: clean
    )})
    msgs = [f.message for f in flagged(fs, "sorted-claims")]
    assert len(msgs) == 5, msgs
    assert any("SignedStream" in m for m in msgs)
    assert any("SigBatch constructed" in m for m in msgs)
    assert any("presorted=True" in m for m in msgs)
    assert any("sigs=" in m for m in msgs)
    assert any("sorted_run" in m for m in msgs)

    fs = lint_tree(tmp_path, {"app.py": (
        "# lint: runs-ok fixture — runs come from a sealed object scan\n"
        "s = SignedStream(d, runs=r)\n"
        "tx.insert('t', batch, sigs=s)  "
        "# lint: runs-ok fixture carry reason\n"
    )})
    assert not flagged(fs, "sorted-claims")
    assert len(suppressed(fs, "sorted-claims")) == 2
    assert all(f.reason for f in suppressed(fs, "sorted-claims"))


def test_sorted_claims_allowlists_producer_modules(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/delta.py":
                              "s = SignedStream(d, runs=r)\n"})
    assert not flagged(fs, "sorted-claims")


def test_hidden_sort_positive_and_negative(tmp_path):
    bad = "import numpy as np\no = np.lexsort((hi, lo))\nu = np.unique(x)\n"
    fs = lint_tree(tmp_path, {"src/repro/core/merge.py": bad})
    assert len(flagged(fs, "hidden-sort")) == 2
    # same code outside the hot modules: not a finding
    fs = lint_tree(tmp_path, {"src/repro/core/fsck.py": bad})
    assert not flagged(fs, "hidden-sort")
    fs = lint_tree(tmp_path, {"src/repro/core/merge.py": (
        "import numpy as np\n"
        "# lint: sort-ok fixture — conflict-slice refinement\n"
        "o = np.lexsort((hi, lo))\n"
    )})
    assert not flagged(fs, "hidden-sort")
    assert suppressed(fs, "hidden-sort")


def test_crash_coverage_positive_and_negative(tmp_path):
    fs = lint_tree(tmp_path, {"seams.py": (
        "import os\n"
        "from repro.core.faults import crash_point, register\n"
        "CP_DEAD = register('fixture.dead', 'never marked')\n"
        "CP_LIVE = register('fixture.live', 'marked in swallow')\n"
        "def save(f):\n"
        "    os.fsync(f.fileno())\n"
        "def swallow():\n"
        "    try:\n"
        "        crash_point(CP_LIVE)\n"
        "    except Exception:\n"
        "        pass\n"
    )})
    msgs = [f.message for f in flagged(fs, "crash-coverage")]
    assert any("'fixture.dead' is registered but never" in m
               for m in msgs), msgs
    assert any("os.fsync" in m for m in msgs), msgs
    assert any("except Exception" in m for m in msgs), msgs

    fs = lint_tree(tmp_path, {"seams.py": (
        "import os\n"
        "from repro.core.faults import crash_point, register\n"
        "CP_SAVE = register('fixture.save', 'pre-fsync seam')\n"
        "def save(f):\n"
        "    crash_point(CP_SAVE)\n"
        "    os.fsync(f.fileno())\n"
        "def forensic(f):\n"
        "    # lint: crash-ok fixture — best-effort sidecar, no ack lost\n"
        "    os.fsync(f.fileno())\n"
    )})
    assert not flagged(fs, "crash-coverage")
    assert suppressed(fs, "crash-coverage")


def test_deprecation_catches_aliasing_attr_and_getattr(tmp_path):
    fs = lint_tree(tmp_path, {"app.py": (
        "from repro.core.workspace import resolve_branch as rb\n"
        "f = engine.resolve_snapshot\n"          # aliased, called later
        "g = getattr(engine, 'snapshot_at')\n"
        "snap = f(ref)\n"
    )})
    hows = [f.message for f in flagged(fs, "deprecation")]
    assert len(hows) == 3, hows
    assert any("import" in m for m in hows)
    assert any("attribute access" in m for m in hows)
    assert any("getattr" in m for m in hows)

    fs = lint_tree(tmp_path, {"app.py": (
        "# lint: legacy-ok fixture — migration shim for one release\n"
        "f = engine.resolve_snapshot\n"
    )})
    assert not flagged(fs, "deprecation")
    assert suppressed(fs, "deprecation")
    # the shim module itself may define/use the names
    fs = lint_tree(tmp_path, {"src/repro/core/engine.py": (
        "def resolve_snapshot(self, ref):\n    return None\n"
        "x = engine.resolve_snapshot\n"
    )})
    assert not flagged(fs, "deprecation")


def test_wal_hygiene_positive_and_negative(tmp_path):
    facts = {
        "src/repro/core/wal.py": "KINDS = frozenset({'commit'})\n",
        "src/repro/core/engine.py": (
            "class Engine:\n"
            "    @staticmethod\n"
            "    def replay(wal):\n"
            "        for rec in wal:\n"
            "            k = rec.kind\n"
            "            if k == 'commit':\n"
            "                pass\n"
        ),
    }
    fs = lint_tree(tmp_path, {**facts, "app.py": (
        "import time\n"
        "def log_bad(self):\n"
        "    self.wal.append('bogus', ts=time.time())\n"
    )})
    msgs = [f.message for f in flagged(fs, "wal-hygiene")]
    assert any("unknown WAL kind 'bogus'" in m for m in msgs), msgs
    assert any("time.time" in m for m in msgs), msgs

    fs = lint_tree(tmp_path, {**facts, "app.py": (
        "def log_ok(self, ts):\n"
        "    self.wal.append('commit', ts=ts)\n"
    )})
    assert not flagged(fs, "wal-hygiene")

    # a kind in KINDS that replay never dispatches is flagged at wal.py
    facts2 = dict(facts)
    facts2["src/repro/core/wal.py"] = \
        "KINDS = frozenset({'commit', 'orphan'})\n"
    fs = lint_tree(tmp_path, {**facts2, "app.py": "x = 1\n"})
    msgs = [f.message for f in flagged(fs, "wal-hygiene")]
    assert any("'orphan'" in m and "never dispatches" in m for m in msgs)

    # the replay dispatch may be split across a `_replay*` helper (the
    # public wrapper opens a telemetry span) — kinds are still collected
    facts3 = dict(facts)
    facts3["src/repro/core/engine.py"] = (
        "class Engine:\n"
        "    @staticmethod\n"
        "    def replay(wal):\n"
        "        return Engine._replay_loop(wal)\n"
        "    @staticmethod\n"
        "    def _replay_loop(wal):\n"
        "        for rec in wal:\n"
        "            k = rec.kind\n"
        "            if k == 'commit':\n"
        "                pass\n"
    )
    fs = lint_tree(tmp_path, {**facts3, "app.py": (
        "def log_bad(self):\n"
        "    self.wal.append('bogus')\n"
    )})
    msgs = [f.message for f in flagged(fs, "wal-hygiene")]
    assert any("unknown WAL kind 'bogus'" in m for m in msgs), msgs


def test_wal_hygiene_clock_allowlist(tmp_path):
    # ISSUE 8: a clock read in ANY repro.core module is flagged...
    clocky = ("import time\n"
              "def stamp():\n"
              "    return time.perf_counter()\n")
    fs = lint_tree(tmp_path, {"src/repro/core/clocky.py": clocky})
    msgs = [f.message for f in flagged(fs, "wal-hygiene")]
    assert any("clocks belong to" in m for m in msgs), msgs
    # ...but the SAME source at core/telemetry.py is allowlisted — the
    # span tracer is the one sanctioned home for the clock
    fs = lint_tree(tmp_path, {"src/repro/core/telemetry.py": clocky})
    assert not flagged(fs, "wal-hygiene")
    # outside repro.core the module-wide clock check does not apply
    fs = lint_tree(tmp_path, {"src/repro/launch/serve.py": clocky})
    assert not flagged(fs, "wal-hygiene")
    # a clock inside a WAL-logging function reports once (the logging-
    # function finding), not twice
    fs = lint_tree(tmp_path, {"src/repro/core/clocky.py": (
        "import time\n"
        "def log_bad(self):\n"
        "    self.wal.append('commit', ts=time.time())\n"
    )})
    msgs = [f.message for f in flagged(fs, "wal-hygiene")
            if "time.time" in f.message]
    assert len(msgs) == 1, msgs
    # a justified pragma suppresses the module-wide check too
    fs = lint_tree(tmp_path, {"src/repro/core/clocky.py": (
        "import time\n"
        "# lint: wal-ok fixture — coarse progress meter, never logged\n"
        "t = time.perf_counter()\n"
    )})
    assert not flagged(fs, "wal-hygiene")
    assert suppressed(fs, "wal-hygiene")


def test_sealed_write_positive_negative_and_taint(tmp_path):
    fs = lint_tree(tmp_path, {"app.py": (
        "def direct(obj):\n"
        "    obj.key_lo[0] = 1\n"
        "def aliased(obj):\n"
        "    arr = obj.cols['v']\n"
        "    arr[0] = 2.0\n"
        "def viewed(obj):\n"
        "    flat = obj.cols['v'].view('u1')\n"
        "    flat[3] ^= 1\n"
        "def unfreeze(a):\n"
        "    a.setflags(write=True)\n"
    )})
    assert len(flagged(fs, "sealed-write")) == 4

    fs = lint_tree(tmp_path, {"app.py": (
        "def fresh(obj):\n"
        "    arr = obj.cols['v'].copy()\n"      # copy kills the taint
        "    arr[0] = 2.0\n"
        "    out = np.concatenate([obj.key_lo, obj.key_lo])\n"
        "    out[0] = 3\n"
        "def injector(obj):\n"
        "    # lint: seal-ok fixture — corruption injector swaps a copy\n"
        "    obj.cols['v'] = rotted\n"
    )})
    assert not flagged(fs, "sealed-write")
    assert suppressed(fs, "sealed-write")


def test_pragma_meta_rule(tmp_path):
    fs = lint_tree(tmp_path, {"app.py": (
        "x = np.unique(y)  # lint: sort-ok\n"          # reasonless
        "z = 1  # lint: sort-okay typo reason\n"       # unknown token
    )})
    msgs = [f.message for f in flagged(fs, "pragma")]
    assert any("has no reason" in m for m in msgs), msgs
    assert any("unknown lint pragma token" in m for m in msgs), msgs
    # and the reasonless pragma did NOT suppress
    fs2 = lint_tree(tmp_path, {"src/repro/core/merge.py":
                               "import numpy as np\n"
                               "x = np.unique(y)  # lint: sort-ok\n"})
    assert flagged(fs2, "hidden-sort")


# --------------------------------------------------------------------------
# whole-tree gates
# --------------------------------------------------------------------------

def test_repo_lints_clean():
    findings = run_analysis(default_paths(REPO), root=REPO)
    bad = [f for f in findings if not f.suppressed]
    assert not bad, "\n".join(f.render() for f in bad)
    # every suppression in the tree carries a written reason
    assert all(f.reason for f in findings if f.suppressed)


def test_every_rule_has_distinct_pragma_token():
    tokens = [r.pragma for r in ALL_RULES]
    assert len(set(tokens)) == len(tokens) == len(ALL_RULES) >= 5


def test_json_schema_pinned(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/merge.py":
                              "import numpy as np\nx = np.unique(y)\n"})
    doc = to_json(fs, nfiles=1)
    assert set(doc) == {"schema", "rules", "counts", "findings"}
    assert doc["schema"] == SCHEMA_VERSION == 1
    assert set(doc["counts"]) == {"files", "findings", "suppressed"}
    assert set(doc["rules"]) == {r.id for r in ALL_RULES}
    for f in doc["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message",
                          "hint", "suppressed", "reason"}
    json.dumps(doc)                     # round-trippable


def test_committed_baseline_matches_schema_and_is_clean():
    base = json.loads((REPO / "LINT_baseline.json").read_text())
    assert base["schema"] == SCHEMA_VERSION
    assert base["counts"]["findings"] == 0
    assert all(f["suppressed"] for f in base["findings"])


def test_baseline_diff_lets_known_findings_through(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "core" / "merge.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nx = np.unique(y)\n")
    snap = tmp_path / "base.json"
    assert lint_main([str(tmp_path), "--write-baseline", str(snap)]) == 0
    capsys.readouterr()
    # the known finding is covered by the baseline -> exit 0
    assert lint_main([str(tmp_path), "--baseline", str(snap)]) == 0
    # a NEW finding is not -> exit 1
    bad.write_text("import numpy as np\nx = np.unique(y)\n"
                   "o = np.lexsort((hi, lo))\n")
    assert lint_main([str(tmp_path), "--baseline", str(snap)]) == 1


def test_ci_gate_fails_on_injected_bad_snippet(tmp_path, capsys):
    """The CI failure mode, demonstrated on the REAL entry points."""
    bad = tmp_path / "src" / "repro" / "core" / "engine.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\n"
                   "def apply(batch):\n"
                   "    return np.lexsort((batch.hi, batch.lo))\n")
    rc = lint_main([str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "hidden-sort" in out
    # module entry point, as CI invokes it
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(tmp_path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1, proc.stderr
    assert "hidden-sort" in proc.stdout


def test_datagit_lint_shares_the_runner(tmp_path, capsys):
    from repro.vcs_cli import main as cli_main
    bad = tmp_path / "app.py"
    bad.write_text("tx.insert('t', b, sigs=s)\n")
    assert cli_main(["lint", str(tmp_path), "--format", "json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["schema"] == SCHEMA_VERSION
    assert doc["counts"]["findings"] == 1
    assert doc["findings"][0]["rule"] == "sorted-claims"
    # and the repo tree itself exits 0 through the CLI door
    assert cli_main(["lint"]) == 0


def test_lint_statement_surface():
    from repro.core import Repo
    from repro.core.statements import execute
    res = execute(Repo(), "LINT")
    assert res.kind == "lint"
    assert "0 finding(s)" in res.message
