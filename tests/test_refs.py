"""Ref grammar + resolver (ISSUE 5): round-trip property, one-resolver
semantics, unified typed errors, and the no-legacy-resolver source gate."""
import re
from pathlib import Path

import numpy as np
import pytest

from conftest import VCS_SCHEMA as SCH
from conftest import kv_batch as _batch
from repro.core import (AmbiguousRefError, Repo, RefSyntaxError,
                        UnknownRefError, parse_ref)
from repro.core.refs import (AtRef, BareRef, BranchRef, HeadRef, PrRef,
                             RelRef, SnapRef, TsRef, format_ref, resolve)


def mk_repo():
    r = Repo()
    r.create_table("t", SCH)
    r.create_table("u", SCH)
    r.insert("t", _batch([1, 2, 3]))
    r.insert("u", _batch([10]))
    r.tag("night", "t")
    r.branch("dev", ["t"])
    return r


# ------------------------------------------------------ round-trip property

_NAMES = ["t", "dev", "night", "a_b", "x.y", "ns/tab", "T-2", "z9"]


def _every_ref_form(names, ints):
    """One instance of every AST form per (name, int) pair."""
    for name, n in zip(names, ints):
        yield HeadRef()
        yield BranchRef(name)
        yield SnapRef(name)
        yield TsRef(n)
        yield AtRef(name, n)
        yield RelRef(name, n)
        yield PrRef(n, ("base", "head", "merged")[n % 3])
        yield BareRef(name)


def test_parse_format_parse_roundtrips_every_form():
    """parse(format(r)) == r for every AST form (the format is canonical),
    and format is a fixed point: format(parse(format(r))) == format(r)."""
    rng = np.random.default_rng(5)
    names = list(_NAMES) * 4
    ints = rng.integers(0, 10_000, size=len(names)).tolist()
    seen = 0
    for ref in _every_ref_form(names, ints):
        text = format_ref(ref)
        again = parse_ref(text)
        assert again == ref, (text, ref, again)
        assert format_ref(again) == text
        seen += 1
    assert seen >= 8 * len(names)


def test_parse_text_forms():
    assert parse_ref("HEAD") == HeadRef()
    assert parse_ref("branch:dev") == BranchRef("dev")
    assert parse_ref("snap:nightly") == SnapRef("nightly")
    assert parse_ref("ts:12345") == TsRef(12345)
    assert parse_ref("orders@{42}") == AtRef("orders", 42)
    assert parse_ref("orders~2") == RelRef("orders", 2)
    assert parse_ref("pr:3:base") == PrRef(3, "base")
    assert parse_ref("pr:3") == PrRef(3, "head")     # role defaults to head
    assert parse_ref("main") == BareRef("main")
    for bad in ("", "ts:abc", "pr:x", "pr:3:sideways", "a b", "@{5}",
                "orders~", "orders@{}", 42):
        with pytest.raises(RefSyntaxError):
            parse_ref(bad)


def test_roundtrip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    name = st.from_regex(r"[A-Za-z_][A-Za-z0-9_.\-/]{0,12}", fullmatch=True)

    @hyp.given(name=name, n=st.integers(0, 10**9),
               form=st.integers(0, 7))
    @hyp.settings(max_examples=200, deadline=None)
    def prop(name, n, form):
        ref = list(_every_ref_form([name], [n]))[form]
        assert parse_ref(format_ref(ref)) == ref

    prop()


# ------------------------------------------------------- resolver semantics

def test_resolver_every_form_resolves():
    r = mk_repo()
    e = r.engine
    # HEAD with table context
    assert r.resolve("HEAD", table="t").snapshot.directory is \
        e.table("t").directory
    # branch ref maps logical -> physical
    rr = r.resolve("branch:dev", table="t")
    assert rr.table == "dev/t"
    # bare branch / bare snapshot / bare table
    assert r.resolve("dev", table="t").table == "dev/t"
    assert r.resolve("night").snapshot is e.snapshots["night"]
    assert r.resolve("u").table == "u"
    # ts: and @-form agree with the PITR index
    s1 = r.resolve("ts:1", table="t").snapshot
    s2 = r.resolve("t@{1}").snapshot
    assert s1.directory.data_oids == s2.directory.data_oids
    # relative history: ~0 is head, ~1 one version back
    assert r.resolve("t~0").snapshot.directory is e.table("t").directory
    r.insert("t", _batch([4]))
    assert r.resolve("t~1").snapshot.directory.data_oids != \
        r.resolve("t~0").snapshot.directory.data_oids


def test_resolver_pr_roles():
    r = mk_repo()
    r.update_by_keys("dev/t", _batch([2], vals=[9.0]))
    pr = r.open_pr("dev")
    base = r.resolve(f"pr:{pr.id}:base").snapshot
    assert base.directory.data_oids == pr.base_pins["t"].directory.data_oids
    head = r.resolve(f"pr:{pr.id}:head")
    assert head.table == "dev/t"
    with pytest.raises(UnknownRefError):      # not published yet
        r.resolve(f"pr:{pr.id}:merged")
    r.publish(pr.id)
    merged = r.resolve(f"pr:{pr.id}:merged").snapshot
    assert merged.directory.data_oids == \
        pr.post_publish["t"].directory.data_oids


def test_bare_name_ambiguity_and_suggestions():
    r = mk_repo()
    # a branch and a snapshot sharing one name must not resolve silently
    r.tag("dev", "u")
    with pytest.raises(AmbiguousRefError) as exc:
        r.resolve("dev", table="t")
        pytest.fail("ambiguous bare name resolved")
    assert "branch:dev" in str(exc.value) and "snap:dev" in str(exc.value)
    # unknown names carry did-you-mean candidates
    with pytest.raises(UnknownRefError) as exc:
        r.resolve("nigth")
    assert "night" in exc.value.suggestions
    with pytest.raises(UnknownRefError) as exc:
        r.resolve("snap:nigth")
    assert "night" in exc.value.suggestions


def test_context_required_forms():
    r = mk_repo()
    for ref in ("HEAD", "ts:1", "branch:dev"):
        with pytest.raises(UnknownRefError):
            r.resolve(ref)                     # no table context
    with pytest.raises(UnknownRefError):
        r.resolve("branch:dev", table="u")     # branch has no such table
    with pytest.raises(UnknownRefError):
        r.resolve("t~99")                      # history shorter than that


# ------------------------------------------------- unified error behavior

def test_all_resolution_errors_are_unknownref():
    """The ISSUE 5 bugfix: engine.revert / workspace revert / clone_table /
    drop_snapshot / branch ops raise UnknownRefError (a KeyError) carrying
    the ref text — never a mixed bare KeyError/ValueError."""
    r = mk_repo()
    e = r.engine
    cases = [
        lambda: e.revert("missing", "night", "night"),
        lambda: e.revert("t", "snap:missing", "night"),
        lambda: e.clone_table("c1", "missing_snap"),
        lambda: e.drop_snapshot("missing"),
        lambda: e.create_branch("b2", ["missing_table"]),
        lambda: e.create_branch("b2", ["t"], from_ref="missing_branch"),
        lambda: e.drop_branch("missing"),
        lambda: e.open_pr(None, "missing"),
        lambda: e.restore_table("t", "snap:missing"),
        lambda: e.restore_table("missing_table", "night"),
        lambda: e.drop_table("missing_table"),
        lambda: e.create_snapshot("s2", "missing_table"),
        lambda: r.pr(99),
        lambda: r.log("missing"),
    ]
    for fn in cases:
        with pytest.raises(UnknownRefError) as exc:
            fn()
        assert isinstance(exc.value, KeyError)
        assert exc.value.ref                      # carries the ref text


def test_legacy_shims_still_resolve():
    """resolve_snapshot/snapshot_at survive as deprecation shims over the
    one resolver (old callers keep working, new errors are typed)."""
    r = mk_repo()
    e = r.engine
    assert e.resolve_snapshot("night") is e.snapshots["night"]
    snap = e.resolve_snapshot(e.snapshots["night"])
    assert snap is e.snapshots["night"]
    assert e.snapshot_at("t", 1).directory.data_oids == \
        r.resolve("t@{1}").snapshot.directory.data_oids
    with pytest.raises(KeyError):
        e.resolve_snapshot("missing")


def test_no_nonshim_code_calls_legacy_resolvers():
    """CI gate (also enforced here): no non-shim code under src/, examples/
    or benchmarks/ calls .resolve_snapshot( / .snapshot_at( — everything
    routes through core.refs. The shim *definitions* in engine.py are the
    single allowed site."""
    root = Path(__file__).resolve().parent.parent
    pat = re.compile(r"\.(resolve_snapshot|snapshot_at)\(")
    offenders = []
    for sub in ("src", "examples", "benchmarks"):
        for p in sorted((root / sub).rglob("*.py")):
            for i, line in enumerate(p.read_text().splitlines(), 1):
                if pat.search(line) and not line.lstrip().startswith("#"):
                    offenders.append(f"{p.relative_to(root)}:{i}: "
                                     f"{line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_creation_names_must_be_speakable_in_the_grammar():
    """A snapshot/branch the ref grammar cannot parse would be unreachable
    through every surface — creation rejects such names up front."""
    r = mk_repo()
    for bad in ("2024-nightly", "a b", "x@y", "x~1", ""):
        with pytest.raises(ValueError):
            r.engine.create_snapshot(bad, "t")
        with pytest.raises(ValueError):
            r.engine.create_branch(bad, ["t"])
    # every accepted name round-trips through resolution
    r.engine.create_snapshot("v1.2-rc/x", "t")
    assert r.resolve("snap:v1.2-rc/x").table == "t"
    # replay is exempt: a pre-grammar WAL with a now-illegal name (old
    # code validated nothing) must still load
    from repro.core import Engine, WAL
    r.engine.wal.append("snapshot", name="2024-nightly", table="t")
    e2 = Engine.replay(WAL.deserialize(r.engine.wal.serialize()))
    assert "2024-nightly" in e2.snapshots


def test_legacy_shim_keeps_snapshot_namespace_priority():
    """engine.resolve_snapshot was a snapshots-only dict lookup — a bare
    name that IS a snapshot must keep resolving even when a table/branch
    shares it (new callers use Repo.resolve, where the same bare name is
    a typed ambiguity)."""
    r = mk_repo()
    r.tag("t", "u")                  # snapshot named like table "t"
    assert r.engine.resolve_snapshot("t") is r.engine.snapshots["t"]
    with pytest.raises(AmbiguousRefError):
        r.resolve("t")


def test_trunk_synthesis_excludes_index_aux_tables():
    """Default-tables branching must not clone internal index aux tables
    as first-class user tables (the clone would never be maintained)."""
    from repro.core.indices import create_index
    r = mk_repo()
    spec = create_index(r.engine, "t", "byv", ["v"])
    br = r.branch("withidx")
    assert spec.aux_table in r.engine.tables
    assert spec.aux_table not in br.tables
    assert "t" in br.tables and "u" in br.tables


def test_repo_branch_default_tables_with_main_collision():
    """repo.branch defaults its table set from the trunk even when a
    table named 'main' exists (branch-only position skips bare-name
    ambiguity)."""
    r = mk_repo()
    r.create_table("main", SCH)
    br = r.branch("dev2")
    assert "u" in br.tables and "main" in br.tables


def test_as_branch_ambiguity_lists_every_reading():
    from repro.core.refs import as_branch
    r = mk_repo()
    r.tag("x", "t")
    r.create_table("x", SCH)
    r.engine.create_branch("x", ["t"])
    with pytest.raises(AmbiguousRefError) as exc:
        as_branch(r.engine, "x")
    assert set(exc.value.suggestions) == {"branch:x", "snap:x", "table 'x'"}


# --------------------------------------------------------- log determinism

def test_repo_log_and_listing_determinism():
    r = mk_repo()
    r.update_by_keys("dev/t", _batch([2], vals=[5.0]))
    pr = r.open_pr("dev")
    r.publish(pr.id)
    log = r.log("t")
    # newest first, kinds tagged, create at the tail
    assert [rec.kind for rec in log] == ["publish", "commit", "create"]
    assert log[0].inserted == 1 and log[0].deleted == 1
    assert log[0].ts > log[1].ts
    assert r.log("t", limit=2) == log[:2]
    # branch-physical tables log too (clone entry from branch creation)
    assert [rec.kind for rec in r.log("dev/t")][-1] == "clone"
    # deterministic listings with created-at ts
    assert r.branches() == [("dev", 2, ("t",))]
    assert r.snapshots() == [("night", "t", 2)]
    # a WAL-replayed engine carries the identical commit log
    from repro.core import WAL, Engine
    e2 = Engine.replay(WAL.deserialize(r.engine.wal.serialize()))
    assert e2.commit_log == r.engine.commit_log
