"""ISSUE 2 regression + property tests.

Covers the sort-free Δ pipeline (k-way merge of presorted runs vs. the
``np.lexsort`` oracle, including crafted lo64-collision signatures) and the
four bugfix satellites: WAL replay of ``clone(with_indices=...)``,
snapshot-consistent index cloning, ``drop_table`` index cleanup, and
conflict-key reporting in non-FAIL merge modes.
"""
import numpy as np
import pytest

try:  # property tests run under hypothesis when present; the deterministic
    # seeded oracle tests below run everywhere (the CI container lacks it)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

from repro.core import (Column, ConflictMode, CType, Engine, Schema,
                        three_way_merge)
from repro.core.delta import SignedStream
from repro.core.indices import create_index, lookup_eq
from repro.core.sigs import key_sigs_for_lookup
from repro.kernels import ops

SCH = Schema((Column("id", CType.I64), Column("cat", CType.I32),
              Column("val", CType.F64)), primary_key=("id",))
SCH_NOPK = Schema(SCH.columns, primary_key=None)


# ===================================================== k-way merge property

def _oracle(lo, hi):
    return np.lexsort((hi, lo))


# runs of sorted (lo, hi) pairs; small value domains force duplicates and
# cross-run ties so stability is actually exercised
if HAVE_HYPOTHESIS:
    _pair = st.tuples(st.integers(0, 7), st.integers(0, 3))
    _run = st.lists(_pair, max_size=12).map(sorted)
    _runs = st.lists(_run, min_size=1, max_size=6)
else:  # pragma: no cover - @given is a skip marker; value never sampled
    _runs = None


def _random_runs(rng, k, n, lo_dom, hi_dom):
    """Deterministic stand-in for the hypothesis strategy."""
    out = []
    for _ in range(k):
        m = int(rng.integers(0, n + 1))
        lo = rng.integers(0, lo_dom, m).astype(np.uint64)
        hi = rng.integers(0, hi_dom, m).astype(np.uint64)
        o = np.lexsort((hi, lo))
        out.append(list(zip(lo[o].tolist(), hi[o].tolist())))
    return out


def _flatten(runs):
    starts, lo, hi = [], [], []
    for r in runs:
        starts.append(len(lo))
        lo.extend(p[0] for p in r)
        hi.extend(p[1] for p in r)
    return (np.asarray(lo, np.uint64), np.asarray(hi, np.uint64),
            np.asarray(starts, np.int64))


@settings(max_examples=200, deadline=None)
@given(_runs)
def test_merge128_runs_matches_lexsort_oracle(runs):
    lo, hi, starts = _flatten(runs)
    order = ops.merge128_runs(lo, hi, starts)
    want = _oracle(lo, hi)
    np.testing.assert_array_equal(order, want)


@settings(max_examples=200, deadline=None)
@given(_runs)
def test_ranksum_merge_matches_lexsort_oracle(runs):
    # the Pallas-backend searchsorted rank-sum path, exercised directly
    # (merge128_runs dispatches it only on the kernel backend)
    lo, hi, starts = _flatten(runs)
    if lo.shape[0] == 0:
        return
    order = ops._merge128_ranksum(lo, hi, starts)
    np.testing.assert_array_equal(order, _oracle(lo, hi))


@pytest.mark.parametrize("seed", range(8))
def test_kway_merge_matches_oracle_seeded(seed):
    """Deterministic k-way-merge-vs-lexsort oracle sweep (runs without
    hypothesis): varied run counts/sizes, tie-heavy domains, both the
    dispatching entry point and the rank-sum kernel path, plus stream
    concat + merge_by_key round-trip."""
    rng = np.random.default_rng([seed] + list(b"KWAY"))
    runs = _random_runs(rng, k=int(rng.integers(1, 9)),
                        n=int(rng.integers(1, 64)),
                        lo_dom=int(rng.integers(2, 32)),
                        hi_dom=int(rng.integers(2, 8)))
    lo, hi, starts = _flatten(runs)
    want = _oracle(lo, hi)
    np.testing.assert_array_equal(ops.merge128_runs(lo, hi, starts), want)
    if lo.shape[0]:
        np.testing.assert_array_equal(
            ops._merge128_ranksum(lo, hi, starts), want)
    parts = []
    for r in runs:
        rlo = np.asarray([p[0] for p in r], np.uint64)
        rhi = np.asarray([p[1] for p in r], np.uint64)
        n = rlo.shape[0]
        parts.append(SignedStream(
            np.ones((n,), np.int32), rlo, rhi, rlo, rhi,
            np.arange(n, dtype=np.uint64),
            runs=np.zeros((1,), np.int64) if n else np.zeros((0,), np.int64),
            key_is_row=True))
    cat = SignedStream.concat(parts)
    merged = cat.merge_by_key()
    np.testing.assert_array_equal(merged.key_lo, cat.key_lo[want])
    np.testing.assert_array_equal(merged.rowid, cat.rowid[want])


def test_kway_merge_lo64_collisions():
    """Crafted signatures sharing the lo word must rank by the hi word —
    both in the run-merge and in the searchsorted refinement."""
    rng = np.random.default_rng(7)
    runs = []
    for _ in range(5):
        n = 200
        lo = rng.integers(0, 4, n).astype(np.uint64)  # massive lo collisions
        hi = rng.integers(0, 1 << 63, n).astype(np.uint64)
        o = np.lexsort((hi, lo))
        runs.append([(int(lo[i]), int(hi[i])) for i in o])
    lo, hi, starts = _flatten(runs)
    np.testing.assert_array_equal(ops.merge128_runs(lo, hi, starts),
                                  _oracle(lo, hi))
    np.testing.assert_array_equal(ops._merge128_ranksum(lo, hi, starts),
                                  _oracle(lo, hi))
    # searchsorted128 exact refinement under equal-lo runs
    order = _oracle(lo, hi)
    t_lo, t_hi = lo[order], hi[order]
    q = rng.permutation(lo.shape[0])[:64]
    pos = ops.searchsorted128(t_lo, t_hi, lo[q], hi[q])
    want = np.array([np.searchsorted(
        t_lo.astype(object) * (1 << 64) + t_hi.astype(object), int(l) * (1 << 64) + int(h))
        for l, h in zip(lo[q], hi[q])], np.int64)
    np.testing.assert_array_equal(pos, want)


def test_sort128_radix_fallback_large_unsorted():
    """The unsorted-fallback radix pre-pass must stay a stable 128-bit sort
    above the size cutoff that enables it."""
    rng = np.random.default_rng(11)
    n = (1 << 15) + 1000
    lo = rng.integers(0, 1 << 20, n).astype(np.uint64)  # many duplicates
    hi = rng.integers(0, 1 << 20, n).astype(np.uint64)
    np.testing.assert_array_equal(ops._sort128(lo, hi), _oracle(lo, hi))


@settings(max_examples=100, deadline=None)
@given(_runs)
def test_signed_stream_concat_merge_by_key(runs):
    """SignedStream.concat preserves run structure; merge_by_key yields the
    oracle order with emission-order ties."""
    parts = []
    for r in runs:
        lo = np.asarray([p[0] for p in r], np.uint64)
        hi = np.asarray([p[1] for p in r], np.uint64)
        n = lo.shape[0]
        parts.append(SignedStream(
            np.ones((n,), np.int32), lo, hi, lo, hi,
            np.arange(n, dtype=np.uint64),
            runs=np.zeros((1,), np.int64) if n else np.zeros((0,), np.int64),
            key_is_row=True))
    cat = SignedStream.concat(parts)
    merged = cat.merge_by_key()
    assert merged.sorted_by_key
    want = _oracle(cat.key_lo, cat.key_hi)
    np.testing.assert_array_equal(merged.key_lo, cat.key_lo[want])
    np.testing.assert_array_equal(merged.key_hi, cat.key_hi[want])
    np.testing.assert_array_equal(merged.rowid, cat.rowid[want])


# =============================================== bugfix satellite coverage

def _setup_indexed(n=50):
    e = Engine()
    e.create_table("T", SCH)
    e.insert("T", {"id": np.arange(n), "cat": np.arange(n) % 5,
                   "val": np.arange(n) * 1.0})
    create_index(e, "T", "by_cat", ["cat"])
    return e


def test_replay_preserves_clone_with_indices():
    """WAL replay must honour the recorded ``with_indices`` flag."""
    e = _setup_indexed()
    snap = e.create_snapshot("s", "T")
    e.clone_table("C", snap, with_indices=True)
    e2 = Engine.replay(e.wal)
    assert [s.name for s in e2.indices.get("C", [])] == ["by_cat"]
    hits = lookup_eq(e2, "C", "by_cat", {"cat": np.int32(3)})["id"].tolist()
    assert sorted(hits) == sorted(
        lookup_eq(e, "C", "by_cat", {"cat": np.int32(3)})["id"].tolist())


def test_clone_with_indices_snapshot_consistent():
    """Cloning an older snapshot must clone the aux index at that snapshot's
    horizon (or rebuild), never at the aux table's current head."""
    e = _setup_indexed()
    snap = e.create_snapshot("old", "T")
    # advance the base table (and thus the aux index) past the snapshot
    e.update_by_keys("T", {"id": np.arange(10), "cat": np.full(10, 9),
                           "val": np.zeros(10)})
    e.clone_table("C", "old", with_indices=True)
    # at "old", no row had cat==9 and ids 0..9 still had cat == id % 5
    assert lookup_eq(e, "C", "by_cat", {"cat": np.int32(9)})["id"].shape[0] == 0
    hits = sorted(lookup_eq(e, "C", "by_cat", {"cat": np.int32(3)})["id"]
                  .tolist())
    assert hits == [i for i in range(50) if i % 5 == 3]


def test_clone_with_indices_rebuilds_index_younger_than_snapshot():
    """An index created after the snapshot can't be cloned at the horizon —
    it must be rebuilt from the cloned data, not cloned at head."""
    e = Engine()
    e.create_table("T", SCH)
    e.insert("T", {"id": np.arange(20), "cat": np.arange(20) % 5,
                   "val": np.zeros(20)})
    snap = e.create_snapshot("s", "T")
    e.update_by_keys("T", {"id": [0], "cat": [9], "val": [0.0]})
    create_index(e, "T", "by_cat", ["cat"])  # younger than the snapshot
    e.clone_table("C", "s", with_indices=True)
    assert lookup_eq(e, "C", "by_cat", {"cat": np.int32(9)})["id"].shape[0] == 0
    assert sorted(lookup_eq(e, "C", "by_cat", {"cat": np.int32(0)})["id"]
                  .tolist()) == [0, 5, 10, 15]


def test_drop_table_drops_indices_and_aux_tables():
    e = _setup_indexed()
    aux = e.indices["T"][0].aux_table
    assert aux in e.tables
    e.drop_table("T")
    assert "T" not in e.indices
    assert aux not in e.tables
    assert "T" not in e.tables


def test_replay_roundtrip_clone_indices_and_drop_table():
    """Replay round-trip over clone-with-indices + drop_table: the replayed
    engine matches, with no dangling index state."""
    e = _setup_indexed()
    e.create_snapshot("s", "T")
    e.clone_table("C", "s", with_indices=True)
    aux_t = e.indices["T"][0].aux_table
    e.drop_table("T")
    e2 = Engine.replay(e.wal)
    assert set(e2.tables) == set(e.tables)
    assert "T" not in e2.indices and aux_t not in e2.tables
    assert [s.name for s in e2.indices.get("C", [])] == ["by_cat"]
    hits = lookup_eq(e2, "C", "by_cat", {"cat": np.int32(2)})["id"].tolist()
    assert sorted(hits) == [i for i in range(50) if i % 5 == 2]


# -------------------------------------- conflict keys in non-FAIL modes

def _conflicting(pk: bool):
    e = Engine()
    sch = SCH if pk else SCH_NOPK
    e.create_table("T", sch)
    e.insert("T", {"id": np.arange(10), "cat": np.zeros(10, np.int64),
                   "val": np.zeros(10)})
    sn = e.create_snapshot("base", "T")
    e.clone_table("C", "base")
    if pk:
        e.update_by_keys("T", {"id": [3], "cat": [1], "val": [30.0]})
        e.update_by_keys("C", {"id": [3], "cat": [2], "val": [300.0]})
    else:
        # §3 rule 3: both branches change the count of the SAME value group
        # (target inserts a copy, source deletes its copy) → true conflict
        e.insert("T", {"id": [3], "cat": [0], "val": [0.0]})  # dup of base row
        batch, rowids = e.table("C").scan()
        victim = rowids[np.flatnonzero(batch["id"] == 3)[:1]]
        tx = e.begin()
        tx.delete_rowids("C", victim)
        tx.commit()
    return e, sn


@pytest.mark.parametrize("mode", [ConflictMode.SKIP, ConflictMode.ACCEPT])
def test_conflict_keys_reported_in_non_fail_modes_pk(mode):
    e, sn = _conflicting(pk=True)
    rep = three_way_merge(e, "T", e.current_snapshot("C"), base=sn, mode=mode)
    assert rep.true_conflicts == 1
    assert rep.conflict_key_lo.shape == (1,) == rep.conflict_key_hi.shape
    lo, hi = key_sigs_for_lookup(SCH, {"id": np.asarray([3], np.int64)})
    assert rep.conflict_key_lo[0] == lo[0] and rep.conflict_key_hi[0] == hi[0]


@pytest.mark.parametrize("mode", [ConflictMode.SKIP, ConflictMode.ACCEPT])
def test_conflict_keys_reported_in_non_fail_modes_nopk(mode):
    e, sn = _conflicting(pk=False)
    rep = three_way_merge(e, "T", e.current_snapshot("C"), base=sn, mode=mode)
    assert rep.true_conflicts >= 1
    assert rep.conflict_key_lo.shape[0] == rep.true_conflicts
    assert rep.conflict_key_hi.shape[0] == rep.true_conflicts


def test_conflict_keys_match_fail_mode_report():
    """Non-FAIL reports must name the same keys FAIL mode raises with."""
    from repro.core import MergeConflictError
    e, sn = _conflicting(pk=True)
    with pytest.raises(MergeConflictError) as ei:
        three_way_merge(e, "T", e.current_snapshot("C"), base=sn,
                        mode=ConflictMode.FAIL)
    fail_rep = ei.value.report
    rep = three_way_merge(e, "T", e.current_snapshot("C"), base=sn,
                          mode=ConflictMode.SKIP)
    np.testing.assert_array_equal(rep.conflict_key_lo,
                                  fail_rep.conflict_key_lo)
    np.testing.assert_array_equal(rep.conflict_key_hi,
                                  fail_rep.conflict_key_hi)
