"""Runtime sealed-write sanitizer (ISSUE 7).

With ``REPRO_SANITIZE=1`` (or ``objects.set_sanitize(True)``) every numpy
lane of a sealed object is frozen ``writeable=False`` at seal time, so an
in-place mutation of sealed state raises ``ValueError`` AT THE WRITE —
instead of silently corrupting zone maps or carried signatures and
surfacing commits later as an fsck mismatch. The static ``sealed-write``
lint pass covers the same invariant at review time; this suite covers the
runtime net, including a full branch → merge → publish → revert workflow
run entirely with the sanitizer armed.
"""
import numpy as np
import pytest

from conftest import VCS_SCHEMA, content_digest, kv_batch
from repro.core import Engine, Repo
from repro.core import objects as objects_mod
from repro.core.faults import corrupt_object_bit
from repro.core.fsck import fsck
from repro.core.objects import set_sanitize


def sealed_objects(engine, table):
    d = engine.table(table).directory
    return [engine.store.get(oid) for oid in d.data_oids]


def armed_engine():
    set_sanitize(True)          # restored by the autouse conftest fixture
    eng = Engine()
    eng.create_table("t", VCS_SCHEMA)
    eng.insert("t", kv_batch(range(100)))
    return eng


def test_sealed_lane_write_raises_when_armed():
    eng = armed_engine()
    (obj,) = sealed_objects(eng, "t")
    with pytest.raises(ValueError):
        obj.cols["v"][0] = 99.0
    with pytest.raises(ValueError):
        obj.key_lo[0] = 0
    with pytest.raises(ValueError):
        obj.commit_ts[:] = 0
    # aliasing does not launder the freeze: views inherit read-only
    view = obj.cols["v"].view()
    with pytest.raises(ValueError):
        view[0] = 1.0


def test_set_sanitize_returns_previous_state():
    prev = set_sanitize(True)
    assert set_sanitize(prev) is True
    assert objects_mod.SANITIZE == prev


def test_disarmed_lanes_stay_writeable():
    set_sanitize(False)
    eng = Engine()
    eng.create_table("t", VCS_SCHEMA)
    eng.insert("t", kv_batch(range(10)))
    (obj,) = sealed_objects(eng, "t")
    obj.cols["v"][0] = 42.0     # legal (if ill-advised) when disarmed
    assert obj.cols["v"][0] == 42.0


def test_tombstone_lanes_frozen_too():
    eng = armed_engine()
    eng.delete_by_keys("t", {"k": np.arange(5, dtype=np.int64)})
    d = eng.table("t").directory
    (tomb,) = [eng.store.get(oid) for oid in d.tomb_oids]
    with pytest.raises(ValueError):
        tomb.target[0] = 0


def test_corruption_injector_still_works_armed():
    """faults.corrupt_object_bit is copy-on-write: it must keep working
    under the sanitizer (it swaps a rotted copy in, never mutates the
    frozen lane) so the fsck suites can run with REPRO_SANITIZE=1."""
    eng = armed_engine()
    (obj,) = sealed_objects(eng, "t")
    before = obj.cols["v"].copy()
    corrupt_object_bit(obj, column="v")
    assert not np.array_equal(before, obj.cols["v"])
    report = fsck(eng, check_replay=False)
    assert not report.ok


def test_e2e_workflow_green_with_sanitizer_armed():
    """Seeded branch → mutate → PR → publish → revert, sanitizer on the
    whole way: proves no hot path (insert, seal, carry-scan, merge apply,
    Δ revert, GC, fsck) mutates sealed state in place."""
    set_sanitize(True)
    repo = Repo()
    repo.create_table("orders", VCS_SCHEMA)
    repo.insert("orders", kv_batch(range(1000)))
    trunk0 = content_digest(repo.engine, "orders")

    repo.branch("dev", tables=["orders"])
    keys = np.arange(100, 200, dtype=np.int64)
    repo.update_by_keys("dev/orders", kv_batch(keys, vals=keys * 3.0))
    repo.delete_by_keys("dev/orders", {"k": np.arange(7, dtype=np.int64)})
    dev_digest = content_digest(repo.engine, "dev/orders")
    assert dev_digest != trunk0

    pr = repo.open_pr("dev")
    repo.publish(pr.id)
    assert content_digest(repo.engine, "orders") == dev_digest

    rv = repo.revert_pr(pr.id)
    assert rv is not None
    assert content_digest(repo.engine, "orders") == trunk0

    repo.gc()
    report = repo.fsck()
    assert report.ok, report
