"""SNAPSHOT DIFF / MERGE semantics: the paper's §3 scenarios, explicitly."""
import numpy as np
import pytest

from repro.core import (Column, CType, ConflictMode, Engine,
                        MergeConflictError, Schema, snapshot_diff, sql_diff,
                        three_way_merge, two_way_merge)
from repro.core.compaction import compact_objects

SCH = Schema((Column("a", CType.I64), Column("b", CType.F64),
              Column("c", CType.LOB)), primary_key=("a",))
SCH_NOPK = Schema(SCH.columns, primary_key=None)


def _b(keys, vals=None, docs=None):
    keys = np.asarray(keys, np.int64)
    return {"a": keys,
            "b": np.asarray(vals if vals is not None else keys * 1.0),
            "c": docs if docs is not None else [b"c%d" % k for k in keys]}


def _table_rows(e, name):
    batch, _ = e.table(name).scan()
    order = np.argsort(batch["a"], kind="stable")
    return (batch["a"][order].tolist(), batch["b"][order].tolist(),
            [batch["c"][i] for i in order])


def _setup(pk=True, n=20):
    e = Engine()
    e.create_table("T", SCH if pk else SCH_NOPK)
    e.insert("T", _b(np.arange(n)))
    sn1 = e.create_snapshot("sn1", "T")
    e.clone_table("TClone", "sn1")
    return e, sn1


# ----------------------------------------------------------------- diff

def test_diff_empty_between_identical():
    e, sn1 = _setup()
    d = snapshot_diff(e.store, sn1, e.current_snapshot("TClone"))
    assert d.is_empty()


def test_diff_matches_sql_baseline_and_scans_less():
    e, sn1 = _setup(n=1000)
    e.update_by_keys("T", _b([5, 6], vals=[50.0, 60.0]))
    e.insert("TClone", _b([2000]))
    e.delete_by_keys("TClone", {"a": np.asarray([10])})
    a = e.current_snapshot("T")
    b = e.current_snapshot("TClone")
    d1 = snapshot_diff(e.store, a, b)
    d2 = sql_diff(e.store, a, b)
    def norm(d):
        o = np.lexsort((d.row_hi, d.row_lo))
        return d.row_lo[o].tolist(), d.diff_cnt[o].tolist()
    assert norm(d1) == norm(d2)
    assert d1.stats.rows_scanned < d2.stats.rows_scanned / 10
    # 6 groups: per updated key (5,6) one −1 (T's new value) and one +1
    # (old value still in TClone); +1 for the clone insert; −1 for the
    # clone-deleted row still visible in T
    assert sorted(d1.diff_cnt.tolist()) == [-1, -1, -1, 1, 1, 1]


def test_diff_payload_gather():
    e, sn1 = _setup()
    e.update_by_keys("TClone", _b([3], vals=[99.0], docs=[b"new"]))
    d = snapshot_diff(e.store, e.current_snapshot("T"),
                      e.current_snapshot("TClone"))
    assert d.n_groups == 2
    payload = d.payload(e.store)
    got = sorted(zip(payload["a"].tolist(), payload["b"].tolist()))
    assert got == [(3, 3.0), (3, 99.0)]


def test_diff_requires_compatible_schema():
    e = Engine()
    e.create_table("A", SCH)
    e.create_table("B", SCH_NOPK)
    with pytest.raises(ValueError):
        snapshot_diff(e.store, e.current_snapshot("A"),
                      e.current_snapshot("B"))


# ------------------------------------------- the six PK scenarios (§3)

def test_scenario_1_insert_only_in_target():
    e, sn1 = _setup()
    e.insert("T", _b([100]))                      # only T inserted
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    assert 100 in _table_rows(e, "T")[0]          # kept


def test_scenario_2_insert_only_in_source():
    e, sn1 = _setup()
    e.insert("TClone", _b([100]))
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0 and rep.inserted == 1
    assert 100 in _table_rows(e, "T")[0]


def test_scenario_3_both_insert_same_key():
    e, sn1 = _setup()
    e.insert("T", _b([100], vals=[1.0]))
    e.insert("TClone", _b([100], vals=[2.0]))
    with pytest.raises(MergeConflictError):
        three_way_merge(e, "T", e.current_snapshot("TClone"),
                        base=sn1, mode=ConflictMode.FAIL)
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.SKIP)
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(100)] == 1.0           # SKIP keeps target
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.ACCEPT)
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(100)] == 2.0           # ACCEPT takes source
    # both insert IDENTICAL values -> cancels, no conflict
    e2, s1 = _setup()
    e2.insert("T", _b([100], vals=[5.0], docs=[b"x"]))
    e2.insert("TClone", _b([100], vals=[5.0], docs=[b"x"]))
    rep = three_way_merge(e2, "T", e2.current_snapshot("TClone"),
                          base=s1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0


def test_scenario_4_source_modified_unchanged_target_row():
    e, sn1 = _setup()
    e.update_by_keys("TClone", _b([3], vals=[33.0]))   # update
    e.delete_by_keys("TClone", {"a": np.asarray([4])})  # delete
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(3)] == 33.0            # source's update applied
    assert 4 not in keys                          # source's delete applied


def test_scenario_5_target_modified_source_untouched():
    e, sn1 = _setup()
    e.update_by_keys("T", _b([3], vals=[33.0]))
    e.delete_by_keys("T", {"a": np.asarray([4])})
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(3)] == 33.0            # target's change stands
    assert 4 not in keys


def test_scenario_6_both_modified_same_row():
    e, sn1 = _setup()
    e.update_by_keys("T", _b([3], vals=[30.0]))
    e.update_by_keys("TClone", _b([3], vals=[300.0]))
    e.update_by_keys("T", _b([5], vals=[50.0]))
    e.delete_by_keys("TClone", {"a": np.asarray([5])})  # update vs delete
    with pytest.raises(MergeConflictError) as ei:
        three_way_merge(e, "T", e.current_snapshot("TClone"),
                        base=sn1, mode=ConflictMode.FAIL)
    assert ei.value.report.true_conflicts == 2
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.ACCEPT)
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(3)] == 300.0           # source version
    assert 5 not in keys                          # source's delete wins
    # identical updates on both sides cancel (no conflict)
    e2, s1 = _setup()
    e2.update_by_keys("T", _b([3], vals=[42.0]))
    e2.update_by_keys("TClone", _b([3], vals=[42.0]))
    rep = three_way_merge(e2, "T", e2.current_snapshot("TClone"),
                          base=s1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    # both delete same row: same change, cancels
    e3, s1 = _setup()
    e3.delete_by_keys("T", {"a": np.asarray([7])})
    e3.delete_by_keys("TClone", {"a": np.asarray([7])})
    rep = three_way_merge(e3, "T", e3.current_snapshot("TClone"),
                          base=s1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    assert 7 not in _table_rows(e3, "T")[0]


# ------------------------------------------------- move handling (§5.2)

def test_compaction_move_is_false_conflict():
    e, sn1 = _setup(n=50)
    # target: compaction moves rows (values unchanged, new positions)
    e.delete_by_keys("T", {"a": np.asarray([49])})  # make a dead row
    compact_objects(e, "T", list(e.table("T").directory.data_oids))
    # source: real update of a moved row
    e.update_by_keys("TClone", _b([10], vals=[1000.0]))
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    assert rep.moves_ignored > 0
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(10)] == 1000.0          # update NOT lost (paper)


# ------------------------------------------------------ NoPK cardinality

def test_nopk_rules():
    # rule 1: δT=0, δS≠0 -> apply source count
    e = Engine()
    e.create_table("T", SCH_NOPK)
    e.insert("T", _b([1, 1, 2], vals=[9.0, 9.0, 2.0],
                     docs=[b"x", b"x", b"y"]))
    sn1 = e.create_snapshot("sn1", "T")
    e.clone_table("TClone", "sn1")
    e.insert("TClone", _b([1], vals=[9.0], docs=[b"x"]))   # now 3 copies
    rep = three_way_merge(e, "T", e.current_snapshot("TClone"),
                          base=sn1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    keys = _table_rows(e, "T")[0]
    assert keys.count(1) == 3

    # rule 3: both changed the count -> true conflict; ACCEPT forces N3
    e2 = Engine()
    e2.create_table("T", SCH_NOPK)
    e2.insert("T", _b([1, 1], vals=[9.0, 9.0], docs=[b"x", b"x"]))
    s1 = e2.create_snapshot("s1", "T")
    e2.clone_table("C", "s1")
    e2.insert("T", _b([1], vals=[9.0], docs=[b"x"]))       # N2 = 3
    t = e2.table("C")
    _, rowids = t.scan()
    tx = e2.begin()
    tx.delete_rowids("C", rowids[:1])                      # N3 = 1
    tx.commit()
    with pytest.raises(MergeConflictError):
        three_way_merge(e2, "T", e2.current_snapshot("C"),
                        base=s1, mode=ConflictMode.FAIL)
    rep = three_way_merge(e2, "T", e2.current_snapshot("C"),
                          base=s1, mode=ConflictMode.ACCEPT)
    assert _table_rows(e2, "T")[0].count(1) == 1           # forced to N3
    # SKIP keeps N2
    rep = three_way_merge(e2, "T", e2.current_snapshot("C"),
                          base=s1, mode=ConflictMode.SKIP)
    assert _table_rows(e2, "T")[0].count(1) == 1  # already merged; no-op

    # same-row deletions on both branches cancel (§5.1)
    e3 = Engine()
    e3.create_table("T", SCH_NOPK)
    e3.insert("T", _b([5, 5], vals=[1.0, 1.0], docs=[b"z", b"z"]))
    s1 = e3.create_snapshot("s1", "T")
    e3.clone_table("C", "s1")
    _, r_t = e3.table("T").scan()
    tx = e3.begin(); tx.delete_rowids("T", r_t[:1]); tx.commit()
    _, r_c = e3.table("C").scan()
    # delete the SAME physical base row in the clone
    tx = e3.begin(); tx.delete_rowids("C", r_t[:1]); tx.commit()
    rep = three_way_merge(e3, "T", e3.current_snapshot("C"),
                          base=s1, mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    assert _table_rows(e3, "T")[0].count(5) == 1


# -------------------------------------------------------- two-way merge

def test_two_way_merge_uses_clone_lineage():
    e, sn1 = _setup()
    e.update_by_keys("TClone", _b([3], vals=[33.0]))
    e.update_by_keys("T", _b([4], vals=[44.0]))
    rep = two_way_merge(e, "T", e.current_snapshot("TClone"),
                        mode=ConflictMode.FAIL)
    assert rep.used_base          # implicit base found via lineage
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(3)] == 33.0 and vals[keys.index(4)] == 44.0


def test_two_way_merge_empty_base_skips_shared_objects():
    """§5.3: no lineage -> empty base; shared objects never scanned."""
    e = Engine()
    e.create_table("T", SCH)
    e.insert("T", _b(np.arange(1000)))
    s = e.create_snapshot("s", "T")
    e.clone_table("C", "s")
    e._base.clear()                     # simulate lost lineage
    e.update_by_keys("C", _b([5], vals=[55.0]))
    e.insert("C", _b([5000]))
    rep = two_way_merge(e, "T", e.current_snapshot("C"),
                        mode=ConflictMode.ACCEPT)
    assert not rep.used_base
    assert rep.stats.rows_scanned < 100   # shared 1000-row object skipped
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(5)] == 55.0 and 5000 in keys


def test_merge_after_merge_lineage_advances():
    e, sn1 = _setup()
    e.update_by_keys("TClone", _b([1], vals=[11.0]))
    s3 = e.create_snapshot("s3", "TClone")
    three_way_merge(e, "T", s3, mode=ConflictMode.FAIL)
    # second round: both sides advance from the NEW base (s3)
    e.update_by_keys("TClone", _b([2], vals=[22.0]))
    rep = two_way_merge(e, "T", e.current_snapshot("TClone"),
                        mode=ConflictMode.FAIL)
    assert rep.true_conflicts == 0
    keys, vals, _ = _table_rows(e, "T")
    assert vals[keys.index(2)] == 22.0


def test_merge_atomicity_on_fail():
    e, sn1 = _setup()
    e.update_by_keys("T", _b([3], vals=[30.0]))
    e.update_by_keys("TClone", _b([3], vals=[300.0]))
    e.insert("TClone", _b([100]))
    before = _table_rows(e, "T")
    with pytest.raises(MergeConflictError):
        three_way_merge(e, "T", e.current_snapshot("TClone"),
                        base=sn1, mode=ConflictMode.FAIL)
    assert _table_rows(e, "T") == before   # nothing applied (atomic)
