"""Shared helpers for the workflow / WAL round-trip suites.

One definition of the test schema, batch builder, and the content-digest
idiom (order-independent hash over full-row signatures) — so every suite
asserts the SAME notion of table equivalence.
"""
import hashlib

import numpy as np

from repro.core import Column, CType, Schema

VCS_SCHEMA = Schema((Column("k", CType.I64), Column("v", CType.F64),
                     Column("doc", CType.LOB)), primary_key=("k",))
VCS_SCHEMA_NOPK = Schema(VCS_SCHEMA.columns, primary_key=None)


def kv_batch(keys, vals=None, docs=None):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": np.asarray(vals if vals is not None else keys * 0.5,
                            np.float64),
            "doc": [b"d%d" % k for k in keys] if docs is None else docs}


def content_digest(engine, table):
    """Order-independent content digest over full-row signatures."""
    _, _, lo, hi = engine.table(table).scan(with_sigs=True)
    order = np.lexsort((hi, lo))
    h = hashlib.sha256(lo[order].tobytes())
    h.update(hi[order].tobytes())
    return h.hexdigest()
