"""Shared helpers for the workflow / WAL round-trip suites.

One definition of the test schema, batch builder, and the content-digest
idiom (order-independent hash over full-row signatures) — so every suite
asserts the SAME notion of table equivalence.

Also the global-state hygiene fixture (ISSUE 7): tests that flip
``sigs.DEBUG_VALIDATE_CARRY``, arm ``faults.inject``, or toggle the
sealed-write sanitizer are restored after EVERY test, so suite ordering
can never mask a carry/crash/sanitizer bug.
"""
import hashlib

import numpy as np
import pytest

from repro.core import Column, CType, Schema
from repro.core import faults as _faults
from repro.core import objects as _objects
from repro.core import sigs as _sigs
from repro.core import telemetry as _telemetry


@pytest.fixture(autouse=True)
def _restore_invariant_globals():
    """Snapshot/restore the three debug globals around each test.

    ``faults._ACTIVE`` is always DISARMED on exit (an armed plan leaking
    out of a test would crash unrelated suites at their first seam, far
    from the leak); the carry-validation and sanitizer flags restore to
    whatever the test found, since CI legitimately runs whole sessions
    with REPRO_SANITIZE=1."""
    carry = _sigs.DEBUG_VALIDATE_CARRY
    sanitize = _objects.SANITIZE
    yield
    _sigs.DEBUG_VALIDATE_CARRY = carry
    _objects.SANITIZE = sanitize
    _faults._ACTIVE = None
    _telemetry._ACTIVE = None

VCS_SCHEMA = Schema((Column("k", CType.I64), Column("v", CType.F64),
                     Column("doc", CType.LOB)), primary_key=("k",))
VCS_SCHEMA_NOPK = Schema(VCS_SCHEMA.columns, primary_key=None)


def kv_batch(keys, vals=None, docs=None):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": np.asarray(vals if vals is not None else keys * 0.5,
                            np.float64),
            "doc": [b"d%d" % k for k in keys] if docs is None else docs}


def content_digest(engine, table):
    """Order-independent content digest over full-row signatures."""
    _, _, lo, hi = engine.table(table).scan(with_sigs=True)
    order = np.lexsort((hi, lo))
    h = hashlib.sha256(lo[order].tobytes())
    h.update(hi[order].tobytes())
    return h.hexdigest()
