"""ISSUE 1 coverage: the cached/incremental visibility subsystem.

- cache reuse + incremental extension on commit (set_directory)
- correctness across invalidation-relevant ops (restore, compaction, GC)
- per-object target partitioning vs. a brute-force oracle
- directory_at bisect vs. the old linear-scan semantics
- vectorized probe paths (locate_keys run walk, locate_rowsig_multi)
"""
import numpy as np
import pytest

from repro.core import (Column, CType, ConflictMode, Engine, Schema,
                        snapshot_diff, sql_diff, three_way_merge)
from repro.core.compaction import compact_objects
from repro.core.directory import Directory
from repro.core.visibility import (VisibilityCache, VisibilityIndex,
                                   visibility_index)
from repro.kernels import ops

SCH = Schema((Column("k", CType.I64), Column("v", CType.I64)),
             primary_key=("k",))
SCH_NOPK = Schema(SCH.columns, primary_key=None)


def mk_engine(n=40, pk=True):
    e = Engine()
    e.create_table("t", SCH if pk else SCH_NOPK)
    e.insert("t", {"k": np.arange(n, dtype=np.int64),
                   "v": np.zeros(n, np.int64)})
    return e


def brute_visible(store, d, obj):
    """Oracle: per-row visibility via a python set of tombstone targets."""
    dead = set()
    for toid in d.tomb_oids:
        t = store.get(toid)
        for tgt, ts in zip(t.target.tolist(), t.commit_ts.tolist()):
            if ts <= d.ts:
                dead.add(tgt)
    from repro.core.objects import pack_rowid
    rids = pack_rowid(obj.oid, np.arange(obj.nrows, dtype=np.uint64))
    return np.array([(ts <= d.ts) and (int(r) not in dead)
                     for r, ts in zip(rids, obj.commit_ts.tolist())], bool)


# ------------------------------------------------------------------ cache

def test_repeated_ops_reuse_one_build():
    e = mk_engine()
    e.delete_by_keys("t", {"k": np.array([3, 5, 7])})
    c = e.store.vis_cache
    builds0 = c.builds
    for _ in range(4):
        e.table("t").scan()
        e.table("t").count()
    assert c.builds == builds0          # same directory version -> no rebuild
    assert c.hits >= 8


def test_commit_extends_instead_of_rebuilding():
    e = mk_engine()
    e.table("t").scan()                  # warm the current version
    c = e.store.vis_cache
    b0, x0 = c.builds, c.extends
    e.delete_by_keys("t", {"k": np.array([1, 2])})
    e.delete_by_keys("t", {"k": np.array([10, 11])})
    assert c.extends >= x0 + 2           # each commit merged incrementally
    assert c.builds == b0                # ... with zero full rebuilds
    # and the extended array equals a from-scratch build
    d = e.table("t").directory
    fresh = VisibilityIndex(e.store, d)  # direct ctor bypasses the cache
    cached = visibility_index(e.store, d)
    np.testing.assert_array_equal(fresh.targets, cached.targets)


def test_write_burst_defers_merge_until_read():
    """A write-only burst of commits records pending batches (O(batch) per
    commit); the first read pays one merge and matches a fresh build."""
    e = mk_engine(60)
    e.table("t").scan()                  # warm the current version
    c = e.store.vis_cache
    b0, x0 = c.builds, c.extends
    for i in range(10):                  # no reads in between
        e.delete_by_keys("t", {"k": np.array([i])})
    assert c.extends == x0 + 10
    assert c.builds == b0
    d = e.table("t").directory
    cached = visibility_index(e.store, d)
    fresh = VisibilityIndex(e.store, d)
    np.testing.assert_array_equal(fresh.targets, cached.targets)
    assert e.table("t").count() == 50


def test_warm_diff_reports_zero_visibility_builds():
    e = mk_engine()
    s1 = e.create_snapshot("s1", "t")
    e.clone_table("t2", s1)
    e.update_by_keys("t2", {"k": np.array([1, 2, 3]),
                            "v": np.array([9, 9, 9])})
    s2 = e.create_snapshot("s2", "t2")
    snapshot_diff(e.store, s1, s2)       # cold
    warm = snapshot_diff(e.store, s1, s2)
    assert warm.stats.visibility_builds == 0
    assert warm.n_groups == 6


def test_cache_lru_eviction_bounded():
    e = mk_engine(8)
    e.store.vis_cache = VisibilityCache(e.store, capacity=2)
    for i in range(6):
        e.delete_by_keys("t", {"k": np.array([i])})
    assert len(e.store.vis_cache._cache) <= 2
    # correctness unaffected by evictions
    assert e.table("t").count() == 2


def test_gc_drops_entries_referencing_dead_tombstones():
    e = Engine(retention_versions=1)
    e.create_table("t", SCH)
    e.insert("t", {"k": np.arange(20, dtype=np.int64),
                   "v": np.zeros(20, np.int64)})
    e.delete_by_keys("t", {"k": np.arange(10, dtype=np.int64)})
    tomb_oids = e.table("t").directory.tomb_oids
    e.table("t").scan()
    compact_objects(e, "t", list(e.table("t").directory.data_oids))
    e.gc()
    assert all(not e.store.has(o) for o in tomb_oids)
    cache = e.store.vis_cache
    assert all(not (set(k[0]) & set(tomb_oids)) for k in cache._cache)
    assert e.table("t").count() == 10


def test_delta_cache_memoizes_and_invalidates_by_key():
    e = mk_engine()
    s1 = e.create_snapshot("s1", "t")
    e.clone_table("t2", s1)
    e.update_by_keys("t2", {"k": np.array([1]), "v": np.array([9])})
    s2 = e.create_snapshot("s2", "t2")
    d1 = snapshot_diff(e.store, s1, s2)
    assert d1.stats.delta_cache_hits == 0
    d2 = snapshot_diff(e.store, s1, s2)
    assert d2.stats.delta_cache_hits == 1
    np.testing.assert_array_equal(d1.diff_cnt, d2.diff_cnt)
    np.testing.assert_array_equal(d1.rowid, d2.rowid)
    # a new commit changes the directory (new key) -> no stale reuse
    e.update_by_keys("t2", {"k": np.array([2]), "v": np.array([8])})
    s3 = e.create_snapshot("s3", "t2")
    d3 = snapshot_diff(e.store, s1, s3)
    assert d3.stats.delta_cache_hits == 0
    assert d3.n_groups == 4


def test_delta_cache_entries_dropped_on_gc():
    e = Engine(retention_versions=1)
    e.create_table("t", SCH)
    e.insert("t", {"k": np.arange(10, dtype=np.int64),
                   "v": np.zeros(10, np.int64)})
    s1 = e.current_snapshot("t")
    e.delete_by_keys("t", {"k": np.array([0, 1])})
    s2 = e.current_snapshot("t")
    snapshot_diff(e.store, s1, s2)
    compact_objects(e, "t", list(e.table("t").directory.data_oids))
    e.gc()
    alive = set(e.store.oids())
    for key in e.store.delta_cache._cache:
        for part in (key[0], key[1], key[3], key[4]):
            assert set(part) <= alive


# -------------------------------------------------- correctness across ops

def test_no_stale_visibility_after_restore():
    e = mk_engine()
    snap = e.create_snapshot("before", "t")
    e.delete_by_keys("t", {"k": np.arange(10, dtype=np.int64)})
    assert e.table("t").count() == 30
    e.restore_table("t", "before")
    assert e.table("t").count() == 40    # deleted rows visible again
    e.delete_by_keys("t", {"k": np.array([0])})
    assert e.table("t").count() == 39


def test_no_stale_visibility_after_compaction():
    e = mk_engine()
    e.delete_by_keys("t", {"k": np.array([3, 5, 7])})
    before, _ = e.table("t").scan()
    compact_objects(e, "t", list(e.table("t").directory.data_oids))
    after, _ = e.table("t").scan()
    assert sorted(before["k"].tolist()) == sorted(after["k"].tolist())
    assert e.table("t").directory.tomb_oids == ()  # tombs died with targets


def test_partitioned_masks_match_bruteforce_oracle():
    rng = np.random.default_rng(7)
    e = mk_engine(60)
    for _ in range(3):
        ks = rng.choice(60, size=5, replace=False)
        e.delete_by_keys("t", {"k": ks.astype(np.int64)})
    d = e.table("t").directory
    vi = visibility_index(e.store, d)
    for oid in d.data_oids:
        obj = e.store.get(oid)
        np.testing.assert_array_equal(
            vi.visible_mask(obj), brute_visible(e.store, d, obj))
        assert vi.has_kills(obj) == bool(
            (~brute_visible(e.store, d, obj)).any()
            or (obj.commit_ts > np.uint64(d.ts)).any()) or not vi.has_kills(obj)
    # killed_rowids agrees with killed_mask per object
    for oid in d.data_oids:
        obj = e.store.get(oid)
        np.testing.assert_array_equal(
            vi.killed_rowids(obj.rowids()), vi.killed_mask(obj))


def test_fully_visible_zone_pruning():
    e = mk_engine()
    d = e.table("t").directory
    vi = visibility_index(e.store, d)
    for oid in d.data_oids:
        obj = e.store.get(oid)
        assert vi.fully_visible(obj)
        assert vi.visible_mask(obj).all()
        assert vi.visible_count(obj) == obj.nrows
    # a horizon before the insert sees nothing
    old = Directory(d.data_oids, d.tomb_oids, 0)
    vi0 = visibility_index(e.store, old)
    for oid in d.data_oids:
        assert not vi0.fully_visible(e.store.get(oid))
        assert not vi0.visible_mask(e.store.get(oid)).any()


# -------------------------------------------------------------- PITR bisect

def linear_directory_at(history, name, ts):
    best = None
    for t, d in history:
        if t <= ts:
            best = d
    if best is None:
        raise KeyError(name)
    return Directory(best.data_oids, best.tomb_oids, ts)


def test_directory_at_bisect_matches_linear_scan():
    e = mk_engine(10)
    for i in range(5):
        e.insert("t", {"k": np.array([100 + i]), "v": np.array([i])})
    t = e.table("t")
    for ts in range(0, e.ts + 2):
        got = t.directory_at(ts)
        exp = linear_directory_at(t.history, "t", ts)
        assert got == exp


def test_directory_at_after_restore_shadows_newer_entries():
    e = mk_engine(10)
    snap = e.create_snapshot("s", "t")
    snap_ts = snap.ts
    e.insert("t", {"k": np.array([100]), "v": np.array([1])})
    e.insert("t", {"k": np.array([101]), "v": np.array([2])})
    e.restore_table("t", "s")            # out-of-order apply-ts
    t = e.table("t")
    # history stays sorted by ts
    tss = [h[0] for h in t.history]
    assert tss == sorted(tss)
    # any horizon >= snap_ts now resolves to the restored version
    for ts in range(snap_ts, e.ts + 2):
        assert t.directory_at(ts).data_oids == snap.directory.data_oids
    assert e.table("t").count() == 10


def test_directory_at_before_history_raises():
    e = Engine()
    e.next_ts(); e.next_ts()
    e.create_table("t", SCH)
    with pytest.raises(KeyError):
        e.table("t").directory_at(0)


# ------------------------------------------------------- vectorized probes

def test_locate_keys_resolves_invisible_run_heads():
    """An updated key's old row sorts at the lower bound but is dead: the
    vectorized run resolution must skip it (in its object) and the LSM walk
    must find the new version in the newer object."""
    e = mk_engine(50)
    from repro.core.sigs import key_sigs_for_lookup
    e.update_by_keys("t", {"k": np.arange(0, 50, 3, dtype=np.int64),
                           "v": np.full(17, 5, np.int64)})
    batch, rowids = e.table("t").scan()
    expect = dict(zip(batch["k"].tolist(), rowids.tolist()))
    klo, khi = key_sigs_for_lookup(SCH, {"k": np.arange(50, dtype=np.int64)})
    got = e.table("t").locate_keys(klo, khi)
    for i in range(50):
        assert int(got[i]) == expect[i], i
    # absent keys miss
    klo, khi = key_sigs_for_lookup(SCH, {"k": np.array([777], np.int64)})
    assert e.table("t").locate_keys(klo, khi)[0] == 0


def test_locate_rowsig_multi_cardinality():
    """NoPK: k duplicates inserted, need<=k resolved, visibility honored."""
    e = Engine()
    e.create_table("t", SCH_NOPK)
    # 4 identical rows (k=1,v=1), 2 identical (k=2,v=2), 1 unique
    e.insert("t", {"k": np.array([1, 1, 1, 1, 2, 2, 3], np.int64),
                   "v": np.array([1, 1, 1, 1, 2, 2, 3], np.int64)})
    _, _, row_lo, row_hi = e.table("t").scan(with_sigs=True)
    batch, rowids = e.table("t").scan()
    k = batch["k"]
    sig1 = (row_lo[k == 1][0], row_hi[k == 1][0])
    sig2 = (row_lo[k == 2][0], row_hi[k == 2][0])
    sig_lo = np.array([sig1[0], sig2[0]], np.uint64)
    sig_hi = np.array([sig1[1], sig2[1]], np.uint64)
    found = e.table("t").locate_rowsig_multi(sig_lo, sig_hi,
                                             np.array([3, 5], np.int64))
    assert found[0].shape[0] == 3        # capped by need
    assert found[1].shape[0] == 2        # capped by availability
    assert set(found[0]) <= set(rowids[k == 1].tolist())
    assert set(found[1]) == set(rowids[k == 2].tolist())
    # delete two of the k=1 dups: only 2 remain findable
    tx = e.begin()
    tx.delete_rowids("t", found[0][:2])
    tx.commit()
    found2 = e.table("t").locate_rowsig_multi(sig_lo, sig_hi,
                                              np.array([4, 1], np.int64))
    assert found2[0].shape[0] == 2
    assert found2[1].shape[0] == 1


def test_upper_bound_matches_numpy():
    rng = np.random.default_rng(3)
    arr = np.sort(rng.integers(0, 100, 50).astype(np.uint64))
    q = rng.integers(0, 110, 30).astype(np.uint64)
    np.testing.assert_array_equal(
        ops.upper_bound(arr, q),
        np.searchsorted(arr, q, side="right").astype(np.int64))
    # uint64-max query cannot overflow into index 0
    q_max = np.array([np.iinfo(np.uint64).max], np.uint64)
    assert ops.upper_bound(arr, q_max)[0] == arr.shape[0]


def test_upper_bound_pallas_interpret_agrees():
    prev = ops.FORCE_PALLAS_INTERPRET
    ops.FORCE_PALLAS_INTERPRET = True
    try:
        rng = np.random.default_rng(4)
        arr = np.sort(rng.integers(0, 1 << 62, 64).astype(np.uint64))
        q = np.concatenate([rng.integers(0, 1 << 62, 17).astype(np.uint64),
                            arr[:5],
                            np.array([np.iinfo(np.uint64).max], np.uint64)])
        np.testing.assert_array_equal(
            ops.upper_bound(arr, q),
            np.searchsorted(arr, q, side="right").astype(np.int64))
    finally:
        ops.FORCE_PALLAS_INTERPRET = prev


def test_per_key_conflicts_vectorized():
    e = mk_engine(20)
    s1 = e.create_snapshot("s1", "t")
    e.clone_table("t2", s1)
    # t: update keys 0,1 ; t2: update keys 1,2 -> key 1 conflicts
    e.update_by_keys("t", {"k": np.array([0, 1]), "v": np.array([5, 5])})
    e.update_by_keys("t2", {"k": np.array([1, 2]), "v": np.array([6, 6])})
    d = snapshot_diff(e.store, e.current_snapshot("t"),
                      e.current_snapshot("t2"))
    groups = d.per_key_conflicts()
    # every touched key (0, 1, 2) has a version on both sides of the diff
    assert len(groups) == 3
    for grp in groups:
        assert (np.sign(d.diff_cnt[grp]) > 0).any()
        assert (np.sign(d.diff_cnt[grp]) < 0).any()
        assert np.unique(d.key_lo[grp]).shape[0] == 1
    empty = snapshot_diff(e.store, e.current_snapshot("t"),
                          e.current_snapshot("t"))
    assert empty.per_key_conflicts() == []


def test_merge_and_diff_agree_after_cache_churn():
    """End-to-end: interleave commits, restores and compaction, then check
    snapshot_diff == sql_diff and a merge lands correctly (PK + NoPK)."""
    for pk in (True, False):
        e = Engine()
        e.create_table("t", SCH if pk else SCH_NOPK)
        e.insert("t", {"k": np.arange(30, dtype=np.int64),
                       "v": np.zeros(30, np.int64)})
        s0 = e.create_snapshot("s0", "t")
        e.clone_table("b", s0)
        tx = e.begin()
        if pk:
            tx.update_by_keys("b", {"k": np.array([1, 2, 3]),
                                    "v": np.array([7, 7, 7])})
        else:
            _, rowids = e.table("b").scan()
            tx.delete_rowids("b", rowids[:3])
            tx.insert("b", {"k": np.array([100, 101, 102], np.int64),
                            "v": np.array([7, 7, 7], np.int64)})
        tx.commit()
        compact_objects(e, "b", list(e.table("b").directory.data_oids))
        sb = e.create_snapshot("sb", "b")
        d1 = snapshot_diff(e.store, s0, sb)
        d2 = sql_diff(e.store, s0, sb)
        assert d1.n_groups == d2.n_groups == 6
        rep = three_way_merge(e, "t", sb, base=s0, mode=ConflictMode.ACCEPT)
        assert rep.true_conflicts == 0
        got = dict()
        batch, _ = e.table("t").scan()
        for kk, vv in zip(batch["k"].tolist(), batch["v"].tolist()):
            got.setdefault(kk, []).append(vv)
        if pk:
            assert got[1] == [7] and got[2] == [7] and got[3] == [7]
        else:
            assert got[100] == [7] and got[101] == [7] and got[102] == [7]
