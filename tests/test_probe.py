"""ISSUE 9 tests: fused 128-bit probe + key-range-sharded merge/aggregate.

Pins the two byte-identity contracts of the perf work:

* ``ops.probe128`` collapses the lower/upper-bound + collision-run
  expansion chain into one pass — the chained reference implementation
  here is the oracle, exercised on adversarial seeded lo64-collision
  workloads (hypothesis property on top when the container has it);
* key-range sharding (``merge128_runs(cuts=...)``,
  ``diff_aggregate(_rows)(shards=...)``, and the end-to-end engine under
  ``set_key_shards``) is a partitioning of the SAME computation — every
  output must be byte-identical to the unsharded path.

Plus the probe edge cases: all-invisible duplicate runs, zone-prune
boundary keys (query == zmin/zmax), empty-table/empty-query guards, and
the EXPLAIN MERGE surface reporting ``probe.*`` deltas next to the
``commit.rows_rehashed=0`` invariant.
"""
import numpy as np
import pytest

try:  # property tests run under hypothesis when present; the deterministic
    # seeded oracle tests below run everywhere (the CI container lacks it)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

from repro.core import Column, CType, Engine, Repo, Schema
from repro.core.objects import pack_rowid, seal_data_object
from repro.core.statements import execute
from repro.distributed import sharding
from repro.kernels import ops

from conftest import VCS_SCHEMA, VCS_SCHEMA_NOPK, content_digest, kv_batch

SCH_PLAIN = Schema((Column("k", CType.I64), Column("v", CType.F64)),
                   primary_key=("k",))


# ================================================= probe128 vs chained oracle

def _chained_probe(t_lo, t_hi, q_lo, q_hi):
    """The pre-fusion reference: lo64 lower/upper bound pair, then expand
    every lo64-collision run and count/rank the hi64 refinement with
    reduceat — exactly the chain ``probe128`` replaced."""
    n, nq = t_lo.shape[0], q_lo.shape[0]
    start = np.zeros((nq,), np.int64)
    cnt = np.zeros((nq,), np.int64)
    if n == 0 or nq == 0:
        return start, cnt
    lb = np.searchsorted(t_lo, q_lo, side="left").astype(np.int64)
    ub = np.searchsorted(t_lo, q_lo, side="right").astype(np.int64)
    start[:] = lb
    run = ub > lb
    ridx = np.flatnonzero(run)
    for i in ridx.tolist():  # oracle clarity over speed
        a, b = int(lb[i]), int(ub[i])
        seg = t_hi[a:b]
        start[i] = a + int(np.searchsorted(seg, q_hi[i], side="left"))
        cnt[i] = int((seg == q_hi[i]).sum())
    return start, cnt


def _sorted_table(rng, n, lo_dom, hi_dom):
    lo = rng.integers(0, lo_dom, n).astype(np.uint64)
    hi = rng.integers(0, hi_dom, n).astype(np.uint64)
    o = np.lexsort((hi, lo))
    return lo[o], hi[o]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_probe128_matches_chained_oracle_seeded(seed):
    rng = np.random.default_rng([seed] + list(b"PROBE"))
    for n, nq, lo_dom, hi_dom in [(0, 5, 4, 3), (7, 0, 4, 3),
                                  (1, 8, 2, 2), (64, 200, 9, 4),
                                  (500, 300, 17, 3), (500, 300, 3, 50)]:
        t_lo, t_hi = _sorted_table(rng, n, lo_dom, hi_dom)
        # query mix: present keys, lo64-collision misses (right lo, wrong
        # hi), and fully absent keys beyond both domains
        q_lo = rng.integers(0, lo_dom + 2, nq).astype(np.uint64)
        q_hi = rng.integers(0, hi_dom + 2, nq).astype(np.uint64)
        got_s, got_c = ops.probe128(t_lo, t_hi, q_lo, q_hi)
        want_s, want_c = _chained_probe(t_lo, t_hi, q_lo, q_hi)
        np.testing.assert_array_equal(got_c, want_c)
        np.testing.assert_array_equal(got_s, want_s)


if HAVE_HYPOTHESIS:
    _sig = st.tuples(st.integers(0, 6), st.integers(0, 3))
    _tbl = st.lists(_sig, max_size=40).map(sorted)
    _qry = st.lists(_sig, max_size=20)
else:  # pragma: no cover - @given is a skip marker; value never sampled
    _tbl = _qry = None


@settings(max_examples=200, deadline=None)
@given(_tbl, _qry)
def test_probe128_property(tbl, qry):
    t_lo = np.asarray([p[0] for p in tbl], np.uint64)
    t_hi = np.asarray([p[1] for p in tbl], np.uint64)
    q_lo = np.asarray([p[0] for p in qry], np.uint64)
    q_hi = np.asarray([p[1] for p in qry], np.uint64)
    got_s, got_c = ops.probe128(t_lo, t_hi, q_lo, q_hi)
    want_s, want_c = _chained_probe(t_lo, t_hi, q_lo, q_hi)
    np.testing.assert_array_equal(got_c, want_c)
    np.testing.assert_array_equal(got_s, want_s)


def test_probe128_interpret_matches_cpu():
    """The Pallas kernel (interpret mode) agrees with the numpy fallback,
    including on duplicate runs that straddle the query padding block."""
    rng = np.random.default_rng(list(b"PROBEK"))
    t_lo, t_hi = _sorted_table(rng, 700, 23, 5)
    q_lo = rng.integers(0, 25, 333).astype(np.uint64)
    q_hi = rng.integers(0, 7, 333).astype(np.uint64)
    want = ops.probe128(t_lo, t_hi, q_lo, q_hi)
    prev = ops.FORCE_PALLAS_INTERPRET
    ops.FORCE_PALLAS_INTERPRET = True
    try:
        got = ops.probe128(t_lo, t_hi, q_lo, q_hi)
    finally:
        ops.FORCE_PALLAS_INTERPRET = prev
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


# ============================================== probe edge cases (visibility)

class _StubVI:
    """Visibility stand-in: a fixed mask, so duplicate-key objects (which
    the PK engine never seals) can exercise the run-expansion path."""

    def __init__(self, mask):
        self._mask = np.asarray(mask, bool)

    def visible_mask(self, obj):
        return self._mask


def _dup_key_object(oid=7):
    """key runs: (1,7)x3, (2,3)x2, (5,0)x1 — sorted, with duplicates."""
    k_lo = np.array([1, 1, 1, 2, 2, 5], np.uint64)
    k_hi = np.array([7, 7, 7, 3, 3, 0], np.uint64)
    n = k_lo.shape[0]
    batch = {"k": np.arange(n, dtype=np.int64),
             "v": np.arange(n, dtype=np.float64)}
    return seal_data_object(
        oid, SCH_PLAIN, batch, np.ones((n,), np.uint64),
        np.arange(10, 10 + n, dtype=np.uint64),
        np.arange(20, 20 + n, dtype=np.uint64), k_lo, k_hi, {})


def test_probe_object_duplicate_run_visibility():
    engine = Engine()
    engine.create_table("t", SCH_PLAIN)
    t = engine.table("t")
    obj = _dup_key_object()
    q_lo = np.array([1, 2, 5, 3], np.uint64)   # 3 -> absent key
    q_hi = np.array([7, 3, 0, 0], np.uint64)

    def rid(off):
        return pack_rowid(obj.oid, np.array([off], np.uint64))[0]

    # head visible: every run resolves at its first row, no expansion
    base = engine.store.metrics.counters.get("probe.expansions", 0)
    out = t._probe_object(obj, _StubVI(np.ones(6, bool)), q_lo, q_hi)
    np.testing.assert_array_equal(out, [rid(0), rid(3), rid(5), 0])
    assert engine.store.metrics.counters.get("probe.expansions", 0) == base

    # head invisible, deeper duplicate visible: expansion finds the FIRST
    # visible row of the exactly-equal run
    out = t._probe_object(obj, _StubVI([False, False, True, False, True,
                                        True]), q_lo, q_hi)
    np.testing.assert_array_equal(out, [rid(2), rid(4), rid(5), 0])
    assert engine.store.metrics.counters.get("probe.expansions", 0) == base + 2

    # all-invisible duplicate runs: misses, never a dead rowid
    out = t._probe_object(obj, _StubVI(np.zeros(6, bool)), q_lo, q_hi)
    np.testing.assert_array_equal(out, [0, 0, 0, 0])


def test_locate_rowsig_all_invisible_duplicate_run():
    """NoPK: identical rows seal one duplicate run; deleting them one by
    one walks the run down to all-invisible (locate returns nothing)."""
    engine = Engine()
    engine.create_table("t", VCS_SCHEMA_NOPK)
    tx = engine.begin()
    tx.insert("t", kv_batch([5, 5, 5], vals=[1.0, 1.0, 1.0],
                            docs=[b"x", b"x", b"x"]))
    tx.insert("t", kv_batch([9]))
    tx.commit()
    t = engine.table("t")
    _, _, lo, hi = t.scan(with_sigs=True)
    # the duplicated signature is the one appearing 3x
    vals, counts = np.unique(lo, return_counts=True)
    dup_lo = vals[np.argmax(counts)]
    dup_hi = hi[lo == dup_lo][0]
    assert int(counts.max()) == 3
    q_lo, q_hi = np.array([dup_lo]), np.array([dup_hi])

    found = t.locate_rowsig_multi(q_lo, q_hi, np.array([3]))[0]
    assert found.shape[0] == 3
    # delete two: the run's newest rows become invisible, locate degrades
    tx = engine.begin()
    tx.delete_rowids("t", found[:2])
    tx.commit()
    found = t.locate_rowsig_multi(q_lo, q_hi, np.array([3]))[0]
    assert found.shape[0] == 1
    tx = engine.begin()
    tx.delete_rowids("t", found)
    tx.commit()
    # all-invisible duplicate run: empty, in both return shapes
    assert t.locate_rowsig_multi(q_lo, q_hi, np.array([3]))[0].shape[0] == 0
    assert t.locate_rowsig_multi(q_lo, q_hi, np.array([3]),
                                 flat=True).shape[0] == 0


def test_locate_keys_zone_boundaries_and_deleted():
    """Zone pruning is inclusive at both edges (key_lo == zmin/zmax must
    probe, not prune) and deleted keys miss; counters move accordingly."""
    from repro.core.sigs import key_sigs_for_lookup
    engine = Engine()
    engine.create_table("t", VCS_SCHEMA)
    keys = list(range(100, 200))
    tx = engine.begin()
    tx.insert("t", kv_batch(keys))
    tx.commit()
    tx = engine.begin()
    tx.delete_by_keys("t", {"k": np.array([150], np.int64)})
    tx.commit()
    t = engine.table("t")
    obj = engine.store.get(t.directory.data_oids[0])
    zmin, zmax = obj.zone
    # recover the int keys sitting exactly on the zone edges
    q_lo, q_hi = key_sigs_for_lookup(
        VCS_SCHEMA, {"k": np.asarray(keys, np.int64)})
    kmin = keys[int(np.flatnonzero(q_lo == zmin)[0])]
    kmax = keys[int(np.flatnonzero(q_lo == zmax)[0])]
    for k, want_hit in [(kmin, True), (kmax, True), (150, False),
                        (999, False)]:
        s_lo, s_hi = key_sigs_for_lookup(VCS_SCHEMA,
                                         {"k": np.array([k], np.int64)})
        got = t.locate_keys(s_lo, s_hi)
        assert (got[0] != 0) == want_hit, k
    assert engine.store.metrics.counters.get("probe.queries", 0) >= 4
    assert engine.store.metrics.counters.get("probe.hits", 0) >= 2


def test_locate_keys_empty_table_and_empty_object_skip():
    engine = Engine()
    engine.create_table("t", VCS_SCHEMA)
    t = engine.table("t")
    q = np.array([1, 2, 3], np.uint64)
    np.testing.assert_array_equal(t.locate_keys(q, q), [0, 0, 0])
    assert engine.store.metrics.counters.get("probe.objects_probed", 0) == 0
    # a zero-row sealed object in the directory is skipped before zone
    # pruning or probing
    empty = seal_data_object(
        engine.store.new_oid(), SCH_PLAIN,
        {"k": np.zeros((0,), np.int64), "v": np.zeros((0,), np.float64)},
        np.zeros((0,), np.uint64), np.zeros((0,), np.uint64),
        np.zeros((0,), np.uint64), np.zeros((0,), np.uint64),
        np.zeros((0,), np.uint64), {})
    engine.store.put(empty)
    d = t.directory
    d2 = type(d)(data_oids=d.data_oids + (empty.oid,),
                 tomb_oids=d.tomb_oids, ts=d.ts)
    np.testing.assert_array_equal(t.locate_keys(q, q, d2), [0, 0, 0])
    assert engine.store.metrics.counters.get("probe.objects_probed", 0) == 0


# ======================================== key-range sharding: byte identity

def _random_stream(rng, k, n, lo_dom, hi_dom):
    parts, starts, off = [], [], 0
    for _ in range(k):
        m = int(rng.integers(1, n + 1))
        lo = rng.integers(0, lo_dom, m).astype(np.uint64)
        hi = rng.integers(0, hi_dom, m).astype(np.uint64)
        o = np.lexsort((hi, lo))
        parts.append((lo[o], hi[o]))
        starts.append(off)
        off += m
    lo = np.concatenate([p[0] for p in parts])
    hi = np.concatenate([p[1] for p in parts])
    return lo, hi, np.asarray(starts, np.int64)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("shards", [2, 4, 7])
def test_merge128_runs_sharded_byte_identical(seed, shards):
    rng = np.random.default_rng([seed, shards] + list(b"SHARD"))
    for k, n, lo_dom, hi_dom in [(2, 50, 5, 3), (5, 200, 31, 2),
                                 (9, 400, 7, 7)]:
        lo, hi, starts = _random_stream(rng, k, n, lo_dom, hi_dom)
        want = ops.merge128_runs(lo, hi, starts)
        cuts = sharding.plan_key_cuts(lo, hi, starts, shards)
        if cuts is None:
            continue
        assert cuts[0].shape[0] >= 1
        got = ops.merge128_runs(lo, hi, starts, cuts=cuts)
        np.testing.assert_array_equal(got, want)
        # the plan itself: ascending, strictly distinct boundary keys
        key = list(zip(cuts[0].tolist(), cuts[1].tolist()))
        assert key == sorted(set(key))


@pytest.mark.parametrize("seed", [0, 1])
def test_diff_aggregate_sharded_byte_identical(seed):
    rng = np.random.default_rng([seed] + list(b"AGG"))
    lo = rng.integers(0, 40, 500).astype(np.uint64)
    hi = rng.integers(0, 3, 500).astype(np.uint64)
    o = np.lexsort((hi, lo))
    lo, hi = lo[o], hi[o]
    sg = rng.choice(np.array([-1, 1], np.int32), 500)
    _, want = ops.diff_aggregate(lo, hi, sg, presorted=True)
    for shards in (2, 4, 9):
        _, got = ops.diff_aggregate(lo, hi, sg, presorted=True,
                                    shards=shards)
        np.testing.assert_array_equal(got.boundary, want.boundary)
        np.testing.assert_array_equal(got.run_sums, want.run_sums)
    # rows variant, PK-style distinct row signatures under duplicate keys
    r_lo = rng.permutation(500).astype(np.uint64)
    r_hi = rng.integers(0, 2, 500).astype(np.uint64)
    _, want = ops.diff_aggregate_rows(lo, hi, r_lo, r_hi, sg,
                                      presorted=True)
    for shards in (2, 4, 9):
        _, got = ops.diff_aggregate_rows(lo, hi, r_lo, r_hi, sg,
                                         presorted=True, shards=shards)
        np.testing.assert_array_equal(got.boundary, want.boundary)
        np.testing.assert_array_equal(got.run_sums, want.run_sums)
    # NoPK aliasing (key IS the row signature) survives the slicing
    _, want = ops.diff_aggregate_rows(lo, hi, lo, hi, sg, presorted=True)
    _, got = ops.diff_aggregate_rows(lo, hi, lo, hi, sg, presorted=True,
                                     shards=4)
    np.testing.assert_array_equal(got.boundary, want.boundary)


@pytest.mark.parametrize("pk", [True, False])
def test_end_to_end_sharded_workload_identical(pk):
    """The full engine under a forced 4-way shard plan produces the same
    diff/merge/scan digests as the unsharded run — sharding is a plan,
    never a semantic."""
    from test_diff_digest import run_workload
    want = run_workload(pk, n_rows=20_000, csize=1_500)
    prev = sharding.set_key_shards(4)
    try:
        got = run_workload(pk, n_rows=20_000, csize=1_500)
    finally:
        sharding.set_key_shards(prev)
    assert got == want


def test_forced_shards_delta_digest_and_counter():
    """A forced shard plan partitions the Δ merge (multi-object signed
    stream) without changing the diff, and the shard_parts counter moves."""
    from repro.core import snapshot_diff
    from test_diff_digest import diff_digest

    def build():
        e = Engine()
        e.create_table("t", VCS_SCHEMA_NOPK)
        rng = np.random.default_rng(list(b"E2E"))
        sn0 = e.create_snapshot("s0", "t")
        for step in range(4):
            tx = e.begin()
            tx.insert("t", kv_batch(rng.integers(0, 500, 700)))
            tx.commit()
        return e, snapshot_diff(e.store, sn0, e.current_snapshot("t"))

    prev = sharding.set_key_shards(4)
    try:
        engine_shard, d_shard = build()
        assert engine_shard.store.metrics.counters.get(
            "probe.shard_parts", 0) > 0
    finally:
        sharding.set_key_shards(prev)
    engine_plain, d_plain = build()
    assert diff_digest(d_shard) == diff_digest(d_plain)
    assert (content_digest(engine_shard, "t")
            == content_digest(engine_plain, "t"))


def test_key_shard_count_policy():
    assert sharding.key_shard_count(sharding.KEY_SHARD_MIN_ROWS - 1) == 1
    big = sharding.key_shard_count(sharding.KEY_SHARD_MIN_ROWS)
    assert 2 <= big <= sharding.KEY_SHARD_MAX
    prev = sharding.set_key_shards(6)
    try:
        assert sharding.key_shard_count(10) == 6
    finally:
        sharding.set_key_shards(prev)
    assert sharding.key_shard_count(10) == 1


# ============================================================ EXPLAIN surface

def test_explain_merge_reports_probe_counters():
    repo = Repo()
    repo.engine.create_table("t", VCS_SCHEMA)
    tx = repo.engine.begin()
    tx.insert("t", kv_batch(range(1000)))
    tx.commit()
    repo.branch("dev", ["t"])
    tx = repo.engine.begin()
    tx.update_by_keys("dev/t", kv_batch(range(200),
                                        vals=np.arange(200) * 3.0))
    tx.commit()
    res = execute(repo, "EXPLAIN MERGE BRANCH dev INTO main")
    assert res.kind == "explain"
    assert "commit.rows_rehashed=0" in res.message
    assert "probe.queries=" in res.message
    assert "probe.hits=" in res.message
