"""Zero-rehash apply path (ISSUE 4): SigBatch carry, sort skipping,
CommitStats invariants, materialized clones, tombstone seal grouping, and
PITR visibility derivation.

The byte-identity of the carried path is pinned by
tests/test_diff_digest.py (GOLDEN_APPLY); these tests pin the *mechanism*:
the hot path literally never hashes, a false sortedness claim is caught,
and the derived PITR arrays match the from-scratch oracle.
"""
import numpy as np
import pytest

from repro.configs.paper_vcs import gen_lineitem
from repro.core import (CommitStats, ConflictMode, Engine, SigBatch,
                        snapshot_diff, three_way_merge)
from repro.core import sigs as sigs_mod
from repro.core.visibility import VisibilityIndex, _build_entry


def _engine(pk: bool, n=4000, seed=0):
    from benchmarks.vcs_tables import _mk_engine
    return _mk_engine(n, pk, seed=seed)


def _update(engine, table, base, idx, pk, tag=1):
    newvals = {k: v[idx].copy() for k, v in base.items()}
    newvals["l_quantity"] = newvals["l_quantity"] + 1.0 + tag
    newvals["l_comment"] = np.array(
        [b"carry-%d-%d" % (tag, i) for i in range(idx.shape[0])], object)
    tx = engine.begin()
    if pk:
        tx.update_by_keys(table, newvals)
    else:
        t = engine.table(table)
        _, rowids = t.scan()
        tx.delete_rowids(table, rowids[idx])
        tx.insert(table, newvals)
    tx.commit()


def _branch_setup(pk, n=4000, csize=300):
    engine, base = _engine(pk, n)
    sn1 = engine.create_snapshot("sn1", "lineitem")
    engine.clone_table("t", sn1)
    rng = np.random.default_rng([7, int(pk)])
    idx = np.sort(rng.choice(n, size=csize, replace=False))
    _update(engine, "t", base, idx, pk, tag=2)
    sn3 = engine.create_snapshot("sn3", "t")
    return engine, sn1, sn3


# ---------------------------------------------------------------- counters

@pytest.mark.parametrize("pk", [True, False])
def test_merge_apply_never_rehashes(pk):
    engine, sn1, sn3 = _branch_setup(pk)
    engine.commit_stats = CommitStats()
    rep = three_way_merge(engine, "lineitem", sn3, base=sn1,
                          mode=ConflictMode.ACCEPT)
    assert rep.inserted > 0
    st = engine.commit_stats
    assert st.rows_rehashed == 0 and st.lob_rows_hashed == 0
    assert st.rows_carried == rep.inserted
    assert st.apply_sorts == 0
    assert st.apply_sort_skipped + st.apply_sort_merged == 1


@pytest.mark.parametrize("pk", [True, False])
def test_revert_apply_never_rehashes(pk):
    engine, sn1, sn3 = _branch_setup(pk)
    pre = engine.create_snapshot("pre", "lineitem")
    three_way_merge(engine, "lineitem", sn3, base=sn1,
                    mode=ConflictMode.ACCEPT)
    post = engine.create_snapshot("post", "lineitem")
    engine.commit_stats = CommitStats()
    assert engine.revert("lineitem", pre, post) is not None
    st = engine.commit_stats
    assert st.rows_rehashed == 0 and st.lob_rows_hashed == 0
    assert st.rows_carried > 0 and st.apply_sorts == 0
    # the revert landed the table back on the pre-merge state
    assert snapshot_diff(engine.store, pre,
                         engine.current_snapshot("lineitem")).is_empty()


@pytest.mark.parametrize("pk", [True, False])
def test_publish_and_revert_publish_never_rehash(pk):
    engine, base = _engine(pk)
    engine.create_branch("dev", ["lineitem"])
    rng = np.random.default_rng([11, int(pk)])
    idx = np.sort(rng.choice(4000, size=250, replace=False))
    _update(engine, "dev/lineitem", base, idx, pk, tag=5)
    pr = engine.open_pr("main", "dev")
    pr.add_check(lambda ctx: ctx.count("lineitem") == 4000, "rows")
    engine.commit_stats = CommitStats()
    pr.publish()
    st = engine.commit_stats
    assert st.rows_rehashed == 0 and st.lob_rows_hashed == 0
    assert st.rows_carried > 0
    pr.revert_publish()
    assert engine.commit_stats.rows_rehashed == 0
    # the CI preview merge runs on a scratch engine with its OWN stats —
    # the live engine's counters must not see preview work either way


@pytest.mark.parametrize("pk", [True, False])
def test_fresh_inserts_still_hash(pk):
    engine, base = _engine(pk, n=500)
    st = engine.commit_stats
    assert st.rows_rehashed == 500 and st.rows_carried == 0
    assert st.lob_rows_hashed == 500  # one LOB column (l_comment)
    assert st.apply_sorts == 1


# ----------------------------------------------------- sortedness contract

def test_false_sorted_claim_caught_by_debug_check():
    engine, base = _engine(True, n=200)
    batch, rid, sigs = engine.table("lineitem").scan_carry()
    # deliberately mis-claim: reverse the rows but keep "one sorted run"
    rev = np.arange(rid.shape[0])[::-1]
    bad = SigBatch(sigs.row_lo[rev].copy(), sigs.row_hi[rev].copy(),
                   sigs.key_lo[rev].copy(), sigs.key_hi[rev].copy(),
                   {c: v[rev].copy() for c, v in sigs.lob_sigs.items()},
                   runs=SigBatch.sorted_run())
    batch = {c: v[rev].copy() for c, v in batch.items()}
    engine.create_table("t2", engine.table("lineitem").schema)
    sigs_mod.DEBUG_VALIDATE_CARRY = True
    try:
        tx = engine.begin()
        tx.insert("t2", batch, sigs=bad)
        with pytest.raises(ValueError, match="sorted"):
            tx.commit()
    finally:
        sigs_mod.DEBUG_VALIDATE_CARRY = False
    # an honest claim (no runs -> seal sorts) passes
    ok = SigBatch(bad.row_lo, bad.row_hi, bad.key_lo, bad.key_hi,
                  bad.lob_sigs, runs=None)
    tx = engine.begin()
    tx.insert("t2", batch, sigs=ok)
    tx.commit()
    assert engine.table("t2").count() == 200


def test_alter_add_lob_column_normalizes_str_default():
    # the carry path skips normalize_batch: alter must normalize the LOB
    # fill itself (str -> bytes), and carry keys/old lob sigs through
    from repro.core.schema import Column, CType
    engine, base = _engine(True, n=300)
    engine.commit_stats = CommitStats()
    engine.alter_table_add_column("lineitem", Column("note", CType.LOB),
                                  "hello")
    batch, _ = engine.table("lineitem").scan()
    assert batch["note"][0] == b"hello" and isinstance(batch["note"][0],
                                                      bytes)
    st = engine.commit_stats
    assert st.rows_rehashed == 300      # row sigs genuinely change
    assert st.lob_rows_hashed == 300    # only the NEW column hashes
    assert st.apply_sorts == 0          # PK runs carried through
    with pytest.raises(TypeError):
        engine.alter_table_add_column("lineitem",
                                      Column("n2", CType.LOB), 7)


def test_mismatched_sidecar_refused():
    engine, base = _engine(True, n=100)
    batch, rid, sigs = engine.table("lineitem").scan_carry()
    engine.create_table("t2", engine.table("lineitem").schema)
    bad = SigBatch(sigs.row_lo[:-1], sigs.row_hi[:-1], sigs.key_lo[:-1],
                   sigs.key_hi[:-1],
                   {c: v[:-1] for c, v in sigs.lob_sigs.items()},
                   runs=sigs.runs)
    tx = engine.begin()
    tx.insert("t2", batch, sigs=bad)
    with pytest.raises(ValueError, match="lane"):
        tx.commit()
    malformed = SigBatch(sigs.row_lo, sigs.row_hi, sigs.key_lo, sigs.key_hi,
                         dict(sigs.lob_sigs),
                         runs=np.array([0, 5000], np.int64))  # offset > n
    tx = engine.begin()
    tx.insert("t2", batch, sigs=malformed)
    with pytest.raises(ValueError, match="runs"):
        tx.commit()


def test_validate_runs_accepts_run_boundaries():
    lo = np.array([1, 5, 9, 2, 3], np.uint64)
    hi = np.zeros(5, np.uint64)
    sigs_mod.validate_runs(lo, hi, np.array([0, 3], np.int64))  # ok
    with pytest.raises(ValueError):
        sigs_mod.validate_runs(lo, hi, np.array([0], np.int64))


# ------------------------------------------------------ materialized clone

@pytest.mark.parametrize("pk", [True, False])
def test_clone_materialize_zero_rehash_and_equal(pk):
    engine, base = _engine(pk, n=3000)
    rng = np.random.default_rng([3, int(pk)])
    _update(engine, "lineitem", base, np.sort(rng.choice(3000, 200, False)),
            pk)
    snap = engine.create_snapshot("s", "lineitem")
    engine.commit_stats = CommitStats()
    engine.clone_table("mat", snap, materialize=True)
    st = engine.commit_stats
    assert st.rows_rehashed == 0 and st.lob_rows_hashed == 0
    assert st.rows_carried == 3000
    # fresh physical objects, same logical content
    assert not (set(engine.table("mat").directory.data_oids)
                & set(engine.table("lineitem").directory.data_oids))
    d = snapshot_diff(engine.store, engine.current_snapshot("lineitem"),
                      engine.current_snapshot("mat"))
    assert d.is_empty()


def test_clone_materialize_wal_replay():
    engine, base = _engine(True, n=800)
    snap = engine.create_snapshot("s", "lineitem")
    engine.clone_table("mat", snap, materialize=True)
    extra = {k: v[:5].copy() for k, v in gen_lineitem(900, seed=9).items()}
    extra["l_orderkey"] = extra["l_orderkey"] + 10_000_000  # fresh keys
    engine.insert("mat", extra)
    replayed = Engine.replay(engine.wal)
    a = engine.table("mat").scan(with_sigs=True)
    b = replayed.table("mat").scan(with_sigs=True)
    assert np.array_equal(a[1], b[1]) and np.array_equal(a[2], b[2])


# --------------------------------------------------------- tombstone seal

def test_tombstone_seal_multi_object_key_sigs():
    # deletes spanning several data objects: the group-boundary gather must
    # attach each target's key signature from ITS object
    engine, base = _engine(True, n=2000)
    _update(engine, "lineitem", base, np.arange(0, 1200, 3), True)  # obj 2
    t = engine.table("lineitem")
    batch, rowids = t.scan()
    rng = np.random.default_rng(5)
    pick = np.sort(rng.choice(rowids.shape[0], 300, replace=False))
    tx = engine.begin()
    tx.delete_rowids("lineitem", rowids[pick])
    tx.commit()
    tomb_oid = t.directory.tomb_oids[-1]
    tomb = engine.store.get(tomb_oid)
    assert len(tomb.target_oids) >= 2
    from repro.core.objects import rowid_off, rowid_oid
    for i in range(tomb.nrows):
        obj = engine.store.get(int(rowid_oid(tomb.target[i:i+1])[0]))
        off = int(rowid_off(tomb.target[i:i+1])[0])
        assert tomb.key_lo[i] == obj.key_lo[off]
        assert tomb.key_hi[i] == obj.key_hi[off]


# ------------------------------------------------------- PITR derive cache

def test_pitr_horizon_derives_instead_of_rebuilding():
    engine, base = _engine(True, n=3000)
    ts_marks = []
    for tag in range(4):
        _update(engine, "lineitem", base, np.arange(tag * 200, tag * 200
                                                    + 150), True, tag=tag)
        ts_marks.append(engine.ts)
    t = engine.table("lineitem")
    cache = engine.store.vis_cache
    # historical versions were cached while live — drop them and prime
    # only the HEAD so the horizons must be served by ts-truncation
    cache.clear()
    cache.get(t.directory)
    b0, d0 = cache.builds, cache.derives
    for ts in ts_marks[:-1]:
        d = t.directory_at(ts)
        got = cache.get(d)
        oracle = VisibilityIndex(engine.store, d,
                                 _entry=_build_entry(engine.store, d))
        assert np.array_equal(got.targets, oracle.targets)
    assert cache.builds == b0, "historical horizons must not rebuild"
    assert cache.derives == d0 + len(ts_marks) - 1
    # scans at the derived horizons agree with golden PITR behaviour
    for ts in ts_marks[:-1]:
        n = t.count(t.directory_at(ts))
        assert n == 3000


def test_pitr_full_coverage_horizons_share_canonical_entry():
    engine, base = _engine(True, n=1000)
    _update(engine, "lineitem", base, np.arange(100), True)
    t = engine.table("lineitem")
    cache = engine.store.vis_cache
    cache.get(t.directory)
    b0 = cache.builds
    # any horizon at/after the last tombstone commit shares one entry
    for ts in (engine.ts, engine.ts + 5, engine.ts + 100):
        d = t.directory_at(min(ts, engine.ts)) if ts <= engine.ts else None
        from repro.core.directory import Directory
        d = Directory(t.directory.data_oids, t.directory.tomb_oids, ts)
        cache.get(d)
    assert cache.builds == b0


def test_derived_horizon_diff_matches_oracle():
    # a PITR diff across a derived horizon equals the same diff computed
    # on a cold cache (full rebuild oracle)
    engine, base = _engine(False, n=2500)
    _update(engine, "lineitem", base, np.arange(0, 600, 2), False, tag=1)
    mid = engine.ts
    _update(engine, "lineitem", base, np.arange(1, 601, 2), False, tag=2)
    cur = engine.current_snapshot("lineitem")
    old = engine.snapshot_at("lineitem", mid)
    d1 = snapshot_diff(engine.store, old, cur)
    engine.store.vis_cache.clear()
    engine.store.delta_cache.clear()
    d2 = snapshot_diff(engine.store, old, cur)
    for f in ("diff_cnt", "key_lo", "key_hi", "row_lo", "row_hi", "rowid"):
        assert np.array_equal(getattr(d1, f), getattr(d2, f))
