"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; decode/prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models import lm

pytestmark = pytest.mark.slow  # heavyweight model/accelerator tests

ARCHS = all_arch_names()


def _inputs(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    ctx = None
    if cfg.is_encdec or cfg.cross_len:
        L = cfg.cross_len or 8
        ctx = jax.random.normal(jax.random.PRNGKey(9), (B, L, cfg.d_model),
                                jnp.float32).astype(jnp.dtype(cfg.dtype))
    return tokens, ctx


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens, ctx = _inputs(cfg)
    batch = {"tokens": tokens, "targets": tokens}
    if ctx is not None:
        batch["ctx"] = ctx
    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch, attn_block=16))(params)
    assert np.isfinite(float(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(float(gn))
    logits, aux, _ = lm.forward(cfg, params, tokens, ctx, attn_block=16)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens, ctx = _inputs(cfg)
    lg, cache = lm.prefill(cfg, params, tokens, ctx, seq_cap=40,
                           attn_block=16)
    assert lg.shape == (2, cfg.vocab)
    nxt = jnp.asarray([[3], [5]], jnp.int32)
    dl, cache2 = lm.decode_step(cfg, params, nxt, cache)
    assert dl.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(dl.astype(jnp.float32)).all())
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x7b",
                                  "rwkv6-7b", "whisper-medium"])
def test_decode_matches_forward_f32(arch):
    """decode(prefill(x)) logits == full forward logits (f32 exact-ish)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    tokens, ctx = _inputs(cfg, B=B, S=S)
    lg, cache = lm.prefill(cfg, params, tokens, ctx, seq_cap=24,
                           attn_block=8)
    full, _, _ = lm.forward(cfg, params, tokens, ctx, attn_block=8)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    nxt = jnp.asarray([[7]], jnp.int32)
    dl, _ = lm.decode_step(cfg, params, nxt, cache)
    toks2 = jnp.concatenate([tokens, nxt], axis=1)
    full2, _, _ = lm.forward(cfg, params, toks2, ctx, attn_block=17)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full2[:, -1]),
                               rtol=3e-3, atol=3e-3)


def test_swa_restricts_attention():
    """Mixtral SWA: tokens outside the window cannot influence logits.

    Capacity factor is raised so no token is ever dropped: with drops, an
    early token can legitimately influence later ones through routing
    contention (causal, but it would break this check)."""
    from repro.configs.base import MoECfg
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=MoECfg(n_experts=cfg.moe.n_experts, top_k=2,
                        capacity_factor=4.0))
    assert cfg.sliding_window == 16
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 64
    t1 = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2, cfg.vocab)
    t2 = t1.at[:, :8].set(1)   # mutate tokens far outside the window
    l1, _, _ = lm.forward(cfg, params, t1, attn_block=16)
    l2, _, _ = lm.forward(cfg, params, t2, attn_block=16)
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_causality():
    """Future tokens must not influence past logits (all attention paths)."""
    for arch in ("internlm2-1.8b", "rwkv6-7b", "jamba-1.5-large-398b"):
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype="float32")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 1, 16
        t1 = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2, cfg.vocab)
        t2 = t1.at[:, -1].set(1)
        ctx = None
        l1, _, _ = lm.forward(cfg, params, t1, ctx, attn_block=8)
        l2, _, _ = lm.forward(cfg, params, t2, ctx, attn_block=8)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]),
                                   rtol=1e-5, atol=1e-5, err_msg=arch)


def test_exact_configs_match_assignment():
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    spec = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for name, (L, d, H, KV, ff, V) in spec.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V), name
    assert get_config("mixtral-8x7b").sliding_window == 4096
    assert get_config("mixtral-8x7b").moe.n_experts == 8
    assert get_config("phi3.5-moe-42b-a6.6b").moe.n_experts == 16
    assert get_config("jamba-1.5-large-398b").moe.n_experts == 16
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("whisper-medium").encoder_layers == 24
