"""Pack-file round-trip property suite (ISSUE 10 satellite).

Mirrors test_wal_roundtrip.py for the spill tier: encode -> decode is
lossless for every sealed object shape (PK, NoPK with shared key/row
signature identity, LOB columns, tombstones); the digest is a pure
content address (oid-independent — oids are recycled by rollback);
and EVERY torn tail, truncation, or flipped byte surfaces as a typed
``StoreFormatError``/``PackFormatError``, never as garbage data or a
foreign exception. Property tests run under hypothesis when the
container has it; the seeded deterministic sweeps below run everywhere.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

from conftest import VCS_SCHEMA as SCH
from conftest import VCS_SCHEMA_NOPK as SCH_NOPK
from conftest import kv_batch as _batch

from repro.core import Engine
from repro.core.objects import TombstoneObject
from repro.core.wal import StoreFormatError
from repro.store import (PackFormatError, attach_packs, blob_digest,
                         decode_object, encode_object)

_TYPED = (StoreFormatError, PackFormatError)


def _sample_objects(rows=8):
    """One of each sealed shape, via the real engine paths: a PK data
    object (with LOB lane + lob_sigs), a tombstone, and a NoPK object."""
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", _batch(range(rows)))
    e.delete_by_keys("t", {"k": np.asarray([2, 3])})
    e.create_table("n", SCH_NOPK)
    e.insert("n", _batch(range(rows)))
    return [e.store.get(o) for o in sorted(e.store.oids())]


def _assert_equal(a, b):
    assert type(a) is type(b) and a.oid == b.oid and a.nrows == b.nrows
    if isinstance(a, TombstoneObject):
        for lane in ("commit_ts", "target", "key_lo", "key_hi"):
            np.testing.assert_array_equal(getattr(a, lane),
                                          getattr(b, lane))
        return
    for lane in ("commit_ts", "row_lo", "row_hi", "key_lo", "key_hi"):
        np.testing.assert_array_equal(getattr(a, lane), getattr(b, lane))
    assert sorted(a.cols) == sorted(b.cols)
    for name in a.cols:
        if a.cols[name].dtype == object:
            assert list(a.cols[name]) == list(b.cols[name])
        else:
            np.testing.assert_array_equal(a.cols[name], b.cols[name])
    assert sorted(a.lob_sigs) == sorted(b.lob_sigs)
    for name in a.lob_sigs:
        np.testing.assert_array_equal(a.lob_sigs[name], b.lob_sigs[name])
    assert a.nbytes == b.nbytes


# --------------------------------------------------------------------------
# lossless round trip
# --------------------------------------------------------------------------

def test_roundtrip_every_object_shape():
    for obj in _sample_objects():
        out = decode_object(encode_object(obj), obj.oid)
        _assert_equal(obj, out)


def test_nopk_preserves_key_is_row_identity():
    e = Engine()
    e.create_table("n", SCH_NOPK)
    e.insert("n", _batch(range(6)))
    obj = e.store.get(next(iter(e.store.oids())))
    assert obj.key_lo is obj.row_lo                   # the seal invariant...
    out = decode_object(encode_object(obj), obj.oid)
    assert out.key_lo is out.row_lo                   # ...survives the disk
    assert out.key_hi is out.row_hi


def test_digest_is_oid_independent():
    """The content address must not move when the engine recycles oids:
    the same sealed content at two oids is ONE pack blob."""
    obj = _sample_objects()[0]
    twin = dataclasses.replace(obj, oid=obj.oid + 1000)
    b1, b2 = encode_object(obj), encode_object(twin)
    assert b1 == b2 and blob_digest(b1) == blob_digest(b2)
    rebound = decode_object(b1, twin.oid)             # load re-binds the oid
    assert rebound.oid == twin.oid


def test_oid_reuse_after_rollback_never_serves_stale_bytes(tmp_path):
    """Rollback rewinds ``_next_oid`` (see ObjectStore docstring), so an
    oid CAN be reused for different content — keying packs by digest (not
    oid) is what keeps the spill tier from aliasing the old bytes."""
    e = Engine()
    attach_packs(e.store, str(tmp_path / "packs"))
    e.create_table("t", SCH)
    e.insert("t", _batch(range(5)))
    oid = max(e.store._objects)
    d1 = e.store.spill(oid)
    e.store.delete(oid)                               # rollback analogue
    assert not e.store.packs.has(d1)                  # old pack released
    donor_e = Engine()
    donor_e.create_table("t", SCH)
    donor_e.insert("t", _batch(range(100, 105)))
    donor = donor_e.store.get(max(donor_e.store._objects))
    e.store.put(dataclasses.replace(donor, oid=oid))  # oid reused
    d2 = e.store.evict(oid)
    assert d2 != d1                                   # new content, new key
    got = e.store.get(oid)                            # fault-in
    _assert_equal(donor, dataclasses.replace(got, oid=donor.oid))
    np.testing.assert_array_equal(np.sort(got.cols["k"]),
                                  np.arange(100, 105))


# --------------------------------------------------------------------------
# torn tails, truncation, corruption: typed errors only
# --------------------------------------------------------------------------

def test_truncation_at_every_boundary_is_typed():
    blob = encode_object(_sample_objects()[0])
    for cut in range(len(blob)):
        with pytest.raises(_TYPED):
            decode_object(blob[:cut], 1)


def test_trailing_garbage_is_typed():
    blob = encode_object(_sample_objects()[0])
    for tail in (b"\x00", b"garbage", blob[:17]):
        with pytest.raises(_TYPED):
            decode_object(blob + tail, 1)


def test_flipped_byte_sweep_never_decodes_garbage():
    """Flip one bit at seeded positions across the whole blob: decode must
    either raise a typed format error or return an object whose re-encoded
    digest exposes the damage (the content address is always re-checked by
    ``PackDir.verify``/fault-through reads) — never a foreign exception."""
    blob = encode_object(_sample_objects()[0])
    digest = blob_digest(blob)
    rng = np.random.default_rng(1234)
    positions = set(rng.integers(0, len(blob), size=256).tolist())
    positions |= set(range(16))                       # whole header, always
    for pos in sorted(positions):
        bad = bytearray(blob)
        bad[pos] ^= 1 << int(rng.integers(0, 8))
        bad = bytes(bad)
        assert blob_digest(bad) != digest             # sha256 sees every flip
        try:
            decode_object(bad, 1)
        except _TYPED:
            continue                                  # typed refusal: good
        # decoded despite the flip (e.g. a reserved header byte): the
        # digest mismatch above is what catches it at the store layer


# --------------------------------------------------------------------------
# property tests (hypothesis when present; the seeded sweep always runs)
# --------------------------------------------------------------------------

def _roundtrip_case(keys, vals, docs, cut_frac):
    e = Engine()
    e.create_table("t", SCH)
    e.insert("t", {"k": np.asarray(keys, np.int64),
                   "v": np.asarray(vals, np.float64),
                   "doc": list(docs)})
    obj = e.store.get(next(iter(e.store.oids())))
    blob = encode_object(obj)
    _assert_equal(obj, decode_object(blob, obj.oid))
    assert decode_object(blob, obj.oid + 7).oid == obj.oid + 7
    cut = int(cut_frac * (len(blob) - 1))
    with pytest.raises(_TYPED):
        decode_object(blob[:cut], 1)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(keys=st.lists(st.integers(-2**40, 2**40), min_size=1,
                         max_size=30, unique=True),
           doc=st.binary(max_size=64),
           cut_frac=st.floats(0.0, 1.0))
    def test_pack_roundtrip_property(keys, doc, cut_frac):
        vals = [k * 0.25 for k in keys]
        docs = [doc + b"%d" % k for k in keys]
        _roundtrip_case(keys, vals, docs, cut_frac)


def test_pack_roundtrip_seeded_sweep():
    """Deterministic stand-in for the hypothesis property (always runs)."""
    rng = np.random.default_rng(7)
    for trial in range(12):
        n = int(rng.integers(1, 40))
        keys = rng.choice(np.arange(-1000, 1000), size=n, replace=False)
        vals = rng.random(n) * 1e6
        docs = [bytes(rng.integers(0, 256, size=int(rng.integers(0, 80)),
                                   dtype=np.uint8).tobytes())
                for _ in range(n)]
        _roundtrip_case(keys.tolist(), vals.tolist(), docs,
                        float(rng.random()))
