"""The HLO static cost analyzer vs XLA's own cost_analysis (loop-free) and
vs hand-computed totals (loops)."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # heavyweight model/accelerator tests

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def sh(*spec):
    return NamedSharding(mesh, P(*spec))
from repro.launch.hlo_analysis import analyze_hlo

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))

# 1. loop-free matmul: analyzer == cost_analysis == expected
def g(x, w):
    return (x @ w).sum()
comp = jax.jit(g, in_shardings=(sh("data", None), sh(None, "model"))).lower(
    jax.ShapeDtypeStruct((256, 512), jnp.float32),
    jax.ShapeDtypeStruct((512, 384), jnp.float32)).compile()
c = analyze_hlo(comp.as_text())
want = 2 * 256 * 512 * 384 / 8
assert abs(c.flops - want) / want < 0.01, (c.flops, want)
ca = comp.cost_analysis()
xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
assert abs(c.flops - xla) / xla < 0.05, (c.flops, xla)

# 2. scan x7: analyzer must multiply by the trip count
def f(x, w):
    def body(c_, _):
        return c_ @ w, ()
    y, _ = jax.lax.scan(body, x, None, length=7)
    return y.sum()
comp2 = jax.jit(f, in_shardings=(sh("data", None), sh(None, "model"))).lower(
    jax.ShapeDtypeStruct((256, 512), jnp.float32),
    jax.ShapeDtypeStruct((512, 512), jnp.float32)).compile()
c2 = analyze_hlo(comp2.as_text())
want2 = 7 * 2 * 256 * 512 * 512 / 8
assert abs(c2.flops - want2) / want2 < 0.01, (c2.flops, want2)
assert c2.coll.get("all-gather", 0) > 0   # in-loop collective counted

# 3. nested scans multiply
def h(x, w):
    def outer(c_, _):
        def inner(d_, __):
            return d_ @ w, ()
        e, _ = jax.lax.scan(inner, c_, None, length=3)
        return e, ()
    y, _ = jax.lax.scan(outer, x, None, length=5)
    return y.sum()
comp3 = jax.jit(h, in_shardings=(sh("data", None), sh(None, "model"))).lower(
    jax.ShapeDtypeStruct((256, 512), jnp.float32),
    jax.ShapeDtypeStruct((512, 512), jnp.float32)).compile()
c3 = analyze_hlo(comp3.as_text())
want3 = 15 * 2 * 256 * 512 * 512 / 8
assert abs(c3.flops - want3) / want3 < 0.01, (c3.flops, want3)
print("HLO_ANALYSIS_OK")
"""


def test_hlo_analyzer_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "HLO_ANALYSIS_OK" in r.stdout, r.stderr[-2000:]
