"""Pure-jnp reference oracles for the Pallas kernels.

These are the semantic ground truth. The Pallas kernels in this package must
produce bit-identical results (integer ops only) and are validated against
these in ``tests/test_kernels.py`` with ``interpret=True`` sweeps.

All arithmetic is 32-bit (TPU VPU native width). 64-bit signatures are
represented as pairs of uint32 lanes ``(hi, lo)``; a 128-bit row signature is
two such pairs. Packing into uint64 for host-side sorting happens in
``ops.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

import numpy as np

# murmur3 32-bit finalizer constants (np scalars: safe to use inside Pallas
# kernel bodies — they become inline literals, not captured jax constants)
_FMIX_C1 = np.uint32(0x85EBCA6B)
_FMIX_C2 = np.uint32(0xC2B2AE35)
# per-lane mixing constants (odd, from splitmix/murmur families)
_LANE_C1 = np.uint32(0x9E3779B1)  # golden ratio
_LANE_C2 = np.uint32(0x95D0BE4F)
_SEEDS = (
    0x2545F491,  # sig lane 0 (lo.lo)
    0x8C2E1B6D,  # sig lane 1 (lo.hi)
    0x64E6D3A5,  # sig lane 2 (hi.lo)
    0x5851F42D,  # sig lane 3 (hi.hi)
)


def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit avalanche finalizer (uint32 -> uint32)."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * _FMIX_C1
    h = h ^ (h >> 13)
    h = h * _FMIX_C2
    h = h ^ (h >> 16)
    return h


def rowhash(lanes: jnp.ndarray) -> jnp.ndarray:
    """Mix per-row uint32 lanes into a 128-bit signature.

    Args:
      lanes: (R, C) uint32. Each logical table column contributes two lanes
        (hi32, lo32) of its canonical 64-bit encoding; C = 2 * n_columns.

    Returns:
      (R, 4) uint32 — signature words [lo.lo, lo.hi, hi.lo, hi.hi].

    The mix must be order-sensitive in C (columns are positional per the
    paper's schema-equality requirement) and avalanche in every lane.
    """
    lanes = lanes.astype(jnp.uint32)
    r, c = lanes.shape
    out = []
    for s, seed in enumerate(_SEEDS):
        h = jnp.full((r,), np.uint32(seed), dtype=jnp.uint32)
        for j in range(c):
            x = lanes[:, j]
            # lane-position salt keeps permuted columns distinct
            salt = np.uint32(((j * 2 + 1) * 0x9E3779B1 + s * 0x7F4A7C15) & 0xFFFFFFFF)
            h = fmix32(h ^ (x * _LANE_C1 + salt))
            h = h * _LANE_C2 + np.uint32(1)
        out.append(fmix32(h ^ np.uint32(c)))
    return jnp.stack(out, axis=1)


def _cmp_lt(a_hi, a_lo, b_hi, b_lo):
    """64-bit '<' on (hi, lo) uint32 pairs."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def lower_bound(sorted_hi: jnp.ndarray, sorted_lo: jnp.ndarray,
                q_hi: jnp.ndarray, q_lo: jnp.ndarray) -> jnp.ndarray:
    """Branchless binary search: first index where sorted[i] >= q.

    Args:
      sorted_hi/lo: (N,) uint32 — table sorted ascending by (hi, lo).
      q_hi/lo: (Q,) uint32 — query keys.

    Returns:
      (Q,) int32 lower-bound indices in [0, N].
    """
    n = sorted_hi.shape[0]
    q = q_hi.shape[0]
    lo_idx = jnp.zeros((q,), dtype=jnp.int32)
    # number of iterations: ceil(log2(n+1)), static
    span = jnp.int32(n)
    it = max(1, int(n).bit_length())
    half = jnp.int32(n)
    for _ in range(it):
        half = (half + 1) // 2
        mid = jnp.minimum(lo_idx + half, jnp.int32(n)) - 1
        mid_c = jnp.clip(mid, 0, max(n - 1, 0))
        m_hi = sorted_hi[mid_c]
        m_lo = sorted_lo[mid_c]
        go_right = _cmp_lt(m_hi, m_lo, q_hi, q_lo) & (mid < n)
        lo_idx = jnp.where(go_right, mid + 1, lo_idx)
    return lo_idx


def diff_aggregate(key_w: jnp.ndarray, signs: jnp.ndarray,
                   prev_last: jnp.ndarray | None = None):
    """Diff aggregation over a sorted signed stream (the paper §5.1 operator).

    Args:
      key_w: (N, 4) uint32 — 128-bit keys, rows sorted ascending
        lexicographically by words [3],[2],[1],[0] (i.e. (hi,lo)).
      signs: (N,) int32 — +1 for rows of the right snapshot, -1 for left.
      prev_last: optional (4,) uint32 — key preceding row 0 (for block
        composition); None means row 0 always starts a run.

    Returns:
      boundary: (N,) bool — True where a new key-run starts.
      csum: (N,) int32 — inclusive cumulative sum of signs (global).
    """
    k = key_w.astype(jnp.uint32)
    if prev_last is None:
        prev = jnp.concatenate([jnp.zeros((1, 4), jnp.uint32), k[:-1]], axis=0)
        first = jnp.zeros((k.shape[0],), dtype=bool).at[0].set(True)
    else:
        prev = jnp.concatenate([prev_last.reshape(1, 4), k[:-1]], axis=0)
        first = jnp.zeros((k.shape[0],), dtype=bool)
    neq = jnp.any(k != prev, axis=1)
    boundary = first | neq
    csum = jnp.cumsum(signs.astype(jnp.int32), axis=0, dtype=jnp.int32)
    return boundary, csum
