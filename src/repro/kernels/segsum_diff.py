"""Pallas TPU kernel: the diff-aggregation operator (paper §5.1).

Input is a signed stream sorted by 128-bit key: rows from the right snapshot
carry sign +1, rows from the left snapshot carry -1. Identical changes on the
two sides must cancel. The kernel computes, per element:

  * ``boundary`` — True where a new key-run starts, and
  * ``csum``     — block-local inclusive cumulative sum of signs.

``ops.diff_aggregate`` composes blocks with a two-phase scan: the kernel
emits per-block partial sums, the (tiny) block-offset scan happens in jnp,
so the kernel stays embarrassingly parallel over the grid — this mirrors the
classic TPU segmented-scan decomposition rather than a sequential carry.

Boundary detection across block edges uses an explicitly passed
``prev_last`` row (the key preceding the block), avoiding overlapping
BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _segsum_kernel(keys_ref, prev_ref, signs_ref, bnd_ref, csum_ref, tot_ref):
    keys = keys_ref[...]          # (B, 4) uint32
    prev_last = prev_ref[...]     # (1, 4) uint32 — key before this block
    signs = signs_ref[...]        # (B,) int32
    prev = jnp.concatenate([prev_last, keys[:-1]], axis=0)
    bnd_ref[...] = jnp.any(keys != prev, axis=1)
    cs = jnp.cumsum(signs, axis=0, dtype=jnp.int32)
    csum_ref[...] = cs
    tot_ref[...] = cs[-1:]        # (1,) block total for the phase-2 scan


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segsum_pallas(keys: jnp.ndarray, prev_last: jnp.ndarray,
                  signs: jnp.ndarray, *, block: int = DEFAULT_BLOCK,
                  interpret: bool = False):
    """keys: (N, 4) uint32 sorted; prev_last: (nblocks, 4) uint32 with the key
    preceding each block (block 0 row = anything unequal to keys[0] or the
    caller marks boundary explicitly); signs: (N,) int32.

    Returns (boundary (N,) bool, csum_local (N,) int32, block_tot (nblocks,)
    int32)."""
    n = keys.shape[0]
    assert n % block == 0, (n, block)
    nblocks = n // block
    grid = (nblocks,)
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.bool_),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, prev_last, signs)
