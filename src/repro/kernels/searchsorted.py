"""Pallas TPU kernel: vectorized branchless lower-bound over sorted 64-bit keys.

Used for base-revision lookup and current-position probes during merge
(DESIGN.md §2): given an object's key-signature array (sorted at seal time),
find for each probe key the first index with table[i] >= key.

TPU adaptation of a pointer-chasing binary search: the whole sorted table
block lives in VMEM (objects are sealed at <= 256Ki rows -> 2 MiB of key
pairs), probes are tiled over the grid, and the search is a fixed-depth
(log2 N, static) sequence of masked gathers — no data-dependent control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 1024


def _cmp_lt(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _searchsorted_kernel(tab_hi_ref, tab_lo_ref, q_hi_ref, q_lo_ref, out_ref,
                         *, n_table: int):
    tab_hi = tab_hi_ref[...]
    tab_lo = tab_lo_ref[...]
    q_hi = q_hi_ref[...]
    q_lo = q_lo_ref[...]
    bq = q_hi.shape[0]
    lo_idx = jnp.zeros((bq,), dtype=jnp.int32)
    half = jnp.int32(n_table)
    for _ in range(max(1, int(n_table).bit_length())):  # static depth
        half = (half + 1) // 2
        mid = jnp.minimum(lo_idx + half, jnp.int32(n_table)) - 1
        mid_c = jnp.clip(mid, 0, max(n_table - 1, 0))
        m_hi = tab_hi[mid_c]
        m_lo = tab_lo[mid_c]
        go_right = _cmp_lt(m_hi, m_lo, q_hi, q_lo) & (mid < n_table)
        lo_idx = jnp.where(go_right, mid + 1, lo_idx)
    out_ref[...] = lo_idx


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def searchsorted_pallas(tab_hi: jnp.ndarray, tab_lo: jnp.ndarray,
                        q_hi: jnp.ndarray, q_lo: jnp.ndarray, *,
                        block_q: int = DEFAULT_BLOCK_Q,
                        interpret: bool = False) -> jnp.ndarray:
    """Lower-bound of each query in the sorted (hi, lo) table.

    tab_hi/tab_lo: (N,) uint32; q_hi/q_lo: (Q,) uint32, Q % block_q == 0.
    Returns (Q,) int32 indices in [0, N].
    """
    n = tab_hi.shape[0]
    q = q_hi.shape[0]
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    full_tab = pl.BlockSpec((n,), lambda i: (0,))
    per_q = pl.BlockSpec((block_q,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_searchsorted_kernel, n_table=n),
        grid=grid,
        in_specs=[full_tab, full_tab, per_q, per_q],
        out_specs=per_q,
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(tab_hi, tab_lo, q_hi, q_lo)
