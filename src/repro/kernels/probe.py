"""Pallas TPU kernel: fused 128-bit key probe (run start + run length).

One pass over the sorted ``(key_lo, key_hi)`` lanes of a sealed object
answers both questions the probe paths used to ask as a lower_bound /
upper_bound / segment_expand / reduceat chain: WHERE the query key's
equal-key run begins (its exact 128-bit lower bound — defined even for
misses) and HOW LONG that run is (0 == key absent).

TPU adaptation mirrors ``searchsorted.py``: the whole table block lives in
VMEM (objects seal at <= 256Ki rows -> 4 MiB of signature lanes), queries
tile over the grid, and BOTH bounds descend in one fixed-depth (log2 N,
static) sequence of masked gathers — the upper bound is a true 128-bit
descent, not the +1 trick the 64-bit kernel needs, so no sentinel guard.
Comparisons are lexicographic with the packed lo64 word primary (the seal
order; see ``ops.py``'s signature convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 1024


def _lt64(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _lt128(a, b):
    """a < b for 128-bit keys as (lo_hi32, lo_lo32, hi_hi32, hi_lo32)
    lane tuples; the packed lo64 word is the primary sort word."""
    a_lh, a_ll, a_hh, a_hl = a
    b_lh, b_ll, b_hh, b_hl = b
    lt_lo = _lt64(a_lh, a_ll, b_lh, b_ll)
    eq_lo = (a_lh == b_lh) & (a_ll == b_ll)
    return lt_lo | (eq_lo & _lt64(a_hh, a_hl, b_hh, b_hl))


def _probe_kernel(t_lh_ref, t_ll_ref, t_hh_ref, t_hl_ref,
                  q_lh_ref, q_ll_ref, q_hh_ref, q_hl_ref,
                  start_ref, cnt_ref, *, n_table: int):
    tab = (t_lh_ref[...], t_ll_ref[...], t_hh_ref[...], t_hl_ref[...])
    q = (q_lh_ref[...], q_ll_ref[...], q_hh_ref[...], q_hl_ref[...])
    bq = q[0].shape[0]
    lb = jnp.zeros((bq,), jnp.int32)
    ub = jnp.zeros((bq,), jnp.int32)
    half = jnp.int32(n_table)
    for _ in range(max(1, int(n_table).bit_length())):  # static depth
        half = (half + 1) // 2
        # lower bound: first i with tab[i] >= q  (go right while tab < q)
        mid = jnp.minimum(lb + half, jnp.int32(n_table)) - 1
        mid_c = jnp.clip(mid, 0, max(n_table - 1, 0))
        t_mid = tuple(lane[mid_c] for lane in tab)
        go = _lt128(t_mid, q) & (mid < n_table)
        lb = jnp.where(go, mid + 1, lb)
        # upper bound: first i with tab[i] > q  (go right while tab <= q)
        mid2 = jnp.minimum(ub + half, jnp.int32(n_table)) - 1
        mid2_c = jnp.clip(mid2, 0, max(n_table - 1, 0))
        t_mid2 = tuple(lane[mid2_c] for lane in tab)
        go2 = (~_lt128(q, t_mid2)) & (mid2 < n_table)
        ub = jnp.where(go2, mid2 + 1, ub)
    start_ref[...] = lb
    cnt_ref[...] = ub - lb


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def probe_pallas(t_lh: jnp.ndarray, t_ll: jnp.ndarray,
                 t_hh: jnp.ndarray, t_hl: jnp.ndarray,
                 q_lh: jnp.ndarray, q_ll: jnp.ndarray,
                 q_hh: jnp.ndarray, q_hl: jnp.ndarray, *,
                 block_q: int = DEFAULT_BLOCK_Q,
                 interpret: bool = False):
    """Fused (run start, run length) probe of each 128-bit query key.

    t_*/q_*: (N,)/(Q,) uint32 lanes as (lo_hi32, lo_lo32, hi_hi32,
    hi_lo32); Q % block_q == 0. Returns ((Q,) int32 start in [0, N],
    (Q,) int32 count >= 0).
    """
    n = t_lh.shape[0]
    q = q_lh.shape[0]
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    full_tab = pl.BlockSpec((n,), lambda i: (0,))
    per_q = pl.BlockSpec((block_q,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_probe_kernel, n_table=n),
        grid=grid,
        in_specs=[full_tab] * 4 + [per_q] * 4,
        out_specs=(per_q, per_q),
        out_shape=(jax.ShapeDtypeStruct((q,), jnp.int32),
                   jax.ShapeDtypeStruct((q,), jnp.int32)),
        interpret=interpret,
    )(t_lh, t_ll, t_hh, t_hl, q_lh, q_ll, q_hh, q_hl)
