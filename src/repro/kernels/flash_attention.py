"""Pallas TPU kernel: flash attention (forward).

This is the §Perf "next lever" for the memory-bound prefill/train cells:
the XLA block-causal attention (models/layers.py) materializes every
(bq, bk) score tile in HBM-visible buffers, which dominates the dot-stream
bytes of the 32k-prefill cells. The Pallas kernel keeps q/k/v tiles and the
online-softmax state in VMEM across the innermost (sequential) grid
dimension, so per-tile scores never leave the core.

Grid: (B*H, n_q_blocks, n_k_blocks) — the last dim is sequential on TPU, so
VMEM scratch (m, l, acc) carries across k-blocks of one q-block. Causal
pairs with ki > qi are masked (pl.when skips their compute).

Used on real TPU via ``ops.attention(..., impl="pallas")``; the CPU dry-run
keeps the XLA path so the HLO cost model stays meaningful (a custom call
reports no FLOPs). Validated against ``ref.attention_ref`` in interpret
mode (tests/test_kernels.py) over shape/dtype/causality sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k_blocks: int, seq_k_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0]                     # (bq, hd)
        k = k_ref[0]                     # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=F32) * scale          # (bq, bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k_valid
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            mask &= qpos >= kpos
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=F32)
        m_ref[...] = m_new

    if causal:
        # blocks strictly above the diagonal contribute nothing
        pl.when(ki <= qi)(_compute)
    else:
        _compute()

    @pl.when(ki == n_k_blocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False):
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) — heads pre-flattened (GQA handled
    by the ops.py wrapper). Sq % block_q == 0; Sk padded here if needed."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0, (sq, block_q)
    seq_k_valid = sk
    pad = (-sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        sk += pad
    n_q, n_k = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k_blocks=n_k, seq_k_valid=seq_k_valid)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), F32),    # m: running max
            pltpu.VMEM((block_q, 1), F32),    # l: running denominator
            pltpu.VMEM((block_q, hd), F32),   # acc: running numerator
        ],
        interpret=interpret,
    )(q, k, v)
