"""Pallas TPU kernel: 128-bit row signatures from uint32 column lanes.

This is the paper's §5.5.5 signature idea promoted to the universal row
identity (DESIGN.md §2): every diff/merge inner loop operates on signatures,
so signature computation is on the critical path of every version-control
operation and is the most bandwidth-hungry elementwise op in the system.

TPU adaptation: all arithmetic is uint32 (VPU native); rows are tiled into
VMEM blocks of ``block_rows`` and the C lane columns are unrolled inside the
kernel (C is a compile-time constant, = 2 * n_table_columns).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_ROWS = 1024  # 1024 rows x C lanes x 4B; C<=32 -> <=128KiB in VMEM


def _rowhash_kernel(lanes_ref, out_ref, *, n_lanes: int):
    """One VMEM tile: (BR, C) uint32 lanes -> (BR, 4) uint32 signature words."""
    import numpy as np
    lanes = lanes_ref[...]
    br = lanes.shape[0]
    outs = []
    for s, seed in enumerate(ref._SEEDS):
        h = jnp.full((br,), np.uint32(seed), dtype=jnp.uint32)
        for j in range(n_lanes):  # unrolled: n_lanes is static
            x = lanes[:, j]
            salt = np.uint32(((j * 2 + 1) * 0x9E3779B1 + s * 0x7F4A7C15) & 0xFFFFFFFF)
            h = ref.fmix32(h ^ (x * ref._LANE_C1 + salt))
            h = h * ref._LANE_C2 + np.uint32(1)
        outs.append(ref.fmix32(h ^ np.uint32(n_lanes)))
    out_ref[...] = jnp.stack(outs, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rowhash_pallas(lanes: jnp.ndarray, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False) -> jnp.ndarray:
    """(R, C) uint32 -> (R, 4) uint32 signatures. R must be a multiple of
    ``block_rows`` (ops.py pads with sentinel rows)."""
    r, c = lanes.shape
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    return pl.pallas_call(
        functools.partial(_rowhash_kernel, n_lanes=c),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 4), jnp.uint32),
        interpret=interpret,
    )(lanes)
