"""Pallas TPU kernels for the version-control hot paths + jit'd wrappers.

Kernels (each <name>.py has the pl.pallas_call + BlockSpec tiling, ref.py has
the pure-jnp oracle, ops.py the dispatching wrappers):

  * rowhash         — 128-bit row/key signatures from uint32 column lanes.
  * searchsorted    — branchless vectorized lower-bound probes.
  * segsum_diff     — the diff-aggregation operator (boundary + signed scan).
  * flash_attention — online-softmax attention with VMEM-resident tiles
                      (the model-side hot spot; ops.attention dispatches).
"""
from . import ops, ref  # noqa: F401
