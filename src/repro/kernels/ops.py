"""Jit'd wrappers + backend dispatch for the VCS kernels.

The version-control engine (``repro.core``) calls these three ops on its hot
paths. On a TPU backend they run the Pallas kernels (``rowhash.py``,
``searchsorted.py``, ``segsum_diff.py``); on CPU they run semantically
identical vectorized fast paths (numpy / the pure-jnp oracle in ``ref.py``)
so that benchmarks on this container measure algorithmic behaviour, not
Pallas interpret-mode overhead. Setting ``FORCE_PALLAS_INTERPRET = True``
routes everything through the Pallas kernels in interpret mode (used by
tests to exercise the real kernels end-to-end).

Signature convention: a 64-bit word is carried host-side as numpy uint64;
kernels see it as (hi32, lo32) uint32 lanes. A row signature is 128 bits =
two uint64 words (lo64, hi64); sorting is lexicographic by (hi64, lo64) --
but since the words are uniformly mixed, we sort by the single packed lo64
word and resolve the rare lo64 collisions with the hi64 word at run level.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import ref
from .probe import probe_pallas, DEFAULT_BLOCK_Q as PROBE_BLOCK_Q
from .rowhash import rowhash_pallas, DEFAULT_BLOCK_ROWS
from .searchsorted import searchsorted_pallas, DEFAULT_BLOCK_Q
from .segsum_diff import segsum_pallas, DEFAULT_BLOCK

# Toggled by tests; on a real TPU backend the pallas path is the default.
FORCE_PALLAS_INTERPRET = False


def backend_uses_pallas() -> bool:
    return FORCE_PALLAS_INTERPRET or jax.default_backend() == "tpu"


def _interp() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- packing

def pack64(hi32: np.ndarray, lo32: np.ndarray) -> np.ndarray:
    return (hi32.astype(np.uint64) << np.uint64(32)) | lo32.astype(np.uint64)


def unpack64(w: np.ndarray):
    w = w.astype(np.uint64)
    return (w >> np.uint64(32)).astype(np.uint32), (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _pad_rows(a: np.ndarray, mult: int, fill=0) -> np.ndarray:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    padding = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, padding, constant_values=fill)


# ---------------------------------------------------------------- rowhash

def rowhash(lanes_u32: np.ndarray) -> np.ndarray:
    """(R, C) uint32 lanes -> (R, 4) uint32 signature words."""
    r = lanes_u32.shape[0]
    if r == 0:
        return np.zeros((0, 4), np.uint32)
    if backend_uses_pallas():
        padded = _pad_rows(np.asarray(lanes_u32, np.uint32), DEFAULT_BLOCK_ROWS)
        out = rowhash_pallas(jnp.asarray(padded), interpret=_interp())
        return np.asarray(out)[:r]
    # CPU fast path: identical math in numpy (wrapping uint32).
    return _rowhash_np(np.asarray(lanes_u32, np.uint32))


def _fmix32_np(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32)
    with np.errstate(over="ignore"):
        h ^= h >> np.uint32(16)
        h = (h * np.uint32(0x85EBCA6B)).astype(np.uint32)
        h ^= h >> np.uint32(13)
        h = (h * np.uint32(0xC2B2AE35)).astype(np.uint32)
        h ^= h >> np.uint32(16)
    return h


def _rowhash_np(lanes: np.ndarray) -> np.ndarray:
    r, c = lanes.shape
    seeds = [np.uint32(int(s)) for s in ref._SEEDS]
    out = np.empty((r, 4), np.uint32)
    with np.errstate(over="ignore"):
        for s, seed in enumerate(seeds):
            h = np.full((r,), seed, np.uint32)
            for j in range(c):
                x = lanes[:, j]
                salt = np.uint32(((j * 2 + 1) * 0x9E3779B1 + s * 0x7F4A7C15) & 0xFFFFFFFF)
                h = _fmix32_np(h ^ (x * np.uint32(0x9E3779B1) + salt).astype(np.uint32))
                h = (h * np.uint32(0x95D0BE4F) + np.uint32(1)).astype(np.uint32)
            out[:, s] = _fmix32_np(h ^ np.uint32(c))
    return out


def signatures_from_lanes(lanes_u32: np.ndarray):
    """(R, C) uint32 -> (sig_lo (R,) uint64, sig_hi (R,) uint64)."""
    w = rowhash(lanes_u32)
    lo = pack64(w[:, 1], w[:, 0])
    hi = pack64(w[:, 3], w[:, 2])
    return lo, hi


# ------------------------------------------------------------ lower bound

def lower_bound(sorted_u64: np.ndarray, queries_u64: np.ndarray) -> np.ndarray:
    """First index i with sorted[i] >= q, per query. Returns int64 indices."""
    if queries_u64.shape[0] == 0 or sorted_u64.shape[0] == 0:
        return np.zeros(queries_u64.shape, np.int64)
    if backend_uses_pallas():
        t_hi, t_lo = unpack64(np.asarray(sorted_u64))
        q_hi, q_lo = unpack64(_pad_rows(np.asarray(queries_u64), DEFAULT_BLOCK_Q,
                                        fill=np.uint64(0)))
        idx = searchsorted_pallas(jnp.asarray(t_hi), jnp.asarray(t_lo),
                                  jnp.asarray(q_hi), jnp.asarray(q_lo),
                                  interpret=_interp())
        return np.asarray(idx[: queries_u64.shape[0]], np.int64)
    return np.searchsorted(sorted_u64, queries_u64, side="left").astype(np.int64)


def upper_bound(sorted_u64: np.ndarray, queries_u64: np.ndarray) -> np.ndarray:
    """First index i with sorted[i] > q, per query. Returns int64 indices.

    Served by the same searchsorted kernel: ub(q) == lb(q + 1) for any q
    below the uint64 maximum (equal-key runs are what the probe paths
    resolve vectorized with lb/ub pairs)."""
    if queries_u64.shape[0] == 0 or sorted_u64.shape[0] == 0:
        return np.zeros(queries_u64.shape, np.int64)
    if backend_uses_pallas():
        q = np.asarray(queries_u64, np.uint64)
        with np.errstate(over="ignore"):
            idx = lower_bound(sorted_u64, q + np.uint64(1))
        return np.where(q == np.uint64(0xFFFFFFFFFFFFFFFF),
                        np.int64(sorted_u64.shape[0]), idx)
    return np.searchsorted(sorted_u64, queries_u64, side="right").astype(np.int64)


def searchsorted128(t_lo: np.ndarray, t_hi: np.ndarray,
                    q_lo: np.ndarray, q_hi: np.ndarray,
                    side: str = "left") -> np.ndarray:
    """Exact 128-bit searchsorted against a stream sorted by (lo, hi).

    Primary ranks come from the 64-bit searchsorted kernel on the lo word.
    Queries whose lo word exists in the table — the COMMON case for the
    merge-join and rank-sum callers, where most queries are exact key
    matches — refine against the hi word in one vectorized gather+compare
    (the table run has length 1 for distinct hashed signatures); only
    genuine lo64 collisions (run length > 1) pay a scalar bisect."""
    n = t_lo.shape[0]
    if q_lo.shape[0] == 0 or n == 0:
        return np.zeros(q_lo.shape, np.int64)
    lb = lower_bound(t_lo, q_lo)
    out = lb.copy()
    hit = (lb < n) & (t_lo[np.minimum(lb, n - 1)] == q_lo)
    # the matched run extends past lb only on a genuine lo64 collision
    multi = hit & (lb + 1 < n) & (t_lo[np.minimum(lb + 1, n - 1)] == q_lo)
    one = hit & ~multi
    if one.any():
        idx = lb[one]
        after = (t_hi[idx] < q_hi[one] if side == "left"
                 else t_hi[idx] <= q_hi[one])
        out[one] = idx + after
    midx = np.flatnonzero(multi)
    if midx.shape[0]:
        ub = upper_bound(t_lo, q_lo[midx])
        for j, i in enumerate(midx):
            s, e = int(lb[i]), int(ub[j])
            out[i] = s + int(np.searchsorted(t_hi[s:e], q_hi[i], side=side))
    return out


def probe128(t_lo: np.ndarray, t_hi: np.ndarray,
             q_lo: np.ndarray, q_hi: np.ndarray):
    """Fused probe of a (lo, hi)-key-sorted table: per query key, the exact
    128-bit lower bound (``start``) and the equal-key run length (``cnt``,
    0 == key absent). ``start`` is defined for misses too — it is where the
    key WOULD insert — so the contract is total and backend-independent.

    This one call replaces the probe paths' lower_bound → key-compare →
    upper_bound → segment_expand → reduceat chain: the run of rows exactly
    equal to the query is ``[start, start + cnt)``, contiguous because
    sealed objects sort by (lo, hi). Queries SHOULD arrive sorted by
    (lo, hi) — correctness never depends on it, but the kernel's per-block
    descents and the CPU searchsorted both degrade on shuffled batches
    (documented probe contract, ROADMAP §Performance).

    Backend dispatch: on Pallas both bounds come out of one fused
    fixed-depth kernel descent over the four uint32 lanes; on CPU one lo64
    searchsorted resolves every query whose lo64 run has length 1 (the
    common case for hashed keys) and only genuine lo64 collisions pay the
    vectorized hi-word refinement."""
    n = t_lo.shape[0]
    nq = q_lo.shape[0]
    if nq == 0 or n == 0:
        return np.zeros((nq,), np.int64), np.zeros((nq,), np.int64)
    if backend_uses_pallas():
        t_lh, t_ll = unpack64(np.asarray(t_lo))
        t_hh, t_hl = unpack64(np.asarray(t_hi))
        q_lh, q_ll = unpack64(_pad_rows(np.asarray(q_lo), PROBE_BLOCK_Q,
                                        fill=np.uint64(0)))
        q_hh, q_hl = unpack64(_pad_rows(np.asarray(q_hi), PROBE_BLOCK_Q,
                                        fill=np.uint64(0)))
        start, cnt = probe_pallas(
            jnp.asarray(t_lh), jnp.asarray(t_ll),
            jnp.asarray(t_hh), jnp.asarray(t_hl),
            jnp.asarray(q_lh), jnp.asarray(q_ll),
            jnp.asarray(q_hh), jnp.asarray(q_hl), interpret=_interp())
        return (np.asarray(start[:nq], np.int64),
                np.asarray(cnt[:nq], np.int64))
    # CPU fused fast path: one primary-word searchsorted for everything
    lb = np.searchsorted(t_lo, q_lo, side="left").astype(np.int64)
    start = lb.copy()
    cnt = np.zeros((nq,), np.int64)
    idx = np.minimum(lb, n - 1)
    hit = (lb < n) & (t_lo[idx] == q_lo)
    if not hit.any():
        return start, cnt
    # the lo64 run extends past lb only on a genuine lo64 collision
    multi = hit & (lb + 1 < n) & (t_lo[np.minimum(lb + 1, n - 1)] == q_lo)
    one = hit & ~multi
    if one.any():
        i1 = lb[one]
        start[one] = i1 + (t_hi[i1] < q_hi[one])
        cnt[one] = (t_hi[i1] == q_hi[one]).astype(np.int64)
    midx = np.flatnonzero(multi)
    if midx.shape[0]:
        ub = np.searchsorted(t_lo, q_lo[midx], side="right").astype(np.int64)
        seg, base, flat = segment_expand(lb[midx], ub - lb[midx])
        t_run, q_seg = t_hi[flat], q_hi[midx][seg]
        start[midx] = lb[midx] + np.add.reduceat(
            (t_run < q_seg).astype(np.int64), base)
        cnt[midx] = np.add.reduceat((t_run == q_seg).astype(np.int64), base)
    return start, cnt


def segment_expand(starts: np.ndarray, lens: np.ndarray):
    """Expand per-segment (start, len) pairs into flat element indices.

    Returns (seg, base, flat): ``seg[j]`` is the segment owning flat slot j,
    ``base[i]`` the first flat slot of segment i (valid reduceat offsets when
    every ``lens[i] > 0``), and ``flat[j]`` the source index — i.e. segment
    ``seg[j]`` contributes ``starts[i] .. starts[i]+lens[i]-1`` in order.
    Callers must pre-filter zero-length segments."""
    total = int(lens.sum())
    seg = np.repeat(np.arange(lens.shape[0]), lens)
    base = np.concatenate([[0], np.cumsum(lens)[:-1]])
    flat = starts[seg] + (np.arange(total, dtype=np.int64) - base[seg])
    return seg, base, flat


# --------------------------------------------------------- diff aggregate

class DiffAgg:
    """Result of diff aggregation over a sorted signed stream.

    Attributes:
      boundary:   (N,) bool  — new-run start flags.
      run_starts: (K,) int64 — index of each run's first element.
      run_lens:   (K,) int64
      run_sums:   (K,) int32 — net sign per run (0 == fully cancelled).
      run_ids:    (N,) int64 — run index per element (computed lazily).
    """

    __slots__ = ("boundary", "run_starts", "_n", "_run_lens", "run_sums",
                 "_run_ids")

    def __init__(self, boundary, signs):
        boundary = np.asarray(boundary, bool)
        signs = np.asarray(signs, np.int32)
        self.boundary = boundary
        self.run_starts = np.flatnonzero(boundary).astype(np.int64)
        n = boundary.shape[0]
        self._n = n
        if n:
            # net sign per run via one cumsum + end-point differences
            # (faster than add.reduceat when runs are short, the Δ-stream
            # common case)
            cs = np.cumsum(signs, dtype=np.int64)
            ends = np.append(self.run_starts[1:], n)
            sums = cs[ends - 1]
            sums[1:] -= cs[self.run_starts[1:] - 1]
            self.run_sums = sums.astype(np.int32)
        else:
            self.run_sums = np.zeros((0,), np.int32)
        self._run_lens = None
        self._run_ids = None

    @property
    def run_lens(self) -> np.ndarray:
        if self._run_lens is None:
            ends = np.append(self.run_starts[1:], self._n)
            self._run_lens = ends - self.run_starts
        return self._run_lens

    @property
    def run_ids(self) -> np.ndarray:
        if self._run_ids is None:
            self._run_ids = np.cumsum(self.boundary).astype(np.int64) - 1
        return self._run_ids


_RADIX_MIN_N = 1 << 15


def _radix16_argsort(a: np.ndarray) -> np.ndarray:
    """Stable LSD radix argsort of uint64 in four 16-bit passes.

    numpy's stable sort on uint16 keys IS a radix sort, so each pass is
    O(n); on unstructured uint64 input this beats the 64-bit stable sort
    (timsort) ~2x at Δ-pipeline sizes."""
    # lint: sort-ok this IS the sort kernel — radix passes are its body
    order = np.argsort((a & np.uint64(0xFFFF)).astype(np.uint16),
                       kind="stable")
    for shift in (16, 32, 48):
        d = ((a[order] >> np.uint64(shift)) & np.uint64(0xFFFF)
             ).astype(np.uint16)
        # lint: sort-ok this IS the sort kernel — radix passes are its body
        order = order[np.argsort(d, kind="stable")]
    return order


def _argsort64_stable(a: np.ndarray) -> np.ndarray:
    """Stable uint64 argsort with a bucket/radix pre-pass decision.

    Presorted-run-structured input (the Δ pipeline's emission order) is
    near-linear under timsort's galloping merge; unstructured input is ~2x
    faster under 16-bit LSD radix. One O(n) descent count picks the path."""
    n = a.shape[0]
    if n >= _RADIX_MIN_N:
        descents = int(np.count_nonzero(a[1:] < a[:-1]))
        if descents > (n >> 6):
            return _radix16_argsort(a)
    return np.argsort(a, kind="stable")  # lint: sort-ok the kernel itself


def _sort128(sig_lo: np.ndarray, sig_hi: np.ndarray, *,
             stable: bool = True) -> np.ndarray:
    """Lexicographic argsort by (sig_lo, sig_hi), stable by default.

    Equivalent to ``np.lexsort((sig_hi, sig_lo))`` but faster: one argsort
    on the primary word (radix/run-aware when stable, introsort when the
    caller's signatures are known distinct and stability is moot), then an
    exact refinement of the (vanishingly rare for hashed sigs) equal-lo
    runs whose hi words are out of order."""
    # lint: sort-ok _sort128 is the one blessed 128-bit sort entry point
    order = _argsort64_stable(sig_lo) if stable else np.argsort(sig_lo)
    lo_s = sig_lo[order]
    dup = np.flatnonzero(lo_s[1:] == lo_s[:-1])
    if dup.shape[0]:
        hi_s = sig_hi[order]
        bad = dup[hi_s[dup + 1] < hi_s[dup]]
        if bad.shape[0]:
            # collision runs whose hi words are out of order: stable-sort
            # each such equal-lo slice by hi, each exactly once
            n = lo_s.shape[0]
            neq = np.empty((n,), bool)
            neq[0] = True
            neq[1:] = lo_s[1:] != lo_s[:-1]
            starts = np.flatnonzero(neq)
            ends = np.append(starts[1:], n)
            rid = np.searchsorted(starts, bad, side="right") - 1
            # lint: sort-ok hash-collision refinement — runs are a handful
            # of rows, reached only when equal-lo sigs are out of hi order
            for ri in np.unique(rid):
                s, e = int(starts[ri]), int(ends[ri])
                # lint: sort-ok hash-collision refinement (see above)
                order[s:e] = order[s:e][np.argsort(hi_s[s:e], kind="stable")]
    return order.astype(np.int64)


def merge128_runs(lo: np.ndarray, hi: np.ndarray,
                  starts: np.ndarray, *, cuts=None) -> np.ndarray:
    """Stable merge permutation for concatenated presorted runs.

    ``starts`` (k,) int64 holds each run's first offset (``starts[0] == 0``);
    run i spans ``[starts[i], starts[i+1])`` and is sorted by (lo, hi).
    Returns ``order`` such that ``lo[order], hi[order]`` is the stable k-way
    merge — identical to ``np.lexsort((hi, lo))`` on the whole stream (ties
    resolved by run order, then in-run position).

    ``cuts`` (optional) is a key-range shard plan from
    ``distributed.sharding.plan_key_cuts``: a (cut_lo, cut_hi) pair of
    ascending distinct 128-bit boundary keys. When given, the merge runs
    per key-range shard and concatenates — byte-identical to the unsharded
    merge (see ``_merge128_sharded``), so multi-device backends can split
    by key range and CPU gets cache-sized partitions for free.

    Backend dispatch: on the Pallas backend the runs are merged by
    searchsorted rank-sums (k passes of the searchsorted kernel, no sort at
    all); on CPU the run-aware stable argsort is measurably faster (timsort's
    galloping merge on run-structured input: ~4ms vs ~40ms per 200k rows x 9
    runs), so the rank-sum path is reserved for the kernel backend."""
    n = lo.shape[0]
    starts = np.asarray(starts, np.int64)
    if n == 0 or starts.shape[0] <= 1:
        return np.arange(n, dtype=np.int64)
    if cuts is not None and cuts[0].shape[0]:
        return _merge128_sharded(lo, hi, starts, cuts)
    if backend_uses_pallas() and starts.shape[0] <= 64:
        return _merge128_ranksum(lo, hi, starts)
    return _sort128(lo, hi)


def _merge128_sharded(lo: np.ndarray, hi: np.ndarray, starts: np.ndarray,
                      cuts) -> np.ndarray:
    """Key-range-sharded stable k-way merge, byte-identical to unsharded.

    Every run is split at the exact 128-bit LOWER bound of each cut key —
    the same rule in every run — so all elements with keys equal to a
    boundary land in the shard that begins at that boundary and equal keys
    never straddle shards. Each shard is then a self-contained stable
    k-way merge (run order and in-run position restricted to the shard are
    exactly the global tie-break restricted to the shard), so per-shard
    merges concatenated in cut order reproduce the global stable merge
    permutation element for element."""
    cut_lo, cut_hi = cuts
    n = lo.shape[0]
    k = starts.shape[0]
    s = cut_lo.shape[0] + 1
    bounds = np.append(starts, n)
    split = np.empty((k, s + 1), np.int64)
    for r in range(k):
        a, b = int(bounds[r]), int(bounds[r + 1])
        split[r, 0], split[r, s] = a, b
        split[r, 1:s] = a + searchsorted128(lo[a:b], hi[a:b],
                                            cut_lo, cut_hi, side="left")
    parts = []
    for j in range(s):
        gidx, run_starts, off = [], [], 0
        for r in range(k):
            a, b = int(split[r, j]), int(split[r, j + 1])
            if b > a:
                run_starts.append(off)
                off += b - a
                gidx.append(np.arange(a, b, dtype=np.int64))
        if not gidx:
            continue
        piece = gidx[0] if len(gidx) == 1 else np.concatenate(gidx)
        if len(run_starts) > 1:
            sub = merge128_runs(lo[piece], hi[piece],
                                np.asarray(run_starts, np.int64))
            piece = piece[sub]
        parts.append(piece)
    if not parts:
        return np.zeros((0,), np.int64)
    return parts[0] if len(parts) == 1 else np.concatenate(parts)


def _merge128_ranksum(lo: np.ndarray, hi: np.ndarray,
                      starts: np.ndarray) -> np.ndarray:
    """k-way merge by rank sums: each element's merged position is its
    in-run rank plus, per other run, the count of elements that must precede
    it (strictly-less, or less-or-equal for earlier runs — that tie-break
    makes the merge stable)."""
    n = lo.shape[0]
    bounds = np.append(starts, n)
    k = starts.shape[0]
    dest = np.empty((n,), np.int64)
    for r in range(k):
        s, e = int(bounds[r]), int(bounds[r + 1])
        d = np.arange(e - s, dtype=np.int64)
        for q in range(k):
            if q == r:
                continue
            qs, qe = int(bounds[q]), int(bounds[q + 1])
            d += searchsorted128(lo[qs:qe], hi[qs:qe], lo[s:e], hi[s:e],
                                 side="right" if q < r else "left")
        dest[s:e] = d
    order = np.empty((n,), np.int64)
    order[dest] = np.arange(n, dtype=np.int64)
    return order


def _shard_slices(s_lo: np.ndarray, s_hi: np.ndarray,
                  shards: int) -> np.ndarray:
    """Slice starts for a key-range-sharded boundary pass over a SORTED
    stream: equal-width candidate positions snapped to the START of the
    equal-key run containing them, so no run straddles a slice and every
    slice's first element begins a fresh run — per-slice boundary flags
    are then globally correct by construction. Returns the interior slice
    starts (ascending, distinct, possibly empty)."""
    n = s_lo.shape[0]
    pos = (np.arange(1, shards, dtype=np.int64) * n) // shards
    aligned = searchsorted128(s_lo, s_hi, s_lo[pos], s_hi[pos], side="left")
    # keys at ascending positions are non-decreasing, so aligned is too:
    # dedupe by adjacent-distinct (no sort) and drop degenerate 0 starts
    aligned = aligned[aligned > 0]
    if aligned.shape[0] > 1:
        keep = np.empty(aligned.shape, bool)
        keep[0] = True
        keep[1:] = aligned[1:] != aligned[:-1]
        aligned = aligned[keep]
    return aligned


def _boundary_flags(s_lo: np.ndarray, s_hi: np.ndarray,
                    s_sg: np.ndarray) -> np.ndarray:
    """New-run boundary flags of one sorted slice (backend dispatch)."""
    if backend_uses_pallas():
        return _segsum_boundary(s_lo, s_hi, s_sg)
    n = s_lo.shape[0]
    neq = np.empty((n,), bool)
    neq[0] = True
    neq[1:] = (s_lo[1:] != s_lo[:-1]) | (s_hi[1:] != s_hi[:-1])
    return neq


def diff_aggregate(sig_lo: np.ndarray, sig_hi: np.ndarray,
                   signs: np.ndarray, *, presorted: bool = False,
                   shards: int = 1):
    """Sort a signed stream by 128-bit signature and aggregate runs.

    Returns (order, DiffAgg): ``order`` is the permutation applied (identity
    if presorted). Runs are maximal groups of equal (sig_lo, sig_hi).

    ``shards > 1`` partitions the boundary pass into key-range slices
    aligned to run starts (``_shard_slices``) — byte-identical flags,
    embarrassingly parallel per slice. Only meaningful with ``presorted``
    (an unsorted stream pays the sort first and shards nothing).
    """
    n = sig_lo.shape[0]
    if n == 0:
        return np.zeros((0,), np.int64), DiffAgg(np.zeros((0,), bool), np.zeros((0,), np.int32))
    if presorted:
        order = np.arange(n, dtype=np.int64)
        s_lo, s_hi, s_sg = sig_lo, sig_hi, np.asarray(signs, np.int32)
    else:
        order = _sort128(sig_lo, sig_hi)
        s_lo, s_hi = sig_lo[order], sig_hi[order]
        s_sg = np.asarray(signs, np.int32)[order]

    if presorted and shards > 1 and n > shards:
        starts = _shard_slices(s_lo, s_hi, shards)
        if starts.shape[0]:
            bnd = np.empty((n,), bool)
            edges = np.concatenate([[0], starts, [n]])
            for a, b in zip(edges[:-1], edges[1:]):
                bnd[a:b] = _boundary_flags(s_lo[a:b], s_hi[a:b], s_sg[a:b])
            return order, DiffAgg(bnd, s_sg)

    return order, DiffAgg(_boundary_flags(s_lo, s_hi, s_sg), s_sg)


def _segsum_boundary(s_lo: np.ndarray, s_hi: np.ndarray,
                     s_sg: np.ndarray) -> np.ndarray:
    """New-run boundary flags of a sorted stream via the segsum kernel."""
    n = s_lo.shape[0]
    lo_hi32, lo_lo32 = unpack64(s_lo)
    hi_hi32, hi_lo32 = unpack64(s_hi)
    keys = np.stack([lo_lo32, lo_hi32, hi_lo32, hi_hi32], axis=1)
    keys_p = _pad_rows(keys, DEFAULT_BLOCK, fill=np.uint32(0xFFFFFFFF))
    sg_p = _pad_rows(s_sg, DEFAULT_BLOCK)
    nblocks = keys_p.shape[0] // DEFAULT_BLOCK
    prev_last = np.empty((nblocks, 4), np.uint32)
    prev_last[0] = np.uint32(0xFFFFFFFF)  # forces boundary at row 0 unless
    # keys[0] == all-ones sentinel; patched below.
    if nblocks > 1:
        prev_last[1:] = keys_p[np.arange(1, nblocks) * DEFAULT_BLOCK - 1]
    bnd, _csum, _tot = segsum_pallas(jnp.asarray(keys_p),
                                     jnp.asarray(prev_last),
                                     jnp.asarray(sg_p), interpret=_interp())
    bnd = np.array(bnd[:n])  # copy: jax buffers are read-only
    bnd[0] = True
    return bnd


def _boundary_flags_rows(k_lo, k_hi, r_lo, r_hi, s_sg,
                         same: bool) -> np.ndarray:
    """(key OR row)-change boundary flags of one key-sorted slice."""
    if backend_uses_pallas():
        bnd = _segsum_boundary(k_lo, k_hi, s_sg)
        if not same:
            bnd |= _segsum_boundary(r_lo, r_hi, s_sg)
        return bnd
    n = k_lo.shape[0]
    neq = np.empty((n,), bool)
    neq[0] = True
    neq[1:] = (k_lo[1:] != k_lo[:-1]) | (k_hi[1:] != k_hi[:-1])
    if not same:
        neq[1:] |= (r_lo[1:] != r_lo[:-1]) | (r_hi[1:] != r_hi[:-1])
    return neq


def diff_aggregate_rows(key_lo: np.ndarray, key_hi: np.ndarray,
                        row_lo: np.ndarray, row_hi: np.ndarray,
                        signs: np.ndarray, *, presorted: bool = False,
                        shards: int = 1):
    """Aggregate a signed stream into (key, row-signature) runs along KEY
    order — the sort-free execution of Listing-2 value grouping.

    The stream must be (or is stably made) sorted by (key_lo, key_hi); runs
    are maximal groups of equal (key, row). For NoPK streams key == row, so
    this is exactly value-group aggregation; for PK streams each run is a
    sub-group of one key's (≤ 2-element, by PK uniqueness) run, so
    equal-valued ± pairs cancel exactly as the row-sorted aggregation would,
    while the key order itself is free at emission time.

    ``shards > 1`` partitions the boundary pass into key-range slices
    aligned to KEY-run starts — a key-run start is also a (key, row) group
    start, so the per-slice flags are globally correct and byte-identical
    to the unsharded pass. Only meaningful with ``presorted``.

    Returns (order, DiffAgg); ``order`` is identity when presorted.
    """
    n = key_lo.shape[0]
    if n == 0:
        return (np.zeros((0,), np.int64),
                DiffAgg(np.zeros((0,), bool), np.zeros((0,), np.int32)))
    if presorted:
        order = np.arange(n, dtype=np.int64)
        k_lo, k_hi, r_lo, r_hi = key_lo, key_hi, row_lo, row_hi
        s_sg = np.asarray(signs, np.int32)
    else:
        order = _sort128(key_lo, key_hi)
        k_lo, k_hi = key_lo[order], key_hi[order]
        r_lo, r_hi = row_lo[order], row_hi[order]
        s_sg = np.asarray(signs, np.int32)[order]

    same = r_lo is k_lo and r_hi is k_hi  # NoPK: key IS the row signature
    if presorted and shards > 1 and n > shards:
        starts = _shard_slices(k_lo, k_hi, shards)
        if starts.shape[0]:
            bnd = np.empty((n,), bool)
            edges = np.concatenate([[0], starts, [n]])
            for a, b in zip(edges[:-1], edges[1:]):
                bnd[a:b] = _boundary_flags_rows(
                    k_lo[a:b], k_hi[a:b], r_lo[a:b], r_hi[a:b],
                    s_sg[a:b], same)
            return order, DiffAgg(bnd, s_sg)

    return order, DiffAgg(
        _boundary_flags_rows(k_lo, k_hi, r_lo, r_hi, s_sg, same), s_sg)


# --------------------------------------------------------- attention entry

def attention(q, k, v, *, causal: bool = True, impl: str = "auto",
              block_q: int = 256, block_k: int = 256,
              interpret: bool = False):
    """Attention dispatcher for the model stack.

    q: (B,S,H,hd); k/v: (B,Sk,KV,hd) (GQA: H % KV == 0). impl:
      * "pallas" — the flash kernel (TPU target; the §Perf lever that keeps
        score tiles in VMEM). GQA handled by repeating kv heads.
      * "xla"    — models.layers.block_causal_attention (the measured
        dry-run path; HLO cost model sees its dots).
      * "auto"   — pallas on TPU backends, xla elsewhere.
    """
    from ..models.layers import block_causal_attention
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return block_causal_attention(q, k, v, causal=causal, block=block_q)
    from .flash_attention import flash_attention_pallas
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    bq = min(block_q, S)
    while S % bq:
        bq -= 1
    out = flash_attention_pallas(qf, kf, vf, causal=causal, block_q=bq,
                                 block_k=min(block_k, kf.shape[1]),
                                 interpret=interpret or _interp())
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
