"""State-space mixers: Mamba (SSD/chunked form) and RWKV6 (Finch), pure JAX.

Hardware adaptation (DESIGN.md §2): the reference CUDA implementations are
sequential selective scans (one fused kernel over time). On TPU we use the
chunked/matmul formulation — intra-chunk terms become batched matmuls on the
MXU, inter-chunk state is carried by a short ``lax.scan`` over S/chunk steps
— mathematically equivalent (Mamba-2's SSD identity; fla's chunked wkv6),
MXU-friendly, and with O(1) decode state.

Shapes: x (B,S,d). Both mixers expose train/prefill form (full sequence +
final state) and a single-step decode form.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
CHUNK = 64
_LOGW_MIN = -0.5   # mamba per-token log-decay clamp
_LOGW_MIN_RWKV = -0.25  # rwkv: exp(-cumsum) appears; tighter bound for f32


# =========================================================== Mamba (SSD)

def mamba_mix(params, x, state: Optional[Tuple] = None, *,
              d_state: int, head_dim: int, d_conv: int, chunk: int = CHUNK):
    """Chunked SSD mixer. x: (B,S,d). state: (conv_state, ssm_state) or None.

    Returns (y (B,S,d), new_state). ssm_state: (B,nh,ds,hp); conv_state:
    (B, d_conv-1, di)."""
    B, S, d = x.shape
    di = params["w_in"].shape[1] // 2
    nh = di // head_dim
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)                      # (B,S,di)
    bcdt = jnp.einsum("bsd,de->bse", x, params["w_bcdt"])
    B_, C_, dt = (bcdt[..., :d_state], bcdt[..., d_state:2 * d_state],
                  bcdt[..., 2 * d_state:])
    # causal conv over xi
    conv_w = params["conv"]                                # (d_conv, di)
    if state is None:
        pad = jnp.zeros((B, d_conv - 1, di), xi.dtype)
    else:
        pad = state[0]
    xi_p = jnp.concatenate([pad, xi], axis=1)
    new_conv = xi_p[:, -(d_conv - 1):, :] if d_conv > 1 else pad
    xi = sum(xi_p[:, k:k + S, :] * conv_w[k][None, None]
             for k in range(d_conv))
    xi = jax.nn.silu(xi)

    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])   # (B,S,nh)
    a_log = -jnp.exp(params["a_log"].astype(F32))              # (nh,) < 0
    logw = jnp.maximum(dt * a_log[None, None], _LOGW_MIN)      # (B,S,nh)
    # matmul stream stays bf16 (f32 full-width tensors double the live
    # activation set — §Perf cell C); decay/state math stays f32
    v = (xi.reshape(B, S, nh, head_dim)
         * dt[..., None].astype(xi.dtype))                     # (B,S,nh,hp)
    k = B_                                                     # (B,S,ds)
    q = C_

    y, new_ssm = _chunked_decay_attn(
        q, k, v, logw, chunk=chunk,
        state=None if state is None else state[1])
    y = y + xi.reshape(B, S, nh, head_dim) \
        * params["d_skip"].astype(xi.dtype)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, (new_conv, new_ssm)


def _chunked_decay_attn(q, k, v, logw, *, chunk, state=None):
    """Linear attention with scalar-per-head decay (SSD identity).

    q,k: (B,S,ds); v: (B,S,nh,hp); logw: (B,S,nh) — per-head log decay.
    h_t = exp(logw_t) h_{t-1} + k_t ⊗ v_t;  y_t = q_t · h_t.
    Returns (y (B,S,nh,hp), final state (B,nh,ds,hp))."""
    B, S, ds = q.shape
    nh, hp = v.shape[2], v.shape[3]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    T = S // chunk
    # leading-T layout for the chunk scan
    qc = q.reshape(B, T, chunk, ds).transpose(1, 0, 2, 3)
    kc = k.reshape(B, T, chunk, ds).transpose(1, 0, 2, 3)
    vc = v.reshape(B, T, chunk, nh, hp).transpose(1, 0, 2, 3, 4)
    lw = jnp.cumsum(logw.reshape(B, T, chunk, nh), axis=2) \
        .transpose(1, 0, 2, 3)
    if state is None:
        state = jnp.zeros((B, nh, ds, hp), F32)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])

    def step(h, xs):
        """ONE chunk: intra (i,j) term + inter (state) term + state update.
        The (B, c, c, nh) decay tensor lives only inside the step —
        materializing it for all T chunks at once costs T x the live memory
        (34 GB per jamba layer; see EXPERIMENTS §Perf cell C)."""
        qt, kt, vt, lwt = xs              # (B,c,ds),(B,c,ds),(B,c,nh,hp)
        att = jnp.einsum("bis,bjs->bij", qt, kt,
                         preferred_element_type=F32)
        ddec = lwt[:, :, None, :] - lwt[:, None, :, :]    # (B,i,j,nh)
        w_ij = jnp.where(mask[None, :, :, None], jnp.exp(ddec), 0.0)
        y = jnp.einsum("bij,bijh,bjhp->bihp", att, w_ij,
                       vt.astype(F32))
        y = y + jnp.einsum("bis,bih,bhsp->bihp", qt.astype(F32),
                           jnp.exp(lwt), h)
        kdec = jnp.exp(lwt[:, -1:, :] - lwt)              # (B,c,nh)
        h = h * jnp.exp(lwt[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bjs,bjh,bjhp->bhsp", kt.astype(F32), kdec,
                         vt.astype(F32))
        return h, jnp.asarray(y, vt.dtype)

    state_f, ys = jax.lax.scan(step, state, (qc, kc, vc, lw))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hp)
    return y, state_f


def mamba_decode(params, x, state, *, d_state: int, head_dim: int,
                 d_conv: int):
    """Single-token step. x: (B,1,d)."""
    B, _, d = x.shape
    di = params["w_in"].shape[1] // 2
    nh = di // head_dim
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    bcdt = jnp.einsum("bsd,de->bse", x, params["w_bcdt"])
    B_, C_, dt = (bcdt[..., :d_state], bcdt[..., d_state:2 * d_state],
                  bcdt[..., 2 * d_state:])
    conv_state, h = state
    xi_p = jnp.concatenate([conv_state, xi], axis=1)        # (B,d_conv,di)
    new_conv = xi_p[:, 1:, :]
    conv_w = params["conv"]
    xi = sum(xi_p[:, k:k + 1, :] * conv_w[k][None, None]
             for k in range(conv_w.shape[0]))
    xi = jax.nn.silu(xi)
    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])
    a_log = -jnp.exp(params["a_log"].astype(F32))
    w = jnp.exp(jnp.maximum(dt * a_log[None, None], _LOGW_MIN))  # (B,1,nh)
    v = xi.reshape(B, 1, nh, head_dim).astype(F32) * dt[..., None]
    h = h * w[:, 0, :, None, None] \
        + jnp.einsum("bs,bhp->bhsp", B_[:, 0].astype(F32), v[:, 0])
    y = jnp.einsum("bs,bhsp->bhp", C_[:, 0].astype(F32), h)[:, None]
    y = y + xi.reshape(B, 1, nh, head_dim).astype(F32) \
        * params["d_skip"].astype(F32)[None, None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), (new_conv, h)


# ================================================================ RWKV6

def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,1,d) last token of the previous segment."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_mix(params, x, state: Optional[Tuple] = None, *,
              head_dim: int, chunk: int = CHUNK):
    """Chunked WKV6: data-dependent per-channel decay linear attention.

    x: (B,S,d); state = (shift (B,1,d), wkv (B,H,dk,dv)).
    Returns (y, new_state)."""
    B, S, d = x.shape
    H = d // head_dim
    dk = dv = head_dim
    prev = (jnp.zeros((B, 1, d), x.dtype) if state is None else state[0])
    wkv0 = (jnp.zeros((B, H, dk, dv), F32) if state is None else state[1])
    xs = _token_shift(x, prev)

    def mix(mu):
        return x + (xs - x) * mu[None, None]

    r = jnp.einsum("bsd,de->bse", mix(params["mu_r"]), params["w_r"])
    k = jnp.einsum("bsd,de->bse", mix(params["mu_k"]), params["w_k"])
    v = jnp.einsum("bsd,de->bse", mix(params["mu_v"]), params["w_v"])
    g = jnp.einsum("bsd,de->bse", mix(params["mu_g"]), params["w_g"])
    wr = jnp.einsum("bsd,de->bse", mix(params["mu_w"]), params["w_dec"]) \
        + params["dec_bias"]
    # data-dependent decay w ∈ (0,1): log w = −exp(wr), clamped for chunk math
    logw = jnp.maximum(-jnp.exp(wr.astype(F32)), _LOGW_MIN_RWKV)  # (B,S,H*dk)

    rh = r.reshape(B, S, H, dk).astype(F32)
    kh = k.reshape(B, S, H, dk).astype(F32)
    vh = v.reshape(B, S, H, dv).astype(F32)
    lwh = logw.reshape(B, S, H, dk)
    u = params["u"].astype(F32)                               # (H,dk)

    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    T = S // chunk
    # leading-T layout for the chunk scan
    rc = rh.reshape(B, T, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    kc = kh.reshape(B, T, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    vc = vh.reshape(B, T, chunk, H, dv).transpose(1, 0, 2, 3, 4)
    lwc = lwh.reshape(B, T, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def step(h, xs_):
        """ONE chunk (intra + inter + state) — the (B,c,c,H) attention
        tensor lives only inside the step (see §Perf cell C)."""
        rt, kt, vt, lwt_tok = xs_
        lw = jnp.cumsum(lwt_tok, axis=1)                 # (B,c,H,dk)
        # decay applies strictly between j and i (exclusive of both):
        # for j<i: w(j,i) = exp(lw_{i-1} - lw_j) = exp((lw_i - logw_i) - lw_j)
        r_dec = rt * jnp.exp(lw - lwt_tok)               # bounded ≤ r
        k_dec = kt * jnp.exp(-lw)                        # bounded ≤ e^{16}
        att = jnp.einsum("bihk,bjhk->bijh", r_dec, k_dec)
        att = jnp.where(mask[None, :, :, None], att, 0.0)
        diag = jnp.einsum("bihk,hk,bihk->bih", rt, u, kt)
        y = jnp.einsum("bijh,bjhv->bihv", att, vt) + diag[..., None] * vt
        y = y + jnp.einsum("bihk,bhkv->bihv", r_dec, h)
        kdec = kt * jnp.exp(lw[:, -1:, :, :] - lw)
        h = h * jnp.exp(lw[:, -1])[:, :, :, None] \
            + jnp.einsum("bjhk,bjhv->bhkv", kdec, vt)
        return h, y

    state_f, ys = jax.lax.scan(step, wkv0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)
    # per-head group norm, then output gating
    mu2 = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(mu2 + 1e-5)
         * params["ln_x"].reshape(H, dv)[None, None]).reshape(B, S, d)
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", y, params["w_o"])
    new_shift = x[:, -1:, :]
    return out, (new_shift, state_f)


def rwkv6_decode(params, x, state, *, head_dim: int):
    """Single-token WKV6 step. x: (B,1,d)."""
    B, _, d = x.shape
    H = d // head_dim
    dk = dv = head_dim
    prev, h = state
    xs = prev

    def mix(mu):
        return x + (xs - x) * mu[None, None]

    r = jnp.einsum("bsd,de->bse", mix(params["mu_r"]), params["w_r"])
    k = jnp.einsum("bsd,de->bse", mix(params["mu_k"]), params["w_k"])
    v = jnp.einsum("bsd,de->bse", mix(params["mu_v"]), params["w_v"])
    g = jnp.einsum("bsd,de->bse", mix(params["mu_g"]), params["w_g"])
    wr = jnp.einsum("bsd,de->bse", mix(params["mu_w"]), params["w_dec"]) \
        + params["dec_bias"]
    w = jnp.exp(jnp.maximum(-jnp.exp(wr.astype(F32)), _LOGW_MIN_RWKV))
    rh = r.reshape(B, H, dk).astype(F32)
    kh = k.reshape(B, H, dk).astype(F32)
    vh = v.reshape(B, H, dv).astype(F32)
    wh = w.reshape(B, H, dk)
    u = params["u"].astype(F32)
    kv = kh[..., :, None] * vh[..., None, :]                  # (B,H,dk,dv)
    y = jnp.einsum("bhk,bhkv->bhv", rh, h + u[None, :, :, None] * kv)
    h = h * wh[..., None] + kv
    y = y.reshape(B, 1, H, dv)
    mu2 = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(mu2 + 1e-5)
         * params["ln_x"].reshape(H, dv)[None, None]).reshape(B, 1, d)
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", y, params["w_o"])
    return out, (x, h)
