"""Transformer building blocks, pure JAX (no flax/optax).

Attention is implemented as *block-causal online-softmax* attention: the
lower-triangular block pairs are enumerated statically and processed by a
``lax.scan``, so compiled FLOPs ≈ the causal-useful S²/2 instead of the
masked-full S² (this is the XLA-native equivalent of a flash kernel; see
EXPERIMENTS.md §Perf for the before/after). Sliding windows restrict the
pair list further (Mixtral SWA ⇒ O(S·W)).

All matmuls run in bf16 with f32 softmax/normalization accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def rms_norm(x, scale, eps=1e-5):
    # f32 only inside the variance reduction; the bf16 datapath stays bf16
    # so TP partial-sum all-reduces are not upcast to f32 (2x bytes).
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps).astype(x.dtype) * scale


def rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    ang = positions[..., :, None, None].astype(F32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _attn_qk(q, k):
    """(B,bq,H,hd) x (B,bk,KV,hd) -> (B,H,bq,bk) with GQA head grouping."""
    B, bq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, bq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=F32)
    return s.reshape(B, KV * g, bq, k.shape[1])


def _attn_sv(p, v):
    """(B,H,bq,bk) x (B,bk,KV,hd) -> (B,bq,H,hd)."""
    B, H, bq, bk = p.shape
    KV = v.shape[2]
    g = H // KV
    pg = p.reshape(B, KV, g, bq, bk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", pg.astype(v.dtype), v)
    return o.reshape(B, bq, H, v.shape[3])


def block_causal_attention(q, k, v, *, window: Optional[int] = None,
                           block: int = 1024, causal: bool = True):
    """Online-softmax attention over statically-enumerated block pairs.

    q: (B,S,H,hd), k/v: (B,Sk,KV,hd) — self (S==Sk, causal) or cross
    (causal=False, all pairs). Returns (B,S,H,hd).
    """
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    block = min(block, S, Sk)
    while S % block:        # largest q-block size that tiles the sequence
        block -= 1
    sk_valid = Sk
    pad_k = (-Sk) % block
    if pad_k:  # non-divisible context (e.g. 6404 vlm patches): pad + mask
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        Sk = Sk + pad_k
    Tq, Tk = S // block, Sk // block
    scale = 1.0 / np.sqrt(hd)

    pairs = []
    for qi in range(Tq):
        for ki in range(Tk):
            if causal and ki > qi:
                continue
            if causal and window is not None:
                # block pair fully outside the window?
                if qi * block - (ki * block + block - 1) >= window:
                    continue
            pairs.append((qi, ki))
    # order: qi-major so a single (m, l, acc) state serves the current row
    pairs.sort()
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)
    flush = np.zeros((len(pairs),), bool)
    for i, (qi, ki) in enumerate(pairs):
        if i + 1 == len(pairs) or pairs[i + 1][0] != qi:
            flush[i] = True
    flush_arr = jnp.asarray(flush)

    neg = jnp.asarray(-1e30, F32)
    row = jnp.arange(block)

    def body(carry, xs):
        out, m, l, acc = carry
        qi, ki, fl = xs
        qs = jax.lax.dynamic_slice_in_dim(q, qi * block, block, axis=1)
        ks = jax.lax.dynamic_slice_in_dim(k, ki * block, block, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ki * block, block, axis=1)
        s = _attn_qk(qs, ks) * scale                    # (B,H,bq,bk) f32
        kpos = ki * block + row[None, :]
        if causal:
            qpos = qi * block + row[:, None]
            mask = qpos >= kpos
            if window is not None:
                mask &= (qpos - kpos) < window
            if pad_k:
                mask &= kpos < sk_valid
            s = jnp.where(mask[None, None], s, neg)
        elif pad_k:
            s = jnp.where((kpos < sk_valid)[None, None], s, neg)
        m_new = jnp.maximum(m, s.max(-1))               # (B,H,bq)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + \
            _attn_sv(p, vs).astype(F32).transpose(0, 2, 1, 3)
        # flush completed q-row into the output buffer
        o = (acc_new / jnp.maximum(l_new, 1e-30)[..., None]) \
            .transpose(0, 2, 1, 3).astype(q.dtype)       # (B,bq,H,hd)
        out = jax.lax.cond(
            fl, lambda o_buf: jax.lax.dynamic_update_slice_in_dim(
                o_buf, o, qi * block, axis=1),
            lambda o_buf: o_buf, out)
        reset = fl
        m_next = jnp.where(reset, jnp.full_like(m, -jnp.inf), m_new)
        l_next = jnp.where(reset, jnp.zeros_like(l), l_new)
        acc_next = jnp.where(reset, jnp.zeros_like(acc), acc_new)
        return (out, m_next, l_next, acc_next), None

    out0 = jnp.zeros_like(q)
    m0 = jnp.full((B, H, block), -jnp.inf, F32)
    l0 = jnp.zeros((B, H, block), F32)
    acc0 = jnp.zeros((B, H, block, hd), F32)
    (out, _, _, _), _ = jax.lax.scan(
        body, (out0, m0, l0, acc0), (qi_arr, ki_arr, flush_arr))
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """Single-position attention against a (possibly ring-buffered) cache.

    q: (B,1,H,hd); k/v_cache: (B,Sc,KV,hd); cache_len: () int32 — number of
    valid positions. With ``window`` the cache is a ring buffer of size Sc
    == window and all slots < min(cache_len, window) are valid.
    """
    B, _, H, hd = q.shape
    Sc = k_cache.shape[1]
    s = _attn_qk(q, k_cache) / np.sqrt(hd)               # (B,H,1,Sc)
    idx = jnp.arange(Sc)
    valid = idx < jnp.minimum(cache_len, Sc) if window is not None \
        else idx < cache_len
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s.astype(F32), axis=-1)
    return _attn_sv(p, v_cache)


# ------------------------------------------------------------------- MLP

def gated_mlp(params, x):
    h = jnp.einsum("bsd,df->bsf", x, params["w1"])
    g = jnp.einsum("bsd,df->bsf", x, params["w3"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, params["w2"])


# ------------------------------------------------------------------- MoE

def moe_ffn(params, x, *, n_experts: int, top_k: int,
            capacity_factor: float, seq_chunk: int = 4096):
    """Top-k MoE with per-batch-row capacity (GShard/MaxText-style dispatch
    einsums that KEEP the batch dim).

    x: (B,S,d). Routing state (one-hot, position-in-expert cumsum, dispatch
    and combine tensors) all carry the leading batch dim, so the whole MoE
    block shards over DP without cross-device cumsums; experts shard over
    the model axis (EP). The sequence is chunked to bound the
    (B, sc, E, C) dispatch tensor (C grows with sc).

    History (EXPERIMENTS §Perf): routing over the flattened global token dim
    made every DP shard recompute every expert chunk — 16x replicated
    expert FLOPs on the production mesh.
    Returns (out, aux_loss)."""
    B, S, d = x.shape
    sc = min(S, seq_chunk)
    while S % sc:
        sc -= 1
    nchunks = S // sc
    C = int(np.ceil(capacity_factor * sc * top_k / n_experts / 4) * 4)

    def route(xc):
        """xc: (B, sc, d) -> dispatch (B,sc,E,C) bool, combine, aux."""
        logits = jnp.einsum("bsd,de->bse", xc,
                            params["router"]).astype(F32)
        probs = jax.nn.softmax(logits, axis=-1)            # (B,sc,E)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (B,sc,k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=(0, 1))
        top1 = jax.nn.one_hot(gate_idx[..., 0], n_experts, dtype=F32)
        ce = top1.mean(axis=(0, 1))
        aux = n_experts * jnp.sum(me * ce)
        disp = jnp.zeros((B, sc, n_experts, C), jnp.bool_)
        comb = jnp.zeros((B, sc, n_experts, C), xc.dtype)
        offset = jnp.zeros((B, n_experts), jnp.int32)
        for j in range(top_k):
            oh = jax.nn.one_hot(gate_idx[..., j], n_experts,
                                dtype=jnp.int32)           # (B,sc,E)
            pos = jnp.cumsum(oh, axis=1) - 1 + offset[:, None, :]
            pos_tok = (pos * oh).sum(-1)                   # (B,sc)
            fits = pos_tok < C
            slot = jax.nn.one_hot(pos_tok, C, dtype=jnp.bool_)  # (B,sc,C)
            d_j = (oh > 0)[..., None] & slot[:, :, None, :] \
                & fits[..., None, None]
            disp = disp | d_j
            comb = comb + d_j.astype(xc.dtype) \
                * gate_vals[..., j][..., None, None].astype(xc.dtype)
            offset = offset + oh.sum(axis=1)
        return disp, comb, aux

    def one_chunk(xc):
        disp, comb, aux = route(xc)
        xe = jnp.einsum("bsec,bsd->becd", disp.astype(xc.dtype), xc)
        h = jnp.einsum("becd,edf->becf", xe, params["w1"])
        g = jnp.einsum("becd,edf->becf", xe, params["w3"])
        ye = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h, params["w2"])
        out = jnp.einsum("bsec,becd->bsd", comb, ye)
        return out, aux

    if nchunks == 1:
        out, aux = one_chunk(x)
    else:
        xs = x.reshape(B, nchunks, sc, d).transpose(1, 0, 2, 3)
        outs, auxs = jax.lax.scan(
            lambda _, xc: (None, one_chunk(xc)), None, xs)[1]
        out = outs.transpose(1, 0, 2, 3).reshape(B, S, d)
        aux = auxs.mean()
    return out, aux
