"""Model assembly: decoder LMs, enc-dec (whisper), VLM cross-attn, hybrid
and SSM block patterns — one code path driven by ``ArchConfig``.

Layers are stacked per period-slot and iterated with ``lax.scan`` over
periods, so trace/compile time is O(period), not O(n_layers) — essential for
the 100-layer dry-runs.

Entry points:
  init_params(cfg, key)                       -> params pytree (bf16)
  forward(cfg, params, tokens, ctx)           -> logits (train/eval)
  loss_fn(cfg, params, batch)                 -> scalar loss (+ MoE aux)
  init_cache(cfg, batch, seq_cap)             -> decode cache pytree
  prefill(cfg, params, tokens, ctx, seq_cap)  -> (last_logits, cache)
  decode_step(cfg, params, token, cache, ctx) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from .layers import (block_causal_attention, decode_attention, gated_mlp,
                     moe_ffn, rms_norm, rope)
from . import ssm

F32 = jnp.float32
BF16 = jnp.bfloat16


# ============================================================ init

def _dense(key, shape, scale=None, dtype=BF16):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def _slot_params(cfg: ArchConfig, kind: str, ffn_kind: str, key) -> Dict:
    P = cfg.n_periods
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 24)
    p: Dict = {"ln1": jnp.ones((P, d), BF16), "ln2": jnp.ones((P, d), BF16)}
    if kind in ("attn", "cross"):
        p["wq"] = _dense(ks[0], (P, d, H * hd))
        p["wk"] = _dense(ks[1], (P, d, KV * hd))
        p["wv"] = _dense(ks[2], (P, d, KV * hd))
        p["wo"] = _dense(ks[3], (P, H * hd, d))
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((P, H * hd), BF16)
            p["bk"] = jnp.zeros((P, KV * hd), BF16)
            p["bv"] = jnp.zeros((P, KV * hd), BF16)
        if kind == "attn" and cfg.is_encdec:  # whisper: cross sublayer
            p["ln_x"] = jnp.ones((P, d), BF16)
            p["xq"] = _dense(ks[4], (P, d, H * hd))
            p["xk"] = _dense(ks[5], (P, d, KV * hd))
            p["xv"] = _dense(ks[6], (P, d, KV * hd))
            p["xo"] = _dense(ks[7], (P, H * hd, d))
    elif kind == "mamba":
        di = cfg.ssm.expand * d
        nh = di // cfg.ssm.head_dim
        ds = cfg.ssm.d_state
        p["w_in"] = _dense(ks[0], (P, d, 2 * di))
        p["w_bcdt"] = _dense(ks[1], (P, d, 2 * ds + nh))
        p["w_out"] = _dense(ks[2], (P, di, d))
        p["conv"] = _dense(ks[3], (P, cfg.ssm.d_conv, di), scale=0.5)
        p["a_log"] = jnp.zeros((P, nh), F32)
        p["dt_bias"] = jnp.full((P, nh), -1.0, F32)
        p["d_skip"] = jnp.ones((P, nh), F32)
    elif kind == "rwkv":
        hdim = cfg.ssm.head_dim
        H6 = d // hdim
        for n in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
            p[n] = jnp.full((P, d), 0.5, BF16)
        for n in ("w_r", "w_k", "w_v", "w_g"):
            p[n] = _dense(ks[hash(n) % 20], (P, d, d))
        p["w_dec"] = _dense(ks[20], (P, d, d), scale=0.01)
        p["dec_bias"] = jnp.full((P, d), 0.5, F32)
        p["u"] = jnp.zeros((P, H6, hdim), F32)
        p["ln_x"] = jnp.ones((P, d), BF16)
        p["w_o"] = _dense(ks[21], (P, d, d))
    else:
        raise ValueError(kind)
    # FFN
    if ffn_kind == "moe":
        E = cfg.moe.n_experts
        p["router"] = _dense(ks[8], (P, d, E), scale=0.02)
        p["moe_w1"] = _dense(ks[9], (P, E, d, cfg.d_ff))
        p["moe_w3"] = _dense(ks[10], (P, E, d, cfg.d_ff))
        p["moe_w2"] = _dense(ks[11], (P, E, cfg.d_ff, d))
    else:
        p["w1"] = _dense(ks[12], (P, d, cfg.d_ff))
        p["w3"] = _dense(ks[13], (P, d, cfg.d_ff))
        p["w2"] = _dense(ks[14], (P, cfg.d_ff, d))
    return p


def init_params(cfg: ArchConfig, key) -> Dict:
    adt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.period + 4)
    params: Dict = {
        "embed": _dense(keys[0], (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), BF16),
        "lm_head": _dense(keys[1], (cfg.d_model, cfg.vocab), scale=0.02),
        "blocks": {},
    }
    kinds, ffns = cfg.slot_kinds(), cfg.ffn_kinds()
    for i, (kind, fk) in enumerate(zip(kinds, ffns)):
        params["blocks"][f"slot{i}"] = _slot_params(cfg, kind, fk, keys[2 + i])
    if cfg.learned_pos:
        params["pos"] = _dense(keys[-1], (cfg.max_seq, cfg.d_model),
                               scale=0.02)
    if cfg.is_encdec:
        ek = jax.random.split(keys[-2], 3)
        enc: Dict = {"ln1": jnp.ones((cfg.encoder_layers, cfg.d_model), BF16),
                     "ln2": jnp.ones((cfg.encoder_layers, cfg.d_model), BF16)}
        d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
        enc["wq"] = _dense(ek[0], (cfg.encoder_layers, d, H * hd))
        enc["wk"] = _dense(ek[0], (cfg.encoder_layers, d, KV * hd))
        enc["wv"] = _dense(ek[1], (cfg.encoder_layers, d, KV * hd))
        enc["wo"] = _dense(ek[1], (cfg.encoder_layers, H * hd, d))
        enc["w1"] = _dense(ek[2], (cfg.encoder_layers, d, cfg.d_ff))
        enc["w3"] = _dense(ek[2], (cfg.encoder_layers, d, cfg.d_ff))
        enc["w2"] = _dense(ek[2], (cfg.encoder_layers, cfg.d_ff, d))
        params["encoder"] = enc
        params["enc_norm"] = jnp.ones((cfg.d_model,), BF16)
        params["enc_pos"] = _dense(keys[-1], (cfg.max_seq, cfg.d_model),
                                   scale=0.02)
    if adt != BF16:  # honor cfg.dtype (f32 used by consistency tests)
        params = jax.tree.map(
            lambda a: a.astype(adt) if a.dtype == BF16 else a, params)
    return params


# ============================================================ helpers

def _proj_qkv(cfg, sp, x, prefix=""):
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    B, S, _ = x.shape
    wq = sp["xq"] if prefix else sp["wq"]
    q = jnp.einsum("bsd,de->bse", x, wq)
    if not prefix and "bq" in sp:
        q = q + sp["bq"]
    return q.reshape(B, S, H, hd)


def _kv(cfg, sp, x, prefix=""):
    KV, hd = cfg.n_kv_heads, cfg.hd
    B, S, _ = x.shape
    wk = sp["xk"] if prefix else sp["wk"]
    wv = sp["xv"] if prefix else sp["wv"]
    k = jnp.einsum("bsd,de->bse", x, wk)
    v = jnp.einsum("bsd,de->bse", x, wv)
    if not prefix and "bk" in sp:
        k, v = k + sp["bk"], v + sp["bv"]
    return k.reshape(B, S, KV, hd), v.reshape(B, S, KV, hd)


def _encoder(cfg: ArchConfig, params, frames, shd=None):
    """Whisper encoder: non-causal attention stack over frame embeddings."""
    B, S, d = frames.shape
    x = frames + params["enc_pos"][:S][None]

    def layer(x, lp):
        if shd is not None:
            lp = shd.encslice(lp)
            x = shd.act(x)
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = _proj_qkv(cfg, lp, h)
        k, v = _kv(cfg, lp, h)
        a = block_causal_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bse,ed->bsd", a.reshape(B, S, -1), lp["wo"])
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + gated_mlp({"w1": lp["w1"], "w3": lp["w3"], "w2": lp["w2"]}, h)
        return x, None

    x, _ = jax.lax.scan(layer, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ============================================================ forward

def _run_slot_full(cfg: ArchConfig, kind: str, ffn_kind: str, sp, x,
                   positions, ctx, sstate, attn_block: int):
    """One slot over a full sequence (train / prefill).

    Returns (x, aux_loss, cache_kv dict|None, new_sstate)."""
    B, S, d = x.shape
    aux = jnp.zeros((), F32)
    kv_out = None
    new_sstate = sstate
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    if kind == "attn":
        q = _proj_qkv(cfg, sp, h)
        k, v = _kv(cfg, sp, h)
        if cfg.rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        a = block_causal_attention(q, k, v, window=cfg.sliding_window,
                                   block=attn_block)
        x = x + jnp.einsum("bse,ed->bsd", a.reshape(B, S, -1), sp["wo"])
        kv_out = {"k": k, "v": v}
        if cfg.is_encdec:  # whisper decoder cross sublayer
            hx = rms_norm(x, sp["ln_x"], cfg.norm_eps)
            qx = _proj_qkv(cfg, sp, hx, prefix="x")
            kx, vx = _kv(cfg, sp, ctx, prefix="x")
            ax = block_causal_attention(qx, kx, vx, causal=False,
                                        block=attn_block)
            x = x + jnp.einsum("bse,ed->bsd", ax.reshape(B, S, -1), sp["xo"])
            kv_out["xk"], kv_out["xv"] = kx, vx  # cross-KV cached at prefill
    elif kind == "cross":
        q = _proj_qkv(cfg, sp, h)
        k, v = _kv(cfg, sp, ctx)
        a = block_causal_attention(q, k, v, causal=False, block=attn_block)
        x = x + jnp.einsum("bse,ed->bsd", a.reshape(B, S, -1), sp["wo"])
        kv_out = {"ck": k, "cv": v}
    elif kind == "mamba":
        y, new_sstate = ssm.mamba_mix(
            sp, h, sstate, d_state=cfg.ssm.d_state,
            head_dim=cfg.ssm.head_dim, d_conv=cfg.ssm.d_conv)
        x = x + y
    elif kind == "rwkv":
        y, new_sstate = ssm.rwkv6_mix(sp, h, sstate,
                                      head_dim=cfg.ssm.head_dim)
        x = x + y
    # FFN
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    if ffn_kind == "moe":
        y, aux = moe_ffn({"router": sp["router"], "w1": sp["moe_w1"],
                          "w3": sp["moe_w3"], "w2": sp["moe_w2"]}, h,
                         n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor)
    else:
        y = gated_mlp({"w1": sp["w1"], "w3": sp["w3"], "w2": sp["w2"]}, h)
    return x + y, aux, kv_out, new_sstate


def _zero_sstate(cfg: ArchConfig, kind: str, B: int):
    adt = jnp.dtype(cfg.dtype)
    if kind == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        nh = di // cfg.ssm.head_dim
        return (jnp.zeros((B, cfg.ssm.d_conv - 1, di), adt),
                jnp.zeros((B, nh, cfg.ssm.d_state, cfg.ssm.head_dim), F32))
    if kind == "rwkv":
        H = cfg.d_model // cfg.ssm.head_dim
        return (jnp.zeros((B, 1, cfg.d_model), adt),
                jnp.zeros((B, H, cfg.ssm.head_dim, cfg.ssm.head_dim), F32))
    return None


def forward(cfg: ArchConfig, params, tokens, ctx=None, *,
            collect_cache: bool = False, seq_cap: Optional[int] = None,
            attn_block: int = 1024, remat: bool = False, shd=None):
    """Full-sequence forward. tokens: (B,S) int32. ctx: (B,Lc,d) stub
    embeddings (frames/patches) for enc-dec / vlm archs.

    Returns (logits or last-position hidden, aux, cache|None)."""
    B, S = tokens.shape
    kinds, ffns = cfg.slot_kinds(), cfg.ffn_kinds()
    adt = jnp.dtype(cfg.dtype)
    embed_w = params["embed"] if shd is None else shd.embed(params["embed"])
    x = embed_w[tokens].astype(adt)
    if shd is not None:
        x = shd.act(x)
    if cfg.learned_pos:
        x = x + params["pos"][:S][None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.is_encdec:
        ctx = _encoder(cfg, params, ctx, shd)

    def period(carry, pslice):
        # NOTE: recurrent (mamba/rwkv) state is per-LAYER: every period slot
        # starts its own zero state over the full sequence; final states are
        # emitted per period (ys) so the decode cache gets a (P, ...) stack.
        x, aux = carry
        kv_caches = {}
        for i, (kind, fk) in enumerate(zip(kinds, ffns)):
            sp = pslice[f"slot{i}"]
            if shd is not None:
                sp = shd.pslice(f"slot{i}", sp)
                x = shd.act(x)
            x, a, kv, st2 = _run_slot_full(cfg, kind, fk, sp, x, positions,
                                           ctx, None, attn_block)
            aux = aux + a
            if collect_cache and kind in ("mamba", "rwkv"):
                kv_caches[f"slot{i}"] = st2
            elif collect_cache and kv is not None:
                kv_caches[f"slot{i}"] = kv
        return (x, aux), (kv_caches if collect_cache else None)

    period_fn = jax.checkpoint(period) if remat else period
    (x, aux), kv_stacked = jax.lax.scan(
        period_fn, (x, jnp.zeros((), F32)), params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head_w = params["lm_head"] if shd is None else shd.head(params["lm_head"])

    if not collect_cache:
        logits = jnp.einsum("bsd,dv->bsv", x, head_w)
        return logits, aux, None

    # prefill: build the decode cache
    assert seq_cap is not None and seq_cap >= S
    cache: Dict = {"len": jnp.full((), S, jnp.int32)}
    for i, kind in enumerate(kinds):
        name = f"slot{i}"
        if kind == "attn":
            kv = kv_stacked[name]
            k, v = kv["k"], kv["v"]  # (P,B,S,KV,hd)
            W = cfg.sliding_window
            cap = min(seq_cap, W) if W else seq_cap
            kc = jnp.zeros((cfg.n_periods, B, cap, cfg.n_kv_heads, cfg.hd),
                           adt)
            vc = jnp.zeros_like(kc)
            if W and S > W:
                k, v = k[:, :, -W:], v[:, :, -W:]
            s_eff = min(S, cap)
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k[:, :, :s_eff].astype(adt), 0, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v[:, :, :s_eff].astype(adt), 0, axis=2)
            cache[name] = {"k": kc, "v": vc}
            if "xk" in kv:  # whisper cross-KV, fixed at prefill
                cache[name]["xk"] = kv["xk"].astype(adt)
                cache[name]["xv"] = kv["xv"].astype(adt)
        elif kind in ("mamba", "rwkv"):
            cache[name] = kv_stacked[name]  # (P, ...) final layer states
        elif kind == "cross":
            kv = kv_stacked[name]
            cache[name] = {"ck": kv["ck"].astype(adt),
                           "cv": kv["cv"].astype(adt)}
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head_w)
    return logits, aux, cache


def loss_fn(cfg: ArchConfig, params, batch, *, attn_block: int = 1024,
            aux_coef: float = 0.01, remat: bool = False, shd=None):
    """Causal LM loss (next-token xent, f32) + MoE load-balance aux."""
    tokens = batch["tokens"]
    targets = batch["targets"]
    ctx = batch.get("ctx")
    logits, aux, _ = forward(cfg, params, tokens, ctx,
                             attn_block=attn_block, remat=remat, shd=shd)
    logits = logits.astype(F32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1).squeeze(-1)
    mask = (targets >= 0).astype(F32)
    nll = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + aux_coef * aux


# ============================================================ decode

def init_cache(cfg: ArchConfig, B: int, seq_cap: int,
               ctx_len: int = 0) -> Dict:
    """Zero decode cache for a given batch and context capacity (also used
    abstractly via eval_shape for the dry-run input specs)."""
    kinds = cfg.slot_kinds()
    P = cfg.n_periods
    adt = jnp.dtype(cfg.dtype)
    cache: Dict = {"len": jnp.zeros((), jnp.int32)}
    for i, kind in enumerate(kinds):
        name = f"slot{i}"
        if kind == "attn":
            W = cfg.sliding_window
            cap = min(seq_cap, W) if W else seq_cap
            cache[name] = {
                "k": jnp.zeros((P, B, cap, cfg.n_kv_heads, cfg.hd), adt),
                "v": jnp.zeros((P, B, cap, cfg.n_kv_heads, cfg.hd), adt)}
            if cfg.is_encdec:
                cache[name]["xk"] = jnp.zeros(
                    (P, B, ctx_len, cfg.n_kv_heads, cfg.hd), adt)
                cache[name]["xv"] = jnp.zeros_like(cache[name]["xk"])
        elif kind == "cross":
            cache[name] = {
                "ck": jnp.zeros((P, B, ctx_len, cfg.n_kv_heads, cfg.hd),
                                adt),
                "cv": jnp.zeros((P, B, ctx_len, cfg.n_kv_heads, cfg.hd),
                                adt)}
        elif kind in ("mamba", "rwkv"):
            z = _zero_sstate(cfg, kind, B)
            cache[name] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (P,) + a.shape).copy(), z)
    return cache


def _run_slot_decode(cfg: ArchConfig, kind: str, ffn_kind: str, sp, x,
                     pos, cslice):
    """One slot for a single new token. x: (B,1,d)."""
    B = x.shape[0]
    aux = jnp.zeros((), F32)
    new_c = cslice
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    if kind == "attn":
        q = _proj_qkv(cfg, sp, h)
        k, v = _kv(cfg, sp, h)
        if cfg.rope:
            pvec = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
            q = rope(q, pvec, cfg.rope_theta)
            k = rope(k, pvec, cfg.rope_theta)
        W = cfg.sliding_window
        cap = cslice["k"].shape[1]  # (B, cap, KV, hd): period dim stripped
        widx = pos % cap if W else pos
        kc = jax.lax.dynamic_update_slice_in_dim(
            cslice["k"], k.astype(cslice["k"].dtype), widx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cslice["v"], v.astype(cslice["v"].dtype), widx, axis=1)
        a = decode_attention(q, kc, vc, pos + 1, window=W)
        x = x + jnp.einsum("bse,ed->bsd",
                           a.reshape(B, 1, -1).astype(x.dtype), sp["wo"])
        new_c = dict(cslice)
        new_c.update({"k": kc, "v": vc})
        if cfg.is_encdec:  # cross-KV was cached at prefill
            hx = rms_norm(x, sp["ln_x"], cfg.norm_eps)
            qx = _proj_qkv(cfg, sp, hx, prefix="x")
            kx, vx = cslice["xk"], cslice["xv"]
            ax = decode_attention(qx, kx, vx, jnp.full((), kx.shape[1]))
            x = x + jnp.einsum("bse,ed->bsd",
                               ax.reshape(B, 1, -1).astype(x.dtype), sp["xo"])
    elif kind == "cross":
        q = _proj_qkv(cfg, sp, h)
        k, v = cslice["ck"], cslice["cv"]  # cached at prefill
        a = decode_attention(q, k, v, jnp.full((), k.shape[1]))
        x = x + jnp.einsum("bse,ed->bsd",
                           a.reshape(B, 1, -1).astype(x.dtype), sp["wo"])
        new_c = cslice
    elif kind == "mamba":
        y, new_c = ssm.mamba_decode(sp, h, cslice, d_state=cfg.ssm.d_state,
                                    head_dim=cfg.ssm.head_dim,
                                    d_conv=cfg.ssm.d_conv)
        x = x + y
    elif kind == "rwkv":
        y, new_c = ssm.rwkv6_decode(sp, h, cslice,
                                    head_dim=cfg.ssm.head_dim)
        x = x + y
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    if ffn_kind == "moe":
        y, aux = moe_ffn({"router": sp["router"], "w1": sp["moe_w1"],
                          "w3": sp["moe_w3"], "w2": sp["moe_w2"]}, h,
                         n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor)
    else:
        y = gated_mlp({"w1": sp["w1"], "w3": sp["w3"], "w2": sp["w2"]}, h)
    return x + y, new_c


def decode_step(cfg: ArchConfig, params, token, cache, shd=None):
    """One serving step: token (B,1) int32 + cache -> (logits (B,V), cache)."""
    B = token.shape[0]
    kinds, ffns = cfg.slot_kinds(), cfg.ffn_kinds()
    pos = cache["len"]
    adt = jnp.dtype(cfg.dtype)
    embed_w = params["embed"] if shd is None else shd.embed(params["embed"])
    x = embed_w[token[:, 0]][:, None].astype(adt)
    if cfg.learned_pos:
        x = x + params["pos"][pos % params["pos"].shape[0]][None, None]

    def period(carry, xs):
        x = carry
        pslice, cslice = xs
        new_cslice = {}
        for i, (kind, fk) in enumerate(zip(kinds, ffns)):
            name = f"slot{i}"
            sp = pslice[name]
            if shd is not None:
                sp = shd.pslice(name, sp)
            x, nc = _run_slot_decode(cfg, kind, fk, sp, x, pos,
                                     cslice.get(name))
            if name in cslice:
                new_cslice[name] = nc
        return x, new_cslice

    scan_cache = {k: v for k, v in cache.items() if k != "len"}
    x, new_scan_cache = jax.lax.scan(
        period, x, (params["blocks"], scan_cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head_w = params["lm_head"] if shd is None else shd.head(params["lm_head"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0], head_w)
    new_cache = dict(cache)
    new_cache.update(new_scan_cache)
    new_cache["len"] = pos + 1
    return logits, new_cache


def prefill(cfg: ArchConfig, params, tokens, ctx=None, *,
            seq_cap: int, attn_block: int = 1024, shd=None):
    """Prefill: full forward + cache build. Returns (last_logits, cache)."""
    return_vals = forward(cfg, params, tokens, ctx, collect_cache=True,
                          seq_cap=seq_cap, attn_block=attn_block, shd=shd)
    logits, aux, cache = return_vals
    return logits, cache
