"""Model stack: layers, SSM mixers, and the unified LM assembly."""
from . import layers, ssm, lm  # noqa: F401
from .lm import (init_params, forward, loss_fn, prefill, decode_step,  # noqa
                 init_cache)
