"""Tiered durable object store + remotes (ISSUE 10).

Turns the in-heap ``ObjectStore`` into a three-tier store:

1. **heap** — the process heap, an LRU cache of sealed objects (tier 1);
2. **packs** — a local durable pack directory of content-addressed,
   CRC32C-framed per-lane columnar spill files (tier 2, ``packs.PackDir``);
3. **remote** — a remote directory holding packs + a refs snapshot + the
   WAL, exchanged by ``push``/``pull``/``fetch``/``clone`` (tier 3,
   ``remote``).

Content addresses key by **digest**, never oid: rollback paths rewind the
oid counter (see ``core.objects.ObjectStore``), so a recycled oid must map
to a fresh digest, never to stale bytes.
"""
from .packs import (PACK_MAGIC, PACK_VERSION, PackDir, PackFormatError,
                    attach_packs, blob_digest, decode_object, encode_object)
from .remote import (REFS_MAGIC, REFS_VERSION, clone, decode_refs,
                     encode_refs, export_refs, fetch, import_refs, pull,
                     push, read_remote)

__all__ = [
    "PACK_MAGIC", "PACK_VERSION", "PackDir", "PackFormatError",
    "attach_packs", "blob_digest", "decode_object", "encode_object",
    "REFS_MAGIC", "REFS_VERSION", "clone", "decode_refs", "encode_refs",
    "export_refs", "fetch", "import_refs", "pull", "push", "read_remote",
]
