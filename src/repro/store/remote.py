"""Remotes: refs snapshots, push/pull/fetch, and repo-level clone (ISSUE 10).

A **remote** is a directory with the same object layout a local pack tier
uses, plus the repo metadata::

    <remote>/refs.dgrf            refs snapshot (the remote's commit point)
    <remote>/wal.dgws             full framed WAL (transport/history copy)
    <remote>/objects/<digest>.dgp content-addressed pack files

The **refs snapshot** is the engine's metadata — directories, histories,
snapshots, branches, PRs, the commit log, and the oid→digest map — WITHOUT
object payloads. It is what makes ``clone --shallow`` possible: a shallow
clone imports refs up front and faults objects from its origin on first
gather, never replaying the WAL's data batches.

Authority rules (the crash-consistency contract):

* On a **remote**, ``refs.dgrf`` is the commit point. WAL bytes beyond
  ``refs["n_records"]`` are an unacknowledged push tail and are ignored by
  every reader (``read_remote`` truncates) — so a crash between the WAL
  swing and the refs swing is invisible, all-or-nothing.
* On a **local refs-mode store**, the WAL is the commit point (the CLI
  acknowledged those frames) and refs are a derived cache: a load replays
  the WAL tail past ``n_records`` on top of the imported refs.

Exchange ships only what the other side is missing: objects by digest
(dedup across oids and repos for free) plus the WAL suffix. DataHub-style:
collaborating repos trade version deltas, never full datasets.
"""
from __future__ import annotations

import os
import pickle
from typing import Dict, List, Optional, Tuple

from ..core.engine import CommitRecord, Engine
from ..core.faults import crash_point, register
from ..core.objects import TombstoneObject
from ..core.table import Table
from ..core.wal import WAL, encode_frame, iter_frames
from .packs import PACK_SUFFIX, PackDir, PackFormatError, _atomic_write

REFS_MAGIC = b"DGRF"
REFS_VERSION = 1
REFS_HEADER = REFS_MAGIC + bytes([REFS_VERSION]) + b"\x00\x00\x00"

REFS_FILE = "refs.dgrf"
WAL_FILE = "wal.dgws"

CP_PUSH_MANIFEST = register(
    "store.push.manifest",
    "objects and the WAL copy are shipped but the remote refs file has "
    "not swung — the refs are the remote's commit point, so recovery "
    "must read the remote at its OLD state (extra content-addressed "
    "objects are invisible garbage)")
CP_PULL_APPLY = register(
    "store.pull.apply",
    "missing objects are fetched into the local pack tier but the local "
    "engine/WAL has not swung — recovery must show the local repo "
    "unchanged (prefetched packs are invisible until referenced)")


class RemoteError(ValueError):
    """A remote is unreadable, diverged, or refused the operation."""


# --------------------------------------------------------------------------
# refs snapshot encode/decode
# --------------------------------------------------------------------------

def encode_refs(payload: dict) -> bytes:
    return REFS_HEADER + encode_frame(
        pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL))


def decode_refs(blob: bytes) -> dict:
    if blob[:4] != REFS_MAGIC:
        raise PackFormatError(
            f"bad magic {blob[:4]!r}: not a datagit refs snapshot")
    if len(blob) < len(REFS_HEADER) or blob[4] != REFS_VERSION:
        raise PackFormatError("refs snapshot header truncated or "
                              f"unsupported version (want v{REFS_VERSION})")
    payload, _ = next(iter_frames(blob, len(REFS_HEADER)))
    return pickle.loads(payload)


def export_refs(engine, objects: Dict[int, Tuple[str, bool, int]], *,
                origin: Optional[str] = None) -> dict:
    """The engine's metadata as a picklable refs payload.

    ``objects`` maps every live oid to ``(digest, is_tomb, nbytes)`` —
    the content-address map that replaces the heap. PR CI checks are
    in-process callables and do not survive (same caveat as WAL replay)."""
    prs = []
    for pr in engine.prs.values():
        prs.append({"id": pr.id, "base_name": pr.base_name,
                    "head_name": pr.head_name, "tables": dict(pr.tables),
                    "base_pins": dict(pr.base_pins), "status": pr.status,
                    "publish_ts": pr.publish_ts,
                    "pre_publish": dict(pr.pre_publish),
                    "post_publish": dict(pr.post_publish)})
    return {
        "format": REFS_VERSION,
        "n_records": len(engine.wal.records),
        "record_sigs": _record_sigs(engine.wal.records),
        "ts": engine.ts,
        "next_oid": engine.store._next_oid,
        "retention": engine.retention_versions,
        "tables": {name: (t.schema, list(t.history))
                   for name, t in engine.tables.items()},
        "snapshots": dict(engine.snapshots),
        "base": dict(engine._base),
        "indices": {k: list(v) for k, v in engine.indices.items()},
        "branches": {name: (br.name, dict(br.tables), dict(br.base),
                            br.parent, br.created_ts)
                     for name, br in engine.branches.items()},
        "prs": prs,
        "next_pr_id": engine._next_pr_id,
        "commit_log": [(c.ts, c.table, c.kind, c.inserted, c.deleted)
                       for c in engine.commit_log],
        "objects": {int(oid): tuple(ent) for oid, ent in objects.items()},
        "origin": origin,
    }


def import_refs(payload: dict, wal: WAL, packs: PackDir) -> Engine:
    """Rebuild an engine from a refs payload WITHOUT replaying the WAL.

    Every object starts evicted (oid → digest in the pack tier) and faults
    in on first gather — the shallow-clone load path. WAL records past
    ``payload["n_records"]`` (a local store's crash tail or post-refs
    appends) are replayed on top; signatures of imported objects are
    carried verbatim, never recomputed (``rows_rehashed`` stays 0)."""
    from ..core.workspace import Branch, PullRequest

    e = Engine(retention_versions=payload.get("retention", 1024))
    st = e.store
    st.attach_packs(packs)
    for oid, ent in payload["objects"].items():
        st._packed[int(oid)] = tuple(ent)
        st._digest_refs[ent[0]] = st._digest_refs.get(ent[0], 0) + 1
    st._next_oid = payload["next_oid"]
    for name, (schema, history) in payload["tables"].items():
        t = Table(name, schema, st, 0)
        t.history = list(history)
        t.directory = t.history[-1][1]
        e.tables[name] = t
    e.snapshots = dict(payload["snapshots"])
    e._base = dict(payload["base"])
    e.indices = {k: list(v) for k, v in payload["indices"].items()}
    for name, tup in payload["branches"].items():
        e.branches[name] = Branch(*tup)
    for d in payload["prs"]:
        pr = object.__new__(PullRequest)
        pr.engine = e
        pr.id = d["id"]
        pr.base_name = d["base_name"]
        pr.head_name = d["head_name"]
        pr.tables = dict(d["tables"])
        pr.base_pins = dict(d["base_pins"])
        pr.checks = []                  # in-process callables never survive
        pr.status = d["status"]
        pr.publish_ts = d["publish_ts"]
        pr.pre_publish = dict(d["pre_publish"])
        pr.post_publish = dict(d["post_publish"])
        pr.publish_reports = {}
        e.prs[pr.id] = pr
    e._next_pr_id = payload["next_pr_id"]
    e.commit_log = [CommitRecord(*t) for t in payload["commit_log"]]
    e.ts = payload["ts"]
    n = payload["n_records"]
    if len(wal.records) > n:
        # local crash tail: the WAL is the local commit point — replay the
        # acknowledged records the refs cache has not absorbed yet
        Engine.replay(wal, into=e, start=n)
    else:
        e.wal = wal
        e.reset_metrics()
    return e


# --------------------------------------------------------------------------
# remote I/O
# --------------------------------------------------------------------------

def _paths(remote: str) -> Tuple[str, str, str]:
    return (os.path.join(remote, REFS_FILE),
            os.path.join(remote, WAL_FILE),
            os.path.join(remote, "objects"))


def read_remote(remote: str) -> Tuple[dict, list]:
    """A remote's ``(refs payload, acknowledged records)``.

    The refs file is the remote's commit point: WAL records past
    ``n_records`` are an unacknowledged push tail and are dropped here."""
    refs_path, wal_path, _ = _paths(remote)
    if not os.path.exists(refs_path):
        raise RemoteError(f"no remote at {remote} (missing {REFS_FILE})")
    with open(refs_path, "rb") as f:
        payload = decode_refs(f.read())
    with open(wal_path, "rb") as f:
        records = WAL.deserialize(f.read()).records
    n = payload["n_records"]
    if len(records) < n:
        raise RemoteError(
            f"remote {remote} is damaged: refs acknowledge {n} record(s) "
            f"but the WAL holds {len(records)}")
    return payload, records[:n]


def _record_sigs(records) -> List[int]:
    """Per-record content fingerprints for the fast-forward check.

    Kinds alone cannot tell two different inserts apart — divergent
    histories with the same op shapes would slip past a prefix-of-kinds
    compare. crc32c over the pickled record keys on actual content; the
    extra loads/dumps round trip first normalises pickle's object-identity
    memoisation (shared subobjects in a freshly built record vs. the
    distinct copies a deserialized one holds), so a pulled history
    fingerprints equal to the remote it came from."""
    from ..core.wal import crc32c
    out = []
    for r in records:
        raw = pickle.dumps((r.kind, r.payload),
                           protocol=pickle.HIGHEST_PROTOCOL)
        out.append(crc32c(pickle.dumps(pickle.loads(raw),
                                       protocol=pickle.HIGHEST_PROTOCOL)))
    return out


def _require_fast_forward(local_sigs: List[int], remote_sigs: List[int],
                          op: str) -> None:
    behind, ahead = ((remote_sigs, local_sigs) if op == "push"
                     else (local_sigs, remote_sigs))
    n = len(behind)
    if n > len(ahead) or behind != ahead[:n]:
        raise RemoteError(
            f"{op} refused: histories diverged (not a fast-forward) — "
            + ("pull first, then push" if op == "push"
               else "the local store has records the remote lacks"))


def _digest_entry(store, oid: int) -> Tuple[Tuple[str, bool, int],
                                            Optional[bytes]]:
    """``(digest, is_tomb, nbytes)`` for one live oid, reusing the pack
    tier's digest when spilled (blob is returned only when freshly
    encoded — callers copy the pack file otherwise)."""
    ent = store._packed.get(oid)
    if ent is not None:
        return ent, None
    obj = store.get(oid)
    from .packs import blob_digest, encode_object
    blob = encode_object(obj)
    return ((blob_digest(blob), isinstance(obj, TombstoneObject),
             int(obj.nbytes)), blob)


def push(engine, remote: str) -> dict:
    """Ship missing objects + the WAL to ``remote``; swing its refs.

    Only objects whose digest the remote lacks are transferred (the
    content address is the dedup key); the refs rewrite is the atomic
    commit point, so a crash anywhere leaves the remote at its old state."""
    refs_path, wal_path, objects_dir = _paths(remote)
    os.makedirs(objects_dir, exist_ok=True)
    local_sigs = _record_sigs(engine.wal.records)
    n_remote = 0
    if os.path.exists(refs_path):
        with open(refs_path, "rb") as f:
            rpayload = decode_refs(f.read())
        _require_fast_forward(local_sigs, rpayload["record_sigs"], "push")
        n_remote = rpayload["n_records"]
    objects: Dict[int, Tuple[str, bool, int]] = {}
    pushed = bytes_pushed = 0
    store = engine.store
    for oid in sorted(store.oids()):
        ent, blob = _digest_entry(store, oid)
        objects[oid] = ent
        dst = os.path.join(objects_dir, ent[0] + PACK_SUFFIX)
        if not os.path.exists(dst):
            if blob is None:            # spilled: copy the local pack file
                blob = store.packs.read(ent[0])
            _atomic_write(dst, blob)
            pushed += 1
            bytes_pushed += len(blob)
    # the WAL copy is transport/history, not the commit point — an atomic
    # whole rewrite keeps it a pure function of the refs that follow
    _atomic_write(wal_path, engine.wal.serialize())
    crash_point(CP_PUSH_MANIFEST)
    _atomic_write(refs_path, encode_refs(export_refs(engine, objects)))
    store.metrics.add("store.objects_pushed", pushed)
    return {"objects_pushed": pushed, "bytes_pushed": bytes_pushed,
            "records_pushed": len(local_sigs) - n_remote}


def fetch(engine, remote: str, pack_dir: Optional[str] = None) -> dict:
    """Copy objects the local pack tier lacks from ``remote`` (no state
    change — a warm-up for shallow clones and future pulls)."""
    payload, _ = read_remote(remote)
    packs = _local_packs(engine, pack_dir, remote)
    fetched, fbytes = _fetch_missing(packs, payload, remote)
    engine.store.metrics.add("store.objects_pulled", fetched)
    return {"objects_pulled": fetched, "bytes_pulled": fbytes}


def _local_packs(engine, pack_dir: Optional[str], remote: str) -> PackDir:
    if engine.store.packs is not None:
        return engine.store.packs
    if pack_dir is None:
        # no local pack tier: mount the remote's objects read-through
        backend = PackDir(remote)
    else:
        backend = PackDir(pack_dir, origin=remote)
    engine.store.attach_packs(backend)
    return backend


def _fetch_missing(packs: PackDir, payload: dict,
                   remote: str) -> Tuple[int, int]:
    if os.path.abspath(packs.root) == os.path.abspath(remote):
        return 0, 0                     # reading the remote in place
    from .packs import blob_digest
    fetched = fbytes = 0
    for digest in sorted({ent[0] for ent in payload["objects"].values()}):
        if packs.has(digest):
            continue
        src = os.path.join(remote, "objects", digest + PACK_SUFFIX)
        with open(src, "rb") as f:
            blob = f.read()
        if blob_digest(blob) != digest:
            raise PackFormatError(
                f"remote object {digest[:12]}… fails its digest")
        packs.store(digest, blob)
        fetched += 1
        fbytes += len(blob)
    return fetched, fbytes


def pull(engine, remote: str,
         pack_dir: Optional[str] = None) -> Tuple[Engine, dict]:
    """Fast-forward the local repo to the remote's acknowledged state.

    Fetches only missing objects (counter-pinned: ``store.objects_pulled``
    == the missing-set size when a local pack tier exists), then rebuilds
    the engine from the remote refs — imported objects carry their
    signatures verbatim, so a pull never re-hashes a row. Objects already
    resident locally with a matching digest stay in the heap tier."""
    payload, records = read_remote(remote)
    local_sigs = _record_sigs(engine.wal.records)
    _require_fast_forward(local_sigs, payload["record_sigs"], "pull")
    if len(local_sigs) == len(payload["record_sigs"]):
        return engine, {"up_to_date": True, "objects_pulled": 0,
                        "records_pulled": 0}
    packs = _local_packs(engine, pack_dir, remote)
    if os.path.abspath(packs.root) != os.path.abspath(remote):
        # make the local tier authoritative for what we already have, so
        # "missing" is computed against durable local content
        engine.store.spill_all()
        packs.origin = payload.get("origin") or remote
    fetched, fbytes = _fetch_missing(packs, payload, remote)
    crash_point(CP_PULL_APPLY)
    new_wal = WAL()
    new_wal.records = list(records)
    e2 = import_refs(dict(payload, origin=packs.origin), new_wal, packs)
    # heap carry-over: same oid + same digest == same bytes (content
    # addressing); keep the resident object instead of a later fault-in
    old = engine.store
    for oid, ent in e2.store._packed.items():
        obj = old._objects.get(oid)
        oent = old._packed.get(oid)
        if obj is not None and oent is not None and oent[0] == ent[0]:
            e2.store._objects[oid] = obj
    e2.store.metrics.add("store.objects_pulled", fetched)
    return e2, {"up_to_date": False, "objects_pulled": fetched,
                "bytes_pulled": fbytes,
                "records_pulled": len(records) - len(local_sigs)}


def clone(remote: str, dest_store: str, *, shallow: bool = False) -> dict:
    """Create a local refs-mode store from ``remote``.

    ``shallow``: copy refs + WAL only; objects fault in from the origin on
    first gather. Otherwise every object is fetched up front."""
    payload, records = read_remote(remote)
    if os.path.exists(dest_store):
        raise RemoteError(f"clone destination {dest_store} already exists")
    packs = PackDir(dest_store + ".packs", origin=os.path.abspath(remote))
    packs.ensure()
    fetched = 0
    if not shallow:
        fetched, _ = _fetch_missing(packs, payload, remote)
    w = WAL()
    w.records = records
    _atomic_write(dest_store, w.serialize())
    _atomic_write(dest_store + ".refs",
                  encode_refs(dict(payload,
                                   origin=os.path.abspath(remote))))
    return {"shallow": shallow, "objects_fetched": fetched,
            "records": len(records)}
