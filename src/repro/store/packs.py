"""Content-addressed pack files: the durable spill tier (ISSUE 10).

One sealed object per pack file, in the DGWS framing style of the WAL::

    header  := MAGIC "DGPK" | version u8 | reserved u8*3       (8 bytes)
    frame 0 := meta (canonical JSON: kind, lane layout, dtypes)
    frame i := one lane payload per meta["lanes"] entry
    frame   := length u32le | crc32c(payload) u32le | payload

Numeric lanes are raw little-endian array bytes — ``np.frombuffer`` over
the blob reconstructs them zero-copy (read-only, mmap-friendly). LOB lanes
(object arrays of ``bytes``) are a u32le length lane followed by the
concatenated values. No pickle anywhere in the pack path: a pack file is
fully decodable (and verifiable) from its bytes alone.

The content address is ``sha256(blob)`` over the whole encoded blob with
the **oid excluded** from the meta frame: oids are recycled by the engine's
rollback paths, so a digest keyed on one would alias a recycled oid to
stale bytes. ``PackDir.load`` re-binds the requesting oid at decode time.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core.faults import crash_point, register
from ..core.objects import DataObject, TombstoneObject
from ..core.wal import StoreFormatError, encode_frame, iter_frames

PACK_MAGIC = b"DGPK"
PACK_VERSION = 1
PACK_HEADER = PACK_MAGIC + bytes([PACK_VERSION]) + b"\x00\x00\x00"
PACK_SUFFIX = ".dgp"

CP_PACK_WRITE = register(
    "store.pack.write",
    "mid atomic pack/refs file write: the tmp file is fully written but "
    "not yet renamed into place — recovery must see either the old file "
    "or none (the stale .tmp is ignored by every reader)")

_LOB_HEAD = struct.Struct("<Q")           # value count of a LOB lane


class PackFormatError(StoreFormatError):
    """A pack blob failed structural validation (magic/version/layout)."""


# --------------------------------------------------------------------------
# lane codecs
# --------------------------------------------------------------------------

def _encode_lob_lane(arr: np.ndarray) -> bytes:
    vals = [v if isinstance(v, bytes) else bytes(v) for v in arr.tolist()]
    lens = np.asarray([len(v) for v in vals], dtype=np.uint32)
    return _LOB_HEAD.pack(len(vals)) + lens.tobytes() + b"".join(vals)


def _decode_lob_lane(payload: bytes) -> np.ndarray:
    if len(payload) < _LOB_HEAD.size:
        raise PackFormatError("LOB lane truncated before its count")
    (n,) = _LOB_HEAD.unpack_from(payload, 0)
    off = _LOB_HEAD.size
    lens = np.frombuffer(payload, dtype=np.uint32, count=n, offset=off)
    off += n * 4
    if off + int(lens.sum()) != len(payload):
        raise PackFormatError("LOB lane length table does not cover payload")
    out = np.empty((n,), dtype=object)
    for i, ln in enumerate(lens.tolist()):
        out[i] = payload[off:off + ln]
        off += ln
    return out


def _encode_num_lane(arr: np.ndarray) -> bytes:
    a = np.ascontiguousarray(arr)
    if a.dtype.byteorder == ">":          # packs are little-endian on disk
        a = a.astype(a.dtype.newbyteorder("<"))
    return a.tobytes()


def _decode_num_lane(payload: bytes, dtype: str, nrows: int) -> np.ndarray:
    arr = np.frombuffer(payload, dtype=np.dtype(dtype))
    if arr.shape[0] != nrows:
        raise PackFormatError(
            f"lane has {arr.shape[0]} row(s), meta declares {nrows}")
    return arr                             # read-only by construction


# --------------------------------------------------------------------------
# object <-> blob
# --------------------------------------------------------------------------

def encode_object(obj) -> bytes:
    """Serialize one sealed object as a self-verifying pack blob.

    Deterministic: identical lane content encodes to identical bytes, so
    the digest doubles as the dedup/exchange key (ForkBase-style)."""
    lanes: List[Tuple[str, str, bytes]] = []   # (name, codec, payload)

    def num(name: str, arr: np.ndarray) -> None:
        a = np.ascontiguousarray(arr)
        code = a.dtype.str if a.dtype.byteorder != ">" else \
            a.dtype.newbyteorder("<").str
        lanes.append((name, code, _encode_num_lane(arr)))

    if isinstance(obj, DataObject):
        key_is_row = obj.key_lo is obj.row_lo
        num("commit_ts", obj.commit_ts)
        num("row_lo", obj.row_lo)
        num("row_hi", obj.row_hi)
        if not key_is_row:
            num("key_lo", obj.key_lo)
            num("key_hi", obj.key_hi)
        cols: List[Tuple[str, str]] = []
        for name, arr in obj.cols.items():
            if arr.dtype == object:
                cols.append((name, "lob"))
                lanes.append((name, "lob", _encode_lob_lane(arr)))
            else:
                cols.append((name, np.ascontiguousarray(arr).dtype.str))
                num(name, arr)
        sig_lob = sorted(obj.lob_sigs)
        for name in sig_lob:
            num("lob_sig:" + name, obj.lob_sigs[name])
        meta = {"kind": "data", "nrows": int(obj.nrows),
                "nbytes": int(obj.nbytes), "key_is_row": key_is_row,
                "cols": cols, "sig_lob": sig_lob,
                "lanes": [(n, c) for n, c, _ in lanes]}
    elif isinstance(obj, TombstoneObject):
        num("commit_ts", obj.commit_ts)
        num("target", obj.target)
        num("key_lo", obj.key_lo)
        num("key_hi", obj.key_hi)
        meta = {"kind": "tomb", "nrows": int(obj.nrows),
                "target_oids": [int(o) for o in obj.target_oids],
                "lanes": [(n, c) for n, c, _ in lanes]}
    else:
        raise TypeError(f"cannot pack {type(obj).__name__}")

    out = [PACK_HEADER,
           encode_frame(json.dumps(meta, sort_keys=True,
                                   separators=(",", ":")).encode())]
    out.extend(encode_frame(payload) for _, _, payload in lanes)
    return b"".join(out)


def blob_digest(blob: bytes) -> str:
    return hashlib.sha256(blob).hexdigest()


def check_pack_header(blob: bytes) -> int:
    """Validate the pack header; returns the offset where frames begin."""
    if blob[:4] != PACK_MAGIC:
        raise PackFormatError(
            f"bad magic {blob[:4]!r}: not a datagit pack file")
    if len(blob) < len(PACK_HEADER):
        raise PackFormatError("pack header truncated")
    if blob[4] != PACK_VERSION:
        raise PackFormatError(
            f"pack format version {blob[4]} is not supported "
            f"(this build reads DGPK v{PACK_VERSION})")
    return len(PACK_HEADER)


def decode_object(blob: bytes, oid: int):
    """Rebuild a sealed object from a pack blob, binding it to ``oid``.

    Every frame CRC is verified on the way in (TornFrame/CorruptFrame are
    the same typed errors the WAL raises); lane shapes are validated
    against the meta frame before any object is constructed."""
    start = check_pack_header(blob)
    frames = [payload for payload, _ in iter_frames(blob, start)]
    if not frames:
        raise PackFormatError("pack has no meta frame")
    try:
        meta = json.loads(frames[0].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as err:
        raise PackFormatError(f"bad meta frame: {err}") from None
    lanes = meta.get("lanes", [])
    if len(frames) - 1 != len(lanes):
        raise PackFormatError(
            f"pack has {len(frames) - 1} lane frame(s), meta declares "
            f"{len(lanes)}")
    nrows = int(meta["nrows"])
    decoded: Dict[str, np.ndarray] = {}
    for (name, codec), payload in zip(lanes, frames[1:]):
        decoded[name] = (_decode_lob_lane(payload) if codec == "lob"
                         else _decode_num_lane(payload, codec, nrows))
    if meta["kind"] == "data":
        row_lo, row_hi = decoded["row_lo"], decoded["row_hi"]
        if meta["key_is_row"]:
            # NoPK tables: the key signature IS the row signature — keep
            # the array identity so Δ emission can tag streams key==row
            key_lo, key_hi = row_lo, row_hi
        else:
            key_lo, key_hi = decoded["key_lo"], decoded["key_hi"]
        return DataObject(
            oid=oid, nrows=nrows,
            cols={name: decoded[name] for name, _ in meta["cols"]},
            commit_ts=decoded["commit_ts"],
            row_lo=row_lo, row_hi=row_hi, key_lo=key_lo, key_hi=key_hi,
            lob_sigs={name: decoded["lob_sig:" + name]
                      for name in meta["sig_lob"]},
            nbytes=int(meta["nbytes"]))
    if meta["kind"] == "tomb":
        return TombstoneObject(
            oid=oid, nrows=nrows, target=decoded["target"],
            key_lo=decoded["key_lo"], key_hi=decoded["key_hi"],
            commit_ts=decoded["commit_ts"],
            target_oids=tuple(meta["target_oids"]))
    raise PackFormatError(f"unknown pack kind {meta['kind']!r}")


# --------------------------------------------------------------------------
# the pack directory (tier 2)
# --------------------------------------------------------------------------

def _atomic_write(path: str, blob: bytes) -> None:
    """Durable all-or-nothing file write: tmp + fsync + rename + dir fsync.

    Readers never see a partial file — the crash point fires with the tmp
    fully written but not yet renamed, and every reader ignores ``.tmp``."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        crash_point(CP_PACK_WRITE)
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


class PackDir:
    """A local pack directory (tier 2), optionally faulting through to a
    remote directory (tier 3) for digests not yet local.

    Layout: ``<root>/objects/<sha256-hex>.dgp`` — the same layout a remote
    uses, so push/fetch are file copies keyed by digest."""

    def __init__(self, root: str, origin: Optional[str] = None):
        self.root = root
        self.objects_dir = os.path.join(root, "objects")
        self.origin = origin            # remote dir for fault-through reads
        self.metrics = None             # bound by ObjectStore.attach_packs

    # ----------------------------------------------------------- layout
    def ensure(self) -> None:
        os.makedirs(self.objects_dir, exist_ok=True)

    def path(self, digest: str) -> str:
        return os.path.join(self.objects_dir, digest + PACK_SUFFIX)

    def has(self, digest: str) -> bool:
        return os.path.exists(self.path(digest))

    def digests(self) -> Set[str]:
        if not os.path.isdir(self.objects_dir):
            return set()
        return {f[:-len(PACK_SUFFIX)] for f in os.listdir(self.objects_dir)
                if f.endswith(PACK_SUFFIX)}

    # ------------------------------------------------------------- write
    def encode(self, obj) -> Tuple[str, bytes]:
        blob = encode_object(obj)
        return blob_digest(blob), blob

    def store(self, digest: str, blob: bytes) -> bool:
        """Write a pack blob under its digest; returns False when already
        present (content-addressed: identical digest == identical bytes)."""
        if self.has(digest):
            return False
        self.ensure()
        _atomic_write(self.path(digest), blob)
        return True

    def release(self, digest: str) -> None:
        """Drop the local pack file for a GC'd digest (best-effort: a
        crash mid-sweep only leaves content-addressed garbage behind)."""
        try:
            os.unlink(self.path(digest))
        except FileNotFoundError:
            pass

    # -------------------------------------------------------------- read
    def read(self, digest: str) -> bytes:
        """The verified blob for ``digest`` — local file first, then a
        fault-through fetch from ``origin`` (cached locally)."""
        p = self.path(digest)
        if os.path.exists(p):
            with open(p, "rb") as f:
                return f.read()
        if self.origin is not None:
            src = os.path.join(self.origin, "objects", digest + PACK_SUFFIX)
            with open(src, "rb") as f:
                blob = f.read()
            if blob_digest(blob) != digest:
                raise PackFormatError(
                    f"remote object {digest[:12]}… fails its digest")
            self.store(digest, blob)
            if self.metrics is not None:
                self.metrics.add("store.objects_pulled")
            return blob
        raise KeyError(f"no pack for digest {digest[:12]}…")

    def load(self, digest: str, oid: int):
        return decode_object(self.read(digest), oid)

    # ------------------------------------------------------------ verify
    def verify(self, digest: str) -> List[str]:
        """Integrity issues for one digest (empty list = clean)."""
        p = self.path(digest)
        if not os.path.exists(p):
            if self.origin is not None:
                return []               # fault-through remote backs it
            return [f"pack {digest[:12]}… missing from {self.objects_dir}"]
        with open(p, "rb") as f:
            blob = f.read()
        if blob_digest(blob) != digest:
            return [f"pack {digest[:12]}… content does not match its "
                    "digest (bit rot or a renamed file)"]
        try:
            start = check_pack_header(blob)
            for _ in iter_frames(blob, start):
                pass
        except StoreFormatError as err:
            return [f"pack {digest[:12]}…: {err}"]
        return []


def attach_packs(store, root: str, origin: Optional[str] = None) -> PackDir:
    """Attach (or return the existing) pack tier of an ``ObjectStore``."""
    if store.packs is not None:
        return store.packs
    backend = PackDir(root, origin=origin)
    store.attach_packs(backend)
    return backend
