"""Static cost analysis over optimized HLO text, with loop trip counts.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, which
undercounts a scanned-layer model by O(depth × microbatches). This analyzer
parses the post-optimization HLO, computes per-computation costs and
propagates them through the call graph (while bodies × known_trip_count,
fusions, calls, conditionals), yielding:

  * flops             — 2·K·numel(out) summed over dot/convolution ops
                        (elementwise flops are <1% for these models),
  * dot_bytes         — operand+output bytes of every dot (≈ HBM traffic of
                        the matmul/attention stream: the roofline memory
                        numerator),
  * collective_bytes  — operand bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        per kind.

Validated against ``cost_analysis()`` on loop-free modules in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^\(?\s*([a-z0-9]+)\[([0-9,]*)\]")
_TUPLE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPNAME = re.compile(r"^(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:{[^}]*})?)\s+([\w\-]+)\(")
_CALLED = re.compile(r"(?:body|calls|to_apply|condition)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations={([^}]*)}")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERANDS = re.compile(r"\(([^)]*)\)")
_ARGREF = re.compile(r"%([\w\.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims={([0-9,]*)}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> Tuple[int, Tuple[int, ...]]:
    shape = tuple(int(d) for d in dims.split(",") if d)
    n = 1
    for d in shape:
        n *= d
    return n * DTYPE_BYTES.get(dtype, 4), shape


@dataclass
class Cost:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_bytes += other.dot_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll.values()))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.instr_shape: Dict[str, Tuple[int, Tuple[int, ...]]] = {}
        self.local_shape: Dict[str, Dict[str, Tuple[int, Tuple[int, ...]]]] = {}
        self.comps: Dict[str, List[str]] = {}
        self._parse(hlo_text)
        self._cost_cache: Dict[str, Cost] = {}
        self.entry = self._find_entry(hlo_text)

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    return m.group(1)
        raise ValueError("no ENTRY computation found")

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    continue
            if line.strip() == "}":
                continue
            mi = _INSTR.match(line)
            if mi and cur is not None:
                name, rhs = mi.group(1), mi.group(2)
                ms = _SHAPE.match(rhs)
                if ms:
                    sb = _shape_bytes(ms.group(1), ms.group(2))
                    self.instr_shape[name] = sb
                    self.local_shape.setdefault(cur, {})[name] = sb
                self.comps[cur].append(line)

    # ------------------------------------------------------------- costs
    def _operand_names(self, rhs: str, opname: str) -> List[str]:
        idx = rhs.find(opname + "(")
        if idx < 0:
            return []
        # slice to the matching close paren (operands never nest parens
        # except shapes in some dialects; names only here)
        depth = 0
        args = ""
        for ch in rhs[idx + len(opname) + 1:]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            args += ch
        return _ARGREF.findall(args)

    def _instr_cost(self, line: str, comp: str
                    ) -> Tuple[Cost, List[Tuple[str, float]]]:
        """Returns (own cost, [(called_comp, multiplier), ...])."""
        local = self.local_shape.get(comp, {})
        look = lambda n: local.get(n) or self.instr_shape.get(n)
        c = Cost()
        called: List[Tuple[str, float]] = []
        mi = _INSTR.match(line)
        if not mi:
            return c, called
        name, rhs = mi.group(1), mi.group(2)
        mo = _OPNAME.match(rhs)
        op = mo.group(1) if mo else ""

        if op in ("dot", "convolution"):
            out_b, out_shape = look(name) or (0, ())
            numel_out = 1
            for d in out_shape:
                numel_out *= d
            k = 1
            ops = self._operand_names(rhs, op)
            mc = _LHS_CDIMS.search(rhs)
            if mc and ops:
                lhs = look(ops[0])
                if lhs:
                    for ci in mc.group(1).split(","):
                        if ci:
                            k *= lhs[1][int(ci)]
            if op == "convolution" and ops:  # rough: kernel numel as K
                rhsop = look(ops[1])
                if rhsop:
                    k = 1
                    for d in rhsop[1][:-1]:
                        k *= d
            c.flops += 2.0 * numel_out * k
            c.dot_bytes += out_b
            for o in ops[:2]:
                sb = look(o)
                if sb:
                    c.dot_bytes += sb[0]
        else:
            for kind in COLLECTIVES:
                if op == kind or op == kind + "-start":
                    ops = self._operand_names(rhs, op)
                    tot = 0.0
                    for o in ops:
                        sb = look(o)
                        if sb:
                            tot += sb[0]
                    # XLA:CPU promotes bf16 all-reduces to f32 (reducer named
                    # *_promoted). A TPU backend reduces in bf16 natively —
                    # count the TPU-equivalent width.
                    if "promoted" in rhs:
                        tot *= 0.5
                    # ring cost: all-reduce moves 2(n-1)/n x operand bytes
                    # (= reduce-scatter + all-gather); count it at 2x so AR
                    # vs RS+AG decompositions compare honestly.
                    if kind == "all-reduce":
                        tot *= 2.0
                    c.coll[kind] = c.coll.get(kind, 0.0) + tot
                    break

        if "while(" in rhs:
            mt = _TRIP.search(rhs)
            trips = float(mt.group(1)) if mt else 1.0
            for mc2 in re.finditer(r"body=%?([\w\.\-]+)", rhs):
                called.append((mc2.group(1), trips))
            for mc2 in re.finditer(r"condition=%?([\w\.\-]+)", rhs):
                called.append((mc2.group(1), trips + 1.0))
        else:
            for mc2 in _CALLED.finditer(rhs):
                called.append((mc2.group(1), 1.0))
            mb = _BRANCHES.search(rhs)
            if mb:
                for nm in _ARGREF.findall(mb.group(1)):
                    called.append((nm, 1.0))
        return c, called

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        total = Cost()
        self._cost_cache[comp] = total  # breaks cycles defensively
        for line in self.comps.get(comp, ()):
            c, called = self._instr_cost(line, comp)
            total.add(c)
            for sub, mult in called:
                if sub in self.comps:
                    total.add(self.comp_cost(sub), mult)
        return total

    def total(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()
