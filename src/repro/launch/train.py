"""End-to-end fault-tolerant trainer.

Pulls everything together: versioned dataset (pinned snapshot) → pipeline →
sharded train_step → versioned checkpoints with NaN rollback.

On this CPU container it trains the reduced configs for real
(examples/train_versioned.py trains ~100 steps); the production meshes are
exercised via the dry-run. The control flow (pin → train → checkpoint →
rollback-on-fault → resume) is identical at any scale.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --reduced --seq-len 128 --batch 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import LM_SHAPES, get_config
from ..configs.base import ShapeCfg
from ..core import Engine
from ..data import (BatchPipeline, PinnedDataset, PipelineCfg,
                    create_token_table, synth_corpus)
from ..models import lm
from ..optim import AdamWCfg, apply_updates, init_opt_state
from ..optim.adamw import global_norm


def train_loop(arch: str, *, steps: int = 50, reduced: bool = True,
               seq_len: int = 128, global_batch: int = 8,
               ckpt_every: int = 20, inject_fault_at: Optional[int] = None,
               attn_block: int = 32, log_every: int = 10,
               lr: float = 3e-4, engine: Optional[Engine] = None):
    """Returns (final_state, losses, engine). ``inject_fault_at`` corrupts
    the state at that step to exercise rollback-recovery."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    engine = engine or Engine()

    # 1. versioned dataset: ingest + pin a snapshot (paper workflow)
    if "corpus" not in engine.tables:
        create_token_table(engine, "corpus")
        synth_corpus(engine, "corpus", n_samples=256,
                     sample_len=seq_len + 1, vocab=cfg.vocab)
    snap = engine.create_snapshot(f"train-pin-{engine.ts}", "corpus")
    ds = PinnedDataset(engine, snap)
    pipe = BatchPipeline(ds, PipelineCfg(seq_len=seq_len,
                                         global_batch=global_batch))

    # 2. model + optimizer
    opt_cfg = AdamWCfg(lr_peak=lr, warmup_steps=max(2, steps // 10),
                       decay_steps=steps)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}

    def loss_fn(p, b):
        return lm.loss_fn(cfg, p, b, attn_block=attn_block)

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        gnorm = global_norm(grads)
        new_p, new_o, metrics = apply_updates(state["params"], grads,
                                              state["opt"], opt_cfg)
        metrics["loss"] = loss
        return {"params": new_p, "opt": new_o}, metrics

    # 3. fault-tolerant loop (unique tag prefix per run: engines may host
    # several sequential runs, e.g. examples/train_versioned.py)
    cm = CheckpointManager(engine, every=ckpt_every,
                           prefix=f"run{engine.ts}-")
    cm.maybe_save(state, 0)
    losses = []
    step = 1
    while step <= steps:
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        if inject_fault_at is not None and step == inject_fault_at:
            # simulated hardware fault: corrupt the params
            state["params"] = jax.tree.map(
                lambda a: (a * jnp.float32(np.nan)).astype(a.dtype)
                if a.ndim >= 2 else a, state["params"])
            inject_fault_at = None
        loss = float(metrics["loss"])
        probe = float(global_norm(
            jax.tree.map(lambda a: a[:1], state["params"])))
        if not cm.healthy(loss) or not np.isfinite(probe):
            good = cm.last_tag
            state = cm.recover(state)
            step = cm.step_of(good) + 1
            print(f"[train] fault detected @ {step}: rolled back to {good}",
                  flush=True)
            continue
        losses.append(loss)
        cm.maybe_save(state, step)
        if step % log_every == 0:
            print(f"[train] step {step:4d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        step += 1
    return state, losses, engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--inject-fault-at", type=int, default=None)
    args = ap.parse_args(argv)
    _, losses, _ = train_loop(
        args.arch, steps=args.steps, reduced=args.reduced,
        seq_len=args.seq_len, global_batch=args.batch,
        inject_fault_at=args.inject_fault_at)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
