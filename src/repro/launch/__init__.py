"""Launchers: production mesh, dry-run, trainer, server.

NOTE: import ``repro.launch.dryrun`` FIRST (before any jax use) when you
need the 512-device host platform — it sets XLA_FLAGS at import time.
"""
from .mesh import make_production_mesh  # noqa: F401
