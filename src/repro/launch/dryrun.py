import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract memory / cost / collective
figures for EXPERIMENTS.md §Dry-run and §Roofline.

MUST be imported/run before any other jax initialization — the XLA_FLAGS
assignment above is the very first statement for that reason.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k [--multi-pod] [--all] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from ..configs import LM_SHAPES, all_arch_names, cells_for, get_config
from . import steps as S
from .hlo_analysis import analyze_hlo
from .mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16, make_production_mesh

def _mesh_context(mesh):
    """``jax.set_mesh`` context on new jax; the Mesh itself (a context
    manager with the same lowering effect) on jax<=0.4.x."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in the optimized HLO."""
    out: Dict[str, float] = {}
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # operand types appear inside the op's argument list
        args = line.split(m.group(0) + "(", 1)
        if len(args) < 2:
            continue
        shapes = SHAPE_RE.findall(args[1])
        total = 0
        for dt, dims in shapes:
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    return out


def analyze_cell(arch: str, shape_name: str, *, multi_pod: bool,
                 sc=None, n_micro: Optional[int] = None,
                 attn_block: int = 1024, mesh=None, cfg=None,
                 opt_cfg=None) -> Dict:
    cfg = cfg or get_config(arch)
    shape = LM_SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()  # monotonic: a wall-clock step breaks timings
    with _mesh_context(mesh):
        if shape.kind == "train":
            step, st_specs, in_sh = S.make_train_step(
                cfg, shape, mesh, sc=sc, n_micro=n_micro,
                attn_block=attn_block, opt_cfg=opt_cfg)
            st_shape = S.abstract_state(cfg, opt_cfg or S.AdamWCfg())
            abs_in, _ = S.input_specs(cfg, shape, mesh, sc)
            lowered = step.lower(st_shape, abs_in["batch"])
        elif shape.kind == "prefill":
            step, pspecs, in_sh = S.make_prefill_step(
                cfg, shape, mesh, sc=sc, attn_block=attn_block)
            import functools
            from ..models import lm
            params_shape = jax.eval_shape(
                functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
            abs_in, _ = S.input_specs(cfg, shape, mesh, sc)
            args = [params_shape, abs_in["tokens"]]
            if "ctx" in abs_in:
                args.append(abs_in["ctx"])
            lowered = step.lower(*args)
        else:
            step, pspecs, in_sh, abs_in = S.make_decode_step(
                cfg, shape, mesh, sc=sc)
            import functools
            from ..models import lm
            params_shape = jax.eval_shape(
                functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
            lowered = step.lower(params_shape, abs_in["token"],
                                 abs_in["cache"])
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax<=0.4 returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # static analysis with loop trip counts (cost_analysis counts scan
    # bodies once — see hlo_analysis.py)
    hc = analyze_hlo(hlo)
    coll = hc.coll

    flops = float(hc.flops)
    bytes_accessed = float(hc.dot_bytes)
    coll_total = float(hc.collective_bytes)

    # roofline terms (seconds); cost_analysis is per-device for SPMD modules
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll_total / ICI_LINK_BW

    # MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D for inference.
    # enc-dec: encoder params see the frame sequence, decoder params the
    # token sequence — count both streams.
    n_active = cfg.n_params_active()
    n_enc = cfg.n_params_encoder()
    B, sl = shape.global_batch, shape.seq_len
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    if shape.kind == "decode":
        model_flops = mult * (n_active - n_enc) * B
    elif cfg.is_encdec:
        model_flops = mult * ((n_active - n_enc) * B * min(448, sl)
                              + n_enc * B * sl)
    else:
        model_flops = mult * n_active * B * sl
    model_flops_per_chip = model_flops / n_chips

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device_bytes": int(getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)
                                + getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "hlo_flops": flops,
        "hlo_flops_raw_costanalysis": float(cost.get("flops", 0.0)),
        "hlo_bytes": bytes_accessed,
        "collective_bytes": coll_total,
        "collectives": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_collective)], key=lambda kv: kv[1])[0],
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops
                               if flops else 0.0),
        "roofline_fraction": (model_flops_per_chip / PEAK_FLOPS_BF16)
        / max(t_compute, t_memory, t_collective)
        if max(t_compute, t_memory, t_collective) > 0 else 0.0,
    }
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for name in all_arch_names():
            cfg = get_config(name)
            for sh in cells_for(cfg):
                cells.append((name, sh.name))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch, shp in cells:
        for mp in meshes:
            meshname = "2x16x16" if mp else "16x16"
            if (arch, shp, meshname) in done:
                continue
            print(f"=== {arch} × {shp} × {meshname}", flush=True)
            try:
                r = analyze_cell(arch, shp, multi_pod=mp)
                print(json.dumps(
                    {k: r[k] for k in ("per_device_bytes", "hlo_flops",
                                       "collective_bytes", "bottleneck",
                                       "compile_s")}), flush=True)
                results.append(r)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                results.append({"arch": arch, "shape": shp,
                                "mesh": meshname, "error": str(e)[:500]})
            json.dump(results, open(args.out, "w"), indent=1)
    n_err = sum(1 for r in results if "error" in r)
    print(f"done: {len(results)} cells, {n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
