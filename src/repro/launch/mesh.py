"""Production mesh definition (TPU v5e pods).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods =
    512 chips as (pod=2, data=16, model=16); 'pod' is pure DP (DCN-friendly:
    only gradient reduce-scatter crosses pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_LINK_BW = 50e9            # B/s per link
