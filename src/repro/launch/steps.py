"""Step builders: sharded train / prefill / decode step functions + their
input specs — shared by the dry-run, the trainer and the server.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeCfg
from ..distributed.collectives import (accumulate_microbatches,
                                       error_feedback_apply)
from ..distributed.sharding import (ModelSharding, ShardCfg, batch_spec,
                                    tree_cache_specs, tree_param_specs)
from ..models import lm
from ..optim import AdamWCfg, OptState, apply_updates, init_opt_state

BF16 = jnp.bfloat16


def as_shardings(tree, mesh: Mesh):
    """jax<=0.4 requires concrete ``Sharding``s in ``jax.jit``'s
    in/out_shardings; newer jax accepts bare PartitionSpecs (under
    ``jax.set_mesh``). Convert specs on old jax, pass through on new."""
    if hasattr(jax, "set_mesh"):
        return tree
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree, is_leaf=lambda s: isinstance(s, P))


# -------------------------------------------------------------- policies

def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))


def pick_microbatches(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                      act_budget: int = 128 << 20) -> int:
    """Accumulation factor: keep per-microbatch live activations (bf16,
    d_model-width, with remat) under ``act_budget`` per device."""
    local_b = max(1, shape.global_batch // dp_size(mesh))
    width = max(cfg.d_model,
                cfg.ssm.expand * cfg.d_model if cfg.ssm else cfg.d_model)
    per_item = shape.seq_len * width * 2 * 4  # x4: residuals + mixer buffers
    n = 1
    while local_b % (2 * n) == 0 and (local_b // n) * per_item > act_budget:
        n *= 2
    return n


def shard_cfg_for(cfg: ArchConfig, shape: ShapeCfg) -> ShardCfg:
    """Default sharding strategy per (arch, shape) cell."""
    return ShardCfg(
        fsdp=True, tp=True,
        seq_shard_cache=(shape.name == "long_500k"),
        # GQA decode: kv-heads rarely divide the 16-way TP axis — shard the
        # cache sequence over 'model' instead of replicating (§Perf cell B:
        # 310x collective, 14x memory)
        cache_seq_model=(shape.kind == "decode"),
    )


# ------------------------------------------------------------ input specs

def input_specs(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                sc: Optional[ShardCfg] = None):
    """ShapeDtypeStruct stand-ins + shardings for every step input.

    Returns (abstract_inputs: dict, shardings: dict) keyed per argument of
    the corresponding step function."""
    sc = sc or shard_cfg_for(cfg, shape)
    dp = batch_spec(mesh)
    B, S = shape.global_batch, shape.seq_len
    bspec = dp if B % dp_size(mesh) == 0 else P()

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), jnp.int32)

    ctx_len = 0
    if cfg.cross_len:
        ctx_len = cfg.cross_len
    dec_len = S
    if cfg.is_encdec:
        ctx_len, dec_len = S, min(448, S)   # frames drive the long dim

    out: Dict[str, Any] = {}
    shardings: Dict[str, Any] = {}
    if shape.kind == "train":
        batch = {"tokens": tok(B, dec_len), "targets": tok(B, dec_len)}
        bsh = {"tokens": bspec, "targets": bspec}
        if ctx_len:
            batch["ctx"] = jax.ShapeDtypeStruct((B, ctx_len, cfg.d_model),
                                                BF16)
            bsh["ctx"] = P(bspec[0] if len(bspec) else None, None, None)
        out["batch"] = batch
        shardings["batch"] = bsh
    elif shape.kind == "prefill":
        out["tokens"] = tok(B, dec_len)
        shardings["tokens"] = bspec
        if ctx_len:
            out["ctx"] = jax.ShapeDtypeStruct((B, ctx_len, cfg.d_model), BF16)
            shardings["ctx"] = P(bspec[0] if len(bspec) else None, None, None)
    else:  # decode
        out["token"] = tok(B, 1)
        shardings["token"] = bspec
        cap = min(448 + 128, S) if cfg.is_encdec else S + 128
        cache_shape = jax.eval_shape(
            lambda: lm.init_cache(cfg, B, cap, ctx_len=ctx_len))
        out["cache"] = cache_shape
        shardings["cache"] = tree_cache_specs(cfg, sc, cache_shape, mesh)
    return out, shardings


def abstract_state(cfg: ArchConfig, opt_cfg: AdamWCfg):
    """Abstract (params, opt) pytree — no allocation."""
    params = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    opt = jax.eval_shape(functools.partial(init_opt_state, cfg=opt_cfg),
                         params)
    return {"params": params, "opt": opt}


def _extend_fsdp_to_pod(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1 across pods: optimizer-state dims sharded by 'data' extend to
    ('data', 'pod') when divisible — m/v never cross the pod boundary except
    in the once-per-step update, so the extra sharding is DCN-free at use."""
    if "pod" not in mesh.axis_names:
        return spec
    total = mesh.shape["data"] * mesh.shape["pod"]
    parts = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax == "data" and dim % total == 0:
            parts.append(("data", "pod"))
        else:
            parts.append(ax)
    return P(*parts)


def _opt_specs(cfg, sc, tree_shape, mesh):
    """Param-spec tree for optimizer states, FSDP extended across 'pod'.
    (PartitionSpec is a tuple subclass, so map over flattened lists —
    jax.tree.map would descend into the specs themselves.)"""
    flat, treedef = jax.tree_util.tree_flatten(tree_shape)
    specs_flat = treedef.flatten_up_to(
        tree_param_specs(cfg, sc, tree_shape, mesh))
    out = [_extend_fsdp_to_pod(sp, leaf.shape, mesh)
           for leaf, sp in zip(flat, specs_flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def state_specs(cfg: ArchConfig, sc: ShardCfg, state_shape, mesh: Mesh):
    pspecs = tree_param_specs(cfg, sc, state_shape["params"], mesh)
    opt = state_shape["opt"]
    mu = _opt_specs(cfg, sc, opt.mu, mesh)
    nu = _opt_specs(cfg, sc, opt.nu, mesh)
    return {"params": pspecs, "opt": OptState(P(), mu, nu)}


# ------------------------------------------------------------ step fns

def make_train_step(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                    sc: Optional[ShardCfg] = None,
                    opt_cfg: Optional[AdamWCfg] = None,
                    n_micro: Optional[int] = None,
                    attn_block: int = 1024):
    """Returns (train_step, state_shardings, batch_shardings).

    train_step(state, batch) -> (state, metrics); donates state."""
    sc = sc or shard_cfg_for(cfg, shape)
    opt_cfg = opt_cfg or AdamWCfg()
    n_micro = n_micro or pick_microbatches(cfg, shape, mesh)
    params_shape = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    shd = ModelSharding(cfg, sc, mesh, params_shape)
    dp = batch_spec(mesh)

    def loss(params, mb):
        return lm.loss_fn(cfg, params, mb, attn_block=attn_block, remat=True,
                          shd=shd)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        # hoist the big FSDP gathers (embed / lm_head) out of the
        # microbatch loop — inside it they re-gather every iteration
        params_use = dict(params)
        params_use["embed"] = shd.embed(params["embed"])
        params_use["lm_head"] = shd.head(params["lm_head"])
        if n_micro > 1:
            def resh(x):
                b = x.shape[0]
                x = x.reshape((n_micro, b // n_micro) + x.shape[1:])
                # keep the microbatch slices DP-sharded (reshape across the
                # batch dim otherwise triggers an all-gather)
                return jax.lax.with_sharding_constraint(
                    x, P(*((None, dp[0] if len(dp) == 1 else dp)
                           + (None,) * (x.ndim - 2))))
            mbs = jax.tree.map(resh, batch)
            loss_val, grads = accumulate_microbatches(
                loss, params_use, mbs,
                grad_specs=tree_param_specs(cfg, sc, params_shape, mesh))
        else:
            loss_val, grads = jax.value_and_grad(loss)(params_use, batch)
        if sc.grad_compress_bf16:
            grads = jax.tree.map(lambda g: g.astype(BF16), grads)
        new_params, new_opt, metrics = apply_updates(params, grads, opt,
                                                     opt_cfg)
        metrics["loss"] = loss_val
        return {"params": new_params, "opt": new_opt}, metrics

    st_shape = abstract_state(cfg, opt_cfg)
    st_specs = state_specs(cfg, sc, st_shape, mesh)
    _, in_sh = input_specs(cfg, shape, mesh, sc)
    jitted = jax.jit(
        train_step,
        in_shardings=as_shardings((st_specs, in_sh["batch"]), mesh),
        out_shardings=as_shardings((st_specs, P()), mesh),
        donate_argnums=(0,),
    )
    return jitted, st_specs, in_sh


def make_prefill_step(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                      sc: Optional[ShardCfg] = None,
                      attn_block: int = 1024):
    sc = sc or shard_cfg_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    dec_len = min(448, S) if cfg.is_encdec else S
    cap = dec_len + 128 if not cfg.sliding_window else dec_len

    params_shape = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    shd = ModelSharding(cfg, sc, mesh, params_shape)

    def prefill_step(params, tokens, ctx=None):
        logits, cache = lm.prefill(cfg, params, tokens, ctx,
                                   seq_cap=cap, attn_block=attn_block,
                                   shd=shd)
        return logits, cache

    pspecs = tree_param_specs(cfg, sc, params_shape, mesh)
    abs_in, in_sh = input_specs(cfg, shape, mesh, sc)
    args = (pspecs, in_sh["tokens"]) + \
        ((in_sh["ctx"],) if "ctx" in in_sh else ())
    cache_shape = jax.eval_shape(
        prefill_step, params_shape, abs_in["tokens"],
        *([abs_in["ctx"]] if "ctx" in abs_in else []))[1]
    cache_specs = tree_cache_specs(cfg, sc, cache_shape, mesh)
    jitted = jax.jit(prefill_step, in_shardings=as_shardings(args, mesh),
                     out_shardings=as_shardings((P(), cache_specs), mesh))
    return jitted, pspecs, in_sh


def make_decode_step(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                     sc: Optional[ShardCfg] = None):
    sc = sc or shard_cfg_for(cfg, shape)
    params_shape = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    shd = ModelSharding(cfg, sc, mesh, params_shape)

    def decode(params, token, cache):
        return lm.decode_step(cfg, params, token, cache, shd=shd)

    pspecs = tree_param_specs(cfg, sc, params_shape, mesh)
    abs_in, in_sh = input_specs(cfg, shape, mesh, sc)
    jitted = jax.jit(
        decode,
        in_shardings=as_shardings((pspecs, in_sh["token"], in_sh["cache"]),
                                  mesh),
        out_shardings=as_shardings((P(), in_sh["cache"]), mesh),
        donate_argnums=(2,),
    )
    return jitted, pspecs, in_sh, abs_in
