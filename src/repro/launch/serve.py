"""Batched serving driver: continuous-batching decode loop.

Prefills requests into per-slot KV caches, then decodes in lockstep; a slot
whose request finishes is immediately refilled from the queue (continuous
batching). On the production mesh the same loop runs with the sharded
prefill/decode step functions from launch.steps.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Server:
    """Fixed-batch continuous-batching server (one cache per slot)."""

    def __init__(self, arch: str, *, reduced: bool = True, batch: int = 4,
                 seq_cap: int = 256, attn_block: int = 32,
                 params=None, seed: int = 0):
        self.cfg = get_config(arch).reduced() if reduced else get_config(arch)
        self.batch = batch
        self.seq_cap = seq_cap
        self.attn_block = attn_block
        self.params = params if params is not None else lm.init_params(
            self.cfg, jax.random.PRNGKey(seed))
        self.slots: List[Optional[Request]] = [None] * batch
        self.caches: List = [None] * batch
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(self.cfg, p, t, c))

    def _prefill_one(self, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        ctx = None
        if self.cfg.is_encdec or self.cfg.cross_len:
            L = self.cfg.cross_len or 8
            ctx = jnp.zeros((1, L, self.cfg.d_model), jnp.bfloat16)
        pad = (-toks.shape[1]) % self.attn_block
        if pad:
            toks = jnp.pad(toks, ((0, 0), (0, pad)))
        logits, cache = lm.prefill(self.cfg, self.params, toks, ctx,
                                   seq_cap=self.seq_cap,
                                   attn_block=self.attn_block)
        if pad:  # position counter must reflect the unpadded prompt
            cache["len"] = cache["len"] - pad
        return logits, cache

    def run(self, requests: List[Request], greedy: bool = True):
        queue = list(requests)
        t0 = time.perf_counter()  # monotonic: a wall-clock step breaks dt
        steps = 0
        while any(s is not None for s in self.slots) or queue:
            # fill empty slots (continuous batching)
            for i in range(self.batch):
                if self.slots[i] is None and queue:
                    req = queue.pop(0)
                    logits, cache = self._prefill_one(req)
                    tok = int(jnp.argmax(logits[0]))
                    req.out.append(tok)
                    self.slots[i] = req
                    self.caches[i] = cache
            if all(s is None for s in self.slots):
                break
            # lockstep decode over active slots
            for i in range(self.batch):
                req = self.slots[i]
                if req is None or req.done:
                    continue
                tok = jnp.asarray([[req.out[-1]]], jnp.int32)
                logits, self.caches[i] = self._decode(self.params, tok,
                                                      self.caches[i])
                req.out.append(int(jnp.argmax(logits[0])))
            steps += 1
            for i in range(self.batch):
                if self.slots[i] is not None and self.slots[i].done:
                    self.slots[i] = None
                    self.caches[i] = None
        dt = time.perf_counter() - t0
        return requests, dt, steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args(argv)
    srv = Server(args.arch, batch=args.batch)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(2, srv.cfg.vocab, size=16).astype(np.int32),
                    args.max_new) for i in range(args.requests)]
    done, dt, steps = srv.run(reqs)
    tput = sum(len(r.out) for r in done) / dt
    print(f"served {len(done)} requests, {steps} decode steps, "
          f"{tput:.1f} tok/s")


if __name__ == "__main__":
    main()
