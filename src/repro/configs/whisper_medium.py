"""whisper-medium — audio encoder-decoder transformer backbone.

The conv frontend is a STUB per assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S, d_model) to the encoder. Decoder is a
standard causal transformer with cross-attention to the encoder output and
learned absolute positions. [arXiv:2212.04356; unverified]
"""
from .base import ArchConfig, register


@register
def whisper_medium() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        encoder_layers=24,
        period=1, slots=("attn",),         # decoder self-attn; cross added
        rope=False, learned_pos=True, max_seq=65536,
        source="arXiv:2212.04356; unverified",
    )
