"""internlm2-1.8b — dense LM, GQA kv=8.
[arXiv:2403.17297; hf]"""
from .base import ArchConfig, register


@register
def internlm2_1_8b() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92544,
        source="arXiv:2403.17297; hf",
    )
