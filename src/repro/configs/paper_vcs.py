"""paper_vcs — the paper's own workload: a TPC-H-lineitem-like versioned
table (scaled). Not an LM; selecting ``--arch paper_vcs`` in the launchers
runs the version-control benchmark workload instead of a model."""
from __future__ import annotations

import numpy as np

from ..core import Column, CType, Schema

LINEITEM_SCHEMA = Schema(
    columns=(
        Column("l_orderkey", CType.I64),
        Column("l_linenumber", CType.I32),
        Column("l_partkey", CType.I64),
        Column("l_suppkey", CType.I64),
        Column("l_quantity", CType.F64),
        Column("l_extendedprice", CType.F64),
        Column("l_discount", CType.F64),
        Column("l_tax", CType.F64),
        Column("l_returnflag", CType.I32),
        Column("l_linestatus", CType.I32),
        Column("l_shipdate", CType.I64),
        Column("l_comment", CType.LOB),
    ),
    primary_key=("l_orderkey", "l_linenumber"),
)

LINEITEM_SCHEMA_NOPK = Schema(LINEITEM_SCHEMA.columns, primary_key=None)


def gen_lineitem(n: int, seed: int = 0, comments: bool = True):
    """Synthetic lineitem rows (clustered by (orderkey, linenumber) like the
    paper's load order)."""
    rng = np.random.default_rng(seed)
    orderkey = np.repeat(np.arange(n // 4 + 1, dtype=np.int64), 4)[:n]
    linenumber = (np.arange(n, dtype=np.int64) % 4 + 1).astype(np.int32)
    batch = {
        "l_orderkey": orderkey,
        "l_linenumber": linenumber,
        "l_partkey": rng.integers(1, 200_000, n).astype(np.int64),
        "l_suppkey": rng.integers(1, 10_000, n).astype(np.int64),
        "l_quantity": rng.integers(1, 50, n).astype(np.float64),
        "l_extendedprice": np.round(rng.uniform(900, 105_000, n), 2),
        "l_discount": np.round(rng.uniform(0, 0.1, n), 2),
        "l_tax": np.round(rng.uniform(0, 0.08, n), 2),
        "l_returnflag": rng.integers(0, 3, n).astype(np.int32),
        "l_linestatus": rng.integers(0, 2, n).astype(np.int32),
        "l_shipdate": rng.integers(8000, 11000, n).astype(np.int64),
    }
    if comments:
        tags = rng.integers(0, 1 << 30, n)
        batch["l_comment"] = np.array(
            [b"comment-%d" % t for t in tags], dtype=object)
    else:
        batch["l_comment"] = np.array([b""] * n, dtype=object)
    return batch
