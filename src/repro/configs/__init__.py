"""Architecture registry: one module per assigned architecture."""
from .base import (ArchConfig, MoECfg, SSMCfg, ShapeCfg, LM_SHAPES,  # noqa
                   SUBQUADRATIC, cells_for, get_config, all_arch_names)

from . import (granite_20b, qwen1_5_0_5b, deepseek_7b, internlm2_1_8b,  # noqa
               whisper_medium, llama_3_2_vision_90b, jamba_1_5_large_398b,
               phi3_5_moe_42b, mixtral_8x7b, rwkv6_7b, paper_vcs)

ALL = True  # marker: all configs registered
