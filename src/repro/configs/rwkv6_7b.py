"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay;
token-shift + chunked WKV6 linear attention. O(1) decode state makes
long_500k trivial. [arXiv:2404.05892; hf]"""
from .base import ArchConfig, SSMCfg, register


@register
def rwkv6_7b() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab=65536,
        period=1, slots=("rwkv",),
        ssm=SSMCfg(kind="rwkv6", d_state=64, head_dim=64),
        rope=False,
        source="arXiv:2404.05892; hf",
    )
