"""Architecture configs: the assigned 10 architectures + the paper workload.

Every architecture is selectable via ``--arch <id>`` in the launchers. The
config captures the exact published hyperparameters; smoke tests use
``reduced()`` copies (same family/block pattern, tiny widths).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int = 2
    every: int = 1          # MoE FFN every k-th layer (1 = all layers)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    kind: str = "mamba"     # "mamba" (SSD form) | "rwkv6"
    d_state: int = 16       # mamba state size / rwkv6 key head dim
    head_dim: int = 64      # channels per decay head
    d_conv: int = 4         # mamba causal conv width
    expand: int = 2         # mamba inner expansion


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # SWA (mixtral)
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # layer pattern: period length and the slot kinds within one period
    period: int = 1
    slots: Tuple[str, ...] = ("attn",)        # attn | mamba | rwkv | cross
    ffn_slots: Optional[Tuple[str, ...]] = None  # mlp | moe (default all mlp
    #                                              or all moe if moe set)
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    # cross-attention context length (vlm patches / audio frames)
    cross_len: int = 0
    learned_pos: bool = False                 # whisper-style abs positions
    max_seq: int = 8192                       # learned-pos table size
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # notes for DESIGN/roofline
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers,
                                                  self.period)
        return self.n_layers // self.period

    def slot_kinds(self) -> Tuple[str, ...]:
        assert len(self.slots) == self.period
        return self.slots

    def ffn_kinds(self) -> Tuple[str, ...]:
        if self.ffn_slots is not None:
            assert len(self.ffn_slots) == self.period
            return self.ffn_slots
        kind = "moe" if self.moe else "mlp"
        return tuple(kind for _ in range(self.period))

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def n_params(self) -> float:
        """Approximate parameter count (for 6ND roofline math)."""
        d, hd = self.d_model, self.hd
        per_layer = {}
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        mlp = 3 * d * self.d_ff     # gated
        kinds = self.slot_kinds()
        ffns = self.ffn_kinds()
        total = 0.0
        for s, f in zip(kinds, ffns):
            if s in ("attn", "cross"):
                total += attn
            elif s == "mamba":
                di = self.ssm.expand * d
                total += 2 * d * di + di * d + di * (2 * self.ssm.d_state + 2)
            elif s == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o (+ small decay mlps)
            if f == "moe":
                total += self.moe.n_experts * 3 * d * self.d_ff
            else:
                total += mlp
        total *= self.n_periods
        if self.is_encdec:  # encoder stack: self-attn + mlp per layer
            total += self.encoder_layers * (attn + 3 * d * self.d_ff)
        total += 2 * self.vocab * d  # embed + lm head
        return float(total)

    def n_params_encoder(self) -> float:
        """Encoder-stack params only (enc-dec archs)."""
        if not self.is_encdec:
            return 0.0
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        return float(self.encoder_layers * (attn + 3 * d * self.d_ff))

    def n_params_active(self) -> float:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        moe_total = 0.0
        for f in self.ffn_kinds():
            if f == "moe":
                moe_total += self.moe.n_experts * 3 * d * self.d_ff
        moe_total *= self.n_periods
        active_moe = moe_total * self.moe.top_k / self.moe.n_experts
        return self.n_params() - moe_total + active_moe

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = {}
        scale["d_model"] = 64
        scale["n_heads"] = 4
        scale["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
        scale["head_dim"] = 16
        scale["d_ff"] = 128
        scale["vocab"] = 512
        scale["n_layers"] = 2 * self.period
        scale["encoder_layers"] = 2 if self.is_encdec else 0
        scale["cross_len"] = 8 if (self.cross_len or self.is_encdec) else 0
        scale["max_seq"] = 256
        if self.moe:
            scale["moe"] = MoECfg(n_experts=4, top_k=2, every=self.moe.every,
                                  capacity_factor=self.moe.capacity_factor)
        if self.ssm:
            scale["ssm"] = SSMCfg(kind=self.ssm.kind, d_state=4, head_dim=16,
                                  d_conv=self.ssm.d_conv, expand=2)
        if self.sliding_window:
            scale["sliding_window"] = 16
        return dataclasses.replace(self, **scale)


# ---------------------------------------------------------------- shapes

@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


LM_SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# archs whose attention is sub-quadratic in context (SSM state, hybrid with
# sparse attention, or bounded sliding window) — eligible for long_500k
SUBQUADRATIC = {"rwkv6-7b", "jamba-1.5-large-398b", "mixtral-8x7b"}


def cells_for(arch: ArchConfig):
    """The (arch × shape) dry-run cells; long_500k only if sub-quadratic."""
    out = []
    for s in LM_SHAPES.values():
        if s.name == "long_500k" and arch.name not in SUBQUADRATIC:
            continue
        out.append(s)
    return out


_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]):
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ArchConfig:
    from . import ALL  # noqa: F401  (forces registration of all configs)
    return _REGISTRY[name]()


def all_arch_names():
    from . import ALL  # noqa: F401
    return sorted(_REGISTRY.keys())
