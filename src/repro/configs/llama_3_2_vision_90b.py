"""llama-3.2-vision-90b — VLM backbone: 100 layers with cross-attention
image layers every 5th layer. The vision tower is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, cross_len, d_model).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from .base import ArchConfig, register


@register
def llama_3_2_vision_90b() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128256,
        period=5, slots=("cross", "attn", "attn", "attn", "attn"),
        cross_len=6404,     # 4 images x 1601 patch embeddings (stub frontend)
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )
