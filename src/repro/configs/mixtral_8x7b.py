"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention (4096).
SWA bounds the KV cache, making 500k-context decode feasible (long_500k
eligible). [arXiv:2401.04088; hf]"""
from .base import ArchConfig, MoECfg, register


@register
def mixtral_8x7b() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        sliding_window=4096,
        moe=MoECfg(n_experts=8, top_k=2, every=1),
        source="arXiv:2401.04088; hf",
    )
