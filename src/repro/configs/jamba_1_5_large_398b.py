"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with
MoE(16e, top-2) every other layer. Attention layers use GQA kv=8 and no
positional encoding (Mamba layers carry position). [arXiv:2403.19887; hf]

TPU adaptation note (DESIGN.md §2): the Mamba mixer is implemented in the
SSD (matmul/chunked) formulation rather than the GPU selective-scan kernel.
"""
from .base import ArchConfig, MoECfg, SSMCfg, register


@register
def jamba_1_5_large_398b() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536,
        period=8,
        slots=("mamba", "mamba", "mamba", "mamba",
               "attn", "mamba", "mamba", "mamba"),
        ffn_slots=("mlp", "moe", "mlp", "moe", "mlp", "moe", "mlp", "moe"),
        moe=MoECfg(n_experts=16, top_k=2, every=2),
        ssm=SSMCfg(kind="mamba", d_state=16, head_dim=64, d_conv=4, expand=2),
        rope=False,
        source="arXiv:2403.19887; hf",
    )
