"""``datagit`` — git-style CLI over the VCS statement layer (ISSUE 5).

Every subcommand compiles to a ``core.statements`` statement and executes
it against a :class:`~repro.core.Repo`, so the CLI, the statement string,
and the Python API are three doors into the SAME resolver and verb set
(the golden parity test pins byte-identical results across all three).

State persists as a serialized WAL: each invocation replays the store file
into an engine, runs the command, and writes the appended WAL back — crash
recovery and the CLI share one durability story.

  PYTHONPATH=src python -m repro.vcs_cli --store /tmp/demo.wal init
  ... seed orders --rows 10000
  ... branch dev -t orders
  ... mutate dev/orders --rows 200 --seed 1
  ... diff 'branch:dev' HEAD --table orders
  ... pr open dev
  ... publish 1
  ... log orders
  ... revert-pr 1
  ... gc
  ... fsck
  ... lint --format json

``seed`` / ``mutate`` generate deterministic demo data (they are the only
subcommands that do not map onto a statement — statements are the VCS
surface, not a DML surface). ``lint`` runs the static invariant analysis
suite (``repro.analysis``) over the source tree; it needs no store at
all and shares the runner with ``python -m repro.analysis`` and the
``LINT`` statement.

Caveat on ``pr check``: user CI checks are in-process Python callables
(``repo.pr(n).add_check(fn)``) and cannot survive the WAL round-trip, so
a fresh ``dg`` invocation sees none of them — across processes the gate
catches only the built-in merge-conflict preview (which is still exit-1
gateable). Long-lived checks belong in the Python/embedding surface.
"""
from __future__ import annotations

import argparse
import json
import os
import pickle
import sys
from typing import List, Optional

import numpy as np

from .core import (AmbiguousRefError, Column, CorruptFrame, CType,
                   MergeConflictError, PKViolation, PublishBlocked, Repo,
                   RefSyntaxError, RevertConflict, Schema, StoreFormatError,
                   StoreVersionError, TornFrame, TxnConflict,
                   UnknownRefError, WAL, as_branch)
from .core import telemetry
from .core.engine import Engine
from .core.faults import crash_point, register
from .core.statements import StatementError, execute, execute_script
from .core.wal import (STORE_HEADER, check_store_header, encode_frame,
                       iter_frames)

DEMO_SCHEMA = Schema((Column("k", CType.I64), Column("v", CType.F64),
                      Column("doc", CType.LOB)), primary_key=("k",))
DEMO_SCHEMA_NOPK = Schema(DEMO_SCHEMA.columns, primary_key=None)


# --------------------------------------------------------------------------
# store persistence — checksummed append-only WAL frames
#
# The store file is the DGWS framed format of ``core.wal``: an 8-byte
# magic/version header, then one CRC32C frame per invocation holding the
# records that invocation appended. Load verifies every frame and replays;
# save appends ONLY the records new since load — O(delta) I/O per command,
# not O(history), which is also the WAL's own durability story (a log you
# append to, not a snapshot you rewrite).
#
# Failure surface (all typed, never pickle garbage):
#   torn tail     -> recovered at load; bytes preserved to <store>.corrupt
#                    and truncated at the NEXT save (never parsed past)
#   flipped bit   -> CorruptFrame naming the frame; `fsck --repair` can
#                    truncate to the last clean frame (tail preserved)
#   wrong version -> StoreVersionError with an upgrade hint; legacy
#                    headerless pickle stores load once and are rewritten
#                    in the framed format on the next save
# --------------------------------------------------------------------------

CP_SAVE_MID_FRAME = register(
    "cli.save.mid_frame",
    "half of a store frame's bytes are on disk when the process dies — "
    "load must recover to the previous clean frame and preserve the torn "
    "tail to the .corrupt sidecar")
CP_SAVE_PRE_FSYNC = register(
    "cli.save.pre_fsync",
    "the frame is fully written but not fsynced — the frame may or may "
    "not survive; both recoveries are all-or-nothing")


def _preserve_tail(store: str, tail: bytes) -> bool:
    """Preserve dropped bytes to ``<store>.corrupt`` — NEVER silently
    discard. Returns False when this exact tail is already preserved (so
    the recovery hint prints once, not on every subsequent load)."""
    if not tail:
        return False
    side = store + ".corrupt"
    if os.path.exists(side):
        with open(side, "rb") as f:
            if f.read().endswith(tail):
                return False
    with open(side, "ab") as f:
        f.write(tail)
        f.flush()
        # lint: crash-ok sidecar preservation is best-effort forensics —
        # a crash here loses no acknowledged data (the store is untouched)
        os.fsync(f.fileno())
    return True


def load_repo(store: str) -> Repo:
    wal = WAL()
    clean_end = 0
    rewrite = False                 # next save must rewrite the whole file
    blob = b""
    if os.path.exists(store):
        with open(store, "rb") as f:
            blob = f.read()
    if blob:
        start = check_store_header(blob)
        if start < 0:
            # one-shot legacy path: pre-frame stores are a bare sequence
            # of pickle frames with no checksums — load them once, then
            # save_repo upgrades the file to the framed format
            import io
            bio = io.BytesIO(blob)
            while True:
                try:
                    recs = pickle.load(bio)
                except Exception:   # EOF = done; anything else = torn tail
                    break
                wal.records.extend(recs)
                clean_end = bio.tell()
            rewrite = True
            if _preserve_tail(store, blob[clean_end:]):
                print(f"warning: {len(blob) - clean_end} byte(s) of torn "
                      f"trailing frame in {store} (unacknowledged crashed "
                      f"write) preserved to {store}.corrupt",
                      file=sys.stderr)
        else:
            clean_end = start
            try:
                for payload, end in iter_frames(blob, start):
                    wal.records.extend(pickle.loads(payload))
                    clean_end = end
            except TornFrame as err:
                # recoverable by construction: the tail was never
                # acknowledged. Preserve it; the next save truncates.
                if _preserve_tail(store, err.tail):
                    print(f"warning: {len(err.tail)} byte(s) of torn "
                          f"trailing frame in {store} (unacknowledged "
                          f"crashed write) preserved to {store}.corrupt",
                          file=sys.stderr)
            # CorruptFrame / StoreVersionError propagate: mid-file damage
            # is not self-healing — main() surfaces the typed error and
            # points at `fsck --repair`
    n_loaded = len(wal.records)
    refs_path = store + ".refs"
    refs_origin = None
    if os.path.exists(refs_path) and not rewrite:
        # refs-mode store (ISSUE 10): rebuild from the refs snapshot and
        # fault objects from the pack tier lazily — only WAL records past
        # the snapshot (a crash tail) replay. The WAL stays authoritative
        # locally; the refs file is a derived cache refreshed at save.
        from .store.packs import PackDir
        from .store.remote import decode_refs, import_refs
        with open(refs_path, "rb") as f:
            payload = decode_refs(f.read())
        refs_origin = payload.get("origin")
        packs = PackDir(store + ".packs", origin=refs_origin)
        engine = import_refs(payload, wal, packs)
    else:
        engine = Engine.replay(wal)  # adopts `wal`, so new records append
        refs_path = None
    repo = Repo(engine)
    if len(wal.records) != n_loaded:
        # replay dropped a torn trailing commit group: the on-disk frames
        # still carry it, so appending after them would turn it into
        # mid-log damage — rewrite the store whole on the next save
        rewrite = True
    repo._persisted_records = len(wal.records)
    repo._persisted_offset = clean_end
    repo._rewrite_store = rewrite
    repo._refs_path = refs_path
    repo._refs_origin = refs_origin
    return repo


def save_repo(store: str, repo: Repo) -> None:
    done = getattr(repo, "_persisted_records", 0)
    new = repo.engine.wal.records[done:]
    exists = os.path.exists(store)
    if not new and exists:
        # nothing appended: read-only commands must never touch the store
        # file — even a pending legacy upgrade / torn-group rewrite waits
        # for the next MUTATING command (the load path handles the old
        # format again until then)
        return
    if getattr(repo, "_rewrite_store", False):
        # legacy upgrade (or a dropped torn txn group): rewrite the whole
        # store in the framed format, atomically via rename
        tmp = store + ".tmp"
        with open(tmp, "wb") as f:
            f.write(STORE_HEADER)
            f.write(encode_frame(pickle.dumps(
                repo.engine.wal.records, protocol=pickle.HIGHEST_PROTOCOL)))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, store)
        repo.engine.wal.bytes_written += os.path.getsize(store)
        repo.engine.wal.fsyncs += 1
        repo._persisted_offset = os.path.getsize(store)
        repo._persisted_records = len(repo.engine.wal.records)
        repo._rewrite_store = False
        _save_refs(store, repo)
        return
    offset = getattr(repo, "_persisted_offset", 0)
    with open(store, "r+b" if exists else "wb") as f:
        if offset < len(STORE_HEADER):
            f.write(STORE_HEADER)
            offset = len(STORE_HEADER)
        f.seek(0, os.SEEK_END)
        if f.tell() > offset:
            # torn tail from a previous crash: already preserved by
            # load_repo; truncate HERE, at save-time, so a purely
            # read-only session never modifies the store file
            f.seek(offset)
            _preserve_tail(store, f.read())
            f.truncate(offset)
        f.seek(offset)
        frame = encode_frame(pickle.dumps(new,
                                          protocol=pickle.HIGHEST_PROTOCOL))
        # two-part write around the crash point so an injected mid-frame
        # kill leaves genuinely torn bytes on disk for load to recover
        half = len(frame) // 2
        f.write(frame[:half])
        f.flush()
        crash_point(CP_SAVE_MID_FRAME)
        f.write(frame[half:])
        f.flush()
        crash_point(CP_SAVE_PRE_FSYNC)
        os.fsync(f.fileno())
        repo.engine.wal.bytes_written += len(frame)
        repo.engine.wal.fsyncs += 1
        repo._persisted_offset = f.tell()
    repo._persisted_records = done + len(new)
    _save_refs(store, repo)


def _save_refs(store: str, repo: Repo) -> None:
    """Refresh the refs snapshot of a refs-mode store (ISSUE 10).

    Runs AFTER the WAL bytes are durable: locally the WAL is the commit
    point and the refs file only caches the replayed state, so a crash
    between the two just means the next load replays a short tail."""
    refs_path = getattr(repo, "_refs_path", None)
    if refs_path is None:
        return
    from .store.packs import _atomic_write, attach_packs
    from .store.remote import encode_refs, export_refs
    e = repo.engine
    origin = getattr(repo, "_refs_origin", None)
    attach_packs(e.store, store + ".packs", origin=origin)
    e.store.spill_all()             # every live object gets a pack copy
    _atomic_write(refs_path, encode_refs(export_refs(
        e, dict(e.store._packed), origin=e.store.packs.origin)))


# --------------------------------------------------------------------------
# demo data (deterministic; the only non-statement subcommands)
# --------------------------------------------------------------------------

def _demo_batch(keys: np.ndarray, seed: int):
    rng = np.random.default_rng(seed)
    return {"k": keys.astype(np.int64),
            "v": np.round(rng.random(keys.shape[0]) * 100.0, 6),
            "doc": [b"doc-%d-%d" % (seed, int(k)) for k in keys]}


def seed_table(repo: Repo, table: str, rows: int, seed: int,
               nopk: bool = False) -> str:
    if table not in repo.engine.tables:
        repo.create_table(table, DEMO_SCHEMA_NOPK if nopk else DEMO_SCHEMA)
    repo.insert(table, _demo_batch(np.arange(rows), seed))
    return f"table {table} seeded with {rows} row(s) (seed={seed})"


def mutate_table(repo: Repo, table: str, rows: int, seed: int) -> str:
    batch, _ = repo.table(table).scan()
    keys = np.sort(batch["k"])
    rng = np.random.default_rng(seed)
    pick = np.sort(rng.choice(keys, size=min(rows, keys.shape[0]),
                              replace=False))
    upd = _demo_batch(pick, seed)
    upd["doc"] = [b"mut-%d-%d" % (seed, int(k)) for k in pick]
    repo.update_by_keys(table, upd)
    return f"table {table}: {pick.shape[0]} row(s) updated (seed={seed})"


# --------------------------------------------------------------------------
# subcommand -> statement compilation
# --------------------------------------------------------------------------

def _q(ref: str) -> str:
    """Quote a ref-position arg into statement text. No legal ref contains
    a quote — reject instead of letting it escape the quoting and be
    reinterpreted as statement syntax (the _ident() rationale)."""
    if "'" in ref:
        raise ValueError(f"invalid ref {ref!r}: refs cannot contain \"'\"")
    return "'" + ref + "'"


def _ident(name: str, what: str) -> str:
    """Name-position CLI args are interpolated into statement text
    unquoted — validate them first so `dg branch "dev FOR (prod)"` is an
    error, not silently reinterpreted as statement syntax."""
    from .core.refs import validate_name
    return validate_name(name, what)


def _branch_ident(name: str) -> str:
    """Branch-position arg: a `branch:` qualifier is legal, strip it."""
    return _ident(name[len("branch:"):] if name.startswith("branch:")
                  else name, "branch name")


def _compile(args, repo: Repo) -> Optional[str]:
    """The statement a subcommand compiles to (None = handled natively)."""
    c = args.cmd
    if c == "branch":
        name = _ident(args.name, "branch name")
        if args.delete:
            return f"DROP BRANCH {name}"
        stmt = f"CREATE BRANCH {name}"
        if args.from_ref:
            stmt += f" FROM {_q(args.from_ref)}"
        if args.tables is not None:
            if not args.tables:
                raise ValueError("branch: -t/--tables needs at least one "
                                 "table (omit it to branch every table)")
            stmt += " FOR (" + ", ".join(
                _ident(t, "table name") for t in args.tables) + ")"
        return stmt
    if c == "snapshot":
        name = _ident(args.name, "snapshot name")
        if args.delete:
            return f"DROP SNAPSHOT {name}"
        if not args.table:
            raise ValueError("snapshot: a table is required "
                             "(snapshot NAME TABLE)")
        return (f"CREATE SNAPSHOT {name} FOR TABLE "
                f"{_ident(args.table, 'table name')}")
    if c == "clone":
        return (f"CLONE TABLE {_ident(args.new, 'table name')} "
                f"FROM {_q(args.ref)}"
                + (" MATERIALIZE" if args.materialize else ""))
    if c == "push":
        return f"PUSH TO {_q(args.remote)}"
    if c == "diff":
        stmt = f"DIFF {_q(args.a)} AGAINST {_q(args.b)}"
        if args.table:
            stmt += f" FOR TABLE {_ident(args.table, 'table name')}"
        return stmt
    if c == "merge":
        # both sides branches -> whole-branch atomic merge; else table
        # form. The into-position prefers an exact table name (same rule
        # as Repo.merge / MERGE ... INTO TABLE): a branch sharing the
        # name must not make the table unreachable from the CLI.
        dst_is_table = args.dst in repo.engine.tables
        if (not dst_is_table
                and as_branch(repo.engine, args.src) is not None
                and as_branch(repo.engine, args.dst) is not None):
            # MERGE BRANCH takes bare names: strip a branch: qualifier the
            # user (legitimately) wrote, instead of double-prefixing it
            src, dst = _branch_ident(args.src), _branch_ident(args.dst)
            stmt = f"MERGE BRANCH {src} INTO {dst}"
            if args.mode:
                stmt += f" MODE {_ident(args.mode, 'mode')}"
            if args.tables is not None:
                if not args.tables:
                    raise ValueError("merge: -t/--tables needs at least "
                                     "one table (omit it to merge every "
                                     "shared table)")
                stmt += " FOR (" + ", ".join(
                    _ident(t, "table name") for t in args.tables) + ")"
            return stmt
        if args.tables is not None:
            raise ValueError("merge: -t/--tables only applies to "
                             "branch-to-branch merges")
        stmt = (f"MERGE {_q(args.src)} INTO TABLE "
                f"{_ident(args.dst, 'table name')}")
        if args.mode:
            stmt += f" MODE {_ident(args.mode, 'mode')}"
        return stmt
    if c == "pr":
        if args.pr_cmd == "open":
            stmt = f"OPEN PR FROM {_branch_ident(args.head)}"
            if args.into:
                stmt += f" INTO {_branch_ident(args.into)}"
            return stmt
        if args.pr_cmd == "check":
            return f"CHECK PR {args.id}"
        return f"CLOSE PR {args.id}"
    if c == "publish":
        return (f"PUBLISH PR {args.id}"
                + (f" MODE {_ident(args.mode, 'mode')}"
                   if args.mode else ""))
    if c == "revert-pr":
        return f"REVERT PR {args.id}"
    if c == "revert":
        return (f"REVERT TABLE {_ident(args.table, 'table name')} "
                f"FROM {_q(args.from_ref)} TO {_q(args.to_ref)}")
    if c == "restore":
        return (f"RESTORE TABLE {_ident(args.table, 'table name')} "
                f"TO {_q(args.ref)}")
    if c == "log":
        return (f"LOG TABLE {_ident(args.table, 'table name')}"
                + (f" LIMIT {args.limit}" if args.limit is not None else ""))
    if c == "branches":
        return "SHOW BRANCHES"
    if c == "snapshots":
        return "SHOW SNAPSHOTS"
    if c == "prs":
        return "SHOW PRS"
    if c == "tables":
        return "SHOW TABLES"
    if c == "status":
        return "STATUS"
    if c == "stats":
        return "STATS"
    if c == "gc":
        return "GC"
    return None


#: subcommands that only read — skipped on store write-back. ``sql`` is
#: NOT here: raw statements may mutate, so their WAL must persist. ``gc``
#: IS here: it is deliberately un-WAL-logged, so the write-back would be
#: byte-identical wasted I/O. ``push``/``fetch`` write to the REMOTE (or
#: the pack sidecar), never to the store file itself.
_READ_ONLY = {"diff", "log", "branches", "snapshots", "prs", "tables",
              "status", "stats", "gc", "push", "fetch"}

#: error types with a deliberate user-facing shape (ref/statement/VCS
#: semantics, durable-format damage); anything else caught below gets its
#: class name surfaced
_TYPED_ERRORS = (UnknownRefError, AmbiguousRefError, RefSyntaxError,
                 StatementError, MergeConflictError, PublishBlocked,
                 RevertConflict, PKViolation, TxnConflict,
                 StoreFormatError)


def _store_fsck(store: str, repair: bool) -> int:
    """Byte-level pass of `dg fsck`: header + frame CRC verification.

    Returns the count of UNREPAIRED store-level problems. With ``repair``,
    a corrupt frame is handled git-style: everything from the bad frame
    onward moves to ``<store>.corrupt`` and the store truncates to the
    last clean prefix (acknowledged data is lost but preserved — the
    report says exactly how many bytes)."""
    with open(store, "rb") as f:
        blob = f.read()
    if not blob:
        return 0
    try:
        clean = check_store_header(blob)
        if clean < 0:
            print(f"store: legacy headerless format (no checksums) — "
                  "loads once; any write upgrades it to the framed format")
            return 0
        for _, end in iter_frames(blob, clean):
            clean = end
    except TornFrame as err:
        preserved = _preserve_tail(store, err.tail)
        print(f"store: torn tail — {len(err.tail)} unacknowledged byte(s) "
              f"past offset {err.clean_end}"
              + (f" (preserved to {store}.corrupt)" if preserved
                 else " (already preserved)"))
        return 0                    # recoverable: load handles this
    except (CorruptFrame, StoreVersionError) as err:
        print(f"store: {err}")
        if repair and isinstance(err, CorruptFrame):
            _preserve_tail(store, blob[err.offset:])
            with open(store, "r+b") as f:
                f.truncate(err.offset)
                f.flush()
                # lint: crash-ok repair truncation is idempotent — a
                # crash here re-runs fsck --repair to the same offset
                os.fsync(f.fileno())
            print(f"store: truncated to last clean frame at offset "
                  f"{err.offset}; {len(blob) - err.offset} byte(s) "
                  f"preserved to {store}.corrupt")
            return 0
        if isinstance(err, CorruptFrame):
            print("hint: `fsck --repair` truncates to the last clean "
                  "frame (damaged bytes preserved to the .corrupt "
                  "sidecar), or restore the store from a backup")
        return 1
    return 0


def _cmd_fsck(args) -> int:
    bad = _store_fsck(args.store, args.repair)
    if bad:
        return 1
    repo = load_repo(args.store)
    report = repo.fsck(sample=args.sample,
                       check_replay=not args.no_replay,
                       repair=args.repair)
    print(report.summary())
    for issue in report.issues:
        print(str(issue))
    if report.repaired:
        # engine state derives from the WAL at every load; quarantine
        # results live only in this process — the durable fix for a
        # WAL-backed store is the byte-level truncation above
        print("note: object-level repairs apply to this process; the "
              "store re-derives state from its WAL on every load")
    return 0 if report.ok else 1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="datagit", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--store", default=os.environ.get("VCS_STORE",
                                                      ".vcs_store.wal"),
                    help="WAL store file (default $VCS_STORE or "
                         ".vcs_store.wal)")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="write this invocation's span tree as "
                         "Chrome-tracing JSON (loads in Perfetto / "
                         "chrome://tracing)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("init", help="create an empty store")

    p = sub.add_parser("seed", help="create + fill a demo table")
    p.add_argument("table")
    p.add_argument("--rows", type=int, default=1000)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--nopk", action="store_true")

    p = sub.add_parser("mutate", help="deterministically update demo rows")
    p.add_argument("table")
    p.add_argument("--rows", type=int, default=100)
    p.add_argument("--seed", type=int, default=1)

    p = sub.add_parser("sql", help="run raw VCS statements (';'-separated)")
    p.add_argument("statements")

    p = sub.add_parser("branch", help="create (or -d delete) a branch")
    p.add_argument("name")
    p.add_argument("-d", "--delete", action="store_true")
    p.add_argument("-t", "--tables", nargs="*", default=None)
    p.add_argument("--from", dest="from_ref", default=None,
                   metavar="REF")

    p = sub.add_parser("snapshot", help="tag (or -d drop) a named snapshot")
    p.add_argument("name")
    p.add_argument("table", nargs="?", default=None)
    p.add_argument("-d", "--delete", action="store_true")

    p = sub.add_parser("clone", help="clone a table from any ref, or — "
                                     "with one arg — clone a whole repo "
                                     "from a remote directory into --store")
    p.add_argument("new", help="new table name (table clone) or the "
                               "remote directory (repo clone)")
    p.add_argument("ref", nargs="?", default=None)
    p.add_argument("--materialize", action="store_true")
    p.add_argument("--shallow", action="store_true",
                   help="repo clone only: skip fetching objects — fault "
                        "them from the origin on first read")

    p = sub.add_parser("diff", help="diff two refs")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--table", default=None)

    p = sub.add_parser("merge", help="merge a ref/branch into a "
                                     "table/branch")
    p.add_argument("src")
    p.add_argument("dst")
    p.add_argument("--mode", default=None)
    p.add_argument("-t", "--tables", nargs="*", default=None)

    p = sub.add_parser("pr", help="pull requests")
    prs = p.add_subparsers(dest="pr_cmd", required=True)
    pp = prs.add_parser("open")
    pp.add_argument("head")
    pp.add_argument("--into", default=None)
    for name in ("check", "close"):
        pp = prs.add_parser(name)
        pp.add_argument("id", type=int)

    p = sub.add_parser("publish", help="publish a PR atomically")
    p.add_argument("id", type=int)
    p.add_argument("--mode", default=None)

    p = sub.add_parser("revert-pr", help="inverse-Δ revert of a publish")
    p.add_argument("id", type=int)

    p = sub.add_parser("revert", help="apply inverse Δ(from -> to)")
    p.add_argument("table")
    p.add_argument("from_ref")
    p.add_argument("to_ref")

    p = sub.add_parser("restore", help="git reset --hard to a ref")
    p.add_argument("table")
    p.add_argument("ref")

    p = sub.add_parser("log", help="commit history of a table")
    p.add_argument("table")
    p.add_argument("-n", "--limit", type=int, default=None)

    for name, help_ in (("branches", "list branches"),
                        ("snapshots", "list snapshots"),
                        ("prs", "list pull requests"),
                        ("tables", "list tables"),
                        ("status", "full repo summary"),
                        ("gc", "mark-sweep garbage collection")):
        sub.add_parser(name, help=help_)

    p = sub.add_parser("push", help="ship missing objects + the WAL to a "
                                    "remote directory (fast-forward only)")
    p.add_argument("remote")

    p = sub.add_parser("pull", help="fast-forward this store to a "
                                    "remote's state (fetches only "
                                    "missing objects)")
    p.add_argument("remote")

    p = sub.add_parser("fetch", help="copy missing objects from a remote "
                                     "without changing repo state")
    p.add_argument("remote")

    p = sub.add_parser("stats", help="metrics registry snapshot")
    p.add_argument("--format", choices=("text", "json"), default="text")

    p = sub.add_parser(
        "lint",
        help="static invariant analysis of the source tree",
        formatter_class=argparse.RawDescriptionHelpFormatter,
        description=(
            "Run the invariant analysis suite (repro.analysis) over the "
            "repo's src/, benchmarks/ and examples/ trees (or the given "
            "paths).\n\n"
            "Passes:\n"
            "  sorted-claims   runs=/sigs=/presorted=True claims outside\n"
            "                  the reviewed producer modules\n"
            "  hidden-sort     np.sort/lexsort/unique/argsort on the\n"
            "                  zero-rehash hot paths (delta/merge/ops/"
            "engine)\n"
            "  crash-coverage  core.faults registry vs crash_point sites;\n"
            "                  unguarded fsync/directory swings; broad\n"
            "                  excepts around seams\n"
            "  deprecation     PR 5 deprecated resolvers, incl. aliasing\n"
            "                  and getattr forms\n"
            "  wal-hygiene     WAL kinds vs the replay dispatch; time/RNG\n"
            "                  in logging functions; clocks anywhere in\n"
            "                  repro.core outside core.telemetry\n"
            "  sealed-write    in-place writes to sealed-object lanes\n"
            "                  (static half of REPRO_SANITIZE=1)\n\n"
            "Suppress a finding with a JUSTIFIED pragma on the finding\n"
            "line or a comment line directly above:\n"
            "  # lint: <token> <reason>\n"
            "where <token> is the pass's token (runs-ok, sort-ok,\n"
            "crash-ok, legacy-ok, wal-ok, seal-ok). A pragma without a\n"
            "reason suppresses nothing and is itself a finding.\n\n"
            "Exit codes: 0 clean, 1 unsuppressed findings, 2 usage "
            "error."))
    p.add_argument("paths", nargs="*",
                   help="files/dirs to lint (default: the repo tree)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--baseline", metavar="FILE", default=None,
                   help="only findings absent from this JSON snapshot "
                        "fail the run")
    p.add_argument("--write-baseline", metavar="FILE", default=None,
                   help="write the findings snapshot and exit 0")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="also list suppressed findings")

    p = sub.add_parser("fsck", help="verify store frames, object "
                                    "signatures, refs, replay equivalence")
    p.add_argument("--repair", action="store_true",
                   help="truncate past store corruption (bytes preserved "
                        "to .corrupt) and quarantine bad objects")
    p.add_argument("--sample", type=float, default=1.0,
                   help="fraction of objects to signature-verify "
                        "(default 1.0 = all)")
    p.add_argument("--no-replay", action="store_true",
                   help="skip the WAL replay-equivalence check")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.trace:
        # arm BEFORE the store loads so the replay span is captured (the
        # engine binds in _cmd once it exists); the trace file is written
        # on the way out, errors included — traces are derived state, so
        # nothing here touches the durability story
        with telemetry.trace(None) as tracer:
            try:
                return _run(args, tracer)
            finally:
                telemetry.write_chrome_trace(args.trace, tracer)
    return _run(args, None)


def _run(args, tracer: Optional[telemetry.Tracer]) -> int:
    # every CLI invocation is itself a span, so an armed trace shows the
    # command as the root with load/replay, the operation, and the store
    # write-back attributed beneath it (registration is idempotent)
    with telemetry.span(telemetry.register_span(
            f"cli.{args.cmd}", "one datagit CLI invocation")):
        return _cmd(args, tracer)


def _cmd(args, tracer: Optional[telemetry.Tracer]) -> int:
    try:
        if args.cmd == "lint":
            # pure source analysis: no store, no repo — same runner and
            # exit-code contract as `python -m repro.analysis`
            from .analysis.runner import main as lint_main
            largv: List[str] = list(args.paths)
            if args.format != "text":
                largv += ["--format", args.format]
            if args.baseline:
                largv += ["--baseline", args.baseline]
            if args.write_baseline:
                largv += ["--write-baseline", args.write_baseline]
            if args.verbose:
                largv.append("-v")
            return lint_main(largv)
        if args.cmd == "init":
            if os.path.exists(args.store):
                print(f"error: store {args.store} already exists "
                      "(delete it to start fresh)", file=sys.stderr)
                return 2
            save_repo(args.store, Repo())
            print(f"initialized empty store at {args.store}")
            return 0
        if args.cmd == "clone" and args.ref is None:
            # repo-level clone (ISSUE 10): `new` is the remote directory
            # and --store names the NEW store — which must not exist yet
            from .store.remote import clone as _repo_clone
            if args.materialize:
                raise ValueError("clone: --materialize is a table-clone "
                                 "flag (repo clones fetch packs instead; "
                                 "use --shallow to skip even that)")
            st = _repo_clone(args.new, args.store, shallow=args.shallow)
            print(f"cloned {args.new} into {args.store}: "
                  f"{st['records']} record(s), "
                  + (f"shallow (objects fault in from the origin)"
                     if st["shallow"]
                     else f"{st['objects_fetched']} object(s) fetched"))
            return 0
        if not os.path.exists(args.store):
            # a typo'd --store must not silently create a store elsewhere
            print(f"error: no store at {args.store} — run `init` first "
                  "(or point --store/$VCS_STORE at the right file)",
                  file=sys.stderr)
            return 2
        if args.cmd == "fsck":
            return _cmd_fsck(args)
        repo = load_repo(args.store)
        if tracer is not None:
            tracer.bind(repo.engine)
        if args.cmd == "seed":
            print(seed_table(repo, args.table, args.rows, args.seed,
                             args.nopk))
        elif args.cmd == "mutate":
            print(mutate_table(repo, args.table, args.rows, args.seed))
        elif args.cmd == "pull":
            # native (not a compiled statement): the CLI supplies the
            # store's pack sidecar and flips the store to refs-mode so
            # subsequent loads import refs instead of replaying data
            st = repo.pull(args.remote, pack_dir=args.store + ".packs")
            if st.get("up_to_date"):
                print(f"pull {args.remote}: already up to date")
            else:
                repo._refs_path = args.store + ".refs"
                repo._refs_origin = repo.engine.store.packs.origin
                print(f"pull {args.remote}: {st['objects_pulled']} "
                      f"object(s), {st['records_pulled']} record(s)")
        elif args.cmd == "fetch":
            st = repo.fetch(args.remote, pack_dir=args.store + ".packs")
            print(f"fetch {args.remote}: {st['objects_pulled']} object(s) "
                  f"({st['bytes_pulled']} bytes)")
        elif args.cmd == "sql":
            checks_failed = False
            for res in execute_script(repo, args.statements):
                print(res.message)
                if res.kind == "check_pr" and any(not c.ok
                                                  for c in res.data):
                    checks_failed = True
            save_repo(args.store, repo)
            # same shell-gateable contract as `dg pr check`: a failing
            # check run exits 1 (after persisting the script's mutations)
            return 1 if checks_failed else 0
        else:
            stmt = _compile(args, repo)
            res = execute(repo, stmt)
            if args.cmd == "stats" and args.format == "json":
                print(json.dumps(res.data, indent=2, sort_keys=True))
            else:
                print(res.message)
            if res.kind == "check_pr" and any(not c.ok for c in res.data):
                # a failing CI check must be shell-gateable:
                # `dg pr check N && deploy` has only the exit code
                return 1
            if args.cmd == "gc":
                # GC is deliberately un-WAL-logged (replay keeps more
                # garbage but identical logical state) — for a WAL-backed
                # store that makes freeing per-process, so say so
                print("note: the store is a replayed WAL — freed objects "
                      "re-materialize on the next load; gc reclaims "
                      "memory for this process only")
        # pr check is read-only too: the preview rolls its oids back and
        # logs nothing, so rewriting the store would be pure wasted I/O
        if args.cmd not in _READ_ONLY and not (
                args.cmd == "pr" and args.pr_cmd == "check"):
            save_repo(args.store, repo)
        return 0
    except (*_TYPED_ERRORS, ValueError, KeyError) as exc:
        msg = exc.args[0] if exc.args else str(exc)
        if isinstance(exc, _TYPED_ERRORS):
            print(f"error: {msg}", file=sys.stderr)
        else:
            # a bare ValueError/KeyError may be a legitimate user error
            # ("branch exists", "PR is closed") OR an internal bug —
            # surface the class so the two are distinguishable
            print(f"error [{type(exc).__name__}]: {msg}", file=sys.stderr)
            if os.environ.get("VCS_DEBUG"):
                raise
        if isinstance(exc, CorruptFrame):
            print("hint: the store has mid-file damage — run "
                  "`datagit fsck --repair` to truncate to the last clean "
                  "frame (damaged bytes preserved to the .corrupt "
                  "sidecar), or restore from a backup", file=sys.stderr)
        suggestions = getattr(exc, "suggestions", ())
        if suggestions:
            print("hint: " + " | ".join(map(str, suggestions)),
                  file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
