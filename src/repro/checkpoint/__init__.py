from .vcs_ckpt import CKPT_SCHEMA, VcsCheckpointer  # noqa
from .manager import CheckpointManager  # noqa
