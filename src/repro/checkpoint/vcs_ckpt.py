"""Versioned checkpointing: model/optimizer state stored in the paper's
version-control engine (a beyond-paper application of the same mechanism).

Each checkpoint writes the *changed* tensor shards of a training-state
pytree into a versioned table ``(shard_id, step, data LOB)`` and tags a
named snapshot ``step-<n>``. Because snapshots are metadata-only:

  * keeping every N-step checkpoint is free until GC,
  * "fork a fine-tune" = CLONE the checkpoint table (instant),
  * crash recovery / NaN rollback = RESTORE to the last good tag (instant),
  * "what changed between step A and B" = SNAPSHOT DIFF over shard rows —
    incremental-upload planning for terabyte checkpoints.

Tensors are chunked into fixed-size shards so a step that only touches some
tensors (or a sparse/frozen fine-tune) uploads only changed shards —
unchanged shard rows are value-identical and cancel in the diff.
"""
from __future__ import annotations

import io
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core import Column, CType, Engine, Schema, Snapshot
from ..core.merge import ConflictMode, three_way_merge

# NOTE: no per-row step column — shard rows must be value-identical across
# checkpoints when the tensor bytes are unchanged, so SNAPSHOT DIFF counts
# only genuinely changed shards (the incremental-upload set).
CKPT_SCHEMA = Schema(
    columns=(
        Column("shard_id", CType.I64),
        Column("data", CType.LOB),
    ),
    primary_key=("shard_id",),
)

SHARD_BYTES = 4 << 20  # 4 MiB logical shards


def _flatten_state(state) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        out.append((name, np.asarray(leaf)))
    return out


def _shard_array(arr: np.ndarray):
    raw = arr.tobytes()
    for off in range(0, max(len(raw), 1), SHARD_BYTES):
        yield raw[off:off + SHARD_BYTES]


class VcsCheckpointer:
    def __init__(self, engine: Engine, table: str = "ckpt"):
        self.engine = engine
        self.table = table
        if table not in engine.tables:
            engine.create_table(table, CKPT_SCHEMA)
        self._layout: Optional[List[Tuple[str, Tuple, str, int]]] = None

    # ------------------------------------------------------------- save
    def save(self, state, step: int, tag: Optional[str] = None) -> Snapshot:
        """Write state as shard rows (update_by_keys collapses history) and
        tag a named snapshot."""
        leaves = _flatten_state(state)
        layout = []
        shard_ids, blobs = [], []
        sid = 0
        for name, arr in leaves:
            n_shards = 0
            for blob in _shard_array(arr):
                shard_ids.append(sid)
                blobs.append(blob)
                sid += 1
                n_shards += 1
            layout.append((name, arr.shape, str(arr.dtype), n_shards))
        self._layout = layout
        t = self.engine.table(self.table)
        tx = self.engine.begin()
        ids = np.asarray(shard_ids, np.int64)
        batch = {"shard_id": ids, "data": blobs}
        if t.count() == 0:
            tx.insert(self.table, batch)
        else:
            tx.update_by_keys(self.table, batch)
        tx.commit()
        return self.engine.create_snapshot(tag or f"step-{step}", self.table)

    # ---------------------------------------------------------- restore
    def restore(self, snapshot, like_state) -> Any:
        """Restore a pytree like ``like_state`` from a checkpoint snapshot."""
        # exact tag match wins before ref parsing (a branch/table sharing
        # the name, or a pre-grammar tag from an old WAL, must not break
        # or misdirect restore) — same rule clone/restore_table apply
        snap = self.engine._snapshotish(snapshot, table=self.table)
        t = self.engine.table(self.table)
        batch, _ = t.scan(snap.directory)
        order = np.argsort(batch["shard_id"], kind="stable")
        blobs = batch["data"][order]
        leaves = _flatten_state(like_state)
        out = []
        cursor = 0
        for name, arr in leaves:
            raw = b""
            need = arr.nbytes
            while len(raw) < max(need, 1) and cursor < len(blobs):
                raw += blobs[cursor]
                cursor += 1
                if need == 0:
                    break
            new = np.frombuffer(raw[:need], dtype=arr.dtype).reshape(arr.shape)
            out.append(new)
        treedef = jax.tree_util.tree_structure(like_state)
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------ extras
    def rollback(self, tag: str) -> None:
        """Instant revert of the checkpoint table (paper's RESTORE)."""
        self.engine.restore_table(self.table, tag)

    def fork(self, new_table: str, tag: str) -> "VcsCheckpointer":
        """Instant fine-tune fork: clone the checkpoint table at a tag."""
        self.engine.clone_table(new_table, tag)
        ck = VcsCheckpointer.__new__(VcsCheckpointer)
        ck.engine, ck.table, ck._layout = self.engine, new_table, self._layout
        return ck

    def changed_shards(self, tag_a: str, tag_b: str) -> int:
        """How many shard rows differ between two checkpoints (SNAPSHOT
        DIFF) — the incremental-upload set."""
        from ..core import snapshot_diff
        d = snapshot_diff(self.engine.store, self.engine.snapshots[tag_a],
                          self.engine.snapshots[tag_b])
        return d.n_groups
