"""Fault-tolerant training-state manager on top of the versioned store.

Policy: tag a checkpoint every ``every`` steps; on a detected failure
(non-finite loss/grad-norm, or an injected fault in tests) roll the
checkpoint table back to the last good tag (instant metadata restore) and
reload. Keeps a bounded set of tags; GC reclaims unpinned objects.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import Engine
from .vcs_ckpt import VcsCheckpointer


class CheckpointManager:
    def __init__(self, engine: Engine, every: int = 50, keep: int = 3,
                 table: str = "ckpt", prefix: str = ""):
        self.engine = engine
        self.ck = VcsCheckpointer(engine, table)
        self.every = every
        self.keep = keep
        self.prefix = prefix
        self.tags: List[str] = []

    @property
    def last_tag(self) -> Optional[str]:
        return self.tags[-1] if self.tags else None

    def maybe_save(self, state, step: int) -> Optional[str]:
        if step % self.every != 0:
            return None
        tag = f"{self.prefix}step-{step}"
        self.ck.save(state, step, tag)
        self.tags.append(tag)
        while len(self.tags) > self.keep:
            old = self.tags.pop(0)
            self.engine.drop_snapshot(old)
        self.engine.gc()
        return tag

    def healthy(self, loss, grad_norm=None) -> bool:
        ok = bool(np.isfinite(np.asarray(loss)))
        if grad_norm is not None:
            ok = ok and bool(np.isfinite(np.asarray(grad_norm)))
        return ok

    def recover(self, like_state) -> Any:
        """Roll back to the last good tag and return the restored state."""
        if self.last_tag is None:
            raise RuntimeError("no checkpoint to recover from")
        self.ck.rollback(self.last_tag)
        return self.ck.restore(self.engine.snapshots[self.last_tag],
                               like_state)

    def step_of(self, tag: str) -> int:
        return int(tag.split("-")[-1])
