"""Sharding rules: map every parameter / activation / cache tensor to a
PartitionSpec on the production mesh (DESIGN.md §4), plus the 128-bit
KEY-RANGE shard planner for the VCS Δ/merge pipeline (ISSUE 9).

Axes: ``pod`` (inter-pod DP), ``data`` (DP + FSDP/ZeRO-3 + SP), ``model``
(TP + EP). Rules are name-pattern based — the same style MaxText/Megatron
use — so configs can override per architecture/shape.

FSDP: stacked layer weights get their largest non-TP dim sharded over
``data``; XLA all-gathers at use inside the layer scan (gather-at-use) and
reduce-scatters the gradients — ZeRO-3 semantics from pjit alone.

Key-range sharding (bottom of this module): sealed objects and Δ streams
are sorted by 128-bit key signature, so merge and diff aggregation are
embarrassingly partitionable on key ranges. ``plan_key_cuts`` picks
boundary keys by rank-sum over the presorted runs; ``kernels.ops``
executes the plan byte-identically to the unsharded path. Shard plans are
DERIVED state — a pure function of the immutable lanes and the backend's
device count — and are never WAL-logged (replay re-derives them).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardCfg:
    """Knobs for the sharding strategy (the §Perf hillclimb surface)."""
    fsdp: bool = True            # shard params/opt-state over 'data'
    tp: bool = True              # shard heads/ffn/experts/vocab over 'model'
    seq_shard_cache: bool = False  # SP: shard decode KV cache seq over 'data'
    cache_seq_model: bool = False  # shard cache seq over 'model' when the
    #                                kv-head count doesn't divide the TP axis
    #                                (GQA decode: distributed flash-decoding)
    seq_parallel: bool = False   # Megatron-SP: residual activations seq-
    #                              sharded over 'model' between TP blocks
    #                              (norms run sharded; AR -> RS+AG pairs)
    grad_compress_bf16: bool = False


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh) -> P:
    return P(_dp_axes(mesh))


def _maybe(axis: Optional[str], on: bool):
    return axis if on else None


def param_spec(cfg: ArchConfig, sc: ShardCfg, path: str,
               shape: Tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one parameter, identified by its pytree path."""
    model = "model" if sc.tp else None
    fsdp = "data" if (sc.fsdp and "data" in mesh.axis_names) else None
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)

    def div(dim, ax):
        """axis name if the dim divides evenly, else None."""
        if ax is None:
            return None
        n = mesh.shape.get(ax, 1)
        return ax if dim % n == 0 and dim >= n else None

    name = path.split("/")[-1]
    # ---- embeddings / head
    if name == "embed":
        return P(div(shape[0], model), div(shape[1], fsdp))
    if name == "lm_head":
        return P(div(shape[0], fsdp), div(shape[1], model))
    if name in ("pos", "enc_pos"):
        return P(None, div(shape[1], fsdp))
    # ---- stacked per-period weights: leading dim = n_periods (never shard)
    if name in ("wq", "xq"):            # (P, d, H*hd)
        return P(None, div(shape[1], fsdp), div(shape[2], model))
    if name in ("wk", "wv", "xk", "xv"):  # (P, d, KV*hd) — KV may be tiny
        kvdim = shape[2]
        return P(None, div(shape[1], fsdp), div(kvdim, model))
    if name in ("wo", "xo"):            # (P, H*hd, d)
        return P(None, div(shape[1], model), div(shape[2], fsdp))
    if name in ("w1", "w3"):            # (P, d, ff) or encoder (L, d, ff)
        return P(None, div(shape[1], fsdp), div(shape[2], model))
    if name == "w2":                    # (P, ff, d)
        return P(None, div(shape[1], model), div(shape[2], fsdp))
    if name in ("moe_w1", "moe_w3"):    # (P, E, d, ff)
        if div(shape[1], model):        # EP: experts across the model axis
            return P(None, model, div(shape[2], fsdp), None)
        # expert count not divisible (mixtral 8e on 16-way TP): fall back to
        # Megatron-style TP over the ffn dim, experts replicated
        return P(None, None, div(shape[2], fsdp), div(shape[3], model))
    if name == "moe_w2":                # (P, E, ff, d)
        if div(shape[1], model):
            return P(None, model, None, div(shape[3], fsdp))
        return P(None, None, div(shape[2], model), div(shape[3], fsdp))
    if name == "router":                # (P, d, E)
        return P(None, div(shape[1], fsdp), None)
    # ---- ssm / rwkv
    if name in ("w_in", "w_bcdt"):      # (P, d, ...)
        return P(None, div(shape[1], fsdp), div(shape[2], model))
    if name == "w_out":                 # (P, di, d)
        return P(None, div(shape[1], model), div(shape[2], fsdp))
    if name in ("w_r", "w_k", "w_v", "w_g", "w_dec", "w_o"):  # (P, d, d)
        return P(None, div(shape[1], fsdp), div(shape[2], model))
    if name == "conv":                  # (P, d_conv, di)
        return P(None, None, div(shape[2], model))
    # small vectors: replicate
    return P(*([None] * len(shape)))


def tree_param_specs(cfg: ArchConfig, sc: ShardCfg, params_shape,
                     mesh: Mesh):
    """Pytree of PartitionSpecs matching a params(-shaped) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        specs.append(param_spec(cfg, sc, pstr, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_spec(cfg: ArchConfig, sc: ShardCfg, path: str,
               shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Sharding for decode-cache tensors.

    Default: batch over (pod, data), kv-heads over model (when divisible).
    With ``seq_shard_cache`` (long-context SP): the cache *sequence* dim is
    sharded over 'data' — decode attention becomes distributed
    flash-decoding (partial softmax per shard + psum, generated by SPMD).
    """
    name = path.split("/")[-1]
    dp = _dp_axes(mesh)
    msize = mesh.shape.get("model", 1)
    if name == "len":
        return P()
    if len(shape) == 5:  # (Pd, B, S, KV, hd) attention / cross caches
        b, s, kv = shape[1], shape[2], shape[3]
        bspec = dp if b % int(np.prod([mesh.shape[a] for a in dp])) == 0 \
            else (dp[0] if b % mesh.shape[dp[0]] == 0 else None)
        if sc.seq_shard_cache:
            sspec = "data" if s % mesh.shape.get("data", 1) == 0 else None
            bspec = "pod" if ("pod" in mesh.axis_names
                              and b % mesh.shape["pod"] == 0) else None
            return P(None, bspec, sspec,
                     "model" if kv % msize == 0 else None, None)
        if kv % msize == 0:
            return P(None, bspec, None, "model", None)
        if sc.cache_seq_model and s % msize == 0:
            # GQA kv-heads don't divide TP: shard the SEQ dim over 'model'
            # instead of replicating the cache (decode attention becomes a
            # partial-softmax + psum over model — flash-decoding by SPMD)
            return P(None, bspec, "model", None, None)
        return P(None, bspec, None, None, None)
    if len(shape) >= 3:  # ssm/rwkv states: (Pd, B, ...)
        b = shape[1]
        bspec = dp if b % int(np.prod([mesh.shape[a] for a in dp])) == 0 \
            else (dp[0] if b % mesh.shape[dp[0]] == 0 else None)
        rest = [None] * (len(shape) - 2)
        # shard the widest state dim over model when possible
        widest = int(np.argmax(shape[2:]))
        if shape[2 + widest] % msize == 0:
            rest[widest] = "model"
        return P(None, bspec, *rest)
    return P(*([None] * len(shape)))


def tree_cache_specs(cfg: ArchConfig, sc: ShardCfg, cache_shape, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        specs.append(cache_spec(cfg, sc, pstr, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


# -------------------------------------------------------- at-use constraints

def at_use_spec(spec: P, drop_leading: bool = True) -> P:
    """Compute-time spec of an FSDP-stored weight: the 'data' (FSDP) axis is
    gathered at use (ZeRO-3 gather-at-use), TP axes stay; the leading stacked
    period dim is stripped inside the layer scan."""
    parts = list(spec) if spec is not None else []
    if drop_leading and parts:
        parts = parts[1:]
    parts = [None if a == "data" else a for a in parts]
    return P(*parts)


class ModelSharding:
    """Sharding constraints applied INSIDE the model (activations +
    gather-at-use weights). Without these, XLA's SPMD partitioner may choose
    partial-sum all-reduces of activation-sized tensors instead of weight
    all-gathers (observed: 5 GB logits all-reduce). Constructed by
    launch.steps; ``None`` disables all constraints (CPU tests)."""

    def __init__(self, cfg, sc: ShardCfg, mesh: Mesh, params_shape):
        self.mesh = mesh
        self.dp = _dp_axes(mesh)
        self.sc = sc
        specs = tree_param_specs(cfg, sc, params_shape, mesh)
        self.block_use = {}
        for slot, tree in specs["blocks"].items():
            self.block_use[slot] = {
                name: at_use_spec(sp, drop_leading=True)
                for name, sp in tree.items()}
        self.embed_use = at_use_spec(specs["embed"], drop_leading=False)
        self.head_use = at_use_spec(specs["lm_head"], drop_leading=False)
        self.enc_use = None
        if "encoder" in specs:
            self.enc_use = {name: at_use_spec(sp, drop_leading=True)
                            for name, sp in specs["encoder"].items()}

    def _wsc(self, x, spec):
        return jax.lax.with_sharding_constraint(x, spec)

    def act(self, x):
        """(B, S, d) activations: batch over DP axes; with seq_parallel the
        sequence dim additionally shards over 'model' (Megatron-SP)."""
        if x.shape[0] % int(np.prod([self.mesh.shape[a] for a in self.dp])):
            return x
        sp = None
        if (self.sc.seq_parallel and x.ndim == 3
                and x.shape[1] % self.mesh.shape.get("model", 1) == 0):
            sp = "model"
        return self._wsc(x, P(self.dp, sp, *([None] * (x.ndim - 2))))

    def pslice(self, slot: str, tree):
        use = self.block_use.get(slot)
        if use is None:
            return tree
        return {k: (self._wsc(v, use[k]) if k in use else v)
                for k, v in tree.items()}

    def encslice(self, tree):
        if self.enc_use is None:
            return tree
        return {k: (self._wsc(v, self.enc_use[k]) if k in self.enc_use
                    else v) for k, v in tree.items()}

    def embed(self, w):
        return self._wsc(w, self.embed_use)

    def head(self, w):
        return self._wsc(w, self.head_use)


# --------------------------------------------------------------------------
# 128-bit key-range sharding for the VCS Δ/merge pipeline (ISSUE 9)
# --------------------------------------------------------------------------

from ..kernels import ops as _ops  # noqa: E402  (after the jax-heavy half)

#: CPU shard sizing: one shard per ~object-capacity of stream rows keeps a
#: partition's six signature/sign lanes inside L2-ish working sets.
KEY_SHARD_TARGET_ROWS = 1 << 18
#: auto-sharding floor: below this, split/concat overhead beats the win
#: (Δ-sized merges — the committed bench C-sets — stay unsharded).
KEY_SHARD_MIN_ROWS = 1 << 20
#: cap on auto shard counts (plan cost is runs x cuts searchsorteds).
KEY_SHARD_MAX = 16

_FORCED_KEY_SHARDS: Optional[int] = None


def set_key_shards(n: Optional[int]) -> Optional[int]:
    """Force the shard count (tests / operators); ``None`` restores the
    auto policy. Returns the previous override so callers can restore."""
    global _FORCED_KEY_SHARDS
    prev = _FORCED_KEY_SHARDS
    _FORCED_KEY_SHARDS = n
    return prev


def key_shard_count(n_rows: int) -> int:
    """How many key-range shards an ``n_rows`` merge/aggregate should use.

    Deterministic in (n_rows, backend): 1 (off) below KEY_SHARD_MIN_ROWS;
    above it, multi-device backends split one shard per local device and
    CPU splits into cache-sized partitions. Never persisted — shard plans
    are derived state, so WAL replay on a different backend re-derives its
    own (outputs are byte-identical either way)."""
    if _FORCED_KEY_SHARDS is not None:
        return max(1, int(_FORCED_KEY_SHARDS))
    if n_rows < KEY_SHARD_MIN_ROWS:
        return 1
    if jax.default_backend() != "cpu" and jax.local_device_count() > 1:
        return min(jax.local_device_count(), KEY_SHARD_MAX)
    return int(min(KEY_SHARD_MAX, max(2, n_rows // KEY_SHARD_TARGET_ROWS)))


def plan_key_cuts(lo: np.ndarray, hi: np.ndarray, runs: np.ndarray,
                  shards: int):
    """Boundary keys splitting presorted runs into ``shards`` balanced
    key ranges, by rank-sum over the run starts.

    Candidates are each run's local quantile keys; a candidate's global
    rank is the sum over runs of its exact 128-bit lower bound (the same
    rank-sum trick the Pallas merge path uses), and the candidate nearest
    each target rank ``i*n/shards`` wins. Returns ``(cut_lo, cut_hi)`` —
    ascending, distinct, possibly fewer than ``shards - 1`` entries — or
    ``None`` when no usable interior boundary exists. Pure function of the
    immutable lanes: derived state, never WAL-logged."""
    n = int(lo.shape[0])
    runs = np.asarray(runs, np.int64)
    k = runs.shape[0]
    if shards <= 1 or n == 0 or k <= 1:
        return None
    bounds = np.append(runs, n)
    cand_parts = []
    for r in range(k):
        a, b = int(bounds[r]), int(bounds[r + 1])
        if b > a:
            cand_parts.append(
                a + (np.arange(1, shards, dtype=np.int64) * (b - a)) // shards)
    if not cand_parts:
        return None
    cand_idx = np.concatenate(cand_parts)
    c_lo, c_hi = lo[cand_idx], hi[cand_idx]
    ranks = np.zeros((cand_idx.shape[0],), np.int64)
    for r in range(k):
        a, b = int(bounds[r]), int(bounds[r + 1])
        ranks += _ops.searchsorted128(lo[a:b], hi[a:b], c_lo, c_hi,
                                      side="left")
    chosen = []
    for j in range(1, shards):
        target = (j * n) // shards
        pick = int(np.argmin(np.abs(ranks - target)))
        rank = int(ranks[pick])
        key = (int(c_lo[pick]), int(c_hi[pick]))
        # degenerate cuts (empty first/last shard) and non-ascending picks
        # are dropped: fewer shards, never a wrong plan
        if rank <= 0 or rank >= n or (chosen and key <= chosen[-1]):
            continue
        chosen.append(key)
    if not chosen:
        return None
    return (np.array([c[0] for c in chosen], np.uint64),
            np.array([c[1] for c in chosen], np.uint64))


def maybe_key_cuts(lo: np.ndarray, hi: np.ndarray, runs):
    """The one-call shard plan: ``None`` (stay unsharded) unless the
    stream is big enough for the backend policy AND has real multi-run
    structure to merge."""
    if runs is None or runs.shape[0] <= 1:
        return None
    shards = key_shard_count(int(lo.shape[0]))
    if shards <= 1:
        return None
    return plan_key_cuts(lo, hi, runs, shards)
