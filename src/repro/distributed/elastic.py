"""Elastic scaling + failure handling.

On a real cluster the control plane (launcher) watches host heartbeats;
when the healthy-device set changes it (1) picks the largest valid mesh,
(2) re-lowers the step function for that mesh, (3) restores the last
versioned checkpoint (instant — metadata restore) and resumes from the
owed step. All state transfer goes through the host: checkpoints are
device-layout-agnostic numpy shards, so any old→new mesh pair works.

This module provides the mesh-selection and state-remap logic; the CPU
container exercises it in tests by resharding between 1-, 2- and 4-way
device counts (and abstractly between the 256/512-chip production meshes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def best_mesh_shape(n_devices: int, *, model_cap: int = 16,
                    want_pods: int = 1) -> Tuple[Tuple[int, ...],
                                                 Tuple[str, ...]]:
    """Largest (pod, data, model) layout for a (possibly degraded) device
    count: keep 'model' as large as divisible (TP efficiency), put the rest
    in 'data'. Drops stragglers to the largest power-of-two fleet."""
    usable = 1 << (int(n_devices).bit_length() - 1)
    model = 1
    for m in (model_cap, 8, 4, 2, 1):
        if usable % m == 0 and usable >= m:
            model = m
            break
    rest = usable // model
    if want_pods > 1 and rest % want_pods == 0 and rest > want_pods:
        return (want_pods, rest // want_pods, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_mesh_for(n_devices: int, **kw) -> Mesh:
    shape, axes = best_mesh_shape(n_devices, **kw)
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def remap_state(state, specs, new_mesh: Mesh):
    """Re-shard a pytree onto a new mesh (host-mediated: fully general
    old-layout → new-layout transfer; on a fleet this is the
    restore-from-checkpoint path)."""
    def put(x, spec):
        arr = np.asarray(x)  # gather to host
        # drop axes the new mesh doesn't have
        clean = []
        for ax in (spec if spec is not None else ()):
            if ax is None:
                clean.append(None)
            elif isinstance(ax, (tuple, list)):
                keep = tuple(a for a in ax if a in new_mesh.axis_names)
                clean.append(keep if keep else None)
            else:
                clean.append(ax if ax in new_mesh.axis_names else None)
        # drop shardings that no longer divide
        final = []
        for dim, ax in zip(arr.shape, clean):
            n = 1
            for a in ((ax,) if isinstance(ax, str) else (ax or ())):
                if a:
                    n *= new_mesh.shape[a]
            final.append(ax if n > 1 and dim % n == 0 else None)
        return jax.device_put(arr, NamedSharding(new_mesh, P(*final)))
    return jax.tree.map(put, state, specs)


@dataclasses.dataclass
class FleetState:
    """Launcher-side view of the fleet (heartbeat bookkeeping)."""
    n_devices: int
    healthy: Optional[Sequence[int]] = None
    generation: int = 0

    def fail(self, k: int = 1) -> "FleetState":
        return FleetState(self.n_devices - k, generation=self.generation + 1)

    def join(self, k: int = 1) -> "FleetState":
        return FleetState(self.n_devices + k, generation=self.generation + 1)
