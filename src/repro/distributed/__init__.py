"""Distribution: sharding rules, collectives/compression, elastic scaling."""
from . import collectives, elastic, sharding  # noqa: F401
from .sharding import ShardCfg, batch_spec, tree_cache_specs, tree_param_specs  # noqa
