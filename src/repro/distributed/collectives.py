"""Distributed-optimization helpers: gradient compression with error
feedback, and microbatch gradient accumulation.

With pjit/GSPMD the data-parallel gradient reduction is implicit (XLA emits
reduce-scatter/all-reduce from the sharding specs). Compression therefore
happens *around* the reduction: grads are cast to bf16 (or int8 with
per-tensor scale) before the psum-inducing consumer, and the quantization
residual is carried in the training state and re-added next step (error
feedback keeps convergence unbiased in expectation).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def compress_bf16(grads):
    """Cast grads to bf16 — halves all-reduce/reduce-scatter bytes."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def compress_int8(grads):
    """Per-tensor symmetric int8 quantization. Returns (q, scales)."""
    def q(g):
        s = jnp.maximum(jnp.max(jnp.abs(g.astype(F32))), 1e-12) / 127.0
        return (g.astype(F32) / s).round().astype(jnp.int8), s
    flat, treedef = jax.tree_util.tree_flatten(grads)
    qs = [q(g) for g in flat]
    return (jax.tree_util.tree_unflatten(treedef, [x[0] for x in qs]),
            jax.tree_util.tree_unflatten(treedef, [x[1] for x in qs]))


def decompress_int8(q, scales):
    return jax.tree.map(lambda g, s: g.astype(F32) * s, q, scales)


def error_feedback_apply(grads, residual):
    """g' = g + residual; new_residual = g' − compress(g')."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, F32), grads)
    corrected = jax.tree.map(lambda g, r: g.astype(F32) + r, grads, residual)
    compressed = compress_bf16(corrected)
    new_residual = jax.tree.map(
        lambda c, comp: c - comp.astype(F32), corrected, compressed)
    return compressed, new_residual


def accumulate_microbatches(loss_fn, params, batches, *, unroll: int = 1,
                            grad_specs=None):
    """Gradient accumulation over a leading microbatch dim via lax.scan.

    batches: pytree with leading dim n_micro. Returns (mean_loss, grads).

    ``grad_specs`` (a PartitionSpec pytree matching params) constrains the
    accumulated-gradient carry to the parameters' FSDP sharding: each
    microbatch's contribution is then reduce-scattered into the sharded
    carry instead of all-reduced and re-sliced (≈2x collective bytes on the
    grad path; see EXPERIMENTS §Perf cell A).
    """
    n_micro = jax.tree.leaves(batches)[0].shape[0]

    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_specs)

    def one(carry, mb):
        loss_sum, gsum = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        gsum = constrain(jax.tree.map(
            lambda a, b: a + b.astype(F32), gsum, g))
        return (loss_sum + loss, gsum), None

    g0 = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params))
    (loss_sum, gsum), _ = jax.lax.scan(
        one, (jnp.zeros((), F32), g0), batches, unroll=unroll)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)
