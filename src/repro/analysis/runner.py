"""Run the analysis suite over a tree and render/diff the findings.

``python -m repro.analysis`` and ``datagit lint`` both land here. Exit
code contract (shell-gateable, like ``dg pr check``): 0 = no unsuppressed
findings, 1 = findings, 2 = usage/parse failure.

JSON schema (pinned; ``LINT_baseline.json`` is a committed snapshot)::

    {
      "schema": 1,
      "rules": {"<rule id>": "<pragma token>", ...},
      "counts": {"files": N, "findings": N, "suppressed": N},
      "findings": [
        {"rule": ..., "path": ..., "line": N, "col": N,
         "message": ..., "hint": ..., "suppressed": bool, "reason": ...},
        ...
      ]
    }

Baseline diffing keys findings on (rule, path, message) — line numbers
drift across unrelated edits and must not churn the baseline. With
``--baseline``, only findings NOT in the snapshot fail the run, so a new
rule can land with its legacy findings recorded and be burned down
finding-by-finding instead of blocking mid-migration.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from .base import Finding, LintModule, Rule
from .project import Project
from .rules_claims import HiddenSortRule, SortedClaimsRule
from .rules_crash import CrashCoverageRule
from .rules_deprecation import DeprecationRule
from .rules_sealed import SealedWriteRule
from .rules_wal import WalHygieneRule

SCHEMA_VERSION = 1

ALL_RULES: List[Rule] = [
    SortedClaimsRule(), HiddenSortRule(), CrashCoverageRule(),
    DeprecationRule(), WalHygieneRule(), SealedWriteRule(),
]

#: tokens a pragma may name: every rule's token (the "pragma" meta-rule
#: rejects the rest as typos)
KNOWN_TOKENS = frozenset(r.pragma for r in ALL_RULES)

#: directories scanned by default, relative to the repo root
DEFAULT_SUBDIRS = ("src", "benchmarks", "examples")


def repo_root() -> Path:
    """The checkout root, located from this installed package
    (``<root>/src/repro/analysis/runner.py``)."""
    return Path(__file__).resolve().parents[3]


def discover(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
    return files


def default_paths(root: Path) -> List[Path]:
    return [root / d for d in DEFAULT_SUBDIRS if (root / d).is_dir()]


def discover_count(paths: Sequence[Path]) -> int:
    return len(discover(paths))


def _pragma_findings(mod: LintModule) -> List[Finding]:
    """The meta-rule: every pragma must name a known token and carry a
    reason. Unsuppressible by design — a suppression that needs
    suppressing is a review problem, not a lint problem."""
    out: List[Finding] = []
    for line, entries in sorted(mod.pragmas.items()):
        for token, reason in entries:
            if token not in KNOWN_TOKENS:
                out.append(Finding(
                    rule="pragma", path=mod.rel, line=line, col=0,
                    message=f"unknown lint pragma token {token!r}",
                    hint=f"known tokens: {', '.join(sorted(KNOWN_TOKENS))}"))
            elif not reason:
                out.append(Finding(
                    rule="pragma", path=mod.rel, line=line, col=0,
                    message=f"pragma '# lint: {token}' has no reason — "
                            "it does not suppress anything",
                    hint="suppressions must say WHY: "
                         f"`# lint: {token} <reason>`"))
    return out


def run_analysis(paths: Sequence[Path], root: Optional[Path] = None,
                 rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Lint ``paths`` (files or directories); returns ALL findings,
    suppressed ones included (callers filter on ``.suppressed``)."""
    root = root or repo_root()
    rules = list(rules if rules is not None else ALL_RULES)
    modules = [LintModule(f, root) for f in discover(paths)]
    project = Project(modules)
    findings: List[Finding] = []
    for mod in modules:
        if mod.parse_error is not None:
            findings.append(mod.parse_error)
            continue
        findings.extend(_pragma_findings(mod))
        for rule in rules:
            for f in rule.check(mod, project):
                reason = mod.pragma_reason(f.line, rule.pragma)
                if reason is not None:
                    f = Finding(rule=f.rule, path=f.path, line=f.line,
                                col=f.col, message=f.message, hint=f.hint,
                                suppressed=True, reason=reason)
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def to_json(findings: Sequence[Finding], nfiles: int) -> dict:
    unsup = [f for f in findings if not f.suppressed]
    return {
        "schema": SCHEMA_VERSION,
        "rules": {r.id: r.pragma for r in ALL_RULES},
        "counts": {"files": nfiles, "findings": len(unsup),
                   "suppressed": len(findings) - len(unsup)},
        "findings": [f.to_json() for f in findings],
    }


def load_baseline(path: Path) -> set:
    data = json.loads(path.read_text())
    if data.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"baseline {path} has schema {data.get('schema')!r}, this "
            f"tool writes schema {SCHEMA_VERSION} — regenerate with "
            "--write-baseline")
    return {(f["rule"], f["path"], f["message"])
            for f in data["findings"] if not f.get("suppressed")}


def render_text(findings: Sequence[Finding], nfiles: int,
                verbose: bool = False) -> str:
    unsup = [f for f in findings if not f.suppressed]
    lines = [f.render() for f in unsup]
    if verbose:
        lines += [f.render() for f in findings if f.suppressed]
    nsup = len(findings) - len(unsup)
    lines.append(f"{nfiles} file(s) checked: {len(unsup)} finding(s), "
                 f"{nsup} suppressed")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="invariant lint for the VCS engine: sortedness/carry "
                    "claims, crash-point coverage, deprecations, "
                    "WAL/replay hygiene, sealed-object immutability")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: the repo's "
                         "src/, benchmarks/, examples/)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help="only findings absent from this snapshot fail "
                         "the run")
    ap.add_argument("--write-baseline", metavar="FILE", default=None,
                    help="write the JSON snapshot and exit 0")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also list suppressed findings")
    args = ap.parse_args(argv)

    root = repo_root()
    paths = ([Path(p).resolve() for p in args.paths] if args.paths
             else default_paths(root))
    for p in paths:
        if not p.exists():
            print(f"error: no such path {p}", file=sys.stderr)
            return 2
    try:
        for p in paths:
            p.relative_to(root)
    except ValueError:
        # linting out-of-tree paths (tests do this with fixture dirs):
        # rebase "repo-relative" onto their common parent
        import os
        root = Path(os.path.commonpath(
            [str(p if p.is_dir() else p.parent) for p in paths]))
    nfiles = len(discover(paths))
    try:
        findings = run_analysis(paths, root=root)
    except OSError as err:
        print(f"error: {err}", file=sys.stderr)
        return 2

    if args.write_baseline:
        Path(args.write_baseline).write_text(
            json.dumps(to_json(findings, nfiles), indent=2,
                       sort_keys=True) + "\n")
        print(f"baseline written to {args.write_baseline}")
        return 0

    failing = [f for f in findings if not f.suppressed]
    if args.baseline:
        try:
            known = load_baseline(Path(args.baseline))
        except (OSError, ValueError, KeyError) as err:
            print(f"error: cannot load baseline: {err}", file=sys.stderr)
            return 2
        failing = [f for f in failing if f.key() not in known]

    if args.format == "json":
        print(json.dumps(to_json(findings, nfiles), indent=2,
                         sort_keys=True))
    else:
        print(render_text(findings, nfiles, verbose=args.verbose))
        if args.baseline and not failing:
            nbase = sum(1 for f in findings
                        if not f.suppressed) - len(failing)
            if nbase:
                print(f"({nbase} known finding(s) covered by baseline "
                      f"{args.baseline})")
    return 1 if failing else 0
