"""Static invariant analysis for the VCS engine (ISSUE 7).

``python -m repro.analysis [paths...]`` lints the tree; ``datagit lint``
is the CLI door onto the same runner. See :mod:`repro.analysis.runner`
for the pass list, the pragma grammar, and the pinned JSON schema.
"""
from .base import Finding, LintModule, Rule
from .runner import (ALL_RULES, KNOWN_TOKENS, SCHEMA_VERSION, default_paths,
                     discover_count, load_baseline, main, render_text,
                     repo_root, run_analysis, to_json)

__all__ = [
    "ALL_RULES", "Finding", "KNOWN_TOKENS", "LintModule", "Rule",
    "SCHEMA_VERSION", "default_paths", "discover_count", "load_baseline",
    "main", "render_text", "repo_root", "run_analysis", "to_json",
]
