"""Deprecation-map enforcement (PR 5), call-graph-aware.

Replaces the old CI ``grep -E '\\.(name)\\('`` step, which missed::

    f = engine.resolve_snapshot      # aliasing, called later
    getattr(engine, "resolve_snapshot")(ref)
    from .engine import resolve_snapshot as rs   # import aliasing

This pass flags ANY load of a deprecated name — attribute access, bare
name, getattr-with-literal, and import aliasing — outside the modules
that define the shims. Definitions themselves (``def resolve_snapshot``)
are not loads and stay clean.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .base import Finding, LintModule, Rule, call_chain, const_str

#: deprecated name -> (replacement hint, modules allowed to touch it)
DEPRECATION_MAP: Dict[str, tuple] = {
    "resolve_snapshot": (
        "repo.resolve('snap:<name>') / refs.resolve — the one ref grammar",
        frozenset({"repro.core.engine"})),
    "snapshot_at": (
        "repo.resolve('<table>@{ts}')",
        frozenset({"repro.core.engine"})),
    "resolve_branch": (
        "refs.as_branch(engine, 'branch:<name>') (resolve_branch is "
        "internal to the resolver)",
        frozenset({"repro.core.workspace", "repro.core.refs"})),
}


class DeprecationRule(Rule):
    id = "deprecation"
    pragma = "legacy-ok"
    doc = ("loads of PR 5 deprecated names (resolve_snapshot, snapshot_at, "
           "workspace.resolve_branch) outside their shim modules — "
           "including aliasing, getattr, and import-as forms")

    def _allowed(self, name: str, mod: LintModule) -> bool:
        return mod.module in DEPRECATION_MAP[name][1]

    def _flag(self, mod: LintModule, node: ast.AST, name: str,
              how: str) -> Finding:
        repl = DEPRECATION_MAP[name][0]
        return self.finding(
            mod, node,
            f"deprecated {name!r} reached via {how}",
            f"use {repl}; only the shim modules may keep calling it "
            f"(or justify with `# lint: {self.pragma} <reason>`)")

    def check(self, mod: LintModule, project) -> List[Finding]:
        if mod.tree is None:
            return []
        out: List[Finding] = []
        imported: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if (alias.name in DEPRECATION_MAP
                            and not self._allowed(alias.name, mod)):
                        out.append(self._flag(
                            mod, node, alias.name,
                            "import" + (f" (aliased as {alias.asname})"
                                        if alias.asname else "")))
                    if alias.name in DEPRECATION_MAP:
                        imported.add(alias.asname or alias.name)
            elif isinstance(node, ast.Attribute):
                if (isinstance(node.ctx, ast.Load)
                        and node.attr in DEPRECATION_MAP
                        and not self._allowed(node.attr, mod)):
                    out.append(self._flag(mod, node, node.attr,
                                          "attribute access"))
            elif isinstance(node, ast.Name):
                if (isinstance(node.ctx, ast.Load)
                        and node.id in DEPRECATION_MAP
                        and node.id in imported
                        and not self._allowed(node.id, mod)):
                    out.append(self._flag(mod, node, node.id, "bare name"))
            elif isinstance(node, ast.Call):
                chain = call_chain(node)
                if chain and chain[-1] == "getattr" and len(node.args) >= 2:
                    attr = const_str(node.args[1])
                    if (attr in DEPRECATION_MAP
                            and not self._allowed(attr, mod)):
                        out.append(self._flag(mod, node, attr,
                                              "getattr with a literal"))
        return out
