"""Sortedness/carry-claim passes (PR 2 / PR 4 producer rules).

A ``runs=`` claim on a :class:`SignedStream`/:class:`SigBatch`, a
``presorted=True`` seal, or a ``sigs=`` carry into ``Txn.insert`` is a
*promise* the engine will not re-verify on the hot path — a false claim
seals misordered objects and corrupts every later probe. The reviewed
producer set lives in ``PRODUCER_MODULES``; any claim elsewhere needs a
``# lint: runs-ok <reason>`` justification.

The companion pass flags ``np.sort``/``np.lexsort``/``np.unique``/
``np.argsort`` in the hot-path modules: the zero-rehash work (PR 4) exists
to keep sorts out of apply/diff, so a new sort there is a latent perf
regression until justified (``# lint: sort-ok <reason>``).
"""
from __future__ import annotations

import ast
from typing import List

from .base import (Finding, LintModule, Rule, call_chain, is_none,
                   keyword_arg)

#: modules whose carry/sortedness claims were reviewed with PR 2/PR 4 —
#: every producer here is covered by carry-validation tests and the
#: DEBUG_VALIDATE_CARRY runtime check
PRODUCER_MODULES = frozenset({
    "repro.core.sigs", "repro.core.objects", "repro.core.delta",
    "repro.core.diff", "repro.core.merge", "repro.core.table",
    "repro.core.engine", "repro.core.workspace", "repro.core.compaction",
    "repro.core.indices",
    # ISSUE 10: pack decode reconstructs sealed objects lane-for-lane from
    # digest-verified blobs — the lanes were sorted when sealed, and the
    # content address pins them bit-for-bit
    "repro.store.packs",
})

#: hot-path modules where a hidden sort undoes the zero-rehash wins
HOT_MODULES = frozenset({
    "repro.core.delta", "repro.core.merge", "repro.core.engine",
    "repro.kernels.ops", "repro.kernels.probe",
    "repro.distributed.sharding",
})

_SORT_FNS = frozenset({"sort", "lexsort", "unique", "argsort"})


class SortedClaimsRule(Rule):
    id = "sorted-claims"
    pragma = "runs-ok"
    doc = ("sortedness/carry claims (SignedStream(runs=...), SigBatch, "
           "seal_data_object(presorted=True), Txn.insert(sigs=...)) outside "
           "the reviewed producer modules need a justification pragma")

    def check(self, mod: LintModule, project) -> List[Finding]:
        if mod.tree is None or mod.module in PRODUCER_MODULES:
            return []
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = call_chain(node)
            tail = chain[-1] if chain else ""
            if tail == "SignedStream":
                runs = keyword_arg(node, "runs")
                if runs is not None and not is_none(runs):
                    out.append(self.finding(
                        mod, node,
                        "SignedStream constructed with a runs= sortedness "
                        "claim outside the reviewed producer modules",
                        "emit runs=None (consumer will sort) or justify "
                        "with `# lint: runs-ok <why the order is real>`"))
            elif tail == "SigBatch":
                runs = keyword_arg(node, "runs")
                claims = ((runs is not None and not is_none(runs))
                          or len(node.args) >= 6)
                if claims:
                    out.append(self.finding(
                        mod, node,
                        "SigBatch constructed with a runs= sortedness claim "
                        "outside the reviewed producer modules"))
            elif chain[-2:] == ["SigBatch", "sorted_run"]:
                out.append(self.finding(
                    mod, node,
                    "SigBatch.sorted_run() claims a single key-sorted run "
                    "outside the reviewed producer modules"))
            elif tail == "seal_data_object":
                pre = keyword_arg(node, "presorted")
                if isinstance(pre, ast.Constant) and pre.value is True:
                    out.append(self.finding(
                        mod, node,
                        "seal_data_object(presorted=True) skips the seal "
                        "sort on an unreviewed path",
                        "drop presorted (the seal will lexsort) or justify "
                        "with `# lint: runs-ok <why rows arrive sorted>`"))
            elif tail == "insert":
                sigs = keyword_arg(node, "sigs")
                if sigs is not None and not is_none(sigs):
                    out.append(self.finding(
                        mod, node,
                        "Txn.insert(..., sigs=...) carries signatures the "
                        "engine will not rehash, from an unreviewed module",
                        "drop sigs= (the engine rehashes) or justify with "
                        "`# lint: runs-ok <where the sigs come from>`"))
        return out


class HiddenSortRule(Rule):
    id = "hidden-sort"
    pragma = "sort-ok"
    doc = ("np.sort/np.lexsort/np.unique/np.argsort in the hot-path "
           "modules (delta, merge, ops, engine, probe, sharding) is a "
           "zero-rehash regression until justified")

    def check(self, mod: LintModule, project) -> List[Finding]:
        if mod.tree is None or mod.module not in HOT_MODULES:
            return []
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = call_chain(node)
            if (len(chain) >= 2 and chain[0] in ("np", "numpy")
                    and chain[-1] in _SORT_FNS):
                out.append(self.finding(
                    mod, node,
                    f"np.{chain[-1]} in hot-path module {mod.module} — "
                    "hidden sort on a zero-rehash path",
                    "carry runs/signatures instead of re-sorting, or "
                    "justify with `# lint: sort-ok <why this path must "
                    "sort>`"))
        return out
