"""Cross-module facts the rules need: crash registry vs call sites, the
WAL kind set, and the replay dispatch table.

Collected in two passes over every scanned module so rules stay local:
pass 1 binds ``CP_X = register("name", ...)`` constants (they are imported
across modules under the same names), pass 2 resolves ``crash_point(...)``
arguments against those bindings.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .base import LintModule, attr_chain, const_str

WAL_MODULE = "repro.core.wal"
ENGINE_MODULE = "repro.core.engine"
#: the one repro.core module allowed to read clocks (spans live here; the
#: wal-hygiene clock check allowlists it)
TELEMETRY_MODULE = "repro.core.telemetry"


class Project:
    """Facts shared by every rule for one analysis run."""

    def __init__(self, modules: List[LintModule]):
        self.modules = modules
        self.by_module: Dict[str, LintModule] = {m.module: m for m in modules}
        #: crash-point name -> (rel path, line) of its register() call
        self.crash_registry: Dict[str, Tuple[str, int]] = {}
        #: constant name (CP_WAL_APPEND) -> crash-point name ("wal.append")
        self.crash_consts: Dict[str, str] = {}
        #: crash-point name -> [(rel path, line)] of crash_point() calls
        self.crash_calls: Dict[str, List[Tuple[str, int]]] = {}
        #: crash_point() calls whose argument could not be resolved
        #: statically: (module, node, source repr)
        self.unresolved_crash_calls: List[Tuple[LintModule, ast.Call, str]] \
            = []
        #: record kinds WAL.append accepts (the KINDS frozenset literal)
        self.wal_kinds: Set[str] = set()
        self.wal_kinds_line: int = 0
        #: record kinds Engine.replay dispatches on
        self.replay_kinds: Set[str] = set()
        self.replay_line: int = 0
        self._collect()

    # ------------------------------------------------------------ pass 1
    def _collect(self) -> None:
        for mod in self.modules:
            if mod.tree is None:
                continue
            self._collect_registry(mod)
            if mod.module == WAL_MODULE:
                self._collect_wal_kinds(mod)
            if mod.module == ENGINE_MODULE:
                self._collect_replay_kinds(mod)
        for mod in self.modules:
            if mod.tree is not None:
                self._collect_crash_calls(mod)

    def _collect_registry(self, mod: LintModule) -> None:
        for node in ast.walk(mod.tree):
            call: Optional[ast.Call] = None
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                call, targets = node.value, node.targets
            elif (isinstance(node, ast.Expr)
                    and isinstance(node.value, ast.Call)):
                call = node.value
            if call is None:
                continue
            chain = attr_chain(call.func)
            if not chain or chain[-1] != "register":
                continue
            if not call.args:
                continue
            name = const_str(call.args[0])
            if name is None:
                continue
            self.crash_registry.setdefault(name, (mod.rel, call.lineno))
            for t in targets:
                if isinstance(t, ast.Name):
                    self.crash_consts[t.id] = name

    def _collect_crash_calls(self, mod: LintModule) -> None:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if not chain or chain[-1] != "crash_point":
                continue
            if not node.args:
                continue
            arg = node.args[0]
            name = const_str(arg)
            if name is None and isinstance(arg, ast.Name):
                name = self.crash_consts.get(arg.id)
            if name is None:
                self.unresolved_crash_calls.append(
                    (mod, node, ast.dump(arg)))
                continue
            self.crash_calls.setdefault(name, []).append(
                (mod.rel, node.lineno))

    # ------------------------------------------------------ WAL / replay
    def _collect_wal_kinds(self, mod: LintModule) -> None:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "KINDS"):
                continue
            self.wal_kinds_line = node.lineno
            for sub in ast.walk(node.value):
                s = const_str(sub)
                if s is not None:
                    self.wal_kinds.add(s)

    def _collect_replay_kinds(self, mod: LintModule) -> None:
        # the dispatch loop may live in ``replay`` itself or in a
        # ``_replay*`` helper it delegates to (the public wrapper opens a
        # telemetry span and resets metrics) — scan both, union the kinds
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.FunctionDef)
                    and (node.name == "replay"
                         or node.name.startswith("_replay"))):
                continue
            if node.name == "replay":
                self.replay_line = node.lineno
            for sub in ast.walk(node):
                if isinstance(sub, ast.Compare):
                    # only DIRECT string operands: `k == "commit"`.
                    # Walking deeper would pick up subscript keys
                    # (p["ts"]) that are not dispatch kinds.
                    for cand in [sub.left, *sub.comparators]:
                        s = const_str(cand)
                        if s is not None:
                            self.replay_kinds.add(s)
