"""Sealed-object immutability pass (static half of the write sanitizer).

Sealed objects (``DataObject``/``TombstoneObject``) are immutable by
contract: zone maps, signature carries, the visibility/delta caches, and
replay determinism all assume a sealed lane never changes. The runtime
half (``REPRO_SANITIZE=1``) freezes every sealed numpy lane at
``ObjectStore.put``; this pass catches the writes statically, including
through local aliases::

    arr = obj.cols["v"]          # alias of a sealed lane
    arr[3] = 0.0                 # flagged (taint-tracked)
    obj.key_lo[i] = sig          # flagged (direct)
    lane.setflags(write=True)    # flagged (un-freezing)

Alias tracking is intra-function and deliberately conservative: taint
propagates through plain views (subscript/slice, ``.view``, ``.reshape``,
``.ravel``) and dies at allocating calls (``.copy()``, ``np.concatenate``,
arithmetic), so rebinding a lane into a fresh array stays clean.
"""
from __future__ import annotations

import ast
from typing import List, Set

from .base import Finding, LintModule, Rule, call_chain

#: attribute names that are sealed-object lanes
SEALED_ATTRS = frozenset({
    "cols", "commit_ts", "row_lo", "row_hi", "key_lo", "key_hi",
    "lob_sigs", "target",
})

#: methods that return a VIEW of their receiver (taint flows through)
_VIEW_METHODS = frozenset({"view", "reshape", "ravel", "squeeze",
                           "transpose"})

#: ndarray methods that mutate their receiver in place
_MUTATORS = frozenset({"fill", "sort", "partition", "put", "itemset",
                       "byteswap"})


def _taints(expr: ast.AST, tainted: Set[str]) -> bool:
    """Does ``expr`` evaluate to (a view of) a sealed lane?"""
    if isinstance(expr, ast.Attribute):
        return expr.attr in SEALED_ATTRS
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Subscript):
        return _taints(expr.value, tainted)
    if isinstance(expr, ast.Call):
        if (isinstance(expr.func, ast.Attribute)
                and expr.func.attr in _VIEW_METHODS):
            return _taints(expr.func.value, tainted)
        return False
    return False


class SealedWriteRule(Rule):
    id = "sealed-write"
    pragma = "seal-ok"
    doc = ("in-place writes to sealed-object lanes (cols/commit_ts/row_*/"
           "key_*/lob_sigs/target), including through local aliases, and "
           "setflags(write=True) un-freezing")

    def check(self, mod: LintModule, project) -> List[Finding]:
        if mod.tree is None:
            return []
        out: List[Finding] = []
        scopes = [n for n in ast.walk(mod.tree)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        scopes.append(mod.tree)
        seen: Set[int] = set()
        for scope in scopes:
            tainted: Set[str] = set()
            body = scope.body if hasattr(scope, "body") else []
            for stmt in body:
                self._visit_stmt(mod, stmt, tainted, out, seen)
        return out

    def _visit_stmt(self, mod, stmt, tainted, out, seen) -> None:
        # statement-order walk so aliases are bound before their writes
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                self._check_store(mod, t, stmt.value, tainted, out, seen)
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    if _taints(stmt.value, tainted):
                        tainted.add(t.id)
                    else:
                        tainted.discard(t.id)
        elif isinstance(stmt, ast.AugAssign):
            self._check_store(mod, stmt.target, stmt.value, tainted, out,
                              seen)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                      # nested scope handled separately
        for sub in ast.iter_child_nodes(stmt):
            if isinstance(sub, ast.stmt):
                self._visit_stmt(mod, sub, tainted, out, seen)
            elif isinstance(sub, ast.ExceptHandler):
                for s in sub.body:
                    self._visit_stmt(mod, s, tainted, out, seen)
            elif isinstance(sub, ast.expr):
                self._check_expr(mod, sub, tainted, out, seen)

    def _check_store(self, mod, target, value, tainted, out, seen) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_store(mod, elt, value, tainted, out, seen)
            return
        if isinstance(target, ast.Subscript) and id(target) not in seen \
                and _taints(target.value, tainted):
            seen.add(id(target))
            out.append(self.finding(
                mod, target,
                "in-place write into a sealed-object lane "
                "(REPRO_SANITIZE=1 raises here at runtime)",
                "build a fresh array and seal a new object — sealed "
                "lanes are immutable; or justify with "
                "`# lint: seal-ok <reason>`"))

    def _check_expr(self, mod, expr, tainted, out, seen) -> None:
        for sub in ast.walk(expr):
            if not isinstance(sub, ast.Call) or id(sub) in seen:
                continue
            chain = call_chain(sub)
            if not chain:
                continue
            if chain[-1] == "setflags":
                for kw in sub.keywords:
                    if (kw.arg == "write"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True):
                        seen.add(id(sub))
                        out.append(self.finding(
                            mod, sub,
                            "setflags(write=True) re-arms writes on an "
                            "array — defeats the sealed-lane sanitizer"))
            elif (chain[-1] in _MUTATORS
                    and isinstance(sub.func, ast.Attribute)
                    and _taints(sub.func.value, tainted)):
                seen.add(id(sub))
                out.append(self.finding(
                    mod, sub,
                    f".{chain[-1]}() mutates a sealed-object lane in "
                    "place"))
