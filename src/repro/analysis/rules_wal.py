"""WAL/replay hygiene pass.

The WAL is the single source of truth at recovery: a record kind nobody
replays is data loss, and a logging function that consults wall-clock
time or an RNG makes replay non-deterministic (replay re-executes the
logged operations — any nondeterministic input diverges the rebuilt
engine from the one that crashed).

Checks:

* every ``*.wal.append("kind", ...)`` site uses a string-literal kind
  that is both in ``wal.KINDS`` and dispatched by ``Engine.replay``;
* the ``KINDS`` set and the replay dispatch table agree exactly (a kind
  in one but not the other is reported once, at the owning module);
* a function that appends WAL records must not call time/RNG sources
  (``time.*``, ``datetime.now``, ``random.*``, ``np.random.*``,
  ``secrets``, ``uuid``);
* no ``repro.core`` module reads clocks at all, except
  ``core.telemetry`` (ISSUE 8): the span tracer is the one sanctioned
  home for ``perf_counter`` — timings that originate anywhere else in
  the core can leak into WAL payloads or derived state and diverge
  replay.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .base import Finding, LintModule, Rule, attr_chain, call_chain, \
    const_str
from .project import ENGINE_MODULE, TELEMETRY_MODULE, WAL_MODULE

#: call chains whose presence in a WAL-appending function breaks replay
#: determinism (matched on the first element + any tail)
_NONDET_HEADS = frozenset({"random", "secrets", "uuid"})
_NONDET_TIME = frozenset({"time", "time_ns", "monotonic", "monotonic_ns",
                          "perf_counter", "perf_counter_ns", "now",
                          "utcnow", "today"})


def _wal_append_calls(node: ast.AST) -> List[ast.Call]:
    out = []
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        chain = call_chain(sub)
        if len(chain) >= 2 and chain[-1] == "append" and chain[-2] == "wal":
            out.append(sub)
    return out


def _nondet_call(node: ast.AST) -> Optional[ast.Call]:
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        chain = call_chain(sub)
        if not chain:
            continue
        if chain[0] in _NONDET_HEADS:
            return sub
        if len(chain) >= 2 and chain[0] in ("np", "numpy") \
                and chain[1] == "random":
            return sub
        if len(chain) >= 2 and chain[0] in ("time", "datetime") \
                and chain[-1] in _NONDET_TIME:
            return sub
    return None


def _clock_calls(tree: ast.AST) -> List[ast.Call]:
    """Every ``time.*``/``datetime.*`` clock read in the tree (the
    module-wide check — RNG is left to the per-function WAL pass)."""
    out = []
    for sub in ast.walk(tree):
        if not isinstance(sub, ast.Call):
            continue
        chain = call_chain(sub)
        if len(chain) >= 2 and chain[0] in ("time", "datetime") \
                and chain[-1] in _NONDET_TIME:
            out.append(sub)
    return out


class WalHygieneRule(Rule):
    id = "wal-hygiene"
    pragma = "wal-ok"
    doc = ("WAL-append sites must log literal kinds known to KINDS and the "
           "replay dispatch, and WAL-appending functions must be replay-"
           "deterministic (no time/RNG)")

    def check(self, mod: LintModule, project) -> List[Finding]:
        if mod.tree is None:
            return []
        out: List[Finding] = []
        if mod.module == WAL_MODULE and project.replay_kinds:
            for kind in sorted(project.wal_kinds - project.replay_kinds):
                out.append(Finding(
                    rule=self.id, path=mod.rel,
                    line=project.wal_kinds_line, col=0,
                    message=f"KINDS contains {kind!r} but Engine.replay "
                            "never dispatches it — records of this kind "
                            "are silently lost at recovery",
                    hint="add a replay arm or drop the kind"))
        if mod.module == ENGINE_MODULE and project.wal_kinds:
            for kind in sorted(project.replay_kinds - project.wal_kinds):
                out.append(Finding(
                    rule=self.id, path=mod.rel, line=project.replay_line,
                    col=0,
                    message=f"Engine.replay dispatches {kind!r} which "
                            "WAL.append would reject (not in KINDS)",
                    hint="add the kind to wal.KINDS or drop the dead arm"))
        funcs = [n for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        seen_calls = set()
        flagged_nondet = set()
        for fn in funcs:
            appends = [c for c in _wal_append_calls(fn)
                       if id(c) not in seen_calls]
            if not appends:
                continue
            seen_calls.update(id(c) for c in appends)
            for call in appends:
                kind = const_str(call.args[0]) if call.args else None
                if kind is None:
                    out.append(self.finding(
                        mod, call,
                        f"{fn.name}() appends a WAL record with a non-"
                        "literal kind — replay reachability cannot be "
                        "checked statically",
                        "pass the kind as a string literal"))
                    continue
                if project.wal_kinds and kind not in project.wal_kinds:
                    out.append(self.finding(
                        mod, call,
                        f"{fn.name}() logs unknown WAL kind {kind!r} "
                        "(not in wal.KINDS)"))
                elif project.replay_kinds \
                        and kind not in project.replay_kinds:
                    out.append(self.finding(
                        mod, call,
                        f"{fn.name}() logs WAL kind {kind!r} that "
                        "Engine.replay never dispatches — unrecoverable "
                        "at crash time"))
            nondet = _nondet_call(fn)
            if nondet is not None:
                flagged_nondet.add(id(nondet))
                src = ".".join(attr_chain(nondet.func)) or "<call>"
                out.append(self.finding(
                    mod, nondet,
                    f"{fn.name}() appends WAL records AND calls {src} — "
                    "time/RNG in a logging function breaks replay "
                    "determinism",
                    "hoist the nondeterminism out (log its result as "
                    "payload) or justify with `# lint: wal-ok <reason>`"))
        if mod.module.startswith("repro.core.") \
                and mod.module != TELEMETRY_MODULE:
            # the clock lives in core.telemetry and ONLY there — a core
            # module that reads time can leak it into WAL payloads or
            # derived state, diverging replay (skip calls the WAL pass
            # above already reported)
            for call in _clock_calls(mod.tree):
                if id(call) in flagged_nondet:
                    continue
                src = ".".join(attr_chain(call.func)) or "<call>"
                out.append(self.finding(
                    mod, call,
                    f"core module calls {src} — clocks belong to "
                    f"{TELEMETRY_MODULE} (span timings), nowhere else "
                    "in repro.core",
                    "open a telemetry span instead, or justify with "
                    "`# lint: wal-ok <reason>`"))
        return out
