"""Crash-point coverage pass (PR 6 fault-injection contract).

Cross-checks the ``core.faults`` registry against real ``crash_point``
call sites, and enforces the seam-placement discipline:

* every registered name is called somewhere (a registered-but-never-hit
  seam gives the crash sweep false confidence);
* every ``crash_point`` argument resolves to a registered name;
* every ``os.fsync`` site and every multi-/looped directory swing sits in
  a function that also marks a crash point (the durability seams the
  sweep must be able to kill);
* no bare ``except:``/``except BaseException`` without re-raise — and no
  ``except Exception`` — lexically encloses a crash-point seam, where it
  reads like (or is) an InjectedCrash swallow.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .base import Finding, LintModule, Rule, attr_chain, call_chain


def _has_call(node: ast.AST, tail: str) -> Optional[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = call_chain(sub)
            if chain and chain[-1] == tail:
                return sub
    return None


def _fsync_calls(node: ast.AST) -> List[ast.Call]:
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = call_chain(sub)
            if chain and chain[-1] == "fsync":
                out.append(sub)
    return out


def _swing_calls(node: ast.AST) -> List[ast.Call]:
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = call_chain(sub)
            if chain and chain[-1] == "set_directory":
                out.append(sub)
    return out


def _in_loop(func: ast.AST, target: ast.Call) -> bool:
    for sub in ast.walk(func):
        if isinstance(sub, (ast.For, ast.While)):
            for inner in ast.walk(sub):
                if inner is target:
                    return True
    return False


def _handler_reraises(handler: ast.ExceptHandler) -> bool:
    for sub in ast.walk(handler):
        if isinstance(sub, ast.Raise):
            return True
    return False


class CrashCoverageRule(Rule):
    id = "crash-coverage"
    pragma = "crash-ok"
    doc = ("cross-check of the core.faults registry vs crash_point call "
           "sites; fsync/directory-swing seams must be guarded; no broad "
           "except may enclose a crash-point seam")

    def check(self, mod: LintModule, project) -> List[Finding]:
        if mod.tree is None:
            return []
        out: List[Finding] = []
        # registry entries with no call site, reported at the register()
        for name, (rel, line) in sorted(project.crash_registry.items()):
            if rel != mod.rel:
                continue
            if not project.crash_calls.get(name):
                out.append(Finding(
                    rule=self.id, path=mod.rel, line=line, col=0,
                    message=f"crash point {name!r} is registered but never "
                            "marked with crash_point() anywhere",
                    hint="call crash_point at the seam (or remove the "
                         "registration) so the crash sweep can reach it"))
        # crash_point args that resolve to nothing
        for m, node, repr_ in project.unresolved_crash_calls:
            if m is mod:
                out.append(self.finding(
                    mod, node,
                    "crash_point() argument is not a registered name or a "
                    "CP_* constant bound by register() — the sweep cannot "
                    "enumerate this seam",
                    "bind the name via `CP_X = register(...)` and pass "
                    "CP_X"))
        for name, sites in project.crash_calls.items():
            if name in project.crash_registry:
                continue
            for rel, line in sites:
                if rel == mod.rel:
                    out.append(Finding(
                        rule=self.id, path=mod.rel, line=line, col=0,
                        message=f"crash_point({name!r}) names an "
                                "unregistered crash point",
                        hint="register() it at import time so "
                             "registered() enumerates the seam"))
        # seam-placement checks, per function
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            guarded = _has_call(node, "crash_point") is not None
            if not guarded:
                for call in _fsync_calls(node):
                    out.append(self.finding(
                        mod, call,
                        f"os.fsync in {node.name}() without a crash_point "
                        "seam — the crash sweep cannot kill the process at "
                        "this durability boundary"))
                swings = _swing_calls(node)
                if len(swings) > 1 or any(_in_loop(node, s) for s in swings):
                    out.append(self.finding(
                        mod, swings[0],
                        f"{node.name}() swings multiple directories "
                        "without a crash_point between swings — a mid-"
                        "swing crash is unreachable by the sweep"))
        # broad excepts around seams
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Try):
                continue
            body_block = ast.Module(body=node.body, type_ignores=[])
            seam = (_has_call(body_block, "crash_point")
                    or _fsync_calls(body_block))
            if not seam:
                continue
            for handler in node.handlers:
                broad = handler.type is None or (
                    attr_chain(handler.type)[-1:] in (["BaseException"],
                                                      ["Exception"]))
                if broad and not _handler_reraises(handler):
                    what = ("bare except" if handler.type is None
                            else f"except {attr_chain(handler.type)[-1]}")
                    out.append(self.finding(
                        mod, handler,
                        f"{what} without re-raise encloses a crash-point/"
                        "fsync seam — an InjectedCrash (or real failure) "
                        "unwind can be masked here",
                        "narrow the except, re-raise, or justify with "
                        "`# lint: crash-ok <why the seam is safe>`"))
        return out
