"""Lint framework core: findings, pragma grammar, module model, rule base.

The analysis suite (ISSUE 7) machine-checks the invariants that previously
lived only in ROADMAP prose: sortedness/carry claims, crash-point coverage,
the deprecation map, WAL/replay hygiene, and sealed-object immutability.
Everything is pure-``ast`` — no imports of the linted code, so a module
that fails to import (missing optional dep, heavy accelerator init) still
lints.

Pragma grammar
--------------
A finding is suppressed by a *justified* pragma on the finding line or on a
comment-only line directly above it::

    # lint: <token> <reason>
    arr = SignedStream(..., runs=my_runs)          # suppressed (if justified)

    tx.insert(t, batch, sigs=sigs)  # lint: runs-ok gathered from sealed objs

``<token>`` names the rule being silenced (each rule owns one token — see
``Rule.pragma``). ``<reason>`` is REQUIRED: a bare ``# lint: runs-ok``
does not suppress and itself raises a ``pragma`` finding, so suppressions
stay reviewable. Unknown tokens are flagged too (catches typos that would
otherwise silently fail to suppress).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

#: the pragma marker (hash, "lint:", token, reason) anywhere in a line —
#: trailing comments and comment-only lines both match
PRAGMA_RE = re.compile(r"#\s*lint:\s*([A-Za-z0-9_-]+)[ \t]*(.*?)\s*$")

#: line is nothing but a comment (a *standalone* pragma line applies to the
#: first code line below it)
COMMENT_ONLY_RE = re.compile(r"^\s*#")


@dataclass(frozen=True)
class Finding:
    """One rule violation, pinned to a source location."""
    rule: str                  # rule id, e.g. "sorted-claims"
    path: str                  # repo-relative posix path
    line: int                  # 1-based
    col: int                   # 0-based
    message: str               # what is wrong
    hint: str = ""             # how to fix (or how to suppress with a reason)
    suppressed: bool = False   # a justified pragma covers this finding
    reason: str = ""           # the pragma's justification text

    def key(self) -> Tuple[str, str, str]:
        """Identity for baseline diffing: line numbers drift across edits,
        (rule, path, message) survives them."""
        return (self.rule, self.path, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message, "hint": self.hint,
                "suppressed": self.suppressed, "reason": self.reason}

    def render(self) -> str:
        tag = f" [suppressed: {self.reason}]" if self.suppressed else ""
        out = f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
              f"{self.message}{tag}"
        if self.hint and not self.suppressed:
            out += f"\n    hint: {self.hint}"
        return out


class LintModule:
    """One parsed source file: AST + raw lines + pragma table."""

    def __init__(self, path: Path, root: Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.module = self._module_name(self.rel)
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        #: 1-based line -> [(token, reason)]
        self.pragmas: Dict[int, List[Tuple[str, str]]] = {}
        self.parse_error: Optional[Finding] = None
        try:
            self.tree: Optional[ast.AST] = ast.parse(self.source,
                                                     filename=str(path))
        except SyntaxError as err:
            self.tree = None
            self.parse_error = Finding(
                rule="parse", path=self.rel, line=err.lineno or 1,
                col=err.offset or 0,
                message=f"syntax error: {err.msg}",
                hint="the analysis suite requires every scanned file to "
                     "parse")
        self._scan_pragmas()

    @staticmethod
    def _module_name(rel: str) -> str:
        parts = rel.split("/")
        if parts[0] == "src":
            parts = parts[1:]
        if parts[-1] == "__init__.py":
            parts = parts[:-1]
        elif parts[-1].endswith(".py"):
            parts[-1] = parts[-1][:-3]
        return ".".join(parts)

    def _scan_pragmas(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            m = PRAGMA_RE.search(line)
            if m:
                token, reason = m.group(1), m.group(2).strip()
                self.pragmas.setdefault(i, []).append((token, reason))

    def pragma_reason(self, line: int, token: str) -> Optional[str]:
        """The justification suppressing ``token`` at ``line`` (or None).

        Looks at the finding line itself, then at a run of comment-only
        lines directly above (so a pragma can sit above a long wrapped
        statement)."""
        for tok, reason in self.pragmas.get(line, ()):
            if tok == token and reason:
                return reason
        j = line - 1
        while j >= 1 and COMMENT_ONLY_RE.match(self.lines[j - 1] or ""):
            for tok, reason in self.pragmas.get(j, ()):
                if tok == token and reason:
                    return reason
            j -= 1
        return None


class Rule:
    """Base class: one invariant pass. Subclasses set ``id`` (finding tag),
    ``pragma`` (suppression token) and ``doc``, and implement ``check``."""

    id: str = ""
    pragma: str = ""
    doc: str = ""

    def check(self, mod: LintModule, project) -> List[Finding]:
        raise NotImplementedError

    def finding(self, mod: LintModule, node: ast.AST, message: str,
                hint: str = "") -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        if not hint:
            hint = (f"justify with `# lint: {self.pragma} <reason>` "
                    "if this is intentional")
        return Finding(rule=self.id, path=mod.rel, line=line, col=col,
                       message=message, hint=hint)


# --------------------------------------------------------------------------
# small AST helpers shared by the rules
# --------------------------------------------------------------------------

def attr_chain(node: ast.AST) -> List[str]:
    """``np.random.default_rng`` -> ['np', 'random', 'default_rng'];
    [] when the expression is not a pure Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def call_chain(node: ast.Call) -> List[str]:
    return attr_chain(node.func)


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def is_none(node: Optional[ast.AST]) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None
