"""AdamW with cosine schedule, global-norm clipping and configurable state
dtype — pure JAX (no optax). Optimizer states inherit the parameters'
sharding (FSDP ⇒ ZeRO: states live sharded over 'data').

``state_dtype='bfloat16'`` halves m/v memory — the knob big-arch configs use
(jamba-398B on one pod; see EXPERIMENTS.md §Dry-run notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # "bfloat16" halves optimizer memory


class OptState(NamedTuple):
    step: jnp.ndarray     # () int32
    mu: Any               # pytree like params
    nu: Any


def init_opt_state(params, cfg: AdamWCfg) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(z, params), jax.tree.map(z, params))


def lr_at(cfg: AdamWCfg, step):
    step = step.astype(F32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) \
        * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: AdamWCfg):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m32 = b1 * m.astype(F32) + (1 - b1) * g
        v32 = b2 * v.astype(F32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(F32)
        newp = p.astype(F32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return newp, OptState(step, newm, newv), metrics
