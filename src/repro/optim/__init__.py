from .adamw import AdamWCfg, OptState, apply_updates, init_opt_state, lr_at  # noqa
