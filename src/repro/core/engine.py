"""The version-control engine: transactions, snapshots, clone/restore,
lineage bookkeeping, WAL + deterministic replay (paper §§3–5).

Single-node stand-in for MatrixOne's CN/TN/LogService split: commits are
serialized through ``_commit`` (the TN role), every logical change is WAL'd
(the LogService role), and all bulk row work is vectorized over the kernel
ops (the CN role).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..kernels import ops
from .directory import Directory, Snapshot
from .objects import (OBJECT_CAPACITY, DataObject, ObjectStore,
                      TombstoneObject, pack_rowid, rowid_off, rowid_oid,
                      seal_data_object)
# cycle-safe: refs only imports .directory at module level (its resolver
# pulls .workspace lazily), unlike the engine<->workspace/indices cycles
# that force the local imports elsewhere in this file
from .refs import AtRef, BareRef, parse_ref, require, validate_name
from .refs import RefSyntaxError, resolve as resolve_ref
from .schema import Schema, concat_batches, take_batch
from .sigs import (SigBatch, concat_sigs, key_sigs_for_lookup, resolve_sigs,
                   validate_runs)
from . import telemetry
from .faults import crash_point, register
from .table import Table
from .visibility import visibility_index
from .wal import WAL, TornTransaction

SP_COMMIT = telemetry.register_span(
    "commit", "one atomic (possibly multi-table) transaction commit")
SP_COMMIT_SEAL = telemetry.register_span(
    "commit.seal", "commit phase 1: validate every table and seal its "
    "objects (no directory touched)")
SP_COMMIT_SWING = telemetry.register_span(
    "commit.swing", "commit phase 2: swing every directory (the WAL "
    "group is already logged)")
SP_GC = telemetry.register_span(
    "gc", "mark-sweep garbage collection over the object store")
SP_REPLAY = telemetry.register_span(
    "replay", "rebuild an engine from a WAL (recovery)")

CP_COMMIT_PRE_SEAL = register(
    "engine.commit.pre_seal",
    "top of _commit, before the timestamp or any object is allocated — "
    "the transaction must be fully absent")
CP_COMMIT_POST_SEAL = register(
    "engine.commit.post_seal",
    "after phase 1 sealed every table's objects but before any WAL record "
    "or directory swing — nothing logged, so recovery must show nothing")
CP_COMMIT_MID_SWING = register(
    "engine.commit.mid_swing",
    "between directory swings of a multi-table commit — the WAL already "
    "holds the FULL group (log-before-swing), so recovery must show the "
    "whole transaction")
CP_GC_MID_SWEEP = register(
    "engine.gc.mid_sweep",
    "between object deletions of a GC sweep — GC is not WAL-logged, so "
    "recovery replays to the same logical state with more garbage")


class TxnConflict(Exception):
    """Write-write conflict: a target row vanished before commit."""


class PKViolation(Exception):
    pass


SnapshotRef = Union[str, Snapshot]


class Txn:
    """Optimistic transaction: workspace of inserts + resolved delete rowids."""

    def __init__(self, engine: "Engine"):
        self.engine = engine
        self.read_ts = engine.ts
        self._ins: Dict[str, List[Dict[str, np.ndarray]]] = {}
        # signature sidecars, aligned 1:1 with _ins (None = hash at seal);
        # kept out of _ins so WAL commit records stay plain batches —
        # replay recomputes the (identical, write-once) signatures
        self._sigs: Dict[str, List[Optional[SigBatch]]] = {}
        self._del: Dict[str, List[np.ndarray]] = {}
        self.committed: Optional[int] = None

    def insert(self, table: str, batch,
               sigs: Optional[SigBatch] = None) -> None:
        """Stage a batch; ``sigs`` is the zero-rehash carry contract.

        Passing ``sigs`` asserts the batch came verbatim off sealed
        objects (``gather_payload(with_sigs=True)`` / ``scan_carry``):
        it is already schema-normalized (bytes LOBs, exact dtypes) and
        the caller RELINQUISHES the arrays — a single-object seal reuses
        them zero-copy, so mutating them after commit would corrupt the
        sealed object behind its carried signatures. Producer-authored
        data must use ``sigs=None`` (normalized + hashed at seal)."""
        t = self.engine.table(table)
        if sigs is None:
            batch = t.schema.normalize_batch(batch)
        self._ins.setdefault(table, []).append(batch)
        self._sigs.setdefault(table, []).append(sigs)

    def delete_rowids(self, table: str, rowids: np.ndarray) -> None:
        self._del.setdefault(table, []).append(np.asarray(rowids, np.uint64))

    def delete_by_keys(self, table: str, key_batch) -> int:
        """Resolve PK -> rowids against the current state; returns #resolved."""
        t = self.engine.table(table)
        key_batch = {k: np.asarray(v) for k, v in key_batch.items()}
        klo, khi = key_sigs_for_lookup(t.schema, key_batch)
        rid = t.locate_keys(klo, khi)
        hit = rid != 0
        self.delete_rowids(table, rid[hit])
        return int(hit.sum())

    def update_by_keys(self, table: str, batch) -> int:
        """Upsert semantics used by the paper's UPDATE experiments: delete the
        existing row for each key (if any), insert the new version."""
        t = self.engine.table(table)
        batch = t.schema.normalize_batch(batch)
        n = self.delete_by_keys(
            table, {k: batch[k] for k in t.schema.primary_key})
        self.insert(table, batch)
        return n

    @property
    def staged(self) -> bool:
        """True iff the workspace holds any insert or delete."""
        return (any(b for b in self._ins.values())
                or any(d.shape[0] for ds in self._del.values() for d in ds))

    def commit(self, *, _log: bool = True) -> int:
        # expand with secondary-index maintenance (same-commit atomic)
        if self.engine.indices:
            from .indices import maintain_on_commit
            for name in list(self._ins.keys() | self._del.keys()):
                if name in self.engine.indices:
                    # lint: sort-ok delete-target dedup at commit time —
                    # targets arrive from arbitrary staging order
                    dels = (np.unique(np.concatenate(self._del[name]))
                            if self._del.get(name)
                            else np.zeros((0,), np.uint64))
                    maintain_on_commit(self.engine, self, name,
                                       self._ins.get(name, []), dels)
        ts = self.engine._commit(self, _log=_log)
        self.committed = ts
        return ts


class Engine:
    def __init__(self, retention_versions: int = 1024):
        self.store = ObjectStore()
        self.wal = WAL()
        self.commit_stats = CommitStats()
        self.ts = 0
        self.tables: Dict[str, Table] = {}
        self.snapshots: Dict[str, Snapshot] = {}
        self.retention_versions = retention_versions
        # lineage: latest common base snapshot per unordered table pair
        self._base: Dict[Tuple[str, str], Snapshot] = {}
        # secondary indices (paper §5.5.4): base table -> [IndexSpec]
        self.indices: Dict[str, list] = {}
        # workflow porcelain (ISSUE 3): branch refs + pull requests
        self.branches: Dict[str, "Branch"] = {}
        self.prs: Dict[int, "PullRequest"] = {}
        self._next_pr_id = 1
        # commit log (ISSUE 5): one CommitRecord per table per applied
        # operation, tagged with the porcelain op kind — the source of
        # ``Repo.log``. Appended on the same code paths replay re-executes,
        # so a replayed engine carries an identical log.
        self.commit_log: List[CommitRecord] = []
        self._op_kind = "commit"

    @contextlib.contextmanager
    def op_kind(self, kind: str):
        """Tag commits applied inside the block with a porcelain op kind
        (merge/publish/revert/...) for the commit log."""
        prev, self._op_kind = self._op_kind, kind
        try:
            yield
        finally:
            self._op_kind = prev

    def reset_metrics(self) -> None:
        """Zero every registered telemetry counter on this engine
        (``telemetry.metrics_snapshot`` reads all zeros afterwards).
        ``replay`` calls this last: replay re-executes commits with live
        counters, but traces are derived state, never durable state — a
        recovered engine must start clean."""
        self.commit_stats = CommitStats()
        store = self.store
        if store.vis_cache is not None:
            vc = store.vis_cache
            vc.builds = vc.extends = vc.derives = vc.hits = 0
        if store.delta_cache is not None:
            store.delta_cache.hits = 0
        store.metrics.reset()
        w = self.wal
        w.frames = w.bytes_written = w.fsyncs = 0

    # ------------------------------------------------------------ basics
    def next_ts(self) -> int:
        self.ts += 1
        return self.ts

    def table(self, name: str) -> Table:
        return self.tables[name]

    def create_table(self, name: str, schema: Schema, *, _log=True) -> Table:
        if name in self.tables:
            raise ValueError(f"table {name} exists")
        t = Table(name, schema, self.store, self.ts)
        self.tables[name] = t
        self.commit_log.append(CommitRecord(self.ts, name, "create", 0, 0))
        if _log:
            self.wal.append("create_table", name=name, schema=schema)
        return t

    def drop_table(self, name: str, *, _log=True) -> None:
        require(self.tables, name, "table")
        # drop secondary-index specs and their auxiliary tables with the
        # base table — a dropped table must not leave dangling
        # ``engine.indices`` entries or live aux tables behind
        for spec in self.indices.pop(name, []):
            if spec.aux_table in self.tables:
                self.drop_table(spec.aux_table, _log=False)
        del self.tables[name]
        self._base = {k: v for k, v in self._base.items() if name not in k}
        if _log:
            self.wal.append("drop_table", name=name)

    def begin(self) -> Txn:
        return Txn(self)

    # convenience single-op transactions
    def insert(self, table: str, batch) -> int:
        tx = self.begin()
        tx.insert(table, batch)
        return tx.commit()

    def delete_by_keys(self, table: str, key_batch) -> int:
        tx = self.begin()
        n = tx.delete_by_keys(table, key_batch)
        tx.commit()
        return n

    def update_by_keys(self, table: str, batch) -> int:
        tx = self.begin()
        n = tx.update_by_keys(table, batch)
        tx.commit()
        return n

    # ------------------------------------------------------------ commit
    def _seal_inserts(self, schema: Schema, batches, sig_batches, ts: int):
        """Key-sort the txn's inserts and seal capacity-sized objects with
        disjoint zones.

        The zero-rehash apply path: batches whose rows were gathered from
        sealed objects arrive with a ``SigBatch`` sidecar — their row/key
        signatures and LOB content signatures are reused verbatim (they are
        write-once per object), and a declared-key-sorted batch (one run)
        skips the global sort outright while multi-run batches take the
        stable k-way merge (≡ np.lexsort). Only producer-authored rows pay
        ``compute_sigs``. Returns (oids, (key_lo, key_hi)) with the key
        lanes in SEALED (sorted) order."""
        from .sigs import DEBUG_VALIDATE_CARRY
        stats = self.commit_stats
        parts = []
        for b, sg in zip(batches, sig_batches):
            if schema.validate_batch(b) == 0:
                continue
            parts.append((b, resolve_sigs(schema, b, sg, stats)))
        if not parts:
            return [], None
        batch = (parts[0][0] if len(parts) == 1
                 else concat_batches(schema, [b for b, _ in parts]))
        sigs = concat_sigs([s for _, s in parts])
        row_lo, row_hi = sigs.row_lo, sigs.row_hi
        key_lo, key_hi = sigs.key_lo, sigs.key_hi
        lob_sigs, runs = sigs.lob_sigs, sigs.runs
        alias = key_lo is row_lo           # NoPK: key IS the row signature
        n = int(row_lo.shape[0])
        if runs is not None and DEBUG_VALIDATE_CARRY:
            validate_runs(key_lo, key_hi, runs)
        order = None
        if runs is not None and runs.shape[0] <= 1:
            stats.apply_sort_skipped += 1  # producer-declared key-sorted
        elif runs is None:
            # lint: sort-ok THE counted fallback for claim-less batches —
            # commit_stats.apply_sorts pins it to zero on carry paths
            order = np.lexsort((key_hi, key_lo))
            stats.apply_sorts += 1
        else:
            # multi-run seal merges shard by key range when big enough
            # (derived plan — byte-identical sealed order, so zone maps,
            # carried sigs and GOLDEN digests are untouched)
            from ..distributed.sharding import maybe_key_cuts
            cuts = maybe_key_cuts(key_lo, key_hi, runs)
            if cuts is not None:
                self.store.metrics.add("probe.shard_parts",
                                       cuts[0].shape[0] + 1)
            order = ops.merge128_runs(key_lo, key_hi, runs, cuts=cuts)
            stats.apply_sort_merged += 1
        if order is not None:
            s_klo, s_khi = key_lo[order], key_hi[order]
        else:
            s_klo, s_khi = key_lo, key_hi
        # Objects must own COMPACT arrays: sealing capacity slices as views
        # of one multi-object parent makes every later Δ-scan gather
        # page-walk the whole parent (measured 3-5x on cold diff). The
        # single-object case — every Δ-sized apply — stays zero-copy.
        multi = n > OBJECT_CAPACITY
        oids = []
        for s in range(0, n, OBJECT_CAPACITY):
            e = min(s + OBJECT_CAPACITY, n)
            if order is not None:
                idx = order[s:e]
                take = lambda a: a[idx]
            elif multi:
                sl = slice(s, e)
                take = lambda a: a[sl].copy()
            else:
                take = lambda a: a
            rl, rh = take(row_lo), take(row_hi)
            kl = rl if alias else take(key_lo)
            kh = rh if alias else take(key_hi)
            obj = seal_data_object(
                self.store.new_oid(), schema,
                {k: take(v) for k, v in batch.items()},
                np.full((e - s,), np.uint64(ts)), rl, rh, kl, kh,
                {k: take(v) for k, v in lob_sigs.items()}, presorted=True)
            self.store.put(obj)
            oids.append(obj.oid)
        return oids, (s_klo, s_khi)

    def _seal_tombstones(self, targets: np.ndarray, ts: int) -> List[int]:
        if targets.shape[0] == 0:
            return []
        # lint: sort-ok tombstone targets must be rowid-sorted so the
        # one boundary pass below can gather per-object key lanes
        targets = np.sort(targets)
        klo = np.empty_like(targets)
        khi = np.empty_like(targets)
        toids = rowid_oid(targets)
        offs = rowid_off(targets)
        # sorted targets group their oids contiguously (rowid = oid<<32 |
        # off), so one boundary pass gathers every object's key lanes —
        # the old per-unique-oid boolean masks were O(n·#objects)
        bnd = np.flatnonzero(toids[1:] != toids[:-1]) + 1
        starts = np.concatenate([[0], bnd])
        ends = np.append(bnd, targets.shape[0])
        for s, e in zip(starts, ends):
            obj: DataObject = self.store.get(int(toids[s]))
            klo[s:e] = obj.key_lo[offs[s:e]]
            khi[s:e] = obj.key_hi[offs[s:e]]
        uniq_oids = tuple(int(toids[s]) for s in starts)
        oids = []
        for s in range(0, targets.shape[0], OBJECT_CAPACITY):
            sl = slice(s, s + OBJECT_CAPACITY)
            t = TombstoneObject(
                oid=self.store.new_oid(), nrows=int(targets[sl].shape[0]),
                target=targets[sl], key_lo=klo[sl], key_hi=khi[sl],
                commit_ts=np.full(targets[sl].shape, np.uint64(ts)),
                target_oids=uniq_oids)
            self.store.put(t)
            oids.append(t.oid)
        return oids

    def _commit(self, tx: Txn, *, _log=True) -> int:
        """Commit a (possibly multi-table) transaction at ONE timestamp.

        Two phases make the commit atomic across tables: phase 1 validates
        every table and seals its objects WITHOUT touching any directory;
        phase 2 swings all directories. A conflict or PK violation in any
        table therefore unwinds every object sealed so far and leaves every
        table untouched — the workflow subsystem's atomic publish leans on
        exactly this all-or-nothing property.

        Phase 2 is write-ahead in the strict sense: the FULL commit group
        (one record per table, tagged ``ntab``) is logged before the first
        directory swings. A crash during logging leaves an incomplete
        trailing group that replay drops whole; a crash mid-swing leaves a
        complete group that replay applies whole — either way the
        transaction is all-or-nothing after recovery."""
        with telemetry.span(SP_COMMIT):
            return self._commit_phases(tx, _log)

    def _commit_phases(self, tx: Txn, _log: bool) -> int:
        crash_point(CP_COMMIT_PRE_SEAL)
        names = sorted(set(tx._ins) | set(tx._del))
        ts = self.next_ts()
        oid0 = self.store._next_oid
        staged: List[Tuple[Table, object, list, np.ndarray, int]] = []
        sealed: List[int] = []
        with telemetry.span(SP_COMMIT_SEAL):
            try:
                for name in names:
                    t = self.table(name)
                    # lint: sort-ok delete-target dedup at commit time —
                    # targets arrive from arbitrary staging order
                    dels = (np.unique(np.concatenate(tx._del[name]))
                            if tx._del.get(name)
                            else np.zeros((0,), np.uint64))
                    # write-write conflict: every target must still be
                    # visible
                    if dels.shape[0]:
                        vi = visibility_index(self.store, t.directory)
                        if vi.killed_rowids(dels).any():
                            raise TxnConflict(
                                f"{name}: delete target already deleted")
                        live_oids = set(t.directory.data_oids)
                        # lint: sort-ok per-object liveness check — unique
                        # oids, not rows; a handful of values per commit
                        for oid in np.unique(rowid_oid(dels)):
                            if int(oid) not in live_oids:
                                raise TxnConflict(
                                    f"{name}: target object gone")
                    ins = tx._ins.get(name, [])
                    data_oids, key_sigs = self._seal_inserts(
                        t.schema, ins, tx._sigs.get(name, [None] * len(ins)),
                        ts)
                    sealed.extend(data_oids)
                    # PK enforcement — the seal path returns the key lanes
                    # in sorted order, so in-batch dedup is one
                    # adjacent-equal scan (np.unique(pairs, axis=0) paid a
                    # hidden second sort)
                    if t.schema.has_pk and key_sigs is not None:
                        klo, khi = key_sigs
                        if klo.shape[0] > 1 and ((klo[1:] == klo[:-1])
                                                 & (khi[1:] == khi[:-1])
                                                 ).any():
                            raise PKViolation(
                                f"{name}: duplicate key in insert batch")
                        existing = t.locate_keys(klo, khi)
                        live = existing != 0
                        if live.any():
                            # vectorized membership: every live key must be
                            # among this txn's deletes (update-in-place)
                            if np.isin(existing[live], dels,
                                       invert=True).any():
                                raise PKViolation(
                                    f"{name}: key already exists")
                    tomb_oids = self._seal_tombstones(dels, ts)
                    sealed.extend(tomb_oids)
                    ins_n = (0 if key_sigs is None
                             else int(key_sigs[0].shape[0]))
                    staged.append((t, t.directory.with_objects(
                        data_oids, tomb_oids, ts=ts), ins, dels, ins_n))
            except Exception:
                # an aborted transaction must be INVISIBLE: unwind the
                # sealed objects and roll back the oid counter and the
                # timestamp it consumed — a failed commit is not
                # WAL-logged, so any leaked allocation would
                # desynchronize every later rowid-bearing record at
                # replay time
                self._unwind(sealed)
                self.store._next_oid = oid0
                self.ts = ts - 1
                raise
        crash_point(CP_COMMIT_POST_SEAL)
        if _log:
            for t, directory, ins, dels, ins_n in staged:
                # the record carries its porcelain op kind so replay
                # rebuilds an identical commit log (merges are logged as
                # plain commits — the kind is the only thing lost
                # otherwise) and ntab so replay can tell a torn group
                # tail from a complete one
                self.wal.append("commit", table=t.name, ts=ts,
                                inserts=ins, deletes=dels,
                                op=self._op_kind, ntab=len(staged))
        with telemetry.span(SP_COMMIT_SWING):
            for j, (t, directory, ins, dels, ins_n) in enumerate(staged):
                if j:
                    crash_point(CP_COMMIT_MID_SWING)
                t.set_directory(directory)
                self.commit_log.append(CommitRecord(
                    ts, t.name, self._op_kind, ins_n, int(dels.shape[0])))
        return ts

    def _unwind(self, oids: Sequence[int]) -> None:
        for o in oids:
            self.store.delete(o)

    # --------------------------------------------------------- snapshots
    def _snapshotish(self, ref: SnapshotRef,
                     table: Optional[str] = None) -> Snapshot:
        """Snapshot-position resolution for clone/restore: an EXACT named
        snapshot wins before ref parsing. A pre-grammar tag literally
        named ``step~1`` (old WALs carry such names; replay skips
        validation) must restore THAT tag — parsing it as a RelRef would
        silently restore different data. Everything else takes the one
        resolver."""
        if isinstance(ref, str) and ref in self.snapshots:
            return self.snapshots[ref]
        return resolve_ref(self, ref, table=table).snapshot

    def resolve_snapshot(self, ref: SnapshotRef) -> Snapshot:
        """DEPRECATED shim — kept for old callers; use ``Repo.resolve``.

        Legacy contract preserved exactly for BARE names: the old code was
        a snapshots-only dict lookup, so a bare string resolves in the
        snapshot namespace alone. Dict-first, unconditionally: a
        pre-grammar legacy name may LOOK like a qualified ref form (a
        snapshot literally named "orders~1" predating the grammar) and
        must still return the named tag, never a reinterpretation. A
        string absent from the dict that parses as a bare name (or not at
        all) raises — a ``try/except KeyError`` "does snapshot X exist"
        probe must not start matching tables or branches. Only qualified
        forms (snap:x, table@{ts}, table~n, ...) of NON-legacy names take
        the one resolver."""
        if isinstance(ref, str):
            if ref in self.snapshots:
                return self.snapshots[ref]
            try:
                bare = isinstance(parse_ref(ref), BareRef)
            except RefSyntaxError:
                bare = True          # pre-grammar legacy name
            if bare:
                return require(self.snapshots, ref, "snapshot",
                               f"snap:{ref}")
        return resolve_ref(self, ref).snapshot

    def create_snapshot(self, name: str, table: str, *, _log=True) -> Snapshot:
        """CREATE SNAPSHOT name FOR TABLE table (a git tag)."""
        if _log:
            # user-facing creations only: replay (_log=False) must load
            # any WAL that was ever legally written, including pre-grammar
            # names this validation would now reject
            validate_name(name, "snapshot name")
        if name in self.snapshots:
            raise ValueError(f"snapshot {name} exists")
        t: Table = require(self.tables, table, "table")
        snap = Snapshot(name=name, table=table, schema=t.schema,
                        directory=t.directory, created_ts=self.ts)
        self.snapshots[name] = snap
        if _log:
            self.wal.append("snapshot", name=name, table=table)
        return snap

    def drop_snapshot(self, name: str, *, _log=True) -> None:
        require(self.snapshots, name, "snapshot", f"snap:{name}")
        del self.snapshots[name]
        # drop lineage entries pointing at the dropped snapshot (anonymous
        # bases have name=None and never match a named drop)
        self._base = {k: v for k, v in self._base.items() if v.name != name}
        if _log:
            self.wal.append("drop_snapshot", name=name)

    def snapshot_at(self, table: str, ts: int) -> Snapshot:
        """DEPRECATED shim — use the ``table@{ts}`` / ``ts:N`` ref forms
        through ``Repo.resolve``. T{mo_ts = ts}, a git commit."""
        return resolve_ref(self, AtRef(table, ts)).snapshot

    def current_snapshot(self, table: str) -> Snapshot:
        t = self.table(table)
        return Snapshot(name=None, table=table, schema=t.schema,
                        directory=t.directory, created_ts=self.ts)

    # ------------------------------------------------------ clone/restore
    def clone_table(self, new_name: str, src: SnapshotRef, *,
                    with_indices: bool = False, materialize: bool = False,
                    _log=True) -> Table:
        """CREATE TABLE new FROM SNAPSHOT src — metadata-only copy.

        ``with_indices`` (beyond paper §5.5.4): also clone the auxiliary
        index tables — still metadata-only, and at the *snapshot-consistent*
        aux version (PITR on the aux table's history at the snapshot's
        creation horizon), never at the aux table's current head. An index
        created after the snapshot (or whose history was GC-trimmed past
        the horizon) is instead rebuilt from the cloned data.

        ``materialize=True``: physically rewrite the snapshot's visible
        rows into fresh objects (an independent copy, decoupled from the
        source's GC/compaction lifetime). Rides the zero-rehash apply
        path: the scan carries every signature lane plus per-object sorted
        runs, so the rewrite never hashes a row and never re-sorts a
        single-object snapshot."""
        snap = self._snapshotish(src)
        if new_name in self.tables:
            raise ValueError(f"table {new_name} exists")
        if materialize:
            if with_indices:
                raise ValueError("clone_table: materialize=True does not "
                                 "support with_indices")
            t = self.create_table(new_name, snap.schema, _log=False)
            reader = Table(snap.table, snap.schema, self.store, snap.ts)
            batch, _, sigs = reader.scan_carry(snap.directory)
            if sigs.row_lo.shape[0]:
                tx = self.begin()
                tx.insert(new_name, batch, sigs=sigs)
                with self.op_kind("clone"):
                    tx.commit(_log=False)
            self.set_common_base(new_name, snap.table, snap)
            if _log:
                self.wal.append("clone", new=new_name, snap=snap,
                                with_indices=False, materialize=True)
            return t
        t = Table(new_name, snap.schema, self.store, snap.ts)
        t.directory = snap.directory
        t.history = [(snap.ts, snap.directory)]
        self.tables[new_name] = t
        self.commit_log.append(CommitRecord(self.ts, new_name, "clone", 0, 0))
        self.set_common_base(new_name, snap.table, snap)
        if with_indices:
            from .indices import IndexSpec, backfill_index
            horizon = max(snap.created_ts, snap.directory.ts)
            batch = None  # one rebuild scan shared by every rebuilt index
            for spec in self.indices.get(snap.table, []):
                new_spec = IndexSpec(spec.name, new_name, spec.columns)
                aux_t = self.tables.get(spec.aux_table)
                aux_dir = None
                if aux_t is not None:
                    try:
                        aux_dir = aux_t.directory_at(horizon)
                    except KeyError:
                        pass  # index younger than the snapshot
                if aux_dir is not None:
                    self.clone_table(
                        new_spec.aux_table,
                        Snapshot(name=None, table=spec.aux_table,
                                 schema=aux_t.schema, directory=aux_dir,
                                 created_ts=horizon),
                        _log=False)
                else:
                    batch = backfill_index(self, new_spec, batch)
                self.indices.setdefault(new_name, []).append(new_spec)
        if _log:
            self.wal.append("clone", new=new_name, snap=snap,
                            with_indices=with_indices)
        return t

    def restore_table(self, table: str, src: SnapshotRef, *, _log=True) -> None:
        """RESTORE TABLE table FROM SNAPSHOT src — git reset --hard.

        ``src`` may be any ref form; table-relative refs (ts:N, HEAD, ~n)
        resolve against ``table``."""
        t: Table = require(self.tables, table, "table")
        snap = self._snapshotish(src, table=table)
        if snap.table != table and not t.schema.compatible_with(snap.schema):
            raise ValueError("restore: incompatible schema")
        t.schema = snap.schema  # PITR across schema change (paper §5.5.6)
        t.set_directory(Directory(snap.directory.data_oids,
                                  snap.directory.tomb_oids, snap.ts))
        self.commit_log.append(CommitRecord(self.ts, table, "restore", 0, 0))
        if snap.table != table:
            self.set_common_base(table, snap.table, snap)
        if _log:
            self.wal.append("restore", table=table, snap=snap)

    # ------------------------------------------------------ schema change
    def alter_table_add_column(self, table: str, column, default, *,
                               _log=True) -> None:
        """ALTER TABLE ADD COLUMN (paper §5.5.6): rewrites the table under
        the new schema (row signatures depend on the full row, so a rewrite
        keeps value identity consistent). Old snapshots keep the old schema;
        diff/merge across schema versions is refused (compatible_with),
        matching the paper's advice to alter before cloning.

        Partial signature carry: row signatures genuinely change (they
        cover the new column) and are recomputed, but PK key signatures,
        old-column LOB content signatures, and the per-object key-sorted
        runs are all unaffected by the added column and ride through —
        the rewrite never re-runs blake2b and (for PK tables) never
        re-sorts what the objects already keep sorted."""
        from .schema import Schema
        t = self.table(table)
        batch, _, carried = t.scan_carry()
        n = batch[t.schema.names[0]].shape[0] if t.schema.names else 0
        new_schema = Schema(t.schema.columns + (column,),
                            primary_key=t.schema.primary_key)
        if column.ctype.value == "lob":
            # the sig-carrying insert below skips normalize_batch, so the
            # fill value must be normalized here (str -> bytes, like
            # Schema.normalize_batch would have)
            if isinstance(default, str):
                default = default.encode()
            if not isinstance(default, (bytes, bytearray)):
                raise TypeError(f"LOB column {column.name}: default must "
                                "be bytes/str")
            fill = np.empty((n,), object)
            fill[:] = bytes(default)
        else:
            fill = np.full((n,), default,
                           dtype=new_schema.np_dtype(column.name))
        batch[column.name] = fill
        if t.schema.has_pk:
            sigs = SigBatch(None, None, carried.key_lo, carried.key_hi,
                            carried.lob_sigs, carried.runs)
        else:
            # NoPK keys ARE row signatures — both change with the new
            # column, and so does their sort order
            sigs = SigBatch(None, None, None, None, carried.lob_sigs, None)
        t.schema = new_schema
        t.directory = t.directory.replace(
            drop_data=t.directory.data_oids,
            drop_tombs=t.directory.tomb_oids, ts=t.directory.ts)
        t._history_append(t.directory)
        if n:
            tx = self.begin()
            tx.insert(table, batch, sigs=sigs)
            # the rewrite is a sub-operation of the ONE alter_add_column
            # record: logging it as a plain commit too would replay it
            # twice, desynchronizing oid/ts allocation for every later
            # rowid-bearing record
            with self.op_kind("alter"):
                tx.commit(_log=False)
        if _log:
            self.wal.append("alter_add_column", table=table, column=column,
                            default=default)

    # ------------------------------------------------- workflow porcelain
    # Branch refs, pull requests, atomic publish, Δ-based revert live in
    # core.workspace; these shims are the stable engine-level API (local
    # imports break the engine <-> workspace cycle, same as .indices).

    def create_branch(self, name: str, tables, from_ref: Optional[str] = None,
                      *, _log=True) -> "Branch":
        from .workspace import create_branch
        return create_branch(self, name, tables, from_ref, _log=_log)

    def drop_branch(self, name: str, *, _log=True) -> None:
        from .workspace import drop_branch
        drop_branch(self, name, _log=_log)

    def branch(self, name: str) -> "Branch":
        # lint: legacy-ok Engine.branch IS the engine-level shim —
        # as_branch lacks resolve_branch's synthesized-trunk semantics
        from .workspace import resolve_branch
        return resolve_branch(self, name)  # lint: legacy-ok the shim body

    def list_branches(self) -> list:
        """Registered branches, sorted by name."""
        return sorted(self.branches.values(), key=lambda b: b.name)

    def list_snapshots(self) -> list:
        """Named snapshots as (name, table, created_ts), oldest first."""
        return sorted(((s.name, s.table, s.created_ts)
                       for s in self.snapshots.values()),
                      key=lambda r: (r[2], r[0]))

    def open_pr(self, base: Optional[str], head: str, *,
                _log=True) -> "PullRequest":
        from .workspace import open_pr
        return open_pr(self, base, head, _log=_log)

    def revert(self, table: str, from_ref: SnapshotRef, to_ref: SnapshotRef,
               *, _log=True) -> Optional[int]:
        """Apply the INVERSE of Δ(from_ref -> to_ref) to ``table``'s current
        state as a new commit — history-preserving, Δ-sized (git revert, not
        the head-rewriting restore_table)."""
        from .workspace import revert
        return revert(self, table, from_ref, to_ref, _log=_log)

    # ----------------------------------------------------------- lineage
    def set_common_base(self, a: str, b: str, snap: Snapshot) -> None:
        self._base[tuple(sorted((a, b)))] = snap

    def find_common_base(self, a: str, b: str) -> Optional[Snapshot]:
        return self._base.get(tuple(sorted((a, b))))

    # ------------------------------------------------------------ replay
    @staticmethod
    def replay(wal: WAL, *, into: Optional["Engine"] = None,
               start: int = 0) -> "Engine":
        """Deterministically rebuild an engine from its WAL (crash recovery).

        ``into``/``start`` continue replay on top of an engine restored by
        other means (a refs snapshot, see ``repro.store.remote``): records
        before ``start`` are assumed already absorbed into ``into``'s
        state — including its oid counter — so only the tail re-runs."""
        from .compaction import compact_objects  # local import: cycle
        _sp = telemetry.span(SP_REPLAY)
        _sp.__enter__()
        try:
            e = Engine._replay_loop(wal, compact_objects,
                                    engine=into, start=start)
        finally:
            _sp.__exit__(None, None, None)
        # traces are derived state, never durable state: replay re-ran the
        # commits with live counters, so wipe them — a recovered engine
        # must report a clean registry and zero spans
        e.reset_metrics()
        return e

    @staticmethod
    def _replay_loop(wal: WAL, compact_objects,
                     engine: Optional["Engine"] = None,
                     start: int = 0) -> "Engine":
        e = engine if engine is not None else Engine()
        records = list(wal)
        i = start
        while i < len(records):
            rec = records[i]
            k, p = rec.kind, rec.payload
            i += 1
            if k == "create_table":
                e.create_table(p["name"], p["schema"], _log=False)
            elif k == "drop_table":
                e.drop_table(p["name"], _log=False)
            elif k == "commit":
                # a multi-table transaction emits one commit record per
                # table at ONE shared ts (in sorted-name order, exactly how
                # _commit seals) — regroup the run into one transaction so
                # replay consumes one timestamp and allocates oids in the
                # live order
                group_start = i - 1
                group = [p]
                while (i < len(records) and records[i].kind == "commit"
                        and records[i].payload["ts"] == p["ts"]):
                    group.append(records[i].payload)
                    i += 1
                # _commit logs the whole group BEFORE swinging (ntab
                # records); fewer means the logger died mid-group. At the
                # tail that is a torn transaction — drop it whole (also
                # from the log, so re-serializing the recovered engine
                # does not resurrect half a txn). Mid-log it is damage
                # no crash can produce: refuse.
                want = group[0].get("ntab")
                if want is not None and len(group) < want:
                    if i >= len(records):
                        del records[group_start:]
                        wal.records = records
                        break
                    raise TornTransaction(p["ts"], len(group), want)
                tx = e.begin()
                op = group[0].get("op", "commit")
                for g in group:
                    for b in g["inserts"]:
                        tx._ins.setdefault(g["table"], []).append(b)
                    if g["deletes"].shape[0]:
                        tx.delete_rowids(g["table"], g["deletes"])
                with e.op_kind(op):
                    e._commit(tx, _log=False)
            elif k == "snapshot":
                e.create_snapshot(p["name"], p["table"], _log=False)
            elif k == "drop_snapshot":
                e.drop_snapshot(p["name"], _log=False)
            elif k == "clone":
                snap = p["snap"]
                snap = e.snapshots.get(snap.name, snap) if snap.name else snap
                e.clone_table(p["new"], snap,
                              with_indices=p.get("with_indices", False),
                              materialize=p.get("materialize", False),
                              _log=False)
            elif k == "restore":
                snap = p["snap"]
                snap = e.snapshots.get(snap.name, snap) if snap.name else snap
                e.restore_table(p["table"], snap, _log=False)
            elif k == "set_base":
                e.set_common_base(p["a"], p["b"], p["snap"])
            elif k == "create_index":
                from .indices import create_index
                create_index(e, p["table"], p["name"], p["columns"],
                             _log=False)
            elif k == "drop_index":
                from .indices import drop_index
                drop_index(e, p["table"], p["name"], _log=False)
            elif k == "alter_add_column":
                e.alter_table_add_column(p["table"], p["column"],
                                         p["default"], _log=False)
            elif k == "compact":
                compact_objects(e, p["table"], p["src_oids"], _log=False)
            # workflow porcelain: one record per logical operation; the
            # sub-operations (clones, merge planning, the publish commit)
            # re-derive deterministically from the replayed state
            elif k == "create_branch":
                e.create_branch(p["name"], p["tables"], p.get("from_ref"),
                                _log=False)
            elif k == "drop_branch":
                e.drop_branch(p["name"], _log=False)
            elif k == "open_pr":
                pr = e.open_pr(p["base"], p["head"], _log=False)
                if pr.id != p["pr"]:
                    raise ValueError(
                        f"replay diverged: PR id {pr.id} != {p['pr']}")
            elif k == "close_pr":
                e.prs[p["pr"]].close(_log=False)
            elif k == "publish":
                from .merge import ConflictMode
                e.prs[p["pr"]].publish(mode=ConflictMode(p["mode"]),
                                       _log=False, _skip_checks=True)
            elif k == "publish_revert":
                e.prs[p["pr"]].revert_publish(_log=False)
            elif k == "revert":
                sf, st = p["snap_from"], p["snap_to"]
                sf = e.snapshots.get(sf.name, sf) if sf.name else sf
                st = e.snapshots.get(st.name, st) if st.name else st
                e.revert(p["table"], sf, st, _log=False)
            else:
                raise ValueError(f"unknown WAL record {k}")
        # replay must land on the same timestamp (`or 0`: no-op publish /
        # revert records carry ts=None); scan `records`, not `wal`, so a
        # dropped torn-tail group does not leak its timestamp
        e.ts = max(e.ts, max((r.payload.get("ts") or 0 for r in records),
                             default=0))
        # the recovered engine owns its history: adopt the source WAL
        # (replay ran with _log=False, so e.wal is empty otherwise) so it
        # can re-serialize and so fsck's replay check closes the loop
        e.wal = wal
        return e

    # ------------------------------------------------------- GC (mark-sweep)
    def _pinned_snapshots(self) -> List[Snapshot]:
        """Snapshots that must survive GC beyond the named ones: lineage
        bases, branch points, and the horizons held by live pull requests
        (open PRs pin their base-at-open; published-but-not-closed PRs pin
        their pre/post publish states so revert_publish stays possible)."""
        pins = list(self._base.values())
        for br in self.branches.values():
            pins.extend(br.base.values())
        for pr in self.prs.values():
            if pr.status == "open":
                pins.extend(pr.base_pins.values())
            elif pr.status == "published":
                pins.extend(pr.pre_publish.values())
                pins.extend(pr.post_publish.values())
        return pins

    def gc(self) -> "GCStats":
        """Mark-sweep GC: drop objects unreachable from current tables,
        retained PITR history, named snapshots, and pinned horizons.

        History is trimmed to ``retention_versions`` per table, but every
        entry still backing a pinned horizon (open PR base, ``_base``
        lineage snapshot, branch point) survives the trim — a pin guarantees
        ``directory_at`` keeps resolving at that horizon."""
        with telemetry.span(SP_GC):
            st = self._gc_sweep()
            m = self.store.metrics
            m.add("gc.objects_freed", st.objects_freed)
            m.add("gc.versions_pruned", st.versions_pruned)
            # a gauge, not a running sum: "pinned at the LAST sweep"
            m.counters["gc.pinned_horizons"] = st.pinned_horizons
            return st

    def _gc_sweep(self) -> "GCStats":
        pins = self._pinned_snapshots()
        pin_ts: Dict[str, set] = {}
        for s in list(self.snapshots.values()) + pins:
            if s.table in self.tables:
                pin_ts.setdefault(s.table, set()).add(
                    max(s.created_ts, s.directory.ts))
        marked = set()
        pruned = 0
        for name, t in self.tables.items():
            pruned += t.trim_history(self.retention_versions,
                                     pin_ts.get(name, ()))
            for _, d in t.history:
                marked.update(d.data_oids)
                marked.update(d.tomb_oids)
            marked.update(t.directory.data_oids)
            marked.update(t.directory.tomb_oids)
        for s in list(self.snapshots.values()) + pins:
            marked.update(s.directory.data_oids)
            marked.update(s.directory.tomb_oids)
        dead = [o for o in list(self.store.oids()) if o not in marked]
        for o in dead:
            # GC is not WAL-logged: dying between deletions only leaves
            # extra garbage for the next sweep, never a logical change
            crash_point(CP_GC_MID_SWEEP)
            self.store.delete(o)
        return GCStats(objects_freed=len(dead), versions_pruned=pruned,
                       pinned_horizons=sum(len(v) for v in pin_ts.values()))


@dataclass
class CommitRecord:
    """One commit-log entry: what one applied operation did to one table.

    ``kind`` is the porcelain op that drove the commit ("commit" for plain
    DML; merge/publish/revert/revert-publish/clone/alter/restore/create
    for porcelain) — set via ``Engine.op_kind`` on the SAME code paths WAL
    replay re-executes, so replayed engines carry identical logs."""
    ts: int
    table: str
    kind: str
    inserted: int
    deleted: int


@dataclass
class GCStats:
    """What one GC pass did (and deliberately did not) collect."""
    objects_freed: int = 0
    versions_pruned: int = 0
    pinned_horizons: int = 0


@dataclass
class CommitStats:
    """Where seal-time work went, cumulative per engine.

    The zero-rehash invariant (ISSUE 4): applying rows gathered from sealed
    objects — merge, revert, publish, materialized clones — must never pay
    ``rows_rehashed`` or ``lob_rows_hashed``; their signatures ride along in
    ``SigBatch`` sidecars and the sort is skipped (one declared run) or a
    k-way run merge. Tests pin the invariant on these counters."""
    rows_rehashed: int = 0       # rows that ran the rowhash kernel at seal
    rows_carried: int = 0        # rows sealed on carried write-once sigs
    lob_rows_hashed: int = 0     # per-LOB-column rows that paid blake2b
    apply_sorts: int = 0         # seals that paid the global key lexsort
    apply_sort_merged: int = 0   # seals that k-way merged declared runs
    apply_sort_skipped: int = 0  # seals of declared-key-sorted batches
