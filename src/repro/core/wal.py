"""Write-ahead log (the LogService/TN roles of paper §4, single-node form).

Every logical state change appends a record; ``Engine.replay`` re-executes
the log against a fresh engine and must reproduce identical logical table
contents (tests assert this). Object ids are allocated deterministically, so
replay also reproduces physical layout.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class WalRecord:
    kind: str                 # create_table | commit | snapshot | drop_snapshot
    #                         | clone | restore | compact | set_base | drop_table
    payload: Dict[str, Any] = field(default_factory=dict)


class WAL:
    def __init__(self):
        self.records: List[WalRecord] = []

    def append(self, kind: str, **payload) -> None:
        self.records.append(WalRecord(kind, payload))

    def __iter__(self):
        return iter(self.records)

    def __len__(self):
        return len(self.records)

    # Durability stand-in: the paper's Raft LogService persists records; we
    # support byte-serialization round-trips for crash-recovery tests.
    def serialize(self) -> bytes:
        return pickle.dumps(self.records, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def deserialize(blob: bytes) -> "WAL":
        w = WAL()
        w.records = pickle.loads(blob)
        return w
