"""Write-ahead log (the LogService/TN roles of paper §4, single-node form).

Every logical state change appends a record; ``Engine.replay`` re-executes
the log against a fresh engine and must reproduce identical logical table
contents (tests assert this). Object ids are allocated deterministically, so
replay also reproduces physical layout.

Durable format (ISSUE 6). Serialized WALs and the CLI's append-only store
share one framed byte format instead of raw pickle streams::

    header   := MAGIC "DGWS" | version u8 | reserved u8*3      (8 bytes)
    frame    := length u32le | crc32c(payload) u32le | payload
    payload  := pickle of a list[WalRecord]

so a flipped bit raises :class:`CorruptFrame` naming the frame, a
crash-torn tail raises :class:`TornFrame` carrying the last clean offset
(recoverable — the bytes were never acknowledged), and a store written by
a different format version raises :class:`StoreVersionError` with an
upgrade hint — never pickle garbage, never a silent wrong answer.
Headerless legacy stores (pre-ISSUE 6 raw pickle) still load via a
one-shot legacy path keyed off the pickle protocol-2 opcode.
"""
from __future__ import annotations

import pickle
import struct
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Tuple

from .faults import crash_point, register

# Every record kind the engine may emit and ``Engine.replay`` understands.
# The workflow porcelain (ISSUE 3) logs ONE record per logical operation —
# its sub-operations (clones, merges, the publish commit) are unlogged and
# re-derived deterministically at replay time.
KINDS = frozenset({
    # storage / transaction layer
    "create_table", "drop_table", "commit", "snapshot", "drop_snapshot",
    "clone", "restore", "set_base", "create_index", "drop_index",
    "alter_add_column", "compact",
    # workflow porcelain: branches, pull requests, atomic publish, Δ-revert
    "create_branch", "drop_branch", "open_pr", "close_pr", "publish",
    "publish_revert", "revert",
})

CP_WAL_APPEND = register(
    "wal.append",
    "before a record is appended to the in-memory WAL — the Nth hit kills "
    "the process at the Nth record boundary, so a sweep over N covers "
    "every boundary of a history")


@dataclass
class WalRecord:
    kind: str                 # one of KINDS
    payload: Dict[str, Any] = field(default_factory=dict)


# --------------------------------------------------------------------------
# durable frame format
# --------------------------------------------------------------------------

MAGIC = b"DGWS"
STORE_VERSION = 1
STORE_HEADER = MAGIC + bytes([STORE_VERSION]) + b"\x00\x00\x00"
_FRAME_HEAD = struct.Struct("<II")        # payload length, crc32c(payload)
FRAME_OVERHEAD = _FRAME_HEAD.size


class StoreFormatError(Exception):
    """Base of the typed durable-format errors."""


class TornFrame(StoreFormatError):
    """A frame extends past end-of-file: the torn tail of a crashed append.

    Recoverable by construction — appends are fsynced frame-at-a-time, so
    bytes past ``clean_end`` were never acknowledged to any caller.
    ``tail`` holds them so recovery can preserve, never silently drop."""

    def __init__(self, clean_end: int, tail: bytes):
        super().__init__(
            f"torn frame: {len(tail)} trailing byte(s) past the last clean "
            f"frame at offset {clean_end} (unacknowledged crashed write)")
        self.clean_end = clean_end
        self.tail = tail


class CorruptFrame(StoreFormatError):
    """A fully-present frame failed its CRC: mid-file storage corruption.

    NOT auto-recoverable (the frame was acknowledged once): the caller
    decides — ``datagit fsck --repair`` quarantines, a plain load refuses."""

    def __init__(self, frame_index: int, offset: int, why: str):
        super().__init__(
            f"corrupt frame #{frame_index} at offset {offset}: {why}")
        self.frame_index = frame_index
        self.offset = offset


class TornTransaction(StoreFormatError):
    """A multi-table commit group is incomplete in the MIDDLE of the log.

    A trailing incomplete group is normal crash recovery (the txn never
    fully logged; replay drops it whole). Records *after* an incomplete
    group mean the log itself is damaged — replay refuses to guess."""

    def __init__(self, ts: int, have: int, want: int):
        super().__init__(
            f"commit group at ts={ts} has {have} of {want} table records "
            "with later records following — WAL is damaged mid-log")
        self.ts = ts


class StoreVersionError(StoreFormatError):
    """The store's magic/version does not match this build's format."""

    def __init__(self, why: str):
        super().__init__(
            f"{why} — this build reads DGWS v{STORE_VERSION} stores and "
            "legacy headerless pickle stores; re-create the store with "
            "this build (or load it with the build that wrote it)")


#: cumulative bytes hashed by the pure-python fallback, and the threshold
#: past which a one-shot warning fires (ISSUE 10 satellite): the table
#: loop is ~100x slower than google-crc32c, which matters once pack spill
#: starts hashing whole row groups. Module globals so tests can shrink
#: the threshold instead of hashing 64MB.
_py_crc32c_bytes = 0
_PY_CRC32C_WARN_BYTES = 64 << 20
_py_crc32c_warned = False


def _note_py_crc32c(nbytes: int) -> None:
    """Account fallback-hashed bytes; warn once past the threshold.

    Defined unconditionally (not just in the fallback branch) so the
    warn-once contract stays testable on hosts with google-crc32c."""
    global _py_crc32c_bytes, _py_crc32c_warned
    _py_crc32c_bytes += nbytes
    if (not _py_crc32c_warned
            and _py_crc32c_bytes > _PY_CRC32C_WARN_BYTES):
        _py_crc32c_warned = True
        import sys
        print(f"datagit: warning: hashed "
              f"{_py_crc32c_bytes / (1 << 20):.0f}MB with the "
              "pure-python crc32c fallback; install google-crc32c "
              "for ~100x faster integrity checks", file=sys.stderr)

try:                                       # C implementation when present
    from google_crc32c import value as _crc32c_impl

    CRC32C_IMPL = "google-crc32c"

    def crc32c(data: bytes) -> int:
        return _crc32c_impl(data)
except ImportError:                        # pure-python fallback (CI has
    _CRC32C_TABLE: List[int] = []          # only numpy/jax/pytest)

    CRC32C_IMPL = "pure-python"

    def _crc32c_build_table() -> None:
        poly = 0x82F63B78                  # Castagnoli, reflected
        for i in range(256):
            c = i
            for _ in range(8):
                c = (c >> 1) ^ poly if c & 1 else c >> 1
            _CRC32C_TABLE.append(c)

    def crc32c(data: bytes) -> int:
        if not _CRC32C_TABLE:
            _crc32c_build_table()
        _note_py_crc32c(len(data))
        tab = _CRC32C_TABLE
        c = 0xFFFFFFFF
        for b in data:
            c = tab[(c ^ b) & 0xFF] ^ (c >> 8)
        return c ^ 0xFFFFFFFF


def encode_frame(payload: bytes) -> bytes:
    """One durable frame: length + crc32c + payload."""
    return _FRAME_HEAD.pack(len(payload), crc32c(payload)) + payload


def check_store_header(blob: bytes) -> int:
    """Validate the store header; returns the offset where frames begin.

    Returns ``-1`` for a recognized LEGACY headerless pickle store (the
    pre-ISSUE 6 format — pickle protocol 2+ opcode ``\\x80``); raises
    :class:`StoreVersionError` for anything else."""
    if blob.startswith(MAGIC):
        version = blob[4]
        if version != STORE_VERSION:
            raise StoreVersionError(
                f"store format version {version} is not supported")
        if len(blob) < len(STORE_HEADER):
            raise StoreVersionError("store header truncated")
        return len(STORE_HEADER)
    if blob[:1] == b"\x80":
        return -1
    raise StoreVersionError(
        f"bad magic {blob[:4]!r}: not a datagit WAL store")


def iter_frames(blob: bytes, offset: int) -> Iterator[Tuple[bytes, int]]:
    """Yield ``(payload, end_offset)`` per frame, verifying each CRC.

    Raises :class:`TornFrame` when the trailing frame extends past EOF
    (including a torn length/crc prefix) and :class:`CorruptFrame` on a
    CRC mismatch. A corrupted length field either lands inside the file
    (the CRC then fails -> CorruptFrame) or past it (-> TornFrame); there
    is no silent resync."""
    size = len(blob)
    idx = 0
    while offset < size:
        if size - offset < FRAME_OVERHEAD:
            raise TornFrame(offset, bytes(blob[offset:]))
        length, crc = _FRAME_HEAD.unpack_from(blob, offset)
        end = offset + FRAME_OVERHEAD + length
        if end > size:
            raise TornFrame(offset, bytes(blob[offset:]))
        payload = blob[offset + FRAME_OVERHEAD:end]
        if crc32c(payload) != crc:
            raise CorruptFrame(
                idx, offset,
                f"crc mismatch over {length} payload byte(s)")
        yield payload, end
        offset = end
        idx += 1


class WAL:
    def __init__(self):
        self.records: List[WalRecord] = []
        # telemetry counters (wal.frames / wal.bytes / wal.fsyncs) — live
        # process state only, never pickled: ``serialize`` ships just the
        # records, and ``deserialize`` fills ``records`` directly, so a
        # replayed engine always starts these at zero
        self.frames = 0
        self.bytes_written = 0
        self.fsyncs = 0

    def append(self, kind: str, **payload) -> None:
        # hard error, not assert: a typo'd kind persisted here would only
        # explode at replay time, after the log is already corrupt
        if kind not in KINDS:
            raise ValueError(f"unknown WAL record kind {kind!r}")
        # the crash fires BEFORE the record exists: a record is either
        # fully appended or never was — there is no half-appended record
        crash_point(CP_WAL_APPEND)
        self.records.append(WalRecord(kind, payload))
        self.frames += 1

    def __iter__(self):
        return iter(self.records)

    def __len__(self):
        return len(self.records)

    # Durability stand-in: the paper's Raft LogService persists records; we
    # support byte-serialization round-trips for crash-recovery tests.
    def serialize(self) -> bytes:
        payload = pickle.dumps(self.records,
                               protocol=pickle.HIGHEST_PROTOCOL)
        return STORE_HEADER + encode_frame(payload)

    @staticmethod
    def deserialize(blob: bytes) -> "WAL":
        w = WAL()
        start = check_store_header(blob)
        if start < 0:                       # legacy headerless pickle blob
            w.records = pickle.loads(blob)
            return w
        for payload, _ in iter_frames(blob, start):
            w.records.extend(pickle.loads(payload))
        return w
