"""Write-ahead log (the LogService/TN roles of paper §4, single-node form).

Every logical state change appends a record; ``Engine.replay`` re-executes
the log against a fresh engine and must reproduce identical logical table
contents (tests assert this). Object ids are allocated deterministically, so
replay also reproduces physical layout.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# Every record kind the engine may emit and ``Engine.replay`` understands.
# The workflow porcelain (ISSUE 3) logs ONE record per logical operation —
# its sub-operations (clones, merges, the publish commit) are unlogged and
# re-derived deterministically at replay time.
KINDS = frozenset({
    # storage / transaction layer
    "create_table", "drop_table", "commit", "snapshot", "drop_snapshot",
    "clone", "restore", "set_base", "create_index", "drop_index",
    "alter_add_column", "compact",
    # workflow porcelain: branches, pull requests, atomic publish, Δ-revert
    "create_branch", "drop_branch", "open_pr", "close_pr", "publish",
    "publish_revert", "revert",
})


@dataclass
class WalRecord:
    kind: str                 # one of KINDS
    payload: Dict[str, Any] = field(default_factory=dict)


class WAL:
    def __init__(self):
        self.records: List[WalRecord] = []

    def append(self, kind: str, **payload) -> None:
        # hard error, not assert: a typo'd kind persisted here would only
        # explode at replay time, after the log is already corrupt
        if kind not in KINDS:
            raise ValueError(f"unknown WAL record kind {kind!r}")
        self.records.append(WalRecord(kind, payload))

    def __iter__(self):
        return iter(self.records)

    def __len__(self):
        return len(self.records)

    # Durability stand-in: the paper's Raft LogService persists records; we
    # support byte-serialization round-trips for crash-recovery tests.
    def serialize(self) -> bytes:
        return pickle.dumps(self.records, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def deserialize(blob: bytes) -> "WAL":
        w = WAL()
        w.records = pickle.loads(blob)
        return w
