"""Table schemas and row batches.

A row batch is a ``dict[str, np.ndarray]`` keyed by column name. Numeric
columns are numpy arrays of the column dtype; LOB columns (TEXT/JSON/BLOB of
the paper §5.5.5) are object arrays of ``bytes``.

Diff/merge require *schema compatibility* (paper §3): same column names,
types and order, and the same primary-key definition.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


class CType(enum.Enum):
    I64 = "i64"
    I32 = "i32"
    F64 = "f64"
    F32 = "f32"
    BOOL = "bool"
    LOB = "lob"  # TEXT / JSON / BLOB — stored in-table, diffed by signature


_NP_DTYPES = {
    CType.I64: np.int64,
    CType.I32: np.int32,
    CType.F64: np.float64,
    CType.F32: np.float32,
    CType.BOOL: np.bool_,
}

_PK_TYPES = (CType.I64, CType.I32)


@dataclass(frozen=True)
class Column:
    name: str
    ctype: CType


@dataclass(frozen=True)
class Schema:
    columns: Tuple[Column, ...]
    primary_key: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names: {names}")
        if self.primary_key:
            by_name = {c.name: c for c in self.columns}
            for k in self.primary_key:
                if k not in by_name:
                    raise ValueError(f"primary key column {k!r} not in schema")
                if by_name[k].ctype not in _PK_TYPES:
                    raise ValueError(
                        f"primary key column {k!r} must be integer-typed")

    # -- helpers ---------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def has_pk(self) -> bool:
        return bool(self.primary_key)

    def column(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def np_dtype(self, name: str):
        ct = self.column(name).ctype
        return np.object_ if ct is CType.LOB else _NP_DTYPES[ct]

    def compatible_with(self, other: "Schema") -> bool:
        """Diff/merge compatibility (paper §3)."""
        return (self.names == other.names
                and tuple(c.ctype for c in self.columns)
                == tuple(c.ctype for c in other.columns)
                and self.primary_key == other.primary_key)

    # -- batch utilities --------------------------------------------------
    def validate_batch(self, batch: Dict[str, np.ndarray]) -> int:
        if set(batch.keys()) != set(self.names):
            raise ValueError(
                f"batch columns {sorted(batch)} != schema {sorted(self.names)}")
        n = -1
        for c in self.columns:
            arr = np.asarray(batch[c.name])
            if n < 0:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError("ragged batch")
        return n

    def normalize_batch(self, batch: Dict[str, Sequence]) -> Dict[str, np.ndarray]:
        out = {}
        for c in self.columns:
            if c.ctype is CType.LOB:
                vals = batch[c.name]
                arr = np.empty((len(vals),), dtype=object)
                for i, v in enumerate(vals):
                    if isinstance(v, str):
                        v = v.encode()
                    if not isinstance(v, (bytes, bytearray)):
                        raise TypeError(f"LOB column {c.name}: want bytes/str")
                    arr[i] = bytes(v)
                out[c.name] = arr
            else:
                out[c.name] = np.asarray(batch[c.name], dtype=_NP_DTYPES[c.ctype])
        self.validate_batch(out)
        return out


def batch_nbytes(schema: Schema, batch: Dict[str, np.ndarray]) -> int:
    """Logical payload bytes of a batch (for the paper's Table-1 space cost)."""
    total = 0
    for c in schema.columns:
        arr = batch[c.name]
        if c.ctype is CType.LOB:
            # map(len, list) beats a generator by ~2x at 100k+ rows —
            # this runs once per sealed object on the commit path
            total += int(sum(map(len, arr.tolist())))
        else:
            total += int(arr.nbytes)
    return total


def concat_batches(schema: Schema, batches: Sequence[Dict[str, np.ndarray]]):
    if not batches:
        return {c.name: np.zeros((0,), dtype=schema.np_dtype(c.name))
                for c in schema.columns}
    return {c.name: np.concatenate([b[c.name] for b in batches])
            for c in schema.columns}


def take_batch(batch: Dict[str, np.ndarray], idx: np.ndarray):
    return {k: v[idx] for k, v in batch.items()}
