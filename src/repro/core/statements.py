"""Paper-style VCS statements (ISSUE 5): the SQL-flavored front-end.

The paper's user surface is *statements* — ``CREATE SNAPSHOT``, ``CLONE
TABLE ... {SNAPSHOT = ...}``, diff/merge/publish as SQL against named
versions. This module parses that surface and dispatches to the ``Repo``
facade, so a statement-driven session takes EXACTLY the code paths (and
writes the identical WAL) of the equivalent Python calls — the golden
parity test pins that byte-for-byte.

Supported statements (keywords case-insensitive; refs quoted or bare)::

    CREATE BRANCH dev [FROM main] [FOR (orders, lineitem)]
    DROP BRANCH dev
    CREATE SNAPSHOT nightly FOR TABLE orders
    DROP SNAPSHOT nightly
    CLONE TABLE orders2 FROM 'snap:nightly' [MATERIALIZE]
    DIFF TABLE orders AGAINST 'snap:nightly'
    DIFF 'orders~2' AGAINST 'HEAD' [FOR TABLE orders]
    MERGE BRANCH dev INTO main [MODE ours] [FOR (orders)]
    MERGE 'snap:nightly' INTO TABLE orders [MODE theirs]
    OPEN PR FROM dev [INTO main]
    CHECK PR 3
    PUBLISH PR 3 [MODE accept]
    CLOSE PR 3
    REVERT PR 3
    REVERT TABLE orders FROM 'orders~1' TO 'HEAD'
    RESTORE TABLE orders TO 'snap:nightly'
    LOG TABLE orders [LIMIT 10]
    SHOW BRANCHES | SNAPSHOTS | PRS | TABLES
    STATUS
    STATS
    EXPLAIN <any statement above>
    GC
    FSCK [REPAIR]
    LINT
    PUSH TO '/path/to/remote'
    PULL FROM '/path/to/remote'
    FETCH FROM '/path/to/remote'

``execute(repo, text)`` runs one statement; ``execute_script`` splits on
``;``. Unknown verbs raise :class:`StatementError` with did-you-mean
suggestions, resolution failures surface the typed ref errors unchanged.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, List, Optional

from . import telemetry
from .refs import did_you_mean, suggest

SP_EXPLAIN = telemetry.register_span(
    "explain", "EXPLAIN wrapper when a tracer is already armed — the "
    "explained statement's spans nest under it")

_TOKEN_RE = re.compile(r"\s*(?:'(?P<str>[^']*)'|(?P<punct>[(),])"
                       r"|(?P<word>[^\s(),;']+))")

class StatementError(ValueError):
    """The statement text does not parse."""

    def __init__(self, text: str, why: str, suggestions=()):
        super().__init__(f"cannot parse {text!r}: {why}"
                         f"{did_you_mean(suggestions)}")
        self.statement = text
        self.suggestions = tuple(suggestions)


@dataclass
class StatementResult:
    """What one statement did: machine data + a human line for the CLI."""
    kind: str                      # e.g. "create_branch", "diff", "publish"
    data: Any = None
    message: str = ""

    def __str__(self) -> str:      # CLI prints results directly
        return self.message


# --------------------------------------------------------------------------
# tokenizer / parser scaffolding
# --------------------------------------------------------------------------

class _P:
    def __init__(self, text: str):
        self.text = text
        self.toks: List[tuple] = []
        pos = 0
        while pos < len(text):
            m = _TOKEN_RE.match(text, pos)
            if not m or m.end() == pos:
                rest = text[pos:].strip()
                if not rest:
                    break
                raise StatementError(text, f"bad token at {rest[:20]!r}")
            pos = m.end()
            if m.group("str") is not None:
                self.toks.append(("str", m.group("str")))
            elif m.group("punct") is not None:
                self.toks.append(("p", m.group("punct")))
            elif m.group("word") is not None:
                self.toks.append(("w", m.group("word")))
        self.i = 0

    def done(self) -> bool:
        return self.i >= len(self.toks)

    def peek_word(self) -> Optional[str]:
        if self.done():
            return None
        t, v = self.toks[self.i]
        return v.upper() if t == "w" else None

    def take(self) -> tuple:
        if self.done():
            raise StatementError(self.text, "unexpected end of statement")
        tok = self.toks[self.i]
        self.i += 1
        return tok

    def kw(self, *expected: str) -> str:
        t, v = self.take()
        if t != "w" or v.upper() not in expected:
            raise StatementError(
                self.text, f"expected {'/'.join(expected)}, got {v!r}",
                suggest(str(v).upper(), expected))
        return v.upper()

    def opt_kw(self, *words: str) -> Optional[str]:
        if self.peek_word() in words:
            return self.kw(*words)
        return None

    def ident(self, what: str = "name") -> str:
        t, v = self.take()
        if t == "p":
            raise StatementError(self.text, f"expected {what}, got {v!r}")
        return v

    def ref(self) -> str:
        """A ref: quoted string or one bare token."""
        return self.ident("ref")

    def int_(self, what: str = "integer") -> int:
        v = self.ident(what)
        if not v.isdigit():
            raise StatementError(self.text, f"expected {what}, got {v!r}")
        return int(v)

    def name_list(self) -> List[str]:
        """(a, b, c) or a single bare name."""
        if not self.done() and self.toks[self.i] == ("p", "("):
            self.take()
            names = []
            while True:
                t, v = self.take()
                if (t, v) == ("p", ")"):
                    break
                if (t, v) == ("p", ","):
                    continue
                names.append(v)
            return names
        return [self.ident("table name")]

    def end(self) -> None:
        if not self.done():
            _, v = self.toks[self.i]
            raise StatementError(self.text, f"trailing input at {v!r}")


# --------------------------------------------------------------------------
# result rendering
# --------------------------------------------------------------------------

def _fmt_diff(d) -> str:
    plus = int((d.diff_cnt > 0).sum())
    minus = int((d.diff_cnt < 0).sum())
    return (f"{d.n_groups} changed group(s): +{plus}/-{minus} "
            f"(rows scanned {d.stats.rows_scanned:,})")


def _fmt_report(rep) -> str:
    return (f"+{rep.inserted}/-{rep.deleted}"
            + (f", {rep.true_conflicts} conflict(s)"
               if rep.true_conflicts else "")
            + (f" at ts={rep.commit_ts}" if rep.commit_ts else " (no-op)"))


def _fmt_reports(reports: dict) -> str:
    return "; ".join(f"{lg}: {_fmt_report(r)}"
                     for lg, r in sorted(reports.items()))


def _fmt_checks(checks: list) -> str:
    if not checks:
        # user checks are in-process callables (Repo.pr(n).add_check) and
        # do not survive a WAL round-trip — say so, or a fresh process
        # reads "clean" as "all checks passed"
        return ("0 user checks registered (checks are in-process "
                "callables: pr.add_check); merge preview clean")
    bad = [c for c in checks if not c.ok]
    if not bad:
        return f"{len(checks)} check(s) passed"
    return (f"{len(bad)}/{len(checks)} check(s) FAILED: "
            + "; ".join(f"{c.name}: {c.error}" for c in bad))


def _fmt_log(entries: list) -> str:
    if not entries:
        return "(empty history)"
    return "\n".join(f"ts={r.ts:<6} {r.kind:<15} +{r.inserted}/-{r.deleted}"
                     for r in entries)


# one row formatter + label per status section, shared by STATUS and SHOW
_SECTIONS = {
    "tables": ("table", lambda r: f"{r[0]}  head_ts={r[1]} "
                                  f"versions={r[2]}"),
    "branches": ("branch", lambda r: f"{r[0]}  created_ts={r[1]} "
                                     f"tables={','.join(r[2])}"),
    "snapshots": ("snapshot", lambda r: f"{r[0]}  table={r[1]} "
                                        f"created_ts={r[2]}"),
    "prs": ("pr", lambda r: f"#{r[0]}  {r[2]} -> {r[1]}  [{r[3]}]"),
}


def _fmt_status(st: dict) -> str:
    lines = [f"ts={st['ts']}"]
    for section, (label, fmt) in _SECTIONS.items():
        lines += [f"{label} {fmt(r)}" for r in st[section]]
    if "crc32c" in st:
        lines.append(f"crc32c={st['crc32c']}")
    tier = st.get("store")
    if tier is not None:
        lines.append(f"store resident={tier['resident']} "
                     f"packed={tier['packed']} "
                     f"packs={tier['packs'] or '(heap only)'}")
    # the full registry snapshot, zeros included: `datagit status` is how
    # an operator checks the zero-rehash invariant without a debugger
    for k, v in sorted(st.get("metrics", {}).items()):
        lines.append(f"metric {k}={v}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# statement handlers
# --------------------------------------------------------------------------

def _create(repo, p: _P) -> StatementResult:
    what = p.kw("BRANCH", "SNAPSHOT")
    name = p.ident()
    if what == "BRANCH":
        from_ref = p.ref() if p.opt_kw("FROM") else None
        tables = p.name_list() if p.opt_kw("FOR") else None
        p.end()
        br = repo.branch(name, tables, from_ref)
        return StatementResult(
            "create_branch", br,
            f"branch {br.name} created for ({', '.join(sorted(br.tables))})"
            f" from {from_ref or 'main'}")
    p.kw("FOR")
    p.opt_kw("TABLE")
    table_ref = p.ref()
    p.end()
    snap = repo.tag(name, table_ref)
    return StatementResult(
        "create_snapshot", snap,
        f"snapshot {name} created for table {snap.table} "
        f"at ts={snap.created_ts}")


def _drop(repo, p: _P) -> StatementResult:
    what = p.kw("BRANCH", "SNAPSHOT", "TABLE")
    name = p.ident()
    p.end()
    if what == "BRANCH":
        repo.drop_branch(name)
    elif what == "SNAPSHOT":
        repo.drop_tag(name)
    else:
        repo.drop_table(name)
    return StatementResult(f"drop_{what.lower()}", name,
                           f"{what.lower()} {name} dropped")


def _clone(repo, p: _P) -> StatementResult:
    p.kw("TABLE")
    new = p.ident()
    p.kw("FROM")
    ref = p.ref()
    materialize = p.opt_kw("MATERIALIZE") is not None
    p.end()
    repo.clone(new, ref, materialize=materialize)
    return StatementResult(
        "clone", new,
        f"table {new} cloned from {ref}"
        + (" (materialized)" if materialize else " (metadata-only)"))


def _diff(repo, p: _P) -> StatementResult:
    if p.opt_kw("TABLE"):
        table = p.ident("table name")
        p.kw("AGAINST")
        ref = p.ref()
        p.end()
        d = repo.diff(ref, "HEAD", table=table)
        return StatementResult(
            "diff", d, f"diff {ref} -> {table}@HEAD: {_fmt_diff(d)}")
    a = p.ref()
    p.kw("AGAINST")
    b = p.ref()
    table = None
    if p.opt_kw("FOR"):
        p.opt_kw("TABLE")
        table = p.ident("table name")
    p.end()
    d = repo.diff(a, b, table=table)
    return StatementResult("diff", d, f"diff {a} -> {b}: {_fmt_diff(d)}")


def _merge(repo, p: _P) -> StatementResult:
    if p.opt_kw("BRANCH"):
        head = p.ident("branch name")
        p.kw("INTO")
        base = p.ident("branch name")
        mode = p.ident("mode") if p.opt_kw("MODE") else None
        tables = p.name_list() if p.opt_kw("FOR") else None
        p.end()
        reports = repo.merge(f"branch:{head}", f"branch:{base}",
                             mode=mode, tables=tables)
        return StatementResult(
            "merge", reports,
            f"merged branch {head} into {base}: {_fmt_reports(reports)}")
    src = p.ref()
    p.kw("INTO")
    p.opt_kw("TABLE")
    target = p.ident("table name")
    mode = p.ident("mode") if p.opt_kw("MODE") else None
    p.end()
    rep = repo.merge(src, target, mode=mode)
    return StatementResult(
        "merge", rep, f"merged {src} into {target}: {_fmt_report(rep)}")


def _open(repo, p: _P) -> StatementResult:
    p.kw("PR")
    p.kw("FROM")
    head = p.ident("branch name")
    base = p.ident("branch name") if p.opt_kw("INTO") else None
    p.end()
    pr = repo.open_pr(head, base)
    return StatementResult(
        "open_pr", pr,
        f"PR #{pr.id} opened: {pr.head_name} -> {pr.base_name}")


def _pr_id(p: _P) -> int:
    p.kw("PR")
    return p.int_("PR id")


def _check(repo, p: _P) -> StatementResult:
    n = _pr_id(p)
    p.end()
    checks = repo.check(n)
    return StatementResult("check_pr", checks,
                           f"PR #{n}: {_fmt_checks(checks)}")


def _publish(repo, p: _P) -> StatementResult:
    n = _pr_id(p)
    mode = p.ident("mode") if p.opt_kw("MODE") else None
    p.end()
    reports = repo.publish(n, mode=mode)
    pr = repo.pr(n)
    when = (f"at ts={pr.publish_ts}" if pr.publish_ts is not None
            else "(no changes, no commit)")
    return StatementResult(
        "publish", reports,
        f"PR #{n} published {when}: {_fmt_reports(reports)}")


def _close(repo, p: _P) -> StatementResult:
    n = _pr_id(p)
    p.end()
    repo.close_pr(n)
    return StatementResult("close_pr", n, f"PR #{n} closed")


def _revert(repo, p: _P) -> StatementResult:
    if p.peek_word() == "PR":
        n = _pr_id(p)
        p.end()
        ts = repo.revert_pr(n)
        return StatementResult(
            "revert_pr", ts,
            f"PR #{n} publish reverted"
            + (f" at ts={ts}" if ts else " (no-op)"))
    p.kw("TABLE")
    table = p.ident("table name")
    p.kw("FROM")
    a = p.ref()
    p.kw("TO")
    b = p.ref()
    p.end()
    ts = repo.revert(table, a, b)
    return StatementResult(
        "revert", ts,
        f"table {table}: inverse Δ({a} -> {b}) applied"
        + (f" at ts={ts}" if ts else " (empty Δ, no-op)"))


def _restore(repo, p: _P) -> StatementResult:
    p.kw("TABLE")
    table = p.ident("table name")
    p.kw("TO", "FROM")
    ref = p.ref()
    p.end()
    repo.restore(table, ref)
    return StatementResult("restore", table,
                           f"table {table} restored to {ref}")


def _log(repo, p: _P) -> StatementResult:
    p.opt_kw("TABLE")
    table = p.ref()
    limit = p.int_("limit") if p.opt_kw("LIMIT") else None
    p.end()
    entries = repo.log(table, limit)
    return StatementResult("log", entries,
                           f"log {table}:\n{_fmt_log(entries)}")


def _show(repo, p: _P) -> StatementResult:
    what = p.kw("BRANCHES", "SNAPSHOTS", "PRS", "TABLES").lower()
    p.end()
    rows = repo.status()[what]
    _, fmt = _SECTIONS[what]
    lines = [fmt(r) for r in rows]
    return StatementResult("show", rows,
                           "\n".join(lines) if lines else "(none)")


def _status(repo, p: _P) -> StatementResult:
    p.end()
    st = repo.status()
    return StatementResult("status", st, _fmt_status(st))


def _gc(repo, p: _P) -> StatementResult:
    p.end()
    stats = repo.gc()
    return StatementResult(
        "gc", stats,
        f"gc: freed {stats.objects_freed} object(s), pruned "
        f"{stats.versions_pruned} version(s), "
        f"{stats.pinned_horizons} pinned horizon(s) honored")


def _fsck(repo, p: _P) -> StatementResult:
    repair = p.opt_kw("REPAIR") is not None
    p.end()
    report = repo.fsck(repair=repair)
    lines = [report.summary()] + [str(i) for i in report.issues]
    return StatementResult("fsck", report, "\n".join(lines))


def _lint(repo, p: _P) -> StatementResult:
    """Static invariant analysis of the SOURCE tree (not the repo data) —
    the statement surface of ``datagit lint`` / ``python -m
    repro.analysis``, so statement-driven sessions can gate on it too."""
    p.end()
    from ..analysis import (default_paths, discover_count, render_text,
                            repo_root, run_analysis)
    root = repo_root()
    paths = default_paths(root)
    findings = run_analysis(paths, root=root)
    return StatementResult(
        "lint", findings,
        render_text(findings, discover_count(paths)))


def _push(repo, p: _P) -> StatementResult:
    p.kw("TO")
    remote = p.ref()
    p.end()
    st = repo.push(remote)
    return StatementResult(
        "push", st,
        f"push {remote}: {st['objects_pushed']} object(s) "
        f"({st['bytes_pushed']} bytes), {st['records_pushed']} record(s)")


def _pull(repo, p: _P) -> StatementResult:
    p.kw("FROM")
    remote = p.ref()
    p.end()
    st = repo.pull(remote)
    if st.get("up_to_date"):
        return StatementResult("pull", st,
                               f"pull {remote}: already up to date")
    return StatementResult(
        "pull", st,
        f"pull {remote}: {st['objects_pulled']} object(s), "
        f"{st['records_pulled']} record(s)")


def _fetch(repo, p: _P) -> StatementResult:
    p.kw("FROM")
    remote = p.ref()
    p.end()
    st = repo.fetch(remote)
    return StatementResult(
        "fetch", st,
        f"fetch {remote}: {st['objects_pulled']} object(s) "
        f"({st['bytes_pulled']} bytes)")


def _stats(repo, p: _P) -> StatementResult:
    p.end()
    doc = telemetry.stats_json(repo.engine)
    lines = [f"{k}={v}" for k, v in doc["metrics"].items()]
    return StatementResult("stats", doc, "\n".join(lines))


def _explain(repo, p: _P) -> StatementResult:
    """EXPLAIN <statement>: run the wrapped statement under the tracer and
    print its span tree + counter deltas. The span renderer shows the
    zero-valued siblings of every touched counter group, so the pinned
    invariants read directly off the output (``EXPLAIN MERGE ...`` shows
    ``commit.rows_rehashed=0``)."""
    t, v = p.take()
    verb = v.upper() if t == "w" else v
    handler = _HANDLERS.get(verb)
    if handler is None or verb == "EXPLAIN":
        raise StatementError(
            p.text, f"EXPLAIN: unknown statement verb {v!r}",
            suggest(verb, tuple(x for x in _VERBS if x != "EXPLAIN")))
    if telemetry.current() is None:
        with telemetry.trace(repo.engine) as tr:
            inner = handler(repo, p)
        spans = tr.roots
    else:
        # already armed (e.g. `datagit --trace` running an EXPLAIN): nest
        # the statement's spans under one explain span instead of
        # re-arming
        with telemetry.span(SP_EXPLAIN) as sp:
            inner = handler(repo, p)
        spans = sp.children
    tree = telemetry.render_spans(spans)
    body = "\n".join(tree) if tree else "(no spans recorded)"
    return StatementResult(
        "explain", {"result": inner, "spans": spans},
        (inner.message + "\n" if inner.message else "") + body)


_HANDLERS = {
    "CREATE": _create, "DROP": _drop, "CLONE": _clone, "DIFF": _diff,
    "MERGE": _merge, "OPEN": _open, "CHECK": _check, "PUBLISH": _publish,
    "CLOSE": _close, "REVERT": _revert, "RESTORE": _restore, "LOG": _log,
    "SHOW": _show, "STATUS": _status, "STATS": _stats,
    "EXPLAIN": _explain, "GC": _gc, "FSCK": _fsck,
    "LINT": _lint, "PUSH": _push, "PULL": _pull, "FETCH": _fetch,
}
_VERBS = tuple(_HANDLERS)        # one source of truth for did-you-mean


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def execute(repo, text: str) -> StatementResult:
    """Parse and run ONE statement against a :class:`~.repo.Repo`."""
    stmts = [s for s in text.split(";") if s.strip()]
    if len(stmts) != 1:
        raise StatementError(text, f"expected one statement, got "
                             f"{len(stmts)} (use execute_script)")
    p = _P(stmts[0])
    t, v = p.take()
    verb = v.upper() if t == "w" else v
    handler = _HANDLERS.get(verb)
    if handler is None:
        raise StatementError(text, f"unknown statement verb {v!r}",
                             suggest(verb, _VERBS))
    return handler(repo, p)


def execute_script(repo, text: str) -> List[StatementResult]:
    """Run a ``;``-separated sequence of statements, in order."""
    return [execute(repo, s) for s in text.split(";") if s.strip()]
