"""Background compaction + GC (paper §4, §5.4).

Compaction is a transaction that rewrites the *visible* rows of a set of
data objects into fresh, fully-sorted objects and drops the old data objects
together with every tombstone object that exclusively targets them
(invariant: a tombstone object never outlives its target data objects —
otherwise dropped tombstones would resurrect rows).

Rows keep their ORIGINAL commit timestamps, so MVCC reads at older horizons
remain correct through the PITR directory history; named snapshots pin the
pre-compaction objects against GC. Moves produced here (same value, new
position) are what §5.2's move-handling must absorb during merge — tests
cover that path explicitly.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from . import telemetry
from .faults import crash_point, register
from .objects import OBJECT_CAPACITY, DataObject, seal_data_object
from .schema import concat_batches, take_batch
from .visibility import visibility_index

SP_COMPACTION = telemetry.register_span(
    "compaction", "rewrite the visible rows of a set of data objects "
    "into fresh fully-sorted objects")

CP_COMPACT_POST_SEAL = register(
    "compaction.post_seal",
    "after the rewritten objects are sealed but before the compact record "
    "is logged or the directory swings — recovery must show the "
    "pre-compaction layout (logically identical content)")


def pick_compaction_sources(engine, table: str,
                            min_objects: int = 2,
                            small_frac: float = 0.25) -> Sequence[int]:
    """Deterministic policy: compact data objects that are small (< 25% of
    capacity) or carry any dead rows, once there are at least two of them."""
    t = engine.table(table)
    vi = visibility_index(engine.store, t.directory)
    picked = []
    for oid in t.directory.data_oids:
        obj: DataObject = engine.store.get(oid)
        if obj.nrows < OBJECT_CAPACITY * small_frac:
            picked.append(oid)
            continue
        if vi.has_kills(obj):
            picked.append(oid)
    return picked if len(picked) >= min_objects else []


def compact_objects(engine, table: str, src_oids: Sequence[int],
                    *, _log: bool = True) -> int:
    """Rewrite the visible rows of ``src_oids`` into fresh objects.

    Returns the number of new data objects written."""
    with telemetry.span(SP_COMPACTION):
        return _compact_objects(engine, table, src_oids, _log=_log)


def _compact_objects(engine, table: str, src_oids: Sequence[int],
                     *, _log: bool) -> int:
    t = engine.table(table)
    src = [o for o in src_oids if o in set(t.directory.data_oids)]
    if not src:
        return 0
    vi = visibility_index(engine.store, t.directory)
    batches, tss, rlo, rhi, klo, khi, lsigs = [], [], [], [], [], [], []
    for oid in src:
        obj: DataObject = engine.store.get(oid)
        idx = np.flatnonzero(vi.visible_mask(obj))
        if idx.shape[0] == 0:
            continue
        batches.append(take_batch(obj.cols, idx))
        tss.append(obj.commit_ts[idx])         # ORIGINAL commit ts preserved
        rlo.append(obj.row_lo[idx])
        rhi.append(obj.row_hi[idx])
        klo.append(obj.key_lo[idx])
        khi.append(obj.key_hi[idx])
        lsigs.append({k: v[idx] for k, v in obj.lob_sigs.items()})
    new_oids = []
    if batches:
        batch = concat_batches(t.schema, batches)
        ts = np.concatenate(tss)
        row_lo, row_hi = np.concatenate(rlo), np.concatenate(rhi)
        if t.schema.has_pk:
            key_lo, key_hi = np.concatenate(klo), np.concatenate(khi)
        else:
            key_lo, key_hi = row_lo, row_hi  # NoPK: key IS the row signature
        lob = {k: np.concatenate([d[k] for d in lsigs])
               for k in (lsigs[0] if lsigs else {})}
        order = np.lexsort((key_hi, key_lo))
        for s in range(0, order.shape[0], OBJECT_CAPACITY):
            idx = order[s:s + OBJECT_CAPACITY]
            rl, rh = row_lo[idx], row_hi[idx]
            kl = rl if key_lo is row_lo else key_lo[idx]
            kh = rh if key_hi is row_hi else key_hi[idx]
            # the global lexsort above already ordered every slice — seal
            # presorted instead of paying a second (identity) lexsort
            obj = seal_data_object(
                engine.store.new_oid(), t.schema, take_batch(batch, idx),
                ts[idx], rl, rh, kl, kh,
                {k: v[idx] for k, v in lob.items()}, presorted=True)
            engine.store.put(obj)
            new_oids.append(obj.oid)

    # drop tombstone objects that only target compacted data objects
    src_set = set(src)
    drop_tombs = []
    for toid in t.directory.tomb_oids:
        tomb = engine.store.get(toid)
        targets = set(int(x) for x in np.unique(
            (tomb.target >> np.uint64(32)).astype(np.int64)))
        if targets and targets <= src_set:
            drop_tombs.append(toid)

    apply_ts = engine.next_ts()
    crash_point(CP_COMPACT_POST_SEAL)
    # log-before-swing (like _commit phase 2): once the record is durable
    # replay re-runs the whole compaction; before it, nothing happened
    if _log:
        engine.wal.append("compact", table=table, src_oids=tuple(src),
                          ts=apply_ts)
    t.set_directory(t.directory.replace(
        drop_data=src, drop_tombs=drop_tombs, add_data=new_oids,
        ts=apply_ts))
    return len(new_oids)


def compact_table(engine, table: str) -> int:
    """Run one round of policy-driven compaction. Returns #objects written."""
    src = pick_compaction_sources(engine, table)
    if not src:
        return 0
    return compact_objects(engine, table, src)
