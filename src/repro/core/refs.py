"""One ref grammar, one resolver (ISSUE 5).

The paper's user surface names versions with *strings* — ``CREATE SNAPSHOT
nightly``, ``CLONE TABLE ... {SNAPSHOT = ...}`` — and OrpheusDB/ForkBase
both organize their porcelain around a uniform version-identifier language.
This module is that language for our reproduction: every way to name a
version parses into one small AST and resolves through ONE path, replacing
the ad-hoc ``resolve_snapshot`` / ``snapshot_at`` / ``resolve_branch`` trio.

Grammar (canonical forms on the left)::

    HEAD                 current state of the context table
    branch:dev           branch by name ("main" = the trunk view)
    snap:nightly         named snapshot (a git tag)
    ts:12345             PITR horizon of the context table (T{mo_ts = ts})
    orders@{12345}       PITR horizon of a named table (no context needed)
    orders~2             2 commits back in the table's PITR history index
    pr:3:base            PR #3's pinned base-at-open horizon
    pr:3:head            PR #3's head branch, current state
    pr:3:merged          PR #3's post-publish state
    dev                  bare name: branch, snapshot, or table head —
                         ambiguity is an error, never a guess

Resolution errors are typed: ``UnknownRefError`` (a ``KeyError``) carries
the offending ref text plus did-you-mean candidates; ``AmbiguousRefError``
(a ``ValueError``) lists every legal reading of a bare name. All porcelain
entry points raise these — never a bare KeyError/ValueError string — so a
CLI or statement front-end renders one consistent error shape.
"""
from __future__ import annotations

import difflib
import re
from dataclasses import dataclass
from typing import Optional, Sequence, Union

from .directory import Snapshot

PR_ROLES = ("base", "head", "merged")

_NAME = r"[A-Za-z_][A-Za-z0-9_.\-/]*"
_NAME_RE = re.compile(rf"^{_NAME}$")
_AT_RE = re.compile(rf"^(?P<table>{_NAME})@\{{(?P<ts>\d+)\}}$")
_REL_RE = re.compile(rf"^(?P<table>{_NAME})~(?P<n>\d+)$")


# --------------------------------------------------------------------------
# errors
# --------------------------------------------------------------------------

class RefSyntaxError(ValueError):
    """The ref text does not parse under the grammar."""

    def __init__(self, text: str, why: str):
        super().__init__(f"bad ref {text!r}: {why}")
        self.ref = text


def did_you_mean(suggestions: Sequence[str]) -> str:
    """The one rendering of a suggestion list (shared with the statement
    layer's errors)."""
    if not suggestions:
        return ""
    return (" — did you mean "
            + " or ".join(repr(s) for s in suggestions) + "?")


class UnknownRefError(KeyError):
    """A syntactically valid ref that names nothing.

    Subclasses ``KeyError`` so legacy callers (``engine.snapshots[...]``
    era) keep working; carries the offending ref text and did-you-mean
    suggestions for the porcelain surfaces to render."""

    def __init__(self, ref: str, why: str = "no such ref",
                 suggestions: Sequence[str] = ()):
        super().__init__(f"{ref}: {why}{did_you_mean(suggestions)}")
        self.ref = ref
        self.suggestions = tuple(suggestions)

    def __str__(self) -> str:
        # KeyError's default __str__ is repr(args[0]) — spurious quotes
        # around the message; keep the one consistent error shape
        return self.args[0] if self.args else ""


class AmbiguousRefError(ValueError):
    """A bare name with more than one legal reading."""

    def __init__(self, ref: str, candidates: Sequence[str]):
        super().__init__(
            f"ambiguous ref {ref!r}: could be " + " or ".join(
                repr(c) for c in candidates)
            + " — qualify it")
        self.ref = ref
        self.suggestions = tuple(candidates)


def validate_name(name: str, what: str = "name") -> str:
    """Creation-side guard: a snapshot/branch name must be speakable in
    the ref grammar, or the object could never be named again through any
    surface (resolve/statements/CLI all parse refs first)."""
    if not isinstance(name, str) or not _NAME_RE.match(name):
        raise ValueError(
            f"invalid {what} {name!r}: must start with a letter/underscore "
            "and contain only letters, digits, and _ . - / "
            "(the ref grammar has to be able to name it)")
    return name


def require(mapping, name: str, what: str, ref_text: Optional[str] = None):
    """Lookup with the one error shape: UnknownRefError + did-you-mean.
    Collapses the ``if name not in ...: raise`` guard every porcelain
    entry point needs."""
    if name not in mapping:
        raise UnknownRefError(ref_text or name, f"no {what} {name!r}",
                              suggest(name, mapping))
    return mapping[name]


def suggest(name: str, candidates) -> list:
    """Did-you-mean candidates: close matches first, then shared prefixes."""
    pool = sorted(set(map(str, candidates)))
    out = difflib.get_close_matches(name, pool, n=3, cutoff=0.5)
    for c in pool:
        if len(out) >= 3:
            break
        if c not in out and (c.startswith(name[:3]) if name else False):
            out.append(c)
    return out


# --------------------------------------------------------------------------
# AST
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Ref:
    """Base class; every concrete form knows its canonical text."""

    def format(self) -> str:
        raise NotImplementedError


@dataclass(frozen=True)
class HeadRef(Ref):
    def format(self) -> str:
        return "HEAD"


@dataclass(frozen=True)
class BranchRef(Ref):
    name: str

    def format(self) -> str:
        return f"branch:{self.name}"


@dataclass(frozen=True)
class SnapRef(Ref):
    name: str

    def format(self) -> str:
        return f"snap:{self.name}"


@dataclass(frozen=True)
class TsRef(Ref):
    ts: int

    def format(self) -> str:
        return f"ts:{self.ts}"


@dataclass(frozen=True)
class AtRef(Ref):
    table: str
    ts: int

    def format(self) -> str:
        return f"{self.table}@{{{self.ts}}}"


@dataclass(frozen=True)
class RelRef(Ref):
    table: str
    n: int

    def format(self) -> str:
        return f"{self.table}~{self.n}"


@dataclass(frozen=True)
class PrRef(Ref):
    pr_id: int
    role: str                       # base | head | merged

    def format(self) -> str:
        return f"pr:{self.pr_id}:{self.role}"


@dataclass(frozen=True)
class BareRef(Ref):
    """A bare name: branch, snapshot, or table head — resolved by lookup,
    ambiguity is an error."""
    name: str

    def format(self) -> str:
        return self.name


@dataclass(frozen=True)
class ResolvedRef:
    """What every ref resolves to: a physical table + a frozen snapshot."""
    ref: Optional[Ref]              # None when resolved from a Snapshot
    table: str                      # physical table name
    snapshot: Snapshot


RefLike = Union[str, Ref, Snapshot]


# --------------------------------------------------------------------------
# parser
# --------------------------------------------------------------------------

def parse_ref(text: str) -> Ref:
    """Parse ref text into its AST form. Raises RefSyntaxError."""
    if not isinstance(text, str):
        raise RefSyntaxError(str(text), f"expected a string, got "
                             f"{type(text).__name__}")
    t = text.strip()
    if not t:
        raise RefSyntaxError(text, "empty ref")
    if t == "HEAD":
        return HeadRef()
    for prefix, cls in (("branch:", BranchRef), ("snap:", SnapRef)):
        if t.startswith(prefix):
            name = t[len(prefix):]
            if not _NAME_RE.match(name):
                raise RefSyntaxError(text, f"invalid name {name!r}")
            return cls(name)
    if t.startswith("ts:"):
        body = t[3:]
        if not body.isdigit():
            raise RefSyntaxError(text, "ts: needs an integer timestamp")
        return TsRef(int(body))
    if t.startswith("pr:"):
        parts = t.split(":")
        if len(parts) not in (2, 3) or not parts[1].isdigit():
            raise RefSyntaxError(text, "expected pr:<id>[:base|head|merged]")
        role = parts[2] if len(parts) == 3 else "head"
        if role not in PR_ROLES:
            raise RefSyntaxError(
                text, f"bad PR role {role!r} (one of {'/'.join(PR_ROLES)})")
        return PrRef(int(parts[1]), role)
    m = _AT_RE.match(t)
    if m:
        return AtRef(m.group("table"), int(m.group("ts")))
    m = _REL_RE.match(t)
    if m:
        return RelRef(m.group("table"), int(m.group("n")))
    if _NAME_RE.match(t):
        return BareRef(t)
    raise RefSyntaxError(text, "unrecognized form")


def format_ref(ref: Ref) -> str:
    return ref.format()


# --------------------------------------------------------------------------
# the one resolver
# --------------------------------------------------------------------------

def _table_snapshot(engine, phys: str, ref_text: str) -> Snapshot:
    if phys not in engine.tables:
        raise UnknownRefError(ref_text, f"no table {phys!r}",
                              suggest(phys, engine.tables))
    return engine.current_snapshot(phys)


def _branch(engine, name: str, ref_text: str):
    """Branch lookup with trunk synthesis; UnknownRefError otherwise."""
    from .workspace import TRUNK, resolve_branch
    if name == TRUNK or name in engine.branches:
        return resolve_branch(engine, name)
    raise UnknownRefError(
        ref_text, f"no branch {name!r}",
        suggest(name, list(engine.branches) + [TRUNK]))


def _branch_table(engine, br, table: Optional[str], ref_text: str) -> str:
    if table is None:
        raise UnknownRefError(
            ref_text, "branch ref needs a table context (pass table=...)",
            [f"{ref_text} with table={t!r}" for t in sorted(br.tables)[:2]])
    if table in br.tables:
        return br.tables[table]
    # accept the branch's own physical names too (dev/t on branch dev)
    if table in br.tables.values():
        return table
    raise UnknownRefError(
        ref_text, f"branch {br.name!r} has no table {table!r}",
        suggest(table, br.tables))


def _pitr_snapshot(engine, phys: str, ts: int, ref_text: str) -> Snapshot:
    if phys not in engine.tables:
        raise UnknownRefError(ref_text, f"no table {phys!r}",
                              suggest(phys, engine.tables))
    t = engine.table(phys)
    try:
        d = t.directory_at(ts)
    except KeyError:
        raise UnknownRefError(
            ref_text, f"no PITR history for {phys!r} at ts={ts} "
            f"(history starts at ts={t.history[0][0]})") from None
    return Snapshot(name=None, table=phys, schema=t.schema, directory=d,
                    created_ts=ts)


def _pr(engine, pr_id: int, ref_text: str):
    pr = engine.prs.get(pr_id)
    if pr is None:
        raise UnknownRefError(
            ref_text, f"no PR #{pr_id}",
            [f"pr:{i}" for i in sorted(engine.prs)][:3])
    return pr


def resolve(engine, ref: RefLike, table: Optional[str] = None) -> ResolvedRef:
    """THE resolution path: every porcelain surface funnels through here.

    ``ref`` may be a ``Snapshot`` (passes through), ref text, or a parsed
    ``Ref``. ``table`` is the logical table context required by the forms
    that do not name a table themselves (HEAD, branch refs, ts:, pr:).
    Raises ``UnknownRefError`` / ``AmbiguousRefError`` / ``RefSyntaxError``.
    """
    if isinstance(ref, Snapshot):
        return ResolvedRef(None, ref.table, ref)
    r = parse_ref(ref) if isinstance(ref, str) else ref
    if not isinstance(r, Ref):
        raise RefSyntaxError(str(ref), f"not a ref: {type(ref).__name__}")
    text = r.format()

    if isinstance(r, HeadRef):
        if table is None:
            raise UnknownRefError(text, "HEAD needs a table context "
                                  "(pass table=...)")
        snap = _table_snapshot(engine, table, text)
        return ResolvedRef(r, table, snap)

    if isinstance(r, BranchRef):
        br = _branch(engine, r.name, text)
        phys = _branch_table(engine, br, table, text)
        return ResolvedRef(r, phys, engine.current_snapshot(phys))

    if isinstance(r, SnapRef):
        snap = engine.snapshots.get(r.name)
        if snap is None:
            raise UnknownRefError(text, f"no snapshot {r.name!r}",
                                  suggest(r.name, engine.snapshots))
        return ResolvedRef(r, snap.table, snap)

    if isinstance(r, TsRef):
        if table is None:
            raise UnknownRefError(text, "ts: ref needs a table context "
                                  "(pass table=..., or use table@{ts})")
        return ResolvedRef(r, table, _pitr_snapshot(engine, table, r.ts,
                                                    text))

    if isinstance(r, AtRef):
        return ResolvedRef(r, r.table, _pitr_snapshot(engine, r.table,
                                                      r.ts, text))

    if isinstance(r, RelRef):
        if r.table not in engine.tables:
            raise UnknownRefError(text, f"no table {r.table!r}",
                                  suggest(r.table, engine.tables))
        t = engine.table(r.table)
        if r.n >= len(t.history):
            raise UnknownRefError(
                text, f"only {len(t.history)} version(s) in "
                f"{r.table!r}'s history index")
        ts, d = t.history[len(t.history) - 1 - r.n]
        snap = Snapshot(name=None, table=r.table, schema=t.schema,
                        directory=d, created_ts=ts)
        return ResolvedRef(r, r.table, snap)

    if isinstance(r, PrRef):
        pr = _pr(engine, r.pr_id, text)
        if table is not None:
            if table not in pr.tables:
                raise UnknownRefError(
                    text, f"PR #{r.pr_id} does not cover table {table!r}",
                    suggest(table, pr.tables))
            lg = table
        elif len(pr.tables) == 1:
            lg = next(iter(pr.tables))
        else:
            raise AmbiguousRefError(
                text, [f"{text} with table={t!r}"
                       for t in sorted(pr.tables)])
        if r.role == "base":
            snap = pr.base_pins[lg]
            return ResolvedRef(r, snap.table, snap)
        if r.role == "head":
            phys = pr.tables[lg]
            return ResolvedRef(r, phys, _table_snapshot(engine, phys, text))
        snap = pr.post_publish.get(lg)     # merged
        if snap is None:
            raise UnknownRefError(
                text, f"PR #{r.pr_id} is {pr.status}: no merged state "
                "(publish it first)")
        return ResolvedRef(r, snap.table, snap)

    if isinstance(r, BareRef):
        from .workspace import TRUNK
        readings = []
        if r.name == TRUNK or r.name in engine.branches:
            readings.append(("branch", BranchRef(r.name)))
        if r.name in engine.snapshots:
            readings.append(("snapshot", SnapRef(r.name)))
        if r.name in engine.tables:
            readings.append(("table", None))
        if len(readings) > 1:
            raise AmbiguousRefError(
                text, [f"branch:{r.name}" if k == "branch"
                       else f"snap:{r.name}" if k == "snapshot"
                       else f"{r.name}@{{ts}} / HEAD of table {r.name!r}"
                       for k, _ in readings])
        if not readings:
            pool = (list(engine.branches) + list(engine.snapshots)
                    + list(engine.tables) + [TRUNK])
            raise UnknownRefError(
                text, "no branch, snapshot, or table by that name",
                suggest(r.name, pool))
        kind, sub = readings[0]
        if kind == "table":
            return ResolvedRef(r, r.name,
                               engine.current_snapshot(r.name))
        return resolve(engine, sub, table)

    raise RefSyntaxError(text, "unhandled ref form")   # pragma: no cover


def as_branch(engine, ref: RefLike):
    """The Branch a ref denotes, or None if it isn't a branch ref.

    ``branch:x`` raises UnknownRefError if x doesn't exist; a bare name
    returns the branch only when that reading is unambiguous."""
    from .workspace import TRUNK
    if isinstance(ref, Snapshot):
        return None
    r = parse_ref(ref) if isinstance(ref, str) else ref
    if isinstance(r, BranchRef):
        return _branch(engine, r.name, r.format())
    if isinstance(r, BareRef):
        is_branch = r.name == TRUNK or r.name in engine.branches
        if is_branch:
            others = []
            if r.name in engine.snapshots:
                others.append(f"snap:{r.name}")
            if r.name in engine.tables:
                others.append(f"table {r.name!r}")
            if others:
                raise AmbiguousRefError(
                    r.format(), [f"branch:{r.name}"] + others)
            return _branch(engine, r.name, r.format())
    return None
