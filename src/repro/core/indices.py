"""Secondary indices as auxiliary versioned tables (paper §5.5.4).

MatrixOne implements a secondary index as "an auxiliary table consisting of
the indexed columns and the primary key columns of the original table,
stored and managed as an LSM tree" — and lists cloning those auxiliary
tables as future work. We implement both: index maintenance rides inside
the SAME transaction as the base-table change (atomic), and
``clone_table(..., with_indices=True)`` clones the auxiliary tables
(metadata-only, like any clone).

The auxiliary schema is (isig I64, <pk columns>) with primary key
(isig, pk...): ``isig`` is the 64-bit signature of the indexed column
values, so equality lookups filter one integer column. A production LSM
would cluster by isig; here lookups are a vectorized scan filter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..kernels import ops
from .schema import Column, CType, Schema
from .sigs import column_lanes, lob_sig64


@dataclass(frozen=True)
class IndexSpec:
    name: str
    table: str
    columns: Tuple[str, ...]   # indexed columns of the base table

    @property
    def aux_table(self) -> str:
        return f"__idx_{self.table}_{self.name}"


def _isig(schema: Schema, batch, columns) -> np.ndarray:
    """Signature of the indexed column values (i64 view of u64 sig_lo)."""
    lob_sigs = {c: lob_sig64(batch[c]) for c in columns
                if schema.column(c).ctype is CType.LOB}
    lanes = column_lanes(schema, batch, columns, lob_sigs)
    lo, _ = ops.signatures_from_lanes(lanes)
    return lo.view(np.int64)


def aux_schema(base: Schema) -> Schema:
    assert base.has_pk, "secondary indices require a primary key"
    cols = (Column("isig", CType.I64),) + tuple(
        base.column(c) for c in base.primary_key)
    return Schema(cols, primary_key=("isig",) + tuple(base.primary_key))


def backfill_index(engine, spec: IndexSpec, batch=None):
    """Create ``spec``'s aux table and backfill it from the base table.

    The single backfill implementation — used by CREATE INDEX and by
    snapshot-clone index rebuilds (sub-operations unlogged either way).
    ``batch`` lets a caller rebuilding several indices share one base-table
    scan; returns the batch actually used so it can be reused."""
    t = engine.table(spec.table)
    engine.create_table(spec.aux_table, aux_schema(t.schema), _log=False)
    if batch is None:
        batch, _ = t.scan()
    if batch[t.schema.primary_key[0]].shape[0]:
        tx = engine.begin()
        tx.insert(spec.aux_table, aux_rows(t.schema, spec, batch))
        engine._commit(tx, _log=False)
    return batch


def create_index(engine, table: str, name: str, columns: Sequence[str],
                 *, _log: bool = True) -> IndexSpec:
    """CREATE INDEX name ON table(columns) — backfills existing rows.

    The WAL carries ONE create_index record; replay re-runs the aux-table
    creation and backfill deterministically (sub-operations unlogged)."""
    t = engine.table(table)
    spec = IndexSpec(name, table, tuple(columns))
    for c in columns:
        t.schema.column(c)  # validates
    if _log:
        engine.wal.append("create_index", table=table, name=name,
                          columns=tuple(columns))
    engine.indices.setdefault(table, []).append(spec)
    backfill_index(engine, spec)
    return spec


def drop_index(engine, table: str, name: str, *, _log: bool = True) -> None:
    specs = engine.indices.get(table, [])
    spec = next(s for s in specs if s.name == name)
    specs.remove(spec)
    engine.drop_table(spec.aux_table, _log=False)
    if _log:
        engine.wal.append("drop_index", table=table, name=name)


def aux_rows(schema: Schema, spec: IndexSpec, batch) -> Dict[str, np.ndarray]:
    out = {"isig": _isig(schema, batch, spec.columns)}
    for c in schema.primary_key:
        out[c] = batch[c]
    return out


def lookup_eq(engine, table: str, name: str, values) -> Dict[str, np.ndarray]:
    """Equality lookup: returns the base-table PK columns of matching rows.

    ``values``: dict {indexed column -> scalar or array of length 1}."""
    t = engine.table(table)
    spec = next(s for s in engine.indices.get(table, [])
                if s.name == name)
    probe = {c: np.asarray([values[c]]).reshape(1)
             if not isinstance(values[c], np.ndarray) else values[c]
             for c in spec.columns}
    if any(t.schema.column(c).ctype is CType.LOB for c in spec.columns):
        probe = {c: (np.asarray([v if isinstance(v, bytes) else bytes(v)
                                 for v in np.atleast_1d(probe[c])],
                                dtype=object)
                     if t.schema.column(c).ctype is CType.LOB else probe[c])
                 for c in probe}
    sig = _isig(t.schema, probe, spec.columns)[0]
    aux = engine.table(spec.aux_table)
    batch, _ = aux.scan()
    hit = batch["isig"] == sig
    return {c: batch[c][hit] for c in t.schema.primary_key}


def maintain_on_commit(engine, tx, table: str,
                       ins_batches, del_rowids) -> None:
    """Expand a txn with the auxiliary-table changes (same-commit atomic)."""
    from .diff import gather_payload
    t = engine.table(table)
    for spec in engine.indices.get(table, []):
        if del_rowids.shape[0]:
            dead = gather_payload(engine.store, t.schema, del_rowids)
            keys = aux_rows(t.schema, spec, dead)
            tx.delete_by_keys(spec.aux_table, keys)
        for b in ins_batches:
            tx.insert(spec.aux_table, aux_rows(t.schema, spec, b))
