"""Immutable storage objects (paper §4).

Data of a table lives in immutable columnar *objects* (row groups). Deletes
are *tombstone* objects holding (key signature, target physical rowid).
Objects form an LSM tree ordered by key signature; each object's rows are
sorted at seal time and carry a zone map for probe pruning.

Physical rowid = (oid << 32) | row_offset, packed in uint64 — mirroring the
paper's (object name, position) rowids.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from .faults import crash_point, register
from .schema import Schema, batch_nbytes, take_batch
from .telemetry import Metrics

CP_STORE_SPILL = register(
    "store.spill",
    "mid spill: the pack file for an object may exist on disk but the "
    "heap entry has not moved to the packed map — the heap copy is still "
    "authoritative, so recovery sees identical content (an orphan pack "
    "file is invisible content-addressed garbage)")

OBJECT_CAPACITY = 1 << 18  # max rows per sealed object (256Ki)

#: the sealed-lane write sanitizer (ISSUE 7): when armed, every numpy lane
#: of a sealed object is marked ``writeable=False`` at store time, so an
#: in-place mutation raises ``ValueError`` AT the write instead of
#: corrupting zone maps / carried signatures silently. Off by default —
#: the only disarmed cost is one module-global truthiness test per seal.
#: Tier-1 CI runs with REPRO_SANITIZE=1.
SANITIZE = os.environ.get("REPRO_SANITIZE", "0") not in ("", "0")


def set_sanitize(on: bool) -> bool:
    """Arm/disarm the write sanitizer; returns the previous state (tests
    restore it). Only objects sealed while armed are frozen — already-
    sealed objects keep whatever flags they have."""
    global SANITIZE
    prev = SANITIZE
    SANITIZE = bool(on)
    return prev


def _freeze_lanes(obj) -> None:
    """Mark every numpy lane of a sealed object read-only (idempotent)."""
    if isinstance(obj, DataObject):
        lanes = [obj.commit_ts, obj.row_lo, obj.row_hi, obj.key_lo,
                 obj.key_hi, *obj.cols.values(), *obj.lob_sigs.values()]
    else:
        lanes = [obj.commit_ts, obj.target, obj.key_lo, obj.key_hi]
    for a in lanes:
        a.setflags(write=False)

_OFF_MASK = np.uint64(0xFFFFFFFF)


def _ts_minmax(commit_ts: np.ndarray) -> Tuple[int, int]:
    """(min, max) commit_ts of an object's rows ((0, 0) when empty)."""
    if commit_ts.shape[0] == 0:
        return (0, 0)
    return (int(commit_ts.min()), int(commit_ts.max()))


def pack_rowid(oid: int, offsets: np.ndarray) -> np.ndarray:
    return (np.uint64(oid) << np.uint64(32)) | offsets.astype(np.uint64)


def rowid_oid(rowids: np.ndarray) -> np.ndarray:
    return (rowids >> np.uint64(32)).astype(np.int64)


def rowid_off(rowids: np.ndarray) -> np.ndarray:
    return (rowids & _OFF_MASK).astype(np.int64)


@dataclass
class DataObject:
    """A sealed, immutable row group. Rows sorted by (key_lo, key_hi)."""
    oid: int
    nrows: int
    cols: Dict[str, np.ndarray]          # column data (LOB: object array)
    commit_ts: np.ndarray                # (n,) uint64
    row_lo: np.ndarray                   # (n,) uint64 full-row signature
    row_hi: np.ndarray
    key_lo: np.ndarray                   # (n,) uint64 key signature (sorted)
    key_hi: np.ndarray
    lob_sigs: Dict[str, np.ndarray] = field(default_factory=dict)
    nbytes: int = 0                      # logical payload bytes
    _ts_zone: Optional[Tuple[int, int]] = field(
        default=None, repr=False, compare=False)
    _rowids: Optional[np.ndarray] = field(
        default=None, repr=False, compare=False)

    @property
    def zone(self) -> Tuple[np.uint64, np.uint64]:
        """(min, max) of key_lo — zone map for probe pruning."""
        if self.nrows == 0:
            return np.uint64(0), np.uint64(0)
        return self.key_lo[0], self.key_lo[-1]

    @property
    def ts_zone(self) -> Tuple[int, int]:
        """(min, max) commit_ts — computed once; objects are immutable.

        Visibility uses this to skip the per-row horizon compare when the
        whole object is within (or beyond) a directory's ts."""
        if self._ts_zone is None:
            self._ts_zone = _ts_minmax(self.commit_ts)
        return self._ts_zone

    def rowids(self) -> np.ndarray:
        # computed once; objects are immutable and the zero-copy Δ emission
        # path reuses this array per scan
        if self._rowids is None:
            self._rowids = pack_rowid(self.oid,
                                      np.arange(self.nrows, dtype=np.uint64))
        return self._rowids


@dataclass
class TombstoneObject:
    """Sealed batch of deletions: each row kills one physical row."""
    oid: int
    nrows: int
    target: np.ndarray                   # (n,) uint64 rowid being deleted
    key_lo: np.ndarray                   # key signature of the deleted row
    key_hi: np.ndarray
    commit_ts: np.ndarray                # (n,) uint64
    # oids of the data objects this tombstone batch targets (for the
    # compaction invariant: tombstones die with their target objects)
    target_oids: Tuple[int, ...] = ()
    _ts_zone: Optional[Tuple[int, int]] = field(
        default=None, repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        return int(self.target.nbytes + self.key_lo.nbytes
                   + self.key_hi.nbytes + self.commit_ts.nbytes)

    @property
    def ts_zone(self) -> Tuple[int, int]:
        """(min, max) commit_ts — computed once; objects are immutable.
        A horizon at or past the max sees every target of this object."""
        if self._ts_zone is None:
            self._ts_zone = _ts_minmax(self.commit_ts)
        return self._ts_zone


def seal_data_object(oid: int, schema: Schema, batch: Dict[str, np.ndarray],
                     commit_ts: np.ndarray, row_lo, row_hi, key_lo, key_hi,
                     lob_sigs: Dict[str, np.ndarray], *,
                     presorted: bool = False) -> DataObject:
    """Freeze a batch as an immutable key-sorted object.

    ``presorted=True``: the caller guarantees the rows already arrive in
    (key_lo, key_hi) order — the zero-rehash apply path and compaction both
    key-sort globally before slicing capacity-sized objects, so re-sorting
    each slice here would be a second (identity) lexsort per seal."""
    if not presorted:
        order = np.lexsort((key_hi, key_lo))
        batch = take_batch(batch, order)
        commit_ts = commit_ts[order]
        row_lo_s, row_hi_s = row_lo[order], row_hi[order]
        # NoPK tables: the key signature IS the row signature — keep the
        # array identity through the gather so Δ emission can tag streams
        # key==row (and halve the signature memory per object)
        key_lo = row_lo_s if key_lo is row_lo else key_lo[order]
        key_hi = row_hi_s if key_hi is row_hi else key_hi[order]
        row_lo, row_hi = row_lo_s, row_hi_s
        lob_sigs = {k: v[order] for k, v in lob_sigs.items()}
    obj = DataObject(
        oid=oid,
        nrows=int(row_lo.shape[0]),
        cols=batch,
        commit_ts=commit_ts,
        row_lo=row_lo, row_hi=row_hi,
        key_lo=key_lo, key_hi=key_hi,
        lob_sigs=lob_sigs,
        nbytes=batch_nbytes(schema, batch),
    )
    if SANITIZE:
        _freeze_lanes(obj)
    return obj


class ObjectStore:
    """The immutable object store (stand-in for S3 in the paper).

    Objects are write-once; deletion happens only through GC (mark-sweep
    from directories + named snapshots) and through the rollback paths
    that make aborted work invisible (``Engine._commit`` unwinding a
    failed transaction, the workflow layer discarding a CI merge
    preview). Those rollbacks also rewind ``_next_oid``, so an oid CAN be
    reused after its object was deleted — any oid-keyed structure must
    therefore subscribe to ``delete`` notifications (``on_delete``, as
    the visibility/delta caches do) rather than assume oids are unique
    forever. That same reuse is why the durable pack tier below keys by
    content digest, never oid. Immutability makes client caching trivial
    (paper §4) — here the heap is tier 1 of a three-tier store: with a
    ``repro.store.packs.PackDir`` attached (``attach_packs``), objects can
    be spilled/evicted to content-addressed pack files and fault back in
    lazily on ``get``.
    """

    def __init__(self):
        self._objects: Dict[int, object] = {}
        self._next_oid = 1
        self.bytes_written = 0  # cumulative physical write volume
        # visibility-target / signed-delta caches, attached lazily by
        # core.visibility / core.delta to avoid import cycles (both modules
        # import objects)
        self.vis_cache = None
        self.delta_cache = None
        # cumulative telemetry counters (delta.* / gc.* totals) — the
        # per-call stats objects are transient, so the store keeps the
        # running sums the tracer snapshots
        self.metrics = Metrics()
        # durable pack tier (ISSUE 10), attached via attach_packs(); when
        # None every path below reduces to the plain heap-dict store.
        self.packs = None
        # oid -> (digest, is_tomb, nbytes) for every oid with a pack copy;
        # an oid in BOTH maps is spilled-but-resident, in _packed only it
        # is evicted and will fault in on get()
        self._packed: Dict[int, Tuple[str, bool, int]] = {}
        # digest -> live-oid refcount: pack files are deleted only when no
        # live oid references their content (oids can share bytes)
        self._digest_refs: Dict[str, int] = {}
        self._atime: Dict[int, int] = {}   # oid -> LRU tick (heap tier)
        self._tick = 0

    def new_oid(self) -> int:
        oid = self._next_oid
        self._next_oid += 1
        return oid

    def put(self, obj) -> int:
        assert obj.oid not in self._objects and obj.oid not in self._packed, \
            "objects are immutable/write-once"
        if SANITIZE:
            _freeze_lanes(obj)
        self._objects[obj.oid] = obj
        self.bytes_written += int(obj.nbytes)
        return obj.oid

    def get(self, oid: int):
        obj = self._objects.get(oid)
        if obj is not None:
            if self.packs is not None:
                self.metrics.add("store.hits")
                self._tick += 1
                self._atime[oid] = self._tick
            return obj
        ent = self._packed.get(oid)
        if ent is None:
            raise KeyError(oid)
        return self._fault_in(oid, ent)

    def has(self, oid: int) -> bool:
        return oid in self._objects or oid in self._packed

    def delete(self, oid: int) -> None:
        obj = self._objects.pop(oid, None)
        ent = self._packed.pop(oid, None)
        if obj is None and ent is None:
            raise KeyError(oid)
        self._atime.pop(oid, None)
        is_tomb = (isinstance(obj, TombstoneObject) if obj is not None
                   else ent[1])
        if self.vis_cache is not None and is_tomb:
            self.vis_cache.on_delete(oid)
        if self.delta_cache is not None:
            self.delta_cache.on_delete(oid)
        if ent is not None:
            digest = ent[0]
            n = self._digest_refs.get(digest, 1) - 1
            if n <= 0:
                self._digest_refs.pop(digest, None)
                self.packs.release(digest)
            else:
                self._digest_refs[digest] = n

    def oids(self):
        if not self._packed:
            return self._objects.keys()
        return self._objects.keys() | self._packed.keys()

    def live_bytes(self) -> int:
        heap = sum(int(o.nbytes) for o in self._objects.values())
        packed_only = sum(ent[2] for oid, ent in self._packed.items()
                          if oid not in self._objects)
        return heap + packed_only

    # -- pack tier (ISSUE 10) ---------------------------------------------

    def attach_packs(self, backend) -> None:
        """Attach a durable pack directory (``repro.store.packs.PackDir``)
        as tier 2. In-place: ``Table._store`` and the caches keep their
        references to this store."""
        self.packs = backend
        backend.metrics = self.metrics

    def digest_of(self, oid: int) -> Optional[str]:
        ent = self._packed.get(oid)
        return ent[0] if ent is not None else None

    def spill(self, oid: int) -> str:
        """Write oid's content to the pack tier (keeps the heap copy);
        returns the content digest. Idempotent per oid."""
        ent = self._packed.get(oid)
        if ent is not None:
            return ent[0]
        digest, blob = self.packs.encode(self._objects[oid])
        crash_point(CP_STORE_SPILL)
        fresh = self.packs.store(digest, blob)
        obj = self._objects[oid]
        self._packed[oid] = (digest, isinstance(obj, TombstoneObject),
                             int(obj.nbytes))
        self._digest_refs[digest] = self._digest_refs.get(digest, 0) + 1
        self.metrics.add("store.spills")
        if fresh:
            self.metrics.add("store.bytes_packed", len(blob))
        return digest

    def evict(self, oid: int) -> str:
        """Spill oid then drop its heap copy — the object stays live (no
        ``on_delete``: caches keyed by oid remain valid because fault-in
        reconstructs identical content at the same oid)."""
        digest = self.spill(oid)
        self._objects.pop(oid, None)
        self._atime.pop(oid, None)
        self.metrics.add("store.evictions")
        return digest

    def _fault_in(self, oid: int, ent):
        obj = self.packs.load(ent[0], oid)
        if SANITIZE:
            _freeze_lanes(obj)
        self._objects[oid] = obj
        self._tick += 1
        self._atime[oid] = self._tick
        self.metrics.add("store.faults")
        return obj

    def spill_all(self) -> int:
        n = 0
        for oid in list(self._objects):
            if oid not in self._packed:
                self.spill(oid)
                n += 1
        return n

    def evict_all(self) -> int:
        n = 0
        for oid in list(self._objects):
            self.evict(oid)
            n += 1
        return n

    def shrink_heap(self, target_bytes: int) -> int:
        """Evict least-recently-used resident objects until the heap tier
        holds at most ``target_bytes``; returns the eviction count."""
        resident = sum(int(o.nbytes) for o in self._objects.values())
        if resident <= target_bytes:
            return 0
        order = sorted(self._objects, key=lambda o: self._atime.get(o, 0))
        n = 0
        for oid in order:
            if resident <= target_bytes:
                break
            resident -= int(self._objects[oid].nbytes)
            self.evict(oid)
            n += 1
        return n
