"""repro.core — the paper's contribution: a git-for-data version-control
engine over immutable columnar storage with MVCC (MatrixOne §§3-5).

Public API:
    Repo                 — THE porcelain facade: every VCS verb, on refs
    parse_ref, resolve, UnknownRefError, AmbiguousRefError — one ref grammar
    execute (statements) — the paper-style SQL statement front-end
    Engine, Txn          — tables, transactions, snapshots, clone/restore
    Schema, Column, CType
    snapshot_diff, sql_diff, DiffResult
    three_way_merge, two_way_merge, ConflictMode, MergeReport
    compact_table, compact_objects
    fsck, FsckReport     — integrity verification and salvage
    FaultPlan, inject, InjectedCrash — deterministic crash injection
    TornFrame, CorruptFrame, StoreVersionError — typed durable-format errors
"""
from .schema import CType, Column, Schema                      # noqa: F401
from .directory import Directory, Snapshot                     # noqa: F401
from .engine import (CommitRecord, CommitStats, Engine,        # noqa: F401
                     GCStats, PKViolation, Txn, TxnConflict)
from .sigs import SigBatch, compute_sigs, resolve_sigs         # noqa: F401
from .diff import (DiffResult, gather_payload, gather_rowsigs,  # noqa: F401
                   snapshot_diff, sql_diff)
from .merge import (ConflictMode, MergeConflictError, MergeReport,  # noqa: F401
                    ThreeWayDiff, plan_merge, three_way_diff,
                    three_way_merge, two_way_merge)
from .compaction import compact_objects, compact_table         # noqa: F401
from .wal import (WAL, CorruptFrame, StoreFormatError,         # noqa: F401
                  StoreVersionError, TornFrame, TornTransaction)
from .faults import (FaultPlan, InjectedCrash, crash_point,    # noqa: F401
                     inject, register, registered)
from .fsck import FsckIssue, FsckReport, fsck                  # noqa: F401
from .refs import (AmbiguousRefError, Ref, RefSyntaxError,     # noqa: F401
                   ResolvedRef, UnknownRefError, as_branch,
                   format_ref, parse_ref, resolve)
from .repo import MODE_ALIASES, Repo, parse_mode               # noqa: F401
from .workspace import (TRUNK, Branch, CheckContext,           # noqa: F401
                        CheckResult, PublishBlocked, PullRequest,
                        RevertConflict)
from .statements import StatementError, StatementResult, execute  # noqa: F401,E501

