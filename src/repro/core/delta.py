"""Signed delta streams (paper §5.1 "scanning Δ").

``signed_delta(a, b)`` produces the multiset difference visible(b) −
visible(a) as a signed stream, reading **only** objects in the symmetric
difference of the two directories plus tombstone differences on shared
objects — never the full table. This one primitive powers both SNAPSHOT DIFF
(a = left snapshot) and the per-branch change sets of merge (a = common base
revision), including the no-common-base optimization of §5.3 (shared objects
are skipped wholesale).

Stream row fields:
  sign    +1: row visible in b, not in a;  −1: visible in a, not in b
  key_lo/hi   key signature (PK sig; == row sig for NoPK tables)
  row_lo/hi   full row-value signature
  rowid       physical location of the row (payload gather source)

Because objects store per-row signatures, "joining with the base revision to
fetch deleted values" (paper §5.1 step 2) is a direct gather by rowid and is
deferred until a payload is actually output.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..kernels import ops
from .directory import Directory
from .objects import DataObject, ObjectStore, pack_rowid
from .visibility import VisibilityIndex


@dataclass
class SignedStream:
    sign: np.ndarray      # (n,) int32
    key_lo: np.ndarray    # (n,) uint64
    key_hi: np.ndarray
    row_lo: np.ndarray
    row_hi: np.ndarray
    rowid: np.ndarray     # (n,) uint64

    @property
    def n(self) -> int:
        return int(self.sign.shape[0])

    @staticmethod
    def empty() -> "SignedStream":
        z64 = np.zeros((0,), np.uint64)
        return SignedStream(np.zeros((0,), np.int32), z64, z64, z64, z64, z64)

    @staticmethod
    def concat(parts) -> "SignedStream":
        parts = [p for p in parts if p.n]
        if not parts:
            return SignedStream.empty()
        return SignedStream(*[np.concatenate([getattr(p, f) for p in parts])
                              for f in ("sign", "key_lo", "key_hi",
                                        "row_lo", "row_hi", "rowid")])

    def take(self, idx) -> "SignedStream":
        return SignedStream(self.sign[idx], self.key_lo[idx], self.key_hi[idx],
                            self.row_lo[idx], self.row_hi[idx], self.rowid[idx])


def _emit(obj: DataObject, idx: np.ndarray, sign: int) -> SignedStream:
    return SignedStream(
        np.full((idx.shape[0],), sign, np.int32),
        obj.key_lo[idx], obj.key_hi[idx],
        obj.row_lo[idx], obj.row_hi[idx],
        pack_rowid(obj.oid, idx.astype(np.uint64)))


class DeltaStats:
    """Instrumentation: how much the Δ-scan actually read (vs. table size)."""

    def __init__(self):
        self.objects_scanned = 0
        self.objects_skipped_shared = 0
        self.rows_scanned = 0
        self.bytes_scanned = 0


def signed_delta(store: ObjectStore, a: Directory, b: Directory,
                 stats: DeltaStats | None = None) -> SignedStream:
    stats = stats if stats is not None else DeltaStats()
    set_a, set_b = set(a.data_oids), set(b.data_oids)
    only_a = sorted(set_a - set_b)
    only_b = sorted(set_b - set_a)
    shared = sorted(set_a & set_b)
    vi_a = VisibilityIndex(store, a)
    vi_b = VisibilityIndex(store, b)
    parts = []

    for oid in only_b:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        idx = np.flatnonzero(vi_b.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, +1))

    for oid in only_a:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        idx = np.flatnonzero(vi_a.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, -1))

    # Shared objects: only rows whose visibility DIFFERS can contribute.
    # The candidates are exactly the tombstone targets of either side within
    # the object (plus ts-horizon differences), so we never materialize the
    # object's full row set unless a tombstone or horizon touches it.
    ts_min = min(a.ts, b.ts)
    for oid in shared:
        obj = store.get(oid)
        touched = np.zeros((obj.nrows,), bool)
        any_tomb = (vi_a.targets.shape[0] or vi_b.targets.shape[0])
        if any_tomb:
            touched |= vi_a.killed_mask(obj)
            touched |= vi_b.killed_mask(obj)
        if obj.commit_ts.shape[0] and int(obj.commit_ts.max()) > ts_min:
            touched |= obj.commit_ts > np.uint64(ts_min)
        if not touched.any():
            stats.objects_skipped_shared += 1
            continue
        stats.objects_scanned += 1
        cand = np.flatnonzero(touched)
        stats.rows_scanned += int(cand.shape[0])
        va = vi_a.visible_mask(obj)[cand]
        vb = vi_b.visible_mask(obj)[cand]
        plus = cand[vb & ~va]
        minus = cand[va & ~vb]
        if plus.shape[0]:
            parts.append(_emit(obj, plus, +1))
        if minus.shape[0]:
            parts.append(_emit(obj, minus, -1))

    return SignedStream.concat(parts)


def full_scan_stream(store: ObjectStore, d: Directory, sign: int,
                     stats: DeltaStats | None = None) -> SignedStream:
    """Scan ALL visible rows of a snapshot (the SQL-baseline path, Listing 2)."""
    stats = stats if stats is not None else DeltaStats()
    vi = VisibilityIndex(store, d)
    parts = []
    for oid in d.data_oids:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        idx = np.flatnonzero(vi.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, sign))
    return SignedStream.concat(parts)
