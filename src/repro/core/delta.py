"""Signed delta streams (paper §5.1 "scanning Δ").

``signed_delta(a, b)`` produces the multiset difference visible(b) −
visible(a) as a signed stream, reading **only** objects in the symmetric
difference of the two directories plus tombstone differences on shared
objects — never the full table. This one primitive powers both SNAPSHOT DIFF
(a = left snapshot) and the per-branch change sets of merge (a = common base
revision), including the no-common-base optimization of §5.3 (shared objects
are skipped wholesale).

Stream row fields:
  sign    +1: row visible in b, not in a;  −1: visible in a, not in b
  key_lo/hi   key signature (PK sig; == row sig for NoPK tables)
  row_lo/hi   full row-value signature
  rowid       physical location of the row (payload gather source)

Because objects store per-row signatures, "joining with the base revision to
fetch deleted values" (paper §5.1 step 2) is a direct gather by rowid and is
deferred until a payload is actually output.

Sortedness invariant (ISSUE 2): data objects are sealed key-sorted, so every
emitted per-object run is already in (key_lo, key_hi) order — ForkBase-style
ordered immutable chunks. ``signed_delta`` k-way merges those presorted runs
once (``SignedStream.merge_by_key``) and caches the globally key-sorted
stream; diff aggregation, PK collapse and the merge paths then run sort-free
(``presorted=True``), never rebuilding an order that was free at emission.

The invariant now extends through COMMIT (ISSUE 4): because merged streams
are globally key-sorted, the apply-side producers (merge, revert, publish)
emit their insert rowids as key-ascending pieces and declare that order via
``SigBatch.runs`` — the seal path then reuses the carried order instead of
re-lexsorting, and the carried signatures instead of rehashing. Anyone
changing emission order here is changing what producers may claim there:
the Δ-side ``runs`` rule and the write-side ``SigBatch.runs`` rule are the
same contract (never claim sortedness that isn't real).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..kernels import ops
from . import telemetry
from .directory import Directory
from .objects import DataObject, ObjectStore, pack_rowid
from .visibility import KeyedLRU, visibility_index

SP_SIGNED_DELTA = telemetry.register_span(
    "signed_delta", "build the signed Δ stream for one directory pair")


_FIELDS = ("sign", "key_lo", "key_hi", "row_lo", "row_hi", "rowid")

_RUN0 = np.zeros((1,), np.int64)
_RUN0.setflags(write=False)


@dataclass
class SignedStream:
    """A signed Δ stream with a per-part sortedness invariant.

    ``runs`` (when not None) is an int64 array of run-start offsets
    (``runs[0] == 0``): every run ``[runs[i], runs[i+1])`` is sorted by
    (key_lo, key_hi) — the order data objects are sealed in, so presorted
    emission is free. A single run means the whole stream is key-sorted.
    ``None`` means no ordering is known (the unsorted fallback).

    ``key_is_row`` marks streams whose key signature IS the row signature
    (NoPK emission): for those, key order is value order and diff
    aggregation needs no sort at all.
    """
    sign: np.ndarray      # (n,) int32
    key_lo: np.ndarray    # (n,) uint64
    key_hi: np.ndarray
    row_lo: np.ndarray
    row_hi: np.ndarray
    rowid: np.ndarray     # (n,) uint64
    runs: Optional[np.ndarray] = None
    key_is_row: bool = False

    @property
    def n(self) -> int:
        return int(self.sign.shape[0])

    @property
    def sorted_by_key(self) -> bool:
        """True iff the whole stream is one key-sorted run."""
        return self.runs is not None and self.runs.shape[0] <= 1

    @staticmethod
    def empty() -> "SignedStream":
        z64 = np.zeros((0,), np.uint64)
        return SignedStream(np.zeros((0,), np.int32), z64, z64, z64, z64, z64,
                            runs=np.zeros((0,), np.int64), key_is_row=True)

    @staticmethod
    def concat(parts) -> "SignedStream":
        parts = [p for p in parts if p.n]
        if not parts:
            return SignedStream.empty()
        if len(parts) == 1:
            return parts[0]
        alias = all(p.key_lo is p.row_lo and p.key_hi is p.row_hi
                    for p in parts)
        fields = []
        for f in _FIELDS:
            if alias and f in ("key_lo", "key_hi"):
                fields.append(None)  # patched below from the row arrays
            else:
                fields.append(np.concatenate([getattr(p, f) for p in parts]))
        if alias:
            fields[1], fields[2] = fields[3], fields[4]
        runs = None
        if all(p.runs is not None for p in parts):
            offs, off = [], 0
            for p in parts:
                offs.append((p.runs if p.runs.shape[0] else _RUN0) + off)
                off += p.n
            runs = np.concatenate(offs)
        return SignedStream(*fields, runs=runs,
                            key_is_row=all(p.key_is_row for p in parts))

    def take(self, idx) -> "SignedStream":
        rl, rh = self.row_lo[idx], self.row_hi[idx]
        kl = rl if self.key_lo is self.row_lo else self.key_lo[idx]
        kh = rh if self.key_hi is self.row_hi else self.key_hi[idx]
        return SignedStream(self.sign[idx], kl, kh, rl, rh,
                            self.rowid[idx], key_is_row=self.key_is_row)

    def filter_mask(self, mask: np.ndarray) -> "SignedStream":
        """Subset by boolean mask. Order-preserving, so a fully key-sorted
        stream stays key-sorted (finer run metadata is dropped)."""
        out = self.take(np.flatnonzero(mask))
        if self.sorted_by_key:
            out.runs = _RUN0 if out.n else np.zeros((0,), np.int64)
        return out

    def inverse(self) -> "SignedStream":
        """The algebraic inverse Δ(b→a) of this stream Δ(a→b): same rows,
        flipped signs. Signs do not participate in the sortedness invariant,
        so runs/key aliasing carry over and every field but ``sign`` is
        shared (cache-served streams stay untouched — their arrays are
        read-only and ``-sign`` allocates fresh)."""
        return SignedStream(-self.sign, self.key_lo, self.key_hi,
                            self.row_lo, self.row_hi, self.rowid,
                            runs=self.runs, key_is_row=self.key_is_row)

    def merge_by_key(self, cuts=None) -> "SignedStream":
        """Materialize the globally key-sorted stream: a stable k-way merge
        of the presorted runs (ties keep emission order), falling back to a
        stable 128-bit sort when no run structure is known. Identity when
        already sorted. ``cuts`` (a key-range shard plan from
        ``distributed.sharding.plan_key_cuts``) partitions the merge by key
        range — byte-identical output, per-shard execution."""
        if self.n == 0 or self.sorted_by_key:
            return self
        if self.runs is not None:
            order = ops.merge128_runs(self.key_lo, self.key_hi, self.runs,
                                      cuts=cuts)
        else:
            order = ops._sort128(self.key_lo, self.key_hi)
        out = self.take(order)
        out.runs = _RUN0
        return out


def _emit(obj: DataObject, idx: Optional[np.ndarray],
          sign: int) -> SignedStream:
    """One presorted run from one object. ``idx`` must be ascending row
    offsets (objects are sealed key-sorted, so any ascending subset is a
    key-sorted run); ``idx=None`` emits every row zero-copy — the stream
    fields ARE the object's immutable arrays."""
    key_is_row = obj.key_lo is obj.row_lo
    if idx is None:
        return SignedStream(
            np.full((obj.nrows,), sign, np.int32),
            obj.key_lo, obj.key_hi, obj.row_lo, obj.row_hi, obj.rowids(),
            runs=_RUN0, key_is_row=key_is_row)
    rl, rh = obj.row_lo[idx], obj.row_hi[idx]
    kl = rl if key_is_row else obj.key_lo[idx]
    kh = rh if key_is_row else obj.key_hi[idx]
    return SignedStream(
        np.full((idx.shape[0],), sign, np.int32),
        kl, kh, rl, rh,
        pack_rowid(obj.oid, idx.astype(np.uint64)),
        runs=_RUN0, key_is_row=key_is_row)


class DeltaStats:
    """Instrumentation: how much the Δ-scan actually read (vs. table size)."""

    def __init__(self):
        self.objects_scanned = 0
        self.objects_skipped_shared = 0
        self.rows_scanned = 0
        self.bytes_scanned = 0
        # fresh tombstone-target-array constructions this op triggered
        # (0 on a warm visibility cache — one build per directory version)
        self.visibility_builds = 0
        # signed Δ streams served from the memo instead of re-scanned
        self.delta_cache_hits = 0


class DeltaCache(KeyedLRU):
    """Memo of signed Δ streams keyed by the two directory values.

    Directories and objects are immutable, so ``signed_delta(a, b)`` is a
    pure function of ``(a, b)`` — repeated diffs of the same two directory
    versions (the paper's PR-review / collaborative loops) can reuse the
    stream without touching a single object. LRU-bounded; entries
    referencing a GC'd object are dropped via ``on_delete``."""

    # streams larger than this are cheap to rebuild relative to the memory
    # they would pin (6 u64/i32 arrays + the aggregation memo), and huge
    # deltas are the least likely to be re-diffed — don't cache them
    MAX_CACHED_ROWS = 1_000_000

    def __init__(self, capacity: int = 8):
        super().__init__(capacity)
        self.hits = 0

    @staticmethod
    def _key(a: Directory, b: Directory):
        return (a.data_oids, a.tomb_oids, a.ts,
                b.data_oids, b.tomb_oids, b.ts)

    def get(self, a: Directory, b: Directory):
        s = self.lookup(self._key(a, b))
        if s is not None:
            self.hits += 1
        return s

    def put(self, a: Directory, b: Directory, stream: "SignedStream"):
        if stream.n > self.MAX_CACHED_ROWS:
            return
        for f in _FIELDS:
            getattr(stream, f).setflags(write=False)
        if stream.runs is not None:
            stream.runs.setflags(write=False)
        self.insert(self._key(a, b), stream)

    def on_delete(self, oid: int) -> None:
        self.drop_if(lambda k: oid in k[0] or oid in k[1]
                     or oid in k[3] or oid in k[4])


def signed_delta(store: ObjectStore, a: Directory, b: Directory,
                 stats: DeltaStats | None = None) -> SignedStream:
    stats = stats if stats is not None else DeltaStats()
    with telemetry.span(SP_SIGNED_DELTA):
        o0 = stats.objects_scanned
        s0 = stats.objects_skipped_shared
        r0 = stats.rows_scanned
        n0 = stats.bytes_scanned
        try:
            return _signed_delta(store, a, b, stats)
        finally:
            # fold this call's scan work into the store-level cumulatives
            # (per-call DeltaStats are transient; the tracer and `datagit
            # stats` read the running sums). In a finally so the
            # delta-cache-hit early return is folded too.
            m = store.metrics
            m.add("delta.objects_scanned", stats.objects_scanned - o0)
            m.add("delta.objects_skipped_shared",
                  stats.objects_skipped_shared - s0)
            m.add("delta.rows_scanned", stats.rows_scanned - r0)
            m.add("delta.bytes_scanned", stats.bytes_scanned - n0)


def _signed_delta(store: ObjectStore, a: Directory, b: Directory,
                  stats: DeltaStats) -> SignedStream:
    cache = getattr(store, "delta_cache", None)
    if cache is None:
        cache = store.delta_cache = DeltaCache()
    cached = cache.get(a, b)
    if cached is not None:
        stats.delta_cache_hits += 1
        return cached
    set_a, set_b = set(a.data_oids), set(b.data_oids)
    only_a = sorted(set_a - set_b)
    only_b = sorted(set_b - set_a)
    shared = sorted(set_a & set_b)
    b0 = store.vis_cache.builds if store.vis_cache is not None else 0
    vi_a = visibility_index(store, a)
    vi_b = visibility_index(store, b)
    stats.visibility_builds += store.vis_cache.builds - b0
    parts = []

    for only, vi, sign in ((only_b, vi_b, +1), (only_a, vi_a, -1)):
        for oid in only:
            obj = store.get(oid)
            stats.objects_scanned += 1
            stats.rows_scanned += obj.nrows
            stats.bytes_scanned += int(obj.nbytes)
            if obj.nrows == 0:
                continue
            if vi.fully_visible(obj):
                parts.append(_emit(obj, None, sign))  # zero-copy run
                continue
            idx = np.flatnonzero(vi.visible_mask(obj))
            if idx.shape[0]:
                parts.append(_emit(obj, idx, sign))

    # Shared objects: only rows whose visibility DIFFERS can contribute.
    # The candidates are exactly the tombstone targets of either side within
    # the object (plus ts-horizon differences), so we never materialize the
    # object's full row set unless a tombstone or horizon touches it.
    ts_min = min(a.ts, b.ts)
    for oid in shared:
        obj = store.get(oid)
        # zone pruning: a shared object with no tombstone from either side
        # and every commit_ts within both horizons cannot contribute
        kills_a = vi_a.has_kills(obj)
        kills_b = vi_b.has_kills(obj)
        any_tomb = kills_a or kills_b
        ts_touched = obj.nrows > 0 and obj.ts_zone[1] > ts_min
        if not any_tomb and not ts_touched:
            stats.objects_skipped_shared += 1
            continue
        # candidate offsets only — tombstone targets of either side plus
        # horizon-straddling rows; never the object's full row range
        base = pack_rowid(obj.oid, np.zeros((1,), np.uint64))[0]
        cand_parts = []
        if any_tomb:
            for vi, kills in ((vi_a, kills_a), (vi_b, kills_b)):
                if kills:
                    t = vi.object_targets(oid)
                    cand_parts.append((t - base).astype(np.int64))
        if ts_touched:
            cand_parts.append(np.flatnonzero(
                obj.commit_ts > np.uint64(ts_min)))
        # each part is already sorted & duplicate-free (target slices and
        # flatnonzero results); the common single-part case skips the sort
        cand = (cand_parts[0] if len(cand_parts) == 1
                # lint: sort-ok multi-part candidate dedup is off the
                # dominant single-part path; parts are tiny tombstone sets
                else np.unique(np.concatenate(cand_parts)))
        if cand.shape[0] == 0:
            stats.objects_skipped_shared += 1
            continue
        stats.objects_scanned += 1
        stats.rows_scanned += int(cand.shape[0])
        if not ts_touched and kills_a != kills_b:
            # one-sided tombstones within both horizons (the dominant diff
            # shape): every candidate flips visibility the same way — no
            # per-row visibility probes needed. Rows killed only in b were
            # visible in a (−); rows killed only in a are visible in b (+).
            parts.append(_emit(obj, cand, -1 if kills_b else +1))
            continue
        va = vi_a.visible_rows(obj, cand)
        vb = vi_b.visible_rows(obj, cand)
        plus = cand[vb & ~va]
        minus = cand[va & ~vb]
        if plus.shape[0]:
            parts.append(_emit(obj, plus, +1))
        if minus.shape[0]:
            parts.append(_emit(obj, minus, -1))

    # k-way merge the presorted per-object runs: the cached stream is
    # globally key-sorted, so every consumer aggregates sort-free. Big
    # multi-run streams merge per key-range shard (derived plan, never
    # WAL-logged) — byte-identical order, partition-parallel execution.
    stream = SignedStream.concat(parts)
    cuts = None
    if stream.n and not stream.sorted_by_key and stream.runs is not None:
        from ..distributed.sharding import maybe_key_cuts
        cuts = maybe_key_cuts(stream.key_lo, stream.key_hi, stream.runs)
        if cuts is not None:
            store.metrics.add("probe.shard_parts", cuts[0].shape[0] + 1)
    stream = stream.merge_by_key(cuts=cuts)
    cache.put(a, b, stream)
    return stream


def full_scan_stream(store: ObjectStore, d: Directory, sign: int,
                     stats: DeltaStats | None = None) -> SignedStream:
    """Scan ALL visible rows of a snapshot (the SQL-baseline path, Listing 2)."""
    stats = stats if stats is not None else DeltaStats()
    b0 = store.vis_cache.builds if store.vis_cache is not None else 0
    vi = visibility_index(store, d)
    stats.visibility_builds += store.vis_cache.builds - b0
    parts = []
    for oid in d.data_oids:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        if obj.nrows == 0:
            continue
        if vi.fully_visible(obj):
            parts.append(_emit(obj, None, sign))  # zero-copy run
            continue
        idx = np.flatnonzero(vi.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, sign))
    # presorted runs, deliberately NOT merged here: the SQL-baseline path
    # concatenates two full scans and pays one merge at aggregation time
    return SignedStream.concat(parts)
