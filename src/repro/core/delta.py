"""Signed delta streams (paper §5.1 "scanning Δ").

``signed_delta(a, b)`` produces the multiset difference visible(b) −
visible(a) as a signed stream, reading **only** objects in the symmetric
difference of the two directories plus tombstone differences on shared
objects — never the full table. This one primitive powers both SNAPSHOT DIFF
(a = left snapshot) and the per-branch change sets of merge (a = common base
revision), including the no-common-base optimization of §5.3 (shared objects
are skipped wholesale).

Stream row fields:
  sign    +1: row visible in b, not in a;  −1: visible in a, not in b
  key_lo/hi   key signature (PK sig; == row sig for NoPK tables)
  row_lo/hi   full row-value signature
  rowid       physical location of the row (payload gather source)

Because objects store per-row signatures, "joining with the base revision to
fetch deleted values" (paper §5.1 step 2) is a direct gather by rowid and is
deferred until a payload is actually output.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..kernels import ops
from .directory import Directory
from .objects import DataObject, ObjectStore, pack_rowid
from .visibility import KeyedLRU, visibility_index


@dataclass
class SignedStream:
    sign: np.ndarray      # (n,) int32
    key_lo: np.ndarray    # (n,) uint64
    key_hi: np.ndarray
    row_lo: np.ndarray
    row_hi: np.ndarray
    rowid: np.ndarray     # (n,) uint64

    @property
    def n(self) -> int:
        return int(self.sign.shape[0])

    @staticmethod
    def empty() -> "SignedStream":
        z64 = np.zeros((0,), np.uint64)
        return SignedStream(np.zeros((0,), np.int32), z64, z64, z64, z64, z64)

    @staticmethod
    def concat(parts) -> "SignedStream":
        parts = [p for p in parts if p.n]
        if not parts:
            return SignedStream.empty()
        return SignedStream(*[np.concatenate([getattr(p, f) for p in parts])
                              for f in ("sign", "key_lo", "key_hi",
                                        "row_lo", "row_hi", "rowid")])

    def take(self, idx) -> "SignedStream":
        return SignedStream(self.sign[idx], self.key_lo[idx], self.key_hi[idx],
                            self.row_lo[idx], self.row_hi[idx], self.rowid[idx])


def _emit(obj: DataObject, idx: np.ndarray, sign: int) -> SignedStream:
    return SignedStream(
        np.full((idx.shape[0],), sign, np.int32),
        obj.key_lo[idx], obj.key_hi[idx],
        obj.row_lo[idx], obj.row_hi[idx],
        pack_rowid(obj.oid, idx.astype(np.uint64)))


class DeltaStats:
    """Instrumentation: how much the Δ-scan actually read (vs. table size)."""

    def __init__(self):
        self.objects_scanned = 0
        self.objects_skipped_shared = 0
        self.rows_scanned = 0
        self.bytes_scanned = 0
        # fresh tombstone-target-array constructions this op triggered
        # (0 on a warm visibility cache — one build per directory version)
        self.visibility_builds = 0
        # signed Δ streams served from the memo instead of re-scanned
        self.delta_cache_hits = 0


class DeltaCache(KeyedLRU):
    """Memo of signed Δ streams keyed by the two directory values.

    Directories and objects are immutable, so ``signed_delta(a, b)`` is a
    pure function of ``(a, b)`` — repeated diffs of the same two directory
    versions (the paper's PR-review / collaborative loops) can reuse the
    stream without touching a single object. LRU-bounded; entries
    referencing a GC'd object are dropped via ``on_delete``."""

    # streams larger than this are cheap to rebuild relative to the memory
    # they would pin (6 u64/i32 arrays + the aggregation memo), and huge
    # deltas are the least likely to be re-diffed — don't cache them
    MAX_CACHED_ROWS = 1_000_000

    def __init__(self, capacity: int = 8):
        super().__init__(capacity)
        self.hits = 0

    @staticmethod
    def _key(a: Directory, b: Directory):
        return (a.data_oids, a.tomb_oids, a.ts,
                b.data_oids, b.tomb_oids, b.ts)

    def get(self, a: Directory, b: Directory):
        s = self.lookup(self._key(a, b))
        if s is not None:
            self.hits += 1
        return s

    def put(self, a: Directory, b: Directory, stream: "SignedStream"):
        if stream.n > self.MAX_CACHED_ROWS:
            return
        for f in ("sign", "key_lo", "key_hi", "row_lo", "row_hi", "rowid"):
            getattr(stream, f).setflags(write=False)
        self.insert(self._key(a, b), stream)

    def on_delete(self, oid: int) -> None:
        self.drop_if(lambda k: oid in k[0] or oid in k[1]
                     or oid in k[3] or oid in k[4])


def signed_delta(store: ObjectStore, a: Directory, b: Directory,
                 stats: DeltaStats | None = None) -> SignedStream:
    stats = stats if stats is not None else DeltaStats()
    cache = getattr(store, "delta_cache", None)
    if cache is None:
        cache = store.delta_cache = DeltaCache()
    cached = cache.get(a, b)
    if cached is not None:
        stats.delta_cache_hits += 1
        return cached
    set_a, set_b = set(a.data_oids), set(b.data_oids)
    only_a = sorted(set_a - set_b)
    only_b = sorted(set_b - set_a)
    shared = sorted(set_a & set_b)
    b0 = store.vis_cache.builds if store.vis_cache is not None else 0
    vi_a = visibility_index(store, a)
    vi_b = visibility_index(store, b)
    stats.visibility_builds += store.vis_cache.builds - b0
    parts = []

    for oid in only_b:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        idx = np.flatnonzero(vi_b.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, +1))

    for oid in only_a:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        idx = np.flatnonzero(vi_a.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, -1))

    # Shared objects: only rows whose visibility DIFFERS can contribute.
    # The candidates are exactly the tombstone targets of either side within
    # the object (plus ts-horizon differences), so we never materialize the
    # object's full row set unless a tombstone or horizon touches it.
    ts_min = min(a.ts, b.ts)
    for oid in shared:
        obj = store.get(oid)
        # zone pruning: a shared object with no tombstone from either side
        # and every commit_ts within both horizons cannot contribute
        any_tomb = vi_a.has_kills(obj) or vi_b.has_kills(obj)
        ts_touched = obj.nrows > 0 and obj.ts_zone[1] > ts_min
        if not any_tomb and not ts_touched:
            stats.objects_skipped_shared += 1
            continue
        # candidate offsets only — tombstone targets of either side plus
        # horizon-straddling rows; never the object's full row range
        base = pack_rowid(obj.oid, np.zeros((1,), np.uint64))[0]
        cand_parts = []
        if any_tomb:
            for vi in (vi_a, vi_b):
                t = vi.object_targets(oid)
                if t.shape[0]:
                    cand_parts.append((t - base).astype(np.int64))
        if ts_touched:
            cand_parts.append(np.flatnonzero(
                obj.commit_ts > np.uint64(ts_min)))
        cand = np.unique(np.concatenate(cand_parts))
        if cand.shape[0] == 0:
            stats.objects_skipped_shared += 1
            continue
        stats.objects_scanned += 1
        stats.rows_scanned += int(cand.shape[0])
        va = vi_a.visible_rows(obj, cand)
        vb = vi_b.visible_rows(obj, cand)
        plus = cand[vb & ~va]
        minus = cand[va & ~vb]
        if plus.shape[0]:
            parts.append(_emit(obj, plus, +1))
        if minus.shape[0]:
            parts.append(_emit(obj, minus, -1))

    stream = SignedStream.concat(parts)
    cache.put(a, b, stream)
    return stream


def full_scan_stream(store: ObjectStore, d: Directory, sign: int,
                     stats: DeltaStats | None = None) -> SignedStream:
    """Scan ALL visible rows of a snapshot (the SQL-baseline path, Listing 2)."""
    stats = stats if stats is not None else DeltaStats()
    b0 = store.vis_cache.builds if store.vis_cache is not None else 0
    vi = visibility_index(store, d)
    stats.visibility_builds += store.vis_cache.builds - b0
    parts = []
    for oid in d.data_oids:
        obj = store.get(oid)
        stats.objects_scanned += 1
        stats.rows_scanned += obj.nrows
        stats.bytes_scanned += int(obj.nbytes)
        idx = np.flatnonzero(vi.visible_mask(obj))
        if idx.shape[0]:
            parts.append(_emit(obj, idx, sign))
    return SignedStream.concat(parts)
