"""SNAPSHOT DIFF (paper §3, §5.1).

Two execution paths, benchmarked against each other exactly as the paper
does:

  * ``snapshot_diff``  — the built-in path: Δ-object scan + diff aggregation.
    Cost ∝ changed data.
  * ``sql_diff``       — the Listing-2 SQL-equivalent baseline: full scans of
    both snapshots, UNION ALL with ±1, GROUP BY all columns, HAVING ≠ 0.
    Cost ∝ table size.

Both return the same ``DiffResult``: per surviving value-group, the net count
(diffCnt, <0 ⇒ only in the left snapshot, >0 ⇒ only in the right) plus the
payload. Payload values are gathered lazily by rowid — only for surviving
rows (the paper's "lookup ... only if needed").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..kernels import ops
from . import telemetry
from .delta import DeltaStats, SignedStream, full_scan_stream, signed_delta

SP_DIFF = telemetry.register_span(
    "diff", "SNAPSHOT DIFF: Δ-scan + diff aggregation")
from .directory import Snapshot
from .objects import ObjectStore, rowid_off, rowid_oid
from .schema import CType, Schema, concat_batches, take_batch
from .sigs import SigBatch


@dataclass
class DiffResult:
    """Result of SNAPSHOT DIFF between snapshots (left=a, right=b)."""
    schema: Schema
    diff_cnt: np.ndarray          # (k,) int32 net count per surviving group
    key_lo: np.ndarray            # (k,) uint64 key signature of the group
    key_hi: np.ndarray
    row_lo: np.ndarray            # (k,) uint64 value signature of the group
    row_hi: np.ndarray
    rowid: np.ndarray             # (k,) uint64 representative payload row
    stats: DeltaStats = field(default_factory=DeltaStats)

    @property
    def n_groups(self) -> int:
        return int(self.diff_cnt.shape[0])

    def is_empty(self) -> bool:
        return self.n_groups == 0

    def payload(self, store: ObjectStore) -> Dict[str, np.ndarray]:
        """Gather the representative row values for each surviving group."""
        return gather_payload(store, self.schema, self.rowid)

    def per_key_conflicts(self):
        """Group surviving value-groups by key signature: keys with entries
        from BOTH snapshots are the paper's 'potential conflicts'.

        Vectorized: per-run sign presence via segmented reductions; only
        the (typically few) conflicting runs are materialized."""
        if self.n_groups == 0:
            return []
        # NoPK results are value-sorted and key == value, so the key
        # grouping is free; PK results need the (small) key sort
        order, agg = ops.diff_aggregate(self.key_lo, self.key_hi,
                                        np.ones_like(self.diff_cnt),
                                        presorted=not self.schema.has_pk)
        starts = agg.run_starts
        sg = np.sign(self.diff_cnt[order])
        any_pos = np.add.reduceat((sg > 0).astype(np.int64), starts) > 0
        any_neg = np.add.reduceat((sg < 0).astype(np.int64), starts) > 0
        both = any_pos & any_neg
        return [order[s:s + l]
                for s, l in zip(starts[both], agg.run_lens[both])]


def gather_payload(store: ObjectStore, schema: Schema,
                   rowids: np.ndarray, *, with_sigs: bool = False,
                   runs: Optional[np.ndarray] = None):
    """Materialize full rows by physical rowid (preserves input order).

    ``with_sigs=True`` returns ``(batch, SigBatch)``: the rows' write-once
    row/key signatures and LOB content signatures gathered from the same
    objects — zero hashing — so the batch can be re-sealed verbatim
    (``Txn.insert(..., sigs=...)``). ``runs`` is the CALLER's sortedness
    claim about the ``rowids`` sequence (key-sorted run-start offsets; the
    gather preserves input order, so the claim transfers to the batch) and
    is carried into the sidecar untouched. Never claim runs that aren't
    real — the seal path's order depends on it."""
    n = rowids.shape[0]
    oids = rowid_oid(rowids)
    offs = rowid_off(rowids)
    alias = with_sigs and not schema.has_pk
    lob_names = ([c.name for c in schema.columns if c.ctype is CType.LOB]
                 if with_sigs else [])
    if n and oids[0] == oids[-1] and (oids == oids[0]).all():
        # single-object fast path (the common post-compaction merge shape):
        # every rowid lives in ONE object, so the per-object split, concat
        # and inverse-permutation round-trip all collapse into direct takes
        obj = store.get(int(oids[0]))
        batch = take_batch(obj.cols, offs)
        if not with_sigs:
            return batch
        row_lo, row_hi = obj.row_lo[offs], obj.row_hi[offs]
        if alias:
            key_lo, key_hi = row_lo, row_hi
        else:
            key_lo, key_hi = obj.key_lo[offs], obj.key_hi[offs]
        lob = {c: obj.lob_sigs[c][offs] for c in lob_names}
        return batch, SigBatch(row_lo, row_hi, key_lo, key_hi, lob,
                               runs=runs)
    batches, perm, sig_parts = [], [], []
    for oid in np.unique(oids):
        sel = np.flatnonzero(oids == oid)
        obj = store.get(int(oid))
        o = offs[sel]
        batches.append(take_batch(obj.cols, o))
        perm.append(sel)
        if with_sigs:
            sig_parts.append(
                (obj.row_lo[o], obj.row_hi[o],
                 None if alias else obj.key_lo[o],
                 None if alias else obj.key_hi[o],
                 {c: obj.lob_sigs[c][o] for c in lob_names}))
    if not batches:
        empty = concat_batches(schema, [])
        if not with_sigs:
            return empty
        z64 = np.zeros((0,), np.uint64)
        return empty, SigBatch(z64, z64, z64, z64,
                               {c: z64 for c in lob_names},
                               runs=np.zeros((0,), np.int64))
    merged = concat_batches(schema, batches)
    flat = np.concatenate(perm)
    if flat.shape[0] > 1 and (flat[1:] > flat[:-1]).all():
        # ascending oids ⇒ the per-object concat order IS the input order:
        # skip building (and applying) the inverse permutation entirely
        inv = None
        batch = merged
    else:
        inv = np.empty((n,), np.int64)
        inv[flat] = np.arange(n)
        batch = take_batch(merged, inv)
    if not with_sigs:
        return batch
    reorder = (lambda a: a) if inv is None else (lambda a: a[inv])
    row_lo = reorder(np.concatenate([p[0] for p in sig_parts]))
    row_hi = reorder(np.concatenate([p[1] for p in sig_parts]))
    if alias:
        key_lo, key_hi = row_lo, row_hi
    else:
        key_lo = reorder(np.concatenate([p[2] for p in sig_parts]))
        key_hi = reorder(np.concatenate([p[3] for p in sig_parts]))
    lob = {c: reorder(np.concatenate([p[4][c] for p in sig_parts]))
           for c in lob_names}
    return batch, SigBatch(row_lo, row_hi, key_lo, key_hi, lob, runs=runs)


def gather_rowsigs(store: ObjectStore,
                   rowids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Row-value signatures at physical rowids (preserves input order).

    The Δ-sized value identity probe: two rows are byte-identical iff their
    128-bit row signatures match, so revert's "is the current row still the
    one being reverted away?" check never gathers payloads."""
    oids = rowid_oid(rowids)
    offs = rowid_off(rowids)
    if rowids.shape[0] and oids[0] == oids[-1] and (oids == oids[0]).all():
        obj = store.get(int(oids[0]))  # single-object fast path
        return obj.row_lo[offs], obj.row_hi[offs]
    lo = np.zeros(rowids.shape, np.uint64)
    hi = np.zeros(rowids.shape, np.uint64)
    for oid in np.unique(oids):
        sel = oids == oid
        obj = store.get(int(oid))
        lo[sel] = obj.row_lo[offs[sel]]
        hi[sel] = obj.row_hi[offs[sel]]
    return lo, hi


def _aggregate_stream(schema: Schema, stream: SignedStream,
                      stats: DeltaStats,
                      store: Optional[ObjectStore] = None) -> DiffResult:
    """Diff aggregation: cancel identical changes, keep net per value-group.

    Grouping is by full row signature (Listing-2 multiset semantics),
    executed sort-free along the stream's presorted key order: for NoPK
    streams key == value so the groups are immediate; for PK streams the
    value groups are sub-groups of the (≤ 2-element) per-key runs and only
    the *surviving* groups pay a final sort into the value-signature output
    order. The representative payload rowid per group prefers a + row
    (payload exists in the right snapshot) and falls back to a − row
    (gathered from the left / base objects — the paper's tombstone join)."""
    if stream.n == 0:
        z64 = np.zeros((0,), np.uint64)
        return DiffResult(schema, np.zeros((0,), np.int32),
                          z64, z64, z64, z64, z64, stats)
    # streams served from the delta memo are immutable, so their aggregation
    # is a pure function too: reuse it across repeated diffs of the same
    # directory pair (fields are shared read-only; stats stay per-op)
    memo = getattr(stream, "_agg_memo", None)
    if memo is not None:
        return DiffResult(schema, *memo, stats)
    # key-range sharding (derived plan, never WAL-logged): big streams
    # merge and aggregate per shard — byte-identical to unsharded
    from ..distributed import sharding as ksh
    shards = ksh.key_shard_count(stream.n)
    cuts = None
    if shards > 1 and not stream.sorted_by_key and stream.runs is not None:
        cuts = ksh.plan_key_cuts(stream.key_lo, stream.key_hi,
                                 stream.runs, shards)
        if cuts is not None and store is not None:
            store.metrics.add("probe.shard_parts", cuts[0].shape[0] + 1)
    st = stream.merge_by_key(cuts=cuts)  # always globally key-sorted, n > 0
    _, agg = ops.diff_aggregate_rows(st.key_lo, st.key_hi,
                                     st.row_lo, st.row_hi, st.sign,
                                     presorted=True, shards=shards)
    surviving = agg.run_sums != 0
    if surviving.all():  # pure-churn diffs: nothing cancelled
        keep = slice(None)
        diff_cnt, starts = agg.run_sums, agg.run_starts
    else:
        keep = np.flatnonzero(surviving)
        diff_cnt, starts = agg.run_sums[keep], agg.run_starts[keep]
    # representative rowid: first element in the run whose sign matches the
    # net direction (all elements share the same value, so any matching-sign
    # element's payload is correct). The run head already matches in the
    # overwhelmingly common case (single-element runs, or net in the head's
    # direction); only mismatching runs pay the per-run argmin.
    n = st.n
    want = np.sign(agg.run_sums)
    rep_pos = agg.run_starts.copy()
    bad = np.flatnonzero((st.sign[agg.run_starts] != want)
                         & (agg.run_sums != 0))
    if bad.shape[0]:
        seg, base, flat = ops.segment_expand(agg.run_starts[bad],
                                             agg.run_lens[bad])
        score = np.where(st.sign[flat] == want[bad][seg], flat, n)
        rep_pos[bad] = np.minimum.reduceat(score, base)
    key_lo, key_hi = st.key_lo[starts], st.key_hi[starts]
    row_lo = key_lo if st.row_lo is st.key_lo else st.row_lo[starts]
    row_hi = key_hi if st.row_hi is st.key_hi else st.row_hi[starts]
    fields = [diff_cnt.astype(np.int32), key_lo, key_hi, row_lo, row_hi,
              st.rowid[rep_pos[keep]]]
    if not st.key_is_row and diff_cnt.shape[0] > 1:
        # PK stream: groups surfaced in key order, but the DiffResult
        # contract is value-signature order — sort just the survivors
        # (distinct signatures, so an unstable primary sort is exact)
        fo = ops._sort128(fields[3], fields[4], stable=False)
        fields = [f[fo] for f in fields]
    fields = tuple(fields)
    for a in fields:
        a.setflags(write=False)
    stream._agg_memo = fields
    return DiffResult(schema, *fields, stats)


def snapshot_diff(store: ObjectStore, a: Snapshot, b: Snapshot) -> DiffResult:
    """Built-in SNAPSHOT DIFF: Δ-scan + diff aggregation (paper §5.1)."""
    if not a.schema.compatible_with(b.schema):
        raise ValueError("SNAPSHOT DIFF: snapshots have incompatible schemas")
    with telemetry.span(SP_DIFF):
        stats = DeltaStats()
        stream = signed_delta(store, a.directory, b.directory, stats)
        return _aggregate_stream(a.schema, stream, stats, store)


def sql_diff(store: ObjectStore, a: Snapshot, b: Snapshot) -> DiffResult:
    """Listing-2 baseline: full scan of both snapshots + global aggregation."""
    if not a.schema.compatible_with(b.schema):
        raise ValueError("SNAPSHOT DIFF: snapshots have incompatible schemas")
    stats = DeltaStats()
    stream = SignedStream.concat([
        full_scan_stream(store, a.directory, -1, stats),
        full_scan_stream(store, b.directory, +1, stats),
    ])
    return _aggregate_stream(a.schema, stream, stats, store)
