"""Table: schema + current directory + PITR history + key probes."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..kernels import ops
from .directory import Directory
from .objects import DataObject, pack_rowid
from .schema import Schema, concat_batches, take_batch
from .visibility import VisibilityIndex


class Table:
    def __init__(self, name: str, schema: Schema, store, ts: int):
        self.name = name
        self.schema = schema
        self._store = store
        self.directory = Directory.empty(ts)
        # PITR history: every directory version, trimmed by Engine GC.
        self.history: List[Tuple[int, Directory]] = [(ts, self.directory)]

    # ------------------------------------------------------------- state
    def set_directory(self, d: Directory) -> None:
        self.directory = d
        self.history.append((d.ts, d))

    def directory_at(self, ts: int) -> Directory:
        """PITR: latest directory version with apply-ts <= ts, horizon ts."""
        best = None
        for t, d in self.history:
            if t <= ts:
                best = d
        if best is None:
            raise KeyError(f"no PITR history for {self.name} at ts={ts}")
        return Directory(best.data_oids, best.tomb_oids, ts)

    # -------------------------------------------------------------- scan
    def scan(self, directory: Optional[Directory] = None,
             with_sigs: bool = False):
        """Materialize all visible rows: (batch, rowids[, row_lo, row_hi])."""
        d = directory or self.directory
        vi = VisibilityIndex(self._store, d)
        batches, rowids, rlo, rhi = [], [], [], []
        for oid in d.data_oids:
            obj: DataObject = self._store.get(oid)
            m = vi.visible_mask(obj)
            if not m.any():
                continue
            idx = np.flatnonzero(m)
            batches.append(take_batch(obj.cols, idx))
            rowids.append(pack_rowid(oid, idx.astype(np.uint64)))
            if with_sigs:
                rlo.append(obj.row_lo[idx])
                rhi.append(obj.row_hi[idx])
        batch = concat_batches(self.schema, batches)
        rid = (np.concatenate(rowids) if rowids else np.zeros((0,), np.uint64))
        if with_sigs:
            lo = np.concatenate(rlo) if rlo else np.zeros((0,), np.uint64)
            hi = np.concatenate(rhi) if rhi else np.zeros((0,), np.uint64)
            return batch, rid, lo, hi
        return batch, rid

    def count(self, directory: Optional[Directory] = None) -> int:
        d = directory or self.directory
        vi = VisibilityIndex(self._store, d)
        return int(sum(int(vi.visible_mask(self._store.get(o)).sum())
                       for o in d.data_oids))

    # ------------------------------------------------------------ probes
    def locate_keys(self, key_lo: np.ndarray, key_hi: np.ndarray,
                    directory: Optional[Directory] = None) -> np.ndarray:
        """PK probe: rowid of the visible row per key signature, 0 if absent.

        LSM probe with zone-map pruning; per-object lower_bound via the
        searchsorted kernel. PK uniqueness -> at most one visible match.
        """
        d = directory or self.directory
        vi = VisibilityIndex(self._store, d)
        q = key_lo.shape[0]
        out = np.zeros((q,), np.uint64)
        pending = np.arange(q)
        for oid in reversed(d.data_oids):  # newest objects first
            if pending.shape[0] == 0:
                break
            obj: DataObject = self._store.get(oid)
            if obj.nrows == 0:
                continue
            zmin, zmax = obj.zone
            sel = (key_lo[pending] >= zmin) & (key_lo[pending] <= zmax)
            cand = pending[sel]
            if cand.shape[0] == 0:
                continue
            found = self._probe_object(obj, vi, key_lo[cand], key_hi[cand])
            hit = found != 0
            out[cand[hit]] = found[hit]
            pending = np.concatenate([pending[~sel], cand[~hit]])
        return out

    def _probe_object(self, obj: DataObject, vi: VisibilityIndex,
                      q_lo: np.ndarray, q_hi: np.ndarray) -> np.ndarray:
        """rowids of visible matches of (q_lo, q_hi) in obj (0 = miss)."""
        n = obj.nrows
        vis = vi.visible_mask(obj)
        lb = ops.lower_bound(obj.key_lo, q_lo)
        out = np.zeros(q_lo.shape, np.uint64)
        # fast path: exact hit at the lower bound
        idx = np.minimum(lb, n - 1)
        exact = ((lb < n) & (obj.key_lo[idx] == q_lo)
                 & (obj.key_hi[idx] == q_hi) & vis[idx])
        out[exact] = pack_rowid(obj.oid, idx[exact].astype(np.uint64))
        # slow path: lo64-collision runs or invisible first row — walk the run
        maybe = np.flatnonzero((lb < n) & ~exact & (obj.key_lo[idx] == q_lo))
        for qi in maybe:
            i = int(lb[qi])
            while i < n and obj.key_lo[i] == q_lo[qi]:
                if obj.key_hi[i] == q_hi[qi] and vis[i]:
                    out[qi] = pack_rowid(obj.oid, np.asarray([i], np.uint64))[0]
                    break
                i += 1
        return out

    def locate_rowsig_multi(self, sig_lo: np.ndarray, sig_hi: np.ndarray,
                            need: np.ndarray,
                            directory: Optional[Directory] = None
                            ) -> List[np.ndarray]:
        """NoPK probe: up to ``need[i]`` visible rowids per row-signature.

        Used by merge to delete k rows among duplicates (paper §3 NoPK
        cardinality resolution).
        """
        d = directory or self.directory
        vi = VisibilityIndex(self._store, d)
        found: List[List[int]] = [[] for _ in range(sig_lo.shape[0])]
        remaining = need.astype(np.int64).copy()
        for oid in reversed(d.data_oids):
            if not (remaining > 0).any():
                break
            obj: DataObject = self._store.get(oid)
            if obj.nrows == 0:
                continue
            vis = vi.visible_mask(obj)
            lb = ops.lower_bound(obj.key_lo, sig_lo)
            for qi in np.flatnonzero(remaining > 0):
                i = int(lb[qi])
                while (i < obj.nrows and obj.key_lo[i] == sig_lo[qi]
                       and remaining[qi] > 0):
                    if obj.key_hi[i] == sig_hi[qi] and vis[i]:
                        found[qi].append(int(pack_rowid(
                            obj.oid, np.asarray([i], np.uint64))[0]))
                        remaining[qi] -= 1
                    i += 1
        return [np.asarray(f, np.uint64) for f in found]
