"""Table: schema + current directory + PITR history + key probes."""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..kernels import ops
from .directory import Directory
from .objects import DataObject, pack_rowid
from .schema import CType, Schema, concat_batches, take_batch
from .sigs import SigBatch
from .visibility import visibility_index


class Table:
    def __init__(self, name: str, schema: Schema, store, ts: int):
        self.name = name
        self.schema = schema
        self._store = store
        self.directory = Directory.empty(ts)
        # PITR history: every directory version, trimmed by Engine GC.
        # Kept sorted by apply-ts (see _history_append) so directory_at is
        # a bisect, not a linear scan.
        self.history: List[Tuple[int, Directory]] = [(ts, self.directory)]

    # ------------------------------------------------------------- state
    def _history_append(self, d: Directory) -> None:
        """Append a directory version, keeping history sorted by ts.

        An out-of-order apply-ts (RESTORE to an older snapshot) shadows
        every existing entry with ts >= its own — those entries could never
        be returned by directory_at again (the restored version is applied
        later and wins any horizon that admits them) — so they are pruned,
        preserving linear-scan semantics exactly."""
        while self.history and self.history[-1][0] >= d.ts:
            self.history.pop()
        self.history.append((d.ts, d))

    def set_directory(self, d: Directory) -> None:
        old = self.directory
        self.directory = d
        self._history_append(d)
        # incremental visibility maintenance: derive the new version's
        # tombstone-target array from the parent's (sorted merge of the
        # freshly sealed batches) instead of re-sorting the world
        cache = self._store.vis_cache
        if cache is not None:
            cache.extend(old, d)

    def trim_history(self, retention: int, pinned_ts=()) -> int:
        """Trim PITR history to the trailing ``retention`` versions while
        keeping every entry still needed to serve ``directory_at`` of a
        pinned horizon (open PR bases, lineage snapshots, branch points).

        For each pin the *latest* entry with apply-ts <= pin survives — the
        one ``directory_at(pin)`` resolves to — so a pinned horizon can
        never be collected out from under its holder. Returns the number of
        entries pruned.

        ``retention <= 0`` keeps everything (the pre-existing
        ``history[-0:]`` semantics of Engine(retention_versions=0))."""
        n = len(self.history)
        if retention <= 0 or n <= retention:
            return 0
        keep = set(range(n - retention, n))
        for ts in pinned_ts:
            i = bisect.bisect_right(self.history, ts, key=lambda e: e[0])
            if i > 0:
                keep.add(i - 1)
        kept = [self.history[i] for i in sorted(keep)]
        pruned = n - len(kept)
        self.history = kept
        return pruned

    def directory_at(self, ts: int) -> Directory:
        """PITR: latest directory version with apply-ts <= ts, horizon ts."""
        i = bisect.bisect_right(self.history, ts, key=lambda e: e[0])
        if i == 0:
            raise KeyError(f"no PITR history for {self.name} at ts={ts}")
        best = self.history[i - 1][1]
        return Directory(best.data_oids, best.tomb_oids, ts)

    # -------------------------------------------------------------- scan
    def scan(self, directory: Optional[Directory] = None,
             with_sigs: bool = False):
        """Materialize all visible rows: (batch, rowids[, row_lo, row_hi])."""
        if with_sigs:
            batch, rid, sigs = self._scan_walk(directory, carry=True)
            return batch, rid, sigs.row_lo, sigs.row_hi
        batch, rid, _ = self._scan_walk(directory, carry=False)
        return batch, rid

    def scan_carry(self, directory: Optional[Directory] = None):
        """Materialize all visible rows WITH their signature sidecar.

        Returns (batch, rowids, SigBatch): row/key signature lanes and LOB
        content signatures gathered straight from the sealed objects (zero
        hashing), plus ``runs`` offsets — every object's visible subset is
        an ascending slice of a key-sorted object, i.e. one presorted run.
        Feeding the result into ``Txn.insert(..., sigs=...)`` re-seals the
        rows without rehashing (clone materialization, ALTER rewrites)."""
        return self._scan_walk(directory, carry=True)

    def _scan_walk(self, directory: Optional[Directory], carry: bool):
        """The one visibility walk behind every scan variant. ``carry``
        additionally collects the signature sidecar (returned third slot
        is a SigBatch; None otherwise)."""
        d = directory or self.directory
        vi = visibility_index(self._store, d)
        alias = not self.schema.has_pk
        lob_names = ([c.name for c in self.schema.columns
                      if c.ctype is CType.LOB] if carry else [])
        batches, rowids, rlo, rhi, klo, khi = [], [], [], [], [], []
        lob = {c: [] for c in lob_names}
        runs, off = [], 0
        for oid in d.data_oids:
            obj: DataObject = self._store.get(oid)
            if obj.nrows == 0:
                continue
            if vi.fully_visible(obj):
                # zone-pruned objects contribute their immutable arrays
                # directly — no mask, no gather (concat copies once below)
                idx = None
            else:
                m = vi.visible_mask(obj)
                if not m.any():
                    continue
                idx = np.flatnonzero(m)
            take = (lambda a: a) if idx is None else (lambda a: a[idx])
            batches.append(obj.cols if idx is None
                           else take_batch(obj.cols, idx))
            rowids.append(obj.rowids() if idx is None
                          else pack_rowid(oid, idx.astype(np.uint64)))
            if not carry:
                continue
            rlo.append(take(obj.row_lo))
            rhi.append(take(obj.row_hi))
            if not alias:
                klo.append(take(obj.key_lo))
                khi.append(take(obj.key_hi))
            for c in lob_names:
                lob[c].append(take(obj.lob_sigs[c]))
            runs.append(off)
            off += rlo[-1].shape[0]
        batch = concat_batches(self.schema, batches)
        z64 = np.zeros((0,), np.uint64)
        rid = np.concatenate(rowids) if rowids else z64
        if not carry:
            return batch, rid, None
        row_lo = np.concatenate(rlo) if rlo else z64
        row_hi = np.concatenate(rhi) if rhi else z64
        if alias:
            key_lo, key_hi = row_lo, row_hi
        else:
            key_lo = np.concatenate(klo) if klo else z64
            key_hi = np.concatenate(khi) if khi else z64
        sigs = SigBatch(
            row_lo, row_hi, key_lo, key_hi,
            {c: (np.concatenate(v) if v else z64) for c, v in lob.items()},
            runs=np.asarray(runs, np.int64))
        return batch, rid, sigs

    def count(self, directory: Optional[Directory] = None) -> int:
        d = directory or self.directory
        vi = visibility_index(self._store, d)
        return int(sum(vi.visible_count(self._store.get(o))
                       for o in d.data_oids))

    # ------------------------------------------------------------ probes
    def locate_keys(self, key_lo: np.ndarray, key_hi: np.ndarray,
                    directory: Optional[Directory] = None) -> np.ndarray:
        """PK probe: rowid of the visible row per key signature, 0 if absent.

        LSM probe with zone-map pruning; per-object fused ``ops.probe128``
        pass. PK uniqueness -> at most one visible match. Query batches
        should arrive sorted by (key_lo, key_hi) — the fused-probe contract
        (ROADMAP §Performance); the merge planner's batches are run starts
        of key-sorted streams, so this is free for the hot callers.
        """
        d = directory or self.directory
        vi = visibility_index(self._store, d)
        q = key_lo.shape[0]
        m = self._store.metrics
        m.add("probe.queries", q)
        out = np.zeros((q,), np.uint64)
        # sorted queries (the hot-caller contract) turn each object's zone
        # filter into two binary searches + one unresolved scan over the
        # window, instead of full-length masks per object
        srt = q > 1 and bool((key_lo[1:] >= key_lo[:-1]).all())
        pending = None if srt else np.arange(q)
        for oid in reversed(d.data_oids):  # newest objects first
            if pending is not None and pending.shape[0] == 0:
                break
            obj: DataObject = self._store.get(oid)
            if obj.nrows == 0:
                continue
            zmin, zmax = obj.zone
            if srt:
                a = int(np.searchsorted(key_lo, zmin, side="left"))
                b = int(np.searchsorted(key_lo, zmax, side="right"))
                cand = (a + np.flatnonzero(out[a:b] == 0) if b > a
                        else np.zeros((0,), np.int64))
            else:
                sel = (key_lo[pending] >= zmin) & (key_lo[pending] <= zmax)
                cand = pending[sel]
            if cand.shape[0] == 0:
                m.add("probe.objects_pruned")
                continue
            found = self._probe_object(obj, vi, key_lo[cand], key_hi[cand])
            hit = found != 0
            m.add("probe.hits", int(hit.sum()))
            out[cand[hit]] = found[hit]
            if not srt:
                pending = np.concatenate([pending[~sel], cand[~hit]])
        return out

    def _probe_object(self, obj: DataObject, vi,
                      q_lo: np.ndarray, q_hi: np.ndarray) -> np.ndarray:
        """rowids of visible matches of (q_lo, q_hi) in obj (0 = miss).

        One fused ``ops.probe128`` pass hands every query its exact-key run
        ``[start, start + cnt)`` directly — no lower/upper-bound pair on
        the lo64 word, no lo64-collision-run expansion. Run heads that are
        visible resolve immediately (the overwhelmingly common case); only
        runs with an invisible head AND length > 1 expand, and the
        expansion covers exactly-equal keys only."""
        n = obj.nrows
        self._store.metrics.add("probe.objects_probed")
        out = np.zeros(q_lo.shape, np.uint64)
        start, cnt = ops.probe128(obj.key_lo, obj.key_hi, q_lo, q_hi)
        hit = cnt > 0
        if not hit.any():
            return out
        vis = vi.visible_mask(obj)
        head = hit & vis[np.minimum(start, n - 1)]
        out[head] = pack_rowid(obj.oid, start[head].astype(np.uint64))
        deep = np.flatnonzero(hit & ~head & (cnt > 1))
        if deep.shape[0]:
            self._store.metrics.add("probe.expansions", int(deep.shape[0]))
            seg, base, flat = ops.segment_expand(start[deep] + 1,
                                                 cnt[deep] - 1)
            first = np.minimum.reduceat(np.where(vis[flat], flat, n), base)
            found = first < n
            out[deep[found]] = pack_rowid(obj.oid,
                                          first[found].astype(np.uint64))
        return out

    def locate_rowsig_multi(self, sig_lo: np.ndarray, sig_hi: np.ndarray,
                            need: np.ndarray,
                            directory: Optional[Directory] = None,
                            *, flat: bool = False):
        """NoPK probe: up to ``need[i]`` visible rowids per row-signature.

        Used by merge to delete k rows among duplicates (paper §3 NoPK
        cardinality resolution). Vectorized: per object, one fused
        ``ops.probe128`` pass hands every still-needy signature its
        exact-key run; only genuine duplicate runs expand (over equal keys
        only — never whole lo64-collision runs), matches are ranked within
        their query segment by a cumulative count and the first
        ``remaining`` of them taken — no nested per-row Python loop.

        ``flat=True`` returns one query-ordered rowid array (exactly the
        concatenation of the per-query buckets), skipping the Python-level
        per-query split — use it when the caller treats all hits alike."""
        d = directory or self.directory
        vi = visibility_index(self._store, d)
        q = sig_lo.shape[0]
        m = self._store.metrics
        m.add("probe.queries", q)
        part_rows: List[np.ndarray] = []   # flat (rowid, query) accumulation
        part_qids: List[np.ndarray] = []
        remaining = need.astype(np.int64).copy()
        # sorted queries: zone windows by binary search (see locate_keys)
        srt = q > 1 and bool((sig_lo[1:] >= sig_lo[:-1]).all())
        for oid in reversed(d.data_oids):
            obj: DataObject = self._store.get(oid)
            if obj.nrows == 0:
                continue
            zmin, zmax = obj.zone
            if srt:
                a = int(np.searchsorted(sig_lo, zmin, side="left"))
                b = int(np.searchsorted(sig_lo, zmax, side="right"))
                act = (a + np.flatnonzero(remaining[a:b] > 0) if b > a
                       else np.zeros((0,), np.int64))
            else:
                act = np.flatnonzero(remaining > 0)
                if act.shape[0] == 0:
                    break
                act = act[(sig_lo[act] >= zmin) & (sig_lo[act] <= zmax)]
            if act.shape[0] == 0:
                m.add("probe.objects_pruned")
                continue
            m.add("probe.objects_probed")
            start, lens = ops.probe128(obj.key_lo, obj.key_hi,
                                       sig_lo[act], sig_hi[act])
            nz = lens > 0
            act, start, lens = act[nz], start[nz], lens[nz]
            if act.shape[0] == 0:
                continue
            vis = vi.visible_mask(obj)
            if bool((lens == 1).all()):
                # unique signatures (the overwhelmingly common case): the
                # run IS its head — no expansion, no rank machinery
                ok = vis[start]
                hit_off = start[ok]
                if hit_off.shape[0]:
                    m.add("probe.hits", int(hit_off.shape[0]))
                    part_rows.append(pack_rowid(obj.oid,
                                                hit_off.astype(np.uint64)))
                    part_qids.append(act[ok])
                    remaining[act[ok]] -= 1
                continue
            m.add("probe.expansions", int((lens > 1).sum()))
            seg, base, offs = ops.segment_expand(start, lens)
            match = vis[offs].astype(np.int64)  # keys equal by construction
            # rank of each match within its query segment (1-based)
            cm = np.cumsum(match)
            seg_base = cm[base] - match[base]
            rank = cm - seg_base[seg]
            take = (match > 0) & (rank <= remaining[act][seg])
            taken = np.flatnonzero(take)
            if taken.shape[0]:
                m.add("probe.hits", int(taken.shape[0]))
                part_rows.append(pack_rowid(obj.oid,
                                            offs[taken].astype(np.uint64)))
                part_qids.append(act[seg[taken]])
            remaining[act] -= np.add.reduceat(take.astype(np.int64), base)
        # bucket the flat hits per query in one pass (stable by discovery
        # order: newest object first, ascending offset within object)
        empty = np.zeros((0,), np.uint64)
        if not part_rows:
            return empty if flat else [empty] * q
        rows = np.concatenate(part_rows)
        qids = np.concatenate(part_qids)
        order = np.argsort(qids, kind="stable")
        rows, qids = rows[order], qids[order]
        if flat:
            return rows
        found = [empty] * q
        cuts = np.flatnonzero(qids[1:] != qids[:-1]) + 1
        heads = np.concatenate([[0], cuts])
        for qi, part in zip(qids[heads], np.split(rows, cuts)):
            found[qi] = part
        return found
