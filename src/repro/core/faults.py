"""Deterministic fault injection (ISSUE 6): crash points + corruption.

The durability story of this repo — WAL append, the CLI's framed store
writes, the two phases of ``Engine._commit``, publish/revert/GC/compaction
— is only as good as its behavior when the process dies half way through.
This module makes "half way through" a first-class, deterministic place:

* every durability-critical seam calls :func:`crash_point` with a name
  REGISTERED at import time (:func:`register`), so tests can enumerate
  every seam (``registered()``) and kill the process at each one in turn;
* a :class:`FaultPlan` arms the registry: ``FaultPlan.at(name, n)`` trips
  the *n*-th hit of ``name``, raising :class:`InjectedCrash`;
* :class:`InjectedCrash` subclasses ``BaseException`` (like
  ``KeyboardInterrupt``) so no ``except Exception`` handler on the way out
  can "gracefully recover" the simulated kill — recovery must come from
  the durable state alone, which is exactly what the crash sweep asserts;
* :func:`flip_bit` / :func:`truncate_file` inject storage corruption into
  store files, and :func:`corrupt_object_bit` flips a bit inside a sealed
  in-memory object — the integrity layer (CRC frames, ``core.fsck``) must
  report each as a typed error, never a silent wrong answer.

Cost when disarmed: ``crash_point`` is one global load + ``is None`` test
+ return — no registry lookup, no allocation. Hot paths stay at parity
(the bench guard pins this); still, never put a crash point inside a
per-row loop: seams are per *operation*, not per row.
"""
from __future__ import annotations

import os
from collections import Counter
from contextlib import contextmanager
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = [
    "InjectedCrash", "FaultPlan", "register", "registered", "crash_point",
    "inject", "flip_bit", "truncate_file", "corrupt_object_bit",
]


class InjectedCrash(BaseException):
    """The simulated ``kill -9``: raised by a tripped crash point.

    A ``BaseException`` on purpose — generic ``except Exception`` cleanup
    handlers must not swallow it, exactly as they would not run under a
    real crash. Tests catch it by name."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected crash at {point!r} (hit #{hit})")
        self.point = point
        self.hit = hit


#: name -> human description of the seam. Populated at import time by the
#: modules that own the seams; the crash sweep derives its coverage from it.
_REGISTRY: Dict[str, str] = {}

#: the armed plan (None = disarmed). One slot, module-global: arming is a
#: test-harness operation, not a concurrency feature.
_ACTIVE: Optional["FaultPlan"] = None


def register(name: str, doc: str) -> str:
    """Register a crash-point name at import time; returns the name so the
    owning module can bind it to a constant. Re-registration with the same
    doc is a no-op (module reimport); with a different doc it is a bug."""
    if _REGISTRY.get(name, doc) != doc:
        raise ValueError(f"crash point {name!r} registered twice "
                         "with different docs")
    _REGISTRY[name] = doc
    return name


def registered() -> Dict[str, str]:
    """Every registered crash point (name -> doc), for sweep enumeration."""
    return dict(_REGISTRY)


def crash_point(name: str) -> None:
    """Durability seam marker: no-op unless a FaultPlan is armed."""
    if _ACTIVE is None:
        return
    _ACTIVE._hit(name)


class FaultPlan:
    """Trip-on-Nth-hit plan over registered crash points.

    ``trips`` maps crash-point name -> 1-based hit count at which to raise.
    ``hits`` counts every observation while armed (tripped or not), so a
    sweep can assert its op script actually reached each seam."""

    def __init__(self, trips: Optional[Dict[str, int]] = None):
        self.trips: Dict[str, int] = dict(trips or {})
        for name, n in self.trips.items():
            if name not in _REGISTRY:
                raise KeyError(f"unknown crash point {name!r} "
                               f"(registered: {sorted(_REGISTRY)})")
            if n < 1:
                raise ValueError(f"trip count for {name!r} is 1-based")
        self.hits: Counter = Counter()
        self.tripped: Optional[str] = None

    @classmethod
    def at(cls, name: str, n: int = 1) -> "FaultPlan":
        return cls({name: n})

    def _hit(self, name: str) -> None:
        if name not in _REGISTRY:
            raise KeyError(f"crash_point({name!r}) is not registered")
        self.hits[name] += 1
        n = self.trips.get(name)
        if n is not None and self.hits[name] == n and self.tripped is None:
            self.tripped = name
            raise InjectedCrash(name, n)


@contextmanager
def inject(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Arm ``plan`` for the duration of the block (no nesting)."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a FaultPlan is already armed")
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None


# --------------------------------------------------------------------------
# corruption injectors — storage-level bit rot, deterministically placed
# --------------------------------------------------------------------------

def flip_bit(path: str, byte_offset: int, bit: int = 0) -> None:
    """Flip one bit of a file in place (single-bit storage corruption)."""
    size = os.path.getsize(path)
    if not 0 <= byte_offset < size:
        raise ValueError(f"offset {byte_offset} outside file of {size} bytes")
    with open(path, "r+b") as f:
        f.seek(byte_offset)
        b = f.read(1)[0]
        f.seek(byte_offset)
        f.write(bytes([b ^ (1 << (bit & 7))]))


def truncate_file(path: str, size: int) -> None:
    """Cut a file at ``size`` bytes (a torn write / lost tail)."""
    with open(path, "r+b") as f:
        f.truncate(size)


def corrupt_object_bit(obj, column: Optional[str] = None, row: int = 0,
                       bit: int = 0) -> None:
    """Flip one bit inside a sealed object's payload (in-memory bit rot).

    ``column=None`` corrupts the first fixed-width column; a LOB column
    corrupts one byte of the row's value. The object's carried signatures
    are left untouched — ``core.fsck`` must flag the mismatch."""
    if column is None:
        column = next(c for c, a in obj.cols.items() if a.dtype != object)
    # mutate a writable COPY and swing the lane pointer: under
    # REPRO_SANITIZE=1 the sealed arrays themselves are frozen, and the
    # injector must plant bit rot without tripping the sanitizer it is
    # there to exercise
    arr = obj.cols[column].copy()
    if arr.dtype == object:                      # LOB: mutate one byte
        v = bytearray(arr[row])
        v[0] ^= 1 << (bit & 7)
        arr[row] = bytes(v)
    else:
        flat = arr.view(np.uint8).reshape(-1)
        flat[row * arr.dtype.itemsize] ^= np.uint8(1 << (bit & 7))
    # lint: seal-ok deliberate corruption injector — swaps in a rotted
    # copy so fsck/CRC layers can be tested against in-memory bit flips
    obj.cols[column] = arr
