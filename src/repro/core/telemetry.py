"""Operation telemetry (ISSUE 8): spans + a unified metrics registry.

The engine has accumulated a pile of disconnected counters —
``DeltaStats``, ``CommitStats``, ``GCStats``, the visibility-cache
build/extend/derive tallies, delta-cache hits — with no timings, no
per-operation attribution, and no user-facing surface. This module
unifies them:

* a **metric registry**: every counter the engine exposes is registered
  here under a stable dotted name (``commit.rows_rehashed``,
  ``vis.builds``, ``wal.fsyncs``…), and :func:`metrics_snapshot` reads
  them all into one flat dict with a *fixed key set* — the key set IS
  the schema that ``datagit stats --format json`` pins;
* a **span tracer**: ``with trace(engine) as t:`` arms a module-global
  tracer; instrumented operations call ``with span("name"):`` and the
  tracer records monotonic wall-time plus the delta of every registered
  counter across the span. Nesting follows the call stack (``diff`` →
  ``signed_delta`` → ``visibility.build``), so a span tree is a profile
  of one operation with its costs attributed;
* **exports**: a text renderer for ``EXPLAIN`` (span tree + counter
  deltas, with zero-valued siblings of any touched counter group shown
  so invariants like ``commit.rows_rehashed=0`` are *visible*, not just
  absent), and a Chrome-tracing/Perfetto event stream for
  ``datagit --trace out.jsonl``.

Two design rules keep telemetry out of the durability story:

* **spans never enter the WAL** — the clock lives here and only here;
  WAL-logged functions may *open* spans (the ``with`` is a no-op when
  disarmed and the timing never lands in a payload) but must not read
  clocks themselves. The ``wal-hygiene`` lint enforces this with a
  telemetry-module allowlist: this is the one ``repro.core`` module
  allowed to call ``time.perf_counter``.
* **traces are derived state, never durable state** — ``Engine.replay``
  ends with ``reset_metrics()``, so a recovered engine reports a clean
  registry and zero spans; nothing here is pickled.

Cost when disarmed mirrors ``faults.crash_point``: ``span()`` is one
global load + ``is None`` test returning a singleton no-op context
manager. Spans mark *operations*, not rows — never open one inside a
per-row loop (the interleaved A/B bench pins hot-path parity).
"""
from __future__ import annotations

import json
from contextlib import contextmanager
from time import perf_counter
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "register_span", "register_metric", "registered_spans",
    "registered_metrics", "Metrics", "metrics_snapshot", "stats_json",
    "Span", "Tracer", "span", "trace", "current", "render_spans",
    "chrome_trace_events", "write_chrome_trace", "STATS_SCHEMA",
]

#: version of the ``stats_json`` document (bumped on any key change, like
#: the LINT report's ``schema: 1``). 2: the probe.* counter group
#: (fused key probes + key-range shard plans, ISSUE 9). 3: the store.*
#: counter group (tiered pack store + remotes, ISSUE 10).
STATS_SCHEMA = 3

#: span name -> human description. Populated at import time by the modules
#: that own the operations, exactly like the crash-point registry.
_SPANS: Dict[str, str] = {}

#: metric name -> human description. Registered HERE (below) rather than at
#: the owning modules so the full key set — the stats JSON schema — reads
#: in one place.
_METRICS: Dict[str, str] = {}


def _register(registry: Dict[str, str], kind: str, name: str,
              doc: str) -> str:
    if registry.get(name, doc) != doc:
        raise ValueError(f"{kind} {name!r} registered twice "
                         "with different docs")
    registry[name] = doc
    return name


def register_span(name: str, doc: str) -> str:
    """Register a span name at import time; returns the name so the owning
    module can bind it to a constant. Re-registration with the same doc is
    a no-op (module reimport); with a different doc it is a bug."""
    return _register(_SPANS, "span", name, doc)


def register_metric(name: str, doc: str) -> str:
    """Register a dotted metric name (same semantics as crash points)."""
    return _register(_METRICS, "metric", name, doc)


def registered_spans() -> Dict[str, str]:
    return dict(_SPANS)


def registered_metrics() -> Dict[str, str]:
    return dict(_METRICS)


# --------------------------------------------------------------------------
# the metric name table — the one place the stats schema is defined
# --------------------------------------------------------------------------

for _n, _d in (
    ("delta.objects_scanned", "objects visited by signed_delta"),
    ("delta.objects_skipped_shared", "objects skipped as shared lineage"),
    ("delta.rows_scanned", "rows materialized while building deltas"),
    ("delta.bytes_scanned", "payload bytes touched while building deltas"),
    ("commit.rows_rehashed", "rows whose signatures were recomputed at seal"),
    ("commit.rows_carried", "rows whose signatures were carried (zero-rehash)"),
    ("commit.lob_rows_hashed", "LOB rows hashed at seal"),
    ("commit.apply_sorts", "full lexsorts paid at seal"),
    ("commit.apply_sort_merged", "seals that merged presorted runs"),
    ("commit.apply_sort_skipped", "seals that skipped sorting entirely"),
    ("vis.builds", "visibility entries built from scratch"),
    ("vis.extends", "visibility entries extended in place"),
    ("vis.derives", "visibility entries derived from a cached ancestor"),
    ("vis.hits", "visibility-cache lookups"),
    ("cache.delta_hits", "signed-delta streams served from the delta cache"),
    ("wal.frames", "WAL records appended"),
    ("wal.bytes", "bytes written to the durable store"),
    ("wal.fsyncs", "fsync calls on the durable store"),
    ("gc.objects_freed", "objects swept by gc"),
    ("gc.versions_pruned", "table versions pruned by gc"),
    ("gc.pinned_horizons", "versions kept alive by pins at last gc"),
    ("probe.queries", "key/rowsig signatures submitted to the probe paths"),
    ("probe.objects_probed", "sealed objects probed by the fused kernel"),
    ("probe.objects_pruned", "objects skipped entirely by zone maps"),
    ("probe.hits", "probe queries resolved to a visible rowid"),
    ("probe.expansions", "equal-key runs expanded past their head"),
    ("probe.shard_parts", "key-range shard partitions merged"),
    ("store.hits", "object gets served from the heap tier (packs attached)"),
    ("store.faults", "objects faulted in from the pack tier on get"),
    ("store.spills", "objects spilled to the pack tier"),
    ("store.evictions", "heap-tier entries evicted to the pack tier"),
    ("store.bytes_packed", "pack-blob bytes freshly written to disk"),
    ("store.objects_pushed", "pack objects shipped to a remote by push"),
    ("store.objects_pulled", "pack objects fetched from a remote"),
):
    register_metric(_n, _d)


class Metrics:
    """A cumulative counter bag (attached to ``ObjectStore`` as
    ``store.metrics``) for counters that have no natural home object —
    the delta.* and gc.* totals, whose per-call stats objects are
    transient."""

    __slots__ = ("counters",)

    def __init__(self):
        self.counters: Dict[str, int] = {}

    def add(self, name: str, n: int = 1) -> None:
        if n:
            self.counters[name] = self.counters.get(name, 0) + n

    def reset(self) -> None:
        self.counters.clear()


def metrics_snapshot(engine) -> Dict[str, int]:
    """One flat dict of every registered metric for ``engine``.

    Every registered name is present (zero-defaulted) so the key set is
    stable — it IS the ``datagit stats`` JSON schema. ``engine=None``
    yields all zeros (a tracer armed before the store is loaded)."""
    snap = {name: 0 for name in _METRICS}
    if engine is None:
        return snap
    cs = engine.commit_stats
    snap["commit.rows_rehashed"] = cs.rows_rehashed
    snap["commit.rows_carried"] = cs.rows_carried
    snap["commit.lob_rows_hashed"] = cs.lob_rows_hashed
    snap["commit.apply_sorts"] = cs.apply_sorts
    snap["commit.apply_sort_merged"] = cs.apply_sort_merged
    snap["commit.apply_sort_skipped"] = cs.apply_sort_skipped
    store = engine.store
    vc = store.vis_cache
    if vc is not None:
        snap["vis.builds"] = vc.builds
        snap["vis.extends"] = vc.extends
        snap["vis.derives"] = vc.derives
        snap["vis.hits"] = vc.hits
    dc = store.delta_cache
    if dc is not None:
        snap["cache.delta_hits"] = dc.hits
    w = engine.wal
    snap["wal.frames"] = w.frames
    snap["wal.bytes"] = w.bytes_written
    snap["wal.fsyncs"] = w.fsyncs
    for name, v in store.metrics.counters.items():
        snap[name] = v
    return snap


def stats_json(engine) -> Dict[str, Any]:
    """The pinned ``datagit stats --format json`` document."""
    return {"schema": STATS_SCHEMA,
            "metrics": dict(sorted(metrics_snapshot(engine).items()))}


# --------------------------------------------------------------------------
# spans
# --------------------------------------------------------------------------

class Span:
    """One timed operation: monotonic duration + counter deltas + children.

    Created armed-path only (``span()`` returns the no-op singleton when
    no tracer is active). Counter deltas are ``snapshot_at_exit -
    snapshot_at_enter`` over the union of keys, so a tracer whose engine
    was bound mid-flight (the CLI arms before the store loads) still
    renders — pre-bind baselines are simply all zeros."""

    __slots__ = ("name", "tracer", "t0_rel", "dur_s", "counters",
                 "children", "_base", "_t0")

    def __init__(self, name: str, tracer: "Tracer"):
        self.name = name
        self.tracer = tracer
        self.t0_rel = 0.0
        self.dur_s = 0.0
        self.counters: Dict[str, int] = {}
        self.children: List["Span"] = []

    def __enter__(self) -> "Span":
        tr = self.tracer
        parent = tr._stack[-1] if tr._stack else None
        (parent.children if parent is not None else tr.roots).append(self)
        tr._stack.append(self)
        self._base = metrics_snapshot(tr.engine)
        self._t0 = perf_counter()
        self.t0_rel = self._t0 - tr.t0
        return self

    def __exit__(self, *exc) -> bool:
        self.dur_s = perf_counter() - self._t0
        base = self._base
        now = metrics_snapshot(self.tracer.engine)
        deltas = {}
        for k in now.keys() | base.keys():
            d = now.get(k, 0) - base.get(k, 0)
            if d:
                deltas[k] = d
        self.counters = deltas
        self.tracer._stack.pop()
        return False


class _NullSpan:
    """The disarmed ``span()`` result: a do-nothing context manager.
    One module-level singleton — no allocation on the hot path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullSpan()

#: the armed tracer (None = disarmed). One slot, module-global — arming is
#: an operator/test surface, not a concurrency feature (same contract as
#: ``faults._ACTIVE``).
_ACTIVE: Optional["Tracer"] = None


def span(name: str):
    """Open a span if a tracer is armed; a no-op context manager otherwise.

    Disarmed cost is the crash-point pattern: one global load + ``is
    None`` test + return of a singleton."""
    if _ACTIVE is None:
        return _NULL
    return _ACTIVE._open(name)


class Tracer:
    """Collects a forest of spans for one armed window.

    ``engine`` may be None at arm time (the CLI arms before the store is
    replayed, so the replay span itself is captured); call
    :meth:`bind` once the engine exists."""

    def __init__(self, engine=None):
        self.engine = engine
        self.roots: List[Span] = []
        self._stack: List[Span] = []
        self.t0 = perf_counter()

    def bind(self, engine) -> None:
        self.engine = engine

    def _open(self, name: str) -> Span:
        if name not in _SPANS:
            raise KeyError(f"span {name!r} is not registered "
                           "(telemetry.register_span at import time)")
        return Span(name, self)


@contextmanager
def trace(engine=None) -> Iterator[Tracer]:
    """Arm a tracer for the duration of the block (no nesting)."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a Tracer is already armed")
    t = Tracer(engine)
    _ACTIVE = t
    try:
        yield t
    finally:
        _ACTIVE = None


def current() -> Optional[Tracer]:
    """The armed tracer, or None."""
    return _ACTIVE


# --------------------------------------------------------------------------
# rendering / export
# --------------------------------------------------------------------------

def _display_counters(counters: Dict[str, int]) -> Dict[str, int]:
    """Counter deltas for display: every nonzero delta, PLUS every
    registered metric of any dotted group with at least one changed
    counter — zeros included. This is what makes invariants *observable*:
    a commit that carried rows shows ``commit.rows_rehashed=0`` instead
    of silently omitting it."""
    groups = {k.split(".", 1)[0] for k in counters}
    shown = dict(counters)
    for name in _METRICS:
        if name not in shown and name.split(".", 1)[0] in groups:
            shown[name] = 0
    return dict(sorted(shown.items()))


def render_spans(spans: List[Span], indent: int = 0) -> List[str]:
    """Text span tree (the ``EXPLAIN`` body): one line per span with its
    wall time, then its counter deltas, then children indented."""
    lines: List[str] = []
    pad = "  " * indent
    for s in spans:
        lines.append(f"{pad}{s.name}  [{s.dur_s * 1e3:.3f} ms]")
        shown = _display_counters(s.counters)
        if shown:
            pairs = " ".join(f"{k}={v}" for k, v in shown.items())
            lines.append(f"{pad}  {pairs}")
        lines.extend(render_spans(s.children, indent + 1))
    return lines


def chrome_trace_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """Flatten a tracer's span forest into Chrome-tracing complete
    events (``ph: "X"``), timestamps in microseconds relative to arm."""
    events: List[Dict[str, Any]] = []

    def walk(s: Span) -> None:
        events.append({
            "name": s.name,
            "cat": "datagit",
            "ph": "X",
            "ts": round(s.t0_rel * 1e6, 3),
            "dur": round(s.dur_s * 1e6, 3),
            "pid": 1,
            "tid": 1,
            "args": dict(sorted(s.counters.items())),
        })
        for c in s.children:
            walk(c)

    for r in tracer.roots:
        walk(r)
    return events


def write_chrome_trace(path: str, tracer: Tracer) -> None:
    """Write the span forest as Chrome-tracing JSON, one event per line
    (loads in Perfetto / ``chrome://tracing``; the array format is also
    line-splittable for streaming consumers)."""
    events = chrome_trace_events(tracer)
    with open(path, "w") as f:
        f.write("[\n")
        for i, ev in enumerate(events):
            tail = ",\n" if i + 1 < len(events) else "\n"
            f.write(json.dumps(ev, sort_keys=True) + tail)
        f.write("]\n")
