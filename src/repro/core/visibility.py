"""MVCC visibility: which rows of which objects a directory can see.

A row r of data object o is visible in directory d iff

    commit_ts[r] <= d.ts   AND   no tombstone t in d with
                                 t.target == rowid(r) and t.commit_ts <= d.ts

Tombstone membership tests are range queries on the per-directory sorted
target array (objects own contiguous rowid ranges), served by the
``searchsorted`` kernel via ``ops.lower_bound``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..kernels import ops
from .directory import Directory
from .objects import DataObject, ObjectStore, pack_rowid


class VisibilityIndex:
    """Sorted tombstone-target index for one directory (built once per op)."""

    def __init__(self, store: ObjectStore, d: Directory):
        self.store = store
        self.d = d
        targets = []
        for oid in d.tomb_oids:
            t = store.get(oid)
            m = t.commit_ts <= np.uint64(d.ts)
            targets.append(t.target[m])
        self.targets = (np.sort(np.concatenate(targets))
                        if targets else np.zeros((0,), np.uint64))

    def killed_mask(self, obj: DataObject) -> np.ndarray:
        """(nrows,) bool — True where a tombstone kills the row."""
        n = obj.nrows
        if self.targets.shape[0] == 0 or n == 0:
            return np.zeros((n,), bool)
        base = pack_rowid(obj.oid, np.zeros((1,), np.uint64))[0]
        lo = int(ops.lower_bound(self.targets, np.asarray([base]))[0])
        hi = int(ops.lower_bound(self.targets,
                                 np.asarray([base + np.uint64(n)]))[0])
        mask = np.zeros((n,), bool)
        if hi > lo:
            offs = (self.targets[lo:hi] - base).astype(np.int64)
            mask[offs] = True
        return mask

    def killed_rowids(self, rowids: np.ndarray) -> np.ndarray:
        """(k,) bool for arbitrary rowids."""
        if self.targets.shape[0] == 0 or rowids.shape[0] == 0:
            return np.zeros(rowids.shape, bool)
        idx = ops.lower_bound(self.targets, rowids)
        idx_c = np.minimum(idx, self.targets.shape[0] - 1)
        return (self.targets[idx_c] == rowids) & (idx < self.targets.shape[0])

    def visible_mask(self, obj: DataObject) -> np.ndarray:
        return (obj.commit_ts <= np.uint64(self.d.ts)) & ~self.killed_mask(obj)


def visible_rowcount(store: ObjectStore, d: Directory) -> int:
    vi = VisibilityIndex(store, d)
    return int(sum(int(vi.visible_mask(store.get(oid)).sum())
                   for oid in d.data_oids))
