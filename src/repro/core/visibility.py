"""MVCC visibility: which rows of which objects a directory can see.

A row r of data object o is visible in directory d iff

    commit_ts[r] <= d.ts   AND   no tombstone t in d with
                                 t.target == rowid(r) and t.commit_ts <= d.ts

Tombstone membership tests are range queries on the per-directory sorted
target array (objects own contiguous rowid ranges), served by the
``searchsorted`` kernel via ``ops.lower_bound``.

Hot-path design (ISSUE 1): the sorted target array depends only on
``(d.tomb_oids, d.ts)`` and the immutable tombstone objects, so it is built
once per *directory version* and cached in the store's ``VisibilityCache``
— not rebuilt per operation.  Commits extend the parent version's array
incrementally (sorted merge of the freshly sealed tombstone batch) instead
of re-sorting the world.  The array is partitioned per data object (objects
own contiguous rowid ranges in the sorted array), so ``killed_mask`` slices
instead of searching, objects without tombstones skip masking entirely, and
per-object commit-ts zones let fully-visible objects skip the horizon
compare too.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from ..kernels import ops
from . import telemetry
from .directory import Directory
from .objects import DataObject, ObjectStore, pack_rowid, rowid_oid

SP_VIS_BUILD = telemetry.register_span(
    "visibility.build", "build a directory's sorted tombstone-target "
    "array from scratch (the cache-miss path)")

_EMPTY_U64 = np.zeros((0,), np.uint64)
_EMPTY_U64.setflags(write=False)


class _Entry:
    """One cached directory version: the sorted target array, its lazy
    per-object partition, and whether the ts-horizon filter dropped rows
    while building (if it did not, the array can be extended to any later
    horizon without rebuilding). ``ts_arr`` (lazy) aligns each target's
    tombstone commit_ts with the sorted array so historical PITR horizons
    derive by masking on commit_ts instead of rebuilding from objects."""

    __slots__ = ("targets", "slices", "complete", "ts_arr")

    def __init__(self, targets: np.ndarray, complete: bool):
        targets.setflags(write=False)
        self.targets = targets
        self.slices: Optional[Dict[int, Tuple[int, int]]] = None
        self.complete = complete
        self.ts_arr: Optional[np.ndarray] = None

    def object_slices(self) -> Dict[int, Tuple[int, int]]:
        if self.slices is None:
            t = self.targets
            if t.shape[0] == 0:
                self.slices = {}
            else:
                oids = rowid_oid(t)
                bnd = np.flatnonzero(oids[1:] != oids[:-1]) + 1
                starts = np.concatenate([[0], bnd])
                ends = np.concatenate([bnd, [t.shape[0]]])
                self.slices = {int(oids[s]): (int(s), int(e))
                               for s, e in zip(starts, ends)}
        return self.slices


def _build_entry(store: ObjectStore, d: Directory) -> _Entry:
    targets, complete = [], True
    ts = np.uint64(d.ts)
    for oid in d.tomb_oids:
        t = store.get(oid)
        m = t.commit_ts <= ts
        if m.all():
            targets.append(t.target)
        else:
            complete = False
            targets.append(t.target[m])
    arr = (np.sort(np.concatenate(targets)) if targets
           else _EMPTY_U64)
    return _Entry(arr, complete)


class KeyedLRU:
    """Tiny keyed LRU shared by the visibility and delta caches."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._cache: OrderedDict = OrderedDict()

    def lookup(self, key):
        v = self._cache.get(key)
        if v is not None:
            self._cache.move_to_end(key)
        return v

    def insert(self, key, value) -> None:
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.capacity:
            self._cache.popitem(last=False)

    def drop_if(self, pred) -> None:
        for k in [k for k in self._cache if pred(k)]:
            del self._cache[k]

    def clear(self) -> None:
        self._cache.clear()


class _Pending:
    """A not-yet-materialized extension: base entry + new sorted batches.

    Commits only record the freshly sealed batches (O(batch) per commit);
    the single merge copy is paid by the first *read* of the version, so a
    write-only burst never copies the full target array per commit."""

    __slots__ = ("base", "batches", "complete")

    def __init__(self, base: _Entry, batches, complete: bool):
        self.base = base
        self.batches = batches
        self.complete = complete


class VisibilityCache(KeyedLRU):
    """LRU cache of tombstone-target arrays keyed by (tomb_oids, ts).

    Correctness is by construction: keys are value-based over immutable
    inputs (tombstone objects are write-once), so a directory change —
    commit, restore, compaction — yields a different key and can never
    observe a stale array.  ``on_delete`` drops entries referencing a
    deleted tombstone; that is load-bearing, not just a memory bound —
    rollback paths (aborted commits, discarded CI previews) rewind the oid
    counter, so a deleted tombstone's oid can be REUSED by a later object
    and a surviving entry would alias it.
    """

    def __init__(self, store: ObjectStore, capacity: int = 32):
        super().__init__(capacity)
        self.store = store
        self.builds = 0    # full target-array constructions
        self.extends = 0   # incremental parent -> child extensions
        self.derives = 0   # PITR horizons derived by commit_ts truncation
        self.hits = 0

    @staticmethod
    def _key(d: Directory) -> Tuple:
        return (d.tomb_oids, d.ts)

    def _hmax(self, d: Directory) -> int:
        """Largest tombstone commit_ts in ``d`` (0 with no tombstones).
        Any horizon >= hmax sees every target — the array no longer
        depends on ts, so all such horizons share ONE canonical entry."""
        return max((self.store.get(o).ts_zone[1] for o in d.tomb_oids),
                   default=0)

    def _lookup_entry(self, key: Tuple) -> Optional[_Entry]:
        val = self.lookup(key)
        if isinstance(val, _Pending):
            val = self._materialize(key, val)
        return val

    def entry(self, d: Directory) -> _Entry:
        key = self._key(d)
        val = self._lookup_entry(key)
        if val is not None:
            self.hits += 1
            return val
        hmax = self._hmax(d)
        # full-coverage horizon (every tombstone commit <= d.ts — ALL
        # directories produced by commits and directory_at are): the array
        # is independent of ts, so every such horizon shares one canonical
        # entry instead of building its own (ROADMAP open item)
        ckey = (d.tomb_oids, hmax) if d.ts >= hmax else key
        hit = None
        if ckey != key:
            hit = self._lookup_entry(ckey)
        if hit is not None:
            self.hits += 1
            val = hit
        else:
            val = self._derive(d, hmax, ckey)
            if val is None:
                with telemetry.span(SP_VIS_BUILD):
                    val = _build_entry(self.store, d)
                    self.builds += 1
                self.insert(ckey, val)
        if ckey != key:
            # alias the exact key to the shared entry: repeat lookups of
            # this horizon must not re-pay the O(#tomb_oids) _hmax scan
            self.insert(key, val)
        return val

    def _derive(self, d: Directory, hmax: int, ckey: Tuple
                ) -> Optional[_Entry]:
        """Serve a historical horizon by truncating a cached HEAD array on
        commit_ts instead of rebuilding from tombstone objects.

        A cached complete entry whose tombstone set is a superset of
        ``d``'s — with every extra object committed entirely after
        ``d.ts`` (exactly what later commits of a linear history add) —
        contains ``d``'s array as the commit_ts <= d.ts subset; masking a
        sorted array preserves sortedness, so the derived array is
        byte-identical to a fresh build. O(cache) key scan + one O(n)
        mask vs. an O(n log n) rebuild."""
        if not d.tomb_oids:
            return None     # empty target array — building is O(1)
        dset = set(d.tomb_oids)
        dts = np.uint64(d.ts)
        for key2 in reversed(list(self._cache.keys())):  # newest first
            toids = key2[0]
            if len(toids) < len(dset) or key2 == ckey:
                continue
            extras = set(toids) - dset
            if len(extras) != len(toids) - len(dset):
                continue                    # not a superset
            if any(self.store.get(o).ts_zone[0] <= d.ts for o in extras):
                continue                    # an extra straddles the horizon
            head = self._lookup_entry(key2)
            if head is None or not head.complete:
                continue
            self._ensure_ts(head, toids)
            val = _Entry(head.targets[head.ts_arr <= dts],
                         complete=d.ts >= hmax)
            self.derives += 1
            self.insert(ckey, val)
            return val
        return None

    def _ensure_ts(self, entry: _Entry, tomb_oids) -> None:
        """Align each target's tombstone commit_ts with the sorted array.
        Valid only for complete entries (every target present exactly once
        — a rowid is killed by at most one tombstone); paid once per head,
        then every historical horizon is an O(n) mask."""
        if entry.ts_arr is not None:
            return
        ts = np.empty(entry.targets.shape, np.uint64)
        for oid in tomb_oids:
            t = self.store.get(oid)
            pos = np.searchsorted(entry.targets, t.target)
            ts[pos] = t.commit_ts
        ts.setflags(write=False)
        entry.ts_arr = ts

    def _materialize(self, key: Tuple, p: _Pending) -> _Entry:
        """Pay the deferred merge: one sort of the accumulated batches and
        one copy of the base array, regardless of how many commits piled
        up since the base was last read."""
        if len(p.batches) == 1:
            add = p.batches[0]
        else:
            add = np.sort(np.concatenate(p.batches))
        merged = p.base.targets
        if add.shape[0] and merged.shape[0] == 0:
            merged = add.copy()
        elif add.shape[0]:
            # manual sorted insert: one allocation + two masked copies
            # (np.insert pays extra normalization overhead per call)
            pos = np.searchsorted(merged, add)
            out = np.empty((merged.shape[0] + add.shape[0],), merged.dtype)
            at = pos + np.arange(add.shape[0])
            mask = np.zeros(out.shape, bool)
            mask[at] = True
            out[at] = add
            out[~mask] = merged
            merged = out
        entry = _Entry(merged, p.complete)
        self.insert(key, entry)
        return entry

    def get(self, d: Directory) -> "VisibilityIndex":
        return VisibilityIndex(self.store, d, _entry=self.entry(d))

    def extend(self, parent: Directory, child: Directory) -> None:
        """Derive the child version's array from the parent's by recording
        the newly added (already sorted at seal time) tombstone batches.
        No-op unless the parent is cached, the child only *adds*
        tombstones, and the parent array was horizon-complete."""
        pval = self._cache.get(self._key(parent))
        ph = None
        if pval is None:
            # full-coverage entries live under their canonical key
            ph = self._hmax(parent)
            if parent.ts >= ph:
                pval = self._cache.get((parent.tomb_oids, ph))
        if pval is None or not pval.complete:
            return
        p_set = set(parent.tomb_oids)
        c_set = set(child.tomb_oids)
        if not (p_set <= c_set) or child.ts < parent.ts:
            return
        complete = True
        ts = np.uint64(child.ts)
        hmax_child = ph if ph is not None else self._hmax(parent)
        batches = []
        for oid in child.tomb_oids:
            if oid in p_set:
                continue
            t = self.store.get(oid)
            hmax_child = max(hmax_child, t.ts_zone[1])
            m = t.commit_ts <= ts
            batches.append(t.target if m.all() else t.target[m])
            complete = complete and bool(m.all())
        # complete children file under the canonical key so later PITR
        # horizons of this version share the entry
        ckey = ((child.tomb_oids, hmax_child) if complete
                else self._key(child))
        if self._cache.get(ckey) is not None:
            return
        if isinstance(pval, _Pending):   # chain of unread commits: flatten
            base, batches = pval.base, pval.batches + batches
        else:
            base = pval
        if not batches:
            self.insert(ckey, _Entry(base.targets, complete))
        else:
            self.insert(ckey, _Pending(base, batches, complete))
        self.extends += 1

    def on_delete(self, oid: int) -> None:
        """A tombstone object was GC'd: drop entries referencing it."""
        self.drop_if(lambda k: oid in k[0])


def visibility_index(store: ObjectStore, d: Directory) -> "VisibilityIndex":
    """The cached entry point every hot path goes through."""
    cache = getattr(store, "vis_cache", None)
    if cache is None:
        cache = VisibilityCache(store)
        store.vis_cache = cache
    return cache.get(d)


class VisibilityIndex:
    """View over one directory version's sorted tombstone-target array."""

    def __init__(self, store: ObjectStore, d: Directory,
                 _entry: Optional[_Entry] = None):
        self.store = store
        self.d = d
        if _entry is None:
            _entry = _build_entry(store, d)
        self._entry = _entry

    @property
    def targets(self) -> np.ndarray:
        return self._entry.targets

    def object_targets(self, oid: int) -> np.ndarray:
        """The slice of targets that can touch data object ``oid``."""
        sl = self._entry.object_slices().get(oid)
        if sl is None:
            return _EMPTY_U64
        return self._entry.targets[sl[0]:sl[1]]

    def has_kills(self, obj: DataObject) -> bool:
        return obj.oid in self._entry.object_slices()

    def fully_visible(self, obj: DataObject) -> bool:
        """Zone pruning: every row passes without masking — no tombstone
        targets the object and its commit-ts zone is within the horizon."""
        return (obj.oid not in self._entry.object_slices()
                and obj.ts_zone[1] <= self.d.ts)

    def killed_mask(self, obj: DataObject) -> np.ndarray:
        """(nrows,) bool — True where a tombstone kills the row."""
        n = obj.nrows
        mask = np.zeros((n,), bool)
        if n == 0:
            return mask
        t = self.object_targets(obj.oid)
        if t.shape[0]:
            base = pack_rowid(obj.oid, np.zeros((1,), np.uint64))[0]
            mask[(t - base).astype(np.int64)] = True
        return mask

    def killed_rowids(self, rowids: np.ndarray) -> np.ndarray:
        """(k,) bool for arbitrary rowids."""
        targets = self._entry.targets
        if targets.shape[0] == 0 or rowids.shape[0] == 0:
            return np.zeros(rowids.shape, bool)
        idx = ops.lower_bound(targets, rowids)
        idx_c = np.minimum(idx, targets.shape[0] - 1)
        return (targets[idx_c] == rowids) & (idx < targets.shape[0])

    def killed_offsets(self, obj: DataObject, offs: np.ndarray) -> np.ndarray:
        """(k,) bool for row offsets within one object — searches only the
        object's slice of the target array, not the global array."""
        t = self.object_targets(obj.oid)
        if t.shape[0] == 0 or offs.shape[0] == 0:
            return np.zeros(offs.shape, bool)
        base = pack_rowid(obj.oid, np.zeros((1,), np.uint64))[0]
        toffs = (t - base).astype(np.int64)
        pos = np.searchsorted(toffs, offs)
        pos_c = np.minimum(pos, toffs.shape[0] - 1)
        return (toffs[pos_c] == offs) & (pos < toffs.shape[0])

    def visible_rows(self, obj: DataObject, offs: np.ndarray) -> np.ndarray:
        """Visibility of selected row offsets without materializing the
        object-wide mask (Δ-scan hot path: cost ∝ candidates, not rows)."""
        ok = ~self.killed_offsets(obj, offs)
        lo, hi = obj.ts_zone
        if hi <= self.d.ts:
            return ok
        if lo > self.d.ts:
            return np.zeros(offs.shape, bool)
        return ok & (obj.commit_ts[offs] <= np.uint64(self.d.ts))

    def visible_mask(self, obj: DataObject) -> np.ndarray:
        if self.fully_visible(obj):
            return np.ones((obj.nrows,), bool)
        if obj.ts_zone[1] <= self.d.ts:
            return ~self.killed_mask(obj)
        return (obj.commit_ts <= np.uint64(self.d.ts)) & ~self.killed_mask(obj)

    def visible_count(self, obj: DataObject) -> int:
        """Visible-row count without materializing a mask when pruned."""
        if self.fully_visible(obj):
            return obj.nrows
        return int(self.visible_mask(obj).sum())


def visible_rowcount(store: ObjectStore, d: Directory) -> int:
    vi = visibility_index(store, d)
    return int(sum(vi.visible_count(store.get(oid)) for oid in d.data_oids))
