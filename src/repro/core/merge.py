"""SNAPSHOT MERGE (paper §3, §5.2, §5.3).

Three-way merge of a source snapshot into the *current* version of a target
table, with an explicit or implicit (lineage) common base revision, or with
an empty base when none exists (§5.3). Conflict modes: FAIL / SKIP (keep
target's version) / ACCEPT (take source's version).

Implementation follows §5.2:
  1. signed Δ streams of each branch vs. the base (cost ∝ changed data),
  2. per-branch collapse per key: DEL / INS / UPD, with *move* detection
     (value-identical reposition ⇒ treated as unchanged — false conflict),
  3. cancellation of identical changes across branches (same-row deletions,
     same-value insertions),
  4. residual keys changed by both branches = true conflicts; single-branch
     keys = false conflicts, auto-applied,
  5. all edits applied to the target in ONE transaction (atomic publish).

NoPK tables use the §3 cardinality rules (δ arithmetic per value group) on
the residuals after cancellation. NOTE (documented in DESIGN.md): for
branch-internal value-neutral rewrites (delete a row + insert an identical
one) §3's raw-count rule and §5's cancel-then-classify mechanics disagree;
we implement the §5 mechanics, like the paper's system does.

Without a common base (§5.3) merge runs on ONE cross delta
``signed_delta(target, source)`` — shared objects are skipped wholesale, so
merging estranged clones stays ∝ their divergence.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from ..kernels import ops
from . import telemetry
from .delta import DeltaStats, SignedStream, signed_delta
from .diff import gather_payload
from .directory import Snapshot
from .engine import Engine
from .schema import Schema
from .sigs import SigBatch

SP_PLAN_MERGE = telemetry.register_span(
    "plan_merge", "plan one table's merge: Δ streams, classification, "
    "staging edits on the transaction")
SP_MERGE = telemetry.register_span(
    "merge", "three-way merge of a source snapshot into a target table")


def _piece_runs(pieces) -> np.ndarray:
    """Run-start offsets for a concatenation of key-sorted pieces.

    Each (possibly empty) piece is individually key-ascending — the merge
    paths emit them as ascending subsets of key-sorted collapsed change
    sets — so the concat is a valid multi-run ``SigBatch.runs`` claim."""
    offs, off = [], 0
    for p in pieces:
        if p.shape[0]:
            offs.append(off)
            off += p.shape[0]
    return np.asarray(offs if offs else [0], np.int64)


class ConflictMode(enum.Enum):
    FAIL = "fail"
    SKIP = "skip"      # keep target's version ("accept yours")
    ACCEPT = "accept"  # take source's version ("accept mine")
    CELL = "cell"      # BEYOND-PAPER (paper §5.5.3 future work): auto-merge
    #                    update-vs-update conflicts at CELL level when the
    #                    two branches changed different columns; same-cell
    #                    divergence still fails


class MergeConflictError(Exception):
    def __init__(self, report: "MergeReport"):
        super().__init__(
            f"merge failed: {report.true_conflicts} true conflict(s)")
        self.report = report


@dataclass
class MergeReport:
    true_conflicts: int = 0
    cell_merged: int = 0       # CELL mode: row conflicts merged column-wise
    false_conflicts: int = 0
    moves_ignored: int = 0
    inserted: int = 0
    deleted: int = 0
    commit_ts: Optional[int] = None
    used_base: bool = True
    # the true-conflict keys, populated in EVERY mode (the paper's PR-review
    # flow must show WHICH keys were force-resolved under SKIP/ACCEPT/CELL,
    # not just how many). On a FAIL/CELL raise they narrow to the keys that
    # caused the failure. NoPK paths report value signatures here.
    conflict_key_lo: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.uint64))
    conflict_key_hi: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.uint64))
    stats: DeltaStats = field(default_factory=DeltaStats)


_NONE = np.iinfo(np.int64).max  # "no entry" sentinel for position reductions

OP_DEL, OP_INS, OP_UPD = np.int8(0), np.int8(1), np.int8(2)


@dataclass
class PKChanges:
    """Per-key collapsed change set of one branch vs. the base (§5.2)."""
    key_lo: np.ndarray       # (k,) uint64, sorted by (lo, hi)
    key_hi: np.ndarray
    op: np.ndarray           # (k,) int8: OP_DEL / OP_INS / OP_UPD
    minus_rowid: np.ndarray  # base row deleted (0 if none)
    plus_rowid: np.ndarray   # new visible row (0 if none)
    plus_row_lo: np.ndarray  # value signature of the new row (0 if none)
    plus_row_hi: np.ndarray

    @property
    def k(self) -> int:
        return int(self.key_lo.shape[0])


def collapse_pk(stream: SignedStream) -> Tuple[PKChanges, int]:
    """Collapse a branch Δ stream per primary key; drop pure moves.

    Returns (changes, n_moves_dropped). PK uniqueness guarantees at most one
    − (the base/left row) and one + (the new/right row) per key. Streams
    from ``signed_delta`` arrive key-sorted, so the collapse is sort-free;
    the output key arrays are sorted by (lo, hi) either way."""
    if stream.n == 0:
        z64 = np.zeros((0,), np.uint64)
        return PKChanges(z64, z64, np.zeros((0,), np.int8), z64.copy(),
                         z64.copy(), z64.copy(), z64.copy()), 0
    s = stream.merge_by_key()
    _, agg = ops.diff_aggregate(s.key_lo, s.key_hi, s.sign, presorted=True)
    n = s.n
    pos = np.arange(n, dtype=np.int64)
    first_minus = np.minimum.reduceat(
        np.where(s.sign < 0, pos, _NONE), agg.run_starts)
    first_plus = np.minimum.reduceat(
        np.where(s.sign > 0, pos, _NONE), agg.run_starts)
    has_minus = first_minus != _NONE
    has_plus = first_plus != _NONE
    fm = np.minimum(first_minus, n - 1)
    fp = np.minimum(first_plus, n - 1)
    moved = (has_minus & has_plus
             & (s.row_lo[fm] == s.row_lo[fp])
             & (s.row_hi[fm] == s.row_hi[fp]))
    keep = ~moved
    op = np.where(has_minus & has_plus, OP_UPD,
                  np.where(has_plus, OP_INS, OP_DEL)).astype(np.int8)
    starts = agg.run_starts
    ch = PKChanges(
        key_lo=s.key_lo[starts][keep],
        key_hi=s.key_hi[starts][keep],
        op=op[keep],
        minus_rowid=np.where(has_minus, s.rowid[fm], 0).astype(np.uint64)[keep],
        plus_rowid=np.where(has_plus, s.rowid[fp], 0).astype(np.uint64)[keep],
        plus_row_lo=np.where(has_plus, s.row_lo[fp], 0).astype(np.uint64)[keep],
        plus_row_hi=np.where(has_plus, s.row_hi[fp], 0).astype(np.uint64)[keep],
    )
    return ch, int(moved.sum())


def _align_keys(t: PKChanges, s: PKChanges):
    """Linear merge-join of the two branches' (key-sorted) change sets.

    Both collapsed change sets are already sorted by (key_lo, key_hi) with
    unique keys, so the union is one searchsorted probe plus a stable 2-run
    merge — no third global sort per merge. Returns (t_idx, s_idx):
    equal-length arrays over the key-sorted union of keys; -1 where that
    branch has no change for the key."""
    if t.k == 0:
        return (np.full((s.k,), -1, np.int64),
                np.arange(s.k, dtype=np.int64))
    if s.k == 0:
        return (np.arange(t.k, dtype=np.int64),
                np.full((t.k,), -1, np.int64))
    pos = ops.searchsorted128(t.key_lo, t.key_hi, s.key_lo, s.key_hi)
    posc = np.minimum(pos, t.k - 1)
    matched = ((pos < t.k) & (t.key_lo[posc] == s.key_lo)
               & (t.key_hi[posc] == s.key_hi))
    s_at_t = np.full((t.k,), -1, np.int64)
    s_at_t[pos[matched]] = np.flatnonzero(matched)
    only = np.flatnonzero(~matched)
    lo = np.concatenate([t.key_lo, s.key_lo[only]])
    hi = np.concatenate([t.key_hi, s.key_hi[only]])
    order = ops.merge128_runs(lo, hi, np.array([0, t.k], np.int64))
    from_t = order < t.k
    t_idx = np.where(from_t, order, -1)
    s_idx = np.empty(order.shape, np.int64)
    s_idx[from_t] = s_at_t[order[from_t]]
    s_idx[~from_t] = only[order[~from_t] - t.k]
    return t_idx, s_idx


# --------------------------------------------------------------------------
# PK paths
# --------------------------------------------------------------------------

def _merge_pk(engine: Engine, target: str, source: Snapshot,
              base_dir, mode: ConflictMode, report: MergeReport):
    """Three-way PK merge. Returns (del_key_lo, del_key_hi, ins_rowids)."""
    t_tab = engine.table(target)
    d_t = signed_delta(engine.store, base_dir, t_tab.directory, report.stats)
    d_s = signed_delta(engine.store, base_dir, source.directory, report.stats)
    ch_t, mv_t = collapse_pk(d_t)
    ch_s, mv_s = collapse_pk(d_s)
    report.moves_ignored = mv_t + mv_s

    t_idx, s_idx = _align_keys(ch_t, ch_s)
    both = (t_idx >= 0) & (s_idx >= 0)
    only_s = (t_idx < 0) & (s_idx >= 0)

    # identical changes cancel (no conflict, already reflected in target)
    ti, si = t_idx[both], s_idx[both]
    same_del = (ch_t.op[ti] == OP_DEL) & (ch_s.op[si] == OP_DEL)
    same_val = ((ch_t.op[ti] != OP_DEL) & (ch_s.op[si] != OP_DEL)
                & (ch_t.plus_row_lo[ti] == ch_s.plus_row_lo[si])
                & (ch_t.plus_row_hi[ti] == ch_s.plus_row_hi[si]))
    identical = same_del | same_val
    conflict_ti, conflict_si = ti[~identical], si[~identical]
    report.false_conflicts += int(identical.sum()) + int(only_s.sum())
    report.true_conflicts = int(conflict_si.shape[0])
    report.conflict_key_lo = ch_s.key_lo[conflict_si]
    report.conflict_key_hi = ch_s.key_hi[conflict_si]

    if report.true_conflicts and mode is ConflictMode.FAIL:
        raise MergeConflictError(report)

    del_lo, del_hi, ins = [], [], []

    # false conflicts: apply source's op (scenarios 2 & 4)
    fi = s_idx[only_s]
    ops_s = ch_s.op[fi]
    needs_del = ops_s != OP_INS
    del_lo.append(ch_s.key_lo[fi][needs_del])
    del_hi.append(ch_s.key_hi[fi][needs_del])
    ins.append(ch_s.plus_rowid[fi][ops_s != OP_DEL])

    # true conflicts under ACCEPT: force source's version (scenarios 3 & 6)
    if report.true_conflicts and mode is ConflictMode.ACCEPT:
        t_has_row = ch_t.op[conflict_ti] != OP_DEL
        del_lo.append(ch_s.key_lo[conflict_si][t_has_row])
        del_hi.append(ch_s.key_hi[conflict_si][t_has_row])
        ins.append(ch_s.plus_rowid[conflict_si][ch_s.op[conflict_si] != OP_DEL])

    # CELL mode (beyond paper, §5.5.3): merge UPD-vs-UPD conflicts per
    # column against the base row; fail on same-cell divergence or on
    # structural conflicts (DEL involved / no base row).
    merged_batch = None
    if report.true_conflicts and mode is ConflictMode.CELL:
        updud = ((ch_t.op[conflict_ti] == OP_UPD)
                 & (ch_s.op[conflict_si] == OP_UPD))
        if not updud.all():
            report.conflict_key_lo = ch_s.key_lo[conflict_si][~updud]
            report.conflict_key_hi = ch_s.key_hi[conflict_si][~updud]
            raise MergeConflictError(report)
        schema = engine.table(target).schema
        base_rows = gather_payload(engine.store, schema,
                                   ch_t.minus_rowid[conflict_ti])
        t_rows = gather_payload(engine.store, schema,
                                ch_t.plus_rowid[conflict_ti])
        s_rows = gather_payload(engine.store, schema,
                                ch_s.plus_rowid[conflict_si])
        merged = {}
        cell_conflict = np.zeros((conflict_ti.shape[0],), bool)
        for col in schema.names:
            b_c, t_c, s_c = base_rows[col], t_rows[col], s_rows[col]
            if schema.np_dtype(col) == np.object_:  # LOB: bytes equality
                t_chg = np.asarray([x != y for x, y in zip(t_c, b_c)])
                s_chg = np.asarray([x != y for x, y in zip(s_c, b_c)])
                t_ne_s = np.asarray([x != y for x, y in zip(t_c, s_c)])
                merged[col] = np.where(s_chg, s_c, t_c)
            else:
                t_chg = t_c != b_c
                s_chg = s_c != b_c
                t_ne_s = t_c != s_c
                merged[col] = np.where(s_chg, s_c, t_c)
            cell_conflict |= t_chg & s_chg & t_ne_s
        if cell_conflict.any():
            report.conflict_key_lo = ch_s.key_lo[conflict_si][cell_conflict]
            report.conflict_key_hi = ch_s.key_hi[conflict_si][cell_conflict]
            raise MergeConflictError(report)
        report.cell_merged = int(conflict_ti.shape[0])
        del_lo.append(ch_s.key_lo[conflict_si])
        del_hi.append(ch_s.key_hi[conflict_si])
        merged_batch = merged

    cat = lambda xs: (np.concatenate(xs) if xs else np.zeros((0,), np.uint64))
    # each ins piece is key-ascending (it walks the key-sorted union), so
    # the concat carries an exact runs claim into the zero-rehash seal
    return cat(del_lo), cat(del_hi), cat(ins), _piece_runs(ins), merged_batch


def _merge_pk_nobase(engine: Engine, target: str, source: Snapshot,
                     mode: ConflictMode, report: MergeReport):
    """§5.3 no-base PK merge on ONE cross delta (shared objects skipped).

    Per key: − only ⇒ target-only (keep); + only ⇒ source-only (insert);
    both with equal values ⇒ no-op; both with different values ⇒ true
    conflict. Returns (del_rowids, ins_rowids) — deletes are direct current
    rowids (the − rows ARE the target's current rows)."""
    t_tab = engine.table(target)
    cross = signed_delta(engine.store, t_tab.directory, source.directory,
                         report.stats)
    ch, moves = collapse_pk(cross)  # "moved" == value-identical in both
    report.moves_ignored = moves
    conflicts = ch.op == OP_UPD
    inserts = ch.op == OP_INS
    report.false_conflicts += int(inserts.sum()) + moves
    report.true_conflicts = int(conflicts.sum())
    report.conflict_key_lo = ch.key_lo[conflicts]
    report.conflict_key_hi = ch.key_hi[conflicts]
    if report.true_conflicts and mode is ConflictMode.FAIL:
        raise MergeConflictError(report)
    del_rowids = [np.zeros((0,), np.uint64)]
    ins_rowids = [ch.plus_rowid[inserts]]
    if report.true_conflicts and mode is ConflictMode.ACCEPT:
        del_rowids.append(ch.minus_rowid[conflicts])
        ins_rowids.append(ch.plus_rowid[conflicts])
    return (np.concatenate(del_rowids), np.concatenate(ins_rowids),
            _piece_runs(ins_rowids))


# --------------------------------------------------------------------------
# NoPK paths
# --------------------------------------------------------------------------

def _merge_nopk(engine: Engine, target: str, source: Snapshot,
                base_dir, mode: ConflictMode, report: MergeReport):
    """Three-way NoPK merge (§3 cardinality rules on post-cancel residuals).

    Returns (del_sig_lo, del_sig_hi, del_need, ins_rowids): delete
    ``del_need[i]`` visible duplicates of value-signature i; insert the
    (possibly repeated) payload rowids."""
    t_tab = engine.table(target)
    d_t = signed_delta(engine.store, base_dir, t_tab.directory, report.stats)
    d_s = signed_delta(engine.store, base_dir, source.directory, report.stats)

    # cancellation #1: deletions of the same base row (same physical rowid)
    if (d_t.n and d_s.n and (d_t.sign < 0).any()
            and (d_s.sign < 0).any()):
        common_del = np.intersect1d(d_t.rowid[d_t.sign < 0],
                                    d_s.rowid[d_s.sign < 0])
    else:
        common_del = np.zeros((0,), np.uint64)

    def residual(stream: SignedStream) -> SignedStream:
        if common_del.shape[0] == 0 or stream.n == 0:
            return stream
        drop = (stream.sign < 0) & np.isin(stream.rowid, common_del)
        return stream.filter_mask(~drop)  # order-preserving: stays sorted

    d_t, d_s = residual(d_t), residual(d_s)

    combined = SignedStream.concat([d_t, d_s])
    if combined.n == 0:
        z = np.zeros((0,), np.uint64)
        return z, z.copy(), np.zeros((0,), np.int64), z.copy()
    side = np.concatenate([np.zeros((d_t.n,), np.int8),
                           np.ones((d_s.n,), np.int8)])
    # both branch streams are value-sorted (NoPK key == value), so the
    # combined stream is a stable 2-run merge and aggregation is sort-free;
    # big streams merge/aggregate per key-range shard (derived plan —
    # byte-identical order, partition-parallel execution)
    from ..distributed import sharding as ksh
    shards = ksh.key_shard_count(combined.n)
    if combined.sorted_by_key:
        st = combined
    else:
        cuts = None
        if shards > 1 and combined.runs is not None:
            cuts = ksh.plan_key_cuts(combined.key_lo, combined.key_hi,
                                     combined.runs, shards)
            if cuts is not None:
                engine.store.metrics.add("probe.shard_parts",
                                         cuts[0].shape[0] + 1)
        order = (ops.merge128_runs(combined.key_lo, combined.key_hi,
                                   combined.runs, cuts=cuts)
                 if combined.runs is not None
                 else ops._sort128(combined.row_lo, combined.row_hi))
        st, side = combined.take(order), side[order]
    _, agg = ops.diff_aggregate(st.row_lo, st.row_hi,
                                np.ones_like(st.sign), presorted=True,
                                shards=shards)
    ro_lo, ro_hi = st.row_lo, st.row_hi
    sd, sg, rid = side, st.sign, st.rowid
    starts = agg.run_starts
    k = starts.shape[0]
    # per-side + counts and net sums; a branch that contributed no Δ rows
    # (common: merging into an untouched target) skips its masked reduceats
    zk = np.zeros((k,), np.int64)
    has_t = bool((sd == 0).any())
    has_s = bool((sd == 1).any())
    sg64 = sg.astype(np.int64)
    if has_t:
        pm = (sg > 0) if not has_s else ((sd == 0) & (sg > 0))
        nm = sg64 if not has_s else np.where(sd == 0, sg64, 0)
        plus_t = np.add.reduceat(pm.astype(np.int64), starts)
        net_t = np.add.reduceat(nm, starts)
    else:
        plus_t, net_t = zk, zk
    if has_s:
        pm = (sg > 0) if not has_t else ((sd == 1) & (sg > 0))
        nm = sg64 if not has_t else np.where(sd == 1, sg64, 0)
        plus_s = np.add.reduceat(pm.astype(np.int64), starts)
        net_s = np.add.reduceat(nm, starts)
    else:
        plus_s, net_s = zk, zk
    # cancellation #2: insertions of identical values on both branches
    c_ins = np.minimum(plus_t, plus_s)
    dt = net_t - c_ins   # residual δ_T per value group
    ds = net_s - c_ins   # residual δ_TClone

    conflict = (dt != 0) & (ds != 0)
    false_c = (dt == 0) & (ds != 0)
    report.true_conflicts = int(conflict.sum())
    report.false_conflicts += int(false_c.sum())
    report.conflict_key_lo = ro_lo[starts][conflict]
    report.conflict_key_hi = ro_hi[starts][conflict]
    if report.true_conflicts and mode is ConflictMode.FAIL:
        raise MergeConflictError(report)

    apply_net = np.zeros((k,), np.int64)
    apply_net[false_c] = ds[false_c]
    if mode is ConflictMode.ACCEPT:
        # force target to the source's count: N3 − N2 == net_s − net_t
        apply_net[conflict] = (net_s - net_t)[conflict]

    # representative payload rowid per group: prefer a source + row, else a
    # − row (base object — the paper's base-revision lookup)
    n = sg.shape[0]
    pos = np.arange(n, dtype=np.int64)
    first_sp = np.minimum.reduceat(
        np.where((sd == 1) & (sg > 0), pos, _NONE), starts)
    first_mn = np.minimum.reduceat(np.where(sg < 0, pos, _NONE), starts)
    rep_pos = np.where(first_sp != _NONE, first_sp, first_mn)
    rep_rowid = rid[np.minimum(rep_pos, n - 1)]

    ins = np.flatnonzero(apply_net > 0)
    dele = np.flatnonzero(apply_net < 0)
    ins_rowids = np.repeat(rep_rowid[ins], apply_net[ins])
    return (ro_lo[starts][dele], ro_hi[starts][dele], -apply_net[dele],
            ins_rowids)


def _merge_nopk_nobase(engine: Engine, target: str, source: Snapshot,
                       mode: ConflictMode, report: MergeReport):
    """§5.3 no-base NoPK merge on one cross delta.

    Per value group of the cross stream (net = N_source − N_target):
    net == 0 ⇒ cancelled; only-− ⇒ target-only value (keep); only-+ ⇒
    source-only value (insert all); mixed ⇒ true conflict (counts differ and
    both have the value). Returns (del_rowids, ins_rowids) — direct rowids."""
    t_tab = engine.table(target)
    cross = signed_delta(engine.store, t_tab.directory, source.directory,
                         report.stats)
    if cross.n == 0:
        z = np.zeros((0,), np.uint64)
        return z, z.copy()
    s = cross.merge_by_key()  # NoPK: key order IS value order; identity
    #                           for cache-served streams
    _, agg = ops.diff_aggregate(s.row_lo, s.row_hi, s.sign, presorted=True)
    starts, lens, nets = agg.run_starts, agg.run_lens, agg.run_sums
    minus_cnt = np.add.reduceat((s.sign < 0).astype(np.int64), starts)
    plus_cnt = np.add.reduceat((s.sign > 0).astype(np.int64), starts)
    mixed = (minus_cnt > 0) & (plus_cnt > 0) & (nets != 0)
    pure_ins = (minus_cnt == 0) & (nets > 0)
    report.true_conflicts = int(mixed.sum())
    report.false_conflicts += int(pure_ins.sum())
    report.conflict_key_lo = s.row_lo[starts][mixed]
    report.conflict_key_hi = s.row_hi[starts][mixed]
    if report.true_conflicts and mode is ConflictMode.FAIL:
        raise MergeConflictError(report)

    apply_net = np.zeros(nets.shape, np.int64)
    apply_net[pure_ins] = nets[pure_ins]
    if mode is ConflictMode.ACCEPT:
        apply_net[mixed] = nets[mixed]

    # element-wise selection: within each run take the first |net| rows of
    # the needed sign (ranks via run-relative cumulative counts)
    run_ids = agg.run_ids
    is_plus = (s.sign > 0).astype(np.int64)
    is_minus = (s.sign < 0).astype(np.int64)
    cp, cm = np.cumsum(is_plus), np.cumsum(is_minus)
    base_p = cp[starts] - is_plus[starts]
    base_m = cm[starts] - is_minus[starts]
    rank_p = cp - base_p[run_ids]   # 1-based among + within run
    rank_m = cm - base_m[run_ids]
    net_e = apply_net[run_ids]
    take_ins = (s.sign > 0) & (net_e > 0) & (rank_p <= net_e)
    take_del = (s.sign < 0) & (net_e < 0) & (rank_m <= -net_e)
    return s.rowid[take_del], s.rowid[take_ins]


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def plan_merge(engine: Engine, target: str, source: Snapshot,
               base: Optional[Snapshot], mode: ConflictMode,
               report: MergeReport, tx) -> None:
    """Stage the merge edits of ``source`` into ``target`` on ``tx``.

    Pure planning: reads the engine, fills ``report``, stages deletes and
    inserts on the caller's transaction — but never commits. Conflicts under
    FAIL/CELL raise *before* anything is staged for this table, so a caller
    batching several tables into one transaction (the workflow subsystem's
    atomic publish) aborts with nothing applied. Committing — or discarding
    the transaction for a dry run — is the caller's move."""
    with telemetry.span(SP_PLAN_MERGE):
        _plan_merge(engine, target, source, base, mode, report, tx)


def _plan_merge(engine: Engine, target: str, source: Snapshot,
                base: Optional[Snapshot], mode: ConflictMode,
                report: MergeReport, tx) -> None:
    t_tab = engine.table(target)
    if not t_tab.schema.compatible_with(source.schema):
        raise ValueError("SNAPSHOT MERGE: incompatible schemas")
    if mode is ConflictMode.CELL and (not t_tab.schema.has_pk
                                      or base is None):
        raise ValueError("CELL conflict mode needs a primary key and a "
                         "common base revision")
    schema = t_tab.schema
    merged_batch = None
    # every merge path emits its insert rowids as (a few) key-ascending
    # pieces of the sort-free Δ pipeline — the runs claim lets the seal
    # skip or k-way-merge instead of re-lexsorting, and the gathered
    # SigBatch means the apply path never rehashes a row
    if schema.has_pk:
        if base is not None:
            del_lo, del_hi, ins_rowids, ins_runs, merged_batch = _merge_pk(
                engine, target, source, base.directory, mode, report)
            if del_lo.shape[0]:
                rid = t_tab.locate_keys(del_lo, del_hi)
                tx.delete_rowids(target, rid[rid != 0])
                report.deleted = int((rid != 0).sum())
        else:
            del_rowids, ins_rowids, ins_runs = _merge_pk_nobase(
                engine, target, source, mode, report)
            if del_rowids.shape[0]:
                tx.delete_rowids(target, del_rowids)
                report.deleted = int(del_rowids.shape[0])
    else:
        ins_runs = SigBatch.sorted_run()  # NoPK paths emit value-sorted
        if base is not None:
            sig_lo, sig_hi, need, ins_rowids = _merge_nopk(
                engine, target, source, base.directory, mode, report)
            if sig_lo.shape[0]:
                rids = t_tab.locate_rowsig_multi(sig_lo, sig_hi, need,
                                                 flat=True)
                if rids.shape[0]:
                    tx.delete_rowids(target, rids)
                report.deleted = int(rids.shape[0])
        else:
            del_rowids, ins_rowids = _merge_nopk_nobase(
                engine, target, source, mode, report)
            if del_rowids.shape[0]:
                tx.delete_rowids(target, del_rowids)
                report.deleted = int(del_rowids.shape[0])

    if ins_rowids.shape[0]:
        payload, sigs = gather_payload(engine.store, schema, ins_rowids,
                                       with_sigs=True, runs=ins_runs)
        tx.insert(target, payload, sigs=sigs)
        report.inserted = int(ins_rowids.shape[0])
    if merged_batch is not None and len(next(iter(merged_batch.values()))):
        # CELL-merged rows are freshly constructed values — genuinely new
        # data, so they take the hashing path
        tx.insert(target, merged_batch)
        report.inserted += int(len(next(iter(merged_batch.values()))))


def three_way_merge(engine: Engine, target: str, source: Snapshot,
                    base: Optional[Snapshot] = None,
                    mode: ConflictMode = ConflictMode.FAIL) -> MergeReport:
    """SNAPSHOT MERGE TABLE target FROM source [BASED ON base]
    [WHEN CONFLICT FAIL|SKIP|ACCEPT]."""
    with telemetry.span(SP_MERGE):
        if base is None:
            base = engine.find_common_base(target, source.table)
        report = MergeReport(used_base=base is not None)
        tx = engine.begin()
        plan_merge(engine, target, source, base, mode, report, tx)
        if report.inserted or report.deleted:
            with engine.op_kind("merge"):
                report.commit_ts = tx.commit()
        # lineage: the merged-in source snapshot becomes the new common
        # base
        if source.table != target and source.table in engine.tables:
            engine.set_common_base(target, source.table, source)
            engine.wal.append("set_base", a=target, b=source.table,
                              snap=source)
        return report


def two_way_merge(engine: Engine, target: str, source: Snapshot,
                  mode: ConflictMode = ConflictMode.FAIL) -> MergeReport:
    """Merge without BASED ON: lineage gives the implicit base (§5.3), else
    the empty-base cross-delta path (shared objects skipped)."""
    return three_way_merge(engine, target, source, base=None, mode=mode)


# --------------------------------------------------------------------------
# Three-way diff (BEYOND PAPER): the paper notes (§5.5.1) the diff
# aggregation's signs carry exactly the information needed but chooses not
# to expose it. We do: per-key classification of how two branches diverged
# from a common base — the PR-review view for complex histories.
# --------------------------------------------------------------------------

TW_TARGET_ONLY, TW_SOURCE_ONLY, TW_BOTH_SAME, TW_BOTH_DIFFER = 0, 1, 2, 3


@dataclass
class ThreeWayDiff:
    key_lo: np.ndarray      # (k,) uint64 — keys changed by either branch
    key_hi: np.ndarray
    status: np.ndarray      # (k,) int8 — TW_* classification
    t_op: np.ndarray        # (k,) int8 — OP_DEL/INS/UPD or -1 (untouched)
    s_op: np.ndarray
    t_rowid: np.ndarray     # payload rows for review (0 = none)
    s_rowid: np.ndarray

    @property
    def k(self) -> int:
        return int(self.key_lo.shape[0])


def three_way_diff(engine: Engine, base: Snapshot, target: Snapshot,
                   source: Snapshot) -> ThreeWayDiff:
    """Classify every key changed by either branch vs. the base."""
    if not (base.schema.compatible_with(target.schema)
            and base.schema.compatible_with(source.schema)):
        raise ValueError("three_way_diff: incompatible schemas")
    d_t = signed_delta(engine.store, base.directory, target.directory)
    d_s = signed_delta(engine.store, base.directory, source.directory)
    ch_t, _ = collapse_pk(d_t)
    ch_s, _ = collapse_pk(d_s)
    t_idx, s_idx = _align_keys(ch_t, ch_s)
    k = t_idx.shape[0]
    key_lo = np.where(t_idx >= 0, ch_t.key_lo[np.maximum(t_idx, 0)],
                      ch_s.key_lo[np.maximum(s_idx, 0)])
    key_hi = np.where(t_idx >= 0, ch_t.key_hi[np.maximum(t_idx, 0)],
                      ch_s.key_hi[np.maximum(s_idx, 0)])
    t_op = np.where(t_idx >= 0, ch_t.op[np.maximum(t_idx, 0)], -1)
    s_op = np.where(s_idx >= 0, ch_s.op[np.maximum(s_idx, 0)], -1)
    t_rowid = np.where(t_idx >= 0, ch_t.plus_rowid[np.maximum(t_idx, 0)],
                       0).astype(np.uint64)
    s_rowid = np.where(s_idx >= 0, ch_s.plus_rowid[np.maximum(s_idx, 0)],
                       0).astype(np.uint64)
    both = (t_idx >= 0) & (s_idx >= 0)
    ti, si = np.maximum(t_idx, 0), np.maximum(s_idx, 0)
    same = both & (
        ((ch_t.op[ti] == OP_DEL) & (ch_s.op[si] == OP_DEL))
        | ((ch_t.op[ti] != OP_DEL) & (ch_s.op[si] != OP_DEL)
           & (ch_t.plus_row_lo[ti] == ch_s.plus_row_lo[si])
           & (ch_t.plus_row_hi[ti] == ch_s.plus_row_hi[si])))
    status = np.full((k,), TW_TARGET_ONLY, np.int8)
    status[(t_idx < 0)] = TW_SOURCE_ONLY
    status[same] = TW_BOTH_SAME
    status[both & ~same] = TW_BOTH_DIFFER
    return ThreeWayDiff(key_lo, key_hi, status.astype(np.int8),
                        t_op.astype(np.int8), s_op.astype(np.int8),
                        t_rowid, s_rowid)
