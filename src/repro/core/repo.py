"""The ``Repo`` facade (ISSUE 5): every porcelain verb, on refs.

One object, one resolver, one verb set — the Python twin of the statement
surface (``core.statements``) and the CLI (``repro.vcs_cli``). Every way to
name a version goes through ``Repo.resolve`` (one grammar, typed errors);
every verb maps 1:1 onto a statement and a CLI subcommand:

    ==============  ============================  =====================
    Repo method     statement                     CLI
    ==============  ============================  =====================
    branch          CREATE BRANCH d FROM m FOR..  branch d -t t ...
    drop_branch     DROP BRANCH d                 branch -d d
    tag             CREATE SNAPSHOT s FOR TABLE   snapshot s t
    drop_tag        DROP SNAPSHOT s               snapshot -d s
    clone           CLONE TABLE new FROM 'ref'    clone new ref
    diff            DIFF 'a' AGAINST 'b'          diff a b
    merge           MERGE BRANCH d INTO m MODE x  merge d m --mode x
    open_pr         OPEN PR FROM d INTO m         pr open d --into m
    check           CHECK PR n                    pr check n
    publish         PUBLISH PR n MODE x           publish n --mode x
    revert_pr       REVERT PR n                   revert-pr n
    close_pr        CLOSE PR n                    pr close n
    revert          REVERT TABLE t FROM 'a' TO    revert t a b
    restore         RESTORE TABLE t TO 'ref'      restore t ref
    log             LOG TABLE t [LIMIT n]         log t [-n N]
    status          STATUS                        status
    gc              GC                            gc
    fsck            FSCK [REPAIR]                 fsck [--repair]
    push            PUSH TO 'dir'                 push dir
    pull            PULL FROM 'dir'               pull dir
    fetch           FETCH FROM 'dir'              fetch dir
    (clone repo)    —                             clone new-store dir
    ==============  ============================  =====================

    ``push``/``pull``/``fetch`` exchange content-addressed pack objects
    with a remote directory (only missing digests transfer; pulled
    signatures are carried, never re-hashed); repo-level ``clone`` with
    ``--shallow`` imports refs up front and faults objects from the origin
    on first gather. See :mod:`repro.store`.

The facade is thin by design: verbs delegate to the engine/workspace layer
(which owns WAL logging and replay), so a statement-driven session and a
Repo-driven session write byte-identical WALs.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from . import telemetry
from .directory import Snapshot
from .diff import DiffResult, snapshot_diff
from .engine import CommitRecord, Engine, GCStats
from .merge import ConflictMode, MergeReport, plan_merge
from .refs import (Ref, RefLike, RefSyntaxError, ResolvedRef,
                   as_branch, parse_ref, resolve)

#: accepted spellings of each conflict mode (statement MODE / --mode)
MODE_ALIASES = {
    "fail": ConflictMode.FAIL,
    "skip": ConflictMode.SKIP, "ours": ConflictMode.SKIP,
    "accept": ConflictMode.ACCEPT, "theirs": ConflictMode.ACCEPT,
    "cell": ConflictMode.CELL,
}


def parse_mode(mode: Union[str, ConflictMode, None]) -> ConflictMode:
    if mode is None:
        return ConflictMode.FAIL
    if isinstance(mode, ConflictMode):
        return mode
    m = MODE_ALIASES.get(str(mode).lower())
    if m is None:
        raise ValueError(
            f"unknown conflict mode {mode!r} "
            f"(one of {', '.join(sorted(MODE_ALIASES))})")
    return m


class Repo:
    """Facade over :class:`Engine`: the full VCS verb set on refs.

    Data-plane DML (schemas, inserts, updates) stays on ``repo.engine`` —
    the facade adds exactly the version-control porcelain."""

    def __init__(self, engine: Optional[Engine] = None, **engine_kw):
        self.engine = engine if engine is not None else Engine(**engine_kw)

    # ------------------------------------------------------------ resolve
    def resolve(self, ref: RefLike,
                table: Optional[str] = None) -> ResolvedRef:
        """Resolve any ref form to ``ResolvedRef(table_physical, Snapshot)``
        — the single naming path behind every verb below."""
        return resolve(self.engine, ref, table)

    # ------------------------------------------------- data-plane sugar
    def create_table(self, name, schema, **kw):
        return self.engine.create_table(name, schema, **kw)

    def drop_table(self, name, **kw):
        return self.engine.drop_table(name, **kw)

    def table(self, name):
        return self.engine.table(name)

    def insert(self, table, batch):
        return self.engine.insert(table, batch)

    def update_by_keys(self, table, batch):
        return self.engine.update_by_keys(table, batch)

    def delete_by_keys(self, table, key_batch):
        return self.engine.delete_by_keys(table, key_batch)

    # ----------------------------------------------------- branches/tags
    def branch(self, name: str, tables: Optional[Sequence[str]] = None,
               from_ref: Optional[str] = None):
        """CREATE BRANCH name [FROM ref] [FOR (tables)] — tables default to
        every table of the source branch (trunk: every plain table)."""
        from .refs import BranchRef
        from .workspace import TRUNK
        src_name = self._branch_name(from_ref)
        if tables is None:
            # branch-only position: BranchRef skips bare-name ambiguity
            # (a table named "main" must not block repo.branch("dev"))
            br = as_branch(self.engine, BranchRef(src_name or TRUNK))
            tables = sorted(br.tables)
        return self.engine.create_branch(name, tables, src_name)

    def drop_branch(self, name: str) -> None:
        self.engine.drop_branch(self._branch_name(name))

    def branches(self) -> list:
        """(name, created_ts, logical tables) rows, name-sorted."""
        return [(b.name, b.created_ts, tuple(sorted(b.tables)))
                for b in self.engine.list_branches()]

    def tag(self, name: str, table_ref: RefLike) -> Snapshot:
        """CREATE SNAPSHOT name — tag the current head of a table.

        Only heads are taggable: the WAL ``snapshot`` record captures
        (name, table) and replay re-derives the directory from the live
        state, so tagging a historical horizon would not survive replay.
        Clone the historical ref instead."""
        if isinstance(table_ref, str) and table_ref in self.engine.tables:
            return self.engine.create_snapshot(name, table_ref)
        rr = self.resolve(table_ref)
        head = self.engine.table(rr.table).directory
        d = rr.snapshot.directory
        # head-ness by content (object sets), not object identity: a
        # restore rebuilds the head Directory from the same oids
        if (d.data_oids, d.tomb_oids) != (head.data_oids, head.tomb_oids):
            text = rr.ref.format() if rr.ref is not None else str(table_ref)
            raise ValueError(
                f"tag: {text} is not the current head — only heads can be "
                "tagged (CLONE the historical ref instead)")
        return self.engine.create_snapshot(name, rr.table)

    def drop_tag(self, name: str) -> None:
        self.engine.drop_snapshot(name)

    def snapshots(self) -> list:
        """(name, table, created_ts) rows, oldest first."""
        return self.engine.list_snapshots()

    # ------------------------------------------------------- clone/restore
    def clone(self, new_name: str, ref: RefLike, *,
              materialize: bool = False, with_indices: bool = False):
        """CLONE TABLE new FROM 'ref' — metadata-only unless materialized."""
        return self.engine.clone_table(new_name, ref,
                                       materialize=materialize,
                                       with_indices=with_indices)

    def restore(self, table: str, ref: RefLike) -> None:
        """RESTORE TABLE t TO 'ref' — git reset --hard (head rewrite; use
        :meth:`revert` for the history-preserving inverse-Δ form)."""
        self.engine.restore_table(table, ref)

    # --------------------------------------------------------------- diff
    def diff(self, a: RefLike, b: RefLike,
             table: Optional[str] = None) -> DiffResult:
        """SNAPSHOT DIFF between two refs: negative groups only in ``a``,
        positive only in ``b``. ``table`` is the context for table-less
        forms (HEAD, ts:, branch refs)."""
        ra = self.resolve(a, table)
        rb = self.resolve(b, table)
        return snapshot_diff(self.engine.store, ra.snapshot, rb.snapshot)

    # -------------------------------------------------------------- merge
    def merge(self, src: RefLike, into: RefLike,
              mode: Union[str, ConflictMode, None] = None,
              tables: Optional[Sequence[str]] = None):
        """MERGE 'src' INTO 'into'.

        Branch into branch: every shared table (or ``tables``) is planned
        onto ONE transaction and lands at ONE commit timestamp — the same
        all-or-nothing property as PR publish; returns {table: MergeReport}.
        Otherwise ``into`` names a table and ``src`` any snapshot ref;
        returns one MergeReport (lineage supplies the three-way base)."""
        from .merge import three_way_merge
        mode = parse_mode(mode)
        engine = self.engine
        src_br = as_branch(engine, src)
        # the into-position prefers an exact table name (same rule as
        # _table_name): "INTO TABLE x" must stay resolvable when a branch
        # shares the name — branch intent is spelled branch:x
        dst_br = (None if isinstance(into, str) and into in engine.tables
                  else as_branch(engine, into))
        if src_br is not None and dst_br is not None:
            logicals = (sorted(set(src_br.tables) & set(dst_br.tables))
                        if tables is None else list(tables))
            # structural conflicts between two refs that both EXIST are
            # ValueError, not UnknownRefError — `except KeyError` callers
            # probing for missing refs must not swallow them
            if not logicals:
                # silent no-op here would read as "merge happened"
                raise ValueError(
                    f"branches {src_br.name!r} and {dst_br.name!r} "
                    "share no tables — nothing to merge")
            for lg in logicals:
                if lg not in src_br.tables or lg not in dst_br.tables:
                    raise ValueError(
                        f"table {lg!r} is not on both branches "
                        f"{src_br.name!r} and {dst_br.name!r}")
            # Sibling of PullRequest.publish's atomic protocol (plan every
            # table onto ONE tx, commit at ONE ts) — kept separate because
            # the WAL semantics differ on purpose: publish is one
            # replayable record with unlogged sub-commits, while a branch
            # merge replays from its plainly-logged commit records. Keep
            # the two in sync when touching either.
            tx = engine.begin()
            planned: Dict[str, tuple] = {}
            for lg in logicals:
                target = dst_br.tables[lg]
                src_snap = engine.current_snapshot(src_br.tables[lg])
                base = (engine.find_common_base(target, src_snap.table)
                        or src_br.base.get(lg))
                report = MergeReport(used_base=base is not None)
                plan_merge(engine, target, src_snap, base, mode, report, tx)
                planned[lg] = (report, src_snap, target)
            with engine.op_kind("merge"):
                ts = tx.commit() if tx.staged else None
            out = {}
            for lg, (report, src_snap, target) in planned.items():
                report.commit_ts = ts
                if src_snap.table != target and src_snap.table in engine.tables:
                    engine.set_common_base(target, src_snap.table, src_snap)
                    engine.wal.append("set_base", a=target, b=src_snap.table,
                                      snap=src_snap)
                out[lg] = report
            return out
        target = self._table_name(into)
        src_snap = self.resolve(src, table=target).snapshot
        return three_way_merge(engine, target, src_snap, mode=mode)

    # ------------------------------------------------------ pull requests
    def open_pr(self, head: RefLike, base: Optional[RefLike] = None):
        """OPEN PR FROM head [INTO base] (base defaults to the trunk)."""
        return self.engine.open_pr(self._branch_name(base),
                                   self._branch_name(head))

    def pr(self, pr_id: int):
        from .refs import _pr
        return _pr(self.engine, int(pr_id), f"pr:{pr_id}")

    def check(self, pr_id: int, mode=None) -> list:
        """CHECK PR n — run the PR's CI checks against the ephemeral merged
        preview (a conflicting preview surfaces as one synthetic failure)."""
        return self.pr(pr_id).run_checks(parse_mode(mode))

    def publish(self, pr_id: int, mode=None) -> Dict[str, MergeReport]:
        return self.pr(pr_id).publish(mode=parse_mode(mode))

    def revert_pr(self, pr_id: int) -> Optional[int]:
        return self.pr(pr_id).revert_publish()

    def close_pr(self, pr_id: int) -> None:
        self.pr(pr_id).close()

    # ------------------------------------------------------------- revert
    def revert(self, table_ref: RefLike, from_ref: RefLike,
               to_ref: RefLike) -> Optional[int]:
        """REVERT TABLE t FROM 'a' TO 'b' — apply inverse Δ(a -> b) as a
        new commit (history-preserving, Δ-sized, strict by value)."""
        return self.engine.revert(self._table_name(table_ref),
                                  from_ref, to_ref)

    # ---------------------------------------------------------------- log
    def log(self, table_ref: RefLike,
            limit: Optional[int] = None) -> List[CommitRecord]:
        """LOG TABLE t — commit history of one table, newest first.

        Every entry is a :class:`CommitRecord` (ts, op kind, rows
        inserted/deleted) appended by the engine at apply time and
        reproduced identically by WAL replay."""
        table = self._table_name(table_ref)
        out = [r for r in reversed(self.engine.commit_log)
               if r.table == table]
        return out[:limit] if limit is not None else out

    # ------------------------------------------------------------- status
    def status(self) -> dict:
        """One deterministic summary of the repo: tables (head ts, retained
        versions), branches, snapshots, PRs, and the full telemetry
        registry snapshot (every registered counter, zeros included — the
        zero-rehash invariant is inspectable without a debugger)."""
        from .wal import CRC32C_IMPL
        e = self.engine
        st = e.store
        return {
            "ts": e.ts,
            "tables": [(n, e.tables[n].directory.ts,
                        len(e.tables[n].history))
                       for n in sorted(e.tables)],
            "branches": self.branches(),
            "snapshots": self.snapshots(),
            "prs": [(i, p.base_name, p.head_name, p.status)
                    for i, p in sorted(e.prs.items())],
            # integrity backend (ISSUE 10 satellite): which crc32c does the
            # framing — the pure-python fallback is ~100x slower and should
            # be visible, not silent
            "crc32c": CRC32C_IMPL,
            "store": {
                "resident": len(st._objects),
                "packed": len(st._packed),
                "packs": st.packs.root if st.packs is not None else None,
            },
            "metrics": dict(sorted(self.stats().items())),
        }

    # -------------------------------------------------------- telemetry
    def trace(self):
        """``with repo.trace() as t:`` — arm the span tracer for the block;
        ``t.roots`` holds the span forest afterwards (see
        :mod:`core.telemetry`)."""
        return telemetry.trace(self.engine)

    def stats(self) -> Dict[str, int]:
        """Snapshot of every registered metric (stable key set — the
        ``datagit stats`` schema)."""
        return telemetry.metrics_snapshot(self.engine)

    # ------------------------------------------------------------ remotes
    def push(self, remote: str) -> dict:
        """PUSH TO 'dir' — ship missing pack objects + the WAL to a remote
        directory and swing its refs (fast-forward only)."""
        from ..store.remote import push as _push
        return _push(self.engine, remote)

    def fetch(self, remote: str, pack_dir: Optional[str] = None) -> dict:
        """FETCH FROM 'dir' — copy missing pack objects locally without
        changing any repo state (warm-up for shallow clones and pulls)."""
        from ..store.remote import fetch as _fetch
        return _fetch(self.engine, remote, pack_dir)

    def pull(self, remote: str, pack_dir: Optional[str] = None) -> dict:
        """PULL FROM 'dir' — fast-forward this repo to the remote's state,
        fetching only missing objects; swaps ``self.engine``. Carried
        signatures are imported verbatim (``rows_rehashed`` stays 0)."""
        from ..store.remote import pull as _pull
        self.engine, stats = _pull(self.engine, remote, pack_dir)
        return stats

    # ----------------------------------------------------------------- gc
    def gc(self) -> GCStats:
        return self.engine.gc()

    def fsck(self, *, sample: float = 1.0, check_replay: bool = True,
             repair: bool = False):
        """FSCK [REPAIR] — verify carried signatures, reachability, refs,
        and WAL-replay equivalence; ``repair`` quarantines and rebuilds
        (see :func:`core.fsck.fsck`). Returns an :class:`FsckReport`."""
        from .fsck import fsck as _fsck
        return _fsck(self.engine, sample=sample, check_replay=check_replay,
                     repair=repair)

    # ------------------------------------------------------------ helpers
    def _table_name(self, ref: RefLike) -> str:
        """Resolve a TABLE-position argument: an exact table name wins
        outright (``LOG TABLE orders`` must not go ambiguous because a
        snapshot shares the name); anything else takes the ref resolver."""
        if isinstance(ref, str) and ref in self.engine.tables:
            return ref
        return self.resolve(ref).table

    def _branch_name(self, ref: Optional[RefLike]) -> Optional[str]:
        """Branch NAME from a ref ('dev' / 'branch:dev'); None passes."""
        if ref is None:
            return None
        from .refs import BareRef, BranchRef
        r = parse_ref(ref) if isinstance(ref, str) else ref
        if isinstance(r, (BranchRef, BareRef)):
            return r.name
        raise RefSyntaxError(
            r.format() if isinstance(r, Ref) else str(ref),
            "expected a branch name ref (dev / branch:dev)")
