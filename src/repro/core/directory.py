"""Metadata directories and snapshots (paper §4).

A *directory* is the immutable metadata structure listing every object of a
table plus the MVCC visibility horizon. **A snapshot is just a frozen
directory** — which is why clone (copy the directory) and restore (repoint
the table at a directory) are O(metadata), the paper's headline property.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from .schema import Schema


@dataclass(frozen=True)
class Directory:
    data_oids: Tuple[int, ...]      # sorted
    tomb_oids: Tuple[int, ...]      # sorted
    ts: int                         # visibility horizon (commit ts <= ts)

    @staticmethod
    def empty(ts: int = 0) -> "Directory":
        return Directory((), (), ts)

    def with_objects(self, new_data=(), new_tombs=(), *, ts: int) -> "Directory":
        return Directory(tuple(sorted(set(self.data_oids) | set(new_data))),
                         tuple(sorted(set(self.tomb_oids) | set(new_tombs))),
                         ts)

    def replace(self, drop_data=(), drop_tombs=(), add_data=(), add_tombs=(),
                *, ts: Optional[int] = None) -> "Directory":
        return Directory(
            tuple(sorted((set(self.data_oids) - set(drop_data)) | set(add_data))),
            tuple(sorted((set(self.tomb_oids) - set(drop_tombs)) | set(add_tombs))),
            self.ts if ts is None else ts,
        )

    def meta_nbytes(self) -> int:
        """Metadata size — what clone actually copies (Table 1 'Space')."""
        return 16 * (len(self.data_oids) + len(self.tomb_oids)) + 8


@dataclass(frozen=True)
class Snapshot:
    """A named (git tag) or timestamp (git commit) snapshot of one table."""
    name: Optional[str]             # None for anonymous/timestamp snapshots
    table: str
    schema: Schema
    directory: Directory
    created_ts: int

    @property
    def ts(self) -> int:
        return self.directory.ts
