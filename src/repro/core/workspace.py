"""Workflow porcelain (ISSUE 3): branch refs, data pull requests, atomic
publish, and Δ-based revert — the team layer over the clone/diff/merge
plumbing (paper §1/§6: "creating branches for isolated experimentation,
submitting pull requests for change review ... published to production in
atomic transactions").

Design invariants (documented in ROADMAP "Workflow"):

* **Branch** = a named set of metadata-only table clones plus the recorded
  branch-point snapshots. Creating/dropping a branch is ONE WAL record; the
  per-table clones are unlogged sub-operations re-derived at replay.
* **Pull request** = head branch -> base branch with the base horizon
  *pinned* at open time: review diffs are stable while the base moves on,
  and ``Engine.gc`` keeps both the pinned objects and the PITR history
  entries backing every pin.
* **Atomic publish** = plan-then-commit: every table's merge edits are
  staged on ONE transaction (``merge.plan_merge``) and committed at ONE
  timestamp; any conflict or failing CI check raises before the commit, and
  the two-phase ``Engine._commit`` unwinds seal-time failures — so a
  partial publish is impossible. The WAL carries a single replayable
  ``publish`` record.
* **CI checks** run against an *ephemeral isolated preview*: a scratch
  engine sharing the immutable object store, holding metadata clones of the
  base tables with the PR merged in. On exit every preview object is
  deleted and the oid counter rolled back, so previews are invisible to the
  WAL, the live timestamp sequence, and replay determinism.
* **Revert** applies the *inverse* signed delta as a NEW commit — history
  is preserved (the published state stays reachable via PITR) and the work
  is ∝ Δ, never ∝ table size. Strict by value: if the current row is no
  longer the one being reverted away, ``RevertConflict`` raises.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import ops
from . import telemetry
from .delta import signed_delta
from .diff import DiffResult, gather_payload, gather_rowsigs, snapshot_diff
from .directory import Snapshot
from .merge import (OP_DEL, OP_INS, ConflictMode, MergeConflictError,
                    MergeReport, collapse_pk, plan_merge)
from .faults import crash_point, register
from .refs import (UnknownRefError, require, resolve as resolve_ref,
                   suggest, validate_name)
from .sigs import SigBatch
from .table import Table

CP_PUBLISH_PLANNED = register(
    "workspace.publish.planned",
    "after every table's merge is planned but before the multi-table "
    "commit — nothing durable yet, recovery must show no publish")
CP_PUBLISH_PRE_LOG = register(
    "workspace.publish.pre_log",
    "after the publish commit swung the live directories but before the "
    "single 'publish' WAL record — the record IS the commit point, so "
    "recovery must show no publish at all")
CP_REVERT_PUBLISH_PRE_LOG = register(
    "workspace.revert_publish.pre_log",
    "after the inverse-delta commit but before the 'publish_revert' "
    "record — recovery must show the PR still published")
CP_REVERT_PRE_LOG = register(
    "workspace.revert.pre_log",
    "after the inverse-delta commit but before the 'revert' record — "
    "recovery must show the revert absent")

SP_PUBLISH = telemetry.register_span(
    "publish", "atomic publish of a PR: checks, per-table planning, one "
    "multi-table commit")
SP_REVERT_PUBLISH = telemetry.register_span(
    "revert_publish", "undo a publish with inverse signed deltas at one "
    "shared timestamp")
SP_REVERT = telemetry.register_span(
    "revert", "one-table inverse-Δ revert applied as a new commit")

TRUNK = "main"

_NONE = np.iinfo(np.int64).max


class RevertConflict(Exception):
    """The current state no longer carries the change being reverted."""


class PublishBlocked(Exception):
    """Publish refused: one or more CI checks failed (or the merge preview
    itself conflicted). ``checks`` holds every CheckResult of the run."""

    def __init__(self, pr: "PullRequest", checks: List["CheckResult"]):
        failed = [c.name for c in checks if not c.ok]
        super().__init__(
            f"PR #{pr.id} {pr.head_name}->{pr.base_name}: "
            f"{len(failed)} failing check(s): {', '.join(failed)}")
        self.pr = pr
        self.checks = checks


@dataclass
class CheckResult:
    name: str
    ok: bool
    error: Optional[str] = None
    # True only for the synthetic result run_checks emits when the merge
    # preview itself conflicts (user checks never set this — publish uses
    # it to route conflicts to MergeConflictError instead of
    # PublishBlocked, and a user check named "merge" must not be mistaken
    # for it)
    synthetic: bool = False


@dataclass
class Branch:
    """A named set of metadata-only clones + their branch-point snapshots."""
    name: str
    tables: Dict[str, str]        # logical name -> physical table name
    base: Dict[str, Snapshot]     # logical name -> branch-point snapshot
    parent: Optional[str]         # parent branch name (None = trunk)
    created_ts: int

    def physical(self, logical: str) -> str:
        return self.tables[logical]


# --------------------------------------------------------------------------
# branch refs
# --------------------------------------------------------------------------

def branch_table_name(branch: str, logical: str) -> str:
    return f"{branch}/{logical}"


def resolve_branch(engine, name: Optional[str]) -> Branch:
    """A registered branch, or the synthesized trunk view (physical ==
    logical over the engine's plain tables). UnknownRefError otherwise."""
    if name in (None, TRUNK) and TRUNK not in engine.branches:
        # index aux tables are internal: branching one as a first-class
        # table would orphan it (never maintained, polluting diffs/status)
        aux = {spec.aux_table for specs in engine.indices.values()
               for spec in specs}
        plain = {n: n for n in engine.tables
                 if "/" not in n and n not in aux}
        return Branch(TRUNK, plain, {}, None, 0)
    name = name if name is not None else TRUNK
    return require(engine.branches, name, "branch", f"branch:{name}")


def create_branch(engine, name: str, tables: Sequence[str],
                  from_ref: Optional[str] = None, *, _log=True) -> Branch:
    """Clone ``tables`` under the ``name/`` namespace in one WAL-logged
    operation, recording the branch-point snapshot per table."""
    if _log:
        # user-facing creations only — replay must load pre-grammar names
        validate_name(name, "branch name")
    if not name or name == TRUNK or "/" in name:
        raise ValueError(f"invalid branch name {name!r}")
    if name in engine.branches:
        raise ValueError(f"branch {name} exists")
    tables = tuple(tables)
    if from_ref in (None, TRUNK):
        parent, src = None, {lg: lg for lg in tables}
    else:
        parent_branch = resolve_branch(engine, from_ref)
        parent = from_ref
        src = {}
        for lg in tables:
            if lg not in parent_branch.tables:
                raise UnknownRefError(
                    lg, f"branch {from_ref!r} has no table {lg!r}",
                    suggest(lg, parent_branch.tables))
            src[lg] = parent_branch.physical(lg)
    for lg in tables:
        require(engine.tables, src[lg], "table")
        if branch_table_name(name, lg) in engine.tables:
            raise ValueError(f"table {branch_table_name(name, lg)} exists")
    mapping, bases = {}, {}
    for lg in tables:
        snap = engine.current_snapshot(src[lg])
        phys = branch_table_name(name, lg)
        engine.clone_table(phys, snap, _log=False)
        mapping[lg] = phys
        bases[lg] = snap
    br = Branch(name, mapping, bases, parent, engine.ts)
    engine.branches[name] = br
    if _log:
        engine.wal.append("create_branch", name=name, tables=tables,
                          from_ref=parent)
    return br


def drop_branch(engine, name: str, *, _log=True) -> None:
    br = require(engine.branches, name, "branch", f"branch:{name}")
    # open PRs still need the branch for review/publish; published-but-not
    # -closed PRs still need it for revert_publish (GC pins their pre/post
    # states for exactly that reason)
    holders = [pr.id for pr in engine.prs.values()
               if pr.status in ("open", "published")
               and name in (pr.base_name, pr.head_name)]
    if holders:
        raise ValueError(f"branch {name} is referenced by live PR(s) "
                         f"{holders}; close or revert them first")
    for phys in br.tables.values():
        if phys in engine.tables:
            engine.drop_table(phys, _log=False)
    del engine.branches[name]
    if _log:
        engine.wal.append("drop_branch", name=name)


# --------------------------------------------------------------------------
# pull requests
# --------------------------------------------------------------------------

class CheckContext:
    """Read view a CI check gets: the ephemeral merged preview tables."""

    def __init__(self, engine, tables: Dict[str, str]):
        self.engine = engine
        self.tables = tables            # logical -> preview physical

    def table(self, logical: str) -> Table:
        return self.engine.table(self.tables[logical])

    def scan(self, logical: str):
        return self.table(logical).scan()

    def count(self, logical: str) -> int:
        return self.table(logical).count()


class PullRequest:
    """A data pull request: merge ``head`` branch into ``base``.

    The base horizon is pinned at open time (review stability + GC pin);
    ``publish`` lands every table at one commit timestamp or not at all."""

    def __init__(self, engine, pr_id: int, base_name: str, head_name: str):
        self.engine = engine
        self.id = pr_id
        self.base_name = base_name
        self.head_name = head_name
        head = engine.branches[head_name]
        self.tables: Dict[str, str] = dict(head.tables)
        base_branch = resolve_branch(engine, base_name)
        for lg in self.tables:
            if lg not in base_branch.tables:
                raise UnknownRefError(
                    lg, f"base branch {base_name!r} has no table {lg!r}",
                    suggest(lg, base_branch.tables))
        # pinned base horizon: review is against the base AS OF open time
        self.base_pins: Dict[str, Snapshot] = {
            lg: engine.current_snapshot(self._base_physical(lg))
            for lg in self.tables}
        self.checks: List[Tuple[str, Callable]] = []
        self.status = "open"            # open | published | reverted | closed
        self.publish_ts: Optional[int] = None
        self.pre_publish: Dict[str, Snapshot] = {}
        self.post_publish: Dict[str, Snapshot] = {}
        self.publish_reports: Dict[str, MergeReport] = {}

    # ------------------------------------------------------------ helpers
    def _base_physical(self, logical: str) -> str:
        return resolve_branch(self.engine, self.base_name).physical(logical)

    def _merge_base(self, logical: str) -> Optional[Snapshot]:
        """Three-way base: lineage first (kept fresh by publishes), falling
        back to the head branch's recorded branch point."""
        base = self.engine.find_common_base(self._base_physical(logical),
                                            self.tables[logical])
        if base is None:
            base = self.engine.branches[self.head_name].base.get(logical)
        return base

    # ------------------------------------------------------------- review
    def diff(self) -> Dict[str, DiffResult]:
        """Per-table review diff: pinned base horizon vs head current.
        Repeated review rounds are served by the delta cache."""
        return {lg: snapshot_diff(self.engine.store, self.base_pins[lg],
                                  self.engine.current_snapshot(phys))
                for lg, phys in self.tables.items()}

    def dry_run_merge(self, mode: ConflictMode = ConflictMode.FAIL
                      ) -> Dict[str, MergeReport]:
        """Plan every table's merge into a discarded transaction: the full
        conflict report with zero mutation (no objects sealed, no commit)."""
        reports = {}
        for lg, phys in self.tables.items():
            report = MergeReport()
            base = self._merge_base(lg)
            report.used_base = base is not None
            tx = self.engine.begin()    # discarded: plan-only
            try:
                plan_merge(self.engine, self._base_physical(lg),
                           self.engine.current_snapshot(phys), base, mode,
                           report, tx)
            except MergeConflictError as exc:
                report = exc.report
            reports[lg] = report
        return reports

    # ----------------------------------------------------------- CI gates
    def add_check(self, fn: Callable, name: Optional[str] = None) -> None:
        """Register a CI check. ``fn(ctx)`` sees the merged preview via a
        CheckContext; returning falsy (other than None) or raising fails."""
        self.checks.append((name or getattr(fn, "__name__", "check"), fn))

    @contextlib.contextmanager
    def _merged_preview(self, mode: ConflictMode):
        """Ephemeral isolated clone of the base tables with this PR merged
        in. Shares the immutable object store; on exit every object sealed
        for the preview is deleted and the oid counter rolled back, so the
        preview never perturbs the WAL, the timestamp sequence, or replay."""
        from .engine import Engine
        engine = self.engine
        store = engine.store
        oid0 = store._next_oid
        scratch = Engine()
        scratch.store = store
        scratch.ts = engine.ts
        mapping: Dict[str, str] = {}
        merge_err: Optional[MergeConflictError] = None
        try:
            for lg in self.tables:
                t_src = engine.table(self._base_physical(lg))
                t = Table(lg, t_src.schema, store, t_src.directory.ts)
                t.directory = t_src.directory
                t.history = [(t_src.directory.ts, t_src.directory)]
                scratch.tables[lg] = t
                mapping[lg] = lg
            tx = scratch.begin()
            try:
                for lg, phys in self.tables.items():
                    plan_merge(scratch, lg,
                               engine.current_snapshot(phys),
                               self._merge_base(lg), mode, MergeReport(), tx)
                if tx.staged:
                    tx.commit(_log=False)
            except MergeConflictError as exc:
                merge_err = exc
            yield scratch, mapping, merge_err
        finally:
            for oid in range(oid0, store._next_oid):
                if store.has(oid):
                    store.delete(oid)
            store._next_oid = oid0

    def run_checks(self, mode: ConflictMode = ConflictMode.FAIL
                   ) -> List[CheckResult]:
        """Run every registered check against the ephemeral merged preview."""
        results: List[CheckResult] = []
        with self._merged_preview(mode) as (scratch, mapping, merge_err):
            if merge_err is not None:
                return [CheckResult(
                    "merge", False,
                    f"{merge_err.report.true_conflicts} true conflict(s)",
                    synthetic=True)]
            ctx = CheckContext(scratch, mapping)
            for name, fn in self.checks:
                try:
                    ok = fn(ctx)
                    ok = True if ok is None else bool(ok)
                    results.append(CheckResult(
                        name, ok, None if ok else "check returned falsy"))
                except Exception as exc:       # a failing check, not a bug
                    results.append(CheckResult(
                        name, False, f"{type(exc).__name__}: {exc}"))
        return results

    # ------------------------------------------------------------ publish
    def publish(self, mode: ConflictMode = ConflictMode.FAIL, *,
                _log=True, _skip_checks=False) -> Dict[str, MergeReport]:
        """Merge every table of the PR into the base branch atomically.

        Order of gates: CI checks (ephemeral preview) -> per-table merge
        planning (conflicts raise with nothing staged) -> ONE multi-table
        commit at ONE timestamp (two-phase, unwinds on seal-time failure).
        The WAL carries a single replayable ``publish`` record."""
        with telemetry.span(SP_PUBLISH):
            return self._publish(mode, _log, _skip_checks)

    def _publish(self, mode: ConflictMode, _log: bool,
                 _skip_checks: bool) -> Dict[str, MergeReport]:
        if self.status != "open":
            raise ValueError(f"PR #{self.id} is {self.status}, not open")
        engine = self.engine
        if self.checks and not _skip_checks:
            results = self.run_checks(mode)
            if any(not r.ok for r in results):
                # a conflicting preview (the synthetic result) falls
                # through to planning below, which raises the genuine
                # MergeConflictError with the full report — the exception
                # type must not depend on whether checks happen to be
                # registered
                if any(not r.ok and not r.synthetic for r in results):
                    raise PublishBlocked(self, results)
        pre = {lg: engine.current_snapshot(self._base_physical(lg))
               for lg in self.tables}
        tx = engine.begin()
        planned: Dict[str, Tuple[MergeReport, Snapshot]] = {}
        for lg, phys in self.tables.items():
            report = MergeReport()
            base = self._merge_base(lg)
            report.used_base = base is not None
            src = engine.current_snapshot(phys)
            plan_merge(engine, self._base_physical(lg), src, base, mode,
                       report, tx)
            planned[lg] = (report, src)
        crash_point(CP_PUBLISH_PLANNED)
        with engine.op_kind("publish"):
            ts = tx.commit(_log=False) if tx.staged else None
        for lg, (report, src) in planned.items():
            report.commit_ts = ts
            target = self._base_physical(lg)
            if src.table != target and src.table in engine.tables:
                engine.set_common_base(target, src.table, src)
        self.status = "published"
        self.publish_ts = ts
        self.pre_publish = pre
        self.post_publish = {
            lg: engine.current_snapshot(self._base_physical(lg))
            for lg in self.tables}
        self.publish_reports = {lg: r for lg, (r, _) in planned.items()}
        if _log:
            crash_point(CP_PUBLISH_PRE_LOG)
            engine.wal.append("publish", pr=self.id, mode=mode.value, ts=ts)
        return self.publish_reports

    def revert_publish(self, *, _log=True) -> Optional[int]:
        """Undo this PR's publish with inverse signed deltas: every base
        table gets the Δ(post -> pre) applied as a NEW commit at one shared
        timestamp. History-preserving — the published state stays reachable
        via PITR — and Δ-sized."""
        with telemetry.span(SP_REVERT_PUBLISH):
            if self.status != "published":
                raise ValueError(f"PR #{self.id} is {self.status}, "
                                 "not published")
            engine = self.engine
            tx = engine.begin()
            for lg in self.tables:
                plan_revert(engine, self._base_physical(lg),
                            self.pre_publish[lg], self.post_publish[lg], tx)
            with engine.op_kind("revert-publish"):
                ts = tx.commit(_log=False) if tx.staged else None
            self.status = "reverted"
            if _log:
                crash_point(CP_REVERT_PUBLISH_PRE_LOG)
                engine.wal.append("publish_revert", pr=self.id, ts=ts)
            return ts

    def close(self, *, _log=True) -> None:
        """Abandon an open PR, or release a published PR's pins."""
        if self.status not in ("open", "published"):
            raise ValueError(f"PR #{self.id} is already {self.status}")
        self.status = "closed"
        if _log:
            self.engine.wal.append("close_pr", pr=self.id)


def open_pr(engine, base: Optional[str], head: str, *,
            _log=True) -> PullRequest:
    """Open a pull request merging branch ``head`` into ``base`` (None or
    "main" = the trunk tables). Pins the base horizon."""
    require(engine.branches, head, "branch", f"branch:{head}")
    base_name = base if base is not None else TRUNK
    if base_name != TRUNK:
        require(engine.branches, base_name, "branch",
                f"branch:{base_name}")
    if base_name == head:
        raise ValueError("PR base and head are the same branch")
    pr = PullRequest(engine, engine._next_pr_id, base_name, head)
    engine._next_pr_id += 1
    engine.prs[pr.id] = pr
    if _log:
        engine.wal.append("open_pr", pr=pr.id, base=base_name, head=head)
    return pr


# --------------------------------------------------------------------------
# Δ-based revert
# --------------------------------------------------------------------------

def plan_revert(engine, table: str, from_snap: Snapshot, to_snap: Snapshot,
                tx) -> bool:
    """Stage the inverse of Δ(from -> to) against ``table``'s CURRENT state.

    Strict by value: a row the revert would delete must still carry the
    to-side value (by 128-bit row signature), and a key it would re-insert
    must not have been re-taken since — otherwise ``RevertConflict``.
    Returns True iff anything was staged."""
    t = engine.table(table)
    if not (t.schema.compatible_with(from_snap.schema)
            and t.schema.compatible_with(to_snap.schema)):
        raise ValueError("revert: incompatible schemas")
    inv = signed_delta(engine.store, from_snap.directory,
                       to_snap.directory).inverse()
    if inv.n == 0:
        return False
    store = engine.store
    if t.schema.has_pk:
        # per key: − rows are the to-side state to remove, + rows the
        # from-side state to restore (collapse drops pure moves)
        ch, _ = collapse_pk(inv)
        needs_del = ch.op != OP_INS
        rid = t.locate_keys(ch.key_lo[needs_del], ch.key_hi[needs_del])
        if (rid == 0).any():
            raise RevertConflict(
                f"{table}: {int((rid == 0).sum())} reverted key(s) no "
                "longer present")
        cur_lo, cur_hi = gather_rowsigs(store, rid)
        exp_lo, exp_hi = gather_rowsigs(store, ch.minus_rowid[needs_del])
        moved = (cur_lo != exp_lo) | (cur_hi != exp_hi)
        if moved.any():
            raise RevertConflict(
                f"{table}: {int(moved.sum())} key(s) changed since the "
                "reverted state")
        re_ins = ch.op == OP_INS       # key was deleted from->to: restore it
        if re_ins.any():
            back = t.locate_keys(ch.key_lo[re_ins], ch.key_hi[re_ins])
            if (back != 0).any():
                raise RevertConflict(
                    f"{table}: {int((back != 0).sum())} reverted key(s) "
                    "re-taken since")
        if rid.shape[0]:
            tx.delete_rowids(table, rid)
        ins_rowids = ch.plus_rowid[ch.op != OP_DEL]
        if ins_rowids.shape[0]:
            # ch is key-sorted and the mask preserves order: one run —
            # the seal reuses the carried signatures and skips its sort
            payload, sigs = gather_payload(store, t.schema, ins_rowids,
                                           with_sigs=True,
                                           runs=SigBatch.sorted_run())
            tx.insert(table, payload, sigs=sigs)
        return bool(rid.shape[0] or ins_rowids.shape[0])
    # NoPK: per value group, net > 0 restores copies of the from-side
    # value, net < 0 deletes that many visible duplicates
    s = inv.merge_by_key()
    _, agg = ops.diff_aggregate(s.row_lo, s.row_hi, s.sign, presorted=True)
    starts, nets = agg.run_starts, agg.run_sums.astype(np.int64)
    pos = np.arange(s.n, dtype=np.int64)
    first_plus = np.minimum.reduceat(np.where(s.sign > 0, pos, _NONE), starts)
    ins_g = np.flatnonzero(nets > 0)
    del_g = np.flatnonzero(nets < 0)
    staged = False
    if del_g.shape[0]:
        need = -nets[del_g]
        rids = t.locate_rowsig_multi(s.row_lo[starts][del_g],
                                     s.row_hi[starts][del_g], need, flat=True)
        if int(rids.shape[0]) != int(need.sum()):
            raise RevertConflict(
                f"{table}: {int(need.sum()) - int(rids.shape[0])} reverted "
                "row(s) no longer present")
        tx.delete_rowids(table, rids)
        staged = True
    if ins_g.shape[0]:
        rep = s.rowid[np.minimum(first_plus[ins_g], s.n - 1)]
        ins_rowids = np.repeat(rep, nets[ins_g])
        # groups ascend in value(=key) order and repeats are adjacent:
        # the rowid sequence is one key-sorted run
        payload, sigs = gather_payload(store, t.schema, ins_rowids,
                                       with_sigs=True,
                                       runs=SigBatch.sorted_run())
        tx.insert(table, payload, sigs=sigs)
        staged = True
    return staged


def revert(engine, table: str, from_ref, to_ref, *,
           _log=True) -> Optional[int]:
    """``engine.revert``: one-table inverse-Δ revert as a new commit.
    Refs resolve against ``table`` (so ts:/HEAD/~n forms work); returns
    the commit ts (None when Δ(from -> to) is empty)."""
    with telemetry.span(SP_REVERT):
        require(engine.tables, table, "table")
        from_snap = resolve_ref(engine, from_ref, table=table).snapshot
        to_snap = resolve_ref(engine, to_ref, table=table).snapshot
        tx = engine.begin()
        staged = plan_revert(engine, table, from_snap, to_snap, tx)
        with engine.op_kind("revert"):
            ts = tx.commit(_log=False) if staged else None
        if _log:
            crash_point(CP_REVERT_PRE_LOG)
            engine.wal.append("revert", table=table, snap_from=from_snap,
                              snap_to=to_snap, ts=ts)
        return ts
