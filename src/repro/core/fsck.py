"""Integrity verification and salvage (`datagit fsck`, ISSUE 6).

The write-once signature substrate (ISSUE 4) makes verification nearly
free in the ForkBase sense: every sealed object *carries* the 128-bit
row/key signatures of its rows, so recomputing them from the stored
column values and comparing is a complete, self-contained integrity
check — no external checksum database needed.

``fsck(engine)`` verifies four layers:

1. **objects** — structural shape (every lane the same length), key-lane
   sortedness (the seal invariant readers bisect on), tombstone targets
   inside their declared object set, and carried signatures vs recomputed
   hashes (full or sampled);
2. **reachability** — every object referenced by any directory reachable
   from a ref root (table current+history, named snapshots, branch bases,
   PR pins, lineage bases) exists in the store;
3. **refs** — branch physical tables resolve;
4. **replay** — serialize -> deserialize -> ``Engine.replay`` reproduces
   identical content digests, timestamps, and porcelain registries.

``repair=True`` is salvage, not undo: corrupt/missing objects are
quarantined (dropped from the store and from *current* table directories
so the table scans again), the report lists every ref the quarantine
makes unreachable (PITR history at those horizons is damaged), and
derivable state is rebuilt — visibility/delta caches are reset for lazy
re-attach and secondary-index aux tables are re-backfilled from their
repaired base tables. Repair is NOT WAL-logged (the WAL describes the
un-corrupted history); a repaired engine no longer replay-matches its
log, and the report says so.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import telemetry
from .directory import Directory
from .objects import DataObject, TombstoneObject, rowid_oid
from .sigs import compute_sigs

__all__ = ["FsckIssue", "FsckReport", "fsck"]

SP_FSCK = telemetry.register_span(
    "fsck", "integrity verification: objects, reachability, refs, "
    "replay round-trip")


@dataclass
class FsckIssue:
    kind: str               # signature_mismatch | bad_structure |
    #                         unsorted_keys | bad_tombstone |
    #                         missing_object | dangling_ref |
    #                         pack_corrupt | replay_divergence |
    #                         replay_failure
    where: str              # ref context, e.g. "table:t@current"
    detail: str
    oid: Optional[int] = None

    def __str__(self):
        o = f" oid={self.oid}" if self.oid is not None else ""
        return f"[{self.kind}]{o} {self.where}: {self.detail}"


@dataclass
class FsckReport:
    issues: List[FsckIssue] = field(default_factory=list)
    objects_checked: int = 0
    rows_verified: int = 0
    directories_checked: int = 0
    refs_checked: int = 0
    packs_checked: int = 0
    replay_checked: bool = False
    # repair results
    repaired: bool = False
    quarantined: List[int] = field(default_factory=list)
    refs_unreachable: List[str] = field(default_factory=list)
    indices_rebuilt: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.issues

    def summary(self) -> str:
        head = ("clean" if self.ok
                else f"{len(self.issues)} issue(s)")
        s = (f"fsck: {head} — {self.objects_checked} object(s), "
             f"{self.rows_verified} row(s) verified, "
             f"{self.directories_checked} directories, "
             f"{self.refs_checked} refs"
             + (f", {self.packs_checked} pack(s)" if self.packs_checked
                else "")
             + (", replay checked" if self.replay_checked else ""))
        if self.repaired:
            s += (f"; repaired: {len(self.quarantined)} quarantined, "
                  f"{len(self.refs_unreachable)} ref(s) now unreachable, "
                  f"{len(self.indices_rebuilt)} index(es) rebuilt "
                  "(WAL no longer replays this state)")
        return s


def _digest(engine, table: str) -> str:
    """Order-independent content digest over full-row signatures."""
    _, _, lo, hi = engine.table(table).scan(with_sigs=True)
    order = np.lexsort((hi, lo))
    h = hashlib.sha256(lo[order].tobytes())
    h.update(hi[order].tobytes())
    return h.hexdigest()


def _state(engine) -> dict:
    out = {"__ts__": engine.ts,
           "__tables__": tuple(sorted(engine.tables)),
           "__snapshots__": tuple(sorted(engine.snapshots)),
           "__branches__": tuple(sorted(engine.branches)),
           "__prs__": tuple(sorted((i, p.status)
                                   for i, p in engine.prs.items()))}
    for name in engine.tables:
        out[name] = _digest(engine, name)
    return out


def _ref_roots(engine) -> List[Tuple[str, object, Directory]]:
    """Every (ref label, schema, directory) fsck must walk: table current
    state and PITR history, named snapshots, lineage bases, branch points,
    and live PR pins — the same roots ``Engine.gc`` marks from."""
    roots: List[Tuple[str, object, Directory]] = []
    for name, t in engine.tables.items():
        roots.append((f"table:{name}@current", t.schema, t.directory))
        for ts_, d in t.history:
            roots.append((f"table:{name}@ts:{ts_}", t.schema, d))
    for s in engine.snapshots.values():
        roots.append((f"snapshot:{s.name}", s.schema, s.directory))
    for (a, b), s in engine._base.items():
        roots.append((f"base:{a}~{b}", s.schema, s.directory))
    for bname, br in engine.branches.items():
        for lg, s in br.base.items():
            roots.append((f"branch:{bname} base {lg}", s.schema,
                          s.directory))
    for pid, pr in engine.prs.items():
        pins: Dict[str, object] = {}
        if pr.status == "open":
            pins = pr.base_pins
        elif pr.status == "published":
            pins = {**pr.pre_publish,
                    **{f"{k}(post)": v for k, v in pr.post_publish.items()}}
        for lg, s in pins.items():
            roots.append((f"pr:{pid} pin {lg}", s.schema, s.directory))
    return roots


def _check_data_object(obj: DataObject, schema, where: str,
                       verify_sigs: bool, report: FsckReport) -> None:
    n = obj.nrows
    lanes = {"commit_ts": obj.commit_ts, "row_lo": obj.row_lo,
             "row_hi": obj.row_hi, "key_lo": obj.key_lo,
             "key_hi": obj.key_hi}
    for cname, arr in obj.cols.items():
        lanes[f"col:{cname}"] = arr
    for lname, sig in obj.lob_sigs.items():
        lanes[f"lob_sig:{lname}"] = sig
    bad = [ln for ln, a in lanes.items() if len(a) != n]
    if bad:
        report.issues.append(FsckIssue(
            "bad_structure", where, f"lane length != nrows={n}: {bad}",
            obj.oid))
        return                      # shape is broken; nothing else is safe
    if n > 1:
        lo, hi = obj.key_lo, obj.key_hi
        ordered = (lo[1:] > lo[:-1]) | ((lo[1:] == lo[:-1])
                                        & (hi[1:] >= hi[:-1]))
        if not ordered.all():
            at = int(np.flatnonzero(~ordered)[0])
            report.issues.append(FsckIssue(
                "unsorted_keys", where,
                f"key lanes not lexsorted at row {at + 1}", obj.oid))
            return                  # bisecting readers would misbehave
    # signature verification needs the sealing-era schema: an object sealed
    # before an ALTER has fewer columns than the table's current schema —
    # verify only when the context schema matches the stored columns
    if (not verify_sigs or schema is None
            or tuple(schema.names) != tuple(obj.cols)):
        return
    rlo, rhi, klo, khi, lob = compute_sigs(schema, obj.cols)
    mism = (rlo != obj.row_lo) | (rhi != obj.row_hi)
    if schema.has_pk:
        mism |= (klo != obj.key_lo) | (khi != obj.key_hi)
    for cname, sig in lob.items():
        mism |= sig != obj.lob_sigs[cname]
    if mism.any():
        rows = np.flatnonzero(mism)
        report.issues.append(FsckIssue(
            "signature_mismatch", where,
            f"{rows.shape[0]} row(s) disagree with carried signatures "
            f"(first at row {int(rows[0])})", obj.oid))
    else:
        report.rows_verified += n


def _check_tombstone(obj: TombstoneObject, where: str,
                     report: FsckReport) -> None:
    n = obj.nrows
    lanes = {"target": obj.target, "key_lo": obj.key_lo,
             "key_hi": obj.key_hi, "commit_ts": obj.commit_ts}
    bad = [ln for ln, a in lanes.items() if len(a) != n]
    if bad:
        report.issues.append(FsckIssue(
            "bad_structure", where, f"lane length != nrows={n}: {bad}",
            obj.oid))
        return
    if n:
        declared = set(int(x) for x in np.asarray(obj.target_oids).ravel())
        actual = set(int(x) for x in np.unique(rowid_oid(obj.target)))
        stray = actual - declared
        if stray:
            report.issues.append(FsckIssue(
                "bad_tombstone", where,
                f"targets hit undeclared object(s) {sorted(stray)}",
                obj.oid))
    if n > 1 and not (obj.target[1:] >= obj.target[:-1]).all():
        report.issues.append(FsckIssue(
            "bad_tombstone", where, "target rowids not sorted", obj.oid))


def fsck(engine, *, sample: float = 1.0, check_replay: bool = True,
         repair: bool = False, seed: int = 0) -> FsckReport:
    """Verify the engine's integrity; optionally salvage (see module doc).

    ``sample`` is the fraction of reachable data objects whose signatures
    are recomputed (1.0 = every row of every object; structural and
    sortedness checks always run on all of them). Deterministic under
    ``seed``."""
    with telemetry.span(SP_FSCK):
        return _fsck(engine, sample=sample, check_replay=check_replay,
                     repair=repair, seed=seed)


def _fsck(engine, *, sample: float, check_replay: bool, repair: bool,
          seed: int) -> FsckReport:
    report = FsckReport()
    roots = _ref_roots(engine)
    report.directories_checked = len(roots)

    # ---- reachability + per-object context (first ref wins for schema)
    ctx: Dict[int, Tuple[str, object]] = {}
    missing: Dict[int, str] = {}
    for where, schema, d in roots:
        for oid in tuple(d.data_oids) + tuple(d.tomb_oids):
            if not engine.store.has(oid):
                missing.setdefault(oid, where)
            elif oid not in ctx:
                ctx[oid] = (where, schema)
    for oid, where in sorted(missing.items()):
        report.issues.append(FsckIssue(
            "missing_object", where,
            "directory references an object absent from the store", oid))

    # ---- ref resolvability (branch physical tables can dangle if a table
    # was force-dropped; snapshots/pins are self-contained by construction)
    for bname, br in engine.branches.items():
        for lg, phys in br.tables.items():
            report.refs_checked += 1
            if phys not in engine.tables:
                report.issues.append(FsckIssue(
                    "dangling_ref", f"branch:{bname}",
                    f"table {lg!r} -> physical {phys!r} does not exist"))
    report.refs_checked += len(roots)

    # ---- object verification (sampled signature recompute)
    oids = sorted(ctx)
    verify = set(oids)
    if sample < 1.0:
        rng = np.random.default_rng(seed)
        data_oids = [o for o in oids
                     if isinstance(engine.store.get(o), DataObject)]
        k = max(1, int(np.ceil(sample * len(data_oids)))) \
            if data_oids else 0
        verify = set(rng.choice(data_oids, size=k, replace=False).tolist()) \
            if k else set()
    for oid in oids:
        obj = engine.store.get(oid)
        where, schema = ctx[oid]
        report.objects_checked += 1
        if isinstance(obj, TombstoneObject):
            _check_tombstone(obj, where, report)
        else:
            _check_data_object(obj, schema, where, oid in verify, report)

    # ---- pack tier integrity (ISSUE 10): every packed oid's pack file
    # must exist (or be origin-backed), match its content address, and
    # frame-verify — bit rot in the spill tier is caught here even while
    # a heap copy masks it from readers
    packs = getattr(engine.store, "packs", None)
    if packs is not None:
        for oid, ent in sorted(engine.store._packed.items()):
            report.packs_checked += 1
            for why in packs.verify(ent[0]):
                report.issues.append(FsckIssue(
                    "pack_corrupt", f"pack:{ent[0][:12]}", why, oid))

    # ---- WAL replay equivalence (skipped when state is already damaged:
    # the live digests would throw on missing objects)
    if check_replay and not report.issues:
        from .engine import Engine
        from .wal import WAL
        report.replay_checked = True
        try:
            replayed = Engine.replay(WAL.deserialize(engine.wal.serialize()))
            live, redo = _state(engine), _state(replayed)
            if live != redo:
                keys = sorted(k for k in set(live) | set(redo)
                              if live.get(k) != redo.get(k))
                report.issues.append(FsckIssue(
                    "replay_divergence", "wal",
                    f"replayed state differs at {keys}"))
        except Exception as exc:
            report.issues.append(FsckIssue(
                "replay_failure", "wal", f"{type(exc).__name__}: {exc}"))

    if repair:
        _repair(engine, report, roots)
    return report


def _repair(engine, report: FsckReport, roots) -> None:
    """Salvage: quarantine bad objects and scrub them out of EVERY
    reachable directory (current, history, snapshots, pins), reporting
    each ref that loses state; then rebuild the derivable state. After
    repair the engine is internally consistent again — a follow-up
    ``fsck(check_replay=False)`` is clean — but the WAL still describes
    the undamaged history, so the replay check reports divergence until
    the store is re-created. See module doc."""
    import dataclasses

    bad_kinds = {"signature_mismatch", "bad_structure", "unsorted_keys",
                 "bad_tombstone"}
    bad = {i.oid for i in report.issues
           if i.kind in bad_kinds and i.oid is not None}
    gone = bad | {i.oid for i in report.issues
                  if i.kind == "missing_object"}
    if not gone:
        return
    report.repaired = True
    # caches first: they index the pre-quarantine object set; None means
    # lazy rebuild on the next visibility/delta read
    engine.store.vis_cache = None
    engine.store.delta_cache = None
    for oid in sorted(bad):
        if engine.store.has(oid):
            engine.store.delete(oid)
        report.quarantined.append(oid)
    for where, _, d in roots:
        if (set(d.data_oids) | set(d.tomb_oids)) & gone:
            report.refs_unreachable.append(where)

    def scrub(d: Directory) -> Directory:
        return d.replace(drop_data=gone, drop_tombs=gone)

    def scrub_snap(s):
        return dataclasses.replace(s, directory=scrub(s.directory))

    affected = []
    for name, t in engine.tables.items():
        if (set(t.directory.data_oids) | set(t.directory.tomb_oids)) & gone:
            affected.append(name)
        t.directory = scrub(t.directory)
        t.history[:] = [(hts, scrub(d)) for hts, d in t.history]
    for nm, s in list(engine.snapshots.items()):
        engine.snapshots[nm] = scrub_snap(s)
    for k, s in list(engine._base.items()):
        engine._base[k] = scrub_snap(s)
    for br in engine.branches.values():
        for lg, s in list(br.base.items()):
            br.base[lg] = scrub_snap(s)
    for pr in engine.prs.values():
        for pins in (pr.base_pins, getattr(pr, "pre_publish", None) or {},
                     getattr(pr, "post_publish", None) or {}):
            for lg, s in list(pins.items()):
                pins[lg] = scrub_snap(s)
    # derivable state: re-backfill secondary indices of repaired tables
    from .indices import backfill_index
    for name in affected:
        for spec in engine.indices.get(name, ()):
            if spec.aux_table in engine.tables:
                engine.drop_table(spec.aux_table, _log=False)
            backfill_index(engine, spec)
            report.indices_rebuilt.append(spec.aux_table)
