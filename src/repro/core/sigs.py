"""Canonical row/key signatures (the paper's §5.5.5 idea, universalized).

Every row gets two 128-bit signatures computed by the ``rowhash`` kernel:

  * ``row signature``  — over ALL columns: the row's value identity. Two rows
    are "the same data" iff their row signatures match (multiset semantics of
    SNAPSHOT DIFF, Listing 2).
  * ``key signature``  — over the PRIMARY KEY columns: the row's logical
    identity for the paper's §3 conflict scenarios. For NoPK tables the key
    signature IS the row signature (identity = full value, §3).

Each column contributes two uint32 lanes, the canonical 64-bit encoding of
its value. LOB columns contribute a blake2b-derived 64-bit content signature
computed once at ingest (host side — this is I/O-time work in the real
system), so diff/merge never hold LOB payloads in the aggregation working
set: exactly the paper's memory-saving trick.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Sequence, Tuple

import numpy as np

from ..kernels import ops
from .schema import CType, Schema

_F64_NAN = np.uint64(0x7FF8000000000000)
_F32_NAN = np.uint32(0x7FC00000)


def lob_sig64(arr: np.ndarray) -> np.ndarray:
    """Content signature (uint64) per LOB value. Ingest-time, host-side.

    The digest loop is unavoidably per-row (hashlib); keep the loop body to
    the bare C calls — ``np.fromiter`` stores python ints straight into the
    uint64 buffer, without per-element ``np.uint64`` round-trips."""
    b2b, ib = hashlib.blake2b, int.from_bytes
    return np.fromiter(
        (ib(b2b(v, digest_size=8).digest(), "little") for v in arr),
        np.uint64, count=arr.shape[0])


def _canon64(col: np.ndarray, ctype: CType,
             lob_sig: np.ndarray | None = None) -> np.ndarray:
    """Canonical uint64 encoding of a column's values."""
    if ctype is CType.LOB:
        assert lob_sig is not None
        return lob_sig.astype(np.uint64)
    if ctype is CType.I64:
        return col.view(np.uint64) if col.dtype == np.int64 else col.astype(np.int64).view(np.uint64)
    if ctype is CType.I32:
        return col.astype(np.int64).view(np.uint64)
    if ctype is CType.BOOL:
        return col.astype(np.uint64)
    if ctype is CType.F64:
        w = np.ascontiguousarray(col, np.float64).view(np.uint64).copy()
        w[np.isnan(col)] = _F64_NAN          # canonical NaN
        w[col == 0.0] = np.uint64(0)         # -0.0 -> +0.0
        return w
    if ctype is CType.F32:
        w32 = np.ascontiguousarray(col, np.float32).view(np.uint32).copy()
        w32[np.isnan(col)] = _F32_NAN
        w32[col == 0.0] = np.uint32(0)
        return w32.astype(np.uint64)
    raise TypeError(ctype)


def column_lanes(schema: Schema, batch: Dict[str, np.ndarray],
                 names: Sequence[str],
                 lob_sigs: Dict[str, np.ndarray] | None = None) -> np.ndarray:
    """(R, 2*len(names)) uint32 lane matrix for the given columns, in order."""
    n = batch[names[0]].shape[0] if names else 0
    lanes = np.empty((n, 2 * len(names)), np.uint32)
    for j, name in enumerate(names):
        ct = schema.column(name).ctype
        sig = (lob_sigs or {}).get(name)
        w = _canon64(batch[name], ct, sig)
        lanes[:, 2 * j] = (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        lanes[:, 2 * j + 1] = (w >> np.uint64(32)).astype(np.uint32)
    return lanes


def compute_sigs(schema: Schema, batch: Dict[str, np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                            Dict[str, np.ndarray]]:
    """Return (row_lo, row_hi, key_lo, key_hi, lob_sigs) for a batch.

    row/key signatures are uint64 arrays; computed via the rowhash kernel.
    """
    lob_sigs = {c.name: lob_sig64(batch[c.name])
                for c in schema.columns if c.ctype is CType.LOB}
    row_lanes = column_lanes(schema, batch, schema.names, lob_sigs)
    row_lo, row_hi = ops.signatures_from_lanes(row_lanes)
    if schema.has_pk:
        key_lanes = column_lanes(schema, batch, schema.primary_key, lob_sigs)
        key_lo, key_hi = ops.signatures_from_lanes(key_lanes)
    else:
        # NoPK: identity is the full value (paper §3)
        key_lo, key_hi = row_lo, row_hi
    return row_lo, row_hi, key_lo, key_hi, lob_sigs


def key_sigs_for_lookup(schema: Schema, key_batch: Dict[str, np.ndarray]
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Signatures for probe keys given just the PK columns."""
    assert schema.has_pk
    lanes = column_lanes(schema, key_batch, schema.primary_key, {})
    return ops.signatures_from_lanes(lanes)
