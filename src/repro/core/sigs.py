"""Canonical row/key signatures (the paper's §5.5.5 idea, universalized).

Every row gets two 128-bit signatures computed by the ``rowhash`` kernel:

  * ``row signature``  — over ALL columns: the row's value identity. Two rows
    are "the same data" iff their row signatures match (multiset semantics of
    SNAPSHOT DIFF, Listing 2).
  * ``key signature``  — over the PRIMARY KEY columns: the row's logical
    identity for the paper's §3 conflict scenarios. For NoPK tables the key
    signature IS the row signature (identity = full value, §3).

Each column contributes two uint32 lanes, the canonical 64-bit encoding of
its value. LOB columns contribute a blake2b-derived 64-bit content signature
computed once at ingest (host side — this is I/O-time work in the real
system), so diff/merge never hold LOB payloads in the aggregation working
set: exactly the paper's memory-saving trick.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..kernels import ops
from .schema import CType, Schema

_F64_NAN = np.uint64(0x7FF8000000000000)
_F32_NAN = np.uint32(0x7FC00000)

# When True, every carried ``runs`` claim is verified (per-run key
# monotonicity) before sealing — a producer falsely declaring sortedness
# is caught at the commit boundary instead of corrupting object order.
# Off by default: the check is O(n) per seal and the invariant is held by
# construction (Δ streams are emitted key-sorted). Tests flip it on.
DEBUG_VALIDATE_CARRY = False

_RUN1 = np.zeros((1,), np.int64)
_RUN1.setflags(write=False)


@dataclass
class SigBatch:
    """Signature sidecar carried alongside a row batch into the seal path.

    Signatures are write-once per sealed object, so a producer whose rows
    are *gathered from existing objects* (merge, revert, publish, clone
    materialization, compaction) can hand them to ``Engine._seal_inserts``
    verbatim — the apply path then never rehashes a row it did not create.

    ``None`` lanes mean "recompute": ``row_lo/hi is None`` ⇒ row value
    signatures must be rebuilt from the canonical lanes (e.g. after a
    schema change added a column), while carried ``key_lo/hi`` and
    ``lob_sigs`` still skip the per-key hashing and the per-LOB blake2b.
    ``lob_sigs`` may be partial — missing LOB columns are hashed.

    ``runs`` (int64 run-start offsets, ``runs[0] == 0``) is the PR 2
    sortedness invariant transplanted to the write side: every run
    ``[runs[i], runs[i+1])`` is sorted by (key_lo, key_hi). A single run
    means the batch is globally key-sorted and the seal-time sort is
    skipped outright; k runs are k-way merged (stable, ≡ np.lexsort).
    ``None`` means no ordering is known. Producers must NEVER claim
    sortedness that isn't real — mirror of the Δ-emission ``runs`` rule.
    """
    row_lo: Optional[np.ndarray]
    row_hi: Optional[np.ndarray]
    key_lo: Optional[np.ndarray]
    key_hi: Optional[np.ndarray]
    lob_sigs: Dict[str, np.ndarray] = field(default_factory=dict)
    runs: Optional[np.ndarray] = None

    @property
    def complete(self) -> bool:
        return self.row_lo is not None and self.key_lo is not None

    @property
    def sorted_by_key(self) -> bool:
        return self.runs is not None and self.runs.shape[0] <= 1

    @staticmethod
    def sorted_run() -> np.ndarray:
        """The single-run ``runs`` value: "this whole batch is key-sorted"."""
        return _RUN1


def validate_runs(key_lo: np.ndarray, key_hi: np.ndarray,
                  runs: np.ndarray) -> None:
    """Raise if any declared run is not (key_lo, key_hi)-monotone."""
    n = key_lo.shape[0]
    if n <= 1:
        return
    lo_desc = key_lo[1:] < key_lo[:-1]
    bad = lo_desc | ((key_lo[1:] == key_lo[:-1]) & (key_hi[1:] < key_hi[:-1]))
    if bad.any():
        allowed = np.zeros((n - 1,), bool)
        starts = runs[(runs > 0) & (runs < n)]
        allowed[starts - 1] = True          # run boundaries may descend
        if (bad & ~allowed).any():
            raise ValueError(
                "SigBatch claims key-sortedness that isn't real: "
                f"{int((bad & ~allowed).sum())} descending pair(s) inside "
                "declared runs")


def concat_sigs(parts: Sequence[SigBatch]) -> SigBatch:
    """Concatenate complete SigBatches, preserving NoPK key==row aliasing
    and the per-part run structure (``None`` anywhere poisons ``runs``)."""
    if len(parts) == 1:
        return parts[0]
    alias = all(p.key_lo is p.row_lo and p.key_hi is p.row_hi for p in parts)
    row_lo = np.concatenate([p.row_lo for p in parts])
    row_hi = np.concatenate([p.row_hi for p in parts])
    if alias:
        key_lo, key_hi = row_lo, row_hi
    else:
        key_lo = np.concatenate([p.key_lo for p in parts])
        key_hi = np.concatenate([p.key_hi for p in parts])
    lob = {c: np.concatenate([p.lob_sigs[c] for p in parts])
           for c in (parts[0].lob_sigs or {})}
    runs = None
    if all(p.runs is not None for p in parts):
        offs, off = [], 0
        for p in parts:
            offs.append((p.runs if p.runs.shape[0] else _RUN1) + off)
            off += p.row_lo.shape[0]
        runs = np.concatenate(offs)
    return SigBatch(row_lo, row_hi, key_lo, key_hi, lob, runs)


def lob_sig64(arr: np.ndarray) -> np.ndarray:
    """Content signature (uint64) per LOB value. Ingest-time, host-side.

    The digest loop is unavoidably per-row (hashlib); keep the loop body to
    the bare C calls — ``np.fromiter`` stores python ints straight into the
    uint64 buffer, without per-element ``np.uint64`` round-trips."""
    b2b, ib = hashlib.blake2b, int.from_bytes
    return np.fromiter(
        (ib(b2b(v, digest_size=8).digest(), "little") for v in arr),
        np.uint64, count=arr.shape[0])


def _canon64(col: np.ndarray, ctype: CType,
             lob_sig: np.ndarray | None = None) -> np.ndarray:
    """Canonical uint64 encoding of a column's values."""
    if ctype is CType.LOB:
        assert lob_sig is not None
        return lob_sig.astype(np.uint64)
    if ctype is CType.I64:
        return col.view(np.uint64) if col.dtype == np.int64 else col.astype(np.int64).view(np.uint64)
    if ctype is CType.I32:
        return col.astype(np.int64).view(np.uint64)
    if ctype is CType.BOOL:
        return col.astype(np.uint64)
    if ctype is CType.F64:
        w = np.ascontiguousarray(col, np.float64).view(np.uint64).copy()
        w[np.isnan(col)] = _F64_NAN          # canonical NaN
        w[col == 0.0] = np.uint64(0)         # -0.0 -> +0.0
        return w
    if ctype is CType.F32:
        w32 = np.ascontiguousarray(col, np.float32).view(np.uint32).copy()
        w32[np.isnan(col)] = _F32_NAN
        w32[col == 0.0] = np.uint32(0)
        return w32.astype(np.uint64)
    raise TypeError(ctype)


def column_lanes(schema: Schema, batch: Dict[str, np.ndarray],
                 names: Sequence[str],
                 lob_sigs: Dict[str, np.ndarray] | None = None) -> np.ndarray:
    """(R, 2*len(names)) uint32 lane matrix for the given columns, in order."""
    n = batch[names[0]].shape[0] if names else 0
    lanes = np.empty((n, 2 * len(names)), np.uint32)
    for j, name in enumerate(names):
        ct = schema.column(name).ctype
        sig = (lob_sigs or {}).get(name)
        w = _canon64(batch[name], ct, sig)
        lanes[:, 2 * j] = (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        lanes[:, 2 * j + 1] = (w >> np.uint64(32)).astype(np.uint32)
    return lanes


def compute_sigs(schema: Schema, batch: Dict[str, np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                            Dict[str, np.ndarray]]:
    """Return (row_lo, row_hi, key_lo, key_hi, lob_sigs) for a batch.

    row/key signatures are uint64 arrays; computed via the rowhash kernel.
    """
    lob_sigs = {c.name: lob_sig64(batch[c.name])
                for c in schema.columns if c.ctype is CType.LOB}
    row_lanes = column_lanes(schema, batch, schema.names, lob_sigs)
    row_lo, row_hi = ops.signatures_from_lanes(row_lanes)
    if schema.has_pk:
        key_lanes = column_lanes(schema, batch, schema.primary_key, lob_sigs)
        key_lo, key_hi = ops.signatures_from_lanes(key_lanes)
    else:
        # NoPK: identity is the full value (paper §3)
        key_lo, key_hi = row_lo, row_hi
    return row_lo, row_hi, key_lo, key_hi, lob_sigs


def resolve_sigs(schema: Schema, batch: Dict[str, np.ndarray],
                 sigs: Optional[SigBatch], stats=None) -> SigBatch:
    """Return a complete SigBatch for ``batch``, hashing only what was not
    carried. ``stats`` (an ``engine.CommitStats``) counts the split:
    ``rows_carried`` rode through on write-once signatures, ``rows_rehashed``
    paid the rowhash kernel, ``lob_rows_hashed`` paid per-row blake2b."""
    n = batch[schema.names[0]].shape[0] if schema.names else 0
    if sigs is not None:
        # a mismatched sidecar would seal a silently corrupt object
        # (nrows from the lanes, cols from the batch) — refuse up front
        for name, arr in (("row", sigs.row_lo), ("key", sigs.key_lo),
                          *((f"lob:{c}", a)
                            for c, a in sigs.lob_sigs.items())):
            if arr is not None and arr.shape[0] != n:
                raise ValueError(
                    f"SigBatch {name} lane has {arr.shape[0]} rows, "
                    f"batch has {n}")
        r = sigs.runs
        if r is not None and r.shape[0] and n and (
                r[0] != 0 or (r[1:] <= r[:-1]).any() or r[-1] >= n):
            raise ValueError(
                "SigBatch runs offsets malformed: need runs[0]==0, "
                f"strictly ascending, all < {n} rows")
    if (sigs is not None and sigs.complete
            and all(c.name in sigs.lob_sigs for c in schema.columns
                    if c.ctype is CType.LOB)):
        if stats is not None:
            stats.rows_carried += n
        if not schema.has_pk and sigs.key_lo is not sigs.row_lo:
            # NoPK: key IS the row signature — restore the alias so seal
            # and Δ emission keep recognizing it
            sigs = SigBatch(sigs.row_lo, sigs.row_hi, sigs.row_lo,
                            sigs.row_hi, sigs.lob_sigs, sigs.runs)
        return sigs
    carried_lob = dict(sigs.lob_sigs) if sigs is not None else {}
    lob_sigs = {}
    for c in schema.columns:
        if c.ctype is not CType.LOB:
            continue
        got = carried_lob.get(c.name)
        if got is None:
            got = lob_sig64(batch[c.name])
            if stats is not None:
                stats.lob_rows_hashed += n
        lob_sigs[c.name] = got
    row_lanes = column_lanes(schema, batch, schema.names, lob_sigs)
    row_lo, row_hi = ops.signatures_from_lanes(row_lanes)
    if stats is not None:
        stats.rows_rehashed += n
    if not schema.has_pk:
        key_lo, key_hi = row_lo, row_hi
        # the carried key order (if any) was the OLD row signature order —
        # meaningless for the recomputed signatures
        runs = None
    elif sigs is not None and sigs.key_lo is not None:
        key_lo, key_hi = sigs.key_lo, sigs.key_hi
        runs = sigs.runs
    else:
        key_lanes = column_lanes(schema, batch, schema.primary_key, lob_sigs)
        key_lo, key_hi = ops.signatures_from_lanes(key_lanes)
        runs = sigs.runs if sigs is not None else None
    return SigBatch(row_lo, row_hi, key_lo, key_hi, lob_sigs, runs)


def key_sigs_for_lookup(schema: Schema, key_batch: Dict[str, np.ndarray]
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Signatures for probe keys given just the PK columns."""
    assert schema.has_pk
    lanes = column_lanes(schema, key_batch, schema.primary_key, {})
    return ops.signatures_from_lanes(lanes)
