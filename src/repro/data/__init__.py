from .tokens import (TOKENS_SCHEMA, PinnedDataset, add_samples,  # noqa
                     create_token_table, decode_tokens, synth_corpus)
from .pipeline import BatchPipeline, PipelineCfg  # noqa
