"""Versioned training-data tables: the paper's engine as the data substrate.

A token dataset is a versioned table ``(sample_id, split, tokens LOB)`` in
``repro.core``. Data engineers branch it, edit/label/filter it, diff/review
the change, and merge back — the exact Listing-1 workflow — while training
jobs pin a *snapshot* so every run is reproducible and isolated from edits
(the paper's dev/prod isolation, applied to ML data).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..core import Column, CType, Engine, Schema, Snapshot

TOKENS_SCHEMA = Schema(
    columns=(
        Column("sample_id", CType.I64),
        Column("split", CType.I32),          # 0=train 1=eval
        Column("n_tokens", CType.I32),
        Column("tokens", CType.LOB),         # uint16/uint32 token bytes
    ),
    primary_key=("sample_id",),
)


def create_token_table(engine: Engine, name: str) -> None:
    engine.create_table(name, TOKENS_SCHEMA)


def add_samples(engine: Engine, table: str, sample_ids: np.ndarray,
                token_arrays, split: int = 0) -> int:
    """Append tokenized samples; tokens stored as little-endian uint32 LOBs."""
    blobs = [np.asarray(t, np.uint32).tobytes() for t in token_arrays]
    return engine.insert(table, {
        "sample_id": np.asarray(sample_ids, np.int64),
        "split": np.full((len(blobs),), split, np.int32),
        "n_tokens": np.asarray([len(t) for t in token_arrays], np.int32),
        "tokens": blobs,
    })


def decode_tokens(blob: bytes) -> np.ndarray:
    return np.frombuffer(blob, np.uint32)


def synth_corpus(engine: Engine, table: str, n_samples: int,
                 sample_len: int, vocab: int, seed: int = 0) -> None:
    """Synthetic corpus with a learnable structure (k-gram repetition)."""
    rng = np.random.default_rng(seed)
    toks = []
    for i in range(n_samples):
        base = rng.integers(2, vocab, size=max(4, sample_len // 4))
        arr = np.tile(base, 5)[:sample_len]
        toks.append(arr.astype(np.uint32))
    add_samples(engine, table, np.arange(n_samples), toks)


class PinnedDataset:
    """A snapshot-pinned view of a token table (training never sees edits
    that land after the pin)."""

    def __init__(self, engine: Engine, snapshot: Snapshot):
        self.engine = engine
        self.snapshot = snapshot
        t = engine.table(snapshot.table)
        batch, _ = t.scan(snapshot.directory)
        order = np.argsort(batch["sample_id"], kind="stable")
        self.sample_ids = batch["sample_id"][order]
        self.blobs = batch["tokens"][order]
        self.n = int(self.sample_ids.shape[0])

    def sample_tokens(self, i: int) -> np.ndarray:
        return decode_tokens(self.blobs[i])
