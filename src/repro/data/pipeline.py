"""Deterministic, resumable, host-sharded batch pipeline.

Every global step is a pure function of (snapshot, seed, step): a restarted
or re-scheduled worker regenerates exactly the batches it owes — the data-
side half of fault tolerance (the model side is the versioned checkpoint).
In a multi-host deployment each host materializes only its data-parallel
slice of the global batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from .tokens import PinnedDataset


@dataclass(frozen=True)
class PipelineCfg:
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


class BatchPipeline:
    def __init__(self, ds: PinnedDataset, cfg: PipelineCfg):
        assert cfg.global_batch % cfg.host_count == 0
        self.ds = ds
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count

    def _rng_for_step(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The local slice of the global batch for ``step`` (deterministic)."""
        rng = self._rng_for_step(step)
        # one global permutation per step; each host takes its slice
        idx = rng.integers(0, self.ds.n, size=self.cfg.global_batch)
        lo = self.cfg.host_index * self.local_batch
        idx = idx[lo:lo + self.local_batch]
        S = self.cfg.seq_len
        tokens = np.zeros((self.local_batch, S), np.int32)
        targets = np.full((self.local_batch, S), -1, np.int32)
        for r, i in enumerate(idx):
            t = self.ds.sample_tokens(int(i))
            if t.shape[0] < 2:
                continue
            take = min(S + 1, t.shape[0])
            tokens[r, :take - 1] = t[:take - 1]
            targets[r, :take - 1] = t[1:take]
        return {"tokens": tokens, "targets": targets}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
