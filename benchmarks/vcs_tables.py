"""Benchmarks mirroring the paper's tables (§6), scaled to this container.

Paper setup: TPC-H 100 GB lineitem (600M rows), change sets C1..C4 =
1k/10k/100k/1M updated rows. Ours: a synthetic lineitem at ``--rows``
(default 2M) with C1..C4 = 100/1k/10k/100k — same table:change ratios
within 1 order of magnitude; the REPORTED CLAIM (builtin ∝ Δ vs SQL ∝
table, 100-500x) is scale-free and reproduces here.

Each function returns a list of result dicts -> CSV rows.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.paper_vcs import (LINEITEM_SCHEMA, LINEITEM_SCHEMA_NOPK,
                                     gen_lineitem)
from repro.core import (ConflictMode, Engine, Snapshot, snapshot_diff,
                        sql_diff, three_way_merge)
from repro.core import telemetry
from repro.core.diff import gather_payload

CHANGE_SETS = {"C1": 100, "C2": 1_000, "C3": 10_000, "C4": 100_000}


def _visibility_builds(engine: Engine) -> int:
    """Tombstone-target-array builds so far (0 on engines without the
    visibility cache — i.e. the pre-cache seed code)."""
    cache = getattr(engine.store, "vis_cache", None)
    if cache is not None:
        return int(cache.builds)
    return 0


# ------------------------------------------------- visibility hot path

def diff_merge_hotpath(n_rows: int = 2_000_000, csizes=None,
                       warm_repeats: int = 3) -> List[Dict]:
    """Cold vs warm repeated diff + merge per change set (ISSUE 1).

    The warm timings measure exactly what the visibility cache buys:
    repeated SNAPSHOT DIFF between the *same* two directory versions must
    not rebuild the sorted tombstone-target arrays. ``visibility_builds``
    counts fresh target-array constructions engine-wide.
    """
    out = []
    for pk in (True, False):
        for cname, csize in (csizes or CHANGE_SETS).items():
            csize = min(csize, n_rows // 5)
            rng = np.random.default_rng([csize] + list(cname.encode()))
            engine, base = _mk_engine(n_rows, pk)
            sn1 = engine.create_snapshot("sn1", "lineitem")
            engine.clone_table("t", sn1)
            _random_update(engine, "t", base, csize, rng, pk)
            sn3 = engine.create_snapshot("sn3", "t")
            cur = engine.current_snapshot("lineitem")

            b0 = _visibility_builds(engine)
            t0 = time.perf_counter()
            d_cold = snapshot_diff(engine.store, cur, sn3)
            t_cold = time.perf_counter() - t0
            builds_cold = _visibility_builds(engine) - b0

            warm_times = []
            b1 = _visibility_builds(engine)
            for _ in range(warm_repeats):
                t0 = time.perf_counter()
                d_warm = snapshot_diff(engine.store, cur, sn3)
                warm_times.append(time.perf_counter() - t0)
            builds_warm = _visibility_builds(engine) - b1
            assert d_warm.n_groups == d_cold.n_groups == 2 * csize

            b2 = _visibility_builds(engine)
            t0 = time.perf_counter()
            rep = three_way_merge(engine, "lineitem", sn3, base=sn1,
                                  mode=ConflictMode.ACCEPT)
            t_merge = time.perf_counter() - t0
            builds_merge = _visibility_builds(engine) - b2

            out.append({
                "op": f"HotDiffMerge{'PK' if pk else 'NoPK'}",
                "change": cname, "rows": n_rows, "changed_rows": csize,
                "diff_cold_s": t_cold,
                "diff_warm_s": float(np.min(warm_times)),
                "diff_warm_avg_s": float(np.mean(warm_times)),
                "merge_s": t_merge,
                "visibility_builds_cold": builds_cold,
                "visibility_builds_warm": builds_warm,
                "visibility_builds_merge": builds_merge,
                "rows_scanned_diff": d_cold.stats.rows_scanned,
                "objects_scanned_diff": d_cold.stats.objects_scanned,
                "visibility_builds_stat": getattr(
                    d_cold.stats, "visibility_builds", 0),
                "merged_inserted": rep.inserted,
                "merged_deleted": rep.deleted,
                # full registry snapshot for the case's engine (ISSUE 8):
                # counters accumulate over seed+diffs+merge of THIS case
                "counters": telemetry.metrics_snapshot(engine),
            })
    return out


def _mk_engine(n_rows: int, pk: bool, seed: int = 0):
    engine = Engine()
    schema = LINEITEM_SCHEMA if pk else LINEITEM_SCHEMA_NOPK
    engine.create_table("lineitem", schema)
    base = gen_lineitem(n_rows, seed=seed)
    engine.insert("lineitem", base)
    return engine, base


def _random_update(engine: Engine, table: str, base, n: int, rng,
                   pk: bool, tag: int = 0):
    """Update n random rows (by PK when available; by rowid for NoPK)."""
    idx = rng.choice(base["l_orderkey"].shape[0], size=n, replace=False)
    newvals = {k: v[idx].copy() for k, v in base.items()}
    newvals["l_quantity"] = newvals["l_quantity"] + 1.0 + tag
    newvals["l_comment"] = np.array(
        [b"upd-%d-%d" % (tag, i) for i in range(n)], dtype=object)
    tx = engine.begin()
    if pk:
        tx.update_by_keys(table, newvals)
    else:
        t = engine.table(table)
        batch, rowids = t.scan()
        tx.delete_rowids(table, rowids[idx])
        tx.insert(table, newvals)
    tx.commit()
    return idx


# ------------------------------------------------- tiered store cold path

def coldstore_scenario(n_rows: int = 2_000_000, csizes=None) -> List[Dict]:
    """Fault-in cost of the tiered store (ISSUE 10): spill + evict the
    WHOLE heap to a pack directory, then time a diff and a merge that
    must fault every touched object back in. ``diff_warm_s`` re-times
    the same diff with everything resident again, so the pair brackets
    exactly what the heap tier buys on this container."""
    import shutil
    import tempfile

    from repro.store import attach_packs

    out = []
    for pk in (True, False):
        for cname, csize in (csizes or {"C3": 10_000}).items():
            csize = min(csize, n_rows // 5)
            rng = np.random.default_rng([csize, 10] + list(cname.encode()))
            engine, base = _mk_engine(n_rows, pk)
            sn1 = engine.create_snapshot("sn1", "lineitem")
            engine.clone_table("t", sn1)
            _random_update(engine, "t", base, csize, rng, pk)
            sn3 = engine.create_snapshot("sn3", "t")
            cur = engine.current_snapshot("lineitem")
            root = tempfile.mkdtemp(prefix="dg_coldstore_")
            try:
                attach_packs(engine.store, root)
                t0 = time.perf_counter()
                engine.store.spill_all()
                t_spill = time.perf_counter() - t0
                t0 = time.perf_counter()
                engine.store.evict_all()
                t_evict = time.perf_counter() - t0
                t0 = time.perf_counter()
                d_cold = snapshot_diff(engine.store, cur, sn3)
                t_diff_fault = time.perf_counter() - t0
                t0 = time.perf_counter()
                d_warm = snapshot_diff(engine.store, cur, sn3)
                t_diff_warm = time.perf_counter() - t0
                assert d_warm.n_groups == d_cold.n_groups
                engine.store.evict_all()
                t0 = time.perf_counter()
                three_way_merge(engine, "lineitem", sn3, base=sn1,
                                mode=ConflictMode.ACCEPT)
                t_merge_fault = time.perf_counter() - t0
                out.append({
                    "op": f"Coldstore{'PK' if pk else 'NoPK'}",
                    "change": cname, "rows": n_rows,
                    "changed_rows": csize,
                    "spill_s": t_spill, "evict_s": t_evict,
                    "diff_fault_s": t_diff_fault,
                    "diff_warm_s": t_diff_warm,
                    "merge_fault_s": t_merge_fault,
                    # store.* counters pin the tier traffic of the case
                    "counters": telemetry.metrics_snapshot(engine),
                })
            finally:
                shutil.rmtree(root, ignore_errors=True)
    return out


# ------------------------------------------------- fused probe microbench

def probe_scenario(n_rows: int = 2_000_000, repeats: int = 3) -> List[Dict]:
    """k-query point-lookup batches against the sealed table (ISSUE 9).

    PK: ``locate_keys`` over sampled key signatures; NoPK:
    ``locate_rowsig_multi(..., flat=True)`` over sampled row signatures —
    both exercise exactly the fused ``ops.probe128`` pass per object.
    Queries are pre-sorted by (lo, hi), matching the fused-probe contract
    (ROADMAP §Performance; the engine's hot callers get this for free).
    The per-case ``counters`` snapshot carries the ``probe.*`` group."""
    out = []
    n_queries = min(100_000, n_rows // 2)
    for pk in (True, False):
        engine, _ = _mk_engine(n_rows, pk)
        t = engine.table("lineitem")
        oids = t.directory.data_oids
        all_lo = np.concatenate([engine.store.get(o).key_lo for o in oids])
        all_hi = np.concatenate([engine.store.get(o).key_hi for o in oids])
        rng = np.random.default_rng([n_queries, int(pk)] + list(b"PRB"))
        idx = rng.choice(all_lo.shape[0], size=n_queries, replace=False)
        order = np.lexsort((all_hi[idx], all_lo[idx]))
        q_lo, q_hi = all_lo[idx][order], all_hi[idx][order]
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            if pk:
                found = t.locate_keys(q_lo, q_hi)
            else:
                found = t.locate_rowsig_multi(
                    q_lo, q_hi, np.ones((n_queries,), np.int64), flat=True)
            times.append(time.perf_counter() - t0)
        nfound = int((found != 0).sum()) if pk else int(found.shape[0])
        assert nfound == n_queries, (nfound, n_queries)
        out.append({
            "op": f"Probe{'PK' if pk else 'NoPK'}",
            "change": "C4", "rows": n_rows, "changed_rows": n_queries,
            "probe_s": float(np.min(times)),
            "counters": telemetry.metrics_snapshot(engine),
        })
    return out


# ------------------------------------------------- workflow porcelain

def workflow_scenario(n_rows: int = 2_000_000, csizes=None) -> List[Dict]:
    """Branch -> mutate -> PR review -> CI-gated atomic publish -> Δ revert
    (ISSUE 3), driven through the ref-unified ``Repo`` facade (ISSUE 5) —
    the bench doubles as the guard that the porcelain redesign stays off
    the hot path. Branch/diff/revert are ∝ metadata/Δ; publish pays the CI
    preview merge plus the real one."""
    from repro.core import Repo
    out = []
    for pk in (True, False):
        for cname, csize in (csizes or {"C3": 10_000, "C4": 100_000}).items():
            csize = min(csize, n_rows // 5)
            rng = np.random.default_rng([csize] + list(cname.encode()))
            engine, base = _mk_engine(n_rows, pk)
            repo = Repo(engine)

            t0 = time.perf_counter()
            repo.branch("dev", ["lineitem"])
            t_branch = time.perf_counter() - t0

            _random_update(engine, "dev/lineitem", base, csize, rng, pk)
            pr = repo.open_pr("dev")
            pr.add_check(lambda ctx: ctx.count("lineitem") == n_rows,
                         "row-count")

            t0 = time.perf_counter()
            d = repo.diff(f"pr:{pr.id}:base", f"pr:{pr.id}:head",
                          table="lineitem")
            t_diff = time.perf_counter() - t0

            t0 = time.perf_counter()
            repo.publish(pr.id)
            t_publish = time.perf_counter() - t0

            t0 = time.perf_counter()
            repo.revert_pr(pr.id)
            t_revert = time.perf_counter() - t0

            out.append({
                "op": f"Workflow{'PK' if pk else 'NoPK'}",
                "change": cname, "rows": n_rows, "changed_rows": csize,
                "branch_s": t_branch,
                "pr_diff_s": t_diff,
                "publish_s": t_publish,
                "revert_s": t_revert,
                "diff_groups": d.n_groups,
                "publish_ts": pr.publish_ts,
                "counters": telemetry.metrics_snapshot(engine),
            })
    return out


# ------------------------------------------------------------- Table 1

def table1_clone(n_rows: int = 2_000_000) -> List[Dict]:
    """Clone vs INSERT-SELECT, time and space (paper Table 1)."""
    out = []
    for pk in (True, False):
        engine, base = _mk_engine(n_rows, pk)
        bytes_before = engine.store.bytes_written
        t0 = time.perf_counter()
        engine.clone_table("clone_t", engine.create_snapshot("s", "lineitem"))
        t_clone = time.perf_counter() - t0
        clone_space = (engine.store.bytes_written - bytes_before
                       + engine.table("clone_t").directory.meta_nbytes())
        # INSERT INTO t SELECT * FROM lineitem
        schema = LINEITEM_SCHEMA if pk else LINEITEM_SCHEMA_NOPK
        engine.create_table("insert_t", schema)
        batch, _ = engine.table("lineitem").scan()
        bytes_before = engine.store.bytes_written
        t0 = time.perf_counter()
        engine.insert("insert_t", batch)
        t_insert = time.perf_counter() - t0
        insert_space = engine.store.bytes_written - bytes_before
        out.append({"op": f"Clone{'PK' if pk else 'NoPK'}",
                    "time_s": t_clone, "space_bytes": clone_space})
        out.append({"op": f"Insert{'PK' if pk else 'NoPK'}",
                    "time_s": t_insert, "space_bytes": insert_space})
        # materialized clone (ISSUE 4): a PHYSICAL copy that rides the
        # zero-rehash apply path — same bytes written as INSERT-SELECT,
        # none of its hashing/sorting (the gap IS the carry win)
        bytes_before = engine.store.bytes_written
        t0 = time.perf_counter()
        engine.clone_table("mat_t", "s", materialize=True)
        t_mat = time.perf_counter() - t0
        mat_space = engine.store.bytes_written - bytes_before
        out.append({"op": f"CloneMat{'PK' if pk else 'NoPK'}",
                    "time_s": t_mat, "space_bytes": mat_space})
    return out


# ---------------------------------------------------------- Tables 2+3

def table23_diff_merge(n_rows: int = 2_000_000) -> List[Dict]:
    """Diff and merge, builtin vs SQL, PK/NoPK × C1..C4 (Tables 2 & 3)."""
    out = []
    for pk in (True, False):
        for cname, csize in CHANGE_SETS.items():
            csize = min(csize, n_rows // 5)
            rng = np.random.default_rng([csize] + list(cname.encode()))
            engine, base = _mk_engine(n_rows, pk)
            sn1 = engine.create_snapshot("sn1", "lineitem")
            engine.clone_table("t", sn1)
            _random_update(engine, "t", base, csize, rng, pk)
            sn3 = engine.create_snapshot("sn3", "t")
            cur = engine.current_snapshot("lineitem")

            t0 = time.perf_counter()
            d_b = snapshot_diff(engine.store, cur, sn3)
            t_bi = time.perf_counter() - t0
            t0 = time.perf_counter()
            d_s = sql_diff(engine.store, cur, sn3)
            t_sql = time.perf_counter() - t0
            assert d_b.n_groups == d_s.n_groups == 2 * csize, (
                d_b.n_groups, d_s.n_groups)
            out.append({"op": f"Diff{'PK' if pk else 'NoPK'}",
                        "change": cname, "builtin_s": t_bi, "sql_s": t_sql,
                        "rows_scanned_builtin": d_b.stats.rows_scanned,
                        "rows_scanned_sql": d_s.stats.rows_scanned})

            # ---- merge: builtin three-way ACCEPT
            t0 = time.perf_counter()
            rep = three_way_merge(engine, "lineitem",
                                  sn3, base=sn1, mode=ConflictMode.ACCEPT)
            t_bim = time.perf_counter() - t0
            # ---- merge: SQL (Listing 4: materialize diff, delete, insert)
            engine2, base2 = _mk_engine(n_rows, pk, seed=0)
            s1b = engine2.create_snapshot("sn1", "lineitem")
            engine2.clone_table("t", s1b)
            _random_update(engine2, "t", base2, csize,
                           np.random.default_rng([csize] + list(cname.encode())), pk)
            s3b = engine2.create_snapshot("sn3", "t")
            t0 = time.perf_counter()
            dd = sql_diff(engine2.store, engine2.current_snapshot("lineitem"),
                          s3b)
            plus = dd.diff_cnt > 0
            minus = dd.diff_cnt < 0
            tx = engine2.begin()
            if pk:
                payload = gather_payload(engine2.store, dd.schema,
                                         dd.rowid[minus])
                tx.delete_by_keys("lineitem", {
                    "l_orderkey": payload["l_orderkey"],
                    "l_linenumber": payload["l_linenumber"]})
            else:
                t = engine2.table("lineitem")
                rids = t.locate_rowsig_multi(
                    dd.row_lo[minus], dd.row_hi[minus],
                    (-dd.diff_cnt[minus]).astype(np.int64), flat=True)
                tx.delete_rowids("lineitem", rids)
            ins = gather_payload(engine2.store, dd.schema, dd.rowid[plus])
            tx.insert("lineitem", ins)
            tx.commit()
            t_sqlm = time.perf_counter() - t0
            out.append({"op": f"Merge{'PK' if pk else 'NoPK'}",
                        "change": cname, "builtin_s": t_bim, "sql_s": t_sqlm,
                        "inserted": rep.inserted, "deleted": rep.deleted})
    return out


# ------------------------------------------------- Tables 4+5 / 6+7

def collaborative(n_rows: int = 2_000_000, overlap: float = 0.0,
                  csizes=None) -> List[Dict]:
    """4 engineers fork, update, merge back (Tables 4/5 no-conflict,
    Tables 6/7 with ``overlap`` fraction of PK overlap). Also emits the
    per-merge timeline of the C4 case (Figures 3/4)."""
    out = []
    csizes = csizes or CHANGE_SETS
    for pk in (True, False):
        for cname, csize in csizes.items():
            csize = min(csize, n_rows // 10)
            rng = np.random.default_rng(42)
            engine, base = _mk_engine(n_rows, pk)
            sn0 = engine.create_snapshot("sn0", "lineitem")
            n_eng = 4
            # partition the key space; optional overlap with next engineer
            perm = rng.permutation(base["l_orderkey"].shape[0])
            snaps = []
            for w in range(n_eng):
                engine.clone_table(f"T{w}", sn0)
                lo = w * csize
                idx = perm[lo:lo + csize].copy()
                if overlap > 0 and w > 0:
                    k = int(overlap * csize)
                    idx[:k] = perm[(w - 1) * csize:(w - 1) * csize + k]
                newvals = {c: v[idx].copy() for c, v in base.items()}
                newvals["l_quantity"] = newvals["l_quantity"] + 10.0 + w
                newvals["l_comment"] = np.array(
                    [b"eng%d-%d" % (w, i) for i in range(idx.shape[0])],
                    dtype=object)
                tx = engine.begin()
                if pk:
                    tx.update_by_keys(f"T{w}", newvals)
                else:
                    t = engine.table(f"T{w}")
                    _, rowids = t.scan()
                    tx.delete_rowids(f"T{w}", rowids[idx])
                    tx.insert(f"T{w}", newvals)
                tx.commit()
                snaps.append(engine.create_snapshot(f"pr{w}", f"T{w}"))
            # diff+merge each engineer's branch back, in sequence
            t_diffs, t_merges, conflicts = [], [], 0
            for w in range(n_eng):
                cur = engine.current_snapshot("lineitem")
                t0 = time.perf_counter()
                d = snapshot_diff(engine.store, cur, snaps[w])
                t_diffs.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                rep = three_way_merge(engine, "lineitem", snaps[w],
                                      base=sn0, mode=ConflictMode.ACCEPT)
                t_merges.append(time.perf_counter() - t0)
                conflicts += rep.true_conflicts
            out.append({
                "op": f"Collab{'PK' if pk else 'NoPK'}",
                "overlap": overlap, "change": cname,
                "diff_avg_s": float(np.mean(t_diffs)),
                "merge_avg_s": float(np.mean(t_merges)),
                "merge_times": [round(t, 4) for t in t_merges],
                "true_conflicts": conflicts,
            })
    return out
