"""Benchmark driver — one function per paper table.

Prints ``name,us_per_call,derived`` CSV per benchmark row, plus the
roofline table from the latest dry-run artifacts if present.

  PYTHONPATH=src python -m benchmarks.run [--rows N] [--quick]

Perf-claim protocol (ROADMAP): this container's timings swing ±30-100%
run to run, so before/after comparisons must use ``--repeat`` (min-fold)
AND ``--interleave OLD_CHECKOUT`` — each repeat runs the baseline tree
and the current tree back to back in subprocesses, so machine-state drift
hits both sides equally instead of masquerading as a regression.
"""
import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile


_HOTPATH_METRICS = ("diff_cold_s", "diff_warm_s", "merge_s")
_WORKFLOW_METRICS = ("branch_s", "pr_diff_s", "publish_s", "revert_s")
_PROBE_METRICS = ("probe_s",)
_COLDSTORE_METRICS = ("spill_s", "evict_s", "diff_fault_s",
                      "diff_warm_s", "merge_fault_s")


def _row_metrics(row_or_op):
    op = row_or_op if isinstance(row_or_op, str) else row_or_op["op"]
    if op.startswith("Workflow"):
        return _WORKFLOW_METRICS
    if op.startswith("Probe"):
        return _PROBE_METRICS
    if op.startswith("Coldstore"):
        return _COLDSTORE_METRICS
    return _HOTPATH_METRICS


def _environment() -> dict:
    """Provenance header for every BENCH json (ISSUE 10 satellite): two
    artifacts are only comparable when this block matches."""
    import platform
    env = {"platform": platform.platform(),
           "python": platform.python_version(),
           "jax_platforms": os.environ.get("JAX_PLATFORMS", "")}
    try:
        import numpy
        env["numpy"] = numpy.__version__
    except ImportError:  # pragma: no cover
        env["numpy"] = None
    try:
        import jax
        env["jax"] = jax.__version__
    except ImportError:
        env["jax"] = None
    try:
        from repro.core.wal import CRC32C_IMPL
        env["crc32c"] = CRC32C_IMPL
    except ImportError:  # pragma: no cover
        env["crc32c"] = None
    return env


def _run_hotpath_subprocess(root: str, n_rows: int) -> list:
    """One hotpath+workflow pass of the tree at ``root`` (its own
    benchmarks/ and src/), returning the raw result rows."""
    tmp = tempfile.mkdtemp(prefix="bench_ab_")
    try:
        out = os.path.join(tmp, "rows.json")
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + ((os.pathsep + env["PYTHONPATH"])
                                     if env.get("PYTHONPATH") else "")
        subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--hotpath-only",
             "--rows", str(n_rows), "--json", out],
            cwd=root, env=env, check=True, stdout=subprocess.DEVNULL)
        with open(out) as f:
            return json.load(f)["results"]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _min_fold(acc, rows):
    if acc is None:
        return rows
    by_key = {(r["op"], r["change"]): r for r in acc}
    for r2 in rows:
        r = by_key.get((r2["op"], r2["change"]))
        if r is None:
            acc.append(r2)
            continue
        for m in _row_metrics(r) + ("diff_warm_avg_s",):
            if m in r and m in r2:
                r[m] = min(r[m], r2[m])
    return acc


def _run_interleaved(baseline_root: str, n_rows: int, repeat: int):
    """Alternate baseline/current per repeat, min-folding each side."""
    old_rows = new_rows = None
    for rep in range(repeat):
        print(f"# interleave {rep + 1}/{repeat}: baseline "
              f"({baseline_root})", flush=True)
        old_rows = _min_fold(old_rows,
                             _run_hotpath_subprocess(baseline_root, n_rows))
        print(f"# interleave {rep + 1}/{repeat}: current", flush=True)
        new_rows = _min_fold(new_rows, _run_hotpath_subprocess(".", n_rows))
    old_by_key = {(r["op"], r["change"]): r for r in old_rows}
    for r in new_rows:
        old = old_by_key.get((r["op"], r["change"]))
        line = f"A/B {r['op']}/{r['change']}:"
        for m in _row_metrics(r):
            if old is None or m not in old or m not in r:
                continue
            ratio = old[m] / r[m] if r[m] > 0 else float("inf")
            line += (f" {m[:-2]} {old[m]*1e3:.1f}->{r[m]*1e3:.1f}ms"
                     f" ({ratio:.2f}x)")
        print(line, flush=True)
    return old_rows, new_rows


def _fold_hotpath_trajectory(prev_path, n_rows, rows, note):
    """Fold a fresh hotpath/workflow run into the committed before/after
    shape.

    ``before`` comes from the previous BENCH json — its ``after`` block when
    it is itself a trajectory file, its raw metrics otherwise — so each PR's
    committed file always compares against the immediately preceding engine
    (ROADMAP: keep ``BENCH_vcs.json`` monotone). Rows the previous file
    lacks (a freshly added scenario) enter as raw metrics and seed the next
    PR's ``before``."""
    with open(prev_path) as f:
        prev = json.load(f)
    prev_by_key = {}
    for r in prev.get("results", []):
        op = r.get("op") or f"HotDiffMerge{r['mode']}"
        src = r.get("after", r)
        prev_by_key[(op, r["change"])] = {
            m: src[m] for m in _row_metrics(op) if m in src}
    results = []
    for r in rows:
        metrics = _row_metrics(r)
        before = prev_by_key.get((r["op"], r["change"]))
        after = {m: r[m] for m in metrics}
        entry = {"op": r["op"], "change": r["change"], "rows": r["rows"],
                 "changed_rows": r["changed_rows"]}
        if before:
            entry["before"] = before
            entry["after"] = after
            for m in metrics:
                if m in before and after[m] > 0:
                    entry[f"speedup_{m[:-2]}"] = round(before[m] / after[m], 2)
        else:
            entry.update(after)
        if "counters" in r:
            # per-case registry snapshot (ISSUE 8) — carried verbatim;
            # _min_fold only folds the timing metrics above
            entry["counters"] = r["counters"]
        results.append(entry)
    out = {"bench": "diff_merge_hotpath", "rows": n_rows,
           "env": _environment(),
           "change_sets": {r["change"]: r["changed_rows"] for r in rows},
           "results": results}
    if note:
        out["note"] = note
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=None,
                    help="base table rows (default 2M; --quick = 200k)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-collab", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (e.g. BENCH_vcs.json)")
    ap.add_argument("--hotpath-only", action="store_true",
                    help="run only the visibility hot-path benchmark")
    ap.add_argument("--compare-to", default=None, metavar="PATH",
                    help="previous hotpath BENCH json: fold the fresh run "
                         "into the before/after trajectory structure "
                         "(before = previous file's after/raw numbers)")
    ap.add_argument("--note", default=None,
                    help="free-form note stored in the --compare-to output")
    ap.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="hotpath only: run N times and keep the per-case "
                         "minimum of each timing (robust against noisy "
                         "shared-tenancy machines)")
    ap.add_argument("--interleave", default=None, metavar="BASELINE_ROOT",
                    help="hotpath only: A/B mode — each repeat runs the "
                         "baseline checkout at BASELINE_ROOT and then this "
                         "tree, back to back in subprocesses (min-fold per "
                         "side). --json folds the result as before=baseline "
                         "mins, after=current mins. This is the required "
                         "protocol for perf claims on this noisy container.")
    args = ap.parse_args()
    n_rows = args.rows or (200_000 if args.quick else 2_000_000)

    from . import vcs_tables as V
    from repro.kernels import ops as _ops
    # force one-time jax backend init OUTSIDE the timed cells: without
    # JAX_PLATFORMS pinned, the first lazy jax.default_backend() pays
    # TPU-plugin probing (hundreds of ms) inside whatever cell hits it
    _ops.backend_uses_pallas()

    if args.interleave:
        if not args.hotpath_only:
            ap.error("--interleave requires --hotpath-only")
        old_rows, rows = _run_interleaved(args.interleave, n_rows,
                                          args.repeat)
        if args.json:
            tf = tempfile.NamedTemporaryFile(
                "w", suffix=".json", delete=False)
            try:
                json.dump({"results": old_rows}, tf)
                tf.close()
                payload = _fold_hotpath_trajectory(tf.name, n_rows, rows,
                                                   args.note)
            finally:
                os.unlink(tf.name)
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
        return

    if args.hotpath_only:
        run_once = lambda: (V.diff_merge_hotpath(n_rows)
                            + V.workflow_scenario(n_rows)
                            + V.probe_scenario(n_rows)
                            + V.coldstore_scenario(n_rows))
        rows = run_once()
        for rep in range(args.repeat - 1):
            print(f"# repeat {rep + 2}/{args.repeat} (min-fold)")
            rows = _min_fold(rows, run_once())
        for r in rows:
            if r["op"].startswith("Probe"):
                c = r.get("counters", {})
                print(f"probe/{r['op']}/{r['change']}: "
                      f"{r['probe_s']*1e3:.1f}ms for {r['changed_rows']} "
                      f"queries (probe.queries={c.get('probe.queries', 0)} "
                      f"hits={c.get('probe.hits', 0)})")
                continue
            if r["op"].startswith("Coldstore"):
                c = r.get("counters", {})
                print(f"coldstore/{r['op']}/{r['change']}: "
                      f"spill {r['spill_s']*1e3:.1f}ms "
                      f"evict {r['evict_s']*1e3:.1f}ms "
                      f"diff fault {r['diff_fault_s']*1e3:.1f}ms "
                      f"warm {r['diff_warm_s']*1e3:.1f}ms "
                      f"merge fault {r['merge_fault_s']*1e3:.1f}ms "
                      f"(store.faults={c.get('store.faults', 0)} "
                      f"spills={c.get('store.spills', 0)})")
                continue
            if r["op"].startswith("Workflow"):
                print(f"workflow/{r['op']}/{r['change']}: "
                      f"branch {r['branch_s']*1e3:.1f}ms "
                      f"diff {r['pr_diff_s']*1e3:.1f}ms "
                      f"publish {r['publish_s']*1e3:.1f}ms "
                      f"revert {r['revert_s']*1e3:.1f}ms")
                continue
            print(f"hotpath/{r['op']}/{r['change']}: "
                  f"diff cold {r['diff_cold_s']*1e3:.1f}ms "
                  f"warm {r['diff_warm_s']*1e3:.1f}ms "
                  f"merge {r['merge_s']*1e3:.1f}ms "
                  f"builds c/w/m={r['visibility_builds_cold']}"
                  f"/{r['visibility_builds_warm']}"
                  f"/{r['visibility_builds_merge']}")
        if args.json:
            payload = {"bench": "diff_merge_hotpath", "rows": n_rows,
                       "env": _environment(), "results": rows}
            if args.compare_to:
                payload = _fold_hotpath_trajectory(
                    args.compare_to, n_rows, rows, args.note)
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
        return

    json_out = {"rows": n_rows, "env": _environment(), "sections": {}}
    print("name,us_per_call,derived")

    # ---- Table 1: clone vs insert
    t1 = V.table1_clone(n_rows)
    json_out["sections"]["table1"] = t1
    for r in t1:
        print(f"table1/{r['op']},{r['time_s']*1e6:.0f},"
              f"space_bytes={r['space_bytes']}")
    sys.stdout.flush()

    # ---- Tables 2/3: diff + merge, builtin vs SQL
    t23 = V.table23_diff_merge(n_rows)
    json_out["sections"]["table23"] = t23
    for r in t23:
        kind = "table2" if r["op"].startswith("Diff") else "table3"
        print(f"{kind}/{r['op']}/{r['change']}/builtin,"
              f"{r['builtin_s']*1e6:.0f},speedup="
              f"{r['sql_s']/max(r['builtin_s'],1e-9):.1f}x")
        print(f"{kind}/{r['op']}/{r['change']}/sql,{r['sql_s']*1e6:.0f},")
    sys.stdout.flush()

    # ---- visibility hot path (ISSUE 1): cold vs warm diffs + counters
    hp = V.diff_merge_hotpath(n_rows)
    json_out["sections"]["hotpath"] = hp
    for r in hp:
        print(f"hotpath/{r['op']}/{r['change']}/diff_warm,"
              f"{r['diff_warm_s']*1e6:.0f},"
              f"cold_us={r['diff_cold_s']*1e6:.0f};"
              f"builds_warm={r['visibility_builds_warm']}")
    sys.stdout.flush()

    # ---- workflow porcelain (ISSUE 3): branch -> PR -> publish -> revert
    wf = V.workflow_scenario(n_rows)
    json_out["sections"]["workflow"] = wf
    for r in wf:
        print(f"workflow/{r['op']}/{r['change']}/publish,"
              f"{r['publish_s']*1e6:.0f},"
              f"branch_us={r['branch_s']*1e6:.0f};"
              f"diff_us={r['pr_diff_s']*1e6:.0f};"
              f"revert_us={r['revert_s']*1e6:.0f}")
    sys.stdout.flush()

    if not args.skip_collab:
        # ---- Tables 4/5: collaborative, no conflicts
        t45 = V.collaborative(n_rows, overlap=0.0)
        json_out["sections"]["table45"] = t45
        for r in t45:
            print(f"table45/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},")
            print(f"table45/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()
        # ---- Tables 6/7: collaborative, 10% overlap conflicts
        t67 = V.collaborative(n_rows, overlap=0.10)
        json_out["sections"]["table67"] = t67
        for r in t67:
            print(f"table67/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},conflicts={r['true_conflicts']}")
            print(f"table67/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_out, f, indent=1)

    # ---- Roofline table (from dry-run artifacts, if present)
    from . import roofline
    print()
    roofline.render("dryrun_results.json")


if __name__ == '__main__':
    main()
